"""Tiled streaming-statistics Pallas kernel.

Computes, per row of a ``[B, T]`` f32 array, eight streaming statistics:

    0: sum        1: sum of squares   2: min            3: max
    4: l1 norm    5: abs-max          6: position-weighted sum (for slope)
    7: element count

The grid tiles ``B`` into ``bm``-row blocks and ``T`` into ``bt``-column
blocks; the output block index depends only on the row-block index, so the
kernel accumulates partial statistics across the ``T`` dimension (the
classic revisited-output reduction schedule).  On a TPU the ``(bm, bt)``
input block is VMEM-resident and statistics reduce on the VPU; here the
kernel is lowered with ``interpret=True`` into plain HLO.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: number of statistics produced per row
STATS = 8


def _kernel(x_ref, o_ref):
    j = pl.program_id(1)
    x = x_ref[...]  # (bm, bt) f32 block
    bm, bt = x.shape
    # Global column positions of this block, used by the position-weighted
    # sum so the statistic is tiling-invariant.
    pos = jnp.float32(j * bt) + jax.lax.broadcasted_iota(jnp.float32, x.shape, 1)
    part = jnp.stack(
        [
            jnp.sum(x, axis=1),
            jnp.sum(x * x, axis=1),
            jnp.min(x, axis=1),
            jnp.max(x, axis=1),
            jnp.sum(jnp.abs(x), axis=1),
            jnp.max(jnp.abs(x), axis=1),
            jnp.sum(x * pos, axis=1),
            jnp.full((bm,), bt, jnp.float32),
        ],
        axis=1,
    )  # (bm, STATS)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = part

    @pl.when(j > 0)
    def _accumulate():
        acc = o_ref[...]
        o_ref[...] = jnp.stack(
            [
                acc[:, 0] + part[:, 0],
                acc[:, 1] + part[:, 1],
                jnp.minimum(acc[:, 2], part[:, 2]),
                jnp.maximum(acc[:, 3], part[:, 3]),
                acc[:, 4] + part[:, 4],
                jnp.maximum(acc[:, 5], part[:, 5]),
                acc[:, 6] + part[:, 6],
                acc[:, 7] + part[:, 7],
            ],
            axis=1,
        )


@functools.partial(jax.jit, static_argnames=("bm", "bt"))
def window_stats(x, *, bm: int = 8, bt: int = 128):
    """Per-row streaming statistics of ``x`` (f32 ``[B, T]`` -> ``[B, 8]``).

    ``bm``/``bt`` are the row/column block sizes; both must divide the
    corresponding array dimension.  ``bt`` defaults to the TPU lane width
    (128) and ``bm`` to the f32 sublane count (8).
    """
    b, t = x.shape
    if b % bm or t % bt:
        raise ValueError(f"shape ({b},{t}) not divisible by block ({bm},{bt})")
    grid = (b // bm, t // bt)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bt), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bm, STATS), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, STATS), jnp.float32),
        interpret=True,
    )(x)
