"""Pure-jnp correctness oracles for the Pallas kernels.

Each function here mirrors one kernel's contract exactly, written with
straight-line jnp (no pallas, no tiling) so any discrepancy implicates the
kernel's schedule rather than the math.  pytest compares kernel vs oracle
with ``assert_allclose`` across hypothesis-generated shapes.
"""

import jax
import jax.numpy as jnp


def window_stats_ref(x):
    """Oracle for :func:`..window_stats.window_stats`."""
    b, t = x.shape
    pos = jnp.arange(t, dtype=jnp.float32)[None, :]
    return jnp.stack(
        [
            jnp.sum(x, axis=1),
            jnp.sum(x * x, axis=1),
            jnp.min(x, axis=1),
            jnp.max(x, axis=1),
            jnp.sum(jnp.abs(x), axis=1),
            jnp.max(jnp.abs(x), axis=1),
            jnp.sum(x * pos, axis=1),
            jnp.full((b,), t, jnp.float32),
        ],
        axis=1,
    )


def matmul_ref(x, w, *, activation=None):
    """Oracle for :func:`..matmul.matmul`."""
    out = jnp.dot(x, w, preferred_element_type=jnp.float32)
    if activation is not None:
        out = activation(out)
    return out


def histogram_ref(x, *, nbins=8, lo=-4.0, hi=4.0):
    """Oracle for :func:`..histogram.histogram` (raw counts)."""
    scaled = (jnp.clip(x, lo, hi) - lo) / (hi - lo) * (nbins - 1e-3)
    bins = jnp.floor(scaled).astype(jnp.int32)
    onehot = jax.nn.one_hot(bins, nbins, dtype=jnp.float32)
    return jnp.sum(onehot, axis=1)


def traffic_summary_ref(x, w):
    """Oracle for :func:`..conv1d.traffic_summary`."""
    b, t = x.shape
    (ktaps,) = w.shape
    half = ktaps // 2
    # 'same' FIR with zero padding: smooth[t] = sum_k x[t + k - half] * w[k]
    xp = jnp.pad(x, ((0, 0), (half, half)))
    smooth = jnp.zeros_like(x)
    for tap in range(ktaps):
        smooth = smooth + xp[:, tap : tap + t] * w[tap]
    mean = jnp.mean(smooth, axis=1, keepdims=True)
    var = jnp.mean((smooth - mean) ** 2, axis=1, keepdims=True)
    thresh = mean + 1.5 * jnp.sqrt(var + 1e-9)
    peaks = jnp.sum((smooth > thresh).astype(jnp.float32), axis=1)
    step = smooth[:, 1:] - smooth[:, :-1]
    return jnp.stack(
        [
            peaks,
            jnp.max(smooth, axis=1),
            mean[:, 0],
            jnp.sum(smooth * smooth, axis=1) / t,
            jnp.max(step, axis=1),
            -jnp.min(step, axis=1),
            jnp.mean(x * w[0], axis=1),
            jnp.full((b,), t, jnp.float32),
        ],
        axis=1,
    )
