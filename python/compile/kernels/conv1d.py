"""Depthwise 1-D convolution + peak-detection summary Pallas kernel.

Smooths each row of a ``[B, T]`` signal with a ``K``-tap FIR filter
(zero-padded boundaries) and emits an 8-wide per-row summary:

    0: peak count (smoothed value > mean + 1.5 sigma)
    1: max smoothed value        2: mean smoothed value
    3: smoothed energy / T       4: max upward step
    5: max downward step         6: first-tap response mean
    7: T (element count)

The block covers the full time axis (T is small enough to be VMEM-resident)
so the halo exchange a T-tiled schedule would need is avoided; rows are
tiled by ``bm``.  The K taps unroll statically into shift-mask-multiply
steps, which XLA fuses into a single elementwise pipeline.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: number of summary statistics produced per row
TRAFFIC_STATS = 8


def _make_kernel(ktaps: int):
    half = ktaps // 2

    def kernel(x_ref, w_ref, o_ref):
        x = x_ref[...]  # (bm, T)
        w = w_ref[...]  # (1, K)
        bm, t = x.shape
        idx = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
        smooth = jnp.zeros_like(x)
        for tap in range(ktaps):  # static unroll
            shift = tap - half
            rolled = jnp.roll(x, -shift, axis=1)  # rolled[t] = x[t + shift]
            valid = (idx + shift >= 0) & (idx + shift <= t - 1)
            smooth = smooth + jnp.where(valid, rolled, 0.0) * w[0, tap]
        mean = jnp.mean(smooth, axis=1, keepdims=True)
        var = jnp.mean((smooth - mean) ** 2, axis=1, keepdims=True)
        thresh = mean + 1.5 * jnp.sqrt(var + 1e-9)
        peaks = jnp.sum((smooth > thresh).astype(jnp.float32), axis=1)
        step = smooth[:, 1:] - smooth[:, :-1]
        o_ref[...] = jnp.stack(
            [
                peaks,
                jnp.max(smooth, axis=1),
                mean[:, 0],
                jnp.sum(smooth * smooth, axis=1) / t,
                jnp.max(step, axis=1),
                -jnp.min(step, axis=1),
                jnp.mean(x * w[0, 0], axis=1),
                jnp.full((bm,), t, jnp.float32),
            ],
            axis=1,
        )

    return kernel


def traffic_summary(x, w, *, bm: int = 8):
    """FIR-smooth ``x`` (``[B, T]``) with taps ``w`` (``[K]``) and summarize.

    Returns f32 ``[B, 8]`` per-row summaries (see module docstring).
    """
    b, t = x.shape
    (ktaps,) = w.shape
    if b % bm:
        raise ValueError(f"batch {b} not divisible by row block {bm}")
    if ktaps % 2 == 0:
        raise ValueError("tap count must be odd")
    w2 = w.reshape(1, ktaps)
    return pl.pallas_call(
        _make_kernel(ktaps),
        grid=(b // bm,),
        in_specs=[
            pl.BlockSpec((bm, t), lambda i: (i, 0)),
            pl.BlockSpec((1, ktaps), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, TRAFFIC_STATS), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, TRAFFIC_STATS), jnp.float32),
        interpret=True,
    )(x, w2)
