"""Layer-1 Pallas kernels for Provuse function compute bodies.

Every kernel is written TPU-idiomatically (VMEM-sized blocks, MXU-aligned
matmul tiles, BlockSpec-expressed HBM<->VMEM schedules) but lowered with
``interpret=True`` so the resulting HLO executes on the CPU PJRT client used
by the Rust runtime.  Correctness oracles live in :mod:`ref`.
"""

from .window_stats import window_stats, STATS
from .matmul import matmul
from .conv1d import traffic_summary, TRAFFIC_STATS
from .histogram import histogram, NBINS

__all__ = [
    "window_stats",
    "matmul",
    "traffic_summary",
    "histogram",
    "STATS",
    "TRAFFIC_STATS",
    "NBINS",
]
