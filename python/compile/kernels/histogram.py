"""Binned-histogram Pallas kernel (the `persist` body's digest).

Computes, per row of a ``[B, T]`` f32 array, an ``NBINS``-bin histogram of
values clipped to ``[lo, hi)``.  TPU adaptation: histograms are
scatter-shaped on GPUs (atomics into bins); on a TPU the idiomatic form is
a dense compare-and-reduce — each bin is a vectorized mask-sum on the VPU,
statically unrolled over the (small, constant) bin count.  The grid tiles
rows by ``bm`` and time by ``bt`` with the revisited-output accumulation
schedule (same as window_stats).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: number of histogram bins
NBINS = 8


def _make_kernel(nbins: int, lo: float, hi: float):
    def kernel(x_ref, o_ref):
        j = pl.program_id(1)
        x = x_ref[...]  # (bm, bt)
        scaled = (jnp.clip(x, lo, hi) - lo) / (hi - lo) * (nbins - 1e-3)
        bin_idx = jnp.floor(scaled)
        # dense compare-and-reduce per bin (static unroll, VPU-friendly)
        part = jnp.stack(
            [
                jnp.sum((bin_idx == float(k)).astype(jnp.float32), axis=1)
                for k in range(nbins)
            ],
            axis=1,
        )  # (bm, nbins)

        @pl.when(j == 0)
        def _init():
            o_ref[...] = part

        @pl.when(j > 0)
        def _accumulate():
            o_ref[...] += part

    return kernel


@functools.partial(jax.jit, static_argnames=("nbins", "lo", "hi", "bm", "bt"))
def histogram(
    x,
    *,
    nbins: int = NBINS,
    lo: float = -4.0,
    hi: float = 4.0,
    bm: int = 8,
    bt: int = 128,
):
    """Per-row clipped histogram of ``x`` (f32 ``[B, T]`` -> ``[B, nbins]``,
    raw counts).  ``bm``/``bt`` must divide the array dimensions."""
    b, t = x.shape
    if b % bm or t % bt:
        raise ValueError(f"shape ({b},{t}) not divisible by block ({bm},{bt})")
    grid = (b // bm, t // bt)
    return pl.pallas_call(
        _make_kernel(nbins, lo, hi),
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bt), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bm, nbins), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, nbins), jnp.float32),
        interpret=True,
    )(x)
