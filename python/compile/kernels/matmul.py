"""K-tiled matmul Pallas kernel with a fused activation epilogue.

The schedule is the canonical MXU-friendly blocked matmul: the grid is
``(M/bm, N/bn, K/bk)`` with the K dimension innermost, the output block is
revisited across K steps and acts as the accumulator (f32 accumulation via
``preferred_element_type``), and the optional activation is applied once on
the final K step so it fuses into the epilogue instead of costing an extra
pass over HBM.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _make_kernel(activation):
    def kernel(x_ref, w_ref, o_ref):
        kk = pl.program_id(2)
        nk = pl.num_programs(2)

        @pl.when(kk == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        o_ref[...] += jnp.dot(
            x_ref[...], w_ref[...], preferred_element_type=jnp.float32
        )

        if activation is not None:

            @pl.when(kk == nk - 1)
            def _epilogue():
                o_ref[...] = activation(o_ref[...])

    return kernel


def matmul(x, w, *, activation=None, bm: int = 8, bn: int = 128, bk: int = 128):
    """Blocked ``x @ w`` (f32) with an optional fused activation epilogue.

    ``x``: ``[M, K]``, ``w``: ``[K, N]``.  Block sizes are clamped to the
    array dimensions; all dimensions must be divisible by their (clamped)
    block size.  ``activation`` is a jnp-level elementwise function (e.g.
    ``jax.nn.relu``) applied to the final accumulator.
    """
    m, k = x.shape
    k2, n = w.shape
    if k != k2:
        raise ValueError(f"contraction mismatch: {x.shape} @ {w.shape}")
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    if m % bm or n % bn or k % bk:
        raise ValueError(f"({m},{k})@({k},{n}) not divisible by ({bm},{bn},{bk})")
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _make_kernel(activation),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w)
