"""AOT lowering: JAX compute bodies -> HLO text artifacts for the Rust runtime.

Interchange format is **HLO text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids, so text round-trips cleanly (see /opt/xla-example/README.md).

For every body in :data:`model.BODIES` this writes

    artifacts/<name>.hlo.txt      the lowered module (return_tuple=True)
    artifacts/golden/<name>.json  deterministic input + expected output

plus ``artifacts/manifest.json`` describing the whole set.  The Rust side
(`runtime::ArtifactSet`) loads the manifest, compiles every module once, and
verifies numeric parity against the goldens (`provuse validate-artifacts`).

Usage:  cd python && python -m compile.aot --out ../artifacts
"""

import argparse
import json
import os

import numpy as np
import jax
from jax._src.lib import xla_client as xc

from . import model

SCHEMA_VERSION = 1


def to_hlo_text(lowered) -> str:
    """Convert a jax Lowered to XLA HLO text via stablehlo.

    ``print_large_constants=True`` is essential: the default printer elides
    big dense literals as ``constant({...})``, which the xla crate's text
    parser silently turns into zeros — every baked weight matrix would
    vanish on the Rust side.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    text = comp.as_hlo_text(print_large_constants=True)
    assert "constant({...})" not in text, "elided constants survived printing"
    return text


def lower_body(name: str) -> str:
    fn = model.BODIES[name]
    spec = jax.ShapeDtypeStruct((model.BATCH, model.IN_DIM), np.float32)
    return to_hlo_text(jax.jit(fn).lower(spec))


def golden_case(name: str):
    fn = model.BODIES[name]
    x = model.golden_input(name)
    y = np.asarray(jax.jit(fn)(x))
    return x, y


def build(out_dir: str, names=None) -> dict:
    names = list(names or model.BODIES)
    golden_dir = os.path.join(out_dir, "golden")
    os.makedirs(golden_dir, exist_ok=True)

    entries = []
    for name in names:
        hlo = lower_body(name)
        hlo_rel = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, hlo_rel), "w") as f:
            f.write(hlo)

        x, y = golden_case(name)
        golden_rel = os.path.join("golden", f"{name}.json")
        with open(os.path.join(out_dir, golden_rel), "w") as f:
            json.dump(
                {
                    "name": name,
                    "input": [float(v) for v in x.ravel()],
                    "output": [float(v) for v in y.ravel()],
                },
                f,
            )
        entries.append(
            {
                "name": name,
                "hlo": hlo_rel,
                "golden": golden_rel,
                "input_shape": [model.BATCH, model.IN_DIM],
                "output_shape": [int(d) for d in y.shape],
            }
        )
        print(f"  lowered {name:>16s}: {len(hlo):7d} chars, out {list(y.shape)}")

    manifest = {
        "schema": SCHEMA_VERSION,
        "batch": model.BATCH,
        "in_dim": model.IN_DIM,
        "out_dim": model.OUT_DIM,
        "bodies": entries,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--only", nargs="*", help="subset of body names")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    manifest = build(args.out, args.only)
    print(f"wrote {len(manifest['bodies'])} artifacts to {args.out}")


if __name__ == "__main__":
    main()
