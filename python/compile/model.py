"""Layer-2 JAX compute bodies for the Provuse benchmark functions.

Each FaaS function in the TREE / IOT benchmark applications carries a real
compute payload.  This module defines those payloads as JAX graphs composed
from the Layer-1 Pallas kernels, with a **uniform signature**

    f32[BATCH, IN_DIM]  ->  f32[BATCH, OUT_DIM]

so the Rust runtime can execute any body generically and thread outputs of
one function into inputs of the next (padding / tiling is done Rust-side).

Weights are baked in as constants from a fixed seed: the platform never
manages parameters (the paper's functions are self-contained code bundles),
and baked constants keep the AOT artifacts single-input.

TPU adaptation note (DESIGN.md §Hardware-Adaptation): the temperature body
computes its exponential moving average as a matmul against a precomputed
lower-triangular decay matrix — an MXU-shaped reformulation of what a GPU
implementation would express as a sequential scan.
"""

import numpy as np
import jax
import jax.numpy as jnp

from .kernels import histogram, matmul, traffic_summary, window_stats

#: uniform body signature
BATCH = 8
IN_DIM = 256
OUT_DIM = 8

_WEIGHT_SEED = 20260710


def _rng():
    return np.random.RandomState(_WEIGHT_SEED)


def _w(rs, shape, scale=None):
    scale = scale if scale is not None else 1.0 / np.sqrt(shape[0])
    return jnp.asarray(rs.randn(*shape).astype(np.float32) * scale)


def _ewma_matrix(t: int, alpha: float = 0.08) -> jnp.ndarray:
    """Lower-triangular decay matrix L with (x @ L)[b, t] = EWMA_t(x[b])."""
    idx = np.arange(t)
    # L[s, t] = alpha * (1 - alpha)^(t - s) for s <= t (column-causal).
    expo = idx[None, :] - idx[:, None]
    mat = alpha * np.power(1.0 - alpha, np.clip(expo, 0, None))
    mat = np.where(expo >= 0, mat, 0.0)
    # Row 0 keeps full initial mass so the EWMA is exact, not leaky.
    mat[0, :] = np.power(1.0 - alpha, idx)
    return jnp.asarray(mat.astype(np.float32))


def _normalize_rows(s):
    return s / (1.0 + jnp.abs(s))


# --------------------------------------------------------------------------
# IOT application bodies (Fig. 3)
# --------------------------------------------------------------------------

def body_analyze_sensor(x):
    """Entry point I: clip raw sensor batch, compute streaming statistics."""
    x = jnp.clip(x, -5.0, 5.0)
    return _normalize_rows(window_stats(x))


def body_parse(x):
    """Decode/rescale raw payload, then summarize."""
    y = jnp.tanh(x * 0.1 + 0.05)
    return _normalize_rows(window_stats(y, bt=64))


def _mlp_head(feats, rs, hidden):
    w1 = _w(rs, (feats.shape[1], hidden))
    w2 = _w(rs, (hidden, OUT_DIM))
    h = matmul(feats, w1, activation=jax.nn.relu, bn=hidden, bk=feats.shape[1])
    return matmul(h, w2, bk=hidden)


def body_temperature(x):
    """EWMA-as-matmul trend extraction + anomaly-scoring MLP.

    Perf note (EXPERIMENTS.md §Perf L1-1): the 256-wide matmuls use
    bn=bk=256 single-step grids — interpret-mode lowering emits a while
    loop + dynamic-update-slice per grid step, so on the CPU-PJRT path
    fewer/larger blocks win; the blocks remain VMEM-resident (~264 KiB)
    and lane-aligned on a real TPU.
    """
    rs = _rng()
    ewma = matmul(x, _ewma_matrix(IN_DIM), bn=256, bk=256)  # (B, 256) trend
    proj = matmul(ewma, _w(rs, (IN_DIM, 128)), activation=jax.nn.relu, bk=256)
    return jnp.tanh(_mlp_head(proj, rs, 256))


def body_airquality(x):
    """Magnitude-feature anomaly scorer (different widths than temperature)."""
    rs = np.random.RandomState(_WEIGHT_SEED + 1)
    feats = matmul(jnp.abs(x), _w(rs, (IN_DIM, 128)), activation=jax.nn.relu, bk=256)
    h = matmul(feats, _w(rs, (128, 128)), activation=jax.nn.relu)
    return jnp.tanh(matmul(h, _w(rs, (128, OUT_DIM))))


def body_traffic(x):
    """FIR smoothing + peak detection via the conv1d kernel."""
    taps = jnp.asarray(
        np.array([1, 4, 8, 12, 14, 12, 8, 4, 1], dtype=np.float32) / 64.0
    )
    return _normalize_rows(traffic_summary(x, taps))


def body_aggregate(x):
    """Combine upstream analysis scores into a routing decision vector."""
    rs = np.random.RandomState(_WEIGHT_SEED + 2)
    z = matmul(x, _w(rs, (IN_DIM, 64)), activation=jax.nn.relu, bk=256)
    o = matmul(z, _w(rs, (64, OUT_DIM)))
    return jax.nn.softmax(o, axis=1)


def body_persist(x):
    """Quantized digest (8-bin per-row histogram) of the stored payload,
    via the compare-and-reduce Pallas histogram kernel."""
    return histogram(x, nbins=OUT_DIM) / x.shape[1]


def body_notify(x):
    """Cheap notification formatting: bounded summary of the trigger."""
    return jnp.tanh(window_stats(x) * 0.01)


# --------------------------------------------------------------------------
# TREE application bodies (Fig. 4)
# --------------------------------------------------------------------------

def body_tree_light(x):
    """Light synchronous-branch payload (nodes A, B, D, E)."""
    return _normalize_rows(window_stats(x))


def body_tree_heavy(x):
    """Heavy asynchronous-branch payload (nodes C, F, G).

    Fig. 4: 'The asynchronous path dominates the workload, requiring far
    more computation than the synchronous branch.'
    """
    rs = np.random.RandomState(_WEIGHT_SEED + 3)
    h = x
    for layer in range(3):
        # single-step grid per layer: see body_temperature perf note
        h = matmul(h, _w(rs, (IN_DIM, IN_DIM)), activation=jax.nn.relu, bn=256, bk=256)
    return jnp.tanh(matmul(h, _w(rs, (IN_DIM, OUT_DIM)), bk=256))


#: registry of every AOT-compiled compute body, keyed by artifact name
BODIES = {
    "analyze_sensor": body_analyze_sensor,
    "parse": body_parse,
    "temperature": body_temperature,
    "airquality": body_airquality,
    "traffic": body_traffic,
    "aggregate": body_aggregate,
    "persist": body_persist,
    "notify": body_notify,
    "tree_light": body_tree_light,
    "tree_heavy": body_tree_heavy,
}


def golden_input(name: str) -> np.ndarray:
    """Deterministic per-body input used for cross-layer parity checks."""
    import zlib

    # crc32 is stable across processes (python hash() is salted).
    seed = zlib.crc32(name.encode()) & 0x7FFFFFFF
    rs = np.random.RandomState(seed)
    return rs.randn(BATCH, IN_DIM).astype(np.float32)
