"""L2 checks: every compute body obeys the uniform contract and is
deterministic, finite, and non-degenerate."""

import numpy as np
import jax
import pytest
from numpy.testing import assert_allclose

from compile import model


@pytest.fixture(scope="module")
def jitted():
    return {name: jax.jit(fn) for name, fn in model.BODIES.items()}


@pytest.mark.parametrize("name", sorted(model.BODIES))
def test_body_contract(name, jitted):
    x = model.golden_input(name)
    y = np.asarray(jitted[name](x))
    assert y.shape == (model.BATCH, model.OUT_DIM), name
    assert y.dtype == np.float32
    assert np.all(np.isfinite(y)), f"{name} produced non-finite values"


@pytest.mark.parametrize("name", sorted(model.BODIES))
def test_body_deterministic(name, jitted):
    x = model.golden_input(name)
    y1 = np.asarray(jitted[name](x))
    y2 = np.asarray(jitted[name](x))
    assert_allclose(y1, y2, rtol=0, atol=0)


@pytest.mark.parametrize("name", sorted(model.BODIES))
def test_body_input_sensitive(name, jitted):
    """Bodies must actually depend on their input (no constant folding)."""
    x = model.golden_input(name)
    y1 = np.asarray(jitted[name](x))
    y2 = np.asarray(jitted[name](x + 0.37))
    assert not np.allclose(y1, y2), f"{name} ignores its input"


def test_golden_input_stable():
    a = model.golden_input("temperature")
    b = model.golden_input("temperature")
    assert_allclose(a, b, rtol=0, atol=0)
    c = model.golden_input("traffic")
    assert not np.allclose(a, c)


def test_aggregate_rows_are_distributions(jitted):
    y = np.asarray(jitted["aggregate"](model.golden_input("aggregate")))
    assert np.all(y >= 0)
    assert_allclose(y.sum(axis=1), 1.0, rtol=1e-5)


def test_persist_histogram_mass(jitted):
    y = np.asarray(jitted["persist"](model.golden_input("persist")))
    # Each row is a normalized 8-bin histogram over IN_DIM samples.
    assert np.all(y >= 0)
    assert_allclose(y.sum(axis=1), 1.0, rtol=1e-5)


def test_tree_heavy_costlier_than_light():
    """The async TREE branch must dominate compute (Fig. 4 caption)."""
    light = jax.jit(model.BODIES["tree_light"]).lower(
        jax.ShapeDtypeStruct((model.BATCH, model.IN_DIM), np.float32)
    ).compile()
    heavy = jax.jit(model.BODIES["tree_heavy"]).lower(
        jax.ShapeDtypeStruct((model.BATCH, model.IN_DIM), np.float32)
    ).compile()
    lf = light.cost_analysis()
    hf = heavy.cost_analysis()
    if lf and hf and "flops" in lf and "flops" in hf:
        assert hf["flops"] > 10 * lf["flops"]


def test_ewma_matrix_is_causal_and_normalized():
    mat = np.asarray(model._ewma_matrix(32, alpha=0.1))
    assert mat.shape == (32, 32)
    assert np.allclose(mat[np.tril_indices(32, -1)], 0.0)  # strictly-lower = 0
    # Columns sum to 1: EWMA of a constant signal is that constant.
    assert_allclose(mat.sum(axis=0), 1.0, rtol=1e-5)
