"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

hypothesis sweeps shapes (and block sizes where the kernel exposes them);
assert_allclose is the CORE correctness signal for the compute layer.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import histogram, matmul, traffic_summary, window_stats
from compile.kernels.ref import (
    histogram_ref,
    matmul_ref,
    traffic_summary_ref,
    window_stats_ref,
)

SETTINGS = settings(max_examples=25, deadline=None)


def _arr(rng, shape, scale=2.0):
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32) * scale)


# ---------------------------------------------------------------- window_stats

@SETTINGS
@given(
    b_blocks=st.integers(1, 3),
    t_blocks=st.integers(1, 4),
    bt=st.sampled_from([32, 64, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_window_stats_matches_ref(b_blocks, t_blocks, bt, seed):
    rng = np.random.default_rng(seed)
    x = _arr(rng, (8 * b_blocks, bt * t_blocks))
    got = window_stats(x, bm=8, bt=bt)
    want = window_stats_ref(x)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4)


def test_window_stats_tiling_invariance():
    rng = np.random.default_rng(7)
    x = _arr(rng, (8, 256))
    a = window_stats(x, bt=32)
    b = window_stats(x, bt=128)
    c = window_stats(x, bt=256)
    assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-4)
    assert_allclose(np.asarray(b), np.asarray(c), rtol=1e-5, atol=1e-4)


def test_window_stats_rejects_bad_blocks():
    x = jnp.zeros((8, 100), jnp.float32)
    with pytest.raises(ValueError):
        window_stats(x, bt=64)


def test_window_stats_constant_rows():
    x = jnp.full((8, 128), 3.0, jnp.float32)
    s = np.asarray(window_stats(x))
    assert_allclose(s[:, 0], 3.0 * 128)          # sum
    assert_allclose(s[:, 2], 3.0)                # min
    assert_allclose(s[:, 3], 3.0)                # max
    assert_allclose(s[:, 7], 128.0)              # count


# --------------------------------------------------------------------- matmul

@SETTINGS
@given(
    m=st.sampled_from([8, 16]),
    k=st.sampled_from([64, 128, 256]),
    n=st.sampled_from([8, 64, 128, 256]),
    act=st.sampled_from([None, "relu", "tanh"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, act, seed):
    rng = np.random.default_rng(seed)
    x = _arr(rng, (m, k), scale=1.0)
    w = _arr(rng, (k, n), scale=0.1)
    activation = {None: None, "relu": jax.nn.relu, "tanh": jnp.tanh}[act]
    got = matmul(x, w, activation=activation)
    want = matmul_ref(x, w, activation=activation)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@SETTINGS
@given(bk=st.sampled_from([32, 64, 128, 256]), seed=st.integers(0, 2**31 - 1))
def test_matmul_k_tiling_invariance(bk, seed):
    rng = np.random.default_rng(seed)
    x = _arr(rng, (8, 256), scale=1.0)
    w = _arr(rng, (256, 64), scale=0.1)
    got = matmul(x, w, bk=bk)
    want = matmul_ref(x, w)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_matmul_shape_mismatch():
    with pytest.raises(ValueError):
        matmul(jnp.zeros((8, 64)), jnp.zeros((32, 8)))


def test_matmul_identity():
    x = _arr(np.random.default_rng(0), (8, 64), scale=1.0)
    eye = jnp.eye(64, dtype=jnp.float32)
    assert_allclose(np.asarray(matmul(x, eye)), np.asarray(x), rtol=1e-6)


# ------------------------------------------------------------ traffic_summary

@SETTINGS
@given(
    b_blocks=st.integers(1, 3),
    t=st.sampled_from([64, 128, 256, 512]),
    ktaps=st.sampled_from([3, 5, 9]),
    seed=st.integers(0, 2**31 - 1),
)
def test_traffic_summary_matches_ref(b_blocks, t, ktaps, seed):
    rng = np.random.default_rng(seed)
    x = _arr(rng, (8 * b_blocks, t))
    w = jnp.asarray(rng.standard_normal(ktaps, dtype=np.float32) * 0.2)
    got = traffic_summary(x, w)
    want = traffic_summary_ref(x, w)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_traffic_summary_rejects_even_taps():
    with pytest.raises(ValueError):
        traffic_summary(jnp.zeros((8, 64)), jnp.zeros((4,)))


# ------------------------------------------------------------------ histogram

@SETTINGS
@given(
    b_blocks=st.integers(1, 3),
    t_blocks=st.integers(1, 4),
    bt=st.sampled_from([32, 64, 128]),
    nbins=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_histogram_matches_ref(b_blocks, t_blocks, bt, nbins, seed):
    rng = np.random.default_rng(seed)
    x = _arr(rng, (8 * b_blocks, bt * t_blocks), scale=3.0)
    got = histogram(x, nbins=nbins, bm=8, bt=bt)
    want = histogram_ref(x, nbins=nbins)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=0)


def test_histogram_mass_conservation():
    rng = np.random.default_rng(1)
    x = _arr(rng, (8, 256), scale=10.0)  # plenty of clipping
    h = np.asarray(histogram(x))
    assert_allclose(h.sum(axis=1), 256.0)
    assert np.all(h >= 0)


def test_histogram_tiling_invariance():
    rng = np.random.default_rng(2)
    x = _arr(rng, (8, 256))
    a = histogram(x, bt=32)
    b = histogram(x, bt=256)
    assert_allclose(np.asarray(a), np.asarray(b), rtol=0, atol=0)


def test_histogram_rejects_bad_blocks():
    with pytest.raises(ValueError):
        histogram(jnp.zeros((8, 100)), bt=64)


def test_traffic_summary_impulse():
    """A delta filter must reproduce the input's own statistics."""
    rng = np.random.default_rng(3)
    x = _arr(rng, (8, 128))
    w = jnp.asarray(np.array([0, 0, 0, 0, 1, 0, 0, 0, 0], dtype=np.float32))
    got = np.asarray(traffic_summary(x, w))
    assert_allclose(got[:, 1], np.max(np.asarray(x), axis=1), rtol=1e-5, atol=1e-5)
    assert_allclose(got[:, 2], np.mean(np.asarray(x), axis=1), rtol=1e-4, atol=1e-5)
