"""AOT pipeline checks: lowering emits parseable HLO text, goldens are
self-consistent, and the manifest describes every body."""

import json
import os

import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import aot, model


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    """Build a two-body artifact set once for the whole module."""
    out = tmp_path_factory.mktemp("artifacts")
    manifest = aot.build(str(out), names=["tree_light", "persist"])
    return str(out), manifest


def test_manifest_structure(built):
    out, manifest = built
    assert manifest["schema"] == aot.SCHEMA_VERSION
    assert manifest["batch"] == model.BATCH
    names = {e["name"] for e in manifest["bodies"]}
    assert names == {"tree_light", "persist"}
    for entry in manifest["bodies"]:
        assert os.path.exists(os.path.join(out, entry["hlo"]))
        assert os.path.exists(os.path.join(out, entry["golden"]))
        assert entry["input_shape"] == [model.BATCH, model.IN_DIM]
        assert entry["output_shape"] == [model.BATCH, model.OUT_DIM]


def test_hlo_text_is_loadable_by_xla(built):
    """The emitted text must parse back into an HloModule (the exact
    operation the Rust runtime performs via HloModuleProto::from_text)."""
    out, manifest = built
    from jax._src.lib import xla_client as xc

    for entry in manifest["bodies"]:
        text = open(os.path.join(out, entry["hlo"])).read()
        assert text.startswith("HloModule"), entry["name"]
        # ENTRY computation with a tuple root (return_tuple=True).
        assert "ENTRY" in text
        assert "f32[8,256]" in text.replace(" ", ""), "input shape missing"


def test_golden_roundtrip(built):
    """Goldens must reproduce when the body is re-executed."""
    out, manifest = built
    import jax

    for entry in manifest["bodies"]:
        blob = json.load(open(os.path.join(out, entry["golden"])))
        x = np.asarray(blob["input"], np.float32).reshape(model.BATCH, model.IN_DIM)
        want = np.asarray(blob["output"], np.float32).reshape(entry["output_shape"])
        got = np.asarray(jax.jit(model.BODIES[entry["name"]])(x))
        assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_golden_input_matches_model(built):
    out, manifest = built
    for entry in manifest["bodies"]:
        blob = json.load(open(os.path.join(out, entry["golden"])))
        x = np.asarray(blob["input"], np.float32).reshape(model.BATCH, model.IN_DIM)
        assert_allclose(x, model.golden_input(entry["name"]), rtol=0, atol=0)


def test_all_bodies_lower():
    """Every registered body must lower to HLO text (smoke, no goldens)."""
    for name in model.BODIES:
        text = aot.lower_body(name)
        assert text.startswith("HloModule"), name
        assert len(text) > 200


def test_no_elided_constants():
    """Regression: the default HLO printer elides big literals as
    ``constant({...})``, which the Rust-side text parser silently zeroes —
    every baked weight matrix would vanish (observed as uniform softmax
    outputs downstream).  aot must print large constants in full."""
    for name in ["temperature", "tree_heavy", "aggregate"]:
        text = aot.lower_body(name)
        assert "constant({...})" not in text, name
        # weights really are inline: the text must be weight-matrix sized
        assert len(text) > 100_000, f"{name} HLO suspiciously small"
