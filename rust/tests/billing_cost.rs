//! Billing semantics: fusion must eliminate double billing (paper §2.3,
//! §6 — "mitigates redundant billing effects that arise from chained
//! invocations in fine-grained FaaS pricing models").

use std::rc::Rc;

use provuse::apps;
use provuse::billing::CostModel;
use provuse::config::{ComputeMode, PlatformConfig, WorkloadConfig};
use provuse::exec::{self, run_virtual};
use provuse::platform::Platform;
use provuse::workload;

fn fast_cfg() -> PlatformConfig {
    let mut cfg = PlatformConfig::tiny().with_compute(ComputeMode::Disabled);
    cfg.latency.image_build_ms = 200.0;
    cfg.latency.boot_ms = 100.0;
    cfg.fusion.min_observations = 1;
    cfg
}

fn run_bill(fusion: bool, requests: u64) -> (provuse::billing::Bill, u64) {
    run_virtual(async move {
        let mut cfg = fast_cfg();
        if !fusion {
            cfg = cfg.vanilla();
        }
        let p = Platform::deploy(apps::iot(), cfg).await.unwrap();
        let wl = WorkloadConfig { requests, rate_rps: 10.0, seed: 7, timeout_ms: 60_000.0 };
        let report = workload::run(Rc::clone(&p), wl).await.unwrap();
        exec::sleep_ms(20_000.0).await;
        assert_eq!(report.failed, 0);
        let bill = p.billing.bill();
        p.shutdown();
        (bill, report.ok)
    })
}

#[test]
fn vanilla_bills_every_function_invocation() {
    let (bill, ok) = run_bill(false, 50);
    // IOT issues 15 billed invocations per request: entry + parse +
    // validate + 3 analyses + 3 aggregate calls (one per analysis) +
    // 3 async persists + 3 notifies
    assert_eq!(bill.invocations, 15 * ok);
    assert!(bill.gb_seconds > 0.0);
}

#[test]
fn fusion_eliminates_double_billing() {
    let n = 200;
    let (vanilla, _) = run_bill(false, n);
    let (fused, _) = run_bill(true, n);

    // fewer billed invocations: inlined calls are not metered
    assert!(
        fused.invocations < vanilla.invocations,
        "fused {} !< vanilla {}",
        fused.invocations,
        vanilla.invocations
    );
    // and strictly fewer GiB-seconds: no caller is billed while blocking
    // on a colocated callee
    assert!(
        fused.gb_seconds < 0.7 * vanilla.gb_seconds,
        "fused {:.1} GB-s !< 70% of vanilla {:.1} GB-s",
        fused.gb_seconds,
        vanilla.gb_seconds
    );
    // dollars follow
    let m = CostModel::default();
    assert!(fused.cost(&m) < vanilla.cost(&m));
}

#[test]
fn steady_state_fused_iot_bills_four_invocations_per_request() {
    // after convergence: one billed arrival for the sync group's entry plus
    // three async persist arrivals (aggregate executes once per analysis);
    // notify is inlined inside the persist+notify group — not billed
    run_virtual(async {
        let p = Platform::deploy(apps::iot(), fast_cfg()).await.unwrap();
        // converge first
        let wl = WorkloadConfig { requests: 60, rate_rps: 10.0, seed: 1, timeout_ms: 60_000.0 };
        workload::run(Rc::clone(&p), wl).await.unwrap();
        exec::sleep_ms(30_000.0).await;
        assert_eq!(p.gateway.distinct_instances(), 2);

        let before = p.billing.bill().invocations;
        let payload = workload::request_payload(5, 0, p.payload_len());
        p.invoke(payload).await.unwrap();
        exec::sleep_ms(10_000.0).await; // let async branch finish
        let after = p.billing.bill().invocations;
        assert_eq!(after - before, 4, "steady-state IOT request bills exactly 4 invocations");
        p.shutdown();
    });
}
