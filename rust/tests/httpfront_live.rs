//! Live-TCP integration: the HTTP front end serving a real platform on a
//! real socket (real clock), exercised by an in-process HTTP client.
//! Latencies are scaled down so the whole test runs in a few seconds.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use provuse::apps;
use provuse::config::{ComputeMode, PlatformConfig};

const PORT: u16 = 28417;

fn http(method: &str, path: &str, body: &str) -> std::io::Result<(u16, String)> {
    let mut stream = TcpStream::connect(("127.0.0.1", PORT))?;
    stream.set_read_timeout(Some(Duration::from_secs(20)))?;
    write!(
        stream,
        "{method} {path} HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )?;
    let mut reader = BufReader::new(stream);
    let mut status = String::new();
    reader.read_line(&mut status)?;
    let code = status.split_whitespace().nth(1).unwrap_or("0").parse().unwrap_or(0);
    let mut len = 0usize;
    loop {
        let mut line = String::new();
        reader.read_line(&mut line)?;
        if line.trim().is_empty() {
            break;
        }
        if let Some(v) = line.to_ascii_lowercase().strip_prefix("content-length:") {
            len = v.trim().parse().unwrap_or(0);
        }
    }
    let mut body = vec![0u8; len];
    reader.read_exact(&mut body)?;
    Ok((code, String::from_utf8_lossy(&body).into_owned()))
}

fn wait_up() {
    for _ in 0..300 {
        if http("GET", "/healthz", "").map(|(c, _)| c == 200).unwrap_or(false) {
            return;
        }
        std::thread::sleep(Duration::from_millis(50));
    }
    panic!("http front end did not come up");
}

#[test]
fn http_front_end_serves_invokes_metrics_routes_and_shuts_down() {
    let server = std::thread::spawn(|| {
        let mut config = PlatformConfig::tiny()
            .with_compute(ComputeMode::Disabled)
            .scale_latency(0.02);
        config.fusion.min_observations = 1;
        provuse::httpfront::serve(apps::chain(3), config, PORT, None).unwrap();
    });
    wait_up();

    // entry invocations (empty body -> seeded payload)
    for i in 0..4 {
        let (code, body) = http("POST", "/invoke", "").unwrap();
        assert_eq!(code, 200, "request {i}: {body}");
        assert!(body.contains("\"latency_ms\""));
        assert!(body.contains("\"output\""));
    }

    // targeted function invocation with an explicit payload
    let (code, body) = http("POST", "/invoke/s1", "[1.0, 2.0, 3.0]").unwrap();
    assert_eq!(code, 200, "{body}");

    // unknown function -> 500 with an error payload
    let (code, body) = http("POST", "/invoke/ghost", "").unwrap();
    assert_eq!(code, 500);
    assert!(body.contains("error"));

    // unknown path -> 404
    let (code, _) = http("GET", "/nope", "").unwrap();
    assert_eq!(code, 404);

    // metrics reflect the served traffic
    let (code, metrics) = http("GET", "/metrics", "").unwrap();
    assert_eq!(code, 200);
    assert!(metrics.contains("\"requests\""), "{metrics}");
    assert!(metrics.contains("\"median_ms\""));

    // routing table lists every function
    let (code, routes) = http("GET", "/routes", "").unwrap();
    assert_eq!(code, 200);
    for f in ["s0", "s1", "s2"] {
        assert!(routes.contains(f), "{routes}");
    }

    // clean shutdown
    let (code, _) = http("POST", "/shutdown", "").unwrap();
    assert_eq!(code, 200);
    server.join().unwrap();
}
