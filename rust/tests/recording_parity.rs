//! ISSUE 5 golden test: the interned, windowed telemetry pipeline must not
//! change a single platform decision.  The FIG7 `--app mixed` admission
//! scenario (light pair admitted, heavy pair churn-gated, cold pair below
//! threshold) runs twice under a pinned seed — full retention vs windowed
//! retention — and every verdict (admission evaluations with bit-exact
//! scores, merges, splits, evicts) plus a sample of trailing p95 windows
//! must be **bit-identical** across the two recording levels.

use std::rc::Rc;

use provuse::apps;
use provuse::config::{ComputeMode, MergePolicyKind, PlatformConfig, SplitPolicyKind};
use provuse::config::WorkloadConfig;
use provuse::exec::{self, run_virtual};
use provuse::metrics::{RecordingLevel, MIN_WINDOW_SAMPLES};
use provuse::platform::Platform;
use provuse::workload::{self, Arrival};

const SEED: u64 = 77;

fn mixed_config(level: RecordingLevel) -> PlatformConfig {
    let mut cfg = PlatformConfig::tiny()
        .with_compute(ComputeMode::Disabled)
        .with_seed(SEED)
        .with_recording(level);
    cfg.latency.image_build_ms = 300.0;
    cfg.latency.boot_ms = 150.0;
    cfg.fusion.min_observations = 3;
    cfg.fusion.feedback_interval_ms = 1_000.0;
    cfg.fusion.merge_policy = MergePolicyKind::CostModel;
    cfg.fusion.split_policy = SplitPolicyKind::CostModel;
    cfg.fusion.max_group_ram_mb = 256.0;
    cfg
}

struct MixedOutcome {
    /// canonical verdict transcript, f64s rendered bit-exactly
    verdicts: Vec<String>,
    /// trailing-window signals per function, as raw bits
    windows: Vec<(String, u64, u64)>,
    light_group: Vec<String>,
    heavy_group: Vec<String>,
    failures: u64,
}

fn run_mixed(level: RecordingLevel) -> MixedOutcome {
    run_virtual(async move {
        let p = Platform::deploy(apps::by_name("mixed").unwrap(), mixed_config(level))
            .await
            .unwrap();
        let wl = |requests: u64, rate_rps: f64| WorkloadConfig {
            requests,
            rate_rps,
            seed: SEED,
            timeout_ms: 60_000.0,
        };
        let light = exec::spawn(workload::run_targeted(
            Rc::clone(&p),
            wl(300, 15.0),
            Arrival::Constant,
            Some("light_api"),
        ));
        let heavy = exec::spawn(workload::run_targeted(
            Rc::clone(&p),
            wl(300, 15.0),
            Arrival::Constant,
            Some("heavy_api"),
        ));
        let cold = exec::spawn(workload::run_targeted(
            Rc::clone(&p),
            wl(10, 0.5),
            Arrival::Constant,
            Some("cold_api"),
        ));
        let mut failures = 0;
        for handle in [light, heavy, cold] {
            let report = handle.await.unwrap();
            failures += report.failed;
        }
        exec::sleep_ms(15_000.0).await;

        let m = &p.metrics;
        // one transcript definition for every parity check (FIG9 + here)
        let verdicts = provuse::experiments::fig9::verdict_transcript(m);
        // trailing p95 / self-time windows: the controller's own signal
        // reads, sampled at the (deterministic) end of the run
        let now = m.rel_now_ms();
        let from = now - 5_000.0;
        let mut windows = Vec::new();
        for f in ["light_api", "light_fmt", "heavy_api", "heavy_model", "cold_api"] {
            windows.push((
                f.to_string(),
                m.fn_p95_window(f, from, now, MIN_WINDOW_SAMPLES).to_bits(),
                m.fn_self_ms_window(f, from, now).to_bits(),
            ));
        }
        let outcome = MixedOutcome {
            verdicts,
            windows,
            light_group: p.group_members("light_api"),
            heavy_group: p.group_members("heavy_api"),
            failures,
        };
        p.shutdown();
        outcome
    })
}

#[test]
fn mixed_verdicts_and_windows_identical_across_recording_levels() {
    let full = run_mixed(RecordingLevel::Full);
    let windowed = run_mixed(RecordingLevel::Windowed);

    assert_eq!(full.failures, 0, "full-retention run dropped requests");
    assert_eq!(windowed.failures, 0, "windowed run dropped requests");

    // the golden scenario itself: the planner admitted the hot light pair
    // and refused the heavy one
    assert_eq!(
        full.light_group,
        vec!["light_api".to_string(), "light_fmt".to_string()],
        "light pair must fuse under cost admission"
    );
    assert_eq!(
        full.heavy_group,
        vec!["heavy_api".to_string()],
        "heavy pair must stay unfused (churn gate)"
    );
    assert!(
        full.verdicts.iter().any(|v| v.starts_with("admission")),
        "no admission evaluations recorded"
    );

    // the actual golden assertion: recording level changes NOTHING
    assert_eq!(full.verdicts, windowed.verdicts, "fusion verdicts diverged");
    assert_eq!(full.windows, windowed.windows, "trailing window signals diverged");
    assert_eq!(full.light_group, windowed.light_group);
    assert_eq!(full.heavy_group, windowed.heavy_group);
}
