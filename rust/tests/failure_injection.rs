//! Failure injection: the merge pipeline must roll back cleanly and the
//! platform must keep serving from the original instances.

use std::rc::Rc;

use provuse::apps;
use provuse::config::{ComputeMode, PlatformConfig, WorkloadConfig};
use provuse::exec::{self, run_virtual};
use provuse::platform::Platform;
use provuse::workload;

fn fast_cfg() -> PlatformConfig {
    let mut cfg = PlatformConfig::tiny().with_compute(ComputeMode::Disabled);
    cfg.latency.image_build_ms = 300.0;
    cfg.latency.boot_ms = 150.0;
    cfg.fusion.min_observations = 1;
    cfg.fusion.cooldown_ms = 2_000.0;
    cfg
}

#[test]
fn build_failure_rolls_back_and_retries_after_cooldown() {
    run_virtual(async {
        let p = Platform::deploy(apps::chain(2), fast_cfg()).await.unwrap();
        p.containers.inject_build_failures(1);

        // trigger fusion; the first build fails
        let wl = WorkloadConfig { requests: 10, rate_rps: 10.0, seed: 1, timeout_ms: 60_000.0 };
        let report = workload::run(Rc::clone(&p), wl).await.unwrap();
        assert_eq!(report.failed, 0, "requests must survive a failed merge");
        exec::sleep_ms(1_000.0).await;
        assert_eq!(p.metrics.merges().len(), 0);
        assert_eq!(p.metrics.counter("fusion_aborted"), 1);
        // both originals still serving
        assert_eq!(p.gateway.distinct_instances(), 2);
        assert_eq!(p.containers.live_count(), 2);

        // after the cooldown, new observations re-request and succeed
        exec::sleep_ms(2_500.0).await;
        let wl = WorkloadConfig { requests: 10, rate_rps: 10.0, seed: 2, timeout_ms: 60_000.0 };
        workload::run(Rc::clone(&p), wl).await.unwrap();
        exec::sleep_ms(10_000.0).await;
        assert_eq!(p.metrics.merges().len(), 1, "retry after cooldown must fuse");
        assert_eq!(p.gateway.distinct_instances(), 1);
        p.shutdown();
    });
}

#[test]
fn health_timeout_aborts_and_tears_down_the_orphan() {
    run_virtual(async {
        let p = Platform::deploy(apps::chain(2), fast_cfg()).await.unwrap();
        // the fused instance will boot forever
        p.containers.inject_boot_hangs(1);

        let wl = WorkloadConfig { requests: 10, rate_rps: 10.0, seed: 3, timeout_ms: 60_000.0 };
        let report = workload::run(Rc::clone(&p), wl).await.unwrap();
        assert_eq!(report.failed, 0);
        // health deadline = 4x boot + 5s; wait it out
        exec::sleep_ms(20_000.0).await;
        assert_eq!(p.metrics.counter("fusion_health_timeouts"), 1);
        assert_eq!(p.metrics.merges().len(), 0);
        // the hung instance must not linger in the RAM ledger
        assert_eq!(p.containers.live_count(), 2);
        assert_eq!(p.gateway.distinct_instances(), 2);
        p.shutdown();
    });
}

#[test]
fn requests_in_flight_during_cutover_complete_on_old_instances() {
    run_virtual(async {
        let mut cfg = fast_cfg();
        cfg.fusion.min_observations = 2;
        let p = Platform::deploy(apps::chain(3), cfg).await.unwrap();

        // long steady stream so cutovers happen under load
        let wl = WorkloadConfig { requests: 300, rate_rps: 40.0, seed: 4, timeout_ms: 60_000.0 };
        let report = workload::run(Rc::clone(&p), wl).await.unwrap();
        assert_eq!(report.failed, 0);
        exec::sleep_ms(20_000.0).await;

        // every pre-merge instance was drained to zero before termination
        // (ContainerRuntime::terminate errors otherwise and the drain task
        // retries forever -> live_count would stay high)
        assert_eq!(p.containers.live_count(), 1);
        assert_eq!(p.metrics.counter("instances_reclaimed") as usize, 2 * p.metrics.merges().len());
        p.shutdown();
    });
}

#[test]
fn boot_hang_mid_split_rolls_back_to_fused_instance_then_retries() {
    // A replacement instance that never gets healthy must abort the split:
    // the fused instance keeps serving (zero drops), the orphans are torn
    // down, the group re-enters cooldown, and the next attempt succeeds.
    run_virtual(async {
        let mut cfg = fast_cfg();
        // chain(2) idle fused RAM = 58 + 2 x 12 = 82 MiB: an 80 MiB cap
        // violates deterministically, traffic or not. First controller
        // ticks at 4 s and 8 s -> first split request at t = 8 s, well
        // after the hang is injected below.
        cfg.fusion.max_group_ram_mb = 80.0;
        cfg.fusion.feedback_interval_ms = 4_000.0;
        cfg.fusion.split_hysteresis_windows = 2;
        let p = Platform::deploy(apps::chain(2), cfg).await.unwrap();

        // fuse under a little traffic (merge completes ~1.4 s)
        let wl = WorkloadConfig { requests: 10, rate_rps: 10.0, seed: 31, timeout_ms: 60_000.0 };
        workload::run(Rc::clone(&p), wl).await.unwrap();
        exec::sleep_ms(2_000.0).await;
        assert_eq!(p.gateway.distinct_instances(), 1, "fusion must complete first");

        // the next instance launch (the split's first replacement) hangs
        p.containers.inject_boot_hangs(1);

        // serve straight through the failed split attempt:
        // split request at 8 s, health deadline 4 x 150 ms + 5 s -> rollback
        // at ~13.6 s; this workload spans ~3 s to ~13 s
        let wl =
            WorkloadConfig { requests: 200, rate_rps: 20.0, seed: 32, timeout_ms: 60_000.0 };
        let report = workload::run(Rc::clone(&p), wl).await.unwrap();
        assert_eq!(report.failed, 0, "requests must survive the aborted split");
        exec::sleep_ms(2_000.0).await;

        // first attempt aborted and rolled back: still fused, orphans gone
        assert_eq!(p.metrics.counter("split_aborted"), 1);
        assert_eq!(p.metrics.counter("split_health_timeouts"), 1);
        assert!(p.metrics.splits().is_empty());
        assert_eq!(p.gateway.distinct_instances(), 1);
        assert_eq!(p.containers.live_count(), 1, "hung replacement must be torn down");

        // cooldown (2 s after the ~13.6 s rollback), then strikes at the
        // 16 s and 20 s ticks -> the retry succeeds
        exec::sleep_ms(10_000.0).await;
        assert_eq!(p.metrics.splits().len(), 1, "retry after cooldown must split");
        assert_eq!(p.metrics.counter("splits_completed"), 1);
        assert_eq!(p.gateway.distinct_instances(), 2);
        assert_eq!(p.containers.live_count(), 2);
        // merge reclaimed 2 originals, the successful split reclaimed the
        // fused instance
        assert_eq!(p.metrics.counter("instances_reclaimed"), 3);
        p.shutdown();
    });
}

#[test]
fn boot_hang_mid_evict_rolls_back_group_intact_with_zero_drops() {
    // ISSUE 2 satellite: inject a boot hang on the evicted function's
    // redeploy.  The eviction must abort with the fused group restored
    // intact — routes untouched, no member unloaded, the orphan replacement
    // torn down — and traffic served straight through the failed attempt
    // must not drop a single request.  A later retry succeeds and shrinks
    // the group in place.
    run_virtual(async {
        let mut cfg = fast_cfg();
        cfg.fusion.feedback_interval_ms = 0.0; // drive the pipeline by hand
        let p = Platform::deploy(apps::chain(3), cfg).await.unwrap();

        // fuse the whole chain first
        let wl = WorkloadConfig { requests: 20, rate_rps: 10.0, seed: 41, timeout_ms: 60_000.0 };
        workload::run(Rc::clone(&p), wl).await.unwrap();
        exec::sleep_ms(10_000.0).await;
        assert_eq!(p.gateway.distinct_instances(), 1, "fusion must complete first");

        let merger = provuse::merger::Merger::new(provuse::merger::MergerCtx {
            config: Rc::clone(&p.config),
            containers: p.containers.clone(),
            cluster: p.cluster.clone(),
            scheduler: provuse::cluster::Scheduler::new(
                p.config.cluster.placement,
                p.cluster.clone(),
            ),
            gateway: p.gateway.clone(),
            observer: Rc::clone(&p.observer),
            metrics: p.metrics.clone(),
            deployer: provuse::platform::deployer::Deployer::direct(p.cluster.clone()),
            originals: Rc::new(
                ["s0", "s1", "s2"]
                    .iter()
                    .filter_map(|f| p.original_image(f).map(|img| (f.to_string(), img)))
                    .collect(),
            ),
        });
        let group = vec!["s0".to_string(), "s1".into(), "s2".into()];

        // the replacement instance for the evicted function hangs booting;
        // serve traffic straight through the doomed attempt (health
        // deadline = 4 x 150 ms + 5 s, workload spans ~5 s)
        p.containers.inject_boot_hangs(1);
        let traffic = exec::spawn(workload::run(
            Rc::clone(&p),
            WorkloadConfig { requests: 100, rate_rps: 20.0, seed: 42, timeout_ms: 60_000.0 },
        ));
        merger
            .process(provuse::fusion::FusionRequest::Evict {
                functions: group.clone(),
                function: "s1".into(),
                reason: provuse::fusion::SplitReason::CostModel,
            })
            .await;
        let report = traffic.await.unwrap();
        assert_eq!(report.failed, 0, "requests must survive the aborted eviction");

        // rolled back: group intact, orphan torn down, nothing unloaded
        assert_eq!(p.metrics.counter("evict_aborted"), 1);
        assert_eq!(p.metrics.counter("evict_health_timeouts"), 1);
        assert!(p.metrics.evicts().is_empty());
        assert_eq!(p.gateway.distinct_instances(), 1);
        assert_eq!(p.containers.live_count(), 1, "hung replacement must be torn down");
        let fused = p.gateway.resolve("s1").unwrap();
        assert!(fused.hosts("s0") && fused.hosts("s1") && fused.hosts("s2"));
        provuse::platform::routing_invariants(&p).unwrap();

        // the retry succeeds: s1 leaves, the remainder stays fused in place
        let retry = merger.handle_evict(&group, "s1", provuse::fusion::SplitReason::CostModel);
        retry.await.unwrap();
        assert_eq!(p.metrics.evicts().len(), 1);
        assert_eq!(p.metrics.counter("evictions_completed"), 1);
        assert_eq!(p.gateway.distinct_instances(), 2);
        assert_eq!(p.containers.live_count(), 2);
        assert_eq!(p.group_members("s0"), vec!["s0".to_string(), "s2".into()]);
        assert_eq!(p.group_members("s1"), vec!["s1".to_string()]);
        // only the evicted pairs are on cooldown
        assert!(p.observer.pair_in_cooldown("s1", "s0"));
        assert!(p.observer.pair_in_cooldown("s2", "s1"));
        assert!(!p.observer.pair_in_cooldown("s0", "s2"));
        provuse::platform::routing_invariants(&p).unwrap();
        p.shutdown();
    });
}

#[test]
fn stale_evict_request_aborts_without_touching_routes() {
    // An Evict whose sampled membership no longer matches the live
    // topology, or that names a non-member, must abort cleanly.
    run_virtual(async {
        let mut cfg = fast_cfg();
        cfg.fusion.feedback_interval_ms = 0.0;
        let p = Platform::deploy(apps::chain(3), cfg).await.unwrap();
        let wl = WorkloadConfig { requests: 20, rate_rps: 10.0, seed: 43, timeout_ms: 60_000.0 };
        workload::run(Rc::clone(&p), wl).await.unwrap();
        exec::sleep_ms(10_000.0).await;
        assert_eq!(p.gateway.distinct_instances(), 1);

        let merger = provuse::merger::Merger::new(provuse::merger::MergerCtx {
            config: Rc::clone(&p.config),
            containers: p.containers.clone(),
            cluster: p.cluster.clone(),
            scheduler: provuse::cluster::Scheduler::new(
                p.config.cluster.placement,
                p.cluster.clone(),
            ),
            gateway: p.gateway.clone(),
            observer: Rc::clone(&p.observer),
            metrics: p.metrics.clone(),
            deployer: provuse::platform::deployer::Deployer::direct(p.cluster.clone()),
            originals: Rc::new(
                ["s0", "s1", "s2"]
                    .iter()
                    .filter_map(|f| p.original_image(f).map(|img| (f.to_string(), img)))
                    .collect(),
            ),
        });
        // sampled a pair, but the live instance hosts all three
        let stale = vec!["s0".to_string(), "s1".into()];
        let err = merger
            .handle_evict(&stale, "s1", provuse::fusion::SplitReason::CostModel)
            .await;
        assert!(err.is_err(), "stale evict must abort");
        // the named function is not a member of the sampled group
        let full = vec!["s0".to_string(), "s1".into(), "s2".into()];
        let err = merger
            .handle_evict(&full, "ghost", provuse::fusion::SplitReason::CostModel)
            .await;
        assert!(err.is_err(), "non-member evict must abort");
        assert_eq!(p.gateway.distinct_instances(), 1, "routes untouched");
        assert_eq!(p.containers.live_count(), 1);
        assert!(p.metrics.evicts().is_empty());
        p.shutdown();
    });
}

#[test]
fn stale_split_request_aborts_without_touching_routes() {
    // A Split whose sampled membership no longer matches the live topology
    // (e.g. the group grew transitively in the meantime) must abort cleanly.
    run_virtual(async {
        let mut cfg = fast_cfg();
        cfg.fusion.feedback_interval_ms = 0.0; // controller off: drive by hand
        let p = Platform::deploy(apps::chain(3), cfg).await.unwrap();
        let wl = WorkloadConfig { requests: 20, rate_rps: 10.0, seed: 33, timeout_ms: 60_000.0 };
        workload::run(Rc::clone(&p), wl).await.unwrap();
        exec::sleep_ms(10_000.0).await;
        assert_eq!(p.gateway.distinct_instances(), 1);

        // sampled a pair, but the live instance hosts all three functions
        let merger = provuse::merger::Merger::new(provuse::merger::MergerCtx {
            config: Rc::clone(&p.config),
            containers: p.containers.clone(),
            cluster: p.cluster.clone(),
            scheduler: provuse::cluster::Scheduler::new(
                p.config.cluster.placement,
                p.cluster.clone(),
            ),
            gateway: p.gateway.clone(),
            observer: Rc::clone(&p.observer),
            metrics: p.metrics.clone(),
            deployer: provuse::platform::deployer::Deployer::direct(p.cluster.clone()),
            originals: Rc::new(
                ["s0", "s1", "s2"]
                    .iter()
                    .filter_map(|f| p.original_image(f).map(|img| (f.to_string(), img)))
                    .collect(),
            ),
        });
        let stale = vec!["s0".to_string(), "s1".to_string()];
        let err = merger
            .handle_split(&stale, provuse::fusion::SplitReason::RamCap)
            .await;
        assert!(err.is_err(), "stale split must abort");
        assert_eq!(p.gateway.distinct_instances(), 1, "routes untouched");
        assert_eq!(p.containers.live_count(), 1);
        assert!(p.metrics.splits().is_empty());
        p.shutdown();
    });
}

#[test]
fn max_group_size_stops_transitive_growth() {
    run_virtual(async {
        let mut cfg = fast_cfg();
        cfg.fusion.max_group_size = 2;
        let p = Platform::deploy(apps::chain(4), cfg).await.unwrap();
        let wl = WorkloadConfig { requests: 120, rate_rps: 20.0, seed: 5, timeout_ms: 60_000.0 };
        workload::run(Rc::clone(&p), wl).await.unwrap();
        exec::sleep_ms(20_000.0).await;

        for (_, inst) in p.gateway.snapshot() {
            assert!(inst.functions().len() <= 2, "group size cap violated");
        }
        // s0+s1 and s2+s3 pair up -> 2 instances
        assert_eq!(p.gateway.distinct_instances(), 2);
        p.shutdown();
    });
}

#[test]
fn disabled_transitive_growth_caps_at_pairs() {
    run_virtual(async {
        let mut cfg = fast_cfg();
        cfg.fusion.transitive = false;
        let p = Platform::deploy(apps::chain(4), cfg).await.unwrap();
        let wl = WorkloadConfig { requests: 120, rate_rps: 20.0, seed: 6, timeout_ms: 60_000.0 };
        workload::run(Rc::clone(&p), wl).await.unwrap();
        exec::sleep_ms(20_000.0).await;
        for (_, inst) in p.gateway.snapshot() {
            assert!(inst.functions().len() <= 2);
        }
        p.shutdown();
    });
}
