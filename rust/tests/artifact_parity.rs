//! Cross-layer parity: the Rust/PJRT execution of every AOT artifact must
//! reproduce the python/JAX goldens bit-for-bit (well, to fp32 tolerance).
//!
//! Requires `make artifacts`; tests self-skip when the directory is absent
//! so `cargo test` works in a fresh checkout.

use provuse::config::ComputeMode;
use provuse::runtime::{ArtifactSet, ComputeService};

fn artifacts() -> Option<std::rc::Rc<ArtifactSet>> {
    if !provuse::xla::PJRT_AVAILABLE {
        eprintln!("skipping: PJRT bindings are stubbed in this build (src/xla.rs)");
        return None;
    }
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(ArtifactSet::cached("artifacts").expect("artifact load failed"))
}

#[test]
fn every_body_matches_python_golden() {
    let Some(set) = artifacts() else { return };
    let results = set.validate(1e-4).unwrap();
    assert_eq!(results.len(), 10, "expected 10 compute bodies");
    for v in &results {
        assert!(
            v.ok,
            "{}: rust/PJRT diverges from python golden by {:.2e}",
            v.name, v.max_abs_err
        );
    }
}

#[test]
fn execution_is_deterministic() {
    let Some(set) = artifacts() else { return };
    for name in set.names() {
        let input = set.golden_input(name).unwrap().to_vec();
        let a = set.execute(name, &input).unwrap();
        let b = set.execute(name, &input).unwrap();
        assert_eq!(a, b, "{name} nondeterministic");
        assert_eq!(a.len(), set.batch * set.out_dim);
        assert!(a.iter().all(|v| v.is_finite()), "{name} produced non-finite output");
    }
}

#[test]
fn outputs_are_input_sensitive() {
    let Some(set) = artifacts() else { return };
    for name in set.names() {
        let input = set.golden_input(name).unwrap().to_vec();
        let mut perturbed = input.clone();
        for v in perturbed.iter_mut() {
            *v += 0.37;
        }
        let a = set.execute(name, &input).unwrap();
        let b = set.execute(name, &perturbed).unwrap();
        assert_ne!(a, b, "{name} ignores its input");
    }
}

#[test]
fn wrong_input_length_is_rejected() {
    let Some(set) = artifacts() else { return };
    let err = set.execute("tree_light", &[0.0; 7]);
    assert!(err.is_err());
    let err = set.execute("no_such_body", &vec![0.0; set.batch * set.in_dim]);
    assert!(err.is_err());
}

#[test]
fn replay_mode_matches_live_mode_outputs() {
    let Some(set) = artifacts() else { return };
    let live = ComputeService::new(std::rc::Rc::clone(&set), ComputeMode::Live);
    let replay = ComputeService::new(set.clone(), ComputeMode::Replay);
    // replay returns the load-time execution of the golden input; live on
    // the same golden input must agree exactly
    for name in set.names() {
        let golden = set.golden_input(name).unwrap().to_vec();
        let (a, live_ms) = live.run(name, &golden).unwrap();
        let (b, replay_ms) = replay.run(name, &golden).unwrap();
        assert_eq!(a, b, "{name}: live vs replay outputs differ");
        assert!(live_ms > 0.0);
        assert!(replay_ms > 0.0, "{name}: profiled duration must be positive");
    }
}

#[test]
fn profiled_durations_reflect_body_cost() {
    let Some(set) = artifacts() else { return };
    // tree_heavy (4 chained 256x256 matmul layers) must profile slower
    // than tree_light (one streaming-stats kernel)
    let heavy = set.profile_ms("tree_heavy").unwrap();
    let light = set.profile_ms("tree_light").unwrap();
    assert!(
        heavy > light,
        "tree_heavy ({heavy} ms) should out-cost tree_light ({light} ms)"
    );
}
