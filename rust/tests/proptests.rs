//! Property-based tests over platform invariants (mini-harness in
//! `util::prop`; replay any failure with PROP_SEED=<seed>).

use std::collections::BTreeMap;
use std::rc::Rc;

use provuse::apps::{AppSpec, CallMode, CallSpec, FunctionSpec};
use provuse::cluster::{Migrator, NodeId, Scheduler};
use provuse::config::{
    ComputeMode, MergePolicyKind, PlacementPolicy, PlatformConfig, PlatformKind,
    SplitPolicyKind, WorkloadConfig,
};
use provuse::containerd::{ImageId, InstanceState};
use provuse::exec::run_virtual;
use provuse::fusion::SplitReason;
use provuse::merger::{Merger, MergerCtx};
use provuse::platform::{deployer::Deployer, routing_invariants, Platform};
use provuse::util::prop::{check, Gen};
use provuse::workload::{self, request_payload};

/// Random DAG application: forward-only edges keep it acyclic by
/// construction; random sync/async modes and 1-2 trust domains.
fn random_app(g: &mut Gen) -> AppSpec {
    let n = g.usize(2, 7);
    let domains = ["alpha", "beta"];
    let n_domains = g.usize(1, 2);
    let mut functions = Vec::new();
    for i in 0..n {
        let mut calls = Vec::new();
        for j in (i + 1)..n {
            if g.f64(0.0, 1.0) < 0.45 {
                calls.push(CallSpec {
                    target: format!("f{j}"),
                    mode: if g.bool() { CallMode::Sync } else { CallMode::Async },
                    scale: g.f64(0.5, 1.5) as f32,
                });
            }
        }
        functions.push(FunctionSpec {
            name: format!("f{i}"),
            body: None,
            busy_ms: g.f64(5.0, 60.0),
            code_mb: g.f64(4.0, 24.0),
            code_kb: g.usize(16, 256) as u64,
            trust_domain: domains[g.usize(0, n_domains - 1)].into(),
            calls,
        });
    }
    AppSpec::new("prop", "f0", functions).expect("forward-edge DAG is always valid")
}

fn fast_cfg(g: &mut Gen, kind: PlatformKind) -> PlatformConfig {
    let mut cfg = PlatformConfig::of_kind(kind).with_compute(ComputeMode::Disabled);
    cfg.latency.image_build_ms = g.f64(50.0, 500.0);
    cfg.latency.boot_ms = g.f64(50.0, 300.0);
    cfg.fusion.min_observations = g.usize(1, 3) as u32;
    cfg.seed = g.rng().next_u64();
    cfg
}

#[test]
fn prop_fusion_never_changes_responses() {
    // For ANY app DAG and ANY platform flavor, enabling fusion must not
    // change a single response byte.
    check("fusion preserves responses", 20, |g| {
        let app = random_app(g);
        let kind = *g.choose(&[PlatformKind::Tiny, PlatformKind::Kube]);
        let cfg = fast_cfg(g, kind);
        let n_requests = g.usize(5, 15) as u64;
        let seed = g.rng().next_u64();

        let collect = |fusion: bool| {
            let app = app.clone();
            let mut cfg = cfg.clone();
            if !fusion {
                cfg = cfg.vanilla();
            }
            run_virtual(async move {
                let p = Platform::deploy(app, cfg).await.unwrap();
                let mut outs = Vec::new();
                for i in 0..n_requests {
                    let payload = request_payload(seed, i, p.payload_len());
                    outs.push(p.invoke(payload).await.unwrap());
                    provuse::exec::sleep_ms(150.0).await;
                }
                p.shutdown();
                outs
            })
        };
        assert_eq!(collect(false), collect(true));
    });
}

#[test]
fn prop_no_failures_and_partition_invariant() {
    // After any run: every function routes to exactly one live instance,
    // every instance's hosted set is internally consistent with the
    // routing table, and no requests were dropped.
    check("routing partition invariant", 16, |g| {
        let app = random_app(g);
        let kind = *g.choose(&[PlatformKind::Tiny, PlatformKind::Kube]);
        let cfg = fast_cfg(g, kind);
        let wl = WorkloadConfig {
            requests: g.usize(20, 80) as u64,
            rate_rps: g.f64(5.0, 50.0),
            seed: g.rng().next_u64(),
            timeout_ms: 120_000.0,
        };
        run_virtual(async move {
            let p = Platform::deploy(app, cfg).await.unwrap();
            let report = workload::run(Rc::clone(&p), wl).await.unwrap();
            assert_eq!(report.failed, 0, "dropped requests");
            provuse::exec::sleep_ms(25_000.0).await;

            let snapshot = p.gateway.snapshot();
            for (function, inst) in &snapshot {
                assert!(inst.state().is_live(), "{function} routed to dead instance");
                assert!(
                    inst.hosts(function),
                    "{function} routed to instance not hosting it"
                );
            }
            // trust domains never mix inside one instance
            for (_, inst) in &snapshot {
                let domains: std::collections::HashSet<&str> = inst
                    .functions()
                    .iter()
                    .map(|(f, _)| p.app.function(f).unwrap().trust_domain.as_str())
                    .collect();
                assert!(domains.len() <= 1, "trust domains mixed: {domains:?}");
            }
            // fused groups never exceed the theoretical sync components
            let components = p.app.sync_fusion_groups();
            for (_, inst) in &snapshot {
                let fns = inst.functions();
                if fns.len() > 1 {
                    let hosted: std::collections::BTreeSet<&str> =
                        fns.iter().map(|(f, _)| f.as_str()).collect();
                    let within_one_component = components.iter().any(|c| {
                        hosted.iter().all(|f| c.iter().any(|m| m == f))
                    });
                    assert!(within_one_component, "fused across sync components: {hosted:?}");
                }
            }
            p.shutdown();
        });
    });
}

#[test]
fn prop_ram_ledger_conservation() {
    // At quiescence the ledger equals base * instances + total code, no
    // matter what merge history happened.
    check("ram ledger conservation", 12, |g| {
        let app = random_app(g);
        let cfg = fast_cfg(g, PlatformKind::Tiny);
        let wl = WorkloadConfig {
            requests: g.usize(15, 50) as u64,
            rate_rps: 20.0,
            seed: g.rng().next_u64(),
            timeout_ms: 120_000.0,
        };
        run_virtual(async move {
            let p = Platform::deploy(app, cfg).await.unwrap();
            workload::run(Rc::clone(&p), wl).await.unwrap();
            provuse::exec::sleep_ms(30_000.0).await;

            let code_total: f64 = p.app.functions().map(|f| f.code_mb).sum();
            let expected = p.config.ram.base_instance_mb * p.containers.live_count() as f64
                + code_total;
            let actual = p.containers.total_ram_mb();
            assert!(
                (actual - expected).abs() < 1e-6,
                "ledger {actual} != {expected} ({} instances)",
                p.containers.live_count()
            );
        });
    });
}

/// A Merger handle over an existing platform's context, so a test can
/// drive Fuse/Split/Evict pipelines explicitly (same pattern as the
/// stale-split test in failure_injection.rs).
fn manual_merger(p: &Rc<Platform>) -> Merger {
    let originals: BTreeMap<String, ImageId> = p
        .app
        .functions()
        .filter_map(|f| p.original_image(&f.name).map(|img| (f.name.clone(), img)))
        .collect();
    Merger::new(MergerCtx {
        config: Rc::clone(&p.config),
        containers: p.containers.clone(),
        cluster: p.cluster.clone(),
        scheduler: Scheduler::new(p.config.cluster.placement, p.cluster.clone()),
        gateway: p.gateway.clone(),
        observer: Rc::clone(&p.observer),
        metrics: p.metrics.clone(),
        deployer: Deployer::direct(p.cluster.clone()),
        originals: Rc::new(originals),
    })
}

/// Sorted member list of the fused group hosting `probe`'s instance.
fn sorted_members(inst: &provuse::containerd::Instance) -> Vec<String> {
    let mut fns: Vec<String> = inst.functions().iter().map(|(n, _)| n.clone()).collect();
    fns.sort();
    fns
}

#[test]
fn prop_fuse_split_evict_interleavings_preserve_invariants() {
    // ISSUE 2 tentpole property: after ANY random interleaving of Fuse /
    // Split / Evict pipeline runs (with traffic woven through) over random
    // DAG apps, the routing table remains a bijection onto the live
    // instances, no function is served by two instances, and every evicted
    // pair is in cooldown.  Pipelines run through the real Merger against a
    // live platform; aborted ops (stale groups etc.) are part of the space.
    check("fuse/split/evict interleaving invariants", 64, |g| {
        let app = random_app(g);
        let kind = *g.choose(&[PlatformKind::Tiny, PlatformKind::Kube]);
        let mut cfg = fast_cfg(g, kind);
        cfg.fusion.feedback_interval_ms = 0.0; // controller off: ops driven by hand
        let ops = g.usize(4, 10);
        let op_seed = g.rng().next_u64();
        run_virtual(async move {
            // vanilla platform: the in-platform merger stays idle, so the
            // manual pipeline runs below are the only topology mutations
            // (the real system serializes pipelines the same way)
            let p = Platform::deploy(app, cfg.vanilla()).await.unwrap();
            let merger = manual_merger(&p);
            let mut g = Gen::replay(op_seed);
            let sync_edges: Vec<(String, String)> = p
                .app
                .functions()
                .flat_map(|f| {
                    f.calls
                        .iter()
                        .filter(|c| c.mode == CallMode::Sync)
                        .map(|c| (f.name.clone(), c.target.clone()))
                        .collect::<Vec<_>>()
                })
                .collect();
            for _ in 0..ops {
                match g.weighted(&[3.0, 3.0, 2.0, 2.0]) {
                    0 => {
                        // traffic (entry route; exercises inline + remote paths)
                        let wl = WorkloadConfig {
                            requests: g.usize(5, 15) as u64,
                            rate_rps: 20.0,
                            seed: g.rng().next_u64(),
                            timeout_ms: 120_000.0,
                        };
                        let report = workload::run(Rc::clone(&p), wl).await.unwrap();
                        assert_eq!(report.failed, 0, "dropped requests");
                    }
                    1 => {
                        // fuse a random sync pair (may abort: already
                        // colocated after a previous fuse — fine)
                        if !sync_edges.is_empty() {
                            let (caller, callee) = g.choose(&sync_edges).clone();
                            let _ = merger.handle_fuse(&caller, &callee).await;
                        }
                    }
                    2 => {
                        // split a random live fused group whole
                        let groups = p.fused_groups();
                        if !groups.is_empty() {
                            let fns = sorted_members(g.choose(&groups));
                            let _ = merger.handle_split(&fns, SplitReason::RamCap).await;
                        }
                    }
                    3 => {
                        // evict a random member of a random fused group
                        let groups = p.fused_groups();
                        if !groups.is_empty() {
                            let fns = sorted_members(g.choose(&groups));
                            let victim = g.choose(&fns).clone();
                            if merger
                                .handle_evict(&fns, &victim, SplitReason::CostModel)
                                .await
                                .is_ok()
                            {
                                // every evicted pair is in cooldown, both
                                // directions; surviving pairs are not
                                for other in fns.iter().filter(|f| **f != victim) {
                                    assert!(
                                        p.observer.pair_in_cooldown(&victim, other),
                                        "evicted pair ({victim}, {other}) not cooling"
                                    );
                                    assert!(
                                        p.observer.pair_in_cooldown(other, &victim),
                                        "evicted pair ({other}, {victim}) not cooling"
                                    );
                                }
                            }
                        }
                    }
                    _ => unreachable!(),
                }
                provuse::exec::sleep_ms(g.f64(100.0, 2_000.0)).await;
            }
            provuse::exec::sleep_ms(25_000.0).await; // drains settle
            if let Err(violation) = routing_invariants(&p) {
                panic!("invariant violated after interleaving: {violation}");
            }
            p.shutdown();
        });
    });
}

#[test]
fn prop_controller_loop_fuzz_preserves_invariants_and_never_flaps() {
    // ISSUE 3 satellite (ROADMAP: "fuzz the controller loop itself"): the
    // REAL controller tick — not hand-driven pipelines — runs at a
    // randomized feedback interval under a randomized policy mix (split
    // threshold vs cost model, merge observation-count vs cost planner,
    // auto-tune on/off) while entry + targeted per-route traffic races it.
    // Afterwards: `routing_invariants` holds, no request was dropped, and
    // no pair that a defusion tore apart was re-fused within one cooldown
    // of that defusion (the anti-flap contract).
    check("controller loop fuzz", 10, |g| {
        let app = random_app(g);
        let kind = *g.choose(&[PlatformKind::Tiny, PlatformKind::Kube]);
        let mut cfg = fast_cfg(g, kind);
        cfg.fusion.feedback_interval_ms = g.f64(300.0, 2_500.0);
        cfg.fusion.split_hysteresis_windows = g.usize(1, 3) as u32;
        cfg.fusion.cooldown_ms = g.f64(4_000.0, 15_000.0);
        cfg.fusion.max_group_ram_mb = g.f64(60.0, 250.0);
        cfg.fusion.split_p95_regression = g.f64(0.2, 1.5);
        cfg.fusion.split_policy = if g.bool() {
            SplitPolicyKind::CostModel
        } else {
            SplitPolicyKind::Threshold
        };
        cfg.fusion.cost.evict_threshold = g.f64(0.5, 3.0);
        if g.bool() {
            cfg.fusion.merge_policy = MergePolicyKind::CostModel;
            cfg.fusion.cost.merge_threshold = g.f64(-0.5, 0.5);
            cfg.fusion.auto_tune = g.bool();
        }
        let n_targeted = g.usize(1, 3);
        let wl_seed = g.rng().next_u64();
        let targeted_rps = g.f64(5.0, 40.0);
        let entry_requests = g.usize(30, 120) as u64;
        run_virtual(async move {
            let p = Platform::deploy(app, cfg).await.unwrap();
            let names: Vec<String> =
                p.app.functions().map(|f| f.name.clone()).collect();
            let mut g = Gen::replay(wl_seed);
            let mut handles = Vec::new();
            handles.push(provuse::exec::spawn(workload::run(
                Rc::clone(&p),
                WorkloadConfig {
                    requests: entry_requests,
                    rate_rps: g.f64(5.0, 30.0),
                    seed: g.rng().next_u64(),
                    timeout_ms: 120_000.0,
                },
            )));
            for _ in 0..n_targeted {
                let target = g.choose(&names).clone();
                let wl = WorkloadConfig {
                    requests: g.usize(20, 100) as u64,
                    rate_rps: targeted_rps,
                    seed: g.rng().next_u64(),
                    timeout_ms: 120_000.0,
                };
                let p2 = Rc::clone(&p);
                handles.push(provuse::exec::spawn(async move {
                    workload::run_targeted(
                        p2,
                        wl,
                        provuse::workload::Arrival::Constant,
                        Some(target.as_str()),
                    )
                    .await
                }));
            }
            for h in handles {
                let report = h.await.unwrap();
                assert_eq!(report.failed, 0, "dropped requests under the controller");
            }
            // let every in-flight pipeline and drain settle
            provuse::exec::sleep_ms(30_000.0).await;
            if let Err(violation) = routing_invariants(&p) {
                panic!("invariant violated under the live controller: {violation}");
            }
            // anti-flap oracle over the full event timeline: for every
            // defusion, no merge re-joins one of its torn-apart pairs
            // within one cooldown.  A split tears every pair apart; an
            // evict tears only the (evicted, member) pairs.
            let cooldown = p.config.fusion.cooldown_ms;
            let merges = p.metrics.merges();
            let mut torn: Vec<(f64, String, String)> = Vec::new();
            for s in p.metrics.splits() {
                for a in &s.functions {
                    for b in &s.functions {
                        if a < b {
                            torn.push((s.t_ms, a.clone(), b.clone()));
                        }
                    }
                }
            }
            for e in p.metrics.evicts() {
                for m in e.group.iter().filter(|f| **f != e.function) {
                    let (a, b) = if *m < e.function {
                        (m.clone(), e.function.clone())
                    } else {
                        (e.function.clone(), m.clone())
                    };
                    torn.push((e.t_ms, a, b));
                }
            }
            for (t, a, b) in &torn {
                for m in &merges {
                    let rejoined = m.functions.iter().any(|f| f == a)
                        && m.functions.iter().any(|f| f == b);
                    if rejoined && m.t_ms > *t && m.t_ms < *t + cooldown {
                        panic!(
                            "fuse->defuse->fuse flap: ({a}, {b}) defused at {t:.0} ms \
                             re-merged at {:.0} ms inside the {cooldown:.0} ms cooldown",
                            m.t_ms
                        );
                    }
                }
            }
            p.shutdown();
        });
    });
}

#[test]
fn prop_cluster_invariants_hold_across_placements_and_migrations() {
    // ISSUE 4 satellite: for ANY node count, placement policy, capacity
    // regime, and traffic, with random fuse + migrate pipelines woven
    // through (driven serially against a vanilla platform, the way the
    // real Merger serializes them, while open-loop entry traffic races
    // every cutover):
    //   * the routing invariants hold at quiescence;
    //   * no request is ever dropped — in particular none routed to a
    //     draining migration source;
    //   * total cluster RAM accounting equals the sum of the per-node
    //     ledgers, and every routed instance has a node assignment.
    check("cluster placement + migration invariants", 10, |g| {
        let app = random_app(g);
        let mut cfg = fast_cfg(g, PlatformKind::Tiny);
        cfg.cluster.nodes = g.usize(1, 4);
        cfg.cluster.placement = *g.choose(&[
            PlacementPolicy::BinPack,
            PlacementPolicy::Spread,
            PlacementPolicy::FusionAffinity,
        ]);
        // generous capacity (or uncapped) so the initial placement always
        // fits; individual migrations may still be refused — that's part
        // of the space
        cfg.cluster.node_capacity_mb = if g.bool() { 0.0 } else { g.f64(700.0, 2_000.0) };
        let ops = g.usize(3, 8);
        let op_seed = g.rng().next_u64();
        let wl = WorkloadConfig {
            requests: g.usize(30, 90) as u64,
            rate_rps: g.f64(5.0, 25.0),
            seed: g.rng().next_u64(),
            timeout_ms: 120_000.0,
        };
        run_virtual(async move {
            // vanilla: the in-platform merger stays idle, so the serial
            // manual pipelines below are the only topology mutations
            let p = Platform::deploy(app, cfg.vanilla()).await.unwrap();
            let n_nodes = p.cluster.node_count();
            for (f, inst) in p.gateway.snapshot() {
                assert!(
                    p.cluster.node_of(inst.id()).is_some(),
                    "`{f}` deployed without a node assignment"
                );
            }
            let merger = manual_merger(&p);
            let migrator = Migrator::new(
                p.cluster.clone(),
                Deployer::direct(p.cluster.clone()),
                p.gateway.clone(),
                p.metrics.clone(),
                Rc::clone(&p.config),
            );
            let names: Vec<String> = p.app.functions().map(|f| f.name.clone()).collect();
            let sync_edges: Vec<(String, String)> = p
                .app
                .functions()
                .flat_map(|f| {
                    f.calls
                        .iter()
                        .filter(|c| c.mode == CallMode::Sync)
                        .map(|c| (f.name.clone(), c.target.clone()))
                        .collect::<Vec<_>>()
                })
                .collect();

            // entry traffic races every pipeline below (open loop)
            let traffic = provuse::exec::spawn(workload::run(Rc::clone(&p), wl));

            let mut g = Gen::replay(op_seed);
            for _ in 0..ops {
                provuse::exec::sleep_ms(g.f64(200.0, 2_500.0)).await;
                if g.bool() && !sync_edges.is_empty() {
                    // fuse a random sync pair — on a multi-node cluster
                    // this may itself run a co-location migration; aborts
                    // (already colocated, capacity) are part of the space
                    let (caller, callee) = g.choose(&sync_edges).clone();
                    let _ = merger.handle_fuse(&caller, &callee).await;
                } else {
                    // migrate the live group of a random function to a
                    // random node
                    let probe = g.choose(&names).clone();
                    let group = p.group_members(&probe);
                    let to = NodeId(g.usize(0, n_nodes - 1) as u64);
                    match migrator.migrate(&group, to, "prop").await {
                        Ok(fresh) => {
                            assert_eq!(p.cluster.node_of(fresh.id()), Some(to));
                            // the cutover was atomic: every member routes
                            // to the replacement, never the draining source
                            for f in &group {
                                assert_eq!(
                                    p.gateway.resolve(f).unwrap().id(),
                                    fresh.id(),
                                    "`{f}` still routed to the migration source"
                                );
                            }
                        }
                        Err(_) => {} // no-op/stale/capacity refusals are fine
                    }
                }
            }
            let report = traffic.await.unwrap();
            assert_eq!(report.failed, 0, "dropped requests under cluster churn");
            provuse::exec::sleep_ms(30_000.0).await; // drains settle

            if let Err(violation) = routing_invariants(&p) {
                panic!("invariant violated on the cluster: {violation}");
            }
            // per-node accounting sums exactly to the cluster ledger
            let node_ram: f64 = p.cluster.nodes().iter().map(|n| n.ram_mb()).sum();
            assert!(
                (node_ram - p.cluster.total_ram_mb()).abs() < 1e-6,
                "per-node RAM {node_ram} != cluster total {}",
                p.cluster.total_ram_mb()
            );
            let node_count: usize = p.cluster.nodes().iter().map(|n| n.live_count()).sum();
            assert_eq!(node_count, p.cluster.live_count());
            // at quiescence every route points at a healthy, node-assigned
            // instance (a draining source still routed would show up here)
            for (f, inst) in p.gateway.snapshot() {
                assert_eq!(
                    inst.state(),
                    InstanceState::Healthy,
                    "`{f}` routed to a {} instance",
                    inst.state().name()
                );
                assert!(p.cluster.node_of(inst.id()).is_some());
            }
            p.shutdown();
        });
    });
}

#[test]
fn broken_route_swap_is_caught_by_invariants() {
    // Mutation check (ISSUE 2 acceptance): a deliberately broken route
    // swap — the bug class the atomic-cutover code exists to prevent —
    // must be caught by the invariant oracle the property suite uses.
    run_virtual(async {
        let cfg = PlatformConfig::tiny().with_compute(ComputeMode::Disabled).vanilla();
        let p = Platform::deploy(provuse::apps::chain(2), cfg).await.unwrap();
        routing_invariants(&p).expect("fresh deployment must satisfy the invariants");
        // simulate a buggy cutover: point s0 at s1's instance, which does
        // not host it
        let wrong = p.gateway.resolve("s1").unwrap();
        p.gateway.set_route("s0", wrong);
        let violation = routing_invariants(&p)
            .expect_err("broken route swap must violate the invariants");
        assert!(
            violation.contains("does not actively host"),
            "unexpected violation message: {violation}"
        );
        p.shutdown();
    });
}

#[test]
fn prop_replica_scaling_races_traffic_and_pipelines_without_drops() {
    // ISSUE 6 satellite: the REAL autoscaler (scale-up through the warm
    // pool, scale-down, scale-to-zero) churns replica sets while open-loop
    // traffic races it AND manual fuse/split pipelines rewrite the routing
    // table underneath.  Afterwards:
    //   * no request was ever dropped — in particular none committed to a
    //     draining replica, and a cold start after scale-to-zero revives
    //     the route instead of failing it;
    //   * `routing_invariants` holds (routed replicas + warm pool are
    //     exactly the live instances — a scale-up racing a cutover must
    //     not leak an instance onto a retired set);
    //   * per-replica RAM attribution sums exactly to the cluster ledger.
    check("replica scaling churn invariants", 8, |g| {
        let app = random_app(g);
        let kind = *g.choose(&[PlatformKind::Tiny, PlatformKind::Kube]);
        let mut cfg = fast_cfg(g, kind);
        cfg.cluster.nodes = g.usize(1, 3);
        cfg.scaling.replicas_max = g.usize(2, 4) as u32;
        cfg.scaling.target_inflight = g.usize(1, 4) as u32;
        cfg.scaling.scale_interval_ms = g.f64(200.0, 1_200.0);
        cfg.scaling.warm_pool = g.usize(0, 2);
        cfg.scaling.concurrency = g.usize(0, 2) as u32;
        if g.bool() {
            // scale-to-zero in play: idle routes empty out and the next
            // arrival pays a cold start (or a warm-pool attach)
            cfg.scaling.idle_horizon_ms = g.f64(2_000.0, 8_000.0);
        }
        let ops = g.usize(3, 7);
        let op_seed = g.rng().next_u64();
        let wl = WorkloadConfig {
            requests: g.usize(40, 120) as u64,
            rate_rps: g.f64(10.0, 60.0),
            seed: g.rng().next_u64(),
            timeout_ms: 120_000.0,
        };
        run_virtual(async move {
            // vanilla: the manual pipelines below are the only fusion ops,
            // but the real autoscaler is armed (replicas_max > 1) and races
            // every one of them
            let p = Platform::deploy(app, cfg.vanilla()).await.unwrap();
            let merger = manual_merger(&p);
            let sync_edges: Vec<(String, String)> = p
                .app
                .functions()
                .flat_map(|f| {
                    f.calls
                        .iter()
                        .filter(|c| c.mode == CallMode::Sync)
                        .map(|c| (f.name.clone(), c.target.clone()))
                        .collect::<Vec<_>>()
                })
                .collect();
            let traffic = provuse::exec::spawn(workload::run(Rc::clone(&p), wl));
            let mut g = Gen::replay(op_seed);
            for _ in 0..ops {
                provuse::exec::sleep_ms(g.f64(300.0, 3_000.0)).await;
                if g.bool() && !sync_edges.is_empty() {
                    // fuse a random sync pair: the fused set deploys at the
                    // busier endpoint's replica count, and its cutover may
                    // race an in-flight scale-up (aborts are in the space)
                    let (caller, callee) = g.choose(&sync_edges).clone();
                    let _ = merger.handle_fuse(&caller, &callee).await;
                } else {
                    // split a random live fused group whole
                    let groups = p.fused_groups();
                    if !groups.is_empty() {
                        let fns = sorted_members(g.choose(&groups));
                        let _ = merger.handle_split(&fns, SplitReason::RamCap).await;
                    }
                }
            }
            let report = traffic.await.unwrap();
            assert_eq!(report.failed, 0, "dropped requests under replica churn");
            provuse::exec::sleep_ms(40_000.0).await; // drains + scale-downs settle

            if let Err(violation) = routing_invariants(&p) {
                panic!("invariant violated under replica churn: {violation}");
            }
            // per-replica RAM attribution sums exactly to the cluster
            // ledger: every routed replica (sets deduped — a fused set is
            // shared by all its member routes) plus every pooled blank
            let mut seen = std::collections::HashSet::new();
            let mut routed_ram = 0.0;
            for (_, set) in p.gateway.snapshot_sets() {
                if !seen.insert(Rc::as_ptr(&set) as usize) {
                    continue;
                }
                routed_ram += set.live().iter().map(|i| i.ram_mb()).sum::<f64>();
            }
            let pool_ram: f64 = p.scaler.pool().iter().map(|i| i.ram_mb()).sum();
            let ledger = p.cluster.total_ram_mb();
            assert!(
                ((routed_ram + pool_ram) - ledger).abs() < 1e-6,
                "per-replica RAM {routed_ram} + pool {pool_ram} != cluster ledger {ledger}"
            );
            p.shutdown();
        });
    });
}

#[test]
fn prop_merge_monotonically_reduces_instances() {
    // Each completed merge reduces distinct routed instances by >= 1 and
    // the instance count never increases at quiescence.
    check("instance count monotone", 12, |g| {
        let app = random_app(g);
        let cfg = fast_cfg(g, PlatformKind::Tiny);
        run_virtual(async move {
            let p = Platform::deploy(app, cfg).await.unwrap();
            let initial = p.gateway.distinct_instances();
            let wl = WorkloadConfig {
                requests: 60,
                rate_rps: 20.0,
                seed: 1,
                timeout_ms: 120_000.0,
            };
            workload::run(Rc::clone(&p), wl).await.unwrap();
            provuse::exec::sleep_ms(30_000.0).await;
            let merges = p.metrics.merges().len();
            let now = p.gateway.distinct_instances();
            assert_eq!(now, initial - merges, "each merge must remove exactly one instance");
            p.shutdown();
        });
    });
}
