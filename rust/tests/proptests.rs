//! Property-based tests over platform invariants (mini-harness in
//! `util::prop`; replay any failure with PROP_SEED=<seed>).

use std::collections::BTreeMap;
use std::rc::Rc;

use provuse::apps::{AppSpec, CallMode, CallSpec, FunctionSpec};
use provuse::cluster::{Migrator, NodeId, Scheduler};
use provuse::config::{
    ComputeMode, FusionParams, MergePolicyKind, PlacementPolicy, PlannerKind,
    PlatformConfig, PlatformKind, SplitPolicyKind, WorkloadConfig,
};
use provuse::containerd::{ImageId, InstanceState};
use provuse::exec::run_virtual;
use provuse::fusion::plan;
use provuse::fusion::{FnSignals, NodeLoad, Plan, PlanAction, PlanSnapshot, SplitReason};
use provuse::merger::{Merger, MergerCtx};
use provuse::platform::{deployer::Deployer, routing_invariants, Platform};
use provuse::util::intern::Sym;
use provuse::util::prop::{check, Gen};
use provuse::workload::{self, request_payload};

/// Random DAG application: forward-only edges keep it acyclic by
/// construction; random sync/async modes and 1-2 trust domains.
fn random_app(g: &mut Gen) -> AppSpec {
    let n = g.usize(2, 7);
    let domains = ["alpha", "beta"];
    let n_domains = g.usize(1, 2);
    let mut functions = Vec::new();
    for i in 0..n {
        let mut calls = Vec::new();
        for j in (i + 1)..n {
            if g.f64(0.0, 1.0) < 0.45 {
                calls.push(CallSpec {
                    target: format!("f{j}"),
                    mode: if g.bool() { CallMode::Sync } else { CallMode::Async },
                    scale: g.f64(0.5, 1.5) as f32,
                });
            }
        }
        functions.push(FunctionSpec {
            name: format!("f{i}"),
            body: None,
            busy_ms: g.f64(5.0, 60.0),
            code_mb: g.f64(4.0, 24.0),
            code_kb: g.usize(16, 256) as u64,
            trust_domain: domains[g.usize(0, n_domains - 1)].into(),
            calls,
        });
    }
    AppSpec::new("prop", "f0", functions).expect("forward-edge DAG is always valid")
}

fn fast_cfg(g: &mut Gen, kind: PlatformKind) -> PlatformConfig {
    let mut cfg = PlatformConfig::of_kind(kind).with_compute(ComputeMode::Disabled);
    cfg.latency.image_build_ms = g.f64(50.0, 500.0);
    cfg.latency.boot_ms = g.f64(50.0, 300.0);
    cfg.fusion.min_observations = g.usize(1, 3) as u32;
    cfg.seed = g.rng().next_u64();
    cfg
}

#[test]
fn prop_fusion_never_changes_responses() {
    // For ANY app DAG and ANY platform flavor, enabling fusion must not
    // change a single response byte.
    check("fusion preserves responses", 20, |g| {
        let app = random_app(g);
        let kind = *g.choose(&[PlatformKind::Tiny, PlatformKind::Kube]);
        let cfg = fast_cfg(g, kind);
        let n_requests = g.usize(5, 15) as u64;
        let seed = g.rng().next_u64();

        let collect = |fusion: bool| {
            let app = app.clone();
            let mut cfg = cfg.clone();
            if !fusion {
                cfg = cfg.vanilla();
            }
            run_virtual(async move {
                let p = Platform::deploy(app, cfg).await.unwrap();
                let mut outs = Vec::new();
                for i in 0..n_requests {
                    let payload = request_payload(seed, i, p.payload_len());
                    outs.push(p.invoke(payload).await.unwrap());
                    provuse::exec::sleep_ms(150.0).await;
                }
                p.shutdown();
                outs
            })
        };
        assert_eq!(collect(false), collect(true));
    });
}

#[test]
fn prop_no_failures_and_partition_invariant() {
    // After any run: every function routes to exactly one live instance,
    // every instance's hosted set is internally consistent with the
    // routing table, and no requests were dropped.
    check("routing partition invariant", 16, |g| {
        let app = random_app(g);
        let kind = *g.choose(&[PlatformKind::Tiny, PlatformKind::Kube]);
        let cfg = fast_cfg(g, kind);
        let wl = WorkloadConfig {
            requests: g.usize(20, 80) as u64,
            rate_rps: g.f64(5.0, 50.0),
            seed: g.rng().next_u64(),
            timeout_ms: 120_000.0,
        };
        run_virtual(async move {
            let p = Platform::deploy(app, cfg).await.unwrap();
            let report = workload::run(Rc::clone(&p), wl).await.unwrap();
            assert_eq!(report.failed, 0, "dropped requests");
            provuse::exec::sleep_ms(25_000.0).await;

            let snapshot = p.gateway.snapshot();
            for (function, inst) in &snapshot {
                assert!(inst.state().is_live(), "{function} routed to dead instance");
                assert!(
                    inst.hosts(function),
                    "{function} routed to instance not hosting it"
                );
            }
            // trust domains never mix inside one instance
            for (_, inst) in &snapshot {
                let domains: std::collections::HashSet<&str> = inst
                    .functions()
                    .iter()
                    .map(|(f, _)| p.app.function(f).unwrap().trust_domain.as_str())
                    .collect();
                assert!(domains.len() <= 1, "trust domains mixed: {domains:?}");
            }
            // fused groups never exceed the theoretical sync components
            let components = p.app.sync_fusion_groups();
            for (_, inst) in &snapshot {
                let fns = inst.functions();
                if fns.len() > 1 {
                    let hosted: std::collections::BTreeSet<&str> =
                        fns.iter().map(|(f, _)| f.as_str()).collect();
                    let within_one_component = components.iter().any(|c| {
                        hosted.iter().all(|f| c.iter().any(|m| m == f))
                    });
                    assert!(within_one_component, "fused across sync components: {hosted:?}");
                }
            }
            p.shutdown();
        });
    });
}

#[test]
fn prop_ram_ledger_conservation() {
    // At quiescence the ledger equals base * instances + total code, no
    // matter what merge history happened.
    check("ram ledger conservation", 12, |g| {
        let app = random_app(g);
        let cfg = fast_cfg(g, PlatformKind::Tiny);
        let wl = WorkloadConfig {
            requests: g.usize(15, 50) as u64,
            rate_rps: 20.0,
            seed: g.rng().next_u64(),
            timeout_ms: 120_000.0,
        };
        run_virtual(async move {
            let p = Platform::deploy(app, cfg).await.unwrap();
            workload::run(Rc::clone(&p), wl).await.unwrap();
            provuse::exec::sleep_ms(30_000.0).await;

            let code_total: f64 = p.app.functions().map(|f| f.code_mb).sum();
            let expected = p.config.ram.base_instance_mb * p.containers.live_count() as f64
                + code_total;
            let actual = p.containers.total_ram_mb();
            assert!(
                (actual - expected).abs() < 1e-6,
                "ledger {actual} != {expected} ({} instances)",
                p.containers.live_count()
            );
        });
    });
}

/// A Merger handle over an existing platform's context, so a test can
/// drive Fuse/Split/Evict pipelines explicitly (same pattern as the
/// stale-split test in failure_injection.rs).
fn manual_merger(p: &Rc<Platform>) -> Merger {
    let originals: BTreeMap<String, ImageId> = p
        .app
        .functions()
        .filter_map(|f| p.original_image(&f.name).map(|img| (f.name.clone(), img)))
        .collect();
    Merger::new(MergerCtx {
        config: Rc::clone(&p.config),
        containers: p.containers.clone(),
        cluster: p.cluster.clone(),
        scheduler: Scheduler::new(p.config.cluster.placement, p.cluster.clone()),
        gateway: p.gateway.clone(),
        observer: Rc::clone(&p.observer),
        metrics: p.metrics.clone(),
        deployer: Deployer::direct(p.cluster.clone()),
        originals: Rc::new(originals),
    })
}

/// Sorted member list of the fused group hosting `probe`'s instance.
fn sorted_members(inst: &provuse::containerd::Instance) -> Vec<String> {
    let mut fns: Vec<String> = inst.functions().iter().map(|(n, _)| n.clone()).collect();
    fns.sort();
    fns
}

#[test]
fn prop_fuse_split_evict_interleavings_preserve_invariants() {
    // ISSUE 2 tentpole property: after ANY random interleaving of Fuse /
    // Split / Evict pipeline runs (with traffic woven through) over random
    // DAG apps, the routing table remains a bijection onto the live
    // instances, no function is served by two instances, and every evicted
    // pair is in cooldown.  Pipelines run through the real Merger against a
    // live platform; aborted ops (stale groups etc.) are part of the space.
    check("fuse/split/evict interleaving invariants", 64, |g| {
        let app = random_app(g);
        let kind = *g.choose(&[PlatformKind::Tiny, PlatformKind::Kube]);
        let mut cfg = fast_cfg(g, kind);
        cfg.fusion.feedback_interval_ms = 0.0; // controller off: ops driven by hand
        let ops = g.usize(4, 10);
        let op_seed = g.rng().next_u64();
        run_virtual(async move {
            // vanilla platform: the in-platform merger stays idle, so the
            // manual pipeline runs below are the only topology mutations
            // (the real system serializes pipelines the same way)
            let p = Platform::deploy(app, cfg.vanilla()).await.unwrap();
            let merger = manual_merger(&p);
            let mut g = Gen::replay(op_seed);
            let sync_edges: Vec<(String, String)> = p
                .app
                .functions()
                .flat_map(|f| {
                    f.calls
                        .iter()
                        .filter(|c| c.mode == CallMode::Sync)
                        .map(|c| (f.name.clone(), c.target.clone()))
                        .collect::<Vec<_>>()
                })
                .collect();
            for _ in 0..ops {
                match g.weighted(&[3.0, 3.0, 2.0, 2.0]) {
                    0 => {
                        // traffic (entry route; exercises inline + remote paths)
                        let wl = WorkloadConfig {
                            requests: g.usize(5, 15) as u64,
                            rate_rps: 20.0,
                            seed: g.rng().next_u64(),
                            timeout_ms: 120_000.0,
                        };
                        let report = workload::run(Rc::clone(&p), wl).await.unwrap();
                        assert_eq!(report.failed, 0, "dropped requests");
                    }
                    1 => {
                        // fuse a random sync pair (may abort: already
                        // colocated after a previous fuse — fine)
                        if !sync_edges.is_empty() {
                            let (caller, callee) = g.choose(&sync_edges).clone();
                            let _ = merger.handle_fuse(&caller, &callee).await;
                        }
                    }
                    2 => {
                        // split a random live fused group whole
                        let groups = p.fused_groups();
                        if !groups.is_empty() {
                            let fns = sorted_members(g.choose(&groups));
                            let _ = merger.handle_split(&fns, SplitReason::RamCap).await;
                        }
                    }
                    3 => {
                        // evict a random member of a random fused group
                        let groups = p.fused_groups();
                        if !groups.is_empty() {
                            let fns = sorted_members(g.choose(&groups));
                            let victim = g.choose(&fns).clone();
                            if merger
                                .handle_evict(&fns, &victim, SplitReason::CostModel)
                                .await
                                .is_ok()
                            {
                                // every evicted pair is in cooldown, both
                                // directions; surviving pairs are not
                                for other in fns.iter().filter(|f| **f != victim) {
                                    assert!(
                                        p.observer.pair_in_cooldown(&victim, other),
                                        "evicted pair ({victim}, {other}) not cooling"
                                    );
                                    assert!(
                                        p.observer.pair_in_cooldown(other, &victim),
                                        "evicted pair ({other}, {victim}) not cooling"
                                    );
                                }
                            }
                        }
                    }
                    _ => unreachable!(),
                }
                provuse::exec::sleep_ms(g.f64(100.0, 2_000.0)).await;
            }
            provuse::exec::sleep_ms(25_000.0).await; // drains settle
            if let Err(violation) = routing_invariants(&p) {
                panic!("invariant violated after interleaving: {violation}");
            }
            p.shutdown();
        });
    });
}

#[test]
fn prop_controller_loop_fuzz_preserves_invariants_and_never_flaps() {
    // ISSUE 3 satellite (ROADMAP: "fuzz the controller loop itself"): the
    // REAL controller tick — not hand-driven pipelines — runs at a
    // randomized feedback interval under a randomized policy mix (split
    // threshold vs cost model, merge observation-count vs cost planner,
    // auto-tune on/off) while entry + targeted per-route traffic races it.
    // Afterwards: `routing_invariants` holds, no request was dropped, and
    // no pair that a defusion tore apart was re-fused within one cooldown
    // of that defusion (the anti-flap contract).
    check("controller loop fuzz", 10, |g| {
        let app = random_app(g);
        let kind = *g.choose(&[PlatformKind::Tiny, PlatformKind::Kube]);
        let mut cfg = fast_cfg(g, kind);
        cfg.fusion.feedback_interval_ms = g.f64(300.0, 2_500.0);
        cfg.fusion.split_hysteresis_windows = g.usize(1, 3) as u32;
        cfg.fusion.cooldown_ms = g.f64(4_000.0, 15_000.0);
        cfg.fusion.max_group_ram_mb = g.f64(60.0, 250.0);
        cfg.fusion.split_p95_regression = g.f64(0.2, 1.5);
        cfg.fusion.split_policy = if g.bool() {
            SplitPolicyKind::CostModel
        } else {
            SplitPolicyKind::Threshold
        };
        cfg.fusion.cost.evict_threshold = g.f64(0.5, 3.0);
        if g.bool() {
            cfg.fusion.merge_policy = MergePolicyKind::CostModel;
            cfg.fusion.cost.merge_threshold = g.f64(-0.5, 0.5);
            cfg.fusion.auto_tune = g.bool();
        }
        let n_targeted = g.usize(1, 3);
        let wl_seed = g.rng().next_u64();
        let targeted_rps = g.f64(5.0, 40.0);
        let entry_requests = g.usize(30, 120) as u64;
        run_virtual(async move {
            let p = Platform::deploy(app, cfg).await.unwrap();
            let names: Vec<String> =
                p.app.functions().map(|f| f.name.clone()).collect();
            let mut g = Gen::replay(wl_seed);
            let mut handles = Vec::new();
            handles.push(provuse::exec::spawn(workload::run(
                Rc::clone(&p),
                WorkloadConfig {
                    requests: entry_requests,
                    rate_rps: g.f64(5.0, 30.0),
                    seed: g.rng().next_u64(),
                    timeout_ms: 120_000.0,
                },
            )));
            for _ in 0..n_targeted {
                let target = g.choose(&names).clone();
                let wl = WorkloadConfig {
                    requests: g.usize(20, 100) as u64,
                    rate_rps: targeted_rps,
                    seed: g.rng().next_u64(),
                    timeout_ms: 120_000.0,
                };
                let p2 = Rc::clone(&p);
                handles.push(provuse::exec::spawn(async move {
                    workload::run_targeted(
                        p2,
                        wl,
                        provuse::workload::Arrival::Constant,
                        Some(target.as_str()),
                    )
                    .await
                }));
            }
            for h in handles {
                let report = h.await.unwrap();
                assert_eq!(report.failed, 0, "dropped requests under the controller");
            }
            // let every in-flight pipeline and drain settle
            provuse::exec::sleep_ms(30_000.0).await;
            if let Err(violation) = routing_invariants(&p) {
                panic!("invariant violated under the live controller: {violation}");
            }
            // anti-flap oracle over the full event timeline: for every
            // defusion, no merge re-joins one of its torn-apart pairs
            // within one cooldown.  A split tears every pair apart; an
            // evict tears only the (evicted, member) pairs.
            let cooldown = p.config.fusion.cooldown_ms;
            let merges = p.metrics.merges();
            let mut torn: Vec<(f64, String, String)> = Vec::new();
            for s in p.metrics.splits() {
                for a in &s.functions {
                    for b in &s.functions {
                        if a < b {
                            torn.push((s.t_ms, a.clone(), b.clone()));
                        }
                    }
                }
            }
            for e in p.metrics.evicts() {
                for m in e.group.iter().filter(|f| **f != e.function) {
                    let (a, b) = if *m < e.function {
                        (m.clone(), e.function.clone())
                    } else {
                        (e.function.clone(), m.clone())
                    };
                    torn.push((e.t_ms, a, b));
                }
            }
            for (t, a, b) in &torn {
                for m in &merges {
                    let rejoined = m.functions.iter().any(|f| f == a)
                        && m.functions.iter().any(|f| f == b);
                    if rejoined && m.t_ms > *t && m.t_ms < *t + cooldown {
                        panic!(
                            "fuse->defuse->fuse flap: ({a}, {b}) defused at {t:.0} ms \
                             re-merged at {:.0} ms inside the {cooldown:.0} ms cooldown",
                            m.t_ms
                        );
                    }
                }
            }
            p.shutdown();
        });
    });
}

#[test]
fn prop_trace_conservation_under_policy_churn() {
    // ISSUE 9 satellite property: with sampling at 1-in-1, for ANY random
    // DAG app, ANY cluster shape, and ANY interleaving of fuse / split /
    // evict / migrate pipelines racing open-loop traffic, every retained
    // trace is a well-formed span tree and every successful request's
    // critical path sums **bit-for-bit** to its recorded e2e latency —
    // cold-start waits, cutover stalls, inline hops and cross-node
    // surcharges included.  The exactness contract is what makes the
    // latency breakdown trustworthy; any drift (a span double-charged, a
    // stall untracked) fails here before it can skew an experiment.
    check("trace conservation under churn", 12, |g| {
        let app = random_app(g);
        let kind = *g.choose(&[PlatformKind::Tiny, PlatformKind::Kube]);
        let mut cfg = fast_cfg(g, kind);
        cfg.cluster.nodes = g.usize(1, 3);
        cfg.fusion.feedback_interval_ms = 0.0; // ops driven by hand
        cfg.trace.sample_every = 1;
        cfg.trace.max_traces = 4096;
        let ops = g.usize(3, 8);
        let op_seed = g.rng().next_u64();
        let wl = WorkloadConfig {
            requests: g.usize(30, 90) as u64,
            rate_rps: g.f64(10.0, 50.0),
            seed: g.rng().next_u64(),
            timeout_ms: 120_000.0,
        };
        let n_requests = wl.requests;
        run_virtual(async move {
            // vanilla platform: the manual pipelines below are the only
            // topology mutations, all racing the traced traffic
            let p = Platform::deploy(app, cfg.vanilla()).await.unwrap();
            let merger = manual_merger(&p);
            let migrator = Migrator::new(
                p.cluster.clone(),
                Deployer::direct(p.cluster.clone()),
                p.gateway.clone(),
                p.metrics.clone(),
                Rc::clone(&p.config),
            );
            let n_nodes = p.cluster.node_count();
            let names: Vec<String> = p.app.functions().map(|f| f.name.clone()).collect();
            let sync_edges: Vec<(String, String)> = p
                .app
                .functions()
                .flat_map(|f| {
                    f.calls
                        .iter()
                        .filter(|c| c.mode == CallMode::Sync)
                        .map(|c| (f.name.clone(), c.target.clone()))
                        .collect::<Vec<_>>()
                })
                .collect();
            let traffic = provuse::exec::spawn(workload::run(Rc::clone(&p), wl));
            let mut g = Gen::replay(op_seed);
            for _ in 0..ops {
                provuse::exec::sleep_ms(g.f64(200.0, 2_500.0)).await;
                match g.weighted(&[3.0, 2.0, 2.0, 2.0]) {
                    0 => {
                        if !sync_edges.is_empty() {
                            let (caller, callee) = g.choose(&sync_edges).clone();
                            let _ = merger.handle_fuse(&caller, &callee).await;
                        }
                    }
                    1 => {
                        let groups = p.fused_groups();
                        if !groups.is_empty() {
                            let fns = sorted_members(g.choose(&groups));
                            let _ = merger.handle_split(&fns, SplitReason::RamCap).await;
                        }
                    }
                    2 => {
                        let groups = p.fused_groups();
                        if !groups.is_empty() {
                            let fns = sorted_members(g.choose(&groups));
                            let victim = g.choose(&fns).clone();
                            let _ = merger
                                .handle_evict(&fns, &victim, SplitReason::CostModel)
                                .await;
                        }
                    }
                    3 => {
                        let probe = g.choose(&names).clone();
                        let group = p.group_members(&probe);
                        let to = NodeId(g.usize(0, n_nodes - 1) as u64);
                        let _ = migrator.migrate(&group, to, "prop").await;
                    }
                    _ => unreachable!(),
                }
            }
            let report = traffic.await.unwrap();
            assert_eq!(report.failed, 0, "dropped requests under churn");
            provuse::exec::sleep_ms(25_000.0).await; // drains settle

            assert_eq!(p.tracer.conservation_violations(), 0);
            let traces = p.tracer.snapshot();
            assert_eq!(
                traces.len() as u64,
                n_requests,
                "1-in-1 sampling must retain every request"
            );
            for t in &traces {
                provuse::trace::verify(t).unwrap_or_else(|e| panic!("{e}"));
                assert!(!t.dropped, "no request dropped, no trace may be");
                assert!(
                    t.conserved,
                    "critical path must sum bit-for-bit to the e2e latency"
                );
            }
            p.shutdown();
        });
    });
}

#[test]
fn prop_cluster_invariants_hold_across_placements_and_migrations() {
    // ISSUE 4 satellite: for ANY node count, placement policy, capacity
    // regime, and traffic, with random fuse + migrate pipelines woven
    // through (driven serially against a vanilla platform, the way the
    // real Merger serializes them, while open-loop entry traffic races
    // every cutover):
    //   * the routing invariants hold at quiescence;
    //   * no request is ever dropped — in particular none routed to a
    //     draining migration source;
    //   * total cluster RAM accounting equals the sum of the per-node
    //     ledgers, and every routed instance has a node assignment.
    check("cluster placement + migration invariants", 10, |g| {
        let app = random_app(g);
        let mut cfg = fast_cfg(g, PlatformKind::Tiny);
        cfg.cluster.nodes = g.usize(1, 4);
        cfg.cluster.placement = *g.choose(&[
            PlacementPolicy::BinPack,
            PlacementPolicy::Spread,
            PlacementPolicy::FusionAffinity,
        ]);
        // generous capacity (or uncapped) so the initial placement always
        // fits; individual migrations may still be refused — that's part
        // of the space
        cfg.cluster.node_capacity_mb = if g.bool() { 0.0 } else { g.f64(700.0, 2_000.0) };
        let ops = g.usize(3, 8);
        let op_seed = g.rng().next_u64();
        let wl = WorkloadConfig {
            requests: g.usize(30, 90) as u64,
            rate_rps: g.f64(5.0, 25.0),
            seed: g.rng().next_u64(),
            timeout_ms: 120_000.0,
        };
        run_virtual(async move {
            // vanilla: the in-platform merger stays idle, so the serial
            // manual pipelines below are the only topology mutations
            let p = Platform::deploy(app, cfg.vanilla()).await.unwrap();
            let n_nodes = p.cluster.node_count();
            for (f, inst) in p.gateway.snapshot() {
                assert!(
                    p.cluster.node_of(inst.id()).is_some(),
                    "`{f}` deployed without a node assignment"
                );
            }
            let merger = manual_merger(&p);
            let migrator = Migrator::new(
                p.cluster.clone(),
                Deployer::direct(p.cluster.clone()),
                p.gateway.clone(),
                p.metrics.clone(),
                Rc::clone(&p.config),
            );
            let names: Vec<String> = p.app.functions().map(|f| f.name.clone()).collect();
            let sync_edges: Vec<(String, String)> = p
                .app
                .functions()
                .flat_map(|f| {
                    f.calls
                        .iter()
                        .filter(|c| c.mode == CallMode::Sync)
                        .map(|c| (f.name.clone(), c.target.clone()))
                        .collect::<Vec<_>>()
                })
                .collect();

            // entry traffic races every pipeline below (open loop)
            let traffic = provuse::exec::spawn(workload::run(Rc::clone(&p), wl));

            let mut g = Gen::replay(op_seed);
            for _ in 0..ops {
                provuse::exec::sleep_ms(g.f64(200.0, 2_500.0)).await;
                if g.bool() && !sync_edges.is_empty() {
                    // fuse a random sync pair — on a multi-node cluster
                    // this may itself run a co-location migration; aborts
                    // (already colocated, capacity) are part of the space
                    let (caller, callee) = g.choose(&sync_edges).clone();
                    let _ = merger.handle_fuse(&caller, &callee).await;
                } else {
                    // migrate the live group of a random function to a
                    // random node
                    let probe = g.choose(&names).clone();
                    let group = p.group_members(&probe);
                    let to = NodeId(g.usize(0, n_nodes - 1) as u64);
                    match migrator.migrate(&group, to, "prop").await {
                        Ok(fresh) => {
                            assert_eq!(p.cluster.node_of(fresh.id()), Some(to));
                            // the cutover was atomic: every member routes
                            // to the replacement, never the draining source
                            for f in &group {
                                assert_eq!(
                                    p.gateway.resolve(f).unwrap().id(),
                                    fresh.id(),
                                    "`{f}` still routed to the migration source"
                                );
                            }
                        }
                        Err(_) => {} // no-op/stale/capacity refusals are fine
                    }
                }
            }
            let report = traffic.await.unwrap();
            assert_eq!(report.failed, 0, "dropped requests under cluster churn");
            provuse::exec::sleep_ms(30_000.0).await; // drains settle

            if let Err(violation) = routing_invariants(&p) {
                panic!("invariant violated on the cluster: {violation}");
            }
            // per-node accounting sums exactly to the cluster ledger
            let node_ram: f64 = p.cluster.nodes().iter().map(|n| n.ram_mb()).sum();
            assert!(
                (node_ram - p.cluster.total_ram_mb()).abs() < 1e-6,
                "per-node RAM {node_ram} != cluster total {}",
                p.cluster.total_ram_mb()
            );
            let node_count: usize = p.cluster.nodes().iter().map(|n| n.live_count()).sum();
            assert_eq!(node_count, p.cluster.live_count());
            // at quiescence every route points at a healthy, node-assigned
            // instance (a draining source still routed would show up here)
            for (f, inst) in p.gateway.snapshot() {
                assert_eq!(
                    inst.state(),
                    InstanceState::Healthy,
                    "`{f}` routed to a {} instance",
                    inst.state().name()
                );
                assert!(p.cluster.node_of(inst.id()).is_some());
            }
            p.shutdown();
        });
    });
}

#[test]
fn broken_route_swap_is_caught_by_invariants() {
    // Mutation check (ISSUE 2 acceptance): a deliberately broken route
    // swap — the bug class the atomic-cutover code exists to prevent —
    // must be caught by the invariant oracle the property suite uses.
    run_virtual(async {
        let cfg = PlatformConfig::tiny().with_compute(ComputeMode::Disabled).vanilla();
        let p = Platform::deploy(provuse::apps::chain(2), cfg).await.unwrap();
        routing_invariants(&p).expect("fresh deployment must satisfy the invariants");
        // simulate a buggy cutover: point s0 at s1's instance, which does
        // not host it
        let wrong = p.gateway.resolve("s1").unwrap();
        p.gateway.set_route("s0", wrong);
        let violation = routing_invariants(&p)
            .expect_err("broken route swap must violate the invariants");
        assert!(
            violation.contains("does not actively host"),
            "unexpected violation message: {violation}"
        );
        p.shutdown();
    });
}

#[test]
fn prop_replica_scaling_races_traffic_and_pipelines_without_drops() {
    // ISSUE 6 satellite: the REAL autoscaler (scale-up through the warm
    // pool, scale-down, scale-to-zero) churns replica sets while open-loop
    // traffic races it AND manual fuse/split pipelines rewrite the routing
    // table underneath.  Afterwards:
    //   * no request was ever dropped — in particular none committed to a
    //     draining replica, and a cold start after scale-to-zero revives
    //     the route instead of failing it;
    //   * `routing_invariants` holds (routed replicas + warm pool are
    //     exactly the live instances — a scale-up racing a cutover must
    //     not leak an instance onto a retired set);
    //   * per-replica RAM attribution sums exactly to the cluster ledger.
    check("replica scaling churn invariants", 8, |g| {
        let app = random_app(g);
        let kind = *g.choose(&[PlatformKind::Tiny, PlatformKind::Kube]);
        let mut cfg = fast_cfg(g, kind);
        cfg.cluster.nodes = g.usize(1, 3);
        cfg.scaling.replicas_max = g.usize(2, 4) as u32;
        cfg.scaling.target_inflight = g.usize(1, 4) as u32;
        cfg.scaling.scale_interval_ms = g.f64(200.0, 1_200.0);
        cfg.scaling.warm_pool = g.usize(0, 2);
        cfg.scaling.concurrency = g.usize(0, 2) as u32;
        if g.bool() {
            // scale-to-zero in play: idle routes empty out and the next
            // arrival pays a cold start (or a warm-pool attach)
            cfg.scaling.idle_horizon_ms = g.f64(2_000.0, 8_000.0);
        }
        let ops = g.usize(3, 7);
        let op_seed = g.rng().next_u64();
        let wl = WorkloadConfig {
            requests: g.usize(40, 120) as u64,
            rate_rps: g.f64(10.0, 60.0),
            seed: g.rng().next_u64(),
            timeout_ms: 120_000.0,
        };
        run_virtual(async move {
            // vanilla: the manual pipelines below are the only fusion ops,
            // but the real autoscaler is armed (replicas_max > 1) and races
            // every one of them
            let p = Platform::deploy(app, cfg.vanilla()).await.unwrap();
            let merger = manual_merger(&p);
            let sync_edges: Vec<(String, String)> = p
                .app
                .functions()
                .flat_map(|f| {
                    f.calls
                        .iter()
                        .filter(|c| c.mode == CallMode::Sync)
                        .map(|c| (f.name.clone(), c.target.clone()))
                        .collect::<Vec<_>>()
                })
                .collect();
            let traffic = provuse::exec::spawn(workload::run(Rc::clone(&p), wl));
            let mut g = Gen::replay(op_seed);
            for _ in 0..ops {
                provuse::exec::sleep_ms(g.f64(300.0, 3_000.0)).await;
                if g.bool() && !sync_edges.is_empty() {
                    // fuse a random sync pair: the fused set deploys at the
                    // busier endpoint's replica count, and its cutover may
                    // race an in-flight scale-up (aborts are in the space)
                    let (caller, callee) = g.choose(&sync_edges).clone();
                    let _ = merger.handle_fuse(&caller, &callee).await;
                } else {
                    // split a random live fused group whole
                    let groups = p.fused_groups();
                    if !groups.is_empty() {
                        let fns = sorted_members(g.choose(&groups));
                        let _ = merger.handle_split(&fns, SplitReason::RamCap).await;
                    }
                }
            }
            let report = traffic.await.unwrap();
            assert_eq!(report.failed, 0, "dropped requests under replica churn");
            provuse::exec::sleep_ms(40_000.0).await; // drains + scale-downs settle

            if let Err(violation) = routing_invariants(&p) {
                panic!("invariant violated under replica churn: {violation}");
            }
            // per-replica RAM attribution sums exactly to the cluster
            // ledger: every routed replica (sets deduped — a fused set is
            // shared by all its member routes) plus every pooled blank
            let mut seen = std::collections::HashSet::new();
            let mut routed_ram = 0.0;
            for (_, set) in p.gateway.snapshot_sets() {
                if !seen.insert(Rc::as_ptr(&set) as usize) {
                    continue;
                }
                routed_ram += set.live().iter().map(|i| i.ram_mb()).sum::<f64>();
            }
            let pool_ram: f64 = p.scaler.pool().iter().map(|i| i.ram_mb()).sum();
            let ledger = p.cluster.total_ram_mb();
            assert!(
                ((routed_ram + pool_ram) - ledger).abs() < 1e-6,
                "per-replica RAM {routed_ram} + pool {pool_ram} != cluster ledger {ledger}"
            );
            p.shutdown();
        });
    });
}

#[test]
fn prop_global_plans_are_valid() {
    // ISSUE 8 tentpole property: for ANY random call graph, signal set,
    // live grouping, cooldown set, and node-capacity regime, a plan the
    // global search emits satisfies every structural contract:
    //   * the target partition is disjoint and complete over the snapshot
    //     universe;
    //   * every multi-member target group is connected via OBSERVED sync
    //     edges, trust-uniform (when enforced), inside the size/RAM caps,
    //     and contains no cooling pair;
    //   * predicted per-node RAM footprints respect node capacities;
    //   * every Fuse action follows an observed sync edge;
    //   * replaying the plan-diff over the snapshot partition reproduces
    //     the target partition exactly (the executor applies precisely
    //     what the search scored);
    //   * the search is deterministic for a pinned (snapshot, seed).
    check("global plan validity", 48, |g| {
        let n = g.usize(2, 8);
        let domains = ["alpha", "beta"];
        let n_domains = g.usize(1, 2);
        let nodes = g.usize(1, 3);
        let mut signals = Vec::new();
        let mut trust = BTreeMap::new();
        for i in 0..n {
            let name = format!("f{i}");
            signals.push(FnSignals {
                function: Sym::intern(&name),
                ram_mb: g.f64(20.0, 700.0),
                p95_ms: g.f64(5.0, 200.0),
                gb_seconds: g.f64(0.0, 3.0),
                billed_ms: g.f64(100.0, 6_000.0),
                self_ms: g.f64(50.0, 1_000.0),
                window_s: g.f64(1.0, 30.0),
                node: if nodes > 1 {
                    Some(NodeId(g.usize(0, nodes - 1) as u64))
                } else {
                    None
                },
                replicas: g.usize(1, 3) as u32,
            });
            trust.insert(name, domains[g.usize(0, n_domains - 1)].to_string());
        }
        let mut edges: Vec<((String, String), u64)> = Vec::new();
        for i in 0..n {
            for j in (i + 1)..n {
                if g.f64(0.0, 1.0) < 0.4 {
                    edges.push(((format!("f{i}"), format!("f{j}")), g.usize(1, 500) as u64));
                }
            }
        }
        // live groups: grown along a random subset of observed same-trust
        // edges — the kind of topology a greedy history could have built
        fn find(owner: &mut Vec<usize>, mut x: usize) -> usize {
            while owner[x] != x {
                owner[x] = owner[owner[x]];
                x = owner[x];
            }
            x
        }
        let mut owner: Vec<usize> = (0..n).collect();
        for ((a, b), _) in &edges {
            if g.f64(0.0, 1.0) < 0.3 && trust[a] == trust[b] {
                let i: usize = a[1..].parse().unwrap();
                let j: usize = b[1..].parse().unwrap();
                let (ra, rb) = (find(&mut owner, i), find(&mut owner, j));
                if ra != rb {
                    owner[ra] = rb;
                }
            }
        }
        let mut by_root: BTreeMap<usize, Vec<String>> = BTreeMap::new();
        for i in 0..n {
            let r = find(&mut owner, i);
            by_root.entry(r).or_default().push(format!("f{i}"));
        }
        let groups: Vec<Vec<String>> =
            by_root.into_values().filter(|members| members.len() > 1).collect();
        let cooling: Vec<(String, String)> = edges
            .iter()
            .filter(|_| g.f64(0.0, 1.0) < 0.15)
            .map(|((a, b), _)| (a.clone(), b.clone()))
            .collect();
        let node_loads: Vec<NodeLoad> = if nodes > 1 {
            (0..nodes)
                .map(|k| NodeLoad {
                    node: NodeId(k as u64),
                    ram_mb: 0.0,
                    capacity_mb: if g.bool() { g.f64(1_000.0, 4_000.0) } else { 0.0 },
                })
                .collect()
        } else {
            Vec::new()
        };
        let snap = PlanSnapshot {
            epoch: g.rng().next_u64() % 1_000,
            signals,
            edges,
            groups,
            node_loads,
            migration_est_ms: g.f64(0.0, 2_000.0),
            trust,
            cooling,
        };
        let mut policy = FusionParams::default_enabled();
        policy.respect_trust_domains = g.bool();
        policy.max_group_size = if g.bool() { 0 } else { g.usize(2, 4) };
        policy.max_group_ram_mb = if g.bool() { 0.0 } else { g.f64(400.0, 1_500.0) };
        let seed = g.rng().next_u64();

        let Some(p) = plan::search(&snap, &policy, seed, 1) else {
            return; // no profitable re-plan for this snapshot — valid outcome
        };
        assert_eq!(
            Some(&p),
            plan::search(&snap, &policy, seed, 1).as_ref(),
            "search must be deterministic for a pinned (snapshot, seed)"
        );
        assert_eq!(p.epoch, snap.epoch, "plan must carry the snapshot epoch");
        assert!(!p.actions.is_empty());

        // disjoint + complete over the snapshot universe
        let universe: std::collections::BTreeSet<String> = snap
            .signals
            .iter()
            .map(|s| s.function.as_str().to_string())
            .chain(snap.groups.iter().flatten().cloned())
            .collect();
        let mut seen = std::collections::BTreeSet::new();
        for pg in &p.target {
            for f in &pg.functions {
                assert!(universe.contains(f), "target invents `{f}`");
                assert!(seen.insert(f.clone()), "target repeats `{f}`");
            }
        }
        assert_eq!(seen, universe, "target partition must be complete");

        let adj: std::collections::HashSet<(String, String)> = snap
            .edges
            .iter()
            .flat_map(|((a, b), _)| [(a.clone(), b.clone()), (b.clone(), a.clone())])
            .collect();
        let sigs: std::collections::HashMap<&str, &FnSignals> =
            snap.signals.iter().map(|s| (s.function.as_str(), s)).collect();
        for pg in &p.target {
            if pg.functions.len() < 2 {
                continue;
            }
            // connected via observed sync edges only
            let mut reach = std::collections::HashSet::new();
            reach.insert(pg.functions[0].clone());
            let mut queue = std::collections::VecDeque::from([pg.functions[0].clone()]);
            while let Some(u) = queue.pop_front() {
                for v in &pg.functions {
                    if !reach.contains(v) && adj.contains(&(u.clone(), v.clone())) {
                        reach.insert(v.clone());
                        queue.push_back(v.clone());
                    }
                }
            }
            assert_eq!(
                reach.len(),
                pg.functions.len(),
                "target group not edge-connected: {:?}",
                pg.functions
            );
            if policy.respect_trust_domains {
                let doms: std::collections::HashSet<&String> =
                    pg.functions.iter().map(|f| snap.trust.get(f).unwrap()).collect();
                assert_eq!(doms.len(), 1, "trust domains mixed: {:?}", pg.functions);
            }
            if policy.max_group_size > 0 {
                assert!(pg.functions.len() <= policy.max_group_size);
            }
            for (a, b) in &snap.cooling {
                assert!(
                    !(pg.functions.contains(a) && pg.functions.contains(b)),
                    "cooling pair ({a}, {b}) regrouped"
                );
            }
            if policy.max_group_ram_mb > 0.0 {
                let ram: f64 = pg
                    .functions
                    .iter()
                    .filter_map(|f| sigs.get(f.as_str()))
                    .map(|s| s.ram_mb)
                    .sum();
                assert!(ram <= policy.max_group_ram_mb + 1e-9, "group RAM cap violated");
            }
        }

        // predicted per-node footprints respect capacities
        let caps: std::collections::HashMap<u64, f64> = snap
            .node_loads
            .iter()
            .filter(|l| l.capacity_mb > 0.0)
            .map(|l| (l.node.0, l.capacity_mb))
            .collect();
        if !caps.is_empty() {
            let mut load: std::collections::HashMap<u64, f64> =
                std::collections::HashMap::new();
            for pg in &p.target {
                if let Some(node) = pg.node {
                    let ram: f64 = pg
                        .functions
                        .iter()
                        .filter_map(|f| sigs.get(f.as_str()))
                        .map(|s| s.ram_mb)
                        .sum();
                    let replicas = pg
                        .functions
                        .iter()
                        .filter_map(|f| sigs.get(f.as_str()))
                        .map(|s| s.replicas.max(1))
                        .max()
                        .unwrap_or(1);
                    *load.entry(node.0).or_insert(0.0) += ram * replicas as f64;
                }
            }
            for (node, cap) in &caps {
                assert!(
                    load.get(node).copied().unwrap_or(0.0) <= cap + 1e-6,
                    "node {node} over capacity"
                );
            }
        }

        // every fuse follows an observed sync edge
        for a in &p.actions {
            if let PlanAction::Fuse { caller, callee } = a {
                assert!(
                    adj.contains(&(caller.clone(), callee.clone())),
                    "fuse off the observed graph: {caller} -> {callee}"
                );
            }
        }

        // replaying the diff over the snapshot partition reproduces the
        // target exactly
        let mut target_parts: Vec<Vec<String>> = p
            .target
            .iter()
            .map(|pg| {
                let mut v = pg.functions.clone();
                v.sort();
                v
            })
            .collect();
        target_parts.sort();
        assert_eq!(
            plan::apply_diff(&plan::snapshot_partition(&snap), &p.actions),
            target_parts,
            "plan-diff replay must land on the scored target"
        );
    });
}

#[test]
fn stale_plan_aborts_cleanly_without_partial_application() {
    // ISSUE 8 satellite: a topology change landing between plan emission
    // and execution must abort the WHOLE remainder — no partial
    // application, no cooldown poisoning.
    run_virtual(async {
        let mut cfg = PlatformConfig::tiny().with_compute(ComputeMode::Disabled);
        cfg.fusion.feedback_interval_ms = 0.0; // ops driven by hand
        let p = Platform::deploy(provuse::apps::chain(3), cfg.vanilla()).await.unwrap();
        let merger = manual_merger(&p);
        let plan = Plan {
            id: 1,
            epoch: p.observer.topology_epoch(),
            actions: vec![
                PlanAction::Fuse { caller: "s1".into(), callee: "s2".into() },
                PlanAction::Fuse { caller: "s0".into(), callee: "s1".into() },
            ],
            predicted_before: 1.0,
            predicted_after: 0.5,
            target: Vec::new(),
        };
        // the topology moves before the plan runs (a foreign fuse lands)
        merger.handle_fuse("s0", "s1").await.unwrap();
        merger.execute_plan(plan).await;

        // aborted before action 0: s1 + s2 were never joined
        assert_ne!(
            p.gateway.resolve("s1").unwrap().id(),
            p.gateway.resolve("s2").unwrap().id(),
            "stale plan must not apply any action"
        );
        assert_eq!(p.metrics.counter("plan_aborted_stale"), 1);
        assert_eq!(p.metrics.counter("plans_executed"), 0);
        let events = p.metrics.plans();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].kind, "aborted");
        assert!(
            events[0].detail.contains("stale_epoch_before_action_0"),
            "unexpected abort detail: {}",
            events[0].detail
        );
        // an abort is not a failure: no pair cooldown was poisoned
        assert!(!p.observer.pair_in_cooldown("s1", "s2"));
        assert!(!p.observer.pair_in_cooldown("s2", "s1"));
        p.shutdown();
    });
}

#[test]
fn mid_plan_epoch_skew_aborts_the_remainder() {
    // A plan action that completes WITHOUT exactly one epoch bump (here: a
    // fuse that turns out to be a no-op because the pair is already
    // colocated) means the plan no longer describes the live topology —
    // the executor must stop right there.
    run_virtual(async {
        let mut cfg = PlatformConfig::tiny().with_compute(ComputeMode::Disabled);
        cfg.fusion.feedback_interval_ms = 0.0;
        let p = Platform::deploy(provuse::apps::chain(3), cfg.vanilla()).await.unwrap();
        let merger = manual_merger(&p);
        merger.handle_fuse("s0", "s1").await.unwrap();
        let plan = Plan {
            id: 2,
            epoch: p.observer.topology_epoch(),
            actions: vec![
                PlanAction::Fuse { caller: "s0".into(), callee: "s1".into() }, // no-op
                PlanAction::Fuse { caller: "s1".into(), callee: "s2".into() },
            ],
            predicted_before: 1.0,
            predicted_after: 0.5,
            target: Vec::new(),
        };
        merger.execute_plan(plan).await;
        assert_ne!(
            p.gateway.resolve("s1").unwrap().id(),
            p.gateway.resolve("s2").unwrap().id(),
            "remainder must not run after an epoch skew"
        );
        assert_eq!(p.metrics.counter("plan_aborted_stale"), 1);
        let events = p.metrics.plans();
        assert_eq!(events.len(), 1);
        assert!(
            events[0].detail.contains("epoch_skew_after_action_0"),
            "unexpected abort detail: {}",
            events[0].detail
        );
        assert!(!p.observer.pair_in_cooldown("s1", "s2"));
        p.shutdown();
    });
}

#[test]
fn plan_fuses_bypass_the_cooldowns_its_own_splits_set() {
    // Positive control for the executor: a plan that splits a group and
    // re-fuses its members in a different shape must run to completion —
    // the split's own pair cooldowns cannot veto the plan's fuses (they
    // still veto greedy fuses, which is the anti-flap contract).
    run_virtual(async {
        let mut cfg = PlatformConfig::tiny().with_compute(ComputeMode::Disabled);
        cfg.fusion.feedback_interval_ms = 0.0;
        let p = Platform::deploy(provuse::apps::chain(3), cfg.vanilla()).await.unwrap();
        let merger = manual_merger(&p);
        merger.handle_fuse("s0", "s1").await.unwrap();
        let plan = Plan {
            id: 3,
            epoch: p.observer.topology_epoch(),
            actions: vec![
                PlanAction::Split { functions: vec!["s0".into(), "s1".into()] },
                PlanAction::Fuse { caller: "s0".into(), callee: "s1".into() },
                PlanAction::Fuse { caller: "s1".into(), callee: "s2".into() },
            ],
            predicted_before: 1.0,
            predicted_after: 0.5,
            target: Vec::new(),
        };
        merger.execute_plan(plan).await;
        assert_eq!(p.metrics.counter("plans_executed"), 1, "plan must complete");
        assert_eq!(p.metrics.counter("plan_aborted_stale"), 0);
        assert_eq!(p.metrics.counter("plan_aborted_action"), 0);
        let s0 = p.gateway.resolve("s0").unwrap().id();
        assert_eq!(s0, p.gateway.resolve("s1").unwrap().id());
        assert_eq!(s0, p.gateway.resolve("s2").unwrap().id());
        p.shutdown();
    });
}

#[test]
fn windowed_signals_calibrate_against_ram_and_billing_ledgers() {
    // ISSUE 8 satellite: the snapshot the planner scores is built from
    // windowed telemetry — its priced working sets and billing rates must
    // agree with the platform's authoritative ledgers within tolerance,
    // or the search optimizes a fiction.
    run_virtual(async {
        let mut cfg = PlatformConfig::tiny().with_compute(ComputeMode::Disabled).with_seed(17);
        cfg.latency.image_build_ms = 400.0;
        cfg.latency.boot_ms = 200.0;
        cfg.fusion.feedback_interval_ms = 1_000.0;
        cfg.fusion.merge_policy = MergePolicyKind::CostModel;
        let p = Platform::deploy(provuse::apps::chain(3), cfg).await.unwrap();
        let wl = WorkloadConfig {
            requests: 400,
            rate_rps: 50.0,
            seed: 17,
            timeout_ms: 120_000.0,
        };
        let report = workload::run(Rc::clone(&p), wl).await.unwrap();
        assert_eq!(report.failed, 0);
        // one more controller tick lands after the workload drains
        provuse::exec::sleep_ms(3_000.0).await;

        let snap = p.observer.plan_snapshot();
        assert!(!snap.signals.is_empty(), "controller ticks must populate signals");
        for s in &snap.signals {
            assert!(s.window_s > 0.0, "{}: empty window", s.function.as_str());
            assert!(s.self_ms >= 0.0 && s.billed_ms >= s.self_ms - 1e-9,
                "{}: billed {} < self {}", s.function.as_str(), s.billed_ms, s.self_ms);
        }
        // RAM side: the priced working sets reproduce the container ledger
        let sig_ram: f64 = snap.signals.iter().map(|s| s.ram_mb).sum();
        let ledger = p.containers.total_ram_mb();
        assert!(
            (sig_ram - ledger).abs() / ledger < 0.25,
            "signal RAM {sig_ram:.1} disagrees with ledger {ledger:.1}"
        );
        // billing side: windowed GB-seconds are a trailing subset of the
        // authoritative bill, and a non-trivial one for a steady run
        let sig_gbs: f64 = snap.signals.iter().map(|s| s.gb_seconds).sum();
        let bill = p.billing.bill();
        assert!(sig_gbs > 0.0, "windowed billing signals must be live");
        assert!(
            sig_gbs <= bill.gb_seconds + 1e-6,
            "windowed {sig_gbs:.3} GB-s exceeds the total bill {:.3}",
            bill.gb_seconds
        );
        // and the objective the planner would score is well-defined
        let objective = plan::snapshot_objective(&snap, &p.config.fusion);
        assert!(objective.is_finite() && objective > 0.0);
        p.shutdown();
    });
}

#[test]
fn planner_greedy_is_bit_identical_to_the_default_platform() {
    // Pinned-seed golden (ISSUE 8 acceptance): `--planner greedy` — with
    // any re-plan cadence — must keep the full verdict transcript
    // bit-identical to an untouched default config.  The planner axis can
    // only ever change behavior under `--planner global`.
    fn transcript(tweak: fn(&mut PlatformConfig)) -> (Vec<String>, usize) {
        let mut cfg =
            PlatformConfig::tiny().with_compute(ComputeMode::Disabled).with_seed(11);
        cfg.latency.image_build_ms = 400.0;
        cfg.latency.boot_ms = 200.0;
        cfg.fusion.min_observations = 3;
        cfg.fusion.feedback_interval_ms = 1_000.0;
        cfg.fusion.merge_policy = MergePolicyKind::CostModel;
        tweak(&mut cfg);
        run_virtual(async move {
            let p = Platform::deploy(provuse::apps::chain(3), cfg).await.unwrap();
            let wl = WorkloadConfig {
                requests: 600,
                rate_rps: 100.0,
                seed: 11,
                timeout_ms: 120_000.0,
            };
            let report = workload::run(Rc::clone(&p), wl).await.unwrap();
            assert_eq!(report.failed, 0);
            provuse::exec::sleep_ms(10_000.0).await;
            p.shutdown();
            (
                provuse::experiments::fig9::verdict_transcript(&p.metrics),
                p.metrics.plans().len(),
            )
        })
    }
    let (base, base_plans) = transcript(|_| {});
    assert!(!base.is_empty(), "the pinned run must produce verdicts");
    assert_eq!(base_plans, 0, "the default platform must never emit plan events");
    let (explicit, explicit_plans) = transcript(|cfg| {
        cfg.fusion.planner = PlannerKind::Greedy;
        cfg.fusion.replan_interval_ticks = 3;
    });
    assert_eq!(base, explicit, "--planner greedy must be bit-identical to the default");
    assert_eq!(explicit_plans, 0);
}

#[test]
fn prop_merge_monotonically_reduces_instances() {
    // Each completed merge reduces distinct routed instances by >= 1 and
    // the instance count never increases at quiescence.
    check("instance count monotone", 12, |g| {
        let app = random_app(g);
        let cfg = fast_cfg(g, PlatformKind::Tiny);
        run_virtual(async move {
            let p = Platform::deploy(app, cfg).await.unwrap();
            let initial = p.gateway.distinct_instances();
            let wl = WorkloadConfig {
                requests: 60,
                rate_rps: 20.0,
                seed: 1,
                timeout_ms: 120_000.0,
            };
            workload::run(Rc::clone(&p), wl).await.unwrap();
            provuse::exec::sleep_ms(30_000.0).await;
            let merges = p.metrics.merges().len();
            let now = p.gateway.distinct_instances();
            assert_eq!(now, initial - merges, "each merge must remove exactly one instance");
            p.shutdown();
        });
    });
}
