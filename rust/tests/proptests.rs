//! Property-based tests over platform invariants (mini-harness in
//! `util::prop`; replay any failure with PROP_SEED=<seed>).

use std::rc::Rc;

use provuse::apps::{AppSpec, CallMode, CallSpec, FunctionSpec};
use provuse::config::{ComputeMode, PlatformConfig, PlatformKind, WorkloadConfig};
use provuse::exec::run_virtual;
use provuse::platform::Platform;
use provuse::util::prop::{check, Gen};
use provuse::workload::{self, request_payload};

/// Random DAG application: forward-only edges keep it acyclic by
/// construction; random sync/async modes and 1-2 trust domains.
fn random_app(g: &mut Gen) -> AppSpec {
    let n = g.usize(2, 7);
    let domains = ["alpha", "beta"];
    let n_domains = g.usize(1, 2);
    let mut functions = Vec::new();
    for i in 0..n {
        let mut calls = Vec::new();
        for j in (i + 1)..n {
            if g.f64(0.0, 1.0) < 0.45 {
                calls.push(CallSpec {
                    target: format!("f{j}"),
                    mode: if g.bool() { CallMode::Sync } else { CallMode::Async },
                    scale: g.f64(0.5, 1.5) as f32,
                });
            }
        }
        functions.push(FunctionSpec {
            name: format!("f{i}"),
            body: None,
            busy_ms: g.f64(5.0, 60.0),
            code_mb: g.f64(4.0, 24.0),
            code_kb: g.usize(16, 256) as u64,
            trust_domain: domains[g.usize(0, n_domains - 1)].into(),
            calls,
        });
    }
    AppSpec::new("prop", "f0", functions).expect("forward-edge DAG is always valid")
}

fn fast_cfg(g: &mut Gen, kind: PlatformKind) -> PlatformConfig {
    let mut cfg = PlatformConfig::of_kind(kind).with_compute(ComputeMode::Disabled);
    cfg.latency.image_build_ms = g.f64(50.0, 500.0);
    cfg.latency.boot_ms = g.f64(50.0, 300.0);
    cfg.fusion.min_observations = g.usize(1, 3) as u32;
    cfg.seed = g.rng().next_u64();
    cfg
}

#[test]
fn prop_fusion_never_changes_responses() {
    // For ANY app DAG and ANY platform flavor, enabling fusion must not
    // change a single response byte.
    check("fusion preserves responses", 20, |g| {
        let app = random_app(g);
        let kind = *g.choose(&[PlatformKind::Tiny, PlatformKind::Kube]);
        let cfg = fast_cfg(g, kind);
        let n_requests = g.usize(5, 15) as u64;
        let seed = g.rng().next_u64();

        let collect = |fusion: bool| {
            let app = app.clone();
            let mut cfg = cfg.clone();
            if !fusion {
                cfg = cfg.vanilla();
            }
            run_virtual(async move {
                let p = Platform::deploy(app, cfg).await.unwrap();
                let mut outs = Vec::new();
                for i in 0..n_requests {
                    let payload = request_payload(seed, i, p.payload_len());
                    outs.push(p.invoke(payload).await.unwrap());
                    provuse::exec::sleep_ms(150.0).await;
                }
                p.shutdown();
                outs
            })
        };
        assert_eq!(collect(false), collect(true));
    });
}

#[test]
fn prop_no_failures_and_partition_invariant() {
    // After any run: every function routes to exactly one live instance,
    // every instance's hosted set is internally consistent with the
    // routing table, and no requests were dropped.
    check("routing partition invariant", 16, |g| {
        let app = random_app(g);
        let kind = *g.choose(&[PlatformKind::Tiny, PlatformKind::Kube]);
        let cfg = fast_cfg(g, kind);
        let wl = WorkloadConfig {
            requests: g.usize(20, 80) as u64,
            rate_rps: g.f64(5.0, 50.0),
            seed: g.rng().next_u64(),
            timeout_ms: 120_000.0,
        };
        run_virtual(async move {
            let p = Platform::deploy(app, cfg).await.unwrap();
            let report = workload::run(Rc::clone(&p), wl).await.unwrap();
            assert_eq!(report.failed, 0, "dropped requests");
            provuse::exec::sleep_ms(25_000.0).await;

            let snapshot = p.gateway.snapshot();
            for (function, inst) in &snapshot {
                assert!(inst.state().is_live(), "{function} routed to dead instance");
                assert!(
                    inst.hosts(function),
                    "{function} routed to instance not hosting it"
                );
            }
            // trust domains never mix inside one instance
            for (_, inst) in &snapshot {
                let domains: std::collections::HashSet<&str> = inst
                    .functions()
                    .iter()
                    .map(|(f, _)| p.app.function(f).unwrap().trust_domain.as_str())
                    .collect();
                assert!(domains.len() <= 1, "trust domains mixed: {domains:?}");
            }
            // fused groups never exceed the theoretical sync components
            let components = p.app.sync_fusion_groups();
            for (_, inst) in &snapshot {
                if inst.functions().len() > 1 {
                    let hosted: std::collections::BTreeSet<&str> =
                        inst.functions().iter().map(|(f, _)| f.as_str()).collect();
                    let within_one_component = components.iter().any(|c| {
                        hosted.iter().all(|f| c.iter().any(|m| m == f))
                    });
                    assert!(within_one_component, "fused across sync components: {hosted:?}");
                }
            }
            p.shutdown();
        });
    });
}

#[test]
fn prop_ram_ledger_conservation() {
    // At quiescence the ledger equals base * instances + total code, no
    // matter what merge history happened.
    check("ram ledger conservation", 12, |g| {
        let app = random_app(g);
        let cfg = fast_cfg(g, PlatformKind::Tiny);
        let wl = WorkloadConfig {
            requests: g.usize(15, 50) as u64,
            rate_rps: 20.0,
            seed: g.rng().next_u64(),
            timeout_ms: 120_000.0,
        };
        run_virtual(async move {
            let p = Platform::deploy(app, cfg).await.unwrap();
            workload::run(Rc::clone(&p), wl).await.unwrap();
            provuse::exec::sleep_ms(30_000.0).await;

            let code_total: f64 = p.app.functions().map(|f| f.code_mb).sum();
            let expected = p.config.ram.base_instance_mb * p.containers.live_count() as f64
                + code_total;
            let actual = p.containers.total_ram_mb();
            assert!(
                (actual - expected).abs() < 1e-6,
                "ledger {actual} != {expected} ({} instances)",
                p.containers.live_count()
            );
        });
    });
}

#[test]
fn prop_merge_monotonically_reduces_instances() {
    // Each completed merge reduces distinct routed instances by >= 1 and
    // the instance count never increases at quiescence.
    check("instance count monotone", 12, |g| {
        let app = random_app(g);
        let cfg = fast_cfg(g, PlatformKind::Tiny);
        run_virtual(async move {
            let p = Platform::deploy(app, cfg).await.unwrap();
            let initial = p.gateway.distinct_instances();
            let wl = WorkloadConfig {
                requests: 60,
                rate_rps: 20.0,
                seed: 1,
                timeout_ms: 120_000.0,
            };
            workload::run(Rc::clone(&p), wl).await.unwrap();
            provuse::exec::sleep_ms(30_000.0).await;
            let merges = p.metrics.merges().len();
            let now = p.gateway.distinct_instances();
            assert_eq!(now, initial - merges, "each merge must remove exactly one instance");
            p.shutdown();
        });
    });
}
