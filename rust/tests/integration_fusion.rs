//! Integration: end-to-end fusion semantics on virtual time.
//!
//! These tests run the whole stack (gateway -> handler -> merger ->
//! containerd) with compute disabled so they are independent of
//! `make artifacts`; cross-layer numeric tests live in artifact_parity.rs.

use std::rc::Rc;

use provuse::apps::{self, AppSpec};
use provuse::config::{ComputeMode, PlatformConfig, PlatformKind, WorkloadConfig};
use provuse::exec::{self, run_virtual};
use provuse::platform::Platform;
use provuse::workload::{self, request_payload};

fn fast_merge(mut cfg: PlatformConfig) -> PlatformConfig {
    cfg.latency.image_build_ms = 300.0;
    cfg.latency.boot_ms = 150.0;
    cfg.fusion.min_observations = 1;
    cfg.compute = ComputeMode::Disabled;
    cfg
}

/// Collect the platform's responses for `n` seeded requests, serially.
async fn responses(platform: &Rc<Platform>, n: u64, gap_ms: f64) -> Vec<Vec<f32>> {
    let mut out = Vec::new();
    for i in 0..n {
        let payload = request_payload(99, i, platform.payload_len());
        out.push(platform.invoke(payload).await.expect("invoke failed"));
        exec::sleep_ms(gap_ms).await;
    }
    out
}

#[test]
fn responses_identical_vanilla_vs_fused_across_merge() {
    // THE correctness property of function fusion: consolidation must not
    // change observable behavior, including during the merge window.
    for app in [apps::tree(), apps::iot(), apps::chain(5)] {
        let vanilla: Vec<Vec<f32>> = run_virtual({
            let app = app.clone();
            async move {
                let p = Platform::deploy(app, fast_merge(PlatformConfig::tiny()).vanilla())
                    .await
                    .unwrap();
                let r = responses(&p, 40, 200.0).await;
                p.shutdown();
                r
            }
        });
        let fused: Vec<Vec<f32>> = run_virtual({
            let app = app.clone();
            async move {
                let p = Platform::deploy(app, fast_merge(PlatformConfig::tiny()))
                    .await
                    .unwrap();
                let r = responses(&p, 40, 200.0).await;
                assert!(!p.metrics.merges().is_empty(), "fusion never happened");
                p.shutdown();
                r
            }
        });
        assert_eq!(vanilla, fused, "app `{}` changed responses under fusion", app.name);
    }
}

#[test]
fn no_request_fails_during_merges() {
    run_virtual(async {
        let p = Platform::deploy(apps::iot(), fast_merge(PlatformConfig::tiny()))
            .await
            .unwrap();
        let wl = WorkloadConfig { requests: 500, rate_rps: 50.0, seed: 3, timeout_ms: 60_000.0 };
        let report = workload::run(Rc::clone(&p), wl).await.unwrap();
        assert_eq!(report.failed, 0);
        assert_eq!(report.ok, 500);
        assert!(p.metrics.merges().len() >= 5);
        p.shutdown();
    });
}

#[test]
fn convergence_matches_theoretical_fusion_groups() {
    for (app, kind) in [
        (apps::tree(), PlatformKind::Tiny),
        (apps::iot(), PlatformKind::Tiny),
        (apps::tree(), PlatformKind::Kube),
        (apps::iot(), PlatformKind::Kube),
    ] {
        run_virtual(async move {
            let groups = app.sync_fusion_groups();
            let cfg = fast_merge(PlatformConfig::of_kind(kind));
            let p = Platform::deploy(app, cfg).await.unwrap();
            let wl =
                WorkloadConfig { requests: 200, rate_rps: 20.0, seed: 8, timeout_ms: 60_000.0 };
            workload::run(Rc::clone(&p), wl).await.unwrap();
            exec::sleep_ms(30_000.0).await;

            // routing must realize exactly the sync-component partition
            let expected_instances = groups.len();
            assert_eq!(
                p.gateway.distinct_instances(),
                expected_instances,
                "{}/{}",
                p.app.name,
                kind.name()
            );
            for group in &groups {
                let first = p.gateway.resolve(&group[0]).unwrap();
                for f in group {
                    assert_eq!(
                        p.gateway.resolve(f).unwrap().id(),
                        first.id(),
                        "group member {f} not colocated"
                    );
                }
                let mut hosted: Vec<String> =
                    first.functions().iter().map(|(f, _)| f.clone()).collect();
                hosted.sort();
                assert_eq!(&hosted, group, "instance hosts wrong function set");
            }
            p.shutdown();
        });
    }
}

#[test]
fn originals_reclaimed_and_ram_drops_to_steady_state() {
    run_virtual(async {
        let p = Platform::deploy(apps::tree(), fast_merge(PlatformConfig::tiny()))
            .await
            .unwrap();
        let ram_before = p.containers.total_ram_mb();
        let wl = WorkloadConfig { requests: 150, rate_rps: 20.0, seed: 5, timeout_ms: 60_000.0 };
        workload::run(Rc::clone(&p), wl).await.unwrap();
        exec::sleep_ms(30_000.0).await; // drains settle

        // steady state: one instance per fusion group, zero in-flight
        let groups = p.app.sync_fusion_groups();
        assert_eq!(p.containers.live_count(), groups.len());
        let ram = &p.config.ram;
        let code_total: f64 = p.app.functions().map(|f| f.code_mb).sum();
        let expected = ram.base_instance_mb * groups.len() as f64 + code_total;
        let actual = p.containers.total_ram_mb();
        assert!(
            (actual - expected).abs() < 1e-6,
            "steady-state RAM {actual} != expected {expected}"
        );
        assert!(ram_before > actual);
        p.shutdown();
    });
}

#[test]
fn merge_events_are_ordered_and_fusion_reduces_post_merge_latency() {
    run_virtual(async {
        let p = Platform::deploy(apps::chain(4), fast_merge(PlatformConfig::tiny()))
            .await
            .unwrap();
        let wl = WorkloadConfig { requests: 400, rate_rps: 20.0, seed: 6, timeout_ms: 60_000.0 };
        workload::run(Rc::clone(&p), wl).await.unwrap();
        exec::sleep_ms(10_000.0).await;

        let merges = p.metrics.merges();
        assert!(merges.len() >= 3);
        // events strictly ordered in time, durations positive
        for w in merges.windows(2) {
            assert!(w[0].t_ms < w[1].t_ms);
        }
        for m in &merges {
            assert!(m.duration_ms > 0.0);
            assert!(m.functions.len() >= 2);
        }
        // paper Fig. 5 shape: post-merge median < pre-merge median
        let last = merges.last().unwrap().t_ms;
        let pre = p.metrics.latency_quantiles_window(0.0, merges[0].t_ms);
        let post = p.metrics.latency_quantiles_window(last, f64::INFINITY);
        assert!(
            post.median() < pre.median(),
            "post {} !< pre {}",
            post.median(),
            pre.median()
        );
        p.shutdown();
    });
}

#[test]
fn ram_cap_split_restores_per_function_routing_with_zero_drops() {
    // The full defusion loop on a live platform: converge to one fused
    // instance under calm load, then blow past the RAM cap under pressure;
    // the controller must split back to per-function instances without
    // dropping a single request.
    run_virtual(async {
        let mut cfg = fast_merge(PlatformConfig::tiny());
        cfg.fusion.max_group_ram_mb = 100.0; // chain(3) idle fused = 94 MiB
        cfg.fusion.feedback_interval_ms = 1_000.0;
        cfg.fusion.split_hysteresis_windows = 2;
        cfg.fusion.cooldown_ms = 60_000.0; // no re-fusion inside this test
        let p = Platform::deploy(apps::chain(3), cfg).await.unwrap();

        // calm phase: fuse
        let wl = WorkloadConfig { requests: 30, rate_rps: 10.0, seed: 21, timeout_ms: 60_000.0 };
        let calm = workload::run(Rc::clone(&p), wl).await.unwrap();
        assert_eq!(calm.failed, 0);
        exec::sleep_ms(5_000.0).await;
        assert_eq!(p.gateway.distinct_instances(), 1, "chain must fuse first");
        assert!(p.metrics.splits().is_empty());

        // pressure phase: in-flight working sets push the group over the cap
        let wl =
            WorkloadConfig { requests: 1_200, rate_rps: 60.0, seed: 22, timeout_ms: 60_000.0 };
        let pressure = workload::run(Rc::clone(&p), wl).await.unwrap();
        assert_eq!(pressure.failed, 0, "requests must survive the split cutover");
        exec::sleep_ms(10_000.0).await;

        let splits = p.metrics.splits();
        assert_eq!(splits.len(), 1, "exactly one corrective split: {splits:?}");
        assert_eq!(splits[0].reason, provuse::fusion::SplitReason::RamCap);
        assert_eq!(splits[0].functions, vec!["s0".to_string(), "s1".into(), "s2".into()]);

        // routing is back to one instance per function, fused original gone
        assert_eq!(p.gateway.distinct_instances(), 3);
        assert_eq!(p.containers.live_count(), 3);
        for f in ["s0", "s1", "s2"] {
            let inst = p.gateway.resolve(f).unwrap();
            assert_eq!(inst.functions().len(), 1, "`{f}` must be alone again");
            assert!(inst.hosts(f));
        }
        // 2 merges reclaimed 2 originals each; the split reclaimed the
        // fused instance
        assert_eq!(p.metrics.merges().len(), 2);
        assert_eq!(p.metrics.counter("instances_reclaimed"), 5);
        // cooldown holds: no re-fusion happened inside this test window
        assert!(p
            .metrics
            .merges()
            .iter()
            .all(|m| m.t_ms < splits[0].t_ms));
        p.shutdown();
    });
}

#[test]
fn defusion_disabled_keeps_group_fused_under_pressure() {
    run_virtual(async {
        let mut cfg = fast_merge(PlatformConfig::tiny());
        cfg.fusion.max_group_ram_mb = 100.0;
        cfg.fusion.feedback_interval_ms = 1_000.0;
        cfg.fusion.split_hysteresis_windows = 2;
        cfg.fusion.defusion = false; // fuse-once, like the original paper
        let p = Platform::deploy(apps::chain(3), cfg).await.unwrap();
        let wl = WorkloadConfig { requests: 30, rate_rps: 10.0, seed: 23, timeout_ms: 60_000.0 };
        workload::run(Rc::clone(&p), wl).await.unwrap();
        exec::sleep_ms(5_000.0).await;
        assert_eq!(p.gateway.distinct_instances(), 1);
        let wl =
            WorkloadConfig { requests: 600, rate_rps: 60.0, seed: 24, timeout_ms: 60_000.0 };
        workload::run(Rc::clone(&p), wl).await.unwrap();
        exec::sleep_ms(5_000.0).await;
        assert!(p.metrics.splits().is_empty(), "defusion=false must never split");
        assert_eq!(p.gateway.distinct_instances(), 1);
        p.shutdown();
    });
}

#[test]
fn responses_identical_across_split_cutover() {
    // Defusion is behavior-preserving, same as fusion: responses across the
    // fuse -> split -> serve sequence must equal a vanilla deployment's.
    let vanilla: Vec<Vec<f32>> = run_virtual(async {
        let p = Platform::deploy(apps::chain(2), fast_merge(PlatformConfig::tiny()).vanilla())
            .await
            .unwrap();
        let r = responses(&p, 30, 400.0).await;
        p.shutdown();
        r
    });
    let cycled: Vec<Vec<f32>> = run_virtual(async {
        let mut cfg = fast_merge(PlatformConfig::tiny());
        // chain(2) idle fused RAM is 82 MiB: an 80 MiB cap guarantees a
        // deterministic split shortly after fusion, traffic or not
        cfg.fusion.max_group_ram_mb = 80.0;
        cfg.fusion.feedback_interval_ms = 2_000.0;
        cfg.fusion.split_hysteresis_windows = 2;
        cfg.fusion.cooldown_ms = 60_000.0;
        let p = Platform::deploy(apps::chain(2), cfg).await.unwrap();
        let r = responses(&p, 30, 400.0).await; // spans fuse AND split
        assert!(!p.metrics.merges().is_empty(), "fusion never happened");
        assert!(!p.metrics.splits().is_empty(), "split never happened");
        p.shutdown();
        r
    });
    assert_eq!(vanilla, cycled, "split cutover changed responses");
}

#[test]
fn cost_model_controller_evicts_then_splits_under_tiny_threshold() {
    // End-to-end cost-policy loop on a live platform, no hand-driving: a
    // tiny evict threshold keeps every fused chain(3) group over budget, so
    // the controller first evicts the heaviest member from the full group
    // (all-equal attribution ties break lexicographically -> s0), then the
    // surviving pair is over budget too and — being a pair — is split
    // whole.  Long cooldowns + no further traffic keep the end state
    // fully defused.
    run_virtual(async {
        let mut cfg = fast_merge(PlatformConfig::tiny());
        cfg.fusion.split_policy = provuse::config::SplitPolicyKind::CostModel;
        cfg.fusion.cost.evict_threshold = 0.1; // any fused group violates
        cfg.fusion.feedback_interval_ms = 1_000.0;
        cfg.fusion.split_hysteresis_windows = 5; // let fusion converge first
        cfg.fusion.cooldown_ms = 120_000.0;
        let p = Platform::deploy(apps::chain(3), cfg).await.unwrap();

        let wl = WorkloadConfig { requests: 20, rate_rps: 10.0, seed: 51, timeout_ms: 60_000.0 };
        let report = workload::run(Rc::clone(&p), wl).await.unwrap();
        assert_eq!(report.failed, 0);
        exec::sleep_ms(35_000.0).await;

        let evicts = p.metrics.evicts();
        assert_eq!(evicts.len(), 1, "exactly one eviction: {evicts:?}");
        assert_eq!(evicts[0].function, "s0", "deterministic heaviest pick");
        assert_eq!(
            evicts[0].group,
            vec!["s0".to_string(), "s1".into(), "s2".into()]
        );
        assert_eq!(evicts[0].reason, provuse::fusion::SplitReason::CostModel);
        let splits = p.metrics.splits();
        assert_eq!(splits.len(), 1, "the surviving pair splits whole: {splits:?}");
        assert_eq!(splits[0].functions, vec!["s1".to_string(), "s2".into()]);
        assert_eq!(splits[0].reason, provuse::fusion::SplitReason::CostModel);
        assert!(splits[0].t_ms > evicts[0].t_ms);

        // fully defused end state, all invariants intact
        assert_eq!(p.gateway.distinct_instances(), 3);
        assert_eq!(p.containers.live_count(), 3);
        provuse::platform::routing_invariants(&p).unwrap();
        p.shutdown();
    });
}

#[test]
fn async_only_app_sees_no_latency_benefit() {
    // paper §6: "fully asynchronous workloads may see limited to no benefit"
    let app = AppSpec::builder("async_only")
        .function("a").entry().busy_ms(50.0).async_call("b").done()
        .function("b").busy_ms(80.0).async_call("c").done()
        .function("c").busy_ms(60.0).done()
        .build()
        .unwrap();
    let run = |fusion: bool| {
        let app = app.clone();
        run_virtual(async move {
            let mut cfg = fast_merge(PlatformConfig::tiny());
            if !fusion {
                cfg = cfg.vanilla();
            }
            let p = Platform::deploy(app, cfg).await.unwrap();
            let wl =
                WorkloadConfig { requests: 100, rate_rps: 20.0, seed: 2, timeout_ms: 60_000.0 };
            let report = workload::run(Rc::clone(&p), wl).await.unwrap();
            let merges = p.metrics.merges().len();
            p.shutdown();
            (report.latency.median(), merges)
        })
    };
    let (vanilla_ms, _) = run(false);
    let (fused_ms, merges) = run(true);
    assert_eq!(merges, 0, "async edges must never trigger fusion");
    assert!((vanilla_ms - fused_ms).abs() / vanilla_ms < 0.02);
}

#[test]
fn kube_deploys_slower_but_converges_the_same() {
    let converge = |kind: PlatformKind| {
        run_virtual(async move {
            let p = Platform::deploy(apps::chain(3), fast_merge(PlatformConfig::of_kind(kind)))
                .await
                .unwrap();
            let wl =
                WorkloadConfig { requests: 60, rate_rps: 10.0, seed: 4, timeout_ms: 60_000.0 };
            workload::run(Rc::clone(&p), wl).await.unwrap();
            exec::sleep_ms(30_000.0).await;
            let last_merge =
                p.metrics.merges().iter().map(|m| m.t_ms).fold(0.0f64, f64::max);
            let n = p.gateway.distinct_instances();
            p.shutdown();
            (last_merge, n)
        })
    };
    let (tiny_t, tiny_n) = converge(PlatformKind::Tiny);
    let (kube_t, kube_n) = converge(PlatformKind::Kube);
    assert_eq!(tiny_n, 1);
    assert_eq!(kube_n, 1);
    // reconciler gating + slower boots: kube merges land later
    assert!(kube_t > tiny_t, "kube {kube_t} !> tiny {tiny_t}");
}
