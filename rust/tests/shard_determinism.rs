//! ISSUE 7 golden test: sharding the simulation core by cluster node must
//! not change the schedule — only how fast it is produced.  A pinned-seed
//! FIG9-style scenario (chain app, cost-model admission, 3-node cluster,
//! windowed recording) runs under 1 lane and under 3 lanes, and the full
//! verdict transcript (admission scores, merges, splits, evicts — f64s
//! compared bit-for-bit), every node's final RAM ledger, and the
//! discrete-event epoch count must be **identical** across shard counts.
//!
//! Also proves nested executors stay isolated while an outer sharded run
//! is in flight: a task pinned to a non-zero lane can spin up its own
//! inner (sharded) executor without perturbing the outer lane assignment.

use std::rc::Rc;

use provuse::apps;
use provuse::config::{ComputeMode, MergePolicyKind, PlatformConfig, WorkloadConfig};
use provuse::exec::{self, Executor, Mode};
use provuse::metrics::RecordingLevel;
use provuse::platform::Platform;
use provuse::workload;

const SEED: u64 = 23;
const NODES: usize = 3;

fn scenario_config() -> PlatformConfig {
    let mut cfg = PlatformConfig::tiny()
        .with_compute(ComputeMode::Disabled)
        .with_seed(SEED)
        .with_recording(RecordingLevel::Windowed);
    cfg.latency.image_build_ms = 300.0;
    cfg.latency.boot_ms = 150.0;
    cfg.fusion.min_observations = 3;
    cfg.fusion.feedback_interval_ms = 1_000.0;
    cfg.fusion.merge_policy = MergePolicyKind::CostModel;
    cfg.cluster.nodes = NODES;
    cfg
}

struct Outcome {
    /// canonical verdict transcript, f64s rendered bit-exactly
    verdicts: Vec<String>,
    /// per-node final RAM ledger as (node id, ram_mb bit pattern)
    node_ram: Vec<(u64, u64)>,
    /// virtual-clock advances the run consumed
    epochs: u64,
    failures: u64,
    merges: usize,
}

fn run_scenario(shards: usize) -> Outcome {
    Executor::sharded(Mode::Virtual, shards).block_on(async move {
        let p = Platform::deploy(apps::chain(3), scenario_config()).await.unwrap();
        let wl = WorkloadConfig {
            requests: 900,
            rate_rps: 150.0,
            seed: SEED,
            timeout_ms: 60_000.0,
        };
        let report = workload::run(Rc::clone(&p), wl).await.unwrap();
        exec::sleep_ms(15_000.0).await;
        p.shutdown();
        let m = &p.metrics;
        Outcome {
            verdicts: provuse::experiments::fig9::verdict_transcript(m),
            node_ram: p
                .node_ram_ledger()
                .into_iter()
                .map(|(id, mb)| (id, mb.to_bits()))
                .collect(),
            epochs: exec::epochs(),
            failures: report.failed,
            merges: m.merges().len(),
        }
    })
}

#[test]
fn schedule_identical_across_shard_counts() {
    let single = run_scenario(1);
    let sharded = run_scenario(3);

    assert_eq!(single.failures, 0, "1-shard run dropped requests");
    assert_eq!(sharded.failures, 0, "3-shard run dropped requests");
    // the scenario is non-trivial: fusion actually happened and verdicts
    // were recorded, so the transcripts below compare real decisions
    assert!(single.merges > 0, "scenario produced no merges");
    assert!(
        single.verdicts.iter().any(|v| v.starts_with("admission")),
        "no admission evaluations recorded"
    );
    assert_eq!(single.node_ram.len(), NODES);

    // the golden assertions: lane count changes NOTHING observable
    assert_eq!(single.verdicts, sharded.verdicts, "fusion verdicts diverged");
    assert_eq!(single.node_ram, sharded.node_ram, "node RAM ledgers diverged");
    assert_eq!(single.epochs, sharded.epochs, "epoch counts diverged");
}

#[test]
fn nested_executor_stays_isolated_under_shards() {
    let (outer_lane_before, inner_result, outer_lane_after, outer_shards) =
        Executor::sharded(Mode::Virtual, 3).block_on(async {
            exec::spawn_on(2, async {
                let before = exec::current_shard();
                // an inner executor on the same thread: its lanes, timers,
                // and CURRENT_SHARD bookkeeping must not leak into ours
                let inner = Executor::sharded(Mode::Virtual, 2).block_on(async {
                    let h = exec::spawn_on(1, async {
                        exec::sleep_ms(5.0).await;
                        exec::current_shard()
                    });
                    h.await
                });
                (before, inner, exec::current_shard(), exec::shard_count())
            })
            .await
        });

    assert_eq!(outer_lane_before, 2, "task not pinned to requested lane");
    assert_eq!(inner_result, 1, "inner executor ignored its own pinning");
    assert_eq!(outer_lane_after, 2, "inner executor leaked lane state");
    assert_eq!(outer_shards, 3, "inner executor leaked its lane count");
}
