//! ISSUE 7 golden test: sharding the simulation core by cluster node must
//! not change the schedule — only how fast it is produced.  A pinned-seed
//! FIG9-style scenario (chain app, cost-model admission, 3-node cluster,
//! windowed recording) runs under 1 lane and under 3 lanes, and the full
//! verdict transcript (admission scores, merges, splits, evicts — f64s
//! compared bit-for-bit), every node's final RAM ledger, and the
//! discrete-event epoch count must be **identical** across shard counts.
//!
//! Also proves nested executors stay isolated while an outer sharded run
//! is in flight: a task pinned to a non-zero lane can spin up its own
//! inner (sharded) executor without perturbing the outer lane assignment.
//!
//! ISSUE 10 extends the golden to **real worker threads**: a pinned-seed
//! tenant fleet driven by 1 vs 3 vs 7 OS workers under the epoch-window
//! protocol must produce bit-identical per-tenant verdict transcripts,
//! RAM ledgers, and epoch counts — thread interleaving (including the
//! shared interner and any other process-global state) must never leak
//! into a lane's schedule — and repeated runs of the same fleet in the
//! same binary must be byte-stable.

use std::future::Future;
use std::pin::Pin;
use std::rc::Rc;

use provuse::apps;
use provuse::config::{ComputeMode, MergePolicyKind, PlatformConfig, WorkloadConfig};
use provuse::exec::{self, Executor, Mode};
use provuse::metrics::RecordingLevel;
use provuse::platform::Platform;
use provuse::workload;

const SEED: u64 = 23;
const NODES: usize = 3;

fn scenario_config() -> PlatformConfig {
    let mut cfg = PlatformConfig::tiny()
        .with_compute(ComputeMode::Disabled)
        .with_seed(SEED)
        .with_recording(RecordingLevel::Windowed);
    cfg.latency.image_build_ms = 300.0;
    cfg.latency.boot_ms = 150.0;
    cfg.fusion.min_observations = 3;
    cfg.fusion.feedback_interval_ms = 1_000.0;
    cfg.fusion.merge_policy = MergePolicyKind::CostModel;
    cfg.cluster.nodes = NODES;
    cfg
}

struct Outcome {
    /// canonical verdict transcript, f64s rendered bit-exactly
    verdicts: Vec<String>,
    /// per-node final RAM ledger as (node id, ram_mb bit pattern)
    node_ram: Vec<(u64, u64)>,
    /// virtual-clock advances the run consumed
    epochs: u64,
    failures: u64,
    merges: usize,
}

fn run_scenario(shards: usize) -> Outcome {
    Executor::sharded(Mode::Virtual, shards).block_on(async move {
        let p = Platform::deploy(apps::chain(3), scenario_config()).await.unwrap();
        let wl = WorkloadConfig {
            requests: 900,
            rate_rps: 150.0,
            seed: SEED,
            timeout_ms: 60_000.0,
        };
        let report = workload::run(Rc::clone(&p), wl).await.unwrap();
        exec::sleep_ms(15_000.0).await;
        p.shutdown();
        let m = &p.metrics;
        Outcome {
            verdicts: provuse::experiments::fig9::verdict_transcript(m),
            node_ram: p
                .node_ram_ledger()
                .into_iter()
                .map(|(id, mb)| (id, mb.to_bits()))
                .collect(),
            epochs: exec::epochs(),
            failures: report.failed,
            merges: m.merges().len(),
        }
    })
}

#[test]
fn schedule_identical_across_shard_counts() {
    let single = run_scenario(1);
    let sharded = run_scenario(3);

    assert_eq!(single.failures, 0, "1-shard run dropped requests");
    assert_eq!(sharded.failures, 0, "3-shard run dropped requests");
    // the scenario is non-trivial: fusion actually happened and verdicts
    // were recorded, so the transcripts below compare real decisions
    assert!(single.merges > 0, "scenario produced no merges");
    assert!(
        single.verdicts.iter().any(|v| v.starts_with("admission")),
        "no admission evaluations recorded"
    );
    assert_eq!(single.node_ram.len(), NODES);

    // the golden assertions: lane count changes NOTHING observable
    assert_eq!(single.verdicts, sharded.verdicts, "fusion verdicts diverged");
    assert_eq!(single.node_ram, sharded.node_ram, "node RAM ledgers diverged");
    assert_eq!(single.epochs, sharded.epochs, "epoch counts diverged");
}

/// Lanes in the threaded fleet golden — 7 so the widest worker count
/// below drives one tenant per thread while 3 gets an uneven 3/2/2 split.
const TENANTS: usize = 7;

/// One tenant lane of the fleet golden: the ISSUE 7 scenario scaled to a
/// single-node slice under a tenant-derived seed.  Returns a `Send`
/// constructor for `exec::threads::run_fleet`.
fn tenant_job(tenant: usize) -> impl FnOnce() -> Pin<Box<dyn Future<Output = Outcome>>> + Send {
    move || {
        Box::pin(async move {
            let mut cfg = scenario_config();
            cfg.seed = SEED ^ 0x9E3779B97F4A7C15u64.wrapping_mul(tenant as u64 + 1);
            cfg.cluster.nodes = 1;
            let seed = cfg.seed;
            let p = Platform::deploy(apps::chain(3), cfg).await.unwrap();
            let wl = WorkloadConfig {
                requests: 240,
                rate_rps: 60.0,
                seed,
                timeout_ms: 60_000.0,
            };
            let report = workload::run(Rc::clone(&p), wl).await.unwrap();
            exec::sleep_ms(15_000.0).await;
            p.shutdown();
            let m = &p.metrics;
            Outcome {
                verdicts: provuse::experiments::fig9::verdict_transcript(m),
                node_ram: p
                    .node_ram_ledger()
                    .into_iter()
                    .map(|(id, mb)| (id, mb.to_bits()))
                    .collect(),
                epochs: exec::epochs(),
                failures: report.failed,
                merges: m.merges().len(),
            }
        })
    }
}

/// Drive the `TENANTS`-lane fleet on `workers` OS threads (tenant `t`
/// rides worker `t % workers`) and return the outcomes in tenant order.
fn run_fleet_golden(workers: usize) -> Vec<Outcome> {
    let mut jobs: Vec<Vec<_>> = (0..workers).map(|_| Vec::new()).collect();
    for t in 0..TENANTS {
        jobs[t % workers].push(tenant_job(t));
    }
    // paced virtual window: the tenants are independent (unbounded
    // lookahead license), but a finite window keeps the gate in play
    let fleet = exec::threads::run_fleet(250_000_000, jobs).expect("fleet must complete");
    let mut by_tenant: Vec<(usize, Outcome)> = Vec::new();
    for (w, lane) in fleet.results.into_iter().enumerate() {
        for (j, outcome) in lane.into_iter().enumerate() {
            by_tenant.push((w + j * workers, outcome));
        }
    }
    by_tenant.sort_by_key(|(t, _)| *t);
    assert_eq!(by_tenant.len(), TENANTS);
    by_tenant.into_iter().map(|(_, o)| o).collect()
}

#[test]
fn threaded_fleet_schedule_identical_across_worker_counts() {
    let w1 = run_fleet_golden(1);
    let w3 = run_fleet_golden(3);
    let w7 = run_fleet_golden(7);

    assert!(w1.iter().any(|o| o.merges > 0), "no tenant fused — golden is trivial");
    for t in 0..TENANTS {
        assert_eq!(w1[t].failures, 0, "tenant {t} dropped requests");
        assert!(!w1[t].verdicts.is_empty(), "tenant {t} recorded no verdicts");
        // the golden assertions: worker count changes NOTHING observable
        assert_eq!(w1[t].verdicts, w3[t].verdicts, "tenant {t} verdicts diverged at 3 workers");
        assert_eq!(w1[t].verdicts, w7[t].verdicts, "tenant {t} verdicts diverged at 7 workers");
        assert_eq!(w1[t].node_ram, w3[t].node_ram, "tenant {t} RAM ledger diverged at 3 workers");
        assert_eq!(w1[t].node_ram, w7[t].node_ram, "tenant {t} RAM ledger diverged at 7 workers");
        assert_eq!(w1[t].epochs, w3[t].epochs, "tenant {t} epochs diverged at 3 workers");
        assert_eq!(w1[t].epochs, w7[t].epochs, "tenant {t} epochs diverged at 7 workers");
    }
}

#[test]
fn threaded_fleet_is_stable_across_repeated_runs() {
    // same binary, same process, 5 runs: transcripts must be byte-stable
    let first = run_fleet_golden(3);
    for run in 1..5 {
        let again = run_fleet_golden(3);
        for t in 0..TENANTS {
            assert_eq!(first[t].verdicts, again[t].verdicts, "run {run}, tenant {t}: verdicts");
            assert_eq!(first[t].node_ram, again[t].node_ram, "run {run}, tenant {t}: RAM");
            assert_eq!(first[t].epochs, again[t].epochs, "run {run}, tenant {t}: epochs");
        }
    }
}

#[test]
fn worker_panic_surfaces_as_shard_panicked_error() {
    // shard 2 of 3 detonates mid-window; the gate poison must convert
    // into the crate error instead of hanging the barrier
    let jobs: Vec<Vec<exec::threads::LaneJob<u32>>> = (0..3usize)
        .map(|w| {
            vec![Box::new(move || -> Pin<Box<dyn Future<Output = u32>>> {
                Box::pin(async move {
                    exec::sleep_ms(5.0).await;
                    if w == 2 {
                        panic!("tenant meltdown");
                    }
                    exec::sleep_ms(50.0).await;
                    w as u32
                })
            }) as exec::threads::LaneJob<u32>]
        })
        .collect();
    let poison = exec::threads::run_fleet(1_000_000, jobs).unwrap_err();
    let err: provuse::Error = poison.into();
    assert!(
        matches!(err, provuse::Error::ShardPanicked { shard: 2, .. }),
        "unexpected error: {err}"
    );
    assert!(err.to_string().contains("tenant meltdown"), "{err}");
}

#[test]
fn nested_executor_stays_isolated_under_shards() {
    let (outer_lane_before, inner_result, outer_lane_after, outer_shards) =
        Executor::sharded(Mode::Virtual, 3).block_on(async {
            exec::spawn_on(2, async {
                let before = exec::current_shard();
                // an inner executor on the same thread: its lanes, timers,
                // and CURRENT_SHARD bookkeeping must not leak into ours
                let inner = Executor::sharded(Mode::Virtual, 2).block_on(async {
                    let h = exec::spawn_on(1, async {
                        exec::sleep_ms(5.0).await;
                        exec::current_shard()
                    });
                    h.await
                });
                (before, inner, exec::current_shard(), exec::shard_count())
            })
            .await
        });

    assert_eq!(outer_lane_before, 2, "task not pinned to requested lane");
    assert_eq!(inner_result, 1, "inner executor ignored its own pinning");
    assert_eq!(outer_lane_after, 2, "inner executor leaked lane state");
    assert_eq!(outer_shards, 3, "inner executor leaked its lane count");
}
