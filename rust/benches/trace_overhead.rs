//! `cargo bench --bench trace_overhead` — the tracer's allocation
//! contract (ISSUE 9 tentpole acceptance):
//!
//! 1. with sampling **off** the tracer adds exactly **zero** heap
//!    allocations to the request path — every call is one `Option` check;
//! 2. with sampling **on** the end-to-end per-request allocation delta is
//!    O(spans): pooled span buffers are recycled through a freelist, so
//!    steady-state cost is the retained-trace copy and nothing else.
//!
//! A counting `#[global_allocator]` must own the whole binary, which is
//! why these assertions live in a bench target rather than a lib test
//! (same split as `benches/hotpath.rs`); CI runs it in the smoke job.

use std::alloc::{GlobalAlloc, Layout, System};
use std::rc::Rc;
use std::sync::atomic::{AtomicU64, Ordering};

use provuse::apps;
use provuse::config::{ComputeMode, PlatformConfig, WorkloadConfig};
use provuse::exec::{run_virtual, Executor, Mode};
use provuse::platform::Platform;
use provuse::trace::{SpanKind, Tracer};
use provuse::util::intern::Sym;
use provuse::workload;

/// Counting allocator: lets the bench assert a code path never touches
/// the heap (the same idiom as `benches/hotpath.rs`).
struct CountingAlloc;

static ALLOCATIONS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// One full chain(3) workload run; returns the allocation count it cost.
fn e2e_allocs(sample_every: u64, requests: u64) -> u64 {
    let before = ALLOCATIONS.load(Ordering::Relaxed);
    Executor::new(Mode::Virtual).block_on(async move {
        let mut cfg =
            PlatformConfig::tiny().with_compute(ComputeMode::Disabled).with_seed(5);
        cfg.latency.image_build_ms = 200.0;
        cfg.latency.boot_ms = 100.0;
        cfg.fusion.min_observations = 1;
        cfg.trace.sample_every = sample_every;
        cfg.trace.max_traces = 512;
        let p = Platform::deploy(apps::chain(3), cfg).await.unwrap();
        let wl = WorkloadConfig {
            requests,
            rate_rps: 50.0,
            seed: 5,
            timeout_ms: 60_000.0,
        };
        let r = workload::run(Rc::clone(&p), wl).await.unwrap();
        assert_eq!(r.failed, 0);
        if sample_every == 1 {
            assert_eq!(p.tracer.conservation_violations(), 0);
            assert_eq!(p.tracer.retained_total(), requests);
        }
        p.shutdown();
    });
    ALLOCATIONS.load(Ordering::Relaxed) - before
}

fn main() {
    println!("== trace overhead (allocation contract) ==");

    // 1. the disabled tracer is allocation-free across its whole API —
    //    the exact call sequence the dispatcher makes per request
    {
        let tracer = Tracer::disabled();
        let f = Sym::intern("bench_fn");
        run_virtual(async move {
            let before = ALLOCATIONS.load(Ordering::Relaxed);
            for i in 0..10_000u64 {
                let ctx = tracer.begin_request(f, i as f64);
                let frame = tracer.open_frame(ctx, SpanKind::Invoke, f, true);
                let seg = tracer.start_seg(frame, SpanKind::ColdWait, f);
                tracer.end_seg(seg);
                let t = provuse::exec::now();
                tracer.add_parts(frame, t, t, f, &[(SpanKind::Dispatch, 0.0)]);
                tracer.close_frame(frame);
                tracer.finish_ok(ctx, 0.0);
            }
            let allocs = ALLOCATIONS.load(Ordering::Relaxed) - before;
            println!("disabled tracer allocations over 10k request cycles: {allocs}");
            assert_eq!(allocs, 0, "the disabled tracer must never touch the heap");
        });
    }

    // 2. end-to-end: sampling off adds nothing (bit-identical schedule),
    //    sampling every request costs O(spans) — pooled buffers recycle,
    //    so the steady-state delta is the retained-trace copy only
    {
        const REQUESTS: u64 = 200;
        // throwaway warmup run: interning tables, thread-locals, and other
        // one-time global growth land here, not in the measurement
        let _ = e2e_allocs(0, REQUESTS);
        let untraced = e2e_allocs(0, REQUESTS);
        let traced = e2e_allocs(1, REQUESTS);
        let delta = traced as i64 - untraced as i64;
        let per_request = delta as f64 / REQUESTS as f64;
        println!(
            "e2e allocations: untraced {untraced}, traced(1-in-1) {traced}, \
             delta {delta} ({per_request:.1}/request)"
        );
        assert!(delta > 0, "tracing every request must retain traces (and pay for them)");
        // generous O(spans) ceiling: a chain(3) trace is a few dozen spans;
        // anything near this bound means per-span buffers stopped recycling
        assert!(
            per_request <= 1_024.0,
            "traced per-request allocation delta {per_request:.1} exceeds the \
             O(spans) bound — is the span pool recycling?"
        );
    }

    println!("\ntrace_overhead bench complete");
}
