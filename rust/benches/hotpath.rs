//! `cargo bench --bench hotpath` — microbenchmarks of the request hot path
//! and the merge pipeline's CPU work (DESIGN.md §Perf: the gateway+handler
//! CPU overhead must be microseconds so the *modeled* hop costs dominate,
//! as in the paper's testbed).
//!
//! ISSUE 5 additions: a counting global allocator proves `gateway::resolve`
//! performs **zero heap allocations per call**, and the controller-tick
//! signal computation (`fn_p95_window` + `fn_self_ms_window` for every
//! routed function) is benchmarked against a faithful replica of the
//! pre-refactor path (scan + filter + collect + sort over the whole
//! interleaved history) with a hard `>= 5x` speedup assertion.

use std::alloc::{GlobalAlloc, Layout, System};
use std::rc::Rc;
use std::sync::atomic::{AtomicU64, Ordering};

use provuse::apps;
use provuse::config::{ComputeMode, PlatformConfig, WorkloadConfig};
use provuse::containerd::{ContainerRuntime, FsManifest};
use provuse::exec::{run_virtual, Executor, Mode};
use provuse::gateway::Gateway;
use provuse::merger::fsunion;
use provuse::metrics::{Recorder, MIN_WINDOW_SAMPLES};
use provuse::platform::Platform;
use provuse::runtime::ArtifactSet;
use provuse::util::bench::bench;
use provuse::util::intern::Sym;
use provuse::util::json::Json;
use provuse::util::rng::Rng;
use provuse::util::stats::Quantiles;
use provuse::workload::{self, request_payload};

/// Counting allocator: lets the bench assert a code path never touches the
/// heap (the ISSUE 5 `gateway::resolve` acceptance criterion).
struct CountingAlloc;

static ALLOCATIONS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// The seed tree's `FnSample` shape + window math, replicated verbatim so
/// the before/after comparison stays honest as the real code evolves.
struct LegacyFnSample {
    t_ms: f64,
    function: String,
    handler_ms: f64,
}

fn legacy_fn_p95_window(
    series: &[LegacyFnSample],
    function: &str,
    from_ms: f64,
    to_ms: f64,
    min_n: usize,
) -> f64 {
    let start = series.partition_point(|s| s.t_ms < from_ms);
    let q = Quantiles::from_samples(
        series[start..]
            .iter()
            .take_while(|s| s.t_ms < to_ms)
            .filter(|s| s.function == function)
            .map(|s| s.handler_ms)
            .collect(),
    );
    if q.len() >= min_n { q.p95() } else { f64::NAN }
}

fn legacy_fn_self_ms_window(
    series: &[LegacyFnSample],
    function: &str,
    from_ms: f64,
    to_ms: f64,
) -> f64 {
    let start = series.partition_point(|s| s.t_ms < from_ms);
    series[start..]
        .iter()
        .take_while(|s| s.t_ms < to_ms)
        .filter(|s| s.function == function)
        .map(|s| s.handler_ms)
        .sum()
}

fn main() {
    println!("== L3 hot-path microbenches ==");

    // gateway resolve + swap
    {
        let cfg = Rc::new(PlatformConfig::tiny());
        let rt = ContainerRuntime::new(cfg);
        let img = rt.register_image(FsManifest::function_code("f", 64), vec![("f".into(), 9.0)]);
        let (inst_a, inst_b) = run_virtual({
            let rt = rt.clone();
            async move { (rt.launch(img).unwrap(), rt.launch(img).unwrap()) }
        });
        let gw = Gateway::new();
        for i in 0..64 {
            gw.set_route(format!("fn{i}"), Rc::clone(&inst_a));
        }
        bench("gateway::resolve (64 routes)", 1_000, 100_000, || {
            gw.resolve("fn42").unwrap()
        });
        let hot = Sym::intern("fn42");
        bench("gateway::resolve_sym (64 routes)", 1_000, 100_000, || {
            gw.resolve_sym(hot).unwrap()
        });
        // ISSUE 5 acceptance: zero heap allocations per resolve call
        let before = ALLOCATIONS.load(Ordering::Relaxed);
        for _ in 0..10_000 {
            std::hint::black_box(gw.resolve("fn42").unwrap());
            std::hint::black_box(gw.resolve_sym(hot).unwrap());
        }
        let allocs = ALLOCATIONS.load(Ordering::Relaxed) - before;
        println!("gateway::resolve allocations over 20k calls: {allocs}");
        assert_eq!(allocs, 0, "gateway::resolve must not allocate per call");
        let names: Vec<String> = (0..8).map(|i| format!("fn{i}")).collect();
        let mut flip = false;
        bench("gateway::swap_routes (8 functions)", 1_000, 50_000, || {
            flip = !flip;
            gw.swap_routes(&names, Rc::clone(if flip { &inst_b } else { &inst_a })).unwrap()
        });
    }

    // controller-tick signal computation: pre-refactor (scan the whole
    // interleaved history per function) vs the interned windowed shards
    {
        const FNS: usize = 16;
        const RATE_PER_S: usize = 2_000; // samples/s across all functions
        const SECS: usize = 120;
        const WINDOW_MS: f64 = 5_000.0;
        let names: Vec<String> = (0..FNS).map(|i| format!("sigfn{i}")).collect();
        let syms: Vec<Sym> = names.iter().map(|n| Sym::intern(n)).collect();
        let mut legacy: Vec<LegacyFnSample> = Vec::with_capacity(RATE_PER_S * SECS);
        let recorder = Recorder::new();
        let mut rng = Rng::new(9);
        for i in 0..(RATE_PER_S * SECS) {
            let t_ms = i as f64 * (1_000.0 / RATE_PER_S as f64);
            let f = i % FNS;
            let v = rng.lognormal(25.0, 0.4);
            legacy.push(LegacyFnSample { t_ms, function: names[f].clone(), handler_ms: v });
            recorder.record_fn_latency(t_ms, syms[f], v);
        }
        let to = (SECS * 1_000) as f64;
        let from = to - WINDOW_MS;
        // correctness first: both paths agree on every window signal
        for name in &names {
            let a = legacy_fn_p95_window(&legacy, name, from, to, MIN_WINDOW_SAMPLES);
            let b = recorder.fn_p95_window(name, from, to, MIN_WINDOW_SAMPLES);
            assert_eq!(a.to_bits(), b.to_bits(), "p95 mismatch for {name}");
            let a = legacy_fn_self_ms_window(&legacy, name, from, to);
            let b = recorder.fn_self_ms_window(name, from, to);
            assert!((a - b).abs() <= 1e-6 * a.abs().max(1.0), "self-ms mismatch for {name}");
        }
        let old = bench(
            &format!("controller tick signals, pre-refactor ({FNS} fns)"),
            20,
            300,
            || {
                let mut acc = 0.0;
                for name in &names {
                    let p = legacy_fn_p95_window(&legacy, name, from, to, MIN_WINDOW_SAMPLES);
                    if p.is_finite() {
                        acc += p;
                    }
                    acc += legacy_fn_self_ms_window(&legacy, name, from, to);
                }
                acc
            },
        );
        let new = bench(
            &format!("controller tick signals, windowed shards ({FNS} fns)"),
            20,
            300,
            || {
                let mut acc = 0.0;
                for name in &names {
                    let p = recorder.fn_p95_window(name, from, to, MIN_WINDOW_SAMPLES);
                    if p.is_finite() {
                        acc += p;
                    }
                    acc += recorder.fn_self_ms_window(name, from, to);
                }
                acc
            },
        );
        let speedup = old.mean_ns / new.mean_ns;
        println!("controller-tick signal speedup: {speedup:.1}x (acceptance: >= 5x)");
        assert!(
            speedup >= 5.0,
            "windowed signal computation must be >= 5x the pre-refactor path, got {speedup:.1}x"
        );
    }

    // merger fs union
    {
        let a = ("i1".to_string(), FsManifest::function_code("alpha", 120));
        let b = ("i2".to_string(), FsManifest::function_code("beta", 140));
        let parts = vec![a, b];
        bench("fsunion::union_namespaced (2 fns)", 1_000, 50_000, || {
            fsunion::union_namespaced(&parts)
        });
        // 8-function fused instance re-export
        let big: Vec<(String, FsManifest)> = (0..8)
            .map(|i| (format!("i{i}"), FsManifest::function_code(&format!("f{i}"), 100)))
            .collect();
        bench("fsunion::union_namespaced (8 fns)", 200, 10_000, || {
            fsunion::union_namespaced(&big)
        });
    }

    // payload derivation + response combine (per-call arithmetic);
    // naive vs shipped (chunked) — §Perf L3-1 before/after
    {
        let out = vec![0.5f32; 64];
        bench("payload tile 64->2048 (naive, pre-opt)", 1_000, 100_000, || {
            let mut payload = vec![0.0f32; 2048];
            for (i, slot) in payload.iter_mut().enumerate() {
                *slot = out[i % out.len()] * 0.5;
            }
            payload
        });
        bench("payload tile 64->2048 (chunked, shipped)", 1_000, 100_000, || {
            let mut payload = vec![0.0f32; 2048];
            let scaled: Vec<f32> = out.iter().map(|v| v * 0.5).collect();
            for chunk in payload.chunks_exact_mut(scaled.len()) {
                chunk.copy_from_slice(&scaled);
            }
            payload
        });
    }

    // RNG + latency sampling
    {
        let mut rng = Rng::new(7);
        bench("rng lognormal sample", 1_000, 200_000, || rng.lognormal(2.0, 0.25));
    }

    // JSON (manifest-sized)
    {
        let text = Json::arr_f64((0..2048).map(|i| i as f64 * 0.5)).to_string();
        bench("json parse 2048-float array", 100, 2_000, || Json::parse(&text).unwrap());
    }

    // executor primitives
    {
        bench("executor spawn+join (noop task)", 100, 5_000, || {
            run_virtual(async {
                let h = provuse::exec::spawn(async { 1u64 });
                h.await
            })
        });
        bench("executor 1k virtual sleeps", 5, 200, || {
            run_virtual(async {
                let handles: Vec<_> = (0..1000)
                    .map(|i| provuse::exec::spawn(provuse::exec::sleep_ms((i % 97) as f64)))
                    .collect();
                for h in handles {
                    h.await;
                }
            })
        });
    }

    // PJRT compute bodies (the L1/L2 layers from the request path's view)
    if provuse::xla::PJRT_AVAILABLE && std::path::Path::new("artifacts/manifest.json").exists() {
        println!("\n== L1/L2 PJRT compute (per-invocation, CPU) ==");
        let set = ArtifactSet::cached("artifacts").unwrap();
        for name in set.names() {
            let input = set.golden_input(name).unwrap().to_vec();
            bench(&format!("pjrt execute `{name}`"), 20, 300, || {
                set.execute(name, &input).unwrap()
            });
        }
    } else {
        eprintln!("artifacts/ missing; skipping PJRT benches");
    }

    // end-to-end single request, virtual time (full platform, replay)
    {
        println!("\n== end-to-end (virtual-clock wall cost per simulated request) ==");
        let compute = if provuse::xla::PJRT_AVAILABLE
            && std::path::Path::new("artifacts/manifest.json").exists()
        {
            ComputeMode::Replay
        } else {
            ComputeMode::Disabled
        };
        for (label, fusion) in [("vanilla", false), ("fused", true)] {
            bench(&format!("simulate 100 iot requests ({label})"), 2, 10, || {
                Executor::new(Mode::Virtual).block_on(async move {
                    let mut cfg = PlatformConfig::tiny().with_compute(compute);
                    cfg.latency.image_build_ms = 200.0;
                    cfg.latency.boot_ms = 100.0;
                    cfg.fusion.min_observations = 1;
                    if !fusion {
                        cfg = cfg.vanilla();
                    }
                    let p = Platform::deploy(apps::iot(), cfg).await.unwrap();
                    let wl = WorkloadConfig {
                        requests: 100,
                        rate_rps: 50.0,
                        seed: 3,
                        timeout_ms: 60_000.0,
                    };
                    let r = workload::run(Rc::clone(&p), wl).await.unwrap();
                    assert_eq!(r.failed, 0);
                    p.shutdown();
                })
            });
        }
    }

    // sanity guard for §Perf: per-request CPU budget
    {
        let payload = request_payload(1, 1, 2048);
        assert_eq!(payload.len(), 2048);
        println!("\nhotpath bench complete");
    }
}
