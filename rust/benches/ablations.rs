//! `cargo bench --bench ablations` — ABL-RATE / ABL-HOP / ABL-POLICY
//! sweeps (DESIGN.md §4): sensitivity of the paper's claims to request
//! rate, per-hop overhead, and fusion-policy knobs.  800 requests per
//! point (PROVUSE_BENCH_FULL=1 for 2 000).

use provuse::config::ComputeMode;
use provuse::experiments::sweep;
use provuse::util::bench::once;

fn main() {
    let requests = if std::env::var("PROVUSE_BENCH_FULL").is_ok() { 2_000 } else { 800 };
    let compute = if std::path::Path::new("artifacts/manifest.json").exists() {
        ComputeMode::Replay
    } else {
        ComputeMode::Disabled
    };
    let out = std::path::PathBuf::from("results/sweeps");

    println!("== ablation sweeps ({requests} requests per point) ==\n");
    for dim in ["rate", "hop", "policy"] {
        let (result, _) = once(&format!("sweep `{dim}`"), || {
            sweep::run(dim, &out, requests, compute).expect("sweep failed")
        });
        println!("{}", result.render());
    }

    println!("outputs written to {}", out.display());
}
