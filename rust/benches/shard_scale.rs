//! `cargo bench --bench shard_scale` — threaded-core scaling (ISSUE 10):
//! the same seeded chain-workload tenant fleet driven by 1, 2, and 4
//! worker threads, printing requests/sec plus per-worker epoch-window and
//! stall counters (barrier wait as % of wall) so lookahead regressions
//! are visible at a glance, and asserting throughput is monotone in the
//! worker count (with a noise tolerance) whenever the host actually has
//! the cores to back the added workers.
//!
//! The fleet shape is fixed at 4 tenant lanes so every worker count
//! divides it evenly and the 4-worker run is one lane per thread — the
//! shape the figure9 `--threads on` acceptance point uses.

use std::future::Future;
use std::pin::Pin;
use std::rc::Rc;
use std::time::Instant;

use provuse::apps;
use provuse::config::{ComputeMode, MergePolicyKind, PlatformConfig, WorkloadConfig};
use provuse::exec;
use provuse::exec::threads::run_fleet;
use provuse::metrics::RecordingLevel;
use provuse::platform::Platform;
use provuse::workload;

const TENANTS: usize = 4;
const REQUESTS_PER_TENANT: u64 = 2_000;
const SEED: u64 = 77;

/// Virtual batch window the fleet paces itself with (the tenants are
/// independent, so the conservative license is unbounded).
const PACED_WINDOW_NS: u64 = 250_000_000;

/// One tenant lane: a single-node chain(3) platform under a
/// tenant-derived seed carrying its share of the workload.  Returns the
/// number of failed requests (asserted zero by the driver).
fn tenant_job(tenant: usize) -> impl FnOnce() -> Pin<Box<dyn Future<Output = u64>>> + Send {
    move || {
        Box::pin(async move {
            let mut cfg = PlatformConfig::tiny()
                .with_compute(ComputeMode::Disabled)
                .with_seed(SEED ^ 0x9E3779B97F4A7C15u64.wrapping_mul(tenant as u64 + 1))
                .with_recording(RecordingLevel::Windowed);
            cfg.latency.image_build_ms = 300.0;
            cfg.latency.boot_ms = 150.0;
            cfg.fusion.min_observations = 3;
            cfg.fusion.feedback_interval_ms = 1_000.0;
            cfg.fusion.merge_policy = MergePolicyKind::CostModel;
            cfg.cluster.nodes = 1;
            let seed = cfg.seed;
            let p = Platform::deploy(apps::chain(3), cfg).await.unwrap();
            let wl = WorkloadConfig {
                requests: REQUESTS_PER_TENANT,
                rate_rps: 400.0,
                seed,
                timeout_ms: 60_000.0,
            };
            let report = workload::run(Rc::clone(&p), wl).await.unwrap();
            exec::sleep_ms(10_000.0).await;
            p.shutdown();
            report.failed
        })
    }
}

/// Drive the fleet on `workers` threads; returns wall requests/sec.
fn run_at(workers: usize) -> f64 {
    let mut jobs: Vec<Vec<_>> = (0..workers).map(|_| Vec::new()).collect();
    for t in 0..TENANTS {
        jobs[t % workers].push(tenant_job(t));
    }
    let wall = Instant::now();
    let fleet = run_fleet(PACED_WINDOW_NS, jobs).expect("fleet must complete");
    let wall_s = wall.elapsed().as_secs_f64();
    let failed: u64 = fleet.results.iter().flatten().sum();
    assert_eq!(failed, 0, "fleet dropped requests at {workers} workers");
    let total = (TENANTS as u64 * REQUESTS_PER_TENANT) as f64;
    let rps = total / wall_s;
    println!(
        "workers {workers}: {total:.0} requests in {wall_s:.2} s -> {rps:.0} req/s \
         ({} epoch windows)",
        fleet.windows
    );
    for ws in &fleet.stats {
        println!(
            "  worker {}: {} lanes, {} windows, {} epochs, stall {:.1}% of wall",
            ws.worker,
            ws.jobs,
            ws.windows,
            ws.epochs,
            ws.stall_pct()
        );
    }
    rps
}

fn main() {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!("== shard scale (threaded simulation core, {cores} host cores) ==");

    // warmup: interner tables, thread locals, and other one-time global
    // growth land here, not in the measured runs
    let _ = run_at(1);

    let r1 = run_at(1);
    let r2 = run_at(2);
    let r4 = run_at(4);

    println!(
        "\nscaling: 1->2 workers {:.2}x, 2->4 workers {:.2}x, 1->4 workers {:.2}x",
        r2 / r1,
        r4 / r2,
        r4 / r1
    );

    // Monotone-throughput gate, tolerance 0.85 for scheduler noise.  Only
    // binding where the host can actually run the workers concurrently —
    // on a smaller box the numbers above are informational.
    if cores >= 2 {
        assert!(
            r2 >= 0.85 * r1,
            "2-worker throughput regressed vs 1 worker: {r2:.0} < 0.85 * {r1:.0}"
        );
    }
    if cores >= 4 {
        assert!(
            r4 >= 0.85 * r2,
            "4-worker throughput regressed vs 2 workers: {r4:.0} < 0.85 * {r2:.0}"
        );
    }

    println!("shard_scale bench complete");
}
