//! `cargo bench --bench paper_figures` — regenerates every table and
//! figure of the paper's evaluation at bench scale (2 000 requests instead
//! of 10 000; pass PROVUSE_BENCH_FULL=1 for the paper's exact workload)
//! and reports measured-vs-paper values plus wall time per regeneration.
//!
//! FIG3/FIG4 (call graphs) are structural: regenerated as DOT + checked
//! against the paper's fusion groups.  FIG5/FIG6/TAB-LAT/TAB-RAM run the
//! platform matrix.

use provuse::apps;
use provuse::config::{ComputeMode, WorkloadConfig};
use provuse::experiments::{fig5, fig6};
use provuse::util::bench::once;

fn workload() -> WorkloadConfig {
    let full = std::env::var("PROVUSE_BENCH_FULL").is_ok();
    let mut wl = WorkloadConfig::paper();
    if !full {
        wl.requests = 2_000;
    }
    wl
}

fn compute() -> ComputeMode {
    // Replay keeps bench timing deterministic; needs real PJRT + artifacts.
    if provuse::xla::PJRT_AVAILABLE && std::path::Path::new("artifacts/manifest.json").exists() {
        ComputeMode::Replay
    } else {
        eprintln!("WARNING: PJRT/artifacts unavailable, benching with compute disabled");
        ComputeMode::Disabled
    }
}

fn main() {
    let wl = workload();
    let compute = compute();
    let out = std::path::PathBuf::from("results/bench");
    println!(
        "== paper figure regeneration ({} requests @ {} rps per run) ==\n",
        wl.requests, wl.rate_rps
    );

    // ---- FIG3 / FIG4: call graphs -------------------------------------------
    let (_, _) = once("FIG3: IOT call graph (DOT)", || {
        let app = apps::iot();
        let dot = app.to_dot();
        assert!(dot.contains("cluster_"));
        provuse::experiments::write_output(&out.join("fig3_iot.dot"), &dot).unwrap();
        assert_eq!(app.sync_fusion_groups().len(), 2);
    });
    let (_, _) = once("FIG4: TREE call graph (DOT)", || {
        let app = apps::tree();
        provuse::experiments::write_output(&out.join("fig4_tree.dot"), &app.to_dot()).unwrap();
        assert_eq!(app.sync_fusion_groups().len(), 2);
    });
    println!();

    // ---- FIG5: IOT/tinyFaaS time series --------------------------------------
    let (fig5_result, _) = once("FIG5: IOT/tinyFaaS vanilla+fusion series", || {
        fig5::run(&out.join("fig5"), wl.clone(), compute).expect("fig5 failed")
    });
    println!("{}", fig5_result.render());

    // ---- FIG6 + TAB-LAT + TAB-RAM: the 4-cell matrix -------------------------
    let (fig6_result, _) = once("FIG6: 4-config matrix (8 runs)", || {
        fig6::run(&out.join("fig6"), wl.clone(), compute).expect("fig6 failed")
    });
    println!("{}", fig6_result.render());
    println!(
        "TAB-RAM mean reduction: {:.1}% (paper 53.6%)\n",
        fig6_result.mean_ram_reduction_pct()
    );

    // ---- headline check -------------------------------------------------------
    let lat = fig6_result.mean_latency_reduction_pct();
    let ram = fig6_result.mean_ram_reduction_pct();
    println!("== headline vs paper ==");
    println!("  mean latency reduction: {lat:.1}%  (paper: 26.3%)");
    println!("  mean RAM reduction:     {ram:.1}%  (paper: 53.6%)");
    assert!(lat > 10.0, "latency reduction shape lost");
    assert!(ram > 25.0, "RAM reduction shape lost");
    println!("\nshape PRESERVED: fusion wins every cell on both axes");
}
