//! PJRT compute runtime: loads the AOT-compiled HLO artifacts produced by
//! `python/compile/aot.py` and executes them on the request path.
//!
//! Interchange is HLO **text** (see aot.py for why), compiled once per body
//! through the `xla` crate's PJRT CPU client.  Python is never on the
//! request path: after `make artifacts` the Rust binary is self-contained.

use std::cell::{Cell, RefCell};
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;
use std::time::Instant;

use crate::config::ComputeMode;
use crate::error::{Error, Result};
use crate::util::json::Json;
use crate::xla;

/// One compiled compute body.
struct Body {
    exe: xla::PjRtLoadedExecutable,
    input_len: usize,
    output_len: usize,
    golden_input: Vec<f32>,
    golden_output: Vec<f32>,
    /// cached output for Replay mode (executed once at load)
    replay_output: RefCell<Option<Vec<f32>>>,
    /// profiled execution wall time (ms), charged per call in Replay mode
    profile_ms: Cell<f64>,
}

/// The full artifact set described by `artifacts/manifest.json`.
pub struct ArtifactSet {
    #[allow(dead_code)] // owns the PJRT runtime the executables run on
    client: xla::PjRtClient,
    bodies: HashMap<String, Body>,
    pub batch: usize,
    pub in_dim: usize,
    pub out_dim: usize,
}

/// Result of validating one body against its python golden.
#[derive(Debug, Clone)]
pub struct Validation {
    pub name: String,
    pub max_abs_err: f64,
    pub ok: bool,
}

impl ArtifactSet {
    /// Load + compile every artifact in `dir` (must contain manifest.json).
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
            Error::Runtime(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                manifest_path.display()
            ))
        })?;
        let manifest = Json::parse(&text)?;
        let batch = manifest.get("batch")?.as_usize()?;
        let in_dim = manifest.get("in_dim")?.as_usize()?;
        let out_dim = manifest.get("out_dim")?.as_usize()?;

        let client = xla::PjRtClient::cpu()?;
        let mut bodies = HashMap::new();
        for entry in manifest.get("bodies")?.as_arr()? {
            let name = entry.get("name")?.as_str()?.to_string();
            let hlo_path = dir.join(entry.get("hlo")?.as_str()?);
            let proto = xla::HloModuleProto::from_text_file(&hlo_path)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;

            let golden_path = dir.join(entry.get("golden")?.as_str()?);
            let golden = Json::parse(&std::fs::read_to_string(&golden_path)?)?;
            let golden_input = golden.get("input")?.as_f32_vec()?;
            let golden_output = golden.get("output")?.as_f32_vec()?;

            let ishape = entry.get("input_shape")?.as_arr()?;
            let oshape = entry.get("output_shape")?.as_arr()?;
            let input_len: usize =
                ishape.iter().map(|d| d.as_usize().unwrap_or(0)).product();
            let output_len: usize =
                oshape.iter().map(|d| d.as_usize().unwrap_or(0)).product();
            if golden_input.len() != input_len || golden_output.len() != output_len {
                return Err(Error::Runtime(format!(
                    "golden shape mismatch for `{name}`"
                )));
            }

            bodies.insert(
                name,
                Body {
                    exe,
                    input_len,
                    output_len,
                    golden_input,
                    golden_output,
                    replay_output: RefCell::new(None),
                    profile_ms: Cell::new(0.0),
                },
            );
        }
        Ok(ArtifactSet { client, bodies, batch, in_dim, out_dim })
    }

    /// Per-thread cache keyed by directory (PJRT types are not `Send`).
    pub fn cached(dir: &str) -> Result<Rc<ArtifactSet>> {
        thread_local! {
            static CACHE: RefCell<HashMap<String, Rc<ArtifactSet>>> =
                RefCell::new(HashMap::new());
        }
        CACHE.with(|c| {
            if let Some(set) = c.borrow().get(dir) {
                return Ok(Rc::clone(set));
            }
            let set = Rc::new(ArtifactSet::load(dir)?);
            set.profile_all(5);
            c.borrow_mut().insert(dir.to_string(), Rc::clone(&set));
            Ok(set)
        })
    }

    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.bodies.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }

    pub fn has(&self, name: &str) -> bool {
        self.bodies.contains_key(name)
    }

    fn body(&self, name: &str) -> Result<&Body> {
        self.bodies.get(name).ok_or_else(|| Error::UnknownBody(name.to_string()))
    }

    /// Execute `name` on `input` (row-major f32, length batch*in_dim).
    pub fn execute(&self, name: &str, input: &[f32]) -> Result<Vec<f32>> {
        let body = self.body(name)?;
        if input.len() != body.input_len {
            return Err(Error::Runtime(format!(
                "`{name}` expects {} floats, got {}",
                body.input_len,
                input.len()
            )));
        }
        let lit = xla::Literal::vec1(input)
            .reshape(&[self.batch as i64, self.in_dim as i64])?;
        let result = body.exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?; // aot.py lowers with return_tuple=True
        let values = out.to_vec::<f32>()?;
        debug_assert_eq!(values.len(), body.output_len);
        Ok(values)
    }

    /// Execute and measure wall time (ms).
    pub fn execute_timed(&self, name: &str, input: &[f32]) -> Result<(Vec<f32>, f64)> {
        let t0 = Instant::now();
        let out = self.execute(name, input)?;
        Ok((out, t0.elapsed().as_secs_f64() * 1e3))
    }

    /// Golden input for `name` (deterministic, exported by aot.py).
    pub fn golden_input(&self, name: &str) -> Result<&[f32]> {
        Ok(&self.body(name)?.golden_input)
    }

    /// Run every body on its golden input and compare against the python
    /// output — the cross-layer numeric parity check.
    pub fn validate(&self, tolerance: f32) -> Result<Vec<Validation>> {
        let mut names: Vec<&String> = self.bodies.keys().collect();
        names.sort();
        let mut out = Vec::new();
        for name in names {
            let body = &self.bodies[name];
            let got = self.execute(name, &body.golden_input)?;
            let max_abs_err = got
                .iter()
                .zip(&body.golden_output)
                .map(|(a, b)| (a - b).abs() as f64)
                .fold(0.0, f64::max);
            out.push(Validation {
                name: name.clone(),
                max_abs_err,
                ok: max_abs_err <= tolerance as f64,
            });
        }
        Ok(out)
    }

    /// Profile every body (median of `reps` runs on the golden input) and
    /// cache a replay output.  Called once at load by [`ArtifactSet::cached`].
    pub fn profile_all(&self, reps: usize) {
        let mut names: Vec<String> = self.bodies.keys().cloned().collect();
        names.sort();
        for name in names {
            let body = &self.bodies[&name];
            // warmup + replay output
            let out = self
                .execute(&name, &body.golden_input)
                .expect("profiling execute failed");
            *body.replay_output.borrow_mut() = Some(out);
            let mut times: Vec<f64> = (0..reps.max(1))
                .map(|_| {
                    let t0 = Instant::now();
                    let _ = self.execute(&name, &body.golden_input).unwrap();
                    t0.elapsed().as_secs_f64() * 1e3
                })
                .collect();
            times.sort_by(|a, b| a.partial_cmp(b).unwrap());
            body.profile_ms.set(times[times.len() / 2]);
        }
    }

    /// Profiled wall time (ms) of one body execution.
    pub fn profile_ms(&self, name: &str) -> Result<f64> {
        Ok(self.body(name)?.profile_ms.get())
    }

    /// Cached output from load-time execution (Replay mode).
    pub fn replay_output(&self, name: &str) -> Result<Vec<f32>> {
        let body = self.body(name)?;
        let cached = body.replay_output.borrow();
        match &*cached {
            Some(v) => Ok(v.clone()),
            None => {
                drop(cached);
                let out = self.execute(name, &body.golden_input)?;
                *body.replay_output.borrow_mut() = Some(out.clone());
                Ok(out)
            }
        }
    }
}

// ---------------------------------------------------------------------------
// ComputeService — what handlers call on the request path
// ---------------------------------------------------------------------------

/// Uniform compute interface for Function Handlers, honoring
/// [`ComputeMode`].
#[derive(Clone)]
pub struct ComputeService {
    artifacts: Option<Rc<ArtifactSet>>,
    mode: ComputeMode,
    out_len: usize,
}

impl ComputeService {
    pub fn new(artifacts: Rc<ArtifactSet>, mode: ComputeMode) -> Self {
        let out_len = artifacts.batch * artifacts.out_dim;
        ComputeService { artifacts: Some(artifacts), mode, out_len }
    }

    /// Compute-free service for coordination-only tests.
    pub fn disabled() -> Self {
        ComputeService { artifacts: None, mode: ComputeMode::Disabled, out_len: 64 }
    }

    pub fn mode(&self) -> ComputeMode {
        self.mode
    }

    pub fn artifacts(&self) -> Option<&Rc<ArtifactSet>> {
        self.artifacts.as_ref()
    }

    /// Execute `body` on `input`; returns `(output, compute_ms)` where
    /// `compute_ms` is the duration to charge on the virtual clock.
    pub fn run(&self, body: &str, input: &[f32]) -> Result<(Vec<f32>, f64)> {
        match (self.mode, &self.artifacts) {
            (ComputeMode::Live, Some(set)) => set.execute_timed(body, input),
            (ComputeMode::Replay, Some(set)) => {
                Ok((set.replay_output(body)?, set.profile_ms(body)?))
            }
            (ComputeMode::Disabled, _) | (_, None) => {
                // Deterministic stand-in: fold the input into out_len values.
                let mut out = vec![0.0f32; self.out_len];
                for (i, v) in input.iter().enumerate() {
                    out[i % self.out_len] += v * 0.125;
                }
                Ok((out, 0.0))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    //! PJRT-dependent tests live in rust/tests/artifact_parity.rs (they need
    //! `make artifacts`); here we only cover the Disabled compute path.
    use super::*;

    #[test]
    fn disabled_compute_is_deterministic_and_input_sensitive() {
        let svc = ComputeService::disabled();
        let a: Vec<f32> = (0..2048).map(|i| i as f32 * 0.01).collect();
        let (o1, ms1) = svc.run("anything", &a).unwrap();
        let (o2, _) = svc.run("anything", &a).unwrap();
        assert_eq!(o1, o2);
        assert_eq!(ms1, 0.0);
        assert_eq!(o1.len(), 64);
        let mut b = a.clone();
        b[5] += 1.0;
        let (o3, _) = svc.run("anything", &b).unwrap();
        assert_ne!(o1, o3);
    }
}
