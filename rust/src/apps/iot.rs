//! The IOT application from Fusionize++ (paper Fig. 3).
//!
//! > "The workflow starts at AnalyzeSensor (I), combining sequential steps
//! > with parallel analysis of temperature, air quality, and traffic."
//!
//! The paper prints only the figure caption, not the edge list; this
//! reconstruction (documented in DESIGN.md) uses: AnalyzeSensor →sync
//! Parse →sync Validate →sync {Temperature ∥ AirQuality ∥ Traffic}, each
//! analysis →sync Aggregate, Aggregate →async Persist →sync Notify.
//! Solid-edge components give the theoretical fusion groups:
//! {analyze_sensor, parse, validate, temperature, airquality, traffic,
//! aggregate} and {persist, notify}.  busy-time calibration targets the
//! paper's vanilla median of ~807 ms (DESIGN.md §5).

use super::spec::{AppSpec, CallMode, FunctionSpec};

fn f(
    name: &str,
    body: &str,
    busy_ms: f64,
    code_mb: f64,
    calls: Vec<(&str, CallMode)>,
) -> FunctionSpec {
    FunctionSpec::calibrated(name, body, busy_ms, code_mb, "iot", calls)
}

/// Build the IOT application.
pub fn iot() -> AppSpec {
    use CallMode::*;
    AppSpec::new(
        "iot",
        "analyze_sensor",
        vec![
            f("analyze_sensor", "analyze_sensor", 70.0, 18.0, vec![("parse", Sync)]),
            f("parse", "parse", 95.0, 14.0, vec![("validate", Sync)]),
            f(
                "validate",
                "tree_light",
                85.0,
                12.0,
                vec![("temperature", Sync), ("airquality", Sync), ("traffic", Sync)],
            ),
            f("temperature", "temperature", 180.0, 26.0, vec![("aggregate", Sync)]),
            f("airquality", "airquality", 160.0, 24.0, vec![("aggregate", Sync)]),
            f("traffic", "traffic", 150.0, 22.0, vec![("aggregate", Sync)]),
            f("aggregate", "aggregate", 90.0, 16.0, vec![("persist", Async)]),
            f("persist", "persist", 60.0, 20.0, vec![("notify", Sync)]),
            f("notify", "notify", 20.0, 10.0, vec![]),
        ],
    )
    .expect("iot app is statically valid")
}

/// The ROADMAP's IOT-app *variant* for the FIG7 eviction scenario: two
/// fused groups where one member (`model`, a 400 MiB ML-dependency
/// function) dominates its group's RAM and — under direct per-route
/// pressure — its bill, so a cost-model controller should shed exactly it
/// while the second group (`persist` → `notify`) stays fused.
///
/// Graph: ingest →sync model →sync refine; refine →async persist →sync
/// notify.  Sync components: {ingest, model, refine} and {notify, persist}.
pub fn iot_heavy() -> AppSpec {
    use CallMode::*;
    AppSpec::new(
        "iot-heavy",
        "ingest",
        vec![
            f("ingest", "parse", 25.0, 10.0, vec![("model", Sync)]),
            f("model", "temperature", 70.0, 400.0, vec![("refine", Sync)]),
            f("refine", "aggregate", 25.0, 12.0, vec![("persist", Async)]),
            f("persist", "persist", 30.0, 14.0, vec![("notify", Sync)]),
            f("notify", "notify", 10.0, 8.0, vec![]),
        ],
    )
    .expect("iot-heavy app is statically valid")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_matches_figure3() {
        let app = iot();
        assert_eq!(app.entry, "analyze_sensor");
        assert_eq!(app.len(), 9);
        // parallel analyses fan out of validate
        let v = app.function("validate").unwrap();
        assert_eq!(v.calls.len(), 3);
        assert!(v.calls.iter().all(|c| c.mode == CallMode::Sync));
    }

    #[test]
    fn fusion_groups() {
        let groups = iot().sync_fusion_groups();
        assert_eq!(groups.len(), 2);
        let big: Vec<String> = vec![
            "aggregate".into(),
            "airquality".into(),
            "analyze_sensor".into(),
            "parse".into(),
            "temperature".into(),
            "traffic".into(),
            "validate".into(),
        ];
        assert!(groups.contains(&big));
        assert!(groups.contains(&vec!["notify".into(), "persist".into()]));
    }

    #[test]
    fn persist_branch_is_off_critical_path() {
        let reach = iot().sync_reachable_from_entry();
        assert!(reach.contains("aggregate"));
        assert!(!reach.contains("persist"));
        assert!(!reach.contains("notify"));
    }

    #[test]
    fn every_function_has_a_body() {
        for f in iot().functions() {
            assert!(f.body.is_some(), "{} missing body", f.name);
        }
    }

    #[test]
    fn iot_heavy_has_two_groups_and_a_dominant_member() {
        let app = iot_heavy();
        assert_eq!(app.entry, "ingest");
        let groups = app.sync_fusion_groups();
        assert_eq!(groups.len(), 2);
        assert!(groups.contains(&vec!["ingest".into(), "model".into(), "refine".into()]));
        assert!(groups.contains(&vec!["notify".into(), "persist".into()]));
        // `model` dominates its group's code RAM (the eviction target)
        let model_mb = app.function("model").unwrap().code_mb;
        let rest_mb: f64 = ["ingest", "refine"]
            .iter()
            .map(|n| app.function(n).unwrap().code_mb)
            .sum();
        assert!(model_mb > 5.0 * rest_mb);
        for f in app.functions() {
            assert!(f.body.is_some(), "{} missing body", f.name);
        }
    }
}
