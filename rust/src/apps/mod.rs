//! Benchmark applications (paper §5.1) + the application model.
//!
//! * [`tree`] — Fusionize++ TREE (Fig. 4): minimal fusion use case.
//! * [`iot`] — Fusionize++ IOT (Fig. 3): realistic sensor pipeline.
//! * [`chain`] — an N-stage sequential chain used by the ablation sweeps.
//! * [`mixed`] — three independent pairs (light/heavy/cold) for the
//!   merge-admission planner scenario.
//! * [`trap`] — a chain whose optimal partition is unreachable by greedy
//!   pairwise admission (the global re-planner A/B scenario).

mod spec;

pub mod chain;
pub mod iot;
pub mod mixed;
pub mod trap;
pub mod tree;

pub use chain::chain;
pub use iot::{iot, iot_heavy};
pub use mixed::mixed;
pub use spec::{AppBuilder, AppSpec, CallMode, CallSpec, FnBuilder, FunctionSpec};
pub use trap::trap;
pub use tree::tree;

use crate::error::{Error, Result};

/// Look an application up by CLI name.  The error string is derived from
/// [`APP_NAMES`], so the advertised list can never drift from the matches
/// (enforced by `by_name_accepts_every_app_name` below).
pub fn by_name(name: &str) -> Result<AppSpec> {
    match name {
        "tree" => Ok(tree()),
        "iot" => Ok(iot()),
        "iot-heavy" => Ok(iot_heavy()),
        "chain" => Ok(chain(6)),
        "mixed" => Ok(mixed()),
        "trap" => Ok(trap()),
        other => Err(Error::Config(format!(
            "unknown app `{other}` (available: {})",
            APP_NAMES.join(", ")
        ))),
    }
}

/// All benchmark app names.
pub const APP_NAMES: &[&str] = &["tree", "iot", "iot-heavy", "chain", "mixed", "trap"];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn by_name_accepts_every_app_name() {
        // the list and the matcher can never drift again: every advertised
        // name must resolve, and the error must advertise every name
        for name in APP_NAMES {
            let app = by_name(name).unwrap_or_else(|e| panic!("APP_NAMES lists `{name}`: {e}"));
            assert!(!app.is_empty());
        }
        let err = by_name("no-such-app").unwrap_err().to_string();
        for name in APP_NAMES {
            assert!(err.contains(name), "error string omits `{name}`: {err}");
        }
    }
}
