//! Benchmark applications (paper §5.1) + the application model.
//!
//! * [`tree`] — Fusionize++ TREE (Fig. 4): minimal fusion use case.
//! * [`iot`] — Fusionize++ IOT (Fig. 3): realistic sensor pipeline.
//! * [`chain`] — an N-stage sequential chain used by the ablation sweeps.

mod spec;

pub mod chain;
pub mod iot;
pub mod tree;

pub use chain::chain;
pub use iot::{iot, iot_heavy};
pub use spec::{AppBuilder, AppSpec, CallMode, CallSpec, FnBuilder, FunctionSpec};
pub use tree::tree;

use crate::error::{Error, Result};

/// Look an application up by CLI name.
pub fn by_name(name: &str) -> Result<AppSpec> {
    match name {
        "tree" => Ok(tree()),
        "iot" => Ok(iot()),
        "iot-heavy" => Ok(iot_heavy()),
        "chain" => Ok(chain(6)),
        other => Err(Error::Config(format!(
            "unknown app `{other}` (available: tree, iot, iot-heavy, chain)"
        ))),
    }
}

/// All benchmark app names.
pub const APP_NAMES: &[&str] = &["tree", "iot", "iot-heavy", "chain"];
