//! The TRAP application — the global re-planner's proving ground
//! (FIG11 `--app trap`).  A three-stage sync chain in one trust domain:
//!
//! ```text
//! intake --sync--> enrich --sync--> archive
//! ```
//!
//! `enrich` carries a ~450 MiB enrichment-model dependency stack, sized so
//! that **every pairwise step is a loss** under the greedy cost-model
//! admission: both (intake, enrich) and (enrich, archive) put `enrich`'s
//! working set into the predicted fused footprint, which trips the churn
//! gate (`w_ram * ram_term >= evict_threshold`).  The greedy planner
//! therefore refuses both candidate pairs forever and locks the topology
//! into all-singletons — a textbook local optimum, reached by never
//! accepting a temporarily-worse intermediate.
//!
//! The *whole-partition* objective tells a different story: fusing the
//! full chain removes both cut edges' double-billed blocked time while the
//! RAM residency total barely moves (the model is resident either way —
//! it is priced once per group, not once per candidate pair).  The global
//! planner walks through the greedy-refused intermediate and lands on the
//! all-fused partition, whose steady state strictly dominates greedy's on
//! the same latency×RAM×bill objective.  `figure11` self-checks exactly
//! that A/B.

use super::spec::{AppSpec, CallMode, FunctionSpec};

fn f(
    name: &str,
    body: &str,
    busy_ms: f64,
    code_mb: f64,
    calls: Vec<(&str, CallMode)>,
) -> FunctionSpec {
    FunctionSpec::calibrated(name, body, busy_ms, code_mb, "trap", calls)
}

/// Build the TRAP application.
pub fn trap() -> AppSpec {
    use CallMode::*;
    AppSpec::new(
        "trap",
        "intake",
        vec![
            f("intake", "parse", 10.0, 10.0, vec![("enrich", Sync)]),
            f("enrich", "temperature", 40.0, 450.0, vec![("archive", Sync)]),
            f("archive", "aggregate", 15.0, 9.0, vec![]),
        ],
    )
    .expect("trap app is statically valid")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn one_chain_one_trust_domain() {
        let app = trap();
        assert_eq!(app.entry, "intake");
        assert_eq!(app.len(), 3);
        let groups = app.sync_fusion_groups();
        assert_eq!(
            groups,
            vec![vec!["archive".to_string(), "enrich".into(), "intake".into()]]
        );
        for f in app.functions() {
            assert_eq!(f.trust_domain, "trap");
            assert!(f.body.is_some(), "{} missing body", f.name);
        }
    }

    #[test]
    fn heavy_middle_traps_every_pairwise_step() {
        let app = trap();
        let enrich = app.function("enrich").unwrap().code_mb;
        // against the default cost params (ram_ref 256 MiB, evict/churn
        // threshold 2.0) the enrich working set alone trips the churn gate
        // for BOTH of its pairs: enrich/256 > 1.7 leaves under 0.3 for the
        // partner, and both partners' instances exceed that on base RAM
        // alone — the greedy arm can never take the first step
        assert!(enrich / 256.0 > 1.7, "enrich must dominate the churn gate");
        for name in ["intake", "archive"] {
            assert!(app.function(name).unwrap().code_mb < 20.0);
        }
    }
}
