//! The MIXED application — the merge-side admission planner's proving
//! ground (FIG7 `--app mixed`).  Three independent sync pairs in one trust
//! domain, each a distinct admission verdict when driven by per-route
//! workloads:
//!
//! * **light pair** `light_api →sync light_fmt` — hot and cheap: the
//!   caller spends most of its wall clock double-billed on the hop, both
//!   functions are small.  A cost-aware planner must **fuse** it.
//! * **heavy pair** `heavy_api →sync heavy_model` — just as hot, but the
//!   callee carries a 400 MiB ML dependency stack: the predicted fused
//!   working set alone makes the group an immediate eviction candidate
//!   under the defusion cost model.  A cost-aware planner must **refuse**
//!   it even though its observation count crosses the threshold, where the
//!   observation-count policy fuses it and then fuse→evict flaps.
//! * **cold pair** `cold_api →sync cold_fmt` — cheap but nearly idle: the
//!   predicted benefit never covers the RAM penalty, so it stays unfused
//!   even after (slowly) crossing the observation threshold.
//!
//! The `router` entry is deliberately disconnected from the pairs (no
//! sync/async edges): each pair's traffic comes from targeted per-route
//! workloads (`workload::run_targeted`), keeping the three verdicts
//! independent.

use super::spec::{AppSpec, CallMode, FunctionSpec};

fn f(
    name: &str,
    body: &str,
    busy_ms: f64,
    code_mb: f64,
    calls: Vec<(&str, CallMode)>,
) -> FunctionSpec {
    FunctionSpec::calibrated(name, body, busy_ms, code_mb, "mixed", calls)
}

/// Build the MIXED application.
pub fn mixed() -> AppSpec {
    use CallMode::*;
    AppSpec::new(
        "mixed",
        "router",
        vec![
            f("router", "parse", 10.0, 8.0, vec![]),
            f("light_api", "parse", 20.0, 10.0, vec![("light_fmt", Sync)]),
            f("light_fmt", "aggregate", 30.0, 9.0, vec![]),
            f("heavy_api", "parse", 20.0, 10.0, vec![("heavy_model", Sync)]),
            f("heavy_model", "temperature", 60.0, 400.0, vec![]),
            f("cold_api", "parse", 15.0, 10.0, vec![("cold_fmt", Sync)]),
            f("cold_fmt", "aggregate", 15.0, 9.0, vec![]),
        ],
    )
    .expect("mixed app is statically valid")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn three_pairs_and_a_disconnected_router() {
        let app = mixed();
        assert_eq!(app.entry, "router");
        assert_eq!(app.len(), 7);
        assert!(app.function("router").unwrap().calls.is_empty());
        let groups = app.sync_fusion_groups();
        assert_eq!(groups.len(), 4);
        assert!(groups.contains(&vec!["light_api".into(), "light_fmt".into()]));
        assert!(groups.contains(&vec!["heavy_api".into(), "heavy_model".into()]));
        assert!(groups.contains(&vec!["cold_api".into(), "cold_fmt".into()]));
        assert!(groups.contains(&vec!["router".into()]));
    }

    #[test]
    fn heavy_callee_dominates_its_pair_ram() {
        let app = mixed();
        let model_mb = app.function("heavy_model").unwrap().code_mb;
        let api_mb = app.function("heavy_api").unwrap().code_mb;
        assert!(model_mb > 20.0 * api_mb, "heavy callee must dwarf its caller");
        // the light and cold pairs stay far under the heavy callee
        for name in ["light_api", "light_fmt", "cold_api", "cold_fmt"] {
            assert!(app.function(name).unwrap().code_mb < 20.0);
        }
    }

    #[test]
    fn every_function_has_a_body() {
        for f in mixed().functions() {
            assert!(f.body.is_some(), "{} missing body", f.name);
        }
    }
}
