//! Application model: a FaaS app as a set of independently deployed
//! functions with typed call edges (DESIGN.md substitution #3).
//!
//! The fusion mechanism never inspects function code (the paper optimizes
//! purely at the invocation level), so a function is fully described by
//! (a) its call pattern — synchronous edges block the caller, asynchronous
//! edges do not — and (b) its compute cost: a real AOT-compiled HLO body
//! plus a calibrated busy-time term standing in for the I/O the paper's
//! Python functions perform.

use std::collections::{BTreeMap, HashSet};

use crate::error::{Error, Result};

/// Whether an outbound call blocks the calling function.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CallMode {
    /// Caller blocks on the result (solid edges in Figs. 3-4); the response
    /// feeds into the caller's own response. Fusion candidates.
    Sync,
    /// Fire-and-forget (dashed edges); does not affect the caller's
    /// end-to-end latency. Never fused.
    Async,
}

/// One outbound call edge.
#[derive(Debug, Clone)]
pub struct CallSpec {
    pub target: String,
    pub mode: CallMode,
    /// linear transform applied when deriving the child payload from the
    /// caller's compute output (keeps data flow deterministic + non-trivial)
    pub scale: f32,
}

/// One deployable function.
#[derive(Debug, Clone)]
pub struct FunctionSpec {
    pub name: String,
    /// AOT artifact executed as the compute body (None = pure orchestration)
    pub body: Option<String>,
    /// calibrated extra busy time (ms) modeling the paper functions' I/O +
    /// processing not captured by the HLO body
    pub busy_ms: f64,
    /// code + dependency RAM footprint (MiB)
    pub code_mb: f64,
    /// code size on disk (KiB) for the image manifest
    pub code_kb: u64,
    /// trust domain label (paper §6: fusion restricted to one domain)
    pub trust_domain: String,
    /// outbound calls; all Sync calls are issued concurrently and joined,
    /// then Async calls are detached (Figs. 3-4 semantics)
    pub calls: Vec<CallSpec>,
}

impl FunctionSpec {
    /// Calibrated constructor shared by the benchmark apps (iot, mixed):
    /// image size on disk follows the code footprint at the seed
    /// calibration's 28 KiB-per-MiB ratio.
    pub(crate) fn calibrated(
        name: &str,
        body: &str,
        busy_ms: f64,
        code_mb: f64,
        trust_domain: &str,
        calls: Vec<(&str, CallMode)>,
    ) -> FunctionSpec {
        FunctionSpec {
            name: name.into(),
            body: Some(body.into()),
            busy_ms,
            code_mb,
            code_kb: (code_mb * 28.0) as u64,
            trust_domain: trust_domain.into(),
            calls: calls
                .into_iter()
                .map(|(t, mode)| CallSpec { target: t.into(), mode, scale: 1.0 })
                .collect(),
        }
    }
}

/// A composed FaaS application.
#[derive(Debug, Clone)]
pub struct AppSpec {
    pub name: String,
    pub entry: String,
    functions: BTreeMap<String, FunctionSpec>,
}

impl AppSpec {
    /// Build + validate. Rejects: missing entry, dangling call targets,
    /// duplicate functions, self-calls, and call cycles (FaaS workflows in
    /// the paper's model are DAGs).
    pub fn new(
        name: impl Into<String>,
        entry: impl Into<String>,
        functions: Vec<FunctionSpec>,
    ) -> Result<Self> {
        let name = name.into();
        let entry = entry.into();
        let mut map = BTreeMap::new();
        for f in functions {
            if map.insert(f.name.clone(), f).is_some() {
                return Err(Error::Config(format!("duplicate function in `{name}`")));
            }
        }
        let app = AppSpec { name, entry, functions: map };
        app.validate()?;
        Ok(app)
    }

    fn validate(&self) -> Result<()> {
        if !self.functions.contains_key(&self.entry) {
            return Err(Error::Config(format!(
                "entry `{}` not defined in app `{}`",
                self.entry, self.name
            )));
        }
        for f in self.functions.values() {
            for c in &f.calls {
                if c.target == f.name {
                    return Err(Error::Config(format!("`{}` calls itself", f.name)));
                }
                if !self.functions.contains_key(&c.target) {
                    return Err(Error::Config(format!(
                        "`{}` calls undefined `{}`",
                        f.name, c.target
                    )));
                }
            }
        }
        // cycle detection (DFS, three-color)
        #[derive(Clone, Copy, PartialEq)]
        enum Color {
            White,
            Grey,
            Black,
        }
        fn visit(
            app: &AppSpec,
            node: &str,
            colors: &mut BTreeMap<String, Color>,
        ) -> Result<()> {
            colors.insert(node.into(), Color::Grey);
            for c in &app.functions[node].calls {
                match colors.get(c.target.as_str()).copied().unwrap_or(Color::White) {
                    Color::Grey => {
                        return Err(Error::Config(format!(
                            "call cycle through `{}` in app `{}`",
                            c.target, app.name
                        )))
                    }
                    Color::White => visit(app, &c.target, colors)?,
                    Color::Black => {}
                }
            }
            colors.insert(node.into(), Color::Black);
            Ok(())
        }
        let mut colors = BTreeMap::new();
        for name in self.functions.keys() {
            if colors.get(name.as_str()).copied().unwrap_or(Color::White) == Color::White {
                visit(self, name, &mut colors)?;
            }
        }
        Ok(())
    }

    pub fn function(&self, name: &str) -> Result<&FunctionSpec> {
        self.functions
            .get(name)
            .ok_or_else(|| Error::NoRoute(name.to_string()))
    }

    pub fn functions(&self) -> impl Iterator<Item = &FunctionSpec> {
        self.functions.values()
    }

    pub fn len(&self) -> usize {
        self.functions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.functions.is_empty()
    }

    /// The theoretical fusion groups (dashed shapes in Figs. 3-4):
    /// connected components of the sync-edge subgraph, restricted to shared
    /// trust domains — what a perfect run of the platform converges to.
    pub fn sync_fusion_groups(&self) -> Vec<Vec<String>> {
        let mut parent: BTreeMap<&str, &str> = BTreeMap::new();
        fn find<'a>(parent: &BTreeMap<&'a str, &'a str>, mut x: &'a str) -> &'a str {
            while parent[x] != x {
                x = parent[x];
            }
            x
        }
        for name in self.functions.keys() {
            parent.insert(name, name);
        }
        for f in self.functions.values() {
            for c in &f.calls {
                if c.mode == CallMode::Sync {
                    let target = &self.functions[&c.target];
                    if target.trust_domain != f.trust_domain {
                        continue;
                    }
                    let ra = find(&parent, f.name.as_str());
                    let rb = find(&parent, c.target.as_str());
                    if ra != rb {
                        parent.insert(ra, rb);
                    }
                }
            }
        }
        let mut groups: BTreeMap<&str, Vec<String>> = BTreeMap::new();
        for name in self.functions.keys() {
            groups.entry(find(&parent, name)).or_default().push(name.clone());
        }
        let mut out: Vec<Vec<String>> = groups.into_values().collect();
        for g in &mut out {
            g.sort();
        }
        out.sort();
        out
    }

    /// Functions whose critical path (sync closure from the entry) includes
    /// them — i.e. they affect end-to-end latency.
    pub fn sync_reachable_from_entry(&self) -> HashSet<String> {
        let mut seen = HashSet::new();
        let mut stack = vec![self.entry.clone()];
        while let Some(f) = stack.pop() {
            if !seen.insert(f.clone()) {
                continue;
            }
            for c in &self.functions[&f].calls {
                if c.mode == CallMode::Sync {
                    stack.push(c.target.clone());
                }
            }
        }
        seen
    }

    /// Graphviz DOT rendering (Figs. 3-4 regeneration:
    /// `provuse apps --graph <name>`).
    pub fn to_dot(&self) -> String {
        let mut out = format!("digraph {} {{\n  rankdir=TB;\n", self.name);
        out.push_str(&format!("  \"{}\" [shape=doublecircle];\n", self.entry));
        for f in self.functions.values() {
            for c in &f.calls {
                let style = match c.mode {
                    CallMode::Sync => "solid",
                    CallMode::Async => "dashed",
                };
                out.push_str(&format!(
                    "  \"{}\" -> \"{}\" [style={style}];\n",
                    f.name, c.target
                ));
            }
        }
        for (i, group) in self.sync_fusion_groups().iter().enumerate() {
            if group.len() > 1 {
                out.push_str(&format!(
                    "  subgraph cluster_{i} {{ style=dashed; label=\"fusion group\"; {} }}\n",
                    group
                        .iter()
                        .map(|g| format!("\"{g}\";"))
                        .collect::<Vec<_>>()
                        .join(" ")
                ));
            }
        }
        out.push_str("}\n");
        out
    }
}

// ---------------------------------------------------------------------------
// builder (public API for custom apps — see examples/custom_app.rs)
// ---------------------------------------------------------------------------

/// Fluent builder for [`AppSpec`].
pub struct AppBuilder {
    name: String,
    entry: Option<String>,
    functions: Vec<FunctionSpec>,
}

impl AppSpec {
    pub fn builder(name: impl Into<String>) -> AppBuilder {
        AppBuilder { name: name.into(), entry: None, functions: Vec::new() }
    }
}

impl AppBuilder {
    /// Add a function; the first one added becomes the entry unless
    /// [`FnBuilder::entry`] marks another.
    pub fn function(self, name: impl Into<String>) -> FnBuilder {
        FnBuilder {
            app: self,
            spec: FunctionSpec {
                name: name.into(),
                body: None,
                busy_ms: 10.0,
                code_mb: 9.0,
                code_kb: 64,
                trust_domain: "default".into(),
                calls: Vec::new(),
            },
            is_entry: false,
        }
    }

    pub fn build(self) -> Result<AppSpec> {
        let entry = self
            .entry
            .clone()
            .or_else(|| self.functions.first().map(|f| f.name.clone()))
            .ok_or_else(|| Error::Config("app has no functions".into()))?;
        AppSpec::new(self.name, entry, self.functions)
    }
}

/// Builder for one function; `done()` returns to the app builder.
pub struct FnBuilder {
    app: AppBuilder,
    spec: FunctionSpec,
    is_entry: bool,
}

impl FnBuilder {
    pub fn entry(mut self) -> Self {
        self.is_entry = true;
        self
    }

    /// Attach an AOT compute body (artifact name from the manifest).
    pub fn body(mut self, artifact: impl Into<String>) -> Self {
        self.spec.body = Some(artifact.into());
        self
    }

    pub fn busy_ms(mut self, ms: f64) -> Self {
        self.spec.busy_ms = ms;
        self
    }

    pub fn code_mb(mut self, mb: f64) -> Self {
        self.spec.code_mb = mb;
        self
    }

    pub fn code_kb(mut self, kb: u64) -> Self {
        self.spec.code_kb = kb;
        self
    }

    pub fn trust_domain(mut self, domain: impl Into<String>) -> Self {
        self.spec.trust_domain = domain.into();
        self
    }

    pub fn sync_call(mut self, target: impl Into<String>) -> Self {
        self.spec.calls.push(CallSpec { target: target.into(), mode: CallMode::Sync, scale: 1.0 });
        self
    }

    pub fn async_call(mut self, target: impl Into<String>) -> Self {
        self.spec.calls.push(CallSpec {
            target: target.into(),
            mode: CallMode::Async,
            scale: 1.0,
        });
        self
    }

    pub fn done(mut self) -> AppBuilder {
        let name = self.spec.name.clone();
        self.app.functions.push(self.spec);
        if self.is_entry {
            self.app.entry = Some(name);
        }
        self.app
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_fn_app() -> AppSpec {
        AppSpec::builder("t")
            .function("a").entry().sync_call("b").done()
            .function("b").done()
            .build()
            .unwrap()
    }

    #[test]
    fn builder_and_accessors() {
        let app = two_fn_app();
        assert_eq!(app.entry, "a");
        assert_eq!(app.len(), 2);
        assert_eq!(app.function("a").unwrap().calls.len(), 1);
        assert!(app.function("zz").is_err());
    }

    #[test]
    fn rejects_dangling_target() {
        let r = AppSpec::builder("t").function("a").sync_call("ghost").done().build();
        assert!(r.is_err());
    }

    #[test]
    fn rejects_self_call() {
        let r = AppSpec::builder("t").function("a").sync_call("a").done().build();
        assert!(r.is_err());
    }

    #[test]
    fn rejects_cycles() {
        let r = AppSpec::builder("t")
            .function("a").entry().sync_call("b").done()
            .function("b").async_call("c").done()
            .function("c").sync_call("a").done()
            .build();
        assert!(r.is_err());
    }

    #[test]
    fn rejects_duplicate_function() {
        let r = AppSpec::builder("t").function("a").done().function("a").done().build();
        assert!(r.is_err());
    }

    #[test]
    fn fusion_groups_follow_sync_edges() {
        let app = AppSpec::builder("t")
            .function("a").entry().sync_call("b").async_call("c").done()
            .function("b").sync_call("d").done()
            .function("c").done()
            .function("d").done()
            .build()
            .unwrap();
        let groups = app.sync_fusion_groups();
        assert!(groups.contains(&vec!["a".into(), "b".into(), "d".into()]));
        assert!(groups.contains(&vec!["c".into()]));
    }

    #[test]
    fn fusion_groups_respect_trust_domains() {
        let app = AppSpec::builder("t")
            .function("a").entry().trust_domain("x").sync_call("b").done()
            .function("b").trust_domain("y").done()
            .build()
            .unwrap();
        let groups = app.sync_fusion_groups();
        assert_eq!(groups.len(), 2);
    }

    #[test]
    fn sync_reachability() {
        let app = AppSpec::builder("t")
            .function("a").entry().sync_call("b").async_call("c").done()
            .function("b").done()
            .function("c").sync_call("d").done()
            .function("d").done()
            .build()
            .unwrap();
        let r = app.sync_reachable_from_entry();
        assert!(r.contains("a") && r.contains("b"));
        assert!(!r.contains("c") && !r.contains("d"));
    }

    #[test]
    fn dot_contains_styles_and_cluster() {
        let dot = two_fn_app().to_dot();
        assert!(dot.contains("style=solid"));
        assert!(dot.contains("cluster_"));
        assert!(dot.contains("doublecircle"));
    }
}
