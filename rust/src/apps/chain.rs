//! Parameterizable N-stage synchronous chain — not from the paper; used by
//! the ablation sweeps (`provuse sweep`) to study how fusion benefit scales
//! with sync-call depth, and by unit tests as a minimal workload.

use super::spec::{AppSpec, CallMode, CallSpec, FunctionSpec};

/// Build a chain `s0 ->sync s1 ->sync ... ->sync s{n-1}`.
pub fn chain(n: usize) -> AppSpec {
    assert!(n >= 1, "chain needs at least one stage");
    let mut functions = Vec::new();
    for i in 0..n {
        let calls = if i + 1 < n {
            vec![CallSpec { target: format!("s{}", i + 1), mode: CallMode::Sync, scale: 1.0 }]
        } else {
            Vec::new()
        };
        functions.push(FunctionSpec {
            name: format!("s{i}"),
            body: Some(if i % 2 == 0 { "tree_light" } else { "parse" }.into()),
            busy_ms: 40.0,
            code_mb: 12.0,
            code_kb: 96,
            trust_domain: "chain".into(),
            calls,
        });
    }
    AppSpec::new("chain", "s0", functions).expect("chain app is statically valid")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chain_structure() {
        let app = chain(4);
        assert_eq!(app.len(), 4);
        assert_eq!(app.entry, "s0");
        assert_eq!(app.function("s0").unwrap().calls[0].target, "s1");
        assert!(app.function("s3").unwrap().calls.is_empty());
    }

    #[test]
    fn whole_chain_is_one_fusion_group() {
        let groups = chain(5).sync_fusion_groups();
        assert_eq!(groups.len(), 1);
        assert_eq!(groups[0].len(), 5);
    }

    #[test]
    fn single_stage_chain() {
        let app = chain(1);
        assert_eq!(app.len(), 1);
        assert!(app.sync_fusion_groups().len() == 1);
    }

    #[test]
    #[should_panic]
    fn zero_stage_chain_panics() {
        chain(0);
    }
}
