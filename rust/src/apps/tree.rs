//! The TREE application from Fusionize++ (paper Fig. 4).
//!
//! > "A synchronously invokes B, which calls D and E, while A also triggers
//! > an asynchronous branch via C to F and G. The asynchronous path
//! > dominates the workload, requiring far more computation than the
//! > synchronous branch."
//!
//! Theoretical fusion groups (dashed in the figure): the synchronous
//! component {A, B, D, E} and the C-side component {C, F, G} (C's own
//! downstream calls are synchronous; only A→C is asynchronous).  busy-time
//! calibration targets the paper's vanilla median of ~452 ms (DESIGN.md §5).

use super::spec::{AppSpec, CallMode, CallSpec, FunctionSpec};

fn f(
    name: &str,
    body: &str,
    busy_ms: f64,
    calls: Vec<(&str, CallMode)>,
) -> FunctionSpec {
    FunctionSpec {
        name: name.into(),
        body: Some(body.into()),
        busy_ms,
        code_mb: 20.0,
        code_kb: 180,
        trust_domain: "tree".into(),
        calls: calls
            .into_iter()
            .map(|(t, mode)| CallSpec { target: t.into(), mode, scale: 1.0 })
            .collect(),
    }
}

/// Build the TREE application.
pub fn tree() -> AppSpec {
    use CallMode::*;
    AppSpec::new(
        "tree",
        "a",
        vec![
            f("a", "tree_light", 60.0, vec![("b", Sync), ("c", Async)]),
            f("b", "tree_light", 110.0, vec![("d", Sync), ("e", Sync)]),
            f("d", "tree_light", 100.0, vec![]),
            f("e", "tree_light", 110.0, vec![]),
            // asynchronous branch: far more computation (heavy bodies)
            f("c", "tree_heavy", 300.0, vec![("f", Sync), ("g", Sync)]),
            f("f", "tree_heavy", 500.0, vec![]),
            f("g", "tree_heavy", 450.0, vec![]),
        ],
    )
    .expect("tree app is statically valid")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_matches_figure4() {
        let app = tree();
        assert_eq!(app.entry, "a");
        assert_eq!(app.len(), 7);
        let a = app.function("a").unwrap();
        assert_eq!(a.calls.len(), 2);
        assert!(a.calls.iter().any(|c| c.target == "b" && c.mode == CallMode::Sync));
        assert!(a.calls.iter().any(|c| c.target == "c" && c.mode == CallMode::Async));
    }

    #[test]
    fn fusion_groups_match_figure4() {
        let groups = tree().sync_fusion_groups();
        assert!(groups.contains(&vec!["a".into(), "b".into(), "d".into(), "e".into()]));
        assert!(groups.contains(&vec!["c".into(), "f".into(), "g".into()]));
        assert_eq!(groups.len(), 2);
    }

    #[test]
    fn async_branch_dominates_compute() {
        let app = tree();
        let sync_busy: f64 = ["a", "b", "d", "e"]
            .iter()
            .map(|n| app.function(n).unwrap().busy_ms)
            .sum();
        let async_busy: f64 = ["c", "f", "g"]
            .iter()
            .map(|n| app.function(n).unwrap().busy_ms)
            .sum();
        assert!(async_busy > 2.0 * sync_busy);
        // and heavy bodies on the async branch
        assert_eq!(app.function("f").unwrap().body.as_deref(), Some("tree_heavy"));
    }

    #[test]
    fn latency_critical_path_excludes_async_branch() {
        let reach = tree().sync_reachable_from_entry();
        assert_eq!(reach.len(), 4);
        assert!(!reach.contains("c"));
    }
}
