//! Crate-wide error type.

use thiserror::Error;

/// Errors produced by the Provuse platform and its substrates.
#[derive(Debug, Error)]
pub enum Error {
    /// A function name was not found in the routing table.
    #[error("no route for function `{0}`")]
    NoRoute(String),

    /// An instance id did not resolve to a live instance.
    #[error("unknown instance `{0}`")]
    UnknownInstance(u64),

    /// An image id did not resolve to a stored image.
    #[error("unknown image `{0}`")]
    UnknownImage(u64),

    /// Lifecycle transition not allowed from the current state.
    #[error("invalid lifecycle transition for instance {instance}: {from} -> {to}")]
    BadTransition {
        instance: u64,
        from: &'static str,
        to: &'static str,
    },

    /// The merger declined or aborted a fusion.
    #[error("fusion aborted: {0}")]
    FusionAborted(String),

    /// Health checks did not pass within the deadline.
    #[error("health check timeout for instance {0}")]
    HealthTimeout(u64),

    /// Artifact loading / PJRT failure.
    #[error("runtime: {0}")]
    Runtime(String),

    /// Compute body unknown to the artifact set.
    #[error("unknown compute body `{0}`")]
    UnknownBody(String),

    /// JSON parse error (hand-rolled parser in `util::json`).
    #[error("json: {0}")]
    Json(String),

    /// Configuration problem.
    #[error("config: {0}")]
    Config(String),

    /// Request failed (dropped, instance terminated mid-flight, ...).
    #[error("request failed: {0}")]
    Request(String),

    #[error(transparent)]
    Io(#[from] std::io::Error),

    /// Error bubbled up from the `xla` crate.
    #[error("xla: {0}")]
    Xla(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;
