//! Crate-wide error type (hand-rolled `Display`/`Error` impls — the
//! zero-dependency build has no `thiserror`).

use std::fmt;

use crate::xla;

/// Errors produced by the Provuse platform and its substrates.
#[derive(Debug)]
pub enum Error {
    /// A function name was not found in the routing table.
    NoRoute(String),

    /// An instance id did not resolve to a live instance.
    UnknownInstance(u64),

    /// An image id did not resolve to a stored image.
    UnknownImage(u64),

    /// Lifecycle transition not allowed from the current state.
    BadTransition {
        instance: u64,
        from: &'static str,
        to: &'static str,
    },

    /// The merger declined or aborted a fusion.
    FusionAborted(String),

    /// The merger declined or aborted a defusion (split).
    SplitAborted(String),

    /// The migrator declined or aborted a live migration.
    MigrationAborted(String),

    /// Health checks did not pass within the deadline.
    HealthTimeout(u64),

    /// Artifact loading / PJRT failure.
    Runtime(String),

    /// Compute body unknown to the artifact set.
    UnknownBody(String),

    /// JSON parse error (hand-rolled parser in `util::json`).
    Json(String),

    /// Configuration problem.
    Config(String),

    /// Request failed (dropped, instance terminated mid-flight, ...).
    Request(String),

    /// I/O error (experiment output files, HTTP front end).
    Io(std::io::Error),

    /// Error bubbled up from the `xla` layer.
    Xla(String),

    /// A worker thread of the threaded simulation core panicked (or the
    /// cohort deadlocked); the epoch gate was poisoned and the run
    /// aborted.  Carries the dying shard and its panic payload.
    ShardPanicked { shard: usize, payload: String },
}

impl Error {
    /// Counter name a dropped request is tagged with (ISSUE 9 drop-cause
    /// tagging: the workload driver bumps this alongside the aggregate
    /// `request_failures`, so `counters_csv` can audit *why* requests
    /// dropped).  Causes map from the error the request path surfaces:
    /// boot health timeouts, fuse/split cutover races (an instance
    /// terminated between routing and dispatch), migration aborts, and
    /// cluster-capacity refusals (scale-from-zero placement failures).
    pub fn drop_cause(&self) -> &'static str {
        match self {
            Error::HealthTimeout(_) => "failed_boot_timeout",
            Error::Request(_) => "failed_cutover_race",
            Error::MigrationAborted(_) => "failed_migration_abort",
            Error::Config(_) => "failed_capacity",
            Error::NoRoute(_) => "failed_no_route",
            _ => "failed_other",
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::NoRoute(name) => write!(f, "no route for function `{name}`"),
            Error::UnknownInstance(id) => write!(f, "unknown instance `{id}`"),
            Error::UnknownImage(id) => write!(f, "unknown image `{id}`"),
            Error::BadTransition { instance, from, to } => write!(
                f,
                "invalid lifecycle transition for instance {instance}: {from} -> {to}"
            ),
            Error::FusionAborted(msg) => write!(f, "fusion aborted: {msg}"),
            Error::SplitAborted(msg) => write!(f, "split aborted: {msg}"),
            Error::MigrationAborted(msg) => write!(f, "migration aborted: {msg}"),
            Error::HealthTimeout(id) => write!(f, "health check timeout for instance {id}"),
            Error::Runtime(msg) => write!(f, "runtime: {msg}"),
            Error::UnknownBody(name) => write!(f, "unknown compute body `{name}`"),
            Error::Json(msg) => write!(f, "json: {msg}"),
            Error::Config(msg) => write!(f, "config: {msg}"),
            Error::Request(msg) => write!(f, "request failed: {msg}"),
            Error::Io(err) => write!(f, "{err}"),
            Error::Xla(msg) => write!(f, "xla: {msg}"),
            Error::ShardPanicked { shard, payload } => {
                write!(f, "shard {shard} panicked: {payload}")
            }
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(err) => Some(err),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

impl From<crate::exec::shard::ShardPanic> for Error {
    fn from(p: crate::exec::shard::ShardPanic) -> Self {
        Error::ShardPanicked { shard: p.shard, payload: p.payload }
    }
}

pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_messages() {
        assert_eq!(Error::NoRoute("f".into()).to_string(), "no route for function `f`");
        assert_eq!(
            Error::BadTransition { instance: 3, from: "Healthy", to: "Terminated" }.to_string(),
            "invalid lifecycle transition for instance 3: Healthy -> Terminated"
        );
        assert_eq!(Error::SplitAborted("x".into()).to_string(), "split aborted: x");
        assert_eq!(
            Error::ShardPanicked { shard: 2, payload: "boom".into() }.to_string(),
            "shard 2 panicked: boom"
        );
    }

    #[test]
    fn shard_panic_converts_from_the_gate_poison() {
        let poison = crate::exec::shard::ShardPanic { shard: 1, payload: "p".into() };
        let err: Error = poison.into();
        assert!(matches!(err, Error::ShardPanicked { shard: 1, .. }));
        assert_eq!(err.drop_cause(), "failed_other");
    }

    #[test]
    fn drop_causes_are_distinct_per_failure_class() {
        assert_eq!(Error::HealthTimeout(1).drop_cause(), "failed_boot_timeout");
        assert_eq!(Error::Request("terminated".into()).drop_cause(), "failed_cutover_race");
        assert_eq!(Error::MigrationAborted("x".into()).drop_cause(), "failed_migration_abort");
        assert_eq!(Error::Config("no node can fit".into()).drop_cause(), "failed_capacity");
        assert_eq!(Error::NoRoute("f".into()).drop_cause(), "failed_no_route");
        assert_eq!(Error::Runtime("r".into()).drop_cause(), "failed_other");
    }

    #[test]
    fn io_errors_convert_and_chain() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let err: Error = io.into();
        assert!(err.to_string().contains("gone"));
        assert!(std::error::Error::source(&err).is_some());
    }

    #[test]
    fn xla_errors_convert() {
        let err: Error = crate::xla::Error("boom".into()).into();
        assert_eq!(err.to_string(), "xla: boom");
    }
}
