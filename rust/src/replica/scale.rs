//! The scale-up engine: where new replicas come from.
//!
//! Two paths, priced very differently (the paper's cold-start motivation):
//!
//! * **warm pool** — a reserve of pre-booted blank instances.  Claiming
//!   one costs only `warm_attach_ms` (code attach: the instance
//!   [`Instance::adopt_image`]s the route's image) instead of a full
//!   container boot; the pool replenishes itself in the background after
//!   each claim.
//! * **cold boot** — place a node via the [`Scheduler`], launch the
//!   route's image, and let arrivals queue on the `Booting` state exactly
//!   like the seed's initial deployment.
//!
//! Every scale-up records a [`crate::metrics::ScaleEvent`] with a `warm`
//! flag and bumps `warm_pool_hits` / `cold_boots`, so the `figure10`
//! experiment can account the two separately.  With `warm_pool = 0`
//! (default) the pool never exists and this engine is only reachable when
//! the autoscaler is armed — the seed path never touches it.

use std::cell::{Cell, RefCell};
use std::rc::Rc;

use crate::cluster::{Cluster, Scheduler};
use crate::config::PlatformConfig;
use crate::containerd::{FsManifest, ImageId, Instance, InstanceState};
use crate::error::{Error, Result};
use crate::exec;
use crate::metrics::{Recorder, ScaleEvent};

use super::ReplicaSet;

/// Replica supplier for the autoscaler and the handler's
/// scale-from-zero path (cheaply clonable via `Rc`).
pub struct Scaler {
    config: Rc<PlatformConfig>,
    cluster: Cluster,
    scheduler: Scheduler,
    metrics: Recorder,
    /// pre-booted blank instances, oldest first
    pool: RefCell<Vec<Rc<Instance>>>,
    /// lazily registered blank image the pool boots from
    warm_image: Cell<Option<ImageId>>,
}

impl Scaler {
    /// A scaler placing replicas through `scheduler`; the warm pool
    /// starts empty until [`Scaler::prewarm`] fills it at deploy time.
    pub fn new(
        config: Rc<PlatformConfig>,
        cluster: Cluster,
        scheduler: Scheduler,
        metrics: Recorder,
    ) -> Rc<Self> {
        Rc::new(Scaler {
            config,
            cluster,
            scheduler,
            metrics,
            pool: RefCell::new(Vec::new()),
            warm_image: Cell::new(None),
        })
    }

    /// Boot `config.scaling.warm_pool` blank instances into the pool
    /// (deploy-time; they come up `Booting` and turn claimable once
    /// healthy).  A no-op at the default pool size 0.
    pub fn prewarm(&self) -> Result<()> {
        for _ in 0..self.config.scaling.warm_pool {
            self.boot_blank()?;
        }
        Ok(())
    }

    /// Pre-booted instances currently parked in the pool (ledger
    /// accounting: their base RAM is real and counts against nodes).
    pub fn pool(&self) -> Vec<Rc<Instance>> {
        self.pool.borrow().clone()
    }

    /// Current warm-pool size (healthy + still-booting blanks).
    pub fn pool_len(&self) -> usize {
        self.pool.borrow().len()
    }

    /// Add one replica to `set`: warm-claim when the pool has a healthy
    /// blank (attach delay only), cold-boot otherwise (full boot latency;
    /// arrivals queue on `Booting` like the seed's initial deployment).
    /// `label` is the route name the scale event is recorded under.
    pub async fn add_replica(
        &self,
        label: &str,
        set: &Rc<ReplicaSet>,
        reason: &'static str,
    ) -> Result<Rc<Instance>> {
        if set.is_retired() {
            return Err(Error::NoRoute(format!(
                "`{label}`: replica set was replaced by a cutover"
            )));
        }
        let image_id = set.image();
        let image = self.cluster.control().image(image_id)?;
        let from = set.live_len() as u32;

        if let Some(warm) = self.claim_warm() {
            exec::sleep_ms(self.config.scaling.warm_attach_ms).await;
            if set.is_retired() {
                // a fuse/split cutover replaced the set while the code
                // attach was in flight: adding now would leak a live
                // instance onto a drained set.  Return the still-blank
                // claim to the pool instead.
                self.pool.borrow_mut().insert(0, warm);
                return Err(Error::NoRoute(format!(
                    "`{label}`: replica set was replaced during warm attach"
                )));
            }
            warm.adopt_image(image);
            set.add(Rc::clone(&warm));
            self.metrics.bump("warm_pool_hits");
            self.record(label, from, set.live_len() as u32, reason, true);
            // keep the reserve warm for the next burst (best effort: a
            // full cluster just leaves the pool smaller)
            let _ = self.boot_blank();
            return Ok(warm);
        }

        let est_mb: f64 = self.config.ram.base_instance_mb
            + image.functions.iter().map(|(_, mb)| mb).sum::<f64>();
        let node = self.scheduler.place(est_mb)?;
        let inst = self.cluster.launch_on(node, image_id)?;
        set.add(Rc::clone(&inst));
        self.metrics.bump("cold_boots");
        self.record(label, from, set.live_len() as u32, reason, false);
        Ok(inst)
    }

    /// Take the oldest healthy blank out of the pool (None while every
    /// pooled instance is still booting, or the pool is empty — the
    /// caller falls back to a cold boot).
    pub fn claim_warm(&self) -> Option<Rc<Instance>> {
        let mut pool = self.pool.borrow_mut();
        let idx = pool.iter().position(|i| i.state() == InstanceState::Healthy)?;
        Some(pool.remove(idx))
    }

    fn boot_blank(&self) -> Result<()> {
        let image = self.warm_image();
        let node = self.scheduler.place(self.config.ram.base_instance_mb)?;
        let inst = self.cluster.launch_on(node, image)?;
        self.pool.borrow_mut().push(inst);
        Ok(())
    }

    fn warm_image(&self) -> ImageId {
        if let Some(id) = self.warm_image.get() {
            return id;
        }
        // a base runtime with no function code: hosts nothing until a
        // claim adopts a real image
        let id = self
            .cluster
            .control()
            .register_image(FsManifest::function_code("__warm", 1), Vec::new());
        self.warm_image.set(Some(id));
        id
    }

    fn record(&self, label: &str, from: u32, to: u32, reason: &'static str, warm: bool) {
        self.metrics.record_scale(ScaleEvent {
            t_ms: self.metrics.rel_now_ms(),
            function: label.to_string(),
            from,
            to,
            reason,
            warm,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PlacementPolicy;
    use crate::exec::{run_virtual, sleep_ms};

    fn scaler_with(warm_pool: usize) -> (Rc<Scaler>, Cluster, Rc<PlatformConfig>) {
        let mut cfg = PlatformConfig::tiny();
        cfg.scaling.warm_pool = warm_pool;
        let config = Rc::new(cfg);
        let cluster = Cluster::new(&config);
        let scheduler = Scheduler::new(PlacementPolicy::Spread, cluster.clone());
        let metrics = Recorder::new();
        (Scaler::new(Rc::clone(&config), cluster.clone(), scheduler, metrics), cluster, config)
    }

    fn route(cluster: &Cluster) -> Rc<ReplicaSet> {
        let img = cluster
            .control()
            .register_image(FsManifest::function_code("f", 16), vec![("f".into(), 9.0)]);
        let inst = cluster.launch_on(crate::cluster::NodeId(0), img).unwrap();
        ReplicaSet::singleton(inst)
    }

    #[test]
    fn warm_claim_attaches_without_a_boot() {
        run_virtual(async {
            let (scaler, cluster, config) = scaler_with(2);
            scaler.prewarm().unwrap();
            assert_eq!(scaler.pool_len(), 2);
            let set = route(&cluster);
            sleep_ms(2_000.0).await; // pool + founder healthy
            let before = crate::exec::now();
            let inst = scaler.add_replica("f", &set, "burst").await.unwrap();
            let took = crate::exec::now().duration_since(before).as_secs_f64() * 1e3;
            assert!(
                (took - config.scaling.warm_attach_ms).abs() < 1e-6,
                "warm claim must cost exactly the attach delay, took {took}"
            );
            // claimed instance serves immediately and hosts the route's code
            assert_eq!(inst.state(), InstanceState::Healthy);
            assert!(inst.hosts("f"));
            assert_eq!(set.live_len(), 2);
            // pool replenished itself in the background
            assert_eq!(scaler.pool_len(), 2);
            assert_eq!(scaler.metrics.counter("warm_pool_hits"), 1);
            assert_eq!(scaler.metrics.counter("cold_boots"), 0);
        });
    }

    #[test]
    fn empty_pool_falls_back_to_cold_boot() {
        run_virtual(async {
            let (scaler, cluster, _config) = scaler_with(0);
            scaler.prewarm().unwrap();
            assert_eq!(scaler.pool_len(), 0);
            let set = route(&cluster);
            sleep_ms(2_000.0).await;
            let inst = scaler.add_replica("f", &set, "burst").await.unwrap();
            // cold boots come up Booting; arrivals queue on the state
            assert_eq!(inst.state(), InstanceState::Booting);
            assert_eq!(set.live_len(), 2);
            assert_eq!(scaler.metrics.counter("cold_boots"), 1);
            assert_eq!(scaler.metrics.counter("warm_pool_hits"), 0);
            sleep_ms(2_000.0).await;
            assert_eq!(inst.state(), InstanceState::Healthy);
        });
    }

    #[test]
    fn booting_pool_is_not_claimable_yet() {
        run_virtual(async {
            let (scaler, _cluster, _config) = scaler_with(1);
            scaler.prewarm().unwrap();
            // no virtual time has passed: the blank is still booting
            assert!(scaler.claim_warm().is_none());
            sleep_ms(2_000.0).await;
            assert!(scaler.claim_warm().is_some());
            assert_eq!(scaler.pool_len(), 0);
        });
    }
}
