//! Replica sets: N load-balanced instances behind one route (ISSUE 6).
//!
//! The seed platform kept exactly one [`Instance`] per deployed function.
//! This module replaces that invariant with a [`ReplicaSet`] per route: the
//! gateway resolves a `Sym` to a set, the set picks a healthy replica with
//! **power-of-two-choices** on in-flight count, and the platform's
//! autoscaler (see [`desired_replicas`] for the policy function) grows and
//! shrinks the set from windowed in-flight and arrival signals — down to
//! zero after an idle horizon, back up on the next arrival (paying the
//! cold-start penalty, or a warm-pool attach when one is available).
//!
//! **Seed parity contract**: a singleton set is an exact no-op. `pick()`
//! returns the sole replica without ever drawing from the balancer RNG, so
//! a config with `replicas_max = 1`, no warm pool, and an unlimited
//! concurrency cap reproduces the pre-replica platform bit for bit — the
//! `figure10` experiment asserts this against the verdict transcript.
//!
//! Fusion interplay: the fuse/split/evict/migrate pipelines treat sets as
//! units — a cutover swaps the whole set atomically in the routing table,
//! a migration replaces one replica at a time via [`ReplicaSet::replace`],
//! and a fused set is sized at the *maximum* of its members' replica
//! counts (the merge planner prices that multiplication; see
//! `fusion::cost::MergeContext::replica_scale`).

mod scale;

pub use scale::Scaler;

use std::cell::{Cell, RefCell};
use std::rc::Rc;

use crate::containerd::{ImageId, Instance, InstanceId, InstanceState};
use crate::util::rng::{splitmix64, Rng};

/// Autoscaler sizing policy: how many replicas a route should run given its
/// current in-flight load and idleness.  Pure so it can be tested (and
/// doctested) without a platform.
///
/// * `inflight / target_inflight` (rounded up) sizes the set,
///   clamped to `[min, max]`;
/// * an idle route (`inflight == 0` for at least `idle_horizon_ms`) scales
///   to **zero**, overriding the floor — the next arrival pays a cold
///   start; `idle_horizon_ms <= 0` disables scale-to-zero entirely.
///
/// ```
/// use provuse::replica::desired_replicas;
/// // 13 in flight at 4 per replica -> ceil(13/4) = 4 replicas
/// assert_eq!(desired_replicas(13, 4, 1, 8, 0.0, 0.0), 4);
/// // a burst beyond the ceiling clamps to `max`
/// assert_eq!(desired_replicas(1_000, 4, 1, 8, 0.0, 0.0), 8);
/// // idle with no horizon configured: hold the floor (seed behavior)
/// assert_eq!(desired_replicas(0, 4, 2, 8, 60_000.0, 0.0), 2);
/// // idle past the horizon: scale to zero, overriding the floor
/// assert_eq!(desired_replicas(0, 4, 2, 8, 60_000.0, 30_000.0), 0);
/// // still idle but horizon not yet reached: floor holds
/// assert_eq!(desired_replicas(0, 4, 2, 8, 10_000.0, 30_000.0), 2);
/// ```
pub fn desired_replicas(
    inflight: u64,
    target_inflight: u32,
    min: u32,
    max: u32,
    idle_ms: f64,
    idle_horizon_ms: f64,
) -> u32 {
    if idle_horizon_ms > 0.0 && inflight == 0 && idle_ms >= idle_horizon_ms {
        return 0;
    }
    let per = target_inflight.max(1) as u64;
    let need = inflight.div_ceil(per) as u32;
    need.clamp(min.max(1), max.max(1))
}

/// N replicas of one deployed (possibly fused) function group behind a
/// single route.  Interior-mutable like everything else in the
/// single-threaded simulation; handed around as `Rc<ReplicaSet>` — the
/// gateway maps every hosted function name of a group to the **same** set,
/// so set identity (`Rc::ptr_eq`) is the "fused together" relation the
/// pipelines check.
///
/// ```
/// use std::rc::Rc;
/// use provuse::config::PlatformConfig;
/// use provuse::containerd::ContainerRuntime;
/// use provuse::replica::ReplicaSet;
///
/// provuse::exec::run_virtual(async {
///     let rt = ContainerRuntime::new(Rc::new(PlatformConfig::tiny()));
///     let img = rt.register_image(
///         provuse::containerd::FsManifest::function_code("f", 16),
///         vec![("f".into(), 9.0)],
///     );
///     let a = rt.launch(img).unwrap();
///     let set = ReplicaSet::new(vec![Rc::clone(&a)], img);
///     // singleton fast path: the sole replica, no RNG draw
///     assert_eq!(set.pick().unwrap().id(), a.id());
///     // a second replica joins; the set tracks aggregate in-flight load
///     let b = rt.launch(img).unwrap();
///     set.add(Rc::clone(&b));
///     a.request_started();
///     a.request_started();
///     assert_eq!(set.total_inflight(), 2);
///     // a draining replica is never picked: cutovers and scale-downs
///     // drain, so traffic deterministically shifts to the survivor
///     a.begin_drain().unwrap();
///     assert_eq!(set.live_len(), 1);
///     assert_eq!(set.pick().unwrap().id(), b.id());
///     a.request_finished();
///     a.request_finished();
/// });
/// ```
pub struct ReplicaSet {
    replicas: RefCell<Vec<Rc<Instance>>>,
    /// image every replica runs (remembered even at zero replicas, so a
    /// scale-from-zero knows what to boot)
    image: Cell<ImageId>,
    /// balancer RNG (power-of-two-choices); seeded deterministically from
    /// the founding replica's id, and never drawn from by singleton sets
    rng: RefCell<Rng>,
    /// virtual-time (ms since executor epoch) of the last routed arrival;
    /// NAN until the first — the autoscaler's idle signal
    last_arrival_ms: Cell<f64>,
    /// a scale-from-zero launch is in flight (collapses the thundering
    /// herd of a burst hitting an empty set into one boot)
    scale_pending: Cell<bool>,
    /// a fuse/split cutover replaced this set in the routing table; its
    /// replicas are draining and it must never grow again (guards the
    /// scale-up-races-cutover window — see [`Scaler::add_replica`])
    retired: Cell<bool>,
}

impl ReplicaSet {
    /// Build a set over `replicas`, all running `image`.  The balancer
    /// seed derives from the first replica's cluster-unique id (or the
    /// image id for an initially empty set), so runs stay reproducible.
    pub fn new(replicas: Vec<Rc<Instance>>, image: ImageId) -> Rc<Self> {
        let mut tag = replicas.first().map(|i| i.id().0).unwrap_or(image.0) ^ 0xC0FFEE;
        let seed = splitmix64(&mut tag);
        Rc::new(ReplicaSet {
            replicas: RefCell::new(replicas),
            image: Cell::new(image),
            rng: RefCell::new(Rng::new(seed)),
            last_arrival_ms: Cell::new(f64::NAN),
            scale_pending: Cell::new(false),
            retired: Cell::new(false),
        })
    }

    /// Convenience: a one-replica set (the seed deployment shape).
    pub fn singleton(instance: Rc<Instance>) -> Rc<Self> {
        let image = instance.image();
        Self::new(vec![instance], image)
    }

    /// The image this set's replicas run (a scale-up boots another one).
    pub fn image(&self) -> ImageId {
        self.image.get()
    }

    /// Pick the replica a new request should go to: among non-draining
    /// live replicas, power-of-two-choices on in-flight count (two uniform
    /// draws of **distinct** replicas, keep the idler; ties keep the
    /// first-drawn).  A singleton set returns its sole replica **without
    /// drawing from the RNG** — the seed-parity fast path.  `None` when no
    /// routable replica exists (scaled to zero, or everything is
    /// draining).
    pub fn pick(&self) -> Option<Rc<Instance>> {
        let replicas = self.replicas.borrow();
        let mut routable = replicas
            .iter()
            .filter(|i| matches!(i.state(), InstanceState::Booting | InstanceState::Healthy));
        let first = routable.next()?;
        let rest: Vec<&Rc<Instance>> = routable.collect();
        if rest.is_empty() {
            return Some(Rc::clone(first));
        }
        let mut candidates = Vec::with_capacity(rest.len() + 1);
        candidates.push(first);
        candidates.extend(rest);
        let n = candidates.len() as u64;
        let mut rng = self.rng.borrow_mut();
        let i = rng.below(n) as usize;
        // Draw the second candidate from the n-1 *others* and offset it
        // past `i`: `i != j` always holds, so the choice never degenerates
        // to a single uniform sample (it used to collide with probability
        // 1/n — worst exactly at the small replica counts the autoscaler
        // lives at).  Still two RNG draws, so seed streams are unchanged.
        let mut j = rng.below(n - 1) as usize;
        if j >= i {
            j += 1;
        }
        let a = candidates[i];
        let b = candidates[j];
        Some(Rc::clone(if b.inflight() < a.inflight() { b } else { a }))
    }

    /// All current replicas, in join order (includes draining ones that
    /// have not yet been removed; callers filter by state as needed).
    pub fn replicas(&self) -> Vec<Rc<Instance>> {
        self.replicas.borrow().clone()
    }

    /// Routable (Booting or Healthy) replicas, in join order.
    pub fn live(&self) -> Vec<Rc<Instance>> {
        self.replicas
            .borrow()
            .iter()
            .filter(|i| matches!(i.state(), InstanceState::Booting | InstanceState::Healthy))
            .cloned()
            .collect()
    }

    /// Count of routable replicas (what the autoscaler sizes against).
    pub fn live_len(&self) -> usize {
        self.replicas
            .borrow()
            .iter()
            .filter(|i| matches!(i.state(), InstanceState::Booting | InstanceState::Healthy))
            .count()
    }

    /// First routable replica — the set's representative for topology
    /// inspection (fs export, hosted-function checks, node affinity).
    pub fn primary(&self) -> Option<Rc<Instance>> {
        self.replicas
            .borrow()
            .iter()
            .find(|i| matches!(i.state(), InstanceState::Booting | InstanceState::Healthy))
            .cloned()
    }

    /// Whether `id` is one of this set's replicas (any state).  The
    /// handler's inline-vs-remote test: a sync call whose target set
    /// contains the calling instance runs in-process.
    pub fn contains(&self, id: InstanceId) -> bool {
        self.replicas.borrow().iter().any(|i| i.id() == id)
    }

    /// Summed in-flight requests across all replicas (the autoscaler's
    /// load signal; queued-for-a-slot requests count — they hold a slot
    /// wait, which is exactly the pressure scale-out relieves).
    pub fn total_inflight(&self) -> u64 {
        self.replicas
            .borrow()
            .iter()
            .map(|i| i.inflight().max(0) as u64)
            .sum()
    }

    /// Add a freshly launched (or warm-claimed) replica.
    pub fn add(&self, instance: Rc<Instance>) {
        self.replicas.borrow_mut().push(instance);
    }

    /// Remove the replica with `id` (scale-down: the caller drains it).
    pub fn remove(&self, id: InstanceId) -> Option<Rc<Instance>> {
        let mut replicas = self.replicas.borrow_mut();
        let idx = replicas.iter().position(|i| i.id() == id)?;
        Some(replicas.remove(idx))
    }

    /// Atomically substitute `fresh` for the replica with `old` — the
    /// migration primitive: the set keeps serving throughout, one replica
    /// moves at a time, and no pick can observe a half-applied swap
    /// (single-threaded executor + this single borrow).  Returns the
    /// replaced replica, or `None` if `old` is no longer a member.
    pub fn replace(&self, old: InstanceId, fresh: Rc<Instance>) -> Option<Rc<Instance>> {
        let mut replicas = self.replicas.borrow_mut();
        let idx = replicas.iter().position(|i| i.id() == old)?;
        Some(std::mem::replace(&mut replicas[idx], fresh))
    }

    /// The scale-down victims: up to `count` routable replicas with the
    /// fewest in-flight requests (ties resolve toward later joiners, so
    /// the founding replica is shed last and the set composition stays
    /// deterministic).
    pub fn drain_candidates(&self, count: usize) -> Vec<Rc<Instance>> {
        let mut live = self.live();
        live.reverse();
        live.sort_by_key(|i| i.inflight());
        live.truncate(count);
        live
    }

    /// Record a routed arrival (the autoscaler's idle clock).
    pub fn note_arrival(&self, t_ms: f64) {
        self.last_arrival_ms.set(t_ms);
    }

    /// Milliseconds since the last routed arrival (`f64::INFINITY` if the
    /// route has never been hit — a never-used function is idle).
    pub fn idle_ms(&self, now_ms: f64) -> f64 {
        let last = self.last_arrival_ms.get();
        if last.is_nan() { f64::INFINITY } else { (now_ms - last).max(0.0) }
    }

    /// Scale-from-zero guard: true while a boot for this empty set is in
    /// flight, so concurrent arrivals wait for it instead of each booting
    /// their own replica.
    pub fn scale_pending(&self) -> bool {
        self.scale_pending.get()
    }

    /// Set/clear the scale-from-zero guard (see [`Self::scale_pending`]).
    pub fn set_scale_pending(&self, pending: bool) {
        self.scale_pending.set(pending);
    }

    /// Mark this set as replaced in the routing table (fuse/split cutover).
    /// A retired set is drained and must never receive another replica: a
    /// scale-up that raced the cutover would otherwise attach a fresh
    /// instance to a dead set and leak it.
    pub fn retire(&self) {
        self.retired.set(true);
    }

    /// Whether a cutover has replaced this set (see [`Self::retire`]).
    pub fn is_retired(&self) -> bool {
        self.retired.get()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PlatformConfig;
    use crate::containerd::{ContainerRuntime, FsManifest};
    use crate::exec::{run_virtual, sleep_ms};

    fn runtime() -> ContainerRuntime {
        ContainerRuntime::new(Rc::new(PlatformConfig::tiny()))
    }

    fn image(rt: &ContainerRuntime, name: &str) -> ImageId {
        rt.register_image(FsManifest::function_code(name, 16), vec![(name.into(), 9.0)])
    }

    #[test]
    fn desired_replicas_policy_edges() {
        // exact multiples round to themselves; the +1 boundary rounds up
        assert_eq!(desired_replicas(8, 4, 1, 10, 0.0, 0.0), 2);
        assert_eq!(desired_replicas(9, 4, 1, 10, 0.0, 0.0), 3);
        // zero in flight holds the floor without a horizon
        assert_eq!(desired_replicas(0, 4, 1, 10, f64::INFINITY, 0.0), 1);
        // scale-to-zero requires BOTH idle-past-horizon and nothing in flight
        assert_eq!(desired_replicas(1, 4, 1, 10, 99_000.0, 30_000.0), 1);
        assert_eq!(desired_replicas(0, 4, 1, 10, 29_999.0, 30_000.0), 1);
        assert_eq!(desired_replicas(0, 4, 1, 10, 30_000.0, 30_000.0), 0);
        // degenerate knobs clamp instead of dividing by zero
        assert_eq!(desired_replicas(5, 0, 0, 0, 0.0, 0.0), 1);
    }

    #[test]
    fn singleton_pick_never_draws_from_the_rng() {
        run_virtual(async {
            let rt = runtime();
            let img = image(&rt, "f");
            let a = rt.launch(img).unwrap();
            let set = ReplicaSet::singleton(Rc::clone(&a));
            let mut probe = set.rng.borrow().clone();
            let rng_before = probe.next_u64();
            for _ in 0..100 {
                assert_eq!(set.pick().unwrap().id(), a.id());
            }
            let mut probe = set.rng.borrow().clone();
            let rng_after = probe.next_u64();
            assert_eq!(rng_before, rng_after, "singleton pick must not consume RNG state");
        });
    }

    #[test]
    fn p2c_prefers_idler_replica_and_skips_draining() {
        run_virtual(async {
            let rt = runtime();
            let img = image(&rt, "f");
            let a = rt.launch(img).unwrap();
            let b = rt.launch(img).unwrap();
            sleep_ms(2_000.0).await; // both healthy
            let set = ReplicaSet::new(vec![Rc::clone(&a), Rc::clone(&b)], img);
            // load a heavily: p2c lands on b far more often than a
            for _ in 0..5 {
                a.request_started();
            }
            let picks_b =
                (0..200).filter(|_| set.pick().unwrap().id() == b.id()).count();
            assert!(picks_b > 150, "p2c must prefer the idle replica: {picks_b}/200");
            for _ in 0..5 {
                a.request_finished();
            }
            // a draining replica never receives a pick
            b.begin_drain().unwrap();
            for _ in 0..50 {
                assert_eq!(set.pick().unwrap().id(), a.id());
            }
            // both gone -> None
            a.begin_drain().unwrap();
            assert!(set.pick().is_none());
            assert_eq!(set.live_len(), 0);
        });
    }

    #[test]
    fn p2c_candidates_never_collide() {
        // The ISSUE 7 distribution test for the i==j sampling bug.  With
        // n = 2 and a strictly less-loaded replica, *collision-free* P2C
        // always compares both replicas and must route every pick to the
        // idler.  The old independent draws collided (i == j) with
        // probability 1/2, sending ~1/4 of picks to the busy replica —
        // ~150/200 here under any seed — so this asserts strictly more
        // than any collided-sample baseline can achieve: all 200.
        run_virtual(async {
            let rt = runtime();
            let img = image(&rt, "f");
            let a = rt.launch(img).unwrap();
            let b = rt.launch(img).unwrap();
            sleep_ms(2_000.0).await; // both healthy
            let set = ReplicaSet::new(vec![Rc::clone(&a), Rc::clone(&b)], img);
            for _ in 0..5 {
                a.request_started();
            }
            let picks_b =
                (0..200).filter(|_| set.pick().unwrap().id() == b.id()).count();
            assert_eq!(
                picks_b, 200,
                "distinct-candidate p2c must always find the idler at n=2: {picks_b}/200"
            );
            for _ in 0..5 {
                a.request_finished();
            }
            // at n=3 the idler still wins whenever it is drawn (2 of 3
            // unordered distinct pairs) — a fixed seed keeps this exact
            let c = rt.launch(img).unwrap();
            sleep_ms(2_000.0).await;
            set.add(Rc::clone(&c));
            for _ in 0..4 {
                a.request_started();
                b.request_started();
            }
            let picks_c =
                (0..300).filter(|_| set.pick().unwrap().id() == c.id()).count();
            // E[picks_c] = 2/3 * 300 = 200; collided draws would pull the
            // expectation down to 5/9 * 300 ≈ 167.  Assert above the
            // collided mean with slack for seed noise.
            assert!(picks_c > 180, "idler must win 2/3 of distinct pairs: {picks_c}/300");
        });
    }

    #[test]
    fn replace_swaps_one_replica_atomically() {
        run_virtual(async {
            let rt = runtime();
            let img = image(&rt, "f");
            let a = rt.launch(img).unwrap();
            let b = rt.launch(img).unwrap();
            let c = rt.launch(img).unwrap();
            let set = ReplicaSet::new(vec![Rc::clone(&a), Rc::clone(&b)], img);
            let swapped = set.replace(a.id(), Rc::clone(&c)).unwrap();
            assert_eq!(swapped.id(), a.id());
            assert!(set.contains(c.id()) && set.contains(b.id()) && !set.contains(a.id()));
            assert_eq!(set.replicas().len(), 2);
            // replacing a non-member is a no-op
            assert!(set.replace(a.id(), Rc::clone(&c)).is_none());
        });
    }

    #[test]
    fn drain_candidates_pick_least_loaded_and_spare_the_founder_on_ties() {
        run_virtual(async {
            let rt = runtime();
            let img = image(&rt, "f");
            let a = rt.launch(img).unwrap();
            let b = rt.launch(img).unwrap();
            let c = rt.launch(img).unwrap();
            let set =
                ReplicaSet::new(vec![Rc::clone(&a), Rc::clone(&b), Rc::clone(&c)], img);
            // all idle: ties shed the newest joiners first, founder last
            let victims = set.drain_candidates(2);
            assert_eq!(
                victims.iter().map(|i| i.id()).collect::<Vec<_>>(),
                vec![c.id(), b.id()]
            );
            // load c: it is no longer the first victim
            c.request_started();
            c.request_started();
            let victims = set.drain_candidates(2);
            assert_eq!(victims[0].id(), b.id());
            assert_eq!(victims[1].id(), a.id());
            c.request_finished();
            c.request_finished();
        });
    }

    #[test]
    fn idle_clock_and_scale_pending_guard() {
        run_virtual(async {
            let rt = runtime();
            let img = image(&rt, "f");
            let set = ReplicaSet::new(vec![rt.launch(img).unwrap()], img);
            assert_eq!(set.idle_ms(5_000.0), f64::INFINITY, "never-hit route is idle");
            set.note_arrival(1_000.0);
            assert_eq!(set.idle_ms(5_000.0), 4_000.0);
            assert!(!set.scale_pending());
            set.set_scale_pending(true);
            assert!(set.scale_pending());
            set.set_scale_pending(false);
            assert!(!set.scale_pending());
        });
    }
}
