//! FIG5 — paper Figure 5: end-to-end latency time series of the IOT
//! application on tinyFaaS, vanilla vs function-fusion deployment, with
//! merge-completion events marked.  The paper reports a 28.9 % median
//! latency reduction (807 ms -> 574 ms) and ~57 % RAM reduction.

use std::path::Path;

use super::{reduction_pct, run_one, write_output, RunResult};
use crate::config::{ComputeMode, PlatformKind, WorkloadConfig};
use crate::error::Result;
use crate::util::stats::fmt_ms;

/// Output of the Figure-5 experiment.
pub struct Fig5 {
    pub vanilla: RunResult,
    pub fusion: RunResult,
}

impl Fig5 {
    pub fn median_reduction_pct(&self) -> f64 {
        reduction_pct(
            self.vanilla.report.latency.median(),
            self.fusion.report.latency.median(),
        )
    }

    pub fn ram_reduction_pct(&self) -> f64 {
        reduction_pct(self.vanilla.ram_mean_mb, self.fusion.ram_mean_mb)
    }

    /// Median latency after the last merge completed (the "optimization
    /// phase concludes" regime the paper describes).  NaN when fewer than
    /// 10 requests arrived post-merge.
    pub fn post_merge_median(&self) -> f64 {
        let last_merge = self
            .fusion
            .merges
            .iter()
            .map(|m| m.t_ms)
            .fold(0.0f64, f64::max);
        let q = crate::util::stats::Quantiles::from_samples(
            self.fusion
                .latency_series
                .iter()
                .filter(|s| s.t_ms > last_merge)
                .map(|s| s.latency_ms)
                .collect(),
        );
        if q.len() < 10 {
            f64::NAN
        } else {
            q.median()
        }
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str("FIG5: IOT on tinyFaaS — latency time series (paper Fig. 5)\n");
        out.push_str(&format!(
            "  vanilla : {}\n  fusion  : {}\n",
            self.vanilla.report.summary(),
            self.fusion.report.summary()
        ));
        out.push_str(&format!(
            "  merges  : {} completed at t = [{}]\n",
            self.fusion.merges.len(),
            self.fusion
                .merges
                .iter()
                .map(|m| format!("{:.1}s", m.t_ms / 1e3))
                .collect::<Vec<_>>()
                .join(", ")
        ));
        out.push_str(&format!(
            "  median reduction: {:.1}%   (paper: 28.9%, 807 ms -> 574 ms)\n",
            self.median_reduction_pct()
        ));
        out.push_str(&format!(
            "  post-merge median: {}   (run-wide fusion median: {})\n",
            fmt_ms(self.post_merge_median()),
            fmt_ms(self.fusion.report.latency.median())
        ));
        out.push_str(&format!(
            "  RAM reduction: {:.1}%   (paper: ~57%)\n",
            self.ram_reduction_pct()
        ));
        out
    }
}

/// Run the experiment and write `fig5_vanilla.csv`, `fig5_fusion.csv`,
/// `fig5_merges.csv`, and `fig5_summary.txt` into `out_dir`.
pub fn run(out_dir: &Path, wl: WorkloadConfig, compute: ComputeMode) -> Result<Fig5> {
    // RecordingLevel::Full on purpose (ISSUE 7 recording audit): fig5's
    // whole output IS the raw per-request latency series CSV plus the
    // post-merge median — both Full-only.  Windowed recording would write
    // empty CSVs.  Drivers without raw exports (fig6, sweeps) run Windowed.
    let vanilla = run_one(PlatformKind::Tiny, "iot", false, wl.clone(), compute)?;
    let fusion = run_one(PlatformKind::Tiny, "iot", true, wl, compute)?;
    let fig = Fig5 { vanilla, fusion };

    let series = |r: &RunResult| {
        let mut csv = String::from("t_ms,latency_ms\n");
        for s in &r.latency_series {
            csv.push_str(&format!("{:.3},{:.3}\n", s.t_ms, s.latency_ms));
        }
        csv
    };
    write_output(&out_dir.join("fig5_vanilla.csv"), &series(&fig.vanilla))?;
    write_output(&out_dir.join("fig5_fusion.csv"), &series(&fig.fusion))?;
    let mut merges = String::from("t_ms,duration_ms,functions\n");
    for m in &fig.fusion.merges {
        merges.push_str(&format!(
            "{:.3},{:.3},{}\n",
            m.t_ms,
            m.duration_ms,
            m.functions.join("+")
        ));
    }
    write_output(&out_dir.join("fig5_merges.csv"), &merges)?;
    write_output(&out_dir.join("fig5_summary.txt"), &fig.render())?;
    Ok(fig)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig5_shape_holds_at_small_scale() {
        let wl = WorkloadConfig { requests: 600, rate_rps: 10.0, seed: 2, timeout_ms: 60_000.0 };
        let dir = std::env::temp_dir().join("provuse_fig5_test");
        let fig = run(&dir, wl, ComputeMode::Disabled).unwrap();
        // fusion completes merges and wins on both axes
        assert!(!fig.fusion.merges.is_empty());
        assert!(fig.median_reduction_pct() > 0.0, "{}", fig.render());
        assert!(fig.ram_reduction_pct() > 0.0, "{}", fig.render());
        // post-merge regime is at least as fast as the run-wide median
        let pm = fig.post_merge_median();
        assert!(
            pm.is_nan() || pm <= fig.fusion.report.latency.median() * 1.05,
            "post-merge {pm} vs {}",
            fig.fusion.report.latency.median()
        );
        assert!(dir.join("fig5_vanilla.csv").exists());
        assert!(dir.join("fig5_merges.csv").exists());
        let summary = fig.render();
        assert!(summary.contains("median reduction"));
    }
}
