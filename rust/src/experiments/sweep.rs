//! ABL-RATE / ABL-HOP / ABL-POLICY — ablation sweeps (ours, motivated by
//! DESIGN.md §4): sensitivity of the paper's latency claim to request rate,
//! per-hop overhead, and fusion-policy knobs.

use std::path::Path;

use super::{reduction_pct, write_output, RunResult};
use crate::apps::{self, AppSpec};
use crate::config::{ComputeMode, PlatformConfig, WorkloadConfig};
use crate::error::Result;
use crate::exec::{Executor, Mode};
use crate::platform::Platform;
use crate::workload::{self, Arrival};

/// One sweep point.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub x: f64,
    pub label: String,
    pub vanilla_median_ms: f64,
    pub fusion_median_ms: f64,
    pub reduction_pct: f64,
    pub merges: usize,
}

/// A completed sweep.
pub struct Sweep {
    pub dim: String,
    pub points: Vec<SweepPoint>,
}

impl Sweep {
    pub fn to_csv(&self) -> String {
        let mut out =
            String::from("x,label,vanilla_median_ms,fusion_median_ms,reduction_pct,merges\n");
        for p in &self.points {
            out.push_str(&format!(
                "{},{},{:.3},{:.3},{:.2},{}\n",
                p.x, p.label, p.vanilla_median_ms, p.fusion_median_ms, p.reduction_pct, p.merges
            ));
        }
        out
    }

    pub fn render(&self) -> String {
        let mut out = format!("ABL-{}: fusion benefit sweep\n", self.dim.to_uppercase());
        out.push_str("|     point | vanilla | fusion | reduction | merges |\n");
        out.push_str("|-----------|--------:|-------:|----------:|-------:|\n");
        for p in &self.points {
            out.push_str(&format!(
                "| {:>9} | {:6.0}  | {:5.0}  | {:8.1}% | {:6} |\n",
                p.label, p.vanilla_median_ms, p.fusion_median_ms, p.reduction_pct, p.merges
            ));
        }
        out
    }
}

/// Like `experiments::run_custom` but under an explicit arrival process.
///
/// Runs under **windowed** recording (ISSUE 7): sweep points consume only
/// workload-side latency medians and merge counts — both level-independent
/// — so the ablation grid never pays Full's O(requests) recorder memory.
fn run_arrival(
    app: AppSpec,
    mut config: PlatformConfig,
    wl: WorkloadConfig,
    arrival: Arrival,
) -> Result<RunResult> {
    let kind = config.kind;
    let fusion = config.fusion.enabled;
    let app_name = app.name.clone();
    config.recording.level = crate::metrics::RecordingLevel::Windowed;
    Executor::new(Mode::Virtual).block_on(async move {
        let platform = Platform::deploy(app, config).await?;
        let report =
            workload::run_with_arrival(std::rc::Rc::clone(&platform), wl, arrival).await?;
        crate::exec::sleep_ms(10_000.0).await;
        platform.shutdown();
        let m = &platform.metrics;
        Ok(RunResult {
            platform: kind,
            app: app_name,
            fusion,
            latency_series: m.latencies(),
            ram_series: m.ram_series(),
            merges: m.merges(),
            splits: m.splits(),
            ram_mean_mb: m.ram_mean_mb(),
            final_instances: platform.containers.live_count(),
            inline_calls: m.counter("inline_calls"),
            remote_sync_calls: m.counter("remote_sync_calls"),
            bill: platform.billing.bill(),
            report,
        })
    })
}

fn point_app(
    label: String,
    x: f64,
    base: PlatformConfig,
    wl: WorkloadConfig,
    app: &AppSpec,
    arrival: Arrival,
) -> Result<SweepPoint> {
    let vanilla = run_arrival(app.clone(), base.clone().vanilla(), wl.clone(), arrival.clone())?;
    let fusion = run_arrival(app.clone(), base, wl, arrival)?;
    Ok(SweepPoint {
        x,
        label,
        vanilla_median_ms: vanilla.report.latency.median(),
        fusion_median_ms: fusion.report.latency.median(),
        reduction_pct: reduction_pct(
            vanilla.report.latency.median(),
            fusion.report.latency.median(),
        ),
        merges: fusion.merges.len(),
    })
}

fn point(
    label: String,
    x: f64,
    base: PlatformConfig,
    wl: WorkloadConfig,
    app: &str,
) -> Result<SweepPoint> {
    point_app(label, x, base, wl, &apps::by_name(app)?, Arrival::Constant)
}

/// ABL-RATE: request-rate sweep on IOT/tiny.
pub fn rate_sweep(requests: u64, compute: ComputeMode) -> Result<Sweep> {
    let mut points = Vec::new();
    for rate in [1.0, 2.0, 5.0, 10.0, 20.0, 50.0] {
        let wl = WorkloadConfig { requests, rate_rps: rate, seed: 11, timeout_ms: 120_000.0 };
        let cfg = PlatformConfig::tiny().with_compute(compute);
        points.push(point(format!("{rate} rps"), rate, cfg, wl, "iot")?);
    }
    Ok(Sweep { dim: "rate".into(), points })
}

/// ABL-HOP: per-hop (dispatch) overhead sweep on IOT/tiny.
pub fn hop_sweep(requests: u64, compute: ComputeMode) -> Result<Sweep> {
    let mut points = Vec::new();
    for hop_ms in [1.0, 5.0, 10.0, 25.0, 50.0] {
        let wl = WorkloadConfig { requests, rate_rps: 5.0, seed: 12, timeout_ms: 120_000.0 };
        let mut cfg = PlatformConfig::tiny().with_compute(compute);
        cfg.latency.dispatch_ms = hop_ms;
        points.push(point(format!("{hop_ms} ms"), hop_ms, cfg, wl, "iot")?);
    }
    Ok(Sweep { dim: "hop".into(), points })
}

/// ABL-POLICY: fusion policy ablation on IOT/tiny.
pub fn policy_sweep(requests: u64, compute: ComputeMode) -> Result<Sweep> {
    let wl = WorkloadConfig { requests, rate_rps: 5.0, seed: 13, timeout_ms: 120_000.0 };
    let mut points = Vec::new();
    type Tweak = Box<dyn Fn(&mut PlatformConfig)>;
    let variants: Vec<(&str, Tweak)> = vec![
        ("default", Box::new(|_| {})),
        ("thresh=1", Box::new(|c| c.fusion.min_observations = 1)),
        ("thresh=25", Box::new(|c| c.fusion.min_observations = 25)),
        ("no-trans", Box::new(|c| c.fusion.transitive = false)),
        ("max-grp=2", Box::new(|c| c.fusion.max_group_size = 2)),
    ];
    for (i, (label, tweak)) in variants.iter().enumerate() {
        let mut cfg = PlatformConfig::tiny().with_compute(compute);
        tweak(&mut cfg);
        points.push(point(label.to_string(), i as f64, cfg, wl.clone(), "iot")?);
    }
    Ok(Sweep { dim: "policy".into(), points })
}

/// ABL-DEPTH: fusion benefit vs sync-chain depth.
pub fn depth_sweep(requests: u64, compute: ComputeMode) -> Result<Sweep> {
    let mut points = Vec::new();
    for depth in [2usize, 3, 4, 6, 8] {
        let wl = WorkloadConfig { requests, rate_rps: 5.0, seed: 14, timeout_ms: 120_000.0 };
        let cfg = PlatformConfig::tiny().with_compute(compute);
        let app = apps::chain(depth);
        points.push(point_app(
            format!("depth {depth}"),
            depth as f64,
            cfg,
            wl,
            &app,
            Arrival::Constant,
        )?);
    }
    Ok(Sweep { dim: "depth".into(), points })
}

/// ABL-ARRIVAL: fusion benefit under different arrival processes
/// (constant / Poisson / bursty — paper §6 motivates pre-warming for
/// bursty workloads).
pub fn arrival_sweep(requests: u64, compute: ComputeMode) -> Result<Sweep> {
    let mut points = Vec::new();
    let arrivals = [
        ("constant", Arrival::Constant),
        ("poisson", Arrival::Poisson),
        ("burst", Arrival::Burst { period_s: 30.0, burst_factor: 4.0 }),
    ];
    for (i, (label, arrival)) in arrivals.iter().enumerate() {
        let wl = WorkloadConfig { requests, rate_rps: 5.0, seed: 15, timeout_ms: 120_000.0 };
        let cfg = PlatformConfig::tiny().with_compute(compute);
        points.push(point_app(
            label.to_string(),
            i as f64,
            cfg,
            wl,
            &apps::iot(),
            arrival.clone(),
        )?);
    }
    Ok(Sweep { dim: "arrival".into(), points })
}

/// Run one sweep dimension by name and write its CSV + table.
pub fn run(dim: &str, out_dir: &Path, requests: u64, compute: ComputeMode) -> Result<Sweep> {
    let sweep = match dim {
        "rate" => rate_sweep(requests, compute)?,
        "hop" => hop_sweep(requests, compute)?,
        "policy" => policy_sweep(requests, compute)?,
        "depth" => depth_sweep(requests, compute)?,
        "arrival" => arrival_sweep(requests, compute)?,
        other => {
            return Err(crate::error::Error::Config(format!(
                "unknown sweep dim `{other}` (rate|hop|policy|depth|arrival)"
            )))
        }
    };
    write_output(&out_dir.join(format!("sweep_{dim}.csv")), &sweep.to_csv())?;
    write_output(&out_dir.join(format!("sweep_{dim}.md")), &sweep.render())?;
    Ok(sweep)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hop_sweep_reduction_grows_with_overhead() {
        // Small-scale variant with fast merge plumbing so the post-merge
        // regime dominates the run (the full-scale sweep is `provuse sweep`).
        let mk = |hop_ms: f64| {
            let mut cfg = PlatformConfig::tiny().with_compute(ComputeMode::Disabled);
            cfg.latency.dispatch_ms = hop_ms;
            cfg.latency.image_build_ms = 200.0;
            cfg.latency.boot_ms = 100.0;
            cfg.fusion.min_observations = 1;
            cfg
        };
        let wl = WorkloadConfig { requests: 300, rate_rps: 20.0, seed: 12, timeout_ms: 120_000.0 };
        let cheap = point("1ms".into(), 1.0, mk(1.0), wl.clone(), "iot").unwrap();
        let dear = point("50ms".into(), 50.0, mk(50.0), wl, "iot").unwrap();
        assert!(
            dear.reduction_pct > cheap.reduction_pct,
            "cheap {:?} vs dear {:?}",
            cheap,
            dear
        );
        assert!(dear.merges > 0);
    }

    #[test]
    fn policy_no_transitive_merges_less() {
        let sweep = policy_sweep(80, ComputeMode::Disabled).unwrap();
        let default = &sweep.points[0];
        let no_trans = sweep.points.iter().find(|p| p.label == "no-trans").unwrap();
        assert!(no_trans.merges <= default.merges);
        // and yields less benefit on a deep-sync app
        assert!(no_trans.reduction_pct <= default.reduction_pct + 1.0);
    }

    #[test]
    fn unknown_dim_errors() {
        let dir = std::env::temp_dir();
        assert!(run("nope", &dir, 10, ComputeMode::Disabled).is_err());
    }
}
