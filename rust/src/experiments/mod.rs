//! Paper experiment drivers (DESIGN.md §4 experiment index).
//!
//! Every table and figure in the paper's evaluation maps to a driver here:
//!
//! | id      | artifact                     | driver                |
//! |---------|------------------------------|-----------------------|
//! | FIG3/4  | call graphs                  | `apps::*::to_dot()`   |
//! | FIG5    | IOT/tinyFaaS latency series  | [`fig5`]              |
//! | FIG6    | median latency, 4 configs    | [`fig6`]              |
//! | TAB-LAT | §5.2 median latencies        | [`fig6`] (table form) |
//! | TAB-RAM | §5.2 RAM reductions          | [`fig6`] (RAM columns)|
//! | ABL-*   | ours: rate/hop/policy sweeps | [`sweep`]             |
//! | FIG7    | ours: fuse ∧ split feedback  | [`fig7`]              |
//! | FIG8    | ours: multi-node cluster     | [`fig8`]              |
//! | FIG9    | ours: telemetry @ 10⁶ reqs   | [`fig9`]              |
//! | FIG10   | ours: replica sets + warm pool under burst | [`fig10`] |
//! | FIG11   | ours: greedy vs global re-planning A/B     | [`fig11`] |
//! | FIG12   | ours: exact span-level latency attribution | [`fig12`] |

pub mod fig10;
pub mod fig11;
pub mod fig12;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod fig8;
pub mod fig9;
pub mod sweep;

use std::rc::Rc;

use crate::apps;
use crate::billing::Bill;
use crate::config::{ComputeMode, PlatformConfig, PlatformKind, WorkloadConfig};
use crate::error::Result;
use crate::exec::{Executor, Mode};
use crate::metrics::{LatencySample, MergeEvent, RamSample, SplitEvent};
use crate::platform::Platform;
use crate::workload::{self, WorkloadReport};

/// One platform x app x deployment-mode benchmark run.
#[derive(Debug, Clone)]
pub struct RunResult {
    pub platform: PlatformKind,
    pub app: String,
    pub fusion: bool,
    pub report: WorkloadReport,
    pub latency_series: Vec<LatencySample>,
    pub ram_series: Vec<RamSample>,
    pub merges: Vec<MergeEvent>,
    pub splits: Vec<SplitEvent>,
    /// time-weighted mean platform RAM over the whole run (MiB)
    pub ram_mean_mb: f64,
    /// instances alive at the end of the run
    pub final_instances: usize,
    pub inline_calls: u64,
    pub remote_sync_calls: u64,
    /// aggregate provider bill (invocations + GiB-seconds)
    pub bill: Bill,
    /// per-window latency-breakdown ledger, when the tracer was armed
    pub trace_breakdown_csv: Option<String>,
    /// Chrome trace-event JSON of the retained traces, when armed
    pub trace_chrome_json: Option<String>,
    /// traces whose critical path failed to sum to the recorded latency
    pub trace_violations: u64,
}

impl RunResult {
    pub fn label(&self) -> String {
        format!(
            "{}/{}/{}",
            self.platform.name(),
            self.app,
            if self.fusion { "fusion" } else { "vanilla" }
        )
    }
}

/// Execute one benchmark run on a fresh virtual-clock executor (Full
/// recording — the drivers whose CSV exports need every raw sample).
pub fn run_one(
    kind: PlatformKind,
    app_name: &str,
    fusion: bool,
    wl: WorkloadConfig,
    compute: ComputeMode,
) -> Result<RunResult> {
    run_one_at(kind, app_name, fusion, wl, compute, crate::metrics::RecordingLevel::Full)
}

/// [`run_one`] at an explicit recording level.  Drivers that never read
/// the Full-only raw series (fig6's tables, the sweeps) pass
/// [`RecordingLevel::Windowed`](crate::metrics::RecordingLevel) for
/// bounded recorder memory; every number they consume — workload-side
/// latencies, the incremental `ram_mean_mb`, event series, billing
/// totals — is bit-identical across levels (`tests/recording_parity.rs`).
pub fn run_one_at(
    kind: PlatformKind,
    app_name: &str,
    fusion: bool,
    wl: WorkloadConfig,
    compute: ComputeMode,
    level: crate::metrics::RecordingLevel,
) -> Result<RunResult> {
    let app = apps::by_name(app_name)?;
    let mut config = PlatformConfig::of_kind(kind).with_compute(compute).with_recording(level);
    if !fusion {
        config = config.vanilla();
    }
    run_custom(app, config, wl)
}

/// Execute a benchmark run with a fully custom platform config (sweeps).
pub fn run_custom(
    app: apps::AppSpec,
    mut config: PlatformConfig,
    wl: WorkloadConfig,
) -> Result<RunResult> {
    let kind = config.kind;
    let fusion = config.fusion.enabled;
    let app_name = app.name.clone();
    // Under windowed recording, grow the retention horizon to span the
    // whole run (ring memory is O(buckets) regardless): whole-run
    // aggregates served off the bounded ledgers — the TAB-COST bill —
    // then cover every event, not just a trailing window.
    if config.recording.level == crate::metrics::RecordingLevel::Windowed {
        let span_ms = if wl.rate_rps > 0.0 {
            wl.requests as f64 / wl.rate_rps * 1e3
        } else {
            0.0
        };
        config.recording.ensure_retention_ms(span_ms + wl.timeout_ms + 60_000.0);
    }
    let shards = config.cluster.shards.max(1);
    Executor::sharded(Mode::Virtual, shards).block_on(async move {
        let platform = Platform::deploy(app, config).await?;
        let report = workload::run(Rc::clone(&platform), wl).await?;
        // let stragglers (async branches, drains) settle before sampling ends
        crate::exec::sleep_ms(10_000.0).await;
        platform.shutdown();
        let m = &platform.metrics;
        Ok(RunResult {
            platform: kind,
            app: app_name,
            fusion,
            latency_series: m.latencies(),
            ram_series: m.ram_series(),
            merges: m.merges(),
            splits: m.splits(),
            ram_mean_mb: m.ram_mean_mb(),
            final_instances: platform.containers.live_count(),
            inline_calls: m.counter("inline_calls"),
            remote_sync_calls: m.counter("remote_sync_calls"),
            bill: platform.billing.bill(),
            trace_breakdown_csv: platform
                .tracer
                .enabled()
                .then(|| platform.tracer.latency_breakdown_csv()),
            trace_chrome_json: platform
                .tracer
                .enabled()
                .then(|| platform.tracer.chrome_trace_json()),
            trace_violations: platform.tracer.conservation_violations(),
            report,
        })
    })
}

/// Percentage reduction from `vanilla` to `fused` (positive = improvement).
pub fn reduction_pct(vanilla: f64, fused: f64) -> f64 {
    if vanilla <= 0.0 {
        return f64::NAN;
    }
    (vanilla - fused) / vanilla * 100.0
}

/// Write a file, creating parent directories.
pub fn write_output(path: &std::path::Path, contents: &str) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, contents)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reduction_math() {
        assert!((reduction_pct(800.0, 600.0) - 25.0).abs() < 1e-9);
        assert!((reduction_pct(100.0, 110.0) + 10.0).abs() < 1e-9);
        assert!(reduction_pct(0.0, 1.0).is_nan());
    }

    #[test]
    fn run_one_smoke_vanilla_vs_fusion() {
        // small workload, no PJRT dependency
        let wl = WorkloadConfig { requests: 60, rate_rps: 10.0, seed: 5, timeout_ms: 60_000.0 };
        let v = run_one(PlatformKind::Tiny, "chain", false, wl.clone(), ComputeMode::Disabled)
            .unwrap();
        let f =
            run_one(PlatformKind::Tiny, "chain", true, wl, ComputeMode::Disabled).unwrap();
        assert_eq!(v.report.failed, 0);
        assert_eq!(f.report.failed, 0);
        assert!(v.merges.is_empty());
        assert!(!f.merges.is_empty());
        assert!(f.inline_calls > 0);
        // fusion must win on latency and RAM for a pure sync chain
        assert!(f.report.latency.median() < v.report.latency.median());
        assert!(f.ram_mean_mb < v.ram_mean_mb);
        assert!(f.final_instances < v.final_instances);
    }

    #[test]
    fn run_one_is_deterministic() {
        let wl = WorkloadConfig { requests: 30, rate_rps: 10.0, seed: 9, timeout_ms: 60_000.0 };
        let a = run_one(PlatformKind::Kube, "chain", true, wl.clone(), ComputeMode::Disabled)
            .unwrap();
        let b =
            run_one(PlatformKind::Kube, "chain", true, wl, ComputeMode::Disabled).unwrap();
        assert_eq!(a.report.latency.median(), b.report.latency.median());
        assert_eq!(a.merges.len(), b.merges.len());
        assert_eq!(a.ram_mean_mb, b.ram_mean_mb);
    }
}
