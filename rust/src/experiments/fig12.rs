//! FIG12 (ours) — the tracing self-check (ISSUE 9): mechanize the paper's
//! central latency claim with *exact* span arithmetic.
//!
//! Two arms run the same chain app on a jitter-free fabric (every hop cost
//! a deterministic constant): **unfused** (vanilla deployment, every call a
//! remote hop) and **fused** (the platform fuses the chain into one
//! instance, interior calls inlined).  Both arms trace every measured
//! request at `sample_every = 1`, then the driver asserts — in integer
//! virtual-clock nanoseconds, no tolerances — that
//!
//! 1. every measured trace is well-formed and **conserved** (its critical
//!    path sums bit-for-bit to the measured e2e latency,
//!    [`crate::trace::verify`]);
//! 2. handler self-time is *preserved* across arms (fusion does not touch
//!    the work, only the plumbing);
//! 3. the measured e2e delta **equals** the eliminated remote-envelope
//!    span components (gateway, service indirection, network, cross-node,
//!    serialization, dispatch) minus the added inline hops:
//!    `e2e_unfused - e2e_fused == eliminated - added`.
//!
//! That identity is the paper's Fig. 1 story ("fusion removes the
//! inter-function overhead, nothing else") as a machine-checked equation
//! rather than a before/after bar chart.  The companion allocation claim —
//! the resolved-request hot path performs zero heap allocations with
//! sampling off and O(spans) with it on — is asserted by
//! `benches/trace_overhead.rs` (a counting `#[global_allocator]` must own
//! the whole binary, so it lives in a bench target, which CI runs).

use std::path::Path;
use std::rc::Rc;

use super::write_output;
use crate::apps;
use crate::config::{ComputeMode, PlatformConfig};
use crate::error::Result;
use crate::exec::{self, Executor, Mode};
use crate::platform::Platform;
use crate::trace::{SpanKind, Trace};
use crate::util::intern::Sym;
use crate::workload::request_payload;

/// FIG12 knobs (CLI + smoke test share the driver).
#[derive(Debug, Clone, Copy)]
pub struct Fig12Params {
    pub chain_len: usize,
    /// traced requests measured per arm (sequential, steady-state)
    pub measured: u64,
    /// untraced warmup requests per arm (boot + fusion transients)
    pub warmup: u64,
    pub seed: u64,
}

impl Fig12Params {
    pub fn defaults(smoke: bool) -> Self {
        Fig12Params {
            chain_len: 3,
            measured: if smoke { 6 } else { 24 },
            warmup: 6,
            seed: 13,
        }
    }
}

/// One completed arm: the measured traces plus their exports.
pub struct Fig12Arm {
    pub label: &'static str,
    /// e2e of a measured request in integer virtual ns (constant across
    /// the arm on the jitter-free fabric; asserted)
    pub e2e_ns: u64,
    pub merges: usize,
    pub conservation_violations: u64,
    pub traces: Vec<Trace>,
    pub breakdown_csv: String,
    pub chrome_json: String,
}

pub struct Fig12 {
    pub params: Fig12Params,
    pub unfused: Fig12Arm,
    pub fused: Fig12Arm,
    /// measured e2e delta (unfused - fused), integer ns
    pub delta_ns: i128,
    /// remote-envelope span ns the fused arm no longer pays
    pub eliminated_ns: i128,
    /// inline-hop span ns the fused arm newly pays
    pub added_inline_ns: i128,
    pub checks: Vec<(String, bool)>,
}

/// Remote-envelope component kinds — the spans fusion eliminates.
const ENVELOPE_KINDS: [SpanKind; 6] = [
    SpanKind::Gateway,
    SpanKind::ServiceIndirection,
    SpanKind::Network,
    SpanKind::CrossNode,
    SpanKind::Serialize,
    SpanKind::Dispatch,
];

/// Stall kinds that must not appear in a steady-state measured trace.
const STALL_KINDS: [SpanKind; 3] =
    [SpanKind::ColdWait, SpanKind::GateQueue, SpanKind::CutoverStall];

/// Total ns of `kind` spans in one trace.
pub fn kind_ns(trace: &Trace, kind: SpanKind) -> u128 {
    trace
        .spans
        .iter()
        .filter(|s| s.kind == kind)
        .map(|s| s.duration_ns() as u128)
        .sum()
}

fn e2e_ns(trace: &Trace) -> u64 {
    trace.spans.first().map(|s| s.duration_ns()).unwrap_or(0)
}

impl Fig12 {
    pub fn passed(&self) -> bool {
        self.checks.iter().all(|(_, ok)| *ok)
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "FIG12: exact latency attribution — chain({}), {} measured requests/arm, \
             jitter-free fabric\n",
            self.params.chain_len, self.params.measured
        ));
        out.push_str("  component      unfused_ms     fused_ms\n");
        let u = &self.unfused.traces[0];
        let f = &self.fused.traces[0];
        for kind in ENVELOPE_KINDS
            .iter()
            .chain([SpanKind::Inline, SpanKind::SelfTime].iter())
        {
            out.push_str(&format!(
                "  {:<14} {:>10.3} {:>12.3}\n",
                kind.name(),
                kind_ns(u, *kind) as f64 / 1e6,
                kind_ns(f, *kind) as f64 / 1e6,
            ));
        }
        out.push_str(&format!(
            "  e2e            {:>10.3} {:>12.3}\n",
            self.unfused.e2e_ns as f64 / 1e6,
            self.fused.e2e_ns as f64 / 1e6,
        ));
        out.push_str(&format!(
            "  delta = {} ns, eliminated envelope = {} ns, added inline = {} ns\n",
            self.delta_ns, self.eliminated_ns, self.added_inline_ns
        ));
        for (name, ok) in &self.checks {
            out.push_str(&format!("  [{}] {}\n", if *ok { "PASS" } else { "FAIL" }, name));
        }
        out
    }
}

/// Jitter-free arm config: every random hop cost is pinned to a constant
/// (zero sigma — or zero mean where the sigma is derived from it), so the
/// span arithmetic below is exact integer comparison, not statistics.
fn config(p: &Fig12Params, fused: bool) -> PlatformConfig {
    let mut cfg =
        PlatformConfig::tiny().with_compute(ComputeMode::Disabled).with_seed(p.seed);
    // gateway jitter is hardwired to 0.1x the mean — zero the mean to get
    // a deterministic (zero-cost) gateway hop; the other hops have
    // explicit sigma knobs
    cfg.latency.gateway_ms = 0.0;
    cfg.latency.net_sigma = 0.0;
    cfg.latency.dispatch_sigma = 0.0;
    cfg.latency.cross_node_sigma = 0.0;
    // dyadic rational: both the inline hop (0.0625 ms) and the exec frame
    // it nests in convert to integer ns without rounding, so inline-frame
    // self-time matches the unfused arm bit-for-bit (0.05 would lose 1 ns)
    cfg.latency.inline_call_ms = 0.0625;
    // fast pipelines so the fused arm converges within the warmup budget
    cfg.latency.image_build_ms = 400.0;
    cfg.latency.boot_ms = 200.0;
    cfg.fusion.min_observations = 1;
    cfg.fusion.feedback_interval_ms = 500.0;
    // trace every measured request; the ring must hold them all
    cfg.trace.sample_every = 1;
    cfg.trace.max_traces = (p.measured as usize).max(8) * 2;
    if !fused {
        cfg = cfg.vanilla();
    }
    cfg
}

/// Whether every function of the app currently routes to one and the same
/// instance (the fully-fused steady state).
fn fully_fused(platform: &Platform, functions: &[String]) -> bool {
    let mut ids = Vec::with_capacity(functions.len());
    for f in functions {
        let Ok(set) = platform.gateway.resolve_set(f) else {
            return false;
        };
        let Some(inst) = set.primary() else {
            return false;
        };
        ids.push(inst.id());
    }
    ids.windows(2).all(|w| w[0] == w[1])
}

fn run_arm(p: &Fig12Params, fused: bool) -> Result<Fig12Arm> {
    let cfg = config(p, fused);
    let app = apps::chain(p.chain_len);
    let p = *p;
    Executor::sharded(Mode::Virtual, 1).block_on(async move {
        let platform = Platform::deploy(app, cfg).await?;
        let entry = platform.app.entry.clone();
        let functions: Vec<String> =
            platform.app.functions().map(|f| f.name.clone()).collect();
        let len = platform.payload_len();
        // untraced warmup: boots, first observations, fusion cutovers.
        // Cutover races are tolerated here — only steady state is measured.
        for i in 0..p.warmup {
            let _ = platform.invoke_function(&entry, request_payload(p.seed, i, len)).await;
            exec::sleep_ms(250.0).await;
        }
        if fused {
            // keep feeding observations until the whole chain routes to a
            // single instance (transitive fusion done), bounded
            let mut spins: u64 = 0;
            while !fully_fused(&platform, &functions) && spins < 400 {
                let payload = request_payload(p.seed, 1_000 + spins, len);
                let _ = platform.invoke_function(&entry, payload).await;
                exec::sleep_ms(250.0).await;
                spins += 1;
            }
            // let drains and the feedback tick settle before measuring
            exec::sleep_ms(10_000.0).await;
        }
        // measurement: sequential steady-state requests, driver-owned
        // trace lifecycle (same contract as the workload generator)
        let entry_sym = Sym::intern(&entry);
        for i in 0..p.measured {
            let payload = request_payload(p.seed ^ 0xF16, 10_000 + i, len);
            let t0 = exec::now();
            let trace =
                platform.tracer.begin_request(entry_sym, platform.metrics.rel_now_ms());
            let out = platform.invoke_function_traced(&entry, payload, trace).await?;
            let latency_ms = exec::now().duration_since(t0).as_secs_f64() * 1e3;
            platform.tracer.finish_ok(trace, latency_ms);
            debug_assert!(!out.is_empty());
        }
        let all = platform.tracer.snapshot();
        let traces: Vec<Trace> =
            all[all.len().saturating_sub(p.measured as usize)..].to_vec();
        let arm = Fig12Arm {
            label: if fused { "fused" } else { "unfused" },
            e2e_ns: traces.first().map(e2e_ns).unwrap_or(0),
            merges: platform.metrics.merges().len(),
            conservation_violations: platform.tracer.conservation_violations(),
            breakdown_csv: platform.tracer.latency_breakdown_csv(),
            chrome_json: platform.tracer.chrome_trace_json(),
            traces,
        };
        platform.shutdown();
        Ok(arm)
    })
}

/// Run FIG12 and write `fig12_summary.txt`, per-arm breakdown CSVs, and
/// the fused arm's Chrome trace-event JSON into `out_dir`.
pub fn run(out_dir: &Path, p: Fig12Params) -> Result<Fig12> {
    let unfused = run_arm(&p, false)?;
    let fused = run_arm(&p, true)?;

    let mut checks: Vec<(String, bool)> = Vec::new();
    let n = p.measured as usize;
    checks.push((
        format!(
            "both arms retained every measured trace ({} + {})",
            unfused.traces.len(),
            fused.traces.len()
        ),
        unfused.traces.len() == n && fused.traces.len() == n,
    ));
    let all_verified = |arm: &Fig12Arm| {
        arm.traces
            .iter()
            .all(|t| t.conserved && !t.truncated && crate::trace::verify(t).is_ok())
    };
    checks.push((
        format!(
            "every measured trace conserved and well-formed ({} + {} violations)",
            unfused.conservation_violations, fused.conservation_violations
        ),
        all_verified(&unfused)
            && all_verified(&fused)
            && unfused.conservation_violations == 0
            && fused.conservation_violations == 0,
    ));
    let stall_free = |arm: &Fig12Arm| {
        arm.traces
            .iter()
            .all(|t| STALL_KINDS.iter().all(|k| kind_ns(t, *k) == 0))
    };
    checks.push((
        "no cold-start/gate/cutover stalls in steady-state traces".to_string(),
        stall_free(&unfused) && stall_free(&fused),
    ));
    let constant = |arm: &Fig12Arm| arm.traces.iter().all(|t| e2e_ns(t) == arm.e2e_ns);
    checks.push((
        format!(
            "jitter-free fabric: e2e constant per arm ({} ns vs {} ns)",
            unfused.e2e_ns, fused.e2e_ns
        ),
        constant(&unfused) && constant(&fused),
    ));
    let u = &unfused.traces[0];
    let f = &fused.traces[0];
    let count =
        |t: &Trace, k: SpanKind| t.spans.iter().filter(|s| s.kind == k).count();
    checks.push((
        format!(
            "fused arm inlined the chain ({} merges, {} inline hops, {} dispatch)",
            fused.merges,
            count(f, SpanKind::Inline),
            count(f, SpanKind::Dispatch)
        ),
        !fused.traces.is_empty()
            && fused.merges >= p.chain_len - 1
            && count(f, SpanKind::Inline) == p.chain_len - 1
            && count(f, SpanKind::Dispatch) == 1
            && count(u, SpanKind::Inline) == 0
            && count(u, SpanKind::Dispatch) == p.chain_len,
    ));
    checks.push((
        "handler self-time preserved bit-for-bit across arms".to_string(),
        kind_ns(u, SpanKind::SelfTime) == kind_ns(f, SpanKind::SelfTime),
    ));

    // the headline identity, exact in integer ns for EVERY measured pair
    let eliminated_ns: i128 = ENVELOPE_KINDS
        .iter()
        .map(|k| kind_ns(u, *k) as i128 - kind_ns(f, *k) as i128)
        .sum();
    let added_inline_ns =
        kind_ns(f, SpanKind::Inline) as i128 - kind_ns(u, SpanKind::Inline) as i128;
    let delta_ns = unfused.e2e_ns as i128 - fused.e2e_ns as i128;
    let identity = unfused.traces.iter().zip(fused.traces.iter()).all(|(tu, tf)| {
        let elim: i128 = ENVELOPE_KINDS
            .iter()
            .map(|k| kind_ns(tu, *k) as i128 - kind_ns(tf, *k) as i128)
            .sum();
        let added = kind_ns(tf, SpanKind::Inline) as i128
            - kind_ns(tu, SpanKind::Inline) as i128;
        e2e_ns(tu) as i128 - e2e_ns(tf) as i128 == elim - added
    });
    checks.push((
        format!(
            "EXACT: e2e delta ({delta_ns} ns) == eliminated envelope \
             ({eliminated_ns} ns) - added inline ({added_inline_ns} ns)"
        ),
        identity && delta_ns == eliminated_ns - added_inline_ns,
    ));
    checks.push((
        format!("fusion wins ({:.3} ms saved/request)", delta_ns as f64 / 1e6),
        delta_ns > 0,
    ));

    let fig = Fig12 {
        params: p,
        unfused,
        fused,
        delta_ns,
        eliminated_ns,
        added_inline_ns,
        checks,
    };
    write_output(&out_dir.join("fig12_summary.txt"), &fig.render())?;
    write_output(
        &out_dir.join("fig12_breakdown_unfused.csv"),
        &fig.unfused.breakdown_csv,
    )?;
    write_output(&out_dir.join("fig12_breakdown_fused.csv"), &fig.fused.breakdown_csv)?;
    write_output(&out_dir.join("fig12_traces.json"), &fig.fused.chrome_json)?;
    Ok(fig)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig12_exact_delta_self_check() {
        let p = Fig12Params::defaults(true);
        let dir = std::env::temp_dir().join("provuse_fig12_test");
        let fig = run(&dir, p).unwrap();
        assert!(fig.passed(), "{}", fig.render());
        assert!(fig.delta_ns > 0);
        assert_eq!(fig.delta_ns, fig.eliminated_ns - fig.added_inline_ns);
        // breakdown ledger names the components it aggregates
        assert!(fig.unfused.breakdown_csv.contains(",dispatch,"));
        assert!(fig.fused.breakdown_csv.contains(",inline,"));
        assert!(dir.join("fig12_traces.json").exists());
        let json = std::fs::read_to_string(dir.join("fig12_traces.json")).unwrap();
        assert!(json.contains("\"traceEvents\""));
        assert!(json.contains("\"name\":\"inline\""));
    }
}
