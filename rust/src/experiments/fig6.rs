//! FIG6 / TAB-LAT / TAB-RAM — paper Figure 6 and the §5.2 numbers: median
//! end-to-end latency and RAM usage for {tinyFaaS, Kubernetes} x {IOT,
//! TREE} x {vanilla, fusion}.
//!
//! Paper reference values:
//!
//! | config     | vanilla | fusion | reduction |  RAM   |
//! |------------|---------|--------|-----------|--------|
//! | tiny/IOT   | 807 ms  | 574 ms |   28.9 %  | ~57 %  |
//! | tiny/TREE  | 452 ms  | 350 ms |   22.6 %  | ~50 %  |
//! | kube/IOT   | 815 ms  | 551 ms |   32.4 %  | ~57 %  |
//! | kube/TREE  | 456 ms  | 358 ms |   21.5 %  | ~50 %  |
//! | average    |         |        |   26.3 %  | 53.6 % |

use std::path::Path;

use super::{reduction_pct, write_output, RunResult};
use crate::config::{ComputeMode, PlatformKind, WorkloadConfig};
use crate::error::Result;

/// Paper reference numbers for one cell (for side-by-side reporting).
#[derive(Debug, Clone, Copy)]
pub struct PaperCell {
    pub vanilla_ms: f64,
    pub fusion_ms: f64,
    pub ram_reduction_pct: f64,
}

/// One platform x app cell: vanilla + fusion runs.
pub struct Cell {
    pub platform: PlatformKind,
    pub app: &'static str,
    pub vanilla: RunResult,
    pub fusion: RunResult,
    pub paper: PaperCell,
}

impl Cell {
    pub fn latency_reduction_pct(&self) -> f64 {
        reduction_pct(
            self.vanilla.report.latency.median(),
            self.fusion.report.latency.median(),
        )
    }

    pub fn ram_reduction_pct(&self) -> f64 {
        reduction_pct(self.vanilla.ram_mean_mb, self.fusion.ram_mean_mb)
    }

    pub fn paper_reduction_pct(&self) -> f64 {
        reduction_pct(self.paper.vanilla_ms, self.paper.fusion_ms)
    }
}

/// The full 4-cell matrix.
pub struct Fig6 {
    pub cells: Vec<Cell>,
}

const CONFIGS: [(PlatformKind, &str, PaperCell); 4] = [
    (
        PlatformKind::Tiny,
        "iot",
        PaperCell { vanilla_ms: 807.0, fusion_ms: 574.0, ram_reduction_pct: 57.0 },
    ),
    (
        PlatformKind::Tiny,
        "tree",
        PaperCell { vanilla_ms: 452.0, fusion_ms: 350.0, ram_reduction_pct: 50.0 },
    ),
    (
        PlatformKind::Kube,
        "iot",
        PaperCell { vanilla_ms: 815.0, fusion_ms: 551.0, ram_reduction_pct: 57.0 },
    ),
    (
        PlatformKind::Kube,
        "tree",
        PaperCell { vanilla_ms: 456.0, fusion_ms: 358.0, ram_reduction_pct: 50.0 },
    ),
];

impl Fig6 {
    pub fn mean_latency_reduction_pct(&self) -> f64 {
        self.cells.iter().map(|c| c.latency_reduction_pct()).sum::<f64>()
            / self.cells.len() as f64
    }

    pub fn mean_ram_reduction_pct(&self) -> f64 {
        self.cells.iter().map(|c| c.ram_reduction_pct()).sum::<f64>() / self.cells.len() as f64
    }

    /// Markdown table: measured vs paper, per cell.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str("FIG6 / TAB-LAT / TAB-RAM: median e2e latency + RAM (paper Fig. 6, §5.2)\n\n");
        out.push_str(
            "| config | vanilla (ms) | fusion (ms) | reduction | paper | RAM reduction | paper RAM |\n",
        );
        out.push_str(
            "|--------|-------------:|------------:|----------:|------:|--------------:|----------:|\n",
        );
        for c in &self.cells {
            out.push_str(&format!(
                "| {}/{} | {:.0} | {:.0} | {:.1}% | {:.1}% ({:.0}→{:.0}) | {:.1}% | ~{:.0}% |\n",
                c.platform.name(),
                c.app,
                c.vanilla.report.latency.median(),
                c.fusion.report.latency.median(),
                c.latency_reduction_pct(),
                c.paper_reduction_pct(),
                c.paper.vanilla_ms,
                c.paper.fusion_ms,
                c.ram_reduction_pct(),
                c.paper.ram_reduction_pct,
            ));
        }
        out.push_str(&format!(
            "| **average** | | | **{:.1}%** | **26.3%** | **{:.1}%** | **53.6%** |\n",
            self.mean_latency_reduction_pct(),
            self.mean_ram_reduction_pct(),
        ));
        out
    }

    /// TAB-COST (ours): provider bill per configuration — the double-
    /// billing elimination the paper motivates with, in dollars.
    pub fn render_cost(&self) -> String {
        let model = crate::billing::CostModel::default();
        let mut out = String::new();
        out.push_str("TAB-COST: provider bill (AWS-like list prices) per 1k requests\n\n");
        out.push_str(
            "| config | vanilla $/kreq | fusion $/kreq | saving | vanilla GB-s | fusion GB-s | billed invocations v->f |\n",
        );
        out.push_str(
            "|--------|---------------:|--------------:|-------:|-------------:|------------:|------------------------:|\n",
        );
        let mut savings = Vec::new();
        for c in &self.cells {
            let v = c.vanilla.bill.cost_per_kreq(&model, c.vanilla.report.issued);
            let f = c.fusion.bill.cost_per_kreq(&model, c.fusion.report.issued);
            let saving = reduction_pct(v, f);
            savings.push(saving);
            out.push_str(&format!(
                "| {}/{} | ${:.4} | ${:.4} | {:.1}% | {:.0} | {:.0} | {} -> {} |\n",
                c.platform.name(),
                c.app,
                v,
                f,
                saving,
                c.vanilla.bill.gb_seconds,
                c.fusion.bill.gb_seconds,
                c.vanilla.bill.invocations,
                c.fusion.bill.invocations,
            ));
        }
        out.push_str(&format!(
            "| **average** | | | **{:.1}%** | | | |\n",
            savings.iter().sum::<f64>() / savings.len() as f64
        ));
        out
    }

    /// CSV of the bar-chart data behind Figure 6.
    pub fn to_csv(&self) -> String {
        let mut out = String::from(
            "platform,app,deployment,median_ms,p25_ms,p75_ms,ram_mean_mb,merges,final_instances\n",
        );
        for c in &self.cells {
            for r in [&c.vanilla, &c.fusion] {
                out.push_str(&format!(
                    "{},{},{},{:.3},{:.3},{:.3},{:.3},{},{}\n",
                    c.platform.name(),
                    c.app,
                    if r.fusion { "fusion" } else { "vanilla" },
                    r.report.latency.median(),
                    r.report.latency.q(0.25),
                    r.report.latency.q(0.75),
                    r.ram_mean_mb,
                    r.merges.len(),
                    r.final_instances,
                ));
            }
        }
        out
    }
}

/// Run all four cells and write `fig6.csv` + `fig6_table.md` to `out_dir`.
pub fn run(out_dir: &Path, wl: WorkloadConfig, compute: ComputeMode) -> Result<Fig6> {
    let mut cells = Vec::new();
    for (kind, app, paper) in CONFIGS {
        eprintln!("  fig6: running {}/{app} ...", kind.name());
        // Windowed recording (ISSUE 7): fig6 exports no raw-series CSVs —
        // every cell consumes workload-side latencies, the incremental
        // ram_mean_mb, merge counts, and billing totals, all of which are
        // level-independent (run_custom grows the windowed retention to
        // span the run, so the TAB-COST bill stays whole-run-exact).
        let level = crate::metrics::RecordingLevel::Windowed;
        let vanilla = super::run_one_at(kind, app, false, wl.clone(), compute, level)?;
        let fusion = super::run_one_at(kind, app, true, wl.clone(), compute, level)?;
        cells.push(Cell { platform: kind, app, vanilla, fusion, paper });
    }
    let fig = Fig6 { cells };
    write_output(&out_dir.join("fig6.csv"), &fig.to_csv())?;
    write_output(&out_dir.join("fig6_table.md"), &fig.render())?;
    write_output(&out_dir.join("cost_table.md"), &fig.render_cost())?;
    Ok(fig)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig6_shape_holds_at_small_scale() {
        let wl = WorkloadConfig { requests: 120, rate_rps: 10.0, seed: 4, timeout_ms: 60_000.0 };
        let dir = std::env::temp_dir().join("provuse_fig6_test");
        let fig = run(&dir, wl, ComputeMode::Disabled).unwrap();
        assert_eq!(fig.cells.len(), 4);
        for c in &fig.cells {
            // the paper's headline: fusion wins every cell on both axes
            assert!(
                c.latency_reduction_pct() > 0.0,
                "{}/{}: {}",
                c.platform.name(),
                c.app,
                c.latency_reduction_pct()
            );
            assert!(c.ram_reduction_pct() > 0.0);
            assert_eq!(c.vanilla.report.failed, 0);
            assert_eq!(c.fusion.report.failed, 0);
            // double billing eliminated: fewer billed invocations and
            // fewer GB-seconds under fusion
            assert!(c.fusion.bill.invocations < c.vanilla.bill.invocations);
            assert!(c.fusion.bill.gb_seconds < c.vanilla.bill.gb_seconds);
        }
        assert!(fig.render_cost().contains("TAB-COST"));
        let table = fig.render();
        assert!(table.contains("average"));
        assert!(dir.join("fig6.csv").exists());
    }
}
