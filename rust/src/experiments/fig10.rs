//! FIG10 (ours) — replica sets under burst (ISSUE 6): a bursty workload
//! that saturates one instance, absorbed by the autoscaler + warm pool
//! with **zero dropped requests**, plus a bit-exact seed-parity trio.
//!
//! Three self-checked runs share the driver:
//!
//! 1. **scaled** — `chain(1)` with a per-replica concurrency cap of 1
//!    (~22 rps per replica at the 40 ms spec busy time), hit with a
//!    `burst_rps` arrival stream far beyond one replica's capacity.  The
//!    autoscaler must ride the burst to multiple replicas (warm-pool
//!    claims first, cold boots for the remainder — accounted separately
//!    and required to sum to the scale-up event count), drop **nothing**,
//!    and scale back down to the one-replica floor once the burst passes.
//! 2. **control** — the identical workload at `--replicas-max 1`: the
//!    burst must saturate the lone replica and time requests out
//!    (`failed > 0`), proving the scaled run's zero-drop verdict is the
//!    autoscaler's doing and not workload slack.
//! 3. **parity trio** — a gentle fused-chain workload (the FIG9 regime)
//!    run three ways: seed-default config, a config built through the
//!    scaling flags at their inert values (`--replicas-max 1`), and an
//!    **armed-but-inert** autoscaler (`replicas_max = 2` with an
//!    unreachable `target_inflight`).  All three must produce
//!    bit-identical fusion verdict transcripts
//!    ([`fig9::verdict_transcript`]) and zero scale events: every replica
//!    mechanism is an exact no-op until a flag asks for it.
//!
//! The burst runs pin `ComputeMode::Disabled` so per-request service time
//! is exactly the spec busy time and the saturation arithmetic stays
//! calibration-independent; the parity trio honors `--live`/`--no-compute`
//! (parity is internal to the trio, whatever the compute mode).

use std::path::Path;
use std::rc::Rc;

use super::{fig9, write_output};
use crate::apps;
use crate::config::{
    ComputeMode, MergePolicyKind, PlatformConfig, ScalingParams, WorkloadConfig,
};
use crate::error::Result;
use crate::exec::{Executor, Mode};
use crate::metrics::ScaleEvent;
use crate::platform::Platform;
use crate::util::stats::fmt_ms;
use crate::workload::{self, WorkloadReport};

/// FIG10 knobs (CLI + smoke test share the driver).
#[derive(Debug, Clone, Copy)]
pub struct Fig10Params {
    /// requests per burst run (the burst lasts `requests / burst_rps` s)
    pub requests: u64,
    /// burst arrival rate — must exceed one replica's ~22 rps capacity
    pub burst_rps: f64,
    /// per-request deadline; the control run proves saturation by blowing it
    pub timeout_ms: f64,
    pub seed: u64,
    /// compute mode of the parity trio (burst runs pin `Disabled`)
    pub compute: ComputeMode,
    pub replicas_max: u32,
    pub target_inflight: u32,
    pub scale_interval_ms: f64,
    pub warm_pool: usize,
    pub warm_attach_ms: f64,
    pub concurrency: u32,
    /// run the seed-parity trio (skipped by `--no-parity`)
    pub parity: bool,
}

impl Fig10Params {
    pub fn defaults(smoke: bool) -> Self {
        Fig10Params {
            requests: if smoke { 240 } else { 1_200 },
            burst_rps: 120.0,
            timeout_ms: 5_000.0,
            seed: 13,
            compute: ComputeMode::Replay,
            replicas_max: 8,
            target_inflight: 1,
            scale_interval_ms: 150.0,
            warm_pool: 2,
            warm_attach_ms: 20.0,
            concurrency: 1,
            parity: true,
        }
    }
}

/// One completed burst run.
pub struct Fig10Run {
    pub report: WorkloadReport,
    pub scale_events: Vec<ScaleEvent>,
    pub scale_ups: u64,
    pub scale_downs: u64,
    pub warm_pool_hits: u64,
    pub cold_boots: u64,
    /// highest routable replica count any scale event reached
    pub peak_replicas: u32,
    /// per-route live replica count after the post-burst settle
    pub floor: Vec<(String, usize)>,
    /// warm-pool size after the settle (claims must have replenished)
    pub pool_len: usize,
    pub scales_csv: String,
    /// full counter ledger (`counter,value` CSV) — on the control run this
    /// carries the per-cause drop tags (ISSUE 9)
    pub counters_csv: String,
}

/// The parity trio's transcripts.
pub struct Fig10Parity {
    pub seed_verdicts: Vec<String>,
    pub flags_verdicts: Vec<String>,
    pub armed_verdicts: Vec<String>,
    pub seed_failed: u64,
    pub scale_events_across_trio: usize,
}

pub struct Fig10 {
    pub params: Fig10Params,
    pub scaled: Fig10Run,
    pub control: Fig10Run,
    pub parity: Option<Fig10Parity>,
    pub checks: Vec<(String, bool)>,
}

impl Fig10 {
    pub fn passed(&self) -> bool {
        self.checks.iter().all(|(_, ok)| *ok)
    }

    pub fn render(&self) -> String {
        let s = &self.scaled;
        let c = &self.control;
        let mut out = String::new();
        out.push_str(&format!(
            "FIG10: replica sets under burst — {} requests @ {:.0} rps, \
             {} ms deadline (chain(1), concurrency {}, replicas-max {}, \
             warm pool {})\n",
            self.params.requests,
            self.params.burst_rps,
            self.params.timeout_ms,
            self.params.concurrency,
            self.params.replicas_max,
            self.params.warm_pool
        ));
        out.push_str(&format!("  scaled  : {}\n", s.report.summary()));
        out.push_str(&format!(
            "            {} scale-ups ({} warm, {} cold), peak {} replicas, \
             {} scale-downs, settled at {} (pool {})\n",
            s.scale_ups,
            s.warm_pool_hits,
            s.cold_boots,
            s.peak_replicas,
            s.scale_downs,
            s.floor
                .iter()
                .map(|(f, n)| format!("{f}={n}"))
                .collect::<Vec<_>>()
                .join(","),
            s.pool_len
        ));
        out.push_str(&format!(
            "  control : {} (replicas-max 1, p95 {})\n",
            c.report.summary(),
            fmt_ms(c.report.latency.p95())
        ));
        if let Some(p) = &self.parity {
            out.push_str(&format!(
                "  parity  : {} verdicts (seed) vs {} (flags R=1) vs {} \
                 (armed-inert), {} scale events across the trio\n",
                p.seed_verdicts.len(),
                p.flags_verdicts.len(),
                p.armed_verdicts.len(),
                p.scale_events_across_trio
            ));
        }
        for (name, ok) in &self.checks {
            out.push_str(&format!("  [{}] {}\n", if *ok { "PASS" } else { "FAIL" }, name));
        }
        out
    }
}

/// Burst-run platform config: vanilla (no fusion — the scaling subsystem
/// is what's under test), compute disabled (service time = spec busy
/// time), and the replica knobs from `p`.
fn burst_config(p: &Fig10Params, replicas_max: u32, warm_pool: usize) -> PlatformConfig {
    let mut cfg = PlatformConfig::tiny()
        .with_compute(ComputeMode::Disabled)
        .with_seed(p.seed)
        .vanilla();
    cfg.latency.boot_ms = 200.0;
    cfg.latency.image_build_ms = 400.0;
    cfg.scaling.replicas_max = replicas_max;
    cfg.scaling.replicas_min = 1;
    cfg.scaling.target_inflight = p.target_inflight;
    cfg.scaling.scale_interval_ms = p.scale_interval_ms;
    cfg.scaling.warm_pool = warm_pool;
    cfg.scaling.warm_attach_ms = p.warm_attach_ms;
    cfg.scaling.concurrency = p.concurrency;
    cfg
}

fn run_burst(p: &Fig10Params, cfg: PlatformConfig) -> Result<Fig10Run> {
    let wl = WorkloadConfig {
        requests: p.requests,
        rate_rps: p.burst_rps,
        seed: p.seed,
        timeout_ms: p.timeout_ms,
    };
    Executor::new(Mode::Virtual).block_on(async move {
        let platform = Platform::deploy(apps::chain(1), cfg).await?;
        let report = workload::run(Rc::clone(&platform), wl).await?;
        // post-burst quiet phase: drains settle and the autoscaler walks
        // the set back down to the floor
        crate::exec::sleep_ms(15_000.0).await;
        let m = &platform.metrics;
        let scale_events = m.scales();
        let floor: Vec<(String, usize)> = platform
            .app
            .functions()
            .map(|f| {
                let n = platform
                    .gateway
                    .resolve_set(&f.name)
                    .map(|s| s.live_len())
                    .unwrap_or(0);
                (f.name.clone(), n)
            })
            .collect();
        let run = Fig10Run {
            scale_ups: m.counter("scale_ups"),
            scale_downs: m.counter("scale_downs") + m.counter("scale_to_zero"),
            warm_pool_hits: m.counter("warm_pool_hits"),
            cold_boots: m.counter("cold_boots"),
            peak_replicas: scale_events.iter().map(|e| e.to).max().unwrap_or(1),
            floor,
            pool_len: platform.scaler.pool_len(),
            scales_csv: m.scales_csv(),
            counters_csv: m.counters_csv(),
            scale_events,
            report,
        };
        platform.shutdown();
        Ok(run)
    })
}

/// Parity-trio config: the FIG9 regime (fused chain, cost-model
/// admission) with an explicit [`ScalingParams`].
fn trio_config(p: &Fig10Params, scaling: ScalingParams) -> PlatformConfig {
    let mut cfg = PlatformConfig::tiny().with_compute(p.compute).with_seed(p.seed);
    cfg.latency.image_build_ms = 400.0;
    cfg.latency.boot_ms = 200.0;
    cfg.fusion.min_observations = 3;
    cfg.fusion.feedback_interval_ms = 1_000.0;
    cfg.fusion.merge_policy = MergePolicyKind::CostModel;
    cfg.scaling = scaling;
    cfg
}

/// One gentle fused-chain run; returns the canonical verdict transcript
/// plus the drop and scale-event counts.
fn run_trio_leg(cfg: PlatformConfig, seed: u64) -> Result<(Vec<String>, u64, usize)> {
    let wl = WorkloadConfig {
        requests: 600,
        rate_rps: 100.0,
        seed,
        timeout_ms: 120_000.0,
    };
    Executor::new(Mode::Virtual).block_on(async move {
        let platform = Platform::deploy(apps::chain(3), cfg).await?;
        let report = workload::run(Rc::clone(&platform), wl).await?;
        crate::exec::sleep_ms(10_000.0).await;
        platform.shutdown();
        let m = &platform.metrics;
        Ok((fig9::verdict_transcript(m), report.failed, m.scales().len()))
    })
}

fn run_parity(p: &Fig10Params) -> Result<Fig10Parity> {
    // 1. the seed shape: ScalingParams never touched
    let seed_cfg = trio_config(p, ScalingParams::default());
    // 2. the flags path at its inert values — what `--replicas-max 1`
    //    builds; must not perturb one bit
    let flags_cfg = trio_config(
        p,
        ScalingParams {
            replicas_max: 1,
            replicas_min: 1,
            target_inflight: 8,
            scale_interval_ms: 1_000.0,
            idle_horizon_ms: 0.0,
            warm_pool: 0,
            warm_attach_ms: 120.0,
            concurrency: 0,
        },
    );
    // 3. armed but provably inert: the autoscaler task runs every tick but
    //    an unreachable target_inflight keeps desired == live == 1 forever
    let armed_cfg = trio_config(
        p,
        ScalingParams {
            replicas_max: 2,
            replicas_min: 1,
            target_inflight: u32::MAX,
            scale_interval_ms: 500.0,
            idle_horizon_ms: 0.0,
            warm_pool: 0,
            warm_attach_ms: 120.0,
            concurrency: 0,
        },
    );
    let (seed_verdicts, seed_failed, s1) = run_trio_leg(seed_cfg, p.seed)?;
    let (flags_verdicts, _, s2) = run_trio_leg(flags_cfg, p.seed)?;
    let (armed_verdicts, _, s3) = run_trio_leg(armed_cfg, p.seed)?;
    Ok(Fig10Parity {
        seed_verdicts,
        flags_verdicts,
        armed_verdicts,
        seed_failed,
        scale_events_across_trio: s1 + s2 + s3,
    })
}

/// Run FIG10 and write `fig10_summary.txt` + `fig10_scales.csv` into
/// `out_dir`.
pub fn run(out_dir: &Path, p: Fig10Params) -> Result<Fig10> {
    let scaled = run_burst(&p, burst_config(&p, p.replicas_max, p.warm_pool))?;
    // identical burst against a single pinned replica (no warm pool): the
    // control that proves the workload saturates one instance
    let control = run_burst(&p, burst_config(&p, 1, 0))?;
    let parity = if p.parity { Some(run_parity(&p)?) } else { None };

    let s = &scaled;
    let mut checks: Vec<(String, bool)> = Vec::new();
    checks.push((
        format!("scaled run dropped nothing ({} failed)", s.report.failed),
        s.report.failed == 0,
    ));
    checks.push((
        format!(
            "autoscaler rode the burst out (peak {} replicas, {} scale-ups)",
            s.peak_replicas, s.scale_ups
        ),
        s.peak_replicas > 1 && s.scale_ups > 0,
    ));
    checks.push((
        format!(
            "warm pool absorbed the first wave ({} warm hits, {} cold boots)",
            s.warm_pool_hits, s.cold_boots
        ),
        s.warm_pool_hits > 0 && s.cold_boots > 0,
    ));
    let up_events =
        s.scale_events.iter().filter(|e| e.reason == "burst" || e.reason == "scale-from-zero");
    let warm_events = up_events.clone().filter(|e| e.warm).count() as u64;
    let up_events = up_events.count() as u64;
    checks.push((
        format!(
            "warm + cold accounting consistent ({} events = {} warm + {} cold)",
            up_events, s.warm_pool_hits, s.cold_boots
        ),
        up_events == s.warm_pool_hits + s.cold_boots && warm_events == s.warm_pool_hits,
    ));
    checks.push((
        format!(
            "scaled back to the floor after the burst ({} scale-downs, {}, pool {})",
            s.scale_downs,
            s.floor
                .iter()
                .map(|(f, n)| format!("{f}={n}"))
                .collect::<Vec<_>>()
                .join(","),
            s.pool_len
        ),
        s.scale_downs > 0
            && s.floor.iter().all(|(_, n)| *n == 1)
            && s.pool_len == p.warm_pool,
    ));
    checks.push((
        format!(
            "control at --replicas-max 1 saturates ({} of {} dropped, 0 scale events)",
            control.report.failed, control.report.issued
        ),
        control.report.failed > 0 && control.scale_events.is_empty(),
    ));
    // drop-cause tagging (ISSUE 9): the control run's drops are deadline
    // blowouts, so the counter ledger must attribute every one of them
    checks.push((
        "control drops are cause-tagged in the counter ledger".to_string(),
        control.counters_csv.lines().any(|l| {
            l.strip_prefix("failed_timeout,")
                .and_then(|v| v.parse::<u64>().ok())
                .is_some_and(|n| n == control.report.failed)
        }),
    ));
    if let Some(par) = &parity {
        checks.push((
            format!(
                "parity trio is non-trivial ({} verdicts, 0 drops)",
                par.seed_verdicts.len()
            ),
            !par.seed_verdicts.is_empty() && par.seed_failed == 0,
        ));
        checks.push((
            "--replicas-max 1 reproduces seed verdicts bit-for-bit".to_string(),
            par.flags_verdicts == par.seed_verdicts,
        ));
        checks.push((
            "armed-but-inert autoscaler perturbs no verdict".to_string(),
            par.armed_verdicts == par.seed_verdicts,
        ));
        checks.push((
            format!(
                "no scale events anywhere in the trio ({})",
                par.scale_events_across_trio
            ),
            par.scale_events_across_trio == 0,
        ));
    }

    let fig = Fig10 { params: p, scaled, control, parity, checks };
    write_output(&out_dir.join("fig10_summary.txt"), &fig.render())?;
    write_output(&out_dir.join("fig10_scales.csv"), &fig.scaled.scales_csv)?;
    write_output(&out_dir.join("fig10_counters.csv"), &fig.control.counters_csv)?;
    Ok(fig)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig10_burst_scales_and_control_saturates() {
        let mut p = Fig10Params::defaults(true);
        p.compute = ComputeMode::Disabled;
        let dir = std::env::temp_dir().join("provuse_fig10_test");
        let fig = run(&dir, p).unwrap();
        assert!(fig.passed(), "{}", fig.render());
        let par = fig.parity.as_ref().expect("parity trio must run");
        assert_eq!(par.seed_verdicts, par.flags_verdicts);
        assert_eq!(par.seed_verdicts, par.armed_verdicts);
        assert!(dir.join("fig10_summary.txt").exists());
        assert!(dir.join("fig10_scales.csv").exists());
        let csv = std::fs::read_to_string(dir.join("fig10_scales.csv")).unwrap();
        assert!(csv.lines().count() > 1, "scale events must be exported:\n{csv}");
        // the control run's drops must be cause-tagged in the ledger
        let counters = std::fs::read_to_string(dir.join("fig10_counters.csv")).unwrap();
        assert!(counters.starts_with("counter,value\n"), "{counters}");
        assert!(counters.contains("failed_timeout,"), "{counters}");
        assert!(counters.contains("request_failures,"), "{counters}");
    }
}
