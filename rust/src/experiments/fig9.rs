//! FIG9 (ours) — the telemetry-pipeline scale proof (ISSUE 5): drive ≥10⁶
//! virtual requests through a chain app with the feedback controller and
//! the cost-model merge planner enabled, under **windowed** (bounded)
//! telemetry retention, and self-check that
//!
//! 1. the run completes with **zero dropped requests**,
//! 2. recorder memory stays under a fixed byte budget regardless of the
//!    request count (the windowed ring shards at work), and
//! 3. every fusion verdict — merge-admission evaluations (scores compared
//!    bit-for-bit), merges, splits, evicts — is **identical** to a
//!    full-retention twin run under the same seed: bounding telemetry
//!    memory must not perturb a single platform decision.
//!
//! The run also emits `BENCH_scale.json` (wall time, requests/sec,
//! recorder bytes) — the first point of the repo's performance trajectory;
//! CI's reduced-scale smoke job regenerates it as an artifact and warns
//! (non-blocking) on >20 % throughput regressions against the checked-in
//! baseline.
//!
//! With `--shards N` (ISSUE 7) the simulation core runs on N per-node
//! lanes; the driver then replays a 1-shard twin and self-checks that the
//! verdict transcript and every node's final RAM ledger are bit-identical
//! before the throughput point is recorded — sharding must never change
//! the schedule, only how fast it is produced.
//!
//! With `--trace-sample N` (ISSUE 9, default 64) a traced twin re-runs
//! the scale point with 1-in-N span sampling armed, self-checks that
//! tracing is schedule-transparent (identical verdict transcript), that
//! every sampled trace conserves its critical path, and that the trace
//! ring stays under [`TRACE_BUDGET_BYTES`]; `trace_overhead_pct` and
//! `trace_bytes` land in `BENCH_scale.json`.
//!
//! With `--threads on` (ISSUE 10) the scale point runs on the **threaded
//! simulation core**: the cluster becomes a fleet of independent tenant
//! lanes (one single-node platform + workload per `--nodes`, carrying an
//! equal share of the requests under a tenant-derived seed), driven by
//! `--shards` real OS worker threads under the epoch-window protocol of
//! [`crate::exec::threads::run_fleet`].  The driver then replays the
//! *same* fleet sequentially on one thread and demands the merged verdict
//! transcript, per-tenant RAM ledgers, and epoch counts are bit-identical
//! — thread interleaving must never leak into any lane's schedule — and
//! records the measured speedup as the `parallel-event-loop` trajectory
//! point.

use std::path::Path;
use std::rc::Rc;

use super::write_output;
use crate::apps;
use crate::config::{ComputeMode, MergePolicyKind, PlatformConfig, WorkloadConfig};
use crate::error::Result;
use crate::exec::{Executor, Mode};
use crate::metrics::{MergeEvent, RecordingLevel};
use crate::platform::Platform;
use crate::util::json::Json;
use crate::util::stats::fmt_ms;
use crate::workload::{self, WorkloadReport};

/// Fixed recorder byte budget the windowed run must stay under — chosen
/// an order of magnitude above the steady-state shard footprint so the
/// check trips on unbounded growth, not on calibration drift.
pub const RECORDER_BUDGET_BYTES: usize = 64 * 1024 * 1024;

/// Byte budget for the trace ring in the traced twin (ISSUE 9) — the ring
/// is bounded by `max_traces`, so its footprint must not scale with the
/// request count either.
pub const TRACE_BUDGET_BYTES: usize = 8 * 1024 * 1024;

/// FIG9 knobs (CLI + smoke test share the driver).
#[derive(Debug, Clone, Copy)]
pub struct Fig9Params {
    /// total requests (≥ 1M for the real scale point)
    pub requests: u64,
    pub rate_rps: f64,
    pub seed: u64,
    pub compute: ComputeMode,
    pub chain_len: usize,
    /// run the full-retention twin and compare verdicts bit-for-bit
    pub parity: bool,
    pub feedback_interval_ms: f64,
    pub min_observations: u32,
    /// simulation-core lanes (`--shards N`).  When > 1 the driver also runs
    /// a 1-shard twin and self-checks the verdict transcript and per-node
    /// RAM ledgers are bit-identical before recording the throughput point.
    pub shards: usize,
    /// cluster nodes (`--nodes N`) — shards map node `n` to lane
    /// `n % shards`, so multi-lane runs want a multi-node cluster.
    pub nodes: usize,
    /// trace-sampling rate for the traced twin (`--trace-sample N`, ISSUE
    /// 9): the scale point itself runs untraced, then a twin re-runs it at
    /// 1-in-N sampling to measure tracing's wall-clock overhead and bound
    /// the trace-ring bytes.  0 skips the twin.
    pub trace_sample: u64,
    /// `--threads on` (ISSUE 10): drive the scale point as a tenant fleet
    /// on real worker threads (`shards` workers over `nodes` tenant
    /// lanes), with a sequentially-driven twin as the bit-parity oracle.
    pub threads: bool,
}

impl Fig9Params {
    pub fn defaults(smoke: bool) -> Self {
        Fig9Params {
            requests: if smoke { 20_000 } else { 1_000_000 },
            rate_rps: if smoke { 400.0 } else { 2_000.0 },
            seed: 11,
            compute: ComputeMode::Replay,
            chain_len: 3,
            parity: true,
            feedback_interval_ms: 1_000.0,
            min_observations: 3,
            shards: 1,
            nodes: 1,
            trace_sample: 64,
            threads: false,
        }
    }
}

/// One completed run (windowed or full-retention twin).
pub struct Fig9Run {
    pub report: WorkloadReport,
    /// wall-clock seconds the simulation took
    pub wall_s: f64,
    pub recorder_bytes: usize,
    /// billing-ledger heap footprint (bounded alongside the recorder in
    /// windowed mode)
    pub billing_bytes: usize,
    pub ram_mean_mb: f64,
    pub merges: Vec<MergeEvent>,
    pub splits: usize,
    pub evicts: usize,
    pub inline_calls: u64,
    /// canonical verdict transcript (admissions with bit-exact scores,
    /// merges/splits/evicts with bit-exact timestamps)
    pub verdicts: Vec<String>,
    /// per-node final RAM ledger as `(node id, ram_mb bit pattern)` —
    /// compared bit-for-bit across shard counts
    pub node_ram: Vec<(u64, u64)>,
    /// discrete-event epochs (virtual-clock advances) the run consumed
    pub epochs: u64,
    /// trace-ring heap footprint (0 when tracing is off)
    pub trace_bytes: usize,
    /// traces whose critical path failed to sum to the recorded latency
    pub trace_violations: u64,
    /// traces retained in the ring at the end of the run
    pub trace_retained: u64,
}

impl Fig9Run {
    pub fn requests_per_sec(&self) -> f64 {
        if self.wall_s > 0.0 { self.report.issued as f64 / self.wall_s } else { f64::NAN }
    }
}

/// Telemetry from the threaded fleet run (`--threads on`): worker-thread
/// shape, epoch-window counters, and the wall-clock speedup over the
/// sequentially-driven twin.
pub struct FleetStats {
    /// independent tenant lanes in the fleet (= `--nodes`)
    pub tenants: usize,
    /// OS worker threads (= `min(--shards, tenants)`)
    pub workers: usize,
    /// `available_parallelism` on the host that produced the numbers
    pub host_cores: usize,
    /// epoch-window rounds the cohort completed at the gate
    pub windows: u64,
    pub worker_stats: Vec<crate::exec::threads::WorkerStats>,
    /// threaded wall vs the same fleet driven sequentially — with equal
    /// request totals this is exactly the requests/sec ratio
    pub speedup: f64,
}

impl FleetStats {
    /// Mean barrier-wait share across workers, in percent.
    pub fn mean_stall_pct(&self) -> f64 {
        if self.worker_stats.is_empty() {
            0.0
        } else {
            self.worker_stats.iter().map(|w| w.stall_pct()).sum::<f64>()
                / self.worker_stats.len() as f64
        }
    }
}

pub struct Fig9 {
    pub params: Fig9Params,
    pub windowed: Fig9Run,
    /// full-retention twin (None with `--no-parity`)
    pub full: Option<Fig9Run>,
    /// 1-shard twin (None unless `--shards N` with N > 1) — the sharded
    /// schedule must reproduce it bit-for-bit before the throughput point
    /// is recorded.  With `--threads on` this holds the sequentially-driven
    /// fleet twin instead (one worker, same lanes).
    pub single: Option<Fig9Run>,
    /// traced twin at `trace_sample` 1-in-N (None with `--trace-sample 0`)
    pub traced: Option<Fig9Run>,
    /// threaded-fleet counters (None unless `--threads on`)
    pub fleet: Option<FleetStats>,
    pub checks: Vec<(String, bool)>,
}

impl Fig9 {
    pub fn passed(&self) -> bool {
        self.checks.iter().all(|(_, ok)| *ok)
    }

    /// Wall-clock overhead of 1-in-N tracing over the untraced scale point
    /// (percent; 0.0 when the traced twin was skipped).  Wall time is
    /// host-dependent noise in small runs — the number is informational,
    /// never a pass/fail check.
    pub fn trace_overhead_pct(&self) -> f64 {
        match &self.traced {
            Some(t) if self.windowed.wall_s > 0.0 => {
                (t.wall_s - self.windowed.wall_s) / self.windowed.wall_s * 100.0
            }
            _ => 0.0,
        }
    }

    pub fn render(&self) -> String {
        let w = &self.windowed;
        let mut out = String::new();
        out.push_str(&format!(
            "FIG9: telemetry pipeline at scale — {} requests @ {:.0} rps (chain({}), \
             cost-model admission, windowed recording)\n",
            self.params.requests, self.params.rate_rps, self.params.chain_len
        ));
        out.push_str(&format!("  workload : {}\n", w.report.summary()));
        out.push_str(&format!(
            "  sim      : {:.2} s wall, {:.0} requests/s (wall), RAM mean {:.0} MiB\n",
            w.wall_s,
            w.requests_per_sec(),
            w.ram_mean_mb
        ));
        out.push_str(&format!(
            "  telemetry: {} recorder bytes + {} billing bytes (budget {}), p95 {}\n",
            w.recorder_bytes,
            w.billing_bytes,
            RECORDER_BUDGET_BYTES,
            fmt_ms(w.report.latency.p95())
        ));
        out.push_str(&format!(
            "  fusion   : {} merges, {} splits, {} evicts, {} inline calls, \
             {} admission evaluations\n",
            w.merges.len(),
            w.splits,
            w.evicts,
            w.inline_calls,
            w.verdicts.iter().filter(|v| v.starts_with("admission")).count()
        ));
        if let Some(full) = &self.full {
            let ratio = full.recorder_bytes / w.recorder_bytes.max(1);
            out.push_str(&format!(
                "  parity   : full-retention twin retained {} bytes ({}x), \
                 {} verdicts compared\n",
                full.recorder_bytes,
                ratio,
                full.verdicts.len()
            ));
        }
        if let Some(traced) = &self.traced {
            out.push_str(&format!(
                "  tracing  : 1-in-{} sampling retained {} traces in {} bytes, \
                 {:+.1}% wall overhead, {} conservation violations\n",
                self.params.trace_sample,
                traced.trace_retained,
                traced.trace_bytes,
                self.trace_overhead_pct(),
                traced.trace_violations
            ));
        }
        if let Some(fl) = &self.fleet {
            out.push_str(&format!(
                "  threads  : {} workers over {} tenant lanes ({} host cores), \
                 {} epoch windows, {:.2}x vs sequential twin, \
                 mean barrier stall {:.1}%\n",
                fl.workers,
                fl.tenants,
                fl.host_cores,
                fl.windows,
                fl.speedup,
                fl.mean_stall_pct()
            ));
            for ws in &fl.worker_stats {
                out.push_str(&format!(
                    "             worker {}: {} lanes, {} windows, {} epochs, \
                     stall {:.1}%\n",
                    ws.worker,
                    ws.jobs,
                    ws.windows,
                    ws.epochs,
                    ws.stall_pct()
                ));
            }
        } else if let Some(single) = &self.single {
            out.push_str(&format!(
                "  shards   : {} lanes over {} nodes, {} epochs — 1-shard twin \
                 replayed {} verdicts + {} node RAM ledgers for comparison\n",
                self.params.shards,
                self.params.nodes,
                w.epochs,
                single.verdicts.len(),
                single.node_ram.len()
            ));
        }
        for (name, ok) in &self.checks {
            out.push_str(&format!("  [{}] {}\n", if *ok { "PASS" } else { "FAIL" }, name));
        }
        out
    }

    /// The `BENCH_scale.json` payload (the perf-trajectory point).
    pub fn bench_json(&self) -> Json {
        let w = &self.windowed;
        Json::obj(vec![
            ("benchmark", Json::str("figure9_scale")),
            ("source", Json::str("provuse figure9")),
            ("requests", Json::Num(self.params.requests as f64)),
            ("rate_rps", Json::Num(self.params.rate_rps)),
            ("seed", Json::Num(self.params.seed as f64)),
            ("wall_time_s", Json::Num(w.wall_s)),
            ("requests_per_sec", Json::Num(w.requests_per_sec())),
            ("recorder_bytes", Json::Num(w.recorder_bytes as f64)),
            ("billing_bytes", Json::Num(w.billing_bytes as f64)),
            ("virtual_duration_s", Json::Num(w.report.duration_ms / 1e3)),
            ("p95_ms", Json::Num(w.report.latency.p95())),
            ("ram_mean_mb", Json::Num(w.ram_mean_mb)),
            ("merges", Json::Num(w.merges.len() as f64)),
            ("failed_requests", Json::Num(w.report.failed as f64)),
            ("parity_checked", Json::Bool(self.full.is_some())),
            ("shards", Json::Num(self.params.shards as f64)),
            ("nodes", Json::Num(self.params.nodes as f64)),
            ("shard_parity_checked", Json::Bool(self.single.is_some())),
            ("trace_sample", Json::Num(self.params.trace_sample as f64)),
            ("trace_overhead_pct", Json::Num(self.trace_overhead_pct())),
            (
                "trace_bytes",
                Json::Num(
                    self.traced.as_ref().map(|t| t.trace_bytes).unwrap_or(0) as f64
                ),
            ),
            ("threads", Json::Bool(self.fleet.is_some())),
            (
                "workers",
                Json::Num(self.fleet.as_ref().map(|f| f.workers).unwrap_or(1) as f64),
            ),
            (
                "tenants",
                Json::Num(self.fleet.as_ref().map(|f| f.tenants).unwrap_or(0) as f64),
            ),
            (
                "host_cores",
                Json::Num(self.fleet.as_ref().map(|f| f.host_cores).unwrap_or(0) as f64),
            ),
            (
                "epoch_windows",
                Json::Num(self.fleet.as_ref().map(|f| f.windows).unwrap_or(0) as f64),
            ),
            (
                "speedup_vs_single_worker",
                Json::Num(self.fleet.as_ref().map(|f| f.speedup).unwrap_or(0.0)),
            ),
            (
                "barrier_stall_pct",
                Json::Num(self.fleet.as_ref().map(|f| f.mean_stall_pct()).unwrap_or(0.0)),
            ),
            (
                "milestone",
                Json::str(if self.fleet.is_some() {
                    "parallel-event-loop"
                } else {
                    "request-span-tracing"
                }),
            ),
            ("provisional", Json::Bool(false)),
        ])
    }
}

fn config(p: &Fig9Params, level: RecordingLevel) -> PlatformConfig {
    let mut cfg = PlatformConfig::tiny().with_compute(p.compute).with_seed(p.seed);
    // fast enough pipelines that fusion converges early in the run
    cfg.latency.image_build_ms = 400.0;
    cfg.latency.boot_ms = 200.0;
    cfg.fusion.min_observations = p.min_observations;
    cfg.fusion.feedback_interval_ms = p.feedback_interval_ms;
    // the planner under test: cost-aware admission from windowed signals;
    // defusion stays on the (default) threshold policy, which is quiet for
    // a healthy fused chain — verdict parity covers it either way
    cfg.fusion.merge_policy = MergePolicyKind::CostModel;
    cfg.recording.level = level;
    cfg.cluster.nodes = p.nodes;
    // `cluster.shards` is informational here (serialized into config dumps);
    // the executor lane count is the `shards` argument to `run_once`, so the
    // 1-shard twin can reuse this config unchanged.
    cfg.cluster.shards = p.shards;
    cfg
}

/// Canonical verdict transcript: every platform decision that consumed a
/// telemetry signal, with f64s rendered bit-exactly.  Shared with the
/// recording-parity golden test so both parity checks compare the same
/// thing.
pub fn verdict_transcript(m: &crate::metrics::Recorder) -> Vec<String> {
    let mut v = Vec::new();
    for a in m.admissions() {
        v.push(format!(
            "admission {} {} {} {:016x} {:016x}",
            a.caller,
            a.callee,
            a.admitted,
            a.score.to_bits(),
            a.t_ms.to_bits()
        ));
    }
    for e in m.merges() {
        v.push(format!("merge {} {:016x}", e.functions.join("+"), e.t_ms.to_bits()));
    }
    for e in m.splits() {
        v.push(format!(
            "split {} {} {:016x}",
            e.functions.join("+"),
            e.reason.name(),
            e.t_ms.to_bits()
        ));
    }
    for e in m.evicts() {
        v.push(format!(
            "evict {} {} {:016x}",
            e.group.join("+"),
            e.function,
            e.t_ms.to_bits()
        ));
    }
    v
}

fn run_once(
    p: &Fig9Params,
    level: RecordingLevel,
    shards: usize,
    trace_sample: u64,
) -> Result<Fig9Run> {
    let mut cfg = config(p, level);
    // the traced twin arms the tracer; every other run keeps the seed's
    // disabled (zero-cost) tracer
    cfg.trace.sample_every = trace_sample;
    let app = apps::chain(p.chain_len);
    let wl = WorkloadConfig {
        requests: p.requests,
        rate_rps: p.rate_rps,
        seed: p.seed,
        timeout_ms: 120_000.0,
    };
    let wall_start = std::time::Instant::now();
    let mut run = Executor::sharded(Mode::Virtual, shards.max(1)).block_on(async move {
        let platform = Platform::deploy(app, cfg).await?;
        let report = workload::run(Rc::clone(&platform), wl).await?;
        // let stragglers (drains, detached work) settle before sampling ends
        crate::exec::sleep_ms(10_000.0).await;
        platform.shutdown();
        let m = &platform.metrics;
        let node_ram = platform
            .node_ram_ledger()
            .into_iter()
            .map(|(id, mb)| (id, mb.to_bits()))
            .collect();
        Ok::<Fig9Run, crate::error::Error>(Fig9Run {
            wall_s: 0.0, // filled in below, outside the virtual clock
            recorder_bytes: m.approx_bytes(),
            billing_bytes: platform.billing.approx_bytes(),
            ram_mean_mb: m.ram_mean_mb(),
            merges: m.merges(),
            splits: m.splits().len(),
            evicts: m.evicts().len(),
            inline_calls: m.counter("inline_calls"),
            verdicts: verdict_transcript(m),
            node_ram,
            epochs: crate::exec::epochs(),
            trace_bytes: platform.tracer.approx_bytes(),
            trace_violations: platform.tracer.conservation_violations(),
            trace_retained: platform.tracer.retained_total(),
            report,
        })
    })?;
    run.wall_s = wall_start.elapsed().as_secs_f64();
    Ok(run)
}

/// Virtual-time batch window the threaded fleet paces itself with when
/// the negotiated lookahead is unbounded (independent tenants, no
/// cross-lane edges): coarse enough that barrier crossings are amortized
/// over thousands of events, finite so the epoch gate is actually
/// exercised and stall accounting stays meaningful.
pub const PACED_WINDOW_NS: u64 = 250_000_000;

/// One tenant lane's completed simulation — the `Send` payload a worker
/// thread ships back to the fleet driver.
struct TenantRun {
    tenant: usize,
    report: WorkloadReport,
    recorder_bytes: usize,
    billing_bytes: usize,
    ram_mean_mb: f64,
    merges: Vec<MergeEvent>,
    splits: usize,
    evicts: usize,
    inline_calls: u64,
    verdicts: Vec<String>,
    node_ram: Vec<(u64, u64)>,
    epochs: u64,
}

/// Per-tenant platform + workload shape: a single-node slice of the
/// cluster carrying an equal share of the requests under a seed derived
/// from the run seed and the tenant id (golden-ratio mix, so tenant
/// streams are decorrelated but pinned).
fn tenant_setup(p: &Fig9Params, tenant: usize, tenants: usize) -> (PlatformConfig, WorkloadConfig) {
    let tseed = p.seed ^ 0x9E3779B97F4A7C15u64.wrapping_mul(tenant as u64 + 1);
    let mut cfg = config(p, RecordingLevel::Windowed);
    cfg.seed = tseed;
    cfg.cluster.nodes = 1;
    cfg.cluster.shards = 1;
    let extra = u64::from((tenant as u64) < p.requests % tenants as u64);
    let wl = WorkloadConfig {
        requests: p.requests / tenants as u64 + extra,
        rate_rps: p.rate_rps / tenants as f64,
        seed: tseed,
        timeout_ms: 120_000.0,
    };
    (cfg, wl)
}

/// The root future one tenant lane runs — same pipeline as [`run_once`]'s
/// body, returning a `Send` [`TenantRun`] instead of borrowing platform
/// state across threads.
fn tenant_future(
    tenant: usize,
    chain_len: usize,
    cfg: PlatformConfig,
    wl: WorkloadConfig,
) -> std::pin::Pin<Box<dyn std::future::Future<Output = Result<TenantRun>>>> {
    Box::pin(async move {
        let app = apps::chain(chain_len);
        let platform = Platform::deploy(app, cfg).await?;
        let report = workload::run(Rc::clone(&platform), wl).await?;
        crate::exec::sleep_ms(10_000.0).await;
        platform.shutdown();
        let m = &platform.metrics;
        let node_ram = platform
            .node_ram_ledger()
            .into_iter()
            .map(|(_, mb)| (tenant as u64, mb.to_bits()))
            .collect();
        Ok(TenantRun {
            tenant,
            recorder_bytes: m.approx_bytes(),
            billing_bytes: platform.billing.approx_bytes(),
            ram_mean_mb: m.ram_mean_mb(),
            merges: m.merges(),
            splits: m.splits().len(),
            evicts: m.evicts().len(),
            inline_calls: m.counter("inline_calls"),
            verdicts: verdict_transcript(m),
            node_ram,
            epochs: crate::exec::epochs(),
            report,
        })
    })
}

/// Merge a fleet of tenant lanes into one [`Fig9Run`]: counters sum,
/// latency samples pool into one distribution, and the canonical
/// transcript is every tenant's verdicts prefixed with its id, in tenant
/// order — the artifact the sequential twin must reproduce bit-for-bit.
fn merge_tenants(mut lanes: Vec<TenantRun>, wall_s: f64) -> Fig9Run {
    lanes.sort_by_key(|t| t.tenant);
    let reports: Vec<WorkloadReport> = lanes.iter().map(|t| t.report.clone()).collect();
    let mut run = Fig9Run {
        report: WorkloadReport::merged(&reports),
        wall_s,
        recorder_bytes: 0,
        billing_bytes: 0,
        ram_mean_mb: 0.0,
        merges: Vec::new(),
        splits: 0,
        evicts: 0,
        inline_calls: 0,
        verdicts: Vec::new(),
        node_ram: Vec::new(),
        epochs: 0,
        trace_bytes: 0,
        trace_violations: 0,
        trace_retained: 0,
    };
    for t in &lanes {
        run.recorder_bytes += t.recorder_bytes;
        run.billing_bytes += t.billing_bytes;
        run.ram_mean_mb += t.ram_mean_mb;
        run.merges.extend(t.merges.iter().cloned());
        run.splits += t.splits;
        run.evicts += t.evicts;
        run.inline_calls += t.inline_calls;
        run.verdicts.extend(t.verdicts.iter().map(|v| format!("t{} {v}", t.tenant)));
        run.node_ram.extend(t.node_ram.iter().copied());
        run.epochs += t.epochs;
    }
    run.ram_mean_mb /= lanes.len().max(1) as f64;
    run
}

/// `--threads on`: run the scale point as a tenant fleet on real worker
/// threads, replay the same fleet sequentially as the bit-parity oracle,
/// and record the measured speedup.
fn run_threaded(out_dir: &Path, p: Fig9Params) -> Result<Fig9> {
    let tenants = p.nodes.max(1);
    let workers = p.shards.clamp(1, tenants);
    // tenant t rides worker t % workers (the node→lane rule of the
    // single-threaded sharded core, applied to whole tenant lanes)
    let mut jobs: Vec<Vec<crate::exec::threads::LaneJob<Result<TenantRun>>>> =
        (0..workers).map(|_| Vec::new()).collect();
    for t in 0..tenants {
        let (cfg, wl) = tenant_setup(&p, t, tenants);
        let chain_len = p.chain_len;
        jobs[t % workers].push(Box::new(move || tenant_future(t, chain_len, cfg, wl)));
    }
    // Independent tenants have no cross-lane edges, so the negotiated
    // conservative license is unbounded; pace with the finite batch
    // window instead so the epoch gate is exercised.
    let lookahead_ns = crate::netsim::negotiate_lookahead(&[]).unwrap_or(PACED_WINDOW_NS);
    let wall = std::time::Instant::now();
    let fleet = crate::exec::threads::run_fleet(lookahead_ns, jobs)
        .map_err(crate::error::Error::from)?;
    let wall_threaded = wall.elapsed().as_secs_f64();
    let mut lanes = Vec::with_capacity(tenants);
    for worker_results in fleet.results {
        for lane in worker_results {
            lanes.push(lane?);
        }
    }
    let windowed = merge_tenants(lanes, wall_threaded);

    // The oracle: the identical fleet driven to completion one lane at a
    // time on this thread.  Tenant lanes are pure functions of
    // (seed, config), so any divergence means thread interleaving leaked
    // into a schedule.
    let wall = std::time::Instant::now();
    let mut twin_lanes = Vec::with_capacity(tenants);
    for t in 0..tenants {
        let (cfg, wl) = tenant_setup(&p, t, tenants);
        let lane = Executor::sharded(Mode::Virtual, 1)
            .block_on(tenant_future(t, p.chain_len, cfg, wl))?;
        twin_lanes.push(lane);
    }
    let single = merge_tenants(twin_lanes, wall.elapsed().as_secs_f64());

    let host_cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let speedup = if windowed.wall_s > 0.0 { single.wall_s / windowed.wall_s } else { 0.0 };

    let mut checks: Vec<(String, bool)> = Vec::new();
    checks.push((
        format!("zero dropped requests ({} failed)", windowed.report.failed),
        windowed.report.failed == 0,
    ));
    checks.push((
        format!(
            "telemetry bytes bounded (recorder {} + billing {} < {})",
            windowed.recorder_bytes, windowed.billing_bytes, RECORDER_BUDGET_BYTES
        ),
        windowed.recorder_bytes + windowed.billing_bytes < RECORDER_BUDGET_BYTES,
    ));
    checks.push((
        format!(
            "every tenant chain fused ({} merges over {} tenants)",
            windowed.merges.len(),
            tenants
        ),
        windowed.merges.len() >= tenants,
    ));
    checks.push((
        format!(
            "threaded verdict transcript identical to sequential twin \
             ({} vs {} entries)",
            windowed.verdicts.len(),
            single.verdicts.len()
        ),
        windowed.verdicts == single.verdicts,
    ));
    checks.push((
        format!(
            "per-tenant RAM ledgers identical across drive modes ({} lanes)",
            windowed.node_ram.len()
        ),
        windowed.node_ram == single.node_ram,
    ));
    checks.push((
        format!(
            "epoch counts identical across drive modes ({} vs {})",
            windowed.epochs, single.epochs
        ),
        windowed.epochs == single.epochs,
    ));
    // The throughput gate only binds at real scale on hardware that can
    // host the fleet; smoke runs record the measured number without
    // failing on a loaded or small runner.
    let binding = host_cores >= workers && workers >= 2 && p.requests >= 200_000;
    let target = 0.75 * workers.min(host_cores) as f64;
    checks.push((
        format!(
            "threaded speedup {speedup:.2}x vs sequential twin \
             ({} workers, {host_cores} cores{})",
            workers,
            if binding {
                format!(", target {target:.2}x")
            } else {
                ", informational at this scale".to_string()
            }
        ),
        !binding || speedup >= target,
    ));

    let fleet_stats = FleetStats {
        tenants,
        workers,
        host_cores,
        windows: fleet.windows,
        worker_stats: fleet.stats,
        speedup,
    };
    let fig = Fig9 {
        params: p,
        windowed,
        full: None,
        single: Some(single),
        traced: None,
        fleet: Some(fleet_stats),
        checks,
    };
    write_output(&out_dir.join("BENCH_scale.json"), &fig.bench_json().to_string())?;
    write_output(&out_dir.join("fig9_summary.txt"), &fig.render())?;
    Ok(fig)
}

/// Run FIG9 and write `BENCH_scale.json` + `fig9_summary.txt` into
/// `out_dir`.
pub fn run(out_dir: &Path, p: Fig9Params) -> Result<Fig9> {
    if p.threads {
        return run_threaded(out_dir, p);
    }
    let windowed = run_once(&p, RecordingLevel::Windowed, p.shards, 0)?;
    let full =
        if p.parity { Some(run_once(&p, RecordingLevel::Full, p.shards, 0)?) } else { None };
    // Shard self-check: replay the same windowed run on a single lane and
    // demand the merged schedule reproduced every platform decision and
    // every node's final RAM balance bit-for-bit.  Only then is the
    // N-shard throughput number comparable to the trajectory baseline.
    let single =
        if p.shards > 1 { Some(run_once(&p, RecordingLevel::Windowed, 1, 0)?) } else { None };
    // Traced twin (ISSUE 9): same run with 1-in-N span sampling armed.
    // Tracing reads the clock only at awaits the request path already
    // takes, so the twin must replay the identical schedule — verdict
    // parity below — while staying inside the trace-ring byte budget.
    let traced = if p.trace_sample > 0 {
        Some(run_once(&p, RecordingLevel::Windowed, p.shards, p.trace_sample)?)
    } else {
        None
    };

    let mut checks: Vec<(String, bool)> = Vec::new();
    checks.push((
        format!("zero dropped requests ({} failed)", windowed.report.failed),
        windowed.report.failed == 0,
    ));
    checks.push((
        format!(
            "telemetry bytes bounded (recorder {} + billing {} < {})",
            windowed.recorder_bytes, windowed.billing_bytes, RECORDER_BUDGET_BYTES
        ),
        windowed.recorder_bytes + windowed.billing_bytes < RECORDER_BUDGET_BYTES,
    ));
    checks.push((
        format!("cost-model admission fused the chain ({} merges)", windowed.merges.len()),
        !windowed.merges.is_empty(),
    ));
    if let Some(full) = &full {
        let same = windowed.verdicts == full.verdicts;
        checks.push((
            format!(
                "fusion verdicts identical to full-retention twin ({} vs {} entries)",
                windowed.verdicts.len(),
                full.verdicts.len()
            ),
            same,
        ));
        checks.push((
            "full-retention twin dropped nothing either".to_string(),
            full.report.failed == 0,
        ));
    }
    if let Some(single) = &single {
        checks.push((
            format!(
                "{}-shard verdict transcript identical to 1-shard ({} vs {} entries)",
                p.shards,
                windowed.verdicts.len(),
                single.verdicts.len()
            ),
            windowed.verdicts == single.verdicts,
        ));
        checks.push((
            format!(
                "per-node RAM ledgers identical across shard counts ({} nodes)",
                windowed.node_ram.len()
            ),
            windowed.node_ram == single.node_ram,
        ));
    }

    if let Some(traced) = &traced {
        checks.push((
            format!(
                "traced twin replayed the schedule bit-for-bit ({} vs {} verdicts)",
                traced.verdicts.len(),
                windowed.verdicts.len()
            ),
            traced.verdicts == windowed.verdicts
                && traced.report.failed == windowed.report.failed,
        ));
        checks.push((
            format!(
                "every sampled trace conserved ({} retained, {} violations)",
                traced.trace_retained, traced.trace_violations
            ),
            traced.trace_retained > 0 && traced.trace_violations == 0,
        ));
        checks.push((
            format!(
                "trace ring bounded ({} bytes < {})",
                traced.trace_bytes, TRACE_BUDGET_BYTES
            ),
            traced.trace_bytes < TRACE_BUDGET_BYTES,
        ));
    }

    let fig = Fig9 { params: p, windowed, full, single, traced, fleet: None, checks };
    write_output(&out_dir.join("BENCH_scale.json"), &fig.bench_json().to_string())?;
    write_output(&out_dir.join("fig9_summary.txt"), &fig.render())?;
    Ok(fig)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig9_reduced_scale_parity_and_bounds() {
        // Reduced budget for the test tier; the CLI smoke and the real 1M
        // point exercise the same driver.
        let mut p = Fig9Params::defaults(true);
        p.requests = 3_000;
        p.rate_rps = 200.0;
        p.compute = ComputeMode::Disabled;
        let dir = std::env::temp_dir().join("provuse_fig9_test");
        let fig = run(&dir, p).unwrap();
        assert!(fig.passed(), "{}", fig.render());
        let full = fig.full.as_ref().expect("parity twin must run");
        assert_eq!(fig.windowed.verdicts, full.verdicts);
        assert!(fig.windowed.recorder_bytes < full.recorder_bytes);
        // traced twin: sampled, conserved, bounded, schedule-transparent
        let traced = fig.traced.as_ref().expect("traced twin must run");
        assert!(traced.trace_retained > 0);
        assert_eq!(traced.trace_violations, 0);
        assert_eq!(traced.verdicts, fig.windowed.verdicts);
        assert!(dir.join("BENCH_scale.json").exists());
        let json = std::fs::read_to_string(dir.join("BENCH_scale.json")).unwrap();
        let v = Json::parse(&json).unwrap();
        assert!(v.get("wall_time_s").unwrap().as_f64().unwrap() > 0.0);
        assert!(v.get("requests_per_sec").unwrap().as_f64().unwrap() > 0.0);
        assert!(v.get("recorder_bytes").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(v.get("trace_sample").unwrap().as_f64().unwrap(), 64.0);
        assert!(v.get("trace_bytes").unwrap().as_f64().unwrap() > 0.0);
        assert!(v.get("trace_overhead_pct").is_some());
    }

    #[test]
    fn fig9_shard_parity_small_scale() {
        // 3 lanes over a 3-node cluster must replay the 1-shard schedule
        // bit-for-bit (the driver runs the twin itself and records the
        // comparison as checks).  Full-retention parity is skipped here —
        // the shard axis is what's under test.
        let mut p = Fig9Params::defaults(true);
        p.requests = 1_200;
        p.rate_rps = 200.0;
        p.compute = ComputeMode::Disabled;
        p.parity = false;
        p.shards = 3;
        p.nodes = 3;
        p.trace_sample = 0; // the shard axis is what's under test
        let dir = std::env::temp_dir().join("provuse_fig9_shard_test");
        let fig = run(&dir, p).unwrap();
        assert!(fig.passed(), "{}", fig.render());
        assert!(fig.traced.is_none());
        let single = fig.single.as_ref().expect("1-shard twin must run");
        assert_eq!(fig.windowed.verdicts, single.verdicts);
        assert_eq!(fig.windowed.node_ram, single.node_ram);
        assert!(!fig.windowed.node_ram.is_empty());
        let json = std::fs::read_to_string(dir.join("BENCH_scale.json")).unwrap();
        let v = Json::parse(&json).unwrap();
        assert_eq!(v.get("shards").unwrap().as_f64().unwrap(), 3.0);
        assert_eq!(v.get("shard_parity_checked").unwrap(), &Json::Bool(true));
    }

    #[test]
    fn fig9_threaded_fleet_parity_small_scale() {
        // 2 worker threads over a 3-tenant fleet: the merged transcript,
        // per-tenant RAM ledgers, and epoch counts must be bit-identical
        // to the same fleet driven sequentially on one thread — the
        // driver runs the twin itself and records the comparisons.
        let mut p = Fig9Params::defaults(true);
        p.requests = 2_400;
        p.rate_rps = 300.0;
        p.compute = ComputeMode::Disabled;
        p.parity = false;
        p.shards = 2;
        p.nodes = 3;
        p.trace_sample = 0;
        p.threads = true;
        let dir = std::env::temp_dir().join("provuse_fig9_threads_test");
        let fig = run(&dir, p).unwrap();
        assert!(fig.passed(), "{}", fig.render());
        let fl = fig.fleet.as_ref().expect("fleet stats must be recorded");
        assert_eq!((fl.workers, fl.tenants), (2, 3));
        assert!(fl.windows > 0, "the epoch gate must be exercised");
        let single = fig.single.as_ref().expect("sequential twin must run");
        assert_eq!(fig.windowed.verdicts, single.verdicts);
        assert!(!fig.windowed.verdicts.is_empty());
        assert_eq!(fig.windowed.node_ram, single.node_ram);
        assert_eq!(fig.windowed.epochs, single.epochs);
        assert_eq!(fig.windowed.report.issued, p.requests);
        let json = std::fs::read_to_string(dir.join("BENCH_scale.json")).unwrap();
        let v = Json::parse(&json).unwrap();
        assert_eq!(v.get("threads").unwrap(), &Json::Bool(true));
        assert_eq!(v.get("workers").unwrap().as_f64().unwrap(), 2.0);
        assert_eq!(v.get("tenants").unwrap().as_f64().unwrap(), 3.0);
        assert_eq!(v.get("milestone").unwrap().as_str().unwrap(), "parallel-event-loop");
    }
}
