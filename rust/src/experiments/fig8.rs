//! FIG8 (ours) — the cluster subsystem end to end, in two self-checked
//! scenarios selected by `--placement`:
//!
//! * **fusion-affinity** (default; also accepts bin-pack): placement,
//!   fusion, and the node-pressure controller on one multi-node platform.
//!   Three phases on the virtual clock:
//!   1. *calm* — the affinity scheduler co-locates the app's hot sync
//!      group at deploy, so fusion proceeds with **zero co-location
//!      migrations**; the group converges to one fused instance.
//!   2. *pressure* — a targeted workload inflates the fused group past its
//!      RAM cap; the defusion controller splits it, the per-function
//!      replacements re-inflate the **node** past its capacity, and the
//!      node-pressure controller resolves with **exactly one** migration
//!      (or, when nothing movable fits, one eviction/split) — zero
//!      dropped requests throughout.
//!   3. *relief* — traffic calms; every node ends under capacity and the
//!      anti-flap cooldowns keep the topology quiet.
//! * **spread** — the measured negative control: the same app deployed
//!   spread-across-nodes with fusion off, against a single-node reference
//!   run with identical traffic.  Cross-node sync hops pay the east-west
//!   surcharge, and the checklist requires the spread p95 to exceed the
//!   single-node p95 by at least one `cross_node_ms` — the latency the
//!   fusion-affinity scheduler exists to avoid.
//!
//! `--app chain` (default) is the calibrated scenario CI runs; `iot` and
//! `mixed` reuse their FIG7 apps with best-effort capacity defaults.

use std::path::Path;
use std::rc::Rc;

use super::write_output;
use crate::apps;
use crate::cluster::NodeId;
use crate::config::{
    ComputeMode, MergePolicyKind, PlacementPolicy, PlatformConfig, SplitPolicyKind,
    WorkloadConfig,
};
use crate::error::Result;
use crate::exec::{self, Executor, Mode};
use crate::fusion::SplitReason;
use crate::metrics::{
    EvictEvent, LatencySample, MergeEvent, MigrationEvent, NodeRamSample, SplitEvent,
};
use crate::platform::Platform;
use crate::workload::{self, Arrival, WorkloadReport};

pub use super::fig7::Check;

/// Which application FIG8 drives (reusing the FIG7 benchmark apps).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fig8App {
    Chain,
    Iot,
    Mixed,
}

impl Fig8App {
    pub fn name(&self) -> &'static str {
        match self {
            Fig8App::Chain => "chain",
            Fig8App::Iot => "iot",
            Fig8App::Mixed => "mixed",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "chain" => Ok(Fig8App::Chain),
            "iot" | "iot-heavy" => Ok(Fig8App::Iot),
            "mixed" => Ok(Fig8App::Mixed),
            other => Err(crate::error::Error::Config(format!(
                "unknown figure8 app `{other}` (available: chain, iot, mixed)"
            ))),
        }
    }

    fn spec(&self) -> apps::AppSpec {
        match self {
            Fig8App::Chain => apps::chain(4),
            Fig8App::Iot => apps::iot_heavy(),
            Fig8App::Mixed => apps::mixed(),
        }
    }

    /// The function the pressure workload targets — the entry of the
    /// app's hot sync group.
    fn hot_probe(&self) -> &'static str {
        match self {
            Fig8App::Chain => "s0",
            Fig8App::Iot => "ingest",
            Fig8App::Mixed => "heavy_api",
        }
    }

    /// The statically predicted hot sync group (sorted).
    fn hot_group(&self) -> Vec<String> {
        let spec = self.spec();
        let probe = self.hot_probe();
        spec.sync_fusion_groups()
            .into_iter()
            .find(|g| g.iter().any(|f| f == probe))
            .unwrap_or_else(|| vec![probe.to_string()])
    }
}

/// FIG8 knobs (one struct shared by the CLI, tests, and CI smoke).
#[derive(Debug, Clone, Copy)]
pub struct Fig8Params {
    pub app: Fig8App,
    pub nodes: usize,
    pub placement: PlacementPolicy,
    /// per-node RAM capacity (MiB) in the affinity scenario (the spread
    /// control runs uncapped: it measures latency, not pressure)
    pub node_capacity_mb: f64,
    /// fused-group RAM cap (`max_group_ram_mb`): the pressure phase's
    /// defusion trigger
    pub group_ram_cap_mb: f64,
    pub calm_rps: f64,
    /// rate of the targeted hot-route workload during the pressure phase
    pub pressure_rps: f64,
    pub phase_a_secs: f64,
    pub phase_b_secs: f64,
    pub phase_c_secs: f64,
    pub seed: u64,
    pub compute: ComputeMode,
    /// sized to outlast the run from the split onward (anti-flap)
    pub cooldown_ms: f64,
    pub feedback_interval_ms: f64,
    pub hysteresis: u32,
    pub min_observations: u32,
    pub image_build_ms: f64,
    pub boot_ms: f64,
    pub cross_node_ms: f64,
}

impl Fig8Params {
    /// Full-scale chain scenario (`provuse figure8`).
    ///
    /// Capacity calibration (chain(4), tiny RAM model): four singletons
    /// idle at 4 x (58 + 12) = 280 MiB, the fused group at 106 MiB.  The
    /// 310 MiB node capacity admits the co-located unfused group with
    /// headroom for calm working sets, while the post-split pressure
    /// regime (280 MiB + tens of in-flight working sets) overshoots it;
    /// the 115 MiB group cap admits the fused group under calm load and
    /// trips under pressure (the FIG7 calibration).
    pub fn paper_scale() -> Self {
        Fig8Params {
            app: Fig8App::Chain,
            nodes: 3,
            placement: PlacementPolicy::FusionAffinity,
            node_capacity_mb: 310.0,
            group_ram_cap_mb: 115.0,
            calm_rps: 2.0,
            pressure_rps: 60.0,
            phase_a_secs: 60.0,
            phase_b_secs: 60.0,
            phase_c_secs: 60.0,
            seed: 8,
            compute: ComputeMode::Disabled,
            cooldown_ms: 180_000.0,
            feedback_interval_ms: 2_000.0,
            hysteresis: 2,
            min_observations: 8,
            image_build_ms: 4_000.0,
            boot_ms: 1_200.0,
            cross_node_ms: 12.0,
        }
    }

    /// Scaled-down chain variant for `cargo test` / the CI smoke job.
    pub fn smoke() -> Self {
        Fig8Params {
            phase_a_secs: 15.0,
            phase_b_secs: 30.0,
            phase_c_secs: 15.0,
            cooldown_ms: 60_000.0,
            feedback_interval_ms: 1_000.0,
            image_build_ms: 300.0,
            boot_ms: 150.0,
            ..Self::paper_scale()
        }
    }

    /// Best-effort defaults for `app` (chain is the calibrated scenario;
    /// iot/mixed reuse their FIG7 apps and may need explicit capacities).
    pub fn for_app(app: Fig8App, smoke: bool) -> Self {
        let base = if smoke { Self::smoke() } else { Self::paper_scale() };
        match app {
            Fig8App::Chain => base,
            // iot-heavy hot group: 68 + 458 + 70 = 596 MiB unfused,
            // 536 MiB fused
            Fig8App::Iot => Fig8Params {
                app,
                node_capacity_mb: 660.0,
                group_ram_cap_mb: 560.0,
                pressure_rps: 40.0,
                ..base
            },
            // mixed heavy pair: 526 MiB unfused, 468 MiB fused
            Fig8App::Mixed => Fig8Params {
                app,
                node_capacity_mb: 545.0,
                group_ram_cap_mb: 480.0,
                pressure_rps: 40.0,
                min_observations: 3,
                ..base
            },
        }
    }
}

/// The spread negative control's paired measurement.
#[derive(Debug, Clone)]
pub struct SpreadControl {
    pub spread_p95_ms: f64,
    pub single_p95_ms: f64,
    /// distinct nodes the hot group landed on under spread
    pub spread_nodes_used: usize,
    pub spread_cross_calls: u64,
    pub single_cross_calls: u64,
    pub spread_failed: u64,
    pub single_failed: u64,
}

/// Output of the FIG8 experiment.
pub struct Fig8 {
    pub params: Fig8Params,
    pub merges: Vec<MergeEvent>,
    pub splits: Vec<SplitEvent>,
    pub evicts: Vec<EvictEvent>,
    pub migrations: Vec<MigrationEvent>,
    pub node_ram: Vec<NodeRamSample>,
    pub latency: Vec<LatencySample>,
    pub reports: Vec<(&'static str, WorkloadReport)>,
    pub phase_end_ms: Vec<f64>,
    /// node of each hot-group member right after deploy
    pub deploy_nodes: Vec<(String, Option<NodeId>)>,
    /// (node, ram, capacity) at the end of the run
    pub final_node_ram: Vec<(NodeId, f64, f64)>,
    pub cross_node_calls: u64,
    pub final_distinct_instances: usize,
    /// present only under `--placement spread`
    pub control: Option<SpreadControl>,
    /// canonical Recorder exports captured before the platform dropped
    /// (one format definition — see `Recorder::latency_csv` /
    /// `Recorder::node_ram_csv`)
    latency_csv: String,
    node_ram_csv: String,
}

impl Fig8 {
    fn hot_group(&self) -> Vec<String> {
        self.params.app.hot_group()
    }

    pub fn first_split(&self) -> Option<&SplitEvent> {
        self.splits.first()
    }

    /// Migrations the node-pressure controller ordered (co-location moves
    /// are a different reason and counted separately).
    pub fn pressure_migrations(&self) -> Vec<&MigrationEvent> {
        self.migrations.iter().filter(|m| m.reason == "node_pressure").collect()
    }

    /// Splits the group-cap defusion controller ordered (the calibrated
    /// pressure-phase trigger), as opposed to node-pressure fallbacks.
    fn group_cap_splits(&self) -> Vec<&SplitEvent> {
        self.splits.iter().filter(|s| s.reason != SplitReason::NodePressure).collect()
    }

    /// Splits the node-pressure controller fell back to when nothing
    /// movable fit anywhere — a valid pressure resolution.
    fn pressure_splits(&self) -> Vec<&SplitEvent> {
        self.splits.iter().filter(|s| s.reason == SplitReason::NodePressure).collect()
    }

    pub fn colocation_migrations(&self) -> Vec<&MigrationEvent> {
        self.migrations.iter().filter(|m| m.reason == "fusion_colocation").collect()
    }

    pub fn checks(&self) -> Vec<Check> {
        match &self.control {
            Some(control) => self.checks_spread(control),
            None => self.checks_affinity(),
        }
    }

    fn checks_affinity(&self) -> Vec<Check> {
        let mut out = Vec::new();
        let end_a = self.phase_end_ms.first().copied().unwrap_or(f64::NAN);
        let end_b = self.phase_end_ms.get(1).copied().unwrap_or(f64::NAN);

        let home = self.deploy_nodes.first().and_then(|(_, n)| *n);
        let colocated = home.is_some()
            && self.deploy_nodes.iter().all(|(_, n)| *n == home)
            && self.deploy_nodes.len() == self.hot_group().len();
        out.push(Check {
            label: "hot sync group co-located at deploy",
            pass: colocated,
            detail: format!(
                "{:?}",
                self.deploy_nodes
                    .iter()
                    .map(|(f, n)| format!("{f}@{}", n.map(|n| n.to_string()).unwrap_or_default()))
                    .collect::<Vec<_>>()
            ),
        });

        let fused_in_calm =
            self.merges.first().map(|m| m.t_ms < end_a).unwrap_or(false);
        out.push(Check {
            label: "hot group fuses under calm load with zero co-location migrations",
            pass: fused_in_calm && self.colocation_migrations().is_empty(),
            detail: format!(
                "{} merges (first at t={:.1}s), {} co-location migrations",
                self.merges.len(),
                self.merges.first().map(|m| m.t_ms / 1e3).unwrap_or(f64::NAN),
                self.colocation_migrations().len()
            ),
        });

        let split_ok = self.group_cap_splits().len() == 1
            && self
                .group_cap_splits()
                .first()
                .map(|s| s.reason == SplitReason::RamCap && s.t_ms > end_a && s.t_ms < end_b)
                .unwrap_or(false);
        out.push(Check {
            label: "pressure trips the group RAM cap exactly once",
            pass: split_ok,
            detail: match self.first_split() {
                Some(s) => format!(
                    "{} split(s); first [{}] at t={:.1}s, reason {}",
                    self.splits.len(),
                    s.functions.join("+"),
                    s.t_ms / 1e3,
                    s.reason.name()
                ),
                None => "no split event".into(),
            },
        });

        // the node-pressure controller's resolution is a migration, an
        // eviction, or — when nothing movable fits anywhere — its split
        // fallback; any one of them, exactly once
        let resolutions = self.pressure_migrations().len()
            + self.evicts.len()
            + self.pressure_splits().len();
        out.push(Check {
            label: "node pressure resolves with exactly one migration-or-defusion",
            pass: resolutions == 1,
            detail: format!(
                "{} pressure migration(s) [{}], {} evict(s), {} node-pressure split(s)",
                self.pressure_migrations().len(),
                self.pressure_migrations()
                    .iter()
                    .map(|m| format!("{}->{} at {:.1}s", m.from, m.to, m.t_ms / 1e3))
                    .collect::<Vec<_>>()
                    .join(", "),
                self.evicts.len(),
                self.pressure_splits().len()
            ),
        });

        let capped_ok = self
            .final_node_ram
            .iter()
            .all(|(_, ram, cap)| *cap <= 0.0 || ram <= cap);
        out.push(Check {
            label: "every node ends under its RAM capacity",
            pass: capped_ok,
            detail: format!(
                "[{}]",
                self.final_node_ram
                    .iter()
                    .map(|(n, ram, cap)| format!("{n}: {ram:.0}/{cap:.0} MiB"))
                    .collect::<Vec<_>>()
                    .join(", ")
            ),
        });

        // cooldowns hold from the split on, so the run must end split
        // apart: one routed instance per hot-group member (plus any
        // functions outside the group)
        let no_reflap = match self.first_split() {
            Some(s) => self.merges.iter().all(|m| m.t_ms < s.t_ms),
            None => false,
        } && self.final_distinct_instances >= self.hot_group().len();
        out.push(Check {
            label: "no re-fusion or further moves after the corrective action",
            pass: no_reflap && resolutions <= 1,
            detail: format!(
                "merges at [{}]; {} final routed instances",
                self.merges
                    .iter()
                    .map(|m| format!("{:.1}s", m.t_ms / 1e3))
                    .collect::<Vec<_>>()
                    .join(", "),
                self.final_distinct_instances
            ),
        });

        out.push(self.zero_drops_check());
        out
    }

    fn checks_spread(&self, control: &SpreadControl) -> Vec<Check> {
        let mut out = Vec::new();
        out.push(Check {
            label: "spread placement lands the hot group on multiple nodes",
            pass: control.spread_nodes_used >= 2,
            detail: format!(
                "{} distinct nodes for {:?}",
                control.spread_nodes_used,
                self.hot_group()
            ),
        });
        out.push(Check {
            label: "cross-node hops occur under spread and never on one node",
            pass: control.spread_cross_calls > 0 && control.single_cross_calls == 0,
            detail: format!(
                "spread {} cross-node calls, single-node {}",
                control.spread_cross_calls, control.single_cross_calls
            ),
        });
        let gap = control.spread_p95_ms - control.single_p95_ms;
        out.push(Check {
            label: "cross-node placement is visible in p95",
            pass: gap.is_finite() && gap >= self.params.cross_node_ms,
            detail: format!(
                "spread p95 {:.1} ms vs single-node p95 {:.1} ms (gap {:.1} >= {:.1})",
                control.spread_p95_ms, control.single_p95_ms, gap, self.params.cross_node_ms
            ),
        });
        out.push(Check {
            label: "zero dropped requests in both runs",
            pass: control.spread_failed == 0 && control.single_failed == 0,
            detail: format!(
                "spread {} failed, single-node {} failed",
                control.spread_failed, control.single_failed
            ),
        });
        out
    }

    fn zero_drops_check(&self) -> Check {
        let all_served = self.reports.iter().all(|(_, r)| r.failed == 0);
        Check {
            label: "zero dropped requests across all phases",
            pass: all_served,
            detail: self
                .reports
                .iter()
                .map(|(l, r)| format!("{l}: {}/{} ok", r.ok, r.issued))
                .collect::<Vec<_>>()
                .join(", "),
        }
    }

    pub fn passed(&self) -> bool {
        self.checks().iter().all(|c| c.pass)
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "FIG8/{}: cluster subsystem ({} nodes, {} placement)\n",
            self.params.app.name(),
            self.params.nodes,
            self.params.placement.name()
        ));
        for (label, report) in &self.reports {
            out.push_str(&format!("  {label:<15}: {}\n", report.summary()));
        }
        if let Some(control) = &self.control {
            out.push_str(&format!(
                "  control   : spread p95 {:.1} ms vs single-node p95 {:.1} ms ({} cross-node calls)\n",
                control.spread_p95_ms, control.single_p95_ms, control.spread_cross_calls
            ));
        } else {
            out.push_str(&format!(
                "  events    : {} merges, {} splits, {} evicts, {} migrations ({} for co-location)\n",
                self.merges.len(),
                self.splits.len(),
                self.evicts.len(),
                self.migrations.len(),
                self.colocation_migrations().len()
            ));
            out.push_str(&format!(
                "  cross-node: {} calls over the whole run\n",
                self.cross_node_calls
            ));
        }
        for c in self.checks() {
            out.push_str(&format!(
                "  [{}] {} — {}\n",
                if c.pass { "PASS" } else { "FAIL" },
                c.label,
                c.detail
            ));
        }
        out
    }
}

fn base_config(p: &Fig8Params, placement: PlacementPolicy, nodes: usize) -> PlatformConfig {
    // Default RecordingLevel::Full on purpose (ISSUE 7 recording audit):
    // fig8 exports raw `fig8_latency.csv` / `fig8_node_ram.csv` and its
    // migration-phase analysis windows over the whole run — Full-only
    // queries.  Drivers without raw exports run Windowed (fig6, sweeps).
    let mut cfg = PlatformConfig::tiny().with_compute(p.compute).with_seed(p.seed);
    cfg.cluster.nodes = nodes;
    cfg.cluster.placement = placement;
    cfg.latency.image_build_ms = p.image_build_ms;
    cfg.latency.boot_ms = p.boot_ms;
    cfg.latency.cross_node_ms = p.cross_node_ms;
    cfg.fusion.min_observations = p.min_observations;
    cfg.fusion.cooldown_ms = p.cooldown_ms;
    cfg.fusion.max_group_ram_mb = p.group_ram_cap_mb;
    cfg.fusion.feedback_interval_ms = p.feedback_interval_ms;
    cfg.fusion.split_hysteresis_windows = p.hysteresis;
    cfg.fusion.split_policy = SplitPolicyKind::Threshold;
    cfg.fusion.merge_policy = MergePolicyKind::ObservationCount;
    cfg
}

/// Run FIG8 and write its CSVs + summary into `out_dir`.
pub fn run(out_dir: &Path, params: Fig8Params) -> Result<Fig8> {
    if params.nodes < 2 {
        return Err(crate::error::Error::Config(
            "figure8 needs --nodes >= 2 (the cluster scenario is the point)".into(),
        ));
    }
    let fig = match params.placement {
        PlacementPolicy::Spread => run_spread_control(params)?,
        _ => run_affinity(params)?,
    };

    write_output(&out_dir.join("fig8_latency.csv"), &fig.latency_csv)?;
    write_output(&out_dir.join("fig8_node_ram.csv"), &fig.node_ram_csv)?;
    let mut events = String::from("t_ms,event,duration_ms,detail,functions\n");
    for m in &fig.merges {
        events.push_str(&format!(
            "{:.3},merge,{:.3},,{}\n",
            m.t_ms,
            m.duration_ms,
            m.functions.join("+")
        ));
    }
    for s in &fig.splits {
        events.push_str(&format!(
            "{:.3},split,{:.3},{},{}\n",
            s.t_ms,
            s.duration_ms,
            s.reason.name(),
            s.functions.join("+")
        ));
    }
    for e in &fig.evicts {
        events.push_str(&format!(
            "{:.3},evict,{:.3},{},{}\n",
            e.t_ms,
            e.duration_ms,
            e.reason.name(),
            e.group.join("+")
        ));
    }
    for m in &fig.migrations {
        events.push_str(&format!(
            "{:.3},migrate,{:.3},{} {}->{},{}\n",
            m.t_ms,
            m.duration_ms,
            m.reason,
            m.from,
            m.to,
            m.functions.join("+")
        ));
    }
    write_output(&out_dir.join("fig8_events.csv"), &events)?;
    write_output(&out_dir.join("fig8_summary.txt"), &fig.render())?;
    Ok(fig)
}

/// The three-phase fusion-affinity (or bin-pack) scenario.
fn run_affinity(params: Fig8Params) -> Result<Fig8> {
    Executor::new(Mode::Virtual).block_on(async move {
        let mut cfg = base_config(&params, params.placement, params.nodes);
        cfg.cluster.node_capacity_mb = params.node_capacity_mb;
        let app = params.app.spec();
        let hot_group = params.app.hot_group();
        let hot_probe = params.app.hot_probe();

        let platform = Platform::deploy(app, cfg).await?;
        let deploy_nodes: Vec<(String, Option<NodeId>)> = hot_group
            .iter()
            .map(|f| (f.clone(), platform.node_of_function(f)))
            .collect();

        let mut reports: Vec<(&'static str, WorkloadReport)> = Vec::new();
        let mut phase_end_ms = Vec::new();
        let phases: [(&'static str, f64); 3] = [
            ("calm", params.phase_a_secs),
            ("pressure", params.phase_b_secs),
            ("relief", params.phase_c_secs),
        ];
        for (i, (label, secs)) in phases.iter().enumerate() {
            let entry_wl = WorkloadConfig {
                requests: (params.calm_rps * secs).round() as u64,
                rate_rps: params.calm_rps,
                seed: params.seed.wrapping_add(i as u64),
                timeout_ms: 120_000.0,
            };
            if *label == "pressure" {
                let hot_wl = WorkloadConfig {
                    requests: (params.pressure_rps * secs).round() as u64,
                    rate_rps: params.pressure_rps,
                    seed: params.seed.wrapping_add(0x8EED + i as u64),
                    timeout_ms: 120_000.0,
                };
                let entry = exec::spawn(workload::run(Rc::clone(&platform), entry_wl));
                let hot = exec::spawn(workload::run_targeted(
                    Rc::clone(&platform),
                    hot_wl,
                    Arrival::Constant,
                    Some(hot_probe),
                ));
                reports.push(("pressure", entry.await?));
                reports.push(("pressure-hot", hot.await?));
            } else {
                reports.push((*label, workload::run(Rc::clone(&platform), entry_wl).await?));
            }
            // let in-flight pipelines land before the phase probe
            exec::sleep_ms(2_000.0).await;
            phase_end_ms.push(platform.metrics.rel_now_ms());
        }
        // let drains and the pressure resolution settle
        exec::sleep_ms(10_000.0).await;
        platform.shutdown();

        let final_node_ram: Vec<(NodeId, f64, f64)> = platform
            .cluster
            .nodes()
            .iter()
            .map(|n| (n.id(), n.ram_mb(), n.capacity_mb()))
            .collect();
        let m = &platform.metrics;
        Ok(Fig8 {
            params,
            merges: m.merges(),
            splits: m.splits(),
            evicts: m.evicts(),
            migrations: m.migrations(),
            node_ram: m.node_ram_series(),
            latency: m.latencies(),
            reports,
            phase_end_ms,
            deploy_nodes,
            final_node_ram,
            cross_node_calls: m.counter("cross_node_calls"),
            final_distinct_instances: platform.gateway.distinct_instances(),
            control: None,
            latency_csv: m.latency_csv(),
            node_ram_csv: m.node_ram_csv(),
        })
    })
}

/// The spread negative control: spread-vanilla vs single-node-vanilla on
/// identical traffic; the p95 gap is the measured cross-node cost.
fn run_spread_control(params: Fig8Params) -> Result<Fig8> {
    // identical open-loop traffic for both runs (same seed, same schedule)
    let wl = WorkloadConfig {
        requests: (params.calm_rps * (params.phase_a_secs + params.phase_b_secs)).round()
            as u64,
        rate_rps: params.calm_rps,
        seed: params.seed,
        timeout_ms: 120_000.0,
    };

    let spread = Executor::new(Mode::Virtual).block_on({
        let wl = wl.clone();
        async move {
            // uncapped + vanilla: this run measures placement latency only
            let cfg = base_config(&params, PlacementPolicy::Spread, params.nodes).vanilla();
            let app = params.app.spec();
            let hot_group = params.app.hot_group();
            let platform = Platform::deploy(app, cfg).await?;
            let deploy_nodes: Vec<(String, Option<NodeId>)> = hot_group
                .iter()
                .map(|f| (f.clone(), platform.node_of_function(f)))
                .collect();
            let report = workload::run(Rc::clone(&platform), wl).await?;
            exec::sleep_ms(5_000.0).await;
            platform.shutdown();
            let m = &platform.metrics;
            Ok::<_, crate::error::Error>((
                deploy_nodes,
                report,
                m.latencies(),
                m.node_ram_series(),
                m.counter("cross_node_calls"),
                m.latency_csv(),
                m.node_ram_csv(),
            ))
        }
    })?;

    let single = Executor::new(Mode::Virtual).block_on(async move {
        let cfg = base_config(&params, PlacementPolicy::BinPack, 1).vanilla();
        let platform = Platform::deploy(params.app.spec(), cfg).await?;
        let report = workload::run(Rc::clone(&platform), wl).await?;
        exec::sleep_ms(5_000.0).await;
        platform.shutdown();
        let cross = platform.metrics.counter("cross_node_calls");
        Ok::<_, crate::error::Error>((report, cross))
    })?;

    let (deploy_nodes, spread_report, latency, node_ram, spread_cross, latency_csv, node_ram_csv) =
        spread;
    let (single_report, single_cross) = single;
    let spread_nodes_used = {
        let mut nodes: Vec<Option<NodeId>> =
            deploy_nodes.iter().map(|(_, n)| *n).collect();
        nodes.sort();
        nodes.dedup();
        nodes.len()
    };
    let control = SpreadControl {
        spread_p95_ms: spread_report.latency.p95(),
        single_p95_ms: single_report.latency.p95(),
        spread_nodes_used,
        spread_cross_calls: spread_cross,
        single_cross_calls: single_cross,
        spread_failed: spread_report.failed,
        single_failed: single_report.failed,
    };
    Ok(Fig8 {
        params,
        merges: Vec::new(),
        splits: Vec::new(),
        evicts: Vec::new(),
        migrations: Vec::new(),
        node_ram,
        latency,
        reports: vec![("spread", spread_report), ("single-node", single_report)],
        phase_end_ms: Vec::new(),
        deploy_nodes,
        final_node_ram: Vec::new(),
        cross_node_calls: spread_cross,
        final_distinct_instances: 0,
        control: Some(control),
        latency_csv,
        node_ram_csv,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig8_affinity_cluster_scenario_at_smoke_scale() {
        let dir = std::env::temp_dir().join("provuse_fig8_test");
        let fig = run(&dir, Fig8Params::smoke()).unwrap();
        for c in fig.checks() {
            assert!(c.pass, "{} — {}\n{}", c.label, c.detail, fig.render());
        }
        // the corrective action was a pressure migration (the empty
        // neighbor nodes can absorb a chain singleton), and it genuinely
        // moved an instance off the packed node
        let pressure = fig.pressure_migrations();
        assert_eq!(pressure.len(), 1, "{:?}", fig.migrations);
        assert_ne!(pressure[0].from, pressure[0].to);
        // the node-pressure episode is visible in the per-node series:
        // some tick saw the home node over its capacity
        let home = fig.deploy_nodes[0].1.unwrap();
        assert!(
            fig.node_ram
                .iter()
                .any(|s| s.node == home && s.capacity_mb > 0.0 && s.ram_mb > s.capacity_mb),
            "no over-capacity tick recorded for {home}"
        );
        assert!(dir.join("fig8_events.csv").exists());
        assert!(dir.join("fig8_node_ram.csv").exists());
        let events = std::fs::read_to_string(dir.join("fig8_events.csv")).unwrap();
        assert!(events.contains("migrate"));
        assert!(events.contains("node_pressure"));
    }

    #[test]
    fn fig8_spread_negative_control_at_smoke_scale() {
        let mut p = Fig8Params::smoke();
        p.placement = PlacementPolicy::Spread;
        let dir = std::env::temp_dir().join("provuse_fig8_spread_test");
        let fig = run(&dir, p).unwrap();
        for c in fig.checks() {
            assert!(c.pass, "{} — {}\n{}", c.label, c.detail, fig.render());
        }
        let control = fig.control.as_ref().unwrap();
        assert!(control.spread_p95_ms > control.single_p95_ms);
        assert!(dir.join("fig8_summary.txt").exists());
    }

    #[test]
    fn fig8_rejects_single_node() {
        let mut p = Fig8Params::smoke();
        p.nodes = 1;
        let dir = std::env::temp_dir().join("provuse_fig8_reject");
        assert!(run(&dir, p).is_err());
    }
}
