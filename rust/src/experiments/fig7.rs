//! FIG7 (ours) — the feedback loop the paper's fuse-once pipeline lacks:
//! a phase-shifted workload drives **fusion under calm load**, then a
//! memory-pressure phase pushes the fused group past its RAM cap and the
//! controller **defuses** it (a [`SplitEvent`]), latency returns to the
//! pre-fusion baseline, and after the pressure lifts (and the anti-flap
//! cooldown expires) the platform **re-fuses** and converges again.
//!
//! Three phases on one live platform, all on the virtual clock and fully
//! deterministic per seed:
//!
//! 1. `calm`     — low rate; the chain fuses into one instance.
//! 2. `pressure` — high rate; per-request working sets blow the fused
//!    group past `max_group_ram_mb` → hysteresis strikes → split.
//! 3. `relief`   — low rate again; the cooldown expires and the pair
//!    re-fuses with no further splits (no flapping).

use std::path::Path;
use std::rc::Rc;

use super::write_output;
use crate::apps;
use crate::config::{ComputeMode, PlatformConfig, WorkloadConfig};
use crate::error::Result;
use crate::exec::{self, Executor, Mode};
use crate::fusion::SplitReason;
use crate::metrics::{
    GroupRamSample, LatencySample, MergeEvent, RamSample, SplitEvent, MIN_WINDOW_SAMPLES,
};
use crate::platform::Platform;
use crate::util::stats::Quantiles;
use crate::workload::{self, WorkloadReport};

/// FIG7 knobs (one struct so the CLI, the bench harness, and the smoke
/// test share the same driver).
#[derive(Debug, Clone, Copy)]
pub struct Fig7Params {
    /// rate of the calm and relief phases (rps)
    pub calm_rps: f64,
    /// rate of the memory-pressure phase (rps)
    pub pressure_rps: f64,
    pub phase_a_secs: f64,
    pub phase_b_secs: f64,
    pub phase_c_secs: f64,
    pub seed: u64,
    pub compute: ComputeMode,
    /// RAM cap for fused groups (MiB)
    pub max_group_ram_mb: f64,
    /// p95 regression fraction that also triggers defusion
    pub split_p95_regression: f64,
    /// anti-flap cooldown; sized to outlast the remaining pressure phase
    pub cooldown_ms: f64,
    pub feedback_interval_ms: f64,
    pub hysteresis: u32,
    pub min_observations: u32,
    pub image_build_ms: f64,
    pub boot_ms: f64,
}

impl Fig7Params {
    /// Full-scale run (the shipped FIG7 numbers): 60 s per phase with the
    /// calibrated tinyFaaS merge latencies.
    pub fn paper_scale() -> Self {
        Fig7Params {
            calm_rps: 2.0,
            pressure_rps: 60.0,
            phase_a_secs: 60.0,
            phase_b_secs: 60.0,
            phase_c_secs: 60.0,
            seed: 7,
            compute: ComputeMode::Disabled,
            // chain(4) fused idle RAM = 58 base + 4 x 12 code = 106 MiB;
            // the cap admits ~6 in-flight working sets, which calm traffic
            // never reaches and pressure traffic always exceeds
            max_group_ram_mb: 115.0,
            split_p95_regression: 0.5,
            cooldown_ms: 60_000.0,
            feedback_interval_ms: 2_000.0,
            hysteresis: 2,
            min_observations: 8,
            image_build_ms: 4_000.0,
            boot_ms: 1_200.0,
        }
    }

    /// Scaled-down variant for `cargo test` / the CI smoke job.
    pub fn smoke() -> Self {
        Fig7Params {
            phase_a_secs: 15.0,
            phase_b_secs: 30.0,
            phase_c_secs: 15.0,
            cooldown_ms: 30_000.0,
            feedback_interval_ms: 1_000.0,
            image_build_ms: 300.0,
            boot_ms: 150.0,
            ..Self::paper_scale()
        }
    }
}

/// One acceptance check of the feedback loop.
#[derive(Debug, Clone)]
pub struct Check {
    pub label: &'static str,
    pub pass: bool,
    pub detail: String,
}

/// Output of the FIG7 experiment.
pub struct Fig7 {
    pub params: Fig7Params,
    pub merges: Vec<MergeEvent>,
    pub splits: Vec<SplitEvent>,
    pub latency: Vec<LatencySample>,
    pub ram: Vec<RamSample>,
    pub group_ram: Vec<GroupRamSample>,
    /// (phase label, workload report), in order
    pub reports: Vec<(&'static str, WorkloadReport)>,
    /// virtual time each phase finished draining (ms since epoch)
    pub phase_end_ms: Vec<f64>,
    pub final_distinct_instances: usize,
    pub final_live_instances: usize,
}

impl Fig7 {
    fn p95_window(&self, from_ms: f64, to_ms: f64, min_n: usize) -> f64 {
        let q = Quantiles::from_samples(
            self.latency
                .iter()
                .filter(|s| s.t_ms >= from_ms && s.t_ms < to_ms)
                .map(|s| s.latency_ms)
                .collect(),
        );
        if q.len() >= min_n { q.p95() } else { f64::NAN }
    }

    /// Pre-fusion regime: every request that arrived before the first
    /// merge's cutover.
    pub fn baseline_p95_ms(&self) -> f64 {
        match self.merges.first() {
            Some(m) => self.p95_window(0.0, m.t_ms, MIN_WINDOW_SAMPLES),
            None => f64::NAN,
        }
    }

    pub fn first_split(&self) -> Option<&SplitEvent> {
        self.splits.first()
    }

    /// p95 of requests arriving after the split cutover, while the
    /// pressure phase is still running.
    pub fn post_split_p95_ms(&self) -> f64 {
        match (self.first_split(), self.phase_end_ms.get(1)) {
            (Some(s), Some(&end_b)) => self.p95_window(s.t_ms, end_b, 30),
            _ => f64::NAN,
        }
    }

    /// p95 of the fused steady state in the calm phase (reporting).
    pub fn fused_p95_ms(&self) -> f64 {
        match (self.merges.last(), self.phase_end_ms.first()) {
            (Some(m), Some(&end_a)) if m.t_ms < end_a => {
                self.p95_window(m.t_ms, end_a, MIN_WINDOW_SAMPLES)
            }
            _ => f64::NAN,
        }
    }

    /// The acceptance checklist for the full feedback loop.
    pub fn checks(&self) -> Vec<Check> {
        let mut out = Vec::new();
        let end_a = self.phase_end_ms.first().copied().unwrap_or(f64::NAN);

        let fused_in_calm =
            self.merges.first().map(|m| m.t_ms < end_a).unwrap_or(false);
        out.push(Check {
            label: "fusion under calm load",
            pass: fused_in_calm,
            detail: format!(
                "{} merges, first at t={:.1}s (calm phase ends {:.1}s)",
                self.merges.len(),
                self.merges.first().map(|m| m.t_ms / 1e3).unwrap_or(f64::NAN),
                end_a / 1e3
            ),
        });

        let split_ok = self
            .first_split()
            .map(|s| s.reason == SplitReason::RamCap && s.t_ms > end_a)
            .unwrap_or(false);
        out.push(Check {
            label: "RAM-cap split under memory pressure",
            pass: split_ok,
            detail: match self.first_split() {
                Some(s) => format!(
                    "split [{}] at t={:.1}s, reason {}",
                    s.functions.join("+"),
                    s.t_ms / 1e3,
                    s.reason.name()
                ),
                None => "no split event".into(),
            },
        });

        let base = self.baseline_p95_ms();
        let post = self.post_split_p95_ms();
        let recovered = base.is_finite() && post.is_finite() && (post - base).abs() <= 0.10 * base;
        out.push(Check {
            label: "post-split p95 within 10% of pre-fusion baseline",
            pass: recovered,
            detail: format!("baseline {base:.1} ms vs post-split {post:.1} ms"),
        });

        let no_flap = match self.first_split() {
            Some(s) => {
                let barrier = s.t_ms + self.params.cooldown_ms;
                self.merges.iter().all(|m| m.t_ms < s.t_ms || m.t_ms >= barrier)
                    && self.splits.iter().all(|o| o.t_ms == s.t_ms || o.t_ms >= barrier)
            }
            None => false,
        };
        out.push(Check {
            label: "no fuse/split flapping within one cooldown window",
            pass: no_flap,
            detail: format!(
                "cooldown {:.0}s; merges at [{}]",
                self.params.cooldown_ms / 1e3,
                self.merges
                    .iter()
                    .map(|m| format!("{:.1}s", m.t_ms / 1e3))
                    .collect::<Vec<_>>()
                    .join(", ")
            ),
        });

        out.push(Check {
            label: "single corrective split",
            pass: self.splits.len() == 1,
            detail: format!("{} split events", self.splits.len()),
        });

        out.push(Check {
            label: "re-fused and converged after relief",
            pass: self.final_distinct_instances == 1 && self.final_live_instances == 1,
            detail: format!(
                "{} routed instances, {} live",
                self.final_distinct_instances, self.final_live_instances
            ),
        });

        let all_served = self.reports.iter().all(|(_, r)| r.failed == 0);
        out.push(Check {
            label: "zero dropped requests across all phases",
            pass: all_served,
            detail: self
                .reports
                .iter()
                .map(|(l, r)| format!("{l}: {}/{} ok", r.ok, r.issued))
                .collect::<Vec<_>>()
                .join(", "),
        });
        out
    }

    pub fn passed(&self) -> bool {
        self.checks().iter().all(|c| c.pass)
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str("FIG7: feedback-driven defusion (fuse under calm load, split under memory pressure)\n");
        for (label, report) in &self.reports {
            out.push_str(&format!("  {label:<9}: {}\n", report.summary()));
        }
        out.push_str(&format!(
            "  regimes   : baseline p95 {:.1} ms -> fused p95 {:.1} ms -> post-split p95 {:.1} ms\n",
            self.baseline_p95_ms(),
            self.fused_p95_ms(),
            self.post_split_p95_ms()
        ));
        out.push_str(&format!(
            "  merges    : {} at t = [{}]\n",
            self.merges.len(),
            self.merges
                .iter()
                .map(|m| format!("{:.1}s", m.t_ms / 1e3))
                .collect::<Vec<_>>()
                .join(", ")
        ));
        out.push_str(&format!(
            "  splits    : {} at t = [{}]\n",
            self.splits.len(),
            self.splits
                .iter()
                .map(|s| format!("{:.1}s ({})", s.t_ms / 1e3, s.reason.name()))
                .collect::<Vec<_>>()
                .join(", ")
        ));
        for c in self.checks() {
            out.push_str(&format!(
                "  [{}] {} — {}\n",
                if c.pass { "PASS" } else { "FAIL" },
                c.label,
                c.detail
            ));
        }
        out
    }
}

/// Run FIG7 and write its CSVs + summary into `out_dir`.
pub fn run(out_dir: &Path, params: Fig7Params) -> Result<Fig7> {
    let fig = Executor::new(Mode::Virtual).block_on(async move {
        let mut cfg = PlatformConfig::tiny().with_compute(params.compute).with_seed(params.seed);
        cfg.latency.image_build_ms = params.image_build_ms;
        cfg.latency.boot_ms = params.boot_ms;
        cfg.fusion.min_observations = params.min_observations;
        cfg.fusion.cooldown_ms = params.cooldown_ms;
        cfg.fusion.max_group_ram_mb = params.max_group_ram_mb;
        cfg.fusion.split_p95_regression = params.split_p95_regression;
        cfg.fusion.feedback_interval_ms = params.feedback_interval_ms;
        cfg.fusion.split_hysteresis_windows = params.hysteresis;

        let platform = Platform::deploy(apps::chain(4), cfg).await?;
        let phases: [(&'static str, f64, f64); 3] = [
            ("calm", params.calm_rps, params.phase_a_secs),
            ("pressure", params.pressure_rps, params.phase_b_secs),
            ("relief", params.calm_rps, params.phase_c_secs),
        ];
        let mut reports = Vec::new();
        let mut phase_end_ms = Vec::new();
        for (i, (label, rate, secs)) in phases.iter().enumerate() {
            let wl = WorkloadConfig {
                requests: (rate * secs).round() as u64,
                rate_rps: *rate,
                seed: params.seed.wrapping_add(i as u64),
                timeout_ms: 120_000.0,
            };
            let report = workload::run(Rc::clone(&platform), wl).await?;
            reports.push((*label, report));
            phase_end_ms.push(platform.metrics.rel_now_ms());
        }
        // let drains / re-fusions settle before the final topology snapshot
        exec::sleep_ms(10_000.0).await;
        platform.shutdown();

        let m = &platform.metrics;
        Ok::<Fig7, crate::error::Error>(Fig7 {
            params,
            merges: m.merges(),
            splits: m.splits(),
            latency: m.latencies(),
            ram: m.ram_series(),
            group_ram: m.group_ram_series(),
            reports,
            phase_end_ms,
            final_distinct_instances: platform.gateway.distinct_instances(),
            final_live_instances: platform.containers.live_count(),
        })
    })?;

    let mut latency_csv = String::from("t_ms,latency_ms\n");
    for s in &fig.latency {
        latency_csv.push_str(&format!("{:.3},{:.3}\n", s.t_ms, s.latency_ms));
    }
    write_output(&out_dir.join("fig7_latency.csv"), &latency_csv)?;

    let mut ram_csv = String::from("t_ms,total_mb,instances\n");
    for s in &fig.ram {
        ram_csv.push_str(&format!("{:.3},{:.3},{}\n", s.t_ms, s.total_mb, s.instances));
    }
    write_output(&out_dir.join("fig7_ram.csv"), &ram_csv)?;

    let mut group_csv = String::from("t_ms,group,ram_mb\n");
    for s in &fig.group_ram {
        group_csv.push_str(&format!("{:.3},{},{:.3}\n", s.t_ms, s.group, s.ram_mb));
    }
    write_output(&out_dir.join("fig7_group_ram.csv"), &group_csv)?;

    let mut events_csv = String::from("t_ms,event,duration_ms,reason,functions\n");
    for m in &fig.merges {
        events_csv.push_str(&format!(
            "{:.3},merge,{:.3},,{}\n",
            m.t_ms,
            m.duration_ms,
            m.functions.join("+")
        ));
    }
    for s in &fig.splits {
        events_csv.push_str(&format!(
            "{:.3},split,{:.3},{},{}\n",
            s.t_ms,
            s.duration_ms,
            s.reason.name(),
            s.functions.join("+")
        ));
    }
    write_output(&out_dir.join("fig7_events.csv"), &events_csv)?;
    write_output(&out_dir.join("fig7_summary.txt"), &fig.render())?;
    Ok(fig)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig7_full_feedback_loop_at_smoke_scale() {
        let dir = std::env::temp_dir().join("provuse_fig7_test");
        let fig = run(&dir, Fig7Params::smoke()).unwrap();
        for c in fig.checks() {
            assert!(c.pass, "{} — {}\n{}", c.label, c.detail, fig.render());
        }
        // the RAM cap was genuinely the trigger: the group's attributed RAM
        // exceeded the cap right before the split
        let split_t = fig.first_split().unwrap().t_ms;
        let cap = fig.params.max_group_ram_mb;
        let over: Vec<&crate::metrics::GroupRamSample> = fig
            .group_ram
            .iter()
            .filter(|s| s.t_ms <= split_t && s.ram_mb > cap)
            .collect();
        assert!(
            over.len() >= fig.params.hysteresis as usize,
            "expected >= {} over-cap samples before the split",
            fig.params.hysteresis
        );
        assert!(dir.join("fig7_events.csv").exists());
        assert!(dir.join("fig7_group_ram.csv").exists());
        assert!(dir.join("fig7_summary.txt").exists());
    }

    #[test]
    fn fig7_is_deterministic_per_seed() {
        // two tiny runs with identical seeds must agree on their event
        // timelines exactly (virtual clock determinism)
        let mut p = Fig7Params::smoke();
        p.phase_a_secs = 10.0;
        p.phase_b_secs = 12.0;
        p.phase_c_secs = 0.0;
        p.cooldown_ms = 20_000.0;
        let dir_a = std::env::temp_dir().join("provuse_fig7_det_a");
        let dir_b = std::env::temp_dir().join("provuse_fig7_det_b");
        let a = run(&dir_a, p).unwrap();
        let b = run(&dir_b, p).unwrap();
        assert_eq!(a.merges.len(), b.merges.len());
        assert_eq!(a.splits.len(), b.splits.len());
        for (x, y) in a.merges.iter().zip(&b.merges) {
            assert_eq!(x.t_ms, y.t_ms);
        }
        for (x, y) in a.splits.iter().zip(&b.splits) {
            assert_eq!(x.t_ms, y.t_ms);
            assert_eq!(x.reason, y.reason);
        }
        assert_eq!(a.baseline_p95_ms(), b.baseline_p95_ms());
    }
}
