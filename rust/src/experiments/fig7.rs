//! FIG7 (ours) — the feedback loop the paper's fuse-once pipeline lacks,
//! in two scenarios selected by [`Fig7App`]:
//!
//! * **Chain** (PR 1): a phase-shifted workload drives **fusion under calm
//!   load**, a memory-pressure phase pushes the fused chain past its RAM
//!   cap and the threshold controller **defuses** it whole (a
//!   [`SplitEvent`]), latency returns to the pre-fusion baseline, and after
//!   the pressure lifts the platform **re-fuses**.
//! * **Iot** (PR 2, the ROADMAP's IoT-app variant): two fused groups
//!   under **asymmetric pressure**.  The `iot-heavy` app fuses into
//!   {ingest, model, refine} and {persist, notify}; the pressure phase
//!   hammers the `model` route directly, the **cost-model** controller
//!   scores the hot group past `evict_threshold` and sheds exactly its
//!   heaviest member (an [`EvictEvent`]: `model` leaves, the remainder
//!   stays fused), while the cool group never splits.
//! * **Mixed** (this PR): the merge-side **admission planner**.  Three
//!   independent pairs under steady per-route traffic: the hot light pair
//!   must be admitted and fused, the equally hot heavy pair must be
//!   *refused* (its predicted fused working set alone makes it an
//!   immediate eviction candidate — zero defusion events for it), and the
//!   cold pair stays unfused even after crossing the observation
//!   threshold.  With `--merge-policy observation-count` the same run is
//!   the **negative control**: the heavy pair fuses, is torn apart by the
//!   defusion cost model, and re-fuses after cooldown — the fuse→evict
//!   flap the planner exists to prevent.
//!
//! Every scenario runs three phases on one live platform, all on the
//! virtual clock and fully deterministic per seed.

use std::path::Path;
use std::rc::Rc;

use super::write_output;
use crate::apps;
use crate::config::{
    ComputeMode, MergePolicyKind, PlatformConfig, SplitPolicyKind, WorkloadConfig,
};
use crate::error::Result;
use crate::exec::{self, Executor, Mode};
use crate::fusion::SplitReason;
use crate::metrics::{
    AdmissionSample, EvictEvent, FnRamSample, GroupRamSample, LatencySample, MergeEvent,
    RamSample, RegretSample, SplitEvent, MIN_WINDOW_SAMPLES,
};
use crate::platform::Platform;
use crate::util::stats::Quantiles;
use crate::workload::{self, Arrival, WorkloadReport};

/// Which FIG7 scenario to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fig7App {
    /// PR 1: chain(4) under memory pressure, threshold policy, whole split.
    Chain,
    /// iot-heavy under asymmetric per-route pressure, cost-model policy,
    /// heaviest-member eviction.
    Iot,
    /// mixed (light/heavy/cold pairs) under steady per-route traffic,
    /// cost-aware merge admission; observation-count is the negative
    /// control.
    Mixed,
}

impl Fig7App {
    pub fn name(&self) -> &'static str {
        match self {
            Fig7App::Chain => "chain",
            Fig7App::Iot => "iot",
            Fig7App::Mixed => "mixed",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "chain" => Ok(Fig7App::Chain),
            "iot" | "iot-heavy" => Ok(Fig7App::Iot),
            "mixed" => Ok(Fig7App::Mixed),
            other => Err(crate::error::Error::Config(format!(
                "unknown figure7 app `{other}` (available: chain, iot, mixed)"
            ))),
        }
    }
}

/// FIG7 knobs (one struct so the CLI, the bench harness, and the smoke
/// test share the same driver).
#[derive(Debug, Clone, Copy)]
pub struct Fig7Params {
    pub app: Fig7App,
    /// rate of the calm and relief phases (rps); in the Iot scenario the
    /// entry route stays at this rate through every phase
    pub calm_rps: f64,
    /// Chain: entry rate of the memory-pressure phase.  Iot: rate of the
    /// *direct* `model`-route workload during the pressure phase.
    pub pressure_rps: f64,
    pub phase_a_secs: f64,
    pub phase_b_secs: f64,
    pub phase_c_secs: f64,
    pub seed: u64,
    pub compute: ComputeMode,
    /// Chain: RAM cap for fused groups.  Iot: the cost model's RAM
    /// reference scale (MiB).
    pub max_group_ram_mb: f64,
    /// p95 regression fraction that also triggers defusion (threshold
    /// policy only)
    pub split_p95_regression: f64,
    /// anti-flap cooldown; sized to outlast the remaining run
    pub cooldown_ms: f64,
    pub feedback_interval_ms: f64,
    pub hysteresis: u32,
    pub min_observations: u32,
    pub image_build_ms: f64,
    pub boot_ms: f64,
    /// cost-model objective threshold (Iot/Mixed scenarios)
    pub evict_threshold: f64,
    pub w_latency: f64,
    pub w_ram: f64,
    pub w_gbs: f64,
    /// which admission objective gates Fuse emission (Mixed scenario: the
    /// planner by default, observation-count as the negative control)
    pub merge_policy: MergePolicyKind,
    /// predicted net benefit a pair must clear to be admitted
    pub merge_threshold: f64,
    /// hill-climb the merge weights from post-fuse regret
    pub auto_tune: bool,
    /// Mixed: rate of the cold pair's route (slowly crosses the
    /// observation threshold but never pays for itself)
    pub cold_rps: f64,
}

impl Fig7Params {
    /// Full-scale chain run (the shipped FIG7 numbers): 60 s per phase with
    /// the calibrated tinyFaaS merge latencies.
    pub fn paper_scale() -> Self {
        Fig7Params {
            app: Fig7App::Chain,
            calm_rps: 2.0,
            pressure_rps: 60.0,
            phase_a_secs: 60.0,
            phase_b_secs: 60.0,
            phase_c_secs: 60.0,
            seed: 7,
            compute: ComputeMode::Disabled,
            // chain(4) fused idle RAM = 58 base + 4 x 12 code = 106 MiB;
            // the cap admits ~6 in-flight working sets, which calm traffic
            // never reaches and pressure traffic always exceeds
            max_group_ram_mb: 115.0,
            split_p95_regression: 0.5,
            cooldown_ms: 60_000.0,
            feedback_interval_ms: 2_000.0,
            hysteresis: 2,
            min_observations: 8,
            image_build_ms: 4_000.0,
            boot_ms: 1_200.0,
            evict_threshold: 2.0,
            w_latency: 1.0,
            w_ram: 1.0,
            w_gbs: 1.0,
            merge_policy: MergePolicyKind::ObservationCount,
            merge_threshold: 0.0,
            auto_tune: false,
            cold_rps: 0.0,
        }
    }

    /// Scaled-down chain variant for `cargo test` / the CI smoke job.
    pub fn smoke() -> Self {
        Fig7Params {
            phase_a_secs: 15.0,
            phase_b_secs: 30.0,
            phase_c_secs: 15.0,
            cooldown_ms: 30_000.0,
            feedback_interval_ms: 1_000.0,
            image_build_ms: 300.0,
            boot_ms: 150.0,
            ..Self::paper_scale()
        }
    }

    /// Full-scale Iot eviction scenario (`provuse figure7 --app iot`).
    pub fn iot_paper_scale() -> Self {
        Fig7Params {
            app: Fig7App::Iot,
            calm_rps: 2.0,
            pressure_rps: 40.0,
            // iot-heavy fused hot group: 58 base + 422 code = 480 MiB; the
            // 600 MiB reference keeps the RAM term ~0.8 so the billed-GiB-s
            // term (asymmetric pressure) is what crosses the threshold
            max_group_ram_mb: 600.0,
            cooldown_ms: 240_000.0,
            evict_threshold: 2.0,
            ..Self::paper_scale()
        }
    }

    /// Scaled-down Iot variant for `cargo test` / the CI smoke job.
    pub fn iot_smoke() -> Self {
        Fig7Params {
            phase_a_secs: 20.0,
            phase_b_secs: 30.0,
            phase_c_secs: 20.0,
            cooldown_ms: 90_000.0,
            feedback_interval_ms: 2_000.0,
            min_observations: 5,
            image_build_ms: 300.0,
            boot_ms: 150.0,
            ..Self::iot_paper_scale()
        }
    }

    /// Full-scale Mixed admission-planner scenario
    /// (`provuse figure7 --app mixed`).
    pub fn mixed_paper_scale() -> Self {
        Fig7Params {
            app: Fig7App::Mixed,
            // entry (router) traffic; the pairs are driven per-route
            calm_rps: 2.0,
            // rate of BOTH hot routes (light_api, heavy_api), every phase
            pressure_rps: 10.0,
            // crosses min_observations ~40 s in, but never pays for itself
            cold_rps: 0.2,
            // the cost model's RAM reference: light pair predicts ~0.5,
            // heavy pair ~2.06 — past the evict threshold, so the planner's
            // churn gate refuses it outright
            max_group_ram_mb: 256.0,
            evict_threshold: 2.0,
            merge_policy: MergePolicyKind::CostModel,
            merge_threshold: 0.0,
            // short cooldown on purpose: the observation-count negative
            // control must fuse -> defuse -> re-fuse within one run
            cooldown_ms: 20_000.0,
            feedback_interval_ms: 2_000.0,
            ..Self::paper_scale()
        }
    }

    /// Scaled-down Mixed variant for `cargo test` / the CI smoke job.
    pub fn mixed_smoke() -> Self {
        Fig7Params {
            phase_a_secs: 25.0,
            phase_b_secs: 25.0,
            phase_c_secs: 25.0,
            image_build_ms: 300.0,
            boot_ms: 150.0,
            ..Self::mixed_paper_scale()
        }
    }

    /// Params for `app` at full or smoke scale.
    pub fn for_app(app: Fig7App, smoke: bool) -> Self {
        match (app, smoke) {
            (Fig7App::Chain, false) => Self::paper_scale(),
            (Fig7App::Chain, true) => Self::smoke(),
            (Fig7App::Iot, false) => Self::iot_paper_scale(),
            (Fig7App::Iot, true) => Self::iot_smoke(),
            (Fig7App::Mixed, false) => Self::mixed_paper_scale(),
            (Fig7App::Mixed, true) => Self::mixed_smoke(),
        }
    }
}

/// One acceptance check of the feedback loop.
#[derive(Debug, Clone)]
pub struct Check {
    pub label: &'static str,
    pub pass: bool,
    pub detail: String,
}

/// Group membership probes captured at the end of each phase (Iot
/// scenario): `(probe function, sorted members of its instance)`.
pub type TopologySnap = Vec<(String, Vec<String>)>;

/// Output of the FIG7 experiment.
pub struct Fig7 {
    pub params: Fig7Params,
    pub merges: Vec<MergeEvent>,
    pub splits: Vec<SplitEvent>,
    pub evicts: Vec<EvictEvent>,
    pub latency: Vec<LatencySample>,
    pub ram: Vec<RamSample>,
    pub group_ram: Vec<GroupRamSample>,
    pub fn_ram: Vec<FnRamSample>,
    /// merge-admission evaluations (empty under observation-count)
    pub admissions: Vec<AdmissionSample>,
    /// auto-tune regrets (weight trajectory)
    pub regrets: Vec<RegretSample>,
    /// final sync-call observation counts per (caller, callee)
    pub pair_observations: Vec<((String, String), u64)>,
    /// (phase label, workload report), in order
    pub reports: Vec<(&'static str, WorkloadReport)>,
    /// virtual time each phase finished draining (ms since epoch)
    pub phase_end_ms: Vec<f64>,
    /// per-phase topology probes (Iot scenario; empty for Chain)
    pub phase_snaps: Vec<TopologySnap>,
    pub final_distinct_instances: usize,
    pub final_live_instances: usize,
}

impl Fig7 {
    fn p95_window(&self, from_ms: f64, to_ms: f64, min_n: usize) -> f64 {
        let q = Quantiles::from_samples(
            self.latency
                .iter()
                .filter(|s| s.t_ms >= from_ms && s.t_ms < to_ms)
                .map(|s| s.latency_ms)
                .collect(),
        );
        if q.len() >= min_n { q.p95() } else { f64::NAN }
    }

    /// Pre-fusion regime: every request that arrived before the first
    /// merge's cutover.
    pub fn baseline_p95_ms(&self) -> f64 {
        match self.merges.first() {
            Some(m) => self.p95_window(0.0, m.t_ms, MIN_WINDOW_SAMPLES),
            None => f64::NAN,
        }
    }

    pub fn first_split(&self) -> Option<&SplitEvent> {
        self.splits.first()
    }

    pub fn first_evict(&self) -> Option<&EvictEvent> {
        self.evicts.first()
    }

    /// p95 of requests arriving after the split cutover, while the
    /// pressure phase is still running (Chain scenario).
    pub fn post_split_p95_ms(&self) -> f64 {
        match (self.first_split(), self.phase_end_ms.get(1)) {
            (Some(s), Some(&end_b)) => self.p95_window(s.t_ms, end_b, 30),
            _ => f64::NAN,
        }
    }

    /// p95 of the relief phase's entry-route traffic (Iot scenario: the
    /// clean post-evict regime, no direct-route requests mixed in).
    pub fn relief_p95_ms(&self) -> f64 {
        self.reports
            .iter()
            .find(|(label, _)| *label == "relief")
            .map(|(_, r)| r.latency.p95())
            .unwrap_or(f64::NAN)
    }

    /// p95 of the fused steady state in the calm phase (reporting).
    pub fn fused_p95_ms(&self) -> f64 {
        match (self.merges.last(), self.phase_end_ms.first()) {
            (Some(m), Some(&end_a)) if m.t_ms < end_a => {
                self.p95_window(m.t_ms, end_a, MIN_WINDOW_SAMPLES)
            }
            _ => f64::NAN,
        }
    }

    /// The acceptance checklist for the configured scenario.
    pub fn checks(&self) -> Vec<Check> {
        match self.params.app {
            Fig7App::Chain => self.checks_chain(),
            Fig7App::Iot => self.checks_iot(),
            Fig7App::Mixed => self.checks_mixed(),
        }
    }

    /// PR 1's whole-group feedback-loop checklist (threshold policy).
    fn checks_chain(&self) -> Vec<Check> {
        let mut out = Vec::new();
        let end_a = self.phase_end_ms.first().copied().unwrap_or(f64::NAN);

        let fused_in_calm =
            self.merges.first().map(|m| m.t_ms < end_a).unwrap_or(false);
        out.push(Check {
            label: "fusion under calm load",
            pass: fused_in_calm,
            detail: format!(
                "{} merges, first at t={:.1}s (calm phase ends {:.1}s)",
                self.merges.len(),
                self.merges.first().map(|m| m.t_ms / 1e3).unwrap_or(f64::NAN),
                end_a / 1e3
            ),
        });

        let split_ok = self
            .first_split()
            .map(|s| s.reason == SplitReason::RamCap && s.t_ms > end_a)
            .unwrap_or(false);
        out.push(Check {
            label: "RAM-cap split under memory pressure",
            pass: split_ok,
            detail: match self.first_split() {
                Some(s) => format!(
                    "split [{}] at t={:.1}s, reason {}",
                    s.functions.join("+"),
                    s.t_ms / 1e3,
                    s.reason.name()
                ),
                None => "no split event".into(),
            },
        });

        let base = self.baseline_p95_ms();
        let post = self.post_split_p95_ms();
        let recovered = base.is_finite() && post.is_finite() && (post - base).abs() <= 0.10 * base;
        out.push(Check {
            label: "post-split p95 within 10% of pre-fusion baseline",
            pass: recovered,
            detail: format!("baseline {base:.1} ms vs post-split {post:.1} ms"),
        });

        let no_flap = match self.first_split() {
            Some(s) => {
                let barrier = s.t_ms + self.params.cooldown_ms;
                self.merges.iter().all(|m| m.t_ms < s.t_ms || m.t_ms >= barrier)
                    && self.splits.iter().all(|o| o.t_ms == s.t_ms || o.t_ms >= barrier)
            }
            None => false,
        };
        out.push(Check {
            label: "no fuse/split flapping within one cooldown window",
            pass: no_flap,
            detail: format!(
                "cooldown {:.0}s; merges at [{}]",
                self.params.cooldown_ms / 1e3,
                self.merges
                    .iter()
                    .map(|m| format!("{:.1}s", m.t_ms / 1e3))
                    .collect::<Vec<_>>()
                    .join(", ")
            ),
        });

        out.push(Check {
            label: "single corrective split",
            pass: self.splits.len() == 1,
            detail: format!("{} split events", self.splits.len()),
        });

        out.push(Check {
            label: "threshold policy never evicts",
            pass: self.evicts.is_empty(),
            detail: format!("{} evict events", self.evicts.len()),
        });

        out.push(Check {
            label: "re-fused and converged after relief",
            pass: self.final_distinct_instances == 1 && self.final_live_instances == 1,
            detail: format!(
                "{} routed instances, {} live",
                self.final_distinct_instances, self.final_live_instances
            ),
        });

        out.push(self.zero_drops_check());
        out
    }

    /// The Iot eviction checklist: asymmetric pressure must evict exactly
    /// the hot group's heaviest member and leave everything else fused.
    fn checks_iot(&self) -> Vec<Check> {
        let mut out = Vec::new();
        let end_a = self.phase_end_ms.first().copied().unwrap_or(f64::NAN);
        let end_b = self.phase_end_ms.get(1).copied().unwrap_or(f64::NAN);

        let hot = vec!["ingest".to_string(), "model".into(), "refine".into()];
        let cool = vec!["notify".to_string(), "persist".into()];
        let remainder = vec!["ingest".to_string(), "refine".into()];

        let calm_ok = self
            .phase_snaps
            .first()
            .map(|snap| {
                members_of(snap, "ingest") == Some(&hot)
                    && members_of(snap, "persist") == Some(&cool)
            })
            .unwrap_or(false);
        out.push(Check {
            label: "both groups fused under calm load",
            pass: calm_ok,
            detail: format!(
                "after calm: ingest -> {:?}, persist -> {:?}",
                self.phase_snaps.first().and_then(|s| members_of(s, "ingest")),
                self.phase_snaps.first().and_then(|s| members_of(s, "persist"))
            ),
        });

        let evict_ok = self.evicts.len() == 1
            && self
                .first_evict()
                .map(|e| {
                    e.function == "model"
                        && e.group == hot
                        && e.reason == SplitReason::CostModel
                        && e.t_ms > end_a
                        && e.t_ms < end_b
                })
                .unwrap_or(false);
        out.push(Check {
            label: "exactly one eviction: the hot group sheds its heaviest member",
            pass: evict_ok,
            detail: match self.first_evict() {
                Some(e) => format!(
                    "{} evict(s); evicted `{}` from [{}] at t={:.1}s, reason {}",
                    self.evicts.len(),
                    e.function,
                    e.group.join("+"),
                    e.t_ms / 1e3,
                    e.reason.name()
                ),
                None => "no evict event".into(),
            },
        });

        let pressure_ok = self
            .phase_snaps
            .get(1)
            .map(|snap| {
                members_of(snap, "ingest") == Some(&remainder)
                    && members_of(snap, "model").map(|m| m.as_slice())
                        == Some(&["model".to_string()][..])
                    && members_of(snap, "persist") == Some(&cool)
            })
            .unwrap_or(false);
        out.push(Check {
            label: "remainder stays fused, evicted member serves alone",
            pass: pressure_ok,
            detail: format!(
                "after pressure: ingest -> {:?}, model -> {:?}, persist -> {:?}",
                self.phase_snaps.get(1).and_then(|s| members_of(s, "ingest")),
                self.phase_snaps.get(1).and_then(|s| members_of(s, "model")),
                self.phase_snaps.get(1).and_then(|s| members_of(s, "persist"))
            ),
        });

        out.push(Check {
            label: "the cool group never splits or evicts",
            pass: self.splits.is_empty()
                && !self.evicts.iter().any(|e| e.group.contains(&"persist".to_string())),
            detail: format!(
                "{} split events, {} evict events",
                self.splits.len(),
                self.evicts.len()
            ),
        });

        // One-sided recovery: the evicted topology must not cost more than
        // 10% over the pre-fusion regime (it is usually *faster*, since the
        // remainder is still fused).
        let base = self.baseline_p95_ms();
        let post = self.relief_p95_ms();
        let recovered = base.is_finite() && post.is_finite() && post <= 1.10 * base;
        out.push(Check {
            label: "post-evict p95 recovers to within 10% of the pre-fusion baseline",
            pass: recovered,
            detail: format!("baseline {base:.1} ms vs post-evict relief {post:.1} ms"),
        });

        let no_flap = match self.first_evict() {
            Some(e) => {
                let barrier = e.t_ms + self.params.cooldown_ms;
                self.merges.iter().all(|m| m.t_ms < e.t_ms || m.t_ms >= barrier)
            }
            None => false,
        };
        out.push(Check {
            label: "no re-fusion of the evicted member within one cooldown window",
            pass: no_flap,
            detail: format!(
                "cooldown {:.0}s; merges at [{}]",
                self.params.cooldown_ms / 1e3,
                self.merges
                    .iter()
                    .map(|m| format!("{:.1}s", m.t_ms / 1e3))
                    .collect::<Vec<_>>()
                    .join(", ")
            ),
        });

        out.push(Check {
            label: "final topology: two fused groups + the evicted singleton",
            pass: self.final_distinct_instances == 3 && self.final_live_instances == 3,
            detail: format!(
                "{} routed instances, {} live",
                self.final_distinct_instances, self.final_live_instances
            ),
        });

        out.push(self.zero_drops_check());
        out
    }

    /// Whether any merge event fused `function` with anything.
    fn ever_merged(&self, function: &str) -> bool {
        self.merges.iter().any(|m| m.functions.iter().any(|f| f == function))
    }

    /// Defusion events (splits + evicts) touching `function`.
    fn defusions_of(&self, function: &str) -> usize {
        self.splits.iter().filter(|s| s.functions.iter().any(|f| f == function)).count()
            + self.evicts.iter().filter(|e| e.group.iter().any(|f| f == function)).count()
    }

    fn observation_count(&self, caller: &str, callee: &str) -> u64 {
        self.pair_observations
            .iter()
            .find(|((a, b), _)| a == caller && b == callee)
            .map(|(_, n)| *n)
            .unwrap_or(0)
    }

    /// The Mixed checklist: admission planner by default, the
    /// observation-count negative control otherwise.
    fn checks_mixed(&self) -> Vec<Check> {
        match self.params.merge_policy {
            MergePolicyKind::CostModel => self.checks_mixed_planner(),
            MergePolicyKind::ObservationCount => self.checks_mixed_negative_control(),
        }
    }

    /// Positive scenario: the planner admits exactly the pair that pays
    /// for itself and nothing ever needs to be defused.
    fn checks_mixed_planner(&self) -> Vec<Check> {
        let mut out = Vec::new();
        let light = vec!["light_api".to_string(), "light_fmt".into()];

        let light_fused = self
            .phase_snaps
            .last()
            .map(|snap| members_of(snap, "light_api") == Some(&light))
            .unwrap_or(false);
        out.push(Check {
            label: "hot light pair is admitted and fused",
            pass: light_fused && self.ever_merged("light_fmt"),
            detail: format!(
                "final light_api -> {:?}, {} merges",
                self.phase_snaps.last().and_then(|s| members_of(s, "light_api")),
                self.merges.len()
            ),
        });

        let heavy_obs = self.observation_count("heavy_api", "heavy_model");
        let heavy_refused = !self.ever_merged("heavy_model")
            && heavy_obs >= self.params.min_observations as u64;
        out.push(Check {
            label: "hot heavy pair crosses the observation threshold yet is refused",
            pass: heavy_refused,
            detail: format!(
                "{} observations (threshold {}), final heavy_api -> {:?}",
                heavy_obs,
                self.params.min_observations,
                self.phase_snaps.last().and_then(|s| members_of(s, "heavy_api"))
            ),
        });

        let heavy_verdicts: Vec<&AdmissionSample> = self
            .admissions
            .iter()
            .filter(|a| a.caller == "heavy_api" && a.callee == "heavy_model")
            .collect();
        out.push(Check {
            label: "the refusal is the planner's: every heavy evaluation scored negative",
            pass: !heavy_verdicts.is_empty()
                && heavy_verdicts.iter().all(|a| !a.admitted && a.score < 0.0),
            detail: format!(
                "{} evaluations, scores [{}]",
                heavy_verdicts.len(),
                heavy_verdicts
                    .iter()
                    .take(4)
                    .map(|a| format!("{:.2}", a.score))
                    .collect::<Vec<_>>()
                    .join(", ")
            ),
        });

        let cold_obs = self.observation_count("cold_api", "cold_fmt");
        out.push(Check {
            label: "cold pair crosses the observation threshold yet stays unfused",
            pass: !self.ever_merged("cold_api")
                && cold_obs >= self.params.min_observations as u64,
            detail: format!(
                "{} observations (threshold {}), final cold_api -> {:?}",
                cold_obs,
                self.params.min_observations,
                self.phase_snaps.last().and_then(|s| members_of(s, "cold_api"))
            ),
        });

        out.push(Check {
            label: "zero defusion events: nothing the planner admitted needed taking back",
            pass: self.splits.is_empty() && self.evicts.is_empty(),
            detail: format!(
                "{} split events, {} evict events",
                self.splits.len(),
                self.evicts.len()
            ),
        });

        out.push(Check {
            label: "exactly one merge: the light pair, once",
            pass: self.merges.len() == 1,
            detail: format!(
                "merges: [{}]",
                self.merges
                    .iter()
                    .map(|m| m.functions.join("+"))
                    .collect::<Vec<_>>()
                    .join(", ")
            ),
        });

        out.push(self.zero_drops_check());
        out
    }

    /// Negative control: the seed's observation-count admission fuses the
    /// heavy pair and the defusion controller has to keep taking it back —
    /// the churn the planner eliminates.
    fn checks_mixed_negative_control(&self) -> Vec<Check> {
        let mut out = Vec::new();
        let heavy_merges = self
            .merges
            .iter()
            .filter(|m| m.functions.iter().any(|f| f == "heavy_model"))
            .count();

        out.push(Check {
            label: "observation-count admission fuses the heavy pair",
            pass: heavy_merges >= 1,
            detail: format!("{heavy_merges} heavy merges"),
        });

        let heavy_defusions = self.defusions_of("heavy_model");
        let heavy_splits = self
            .splits
            .iter()
            .filter(|s| s.functions.iter().any(|f| f == "heavy_model"))
            .count();
        out.push(Check {
            label: "the defusion cost model takes the heavy group back apart",
            pass: heavy_defusions >= 1,
            detail: format!(
                "{heavy_splits} split events, {} evict events touching heavy_model",
                heavy_defusions - heavy_splits
            ),
        });

        out.push(Check {
            label: "the heavy pair re-fuses after cooldown: fuse -> defuse flap demonstrated",
            pass: heavy_merges >= 2 && heavy_defusions >= 1,
            detail: format!("{heavy_merges} heavy merges, {heavy_defusions} heavy defusions"),
        });

        let light_defusions = self.defusions_of("light_api");
        out.push(Check {
            label: "the light pair fuses and stays fused",
            pass: self.ever_merged("light_fmt") && light_defusions == 0,
            detail: format!("{light_defusions} defusions touching light_api"),
        });

        out.push(self.zero_drops_check());
        out
    }

    fn zero_drops_check(&self) -> Check {
        let all_served = self.reports.iter().all(|(_, r)| r.failed == 0);
        Check {
            label: "zero dropped requests across all phases",
            pass: all_served,
            detail: self
                .reports
                .iter()
                .map(|(l, r)| format!("{l}: {}/{} ok", r.ok, r.issued))
                .collect::<Vec<_>>()
                .join(", "),
        }
    }

    pub fn passed(&self) -> bool {
        self.checks().iter().all(|c| c.pass)
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        match self.params.app {
            Fig7App::Chain => out.push_str(
                "FIG7/chain: feedback-driven defusion (fuse under calm load, split under memory pressure)\n",
            ),
            Fig7App::Iot => out.push_str(
                "FIG7/iot: cost-model partial defusion (two groups, asymmetric pressure, heaviest member evicted)\n",
            ),
            Fig7App::Mixed => out.push_str(&format!(
                "FIG7/mixed: merge-side admission planner (light/heavy/cold pairs, --merge-policy {})\n",
                self.params.merge_policy.name()
            )),
        }
        for (label, report) in &self.reports {
            out.push_str(&format!("  {label:<15}: {}\n", report.summary()));
        }
        out.push_str(&format!(
            "  regimes   : baseline p95 {:.1} ms -> fused p95 {:.1} ms -> post-correction p95 {:.1} ms\n",
            self.baseline_p95_ms(),
            self.fused_p95_ms(),
            match self.params.app {
                Fig7App::Chain => self.post_split_p95_ms(),
                Fig7App::Iot => self.relief_p95_ms(),
                // no correction phase by design: the planner refused upfront
                Fig7App::Mixed => f64::NAN,
            }
        ));
        out.push_str(&format!(
            "  merges    : {} at t = [{}]\n",
            self.merges.len(),
            self.merges
                .iter()
                .map(|m| format!("{:.1}s", m.t_ms / 1e3))
                .collect::<Vec<_>>()
                .join(", ")
        ));
        out.push_str(&format!(
            "  splits    : {} at t = [{}]\n",
            self.splits.len(),
            self.splits
                .iter()
                .map(|s| format!("{:.1}s ({})", s.t_ms / 1e3, s.reason.name()))
                .collect::<Vec<_>>()
                .join(", ")
        ));
        out.push_str(&format!(
            "  evicts    : {} at t = [{}]\n",
            self.evicts.len(),
            self.evicts
                .iter()
                .map(|e| {
                    format!("{:.1}s ({} from {})", e.t_ms / 1e3, e.function, e.group.join("+"))
                })
                .collect::<Vec<_>>()
                .join(", ")
        ));
        if self.params.app == Fig7App::Mixed {
            let admitted = self.admissions.iter().filter(|a| a.admitted).count();
            out.push_str(&format!(
                "  admission : {} evaluations ({} admitted), {} regrets\n",
                self.admissions.len(),
                admitted,
                self.regrets.len()
            ));
        }
        for c in self.checks() {
            out.push_str(&format!(
                "  [{}] {} — {}\n",
                if c.pass { "PASS" } else { "FAIL" },
                c.label,
                c.detail
            ));
        }
        out
    }
}

fn members_of<'a>(snap: &'a TopologySnap, probe: &str) -> Option<&'a Vec<String>> {
    snap.iter().find(|(f, _)| f == probe).map(|(_, members)| members)
}

fn snapshot(platform: &Platform, probes: &[&str]) -> TopologySnap {
    probes
        .iter()
        .map(|p| (p.to_string(), platform.group_members(p)))
        .collect()
}

/// Run FIG7 and write its CSVs + summary into `out_dir`.
pub fn run(out_dir: &Path, params: Fig7Params) -> Result<Fig7> {
    let (fig, series_csvs) = Executor::new(Mode::Virtual).block_on(async move {
        // Stays on the default RecordingLevel::Full (ISSUE 7 recording
        // audit): fig7 exports the raw latency/ram/group-ram/fn-series
        // CSVs and its phase analysis reads p95s over arbitrary windows —
        // both genuinely Full-only.  Bounded-memory drivers are fig6, the
        // sweeps, and fig9/fig10.
        let mut cfg = PlatformConfig::tiny().with_compute(params.compute).with_seed(params.seed);
        cfg.latency.image_build_ms = params.image_build_ms;
        cfg.latency.boot_ms = params.boot_ms;
        cfg.fusion.min_observations = params.min_observations;
        cfg.fusion.cooldown_ms = params.cooldown_ms;
        cfg.fusion.max_group_ram_mb = params.max_group_ram_mb;
        cfg.fusion.split_p95_regression = params.split_p95_regression;
        cfg.fusion.feedback_interval_ms = params.feedback_interval_ms;
        cfg.fusion.split_hysteresis_windows = params.hysteresis;
        if params.app == Fig7App::Iot || params.app == Fig7App::Mixed {
            cfg.fusion.split_policy = SplitPolicyKind::CostModel;
            cfg.fusion.cost.evict_threshold = params.evict_threshold;
            cfg.fusion.cost.w_latency = params.w_latency;
            cfg.fusion.cost.w_ram = params.w_ram;
            cfg.fusion.cost.w_gbs = params.w_gbs;
        }
        cfg.fusion.merge_policy = params.merge_policy;
        cfg.fusion.auto_tune = params.auto_tune;
        cfg.fusion.cost.merge_threshold = params.merge_threshold;

        let app = match params.app {
            Fig7App::Chain => apps::chain(4),
            Fig7App::Iot => apps::iot_heavy(),
            Fig7App::Mixed => apps::mixed(),
        };
        let platform = Platform::deploy(app, cfg).await?;
        let mut reports: Vec<(&'static str, WorkloadReport)> = Vec::new();
        let mut phase_end_ms = Vec::new();
        let mut phase_snaps = Vec::new();
        let probes: &[&str] = match params.app {
            Fig7App::Mixed => &["light_api", "heavy_api", "cold_api"],
            _ => &["ingest", "model", "persist"],
        };

        let phases: [(&'static str, f64); 3] = [
            ("calm", params.phase_a_secs),
            ("pressure", params.phase_b_secs),
            ("relief", params.phase_c_secs),
        ];
        for (i, (label, secs)) in phases.iter().enumerate() {
            match params.app {
                Fig7App::Chain => {
                    // PR 1 shape: the entry rate itself shifts between phases
                    let rate = if *label == "pressure" {
                        params.pressure_rps
                    } else {
                        params.calm_rps
                    };
                    let wl = WorkloadConfig {
                        requests: (rate * secs).round() as u64,
                        rate_rps: rate,
                        seed: params.seed.wrapping_add(i as u64),
                        timeout_ms: 120_000.0,
                    };
                    let report = workload::run(Rc::clone(&platform), wl).await?;
                    reports.push((*label, report));
                }
                Fig7App::Iot => {
                    // entry traffic stays calm in every phase; pressure adds
                    // a concurrent direct workload on the `model` route
                    let entry_wl = WorkloadConfig {
                        requests: (params.calm_rps * secs).round() as u64,
                        rate_rps: params.calm_rps,
                        seed: params.seed.wrapping_add(i as u64),
                        timeout_ms: 120_000.0,
                    };
                    if *label == "pressure" {
                        let direct_wl = WorkloadConfig {
                            requests: (params.pressure_rps * secs).round() as u64,
                            rate_rps: params.pressure_rps,
                            seed: params.seed.wrapping_add(0x5EED + i as u64),
                            timeout_ms: 120_000.0,
                        };
                        let entry = exec::spawn(workload::run(Rc::clone(&platform), entry_wl));
                        let direct = exec::spawn(workload::run_targeted(
                            Rc::clone(&platform),
                            direct_wl,
                            Arrival::Constant,
                            Some("model"),
                        ));
                        reports.push(("pressure", entry.await?));
                        reports.push(("pressure-direct", direct.await?));
                    } else {
                        let report = workload::run(Rc::clone(&platform), entry_wl).await?;
                        reports.push((*label, report));
                    }
                }
                Fig7App::Mixed => {
                    // steady per-route traffic in EVERY phase — the three
                    // verdicts come from predicted cost, not phase shifts:
                    // entry (router) at calm_rps, both hot routes at
                    // pressure_rps, the cold route at cold_rps
                    let wl = |rate: f64, salt: u64| WorkloadConfig {
                        requests: (rate * secs).round() as u64,
                        rate_rps: rate,
                        seed: params.seed.wrapping_add(salt).wrapping_add(i as u64),
                        timeout_ms: 120_000.0,
                    };
                    let entry =
                        exec::spawn(workload::run(Rc::clone(&platform), wl(params.calm_rps, 0)));
                    let light = exec::spawn(workload::run_targeted(
                        Rc::clone(&platform),
                        wl(params.pressure_rps, 0x11),
                        Arrival::Constant,
                        Some("light_api"),
                    ));
                    let heavy = exec::spawn(workload::run_targeted(
                        Rc::clone(&platform),
                        wl(params.pressure_rps, 0x22),
                        Arrival::Constant,
                        Some("heavy_api"),
                    ));
                    let cold = exec::spawn(workload::run_targeted(
                        Rc::clone(&platform),
                        wl(params.cold_rps, 0x33),
                        Arrival::Constant,
                        Some("cold_api"),
                    ));
                    reports.push(("entry", entry.await?));
                    reports.push(("light", light.await?));
                    reports.push(("heavy", heavy.await?));
                    reports.push(("cold", cold.await?));
                }
            }
            // let in-flight pipelines land before probing the topology
            exec::sleep_ms(2_000.0).await;
            phase_end_ms.push(platform.metrics.rel_now_ms());
            if params.app != Fig7App::Chain {
                phase_snaps.push(snapshot(&platform, probes));
            }
        }
        // let drains / re-fusions settle before the final topology snapshot
        exec::sleep_ms(10_000.0).await;
        platform.shutdown();

        let m = &platform.metrics;
        // series CSVs come straight from the Recorder's canonical exporters
        // (one format definition; fig7 adds only the combined event timeline)
        let series_csvs: Vec<(&'static str, String)> = vec![
            ("fig7_latency.csv", m.latency_csv()),
            ("fig7_ram.csv", m.ram_csv()),
            ("fig7_group_ram.csv", m.group_ram_csv()),
            ("fig7_fn_ram.csv", m.fn_ram_csv()),
            ("fig7_fn_latency.csv", m.fn_latency_csv()),
            ("fig7_admissions.csv", m.admissions_csv()),
            ("fig7_regrets.csv", m.regrets_csv()),
        ];
        let fig = Fig7 {
            params,
            merges: m.merges(),
            splits: m.splits(),
            evicts: m.evicts(),
            latency: m.latencies(),
            ram: m.ram_series(),
            group_ram: m.group_ram_series(),
            fn_ram: m.fn_ram_series(),
            admissions: m.admissions(),
            regrets: m.regrets(),
            pair_observations: platform.observer.observed_graph(),
            reports,
            phase_end_ms,
            phase_snaps,
            final_distinct_instances: platform.gateway.distinct_instances(),
            final_live_instances: platform.containers.live_count(),
        };
        Ok::<(Fig7, Vec<(&'static str, String)>), crate::error::Error>((fig, series_csvs))
    })?;

    for (name, contents) in &series_csvs {
        write_output(&out_dir.join(name), contents)?;
    }

    let mut events_csv = String::from("t_ms,event,duration_ms,reason,function,functions\n");
    for m in &fig.merges {
        events_csv.push_str(&format!(
            "{:.3},merge,{:.3},,,{}\n",
            m.t_ms,
            m.duration_ms,
            m.functions.join("+")
        ));
    }
    for s in &fig.splits {
        events_csv.push_str(&format!(
            "{:.3},split,{:.3},{},,{}\n",
            s.t_ms,
            s.duration_ms,
            s.reason.name(),
            s.functions.join("+")
        ));
    }
    for e in &fig.evicts {
        events_csv.push_str(&format!(
            "{:.3},evict,{:.3},{},{},{}\n",
            e.t_ms,
            e.duration_ms,
            e.reason.name(),
            e.function,
            e.group.join("+")
        ));
    }
    write_output(&out_dir.join("fig7_events.csv"), &events_csv)?;
    write_output(&out_dir.join("fig7_summary.txt"), &fig.render())?;
    Ok(fig)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig7_full_feedback_loop_at_smoke_scale() {
        let dir = std::env::temp_dir().join("provuse_fig7_test");
        let fig = run(&dir, Fig7Params::smoke()).unwrap();
        for c in fig.checks() {
            assert!(c.pass, "{} — {}\n{}", c.label, c.detail, fig.render());
        }
        // the RAM cap was genuinely the trigger: the group's attributed RAM
        // exceeded the cap right before the split
        let split_t = fig.first_split().unwrap().t_ms;
        let cap = fig.params.max_group_ram_mb;
        let over: Vec<&crate::metrics::GroupRamSample> = fig
            .group_ram
            .iter()
            .filter(|s| s.t_ms <= split_t && s.ram_mb > cap)
            .collect();
        assert!(
            over.len() >= fig.params.hysteresis as usize,
            "expected >= {} over-cap samples before the split",
            fig.params.hysteresis
        );
        assert!(dir.join("fig7_events.csv").exists());
        assert!(dir.join("fig7_group_ram.csv").exists());
        assert!(dir.join("fig7_summary.txt").exists());
    }

    #[test]
    fn fig7_iot_eviction_scenario_at_smoke_scale() {
        let dir = std::env::temp_dir().join("provuse_fig7_iot_test");
        let fig = run(&dir, Fig7Params::iot_smoke()).unwrap();
        for c in fig.checks() {
            assert!(c.pass, "{} — {}\n{}", c.label, c.detail, fig.render());
        }
        // the eviction shed real RAM: the hot group's attributed RAM drops
        // by ~the model function's 400 MiB code footprint
        let evict = fig.first_evict().unwrap();
        let hot_before = fig
            .group_ram
            .iter()
            .filter(|s| s.group == "ingest+model+refine" && s.t_ms < evict.t_ms)
            .map(|s| s.ram_mb)
            .fold(f64::NAN, f64::max);
        let remainder_after = fig
            .group_ram
            .iter()
            .filter(|s| s.group == "ingest+refine" && s.t_ms > evict.t_ms)
            .map(|s| s.ram_mb)
            .fold(f64::NAN, f64::max);
        assert!(
            hot_before.is_finite() && remainder_after.is_finite(),
            "missing group RAM attribution around the eviction"
        );
        assert!(
            hot_before - remainder_after > 300.0,
            "eviction shed only {:.0} MiB",
            hot_before - remainder_after
        );
        // per-function attribution flagged `model` as the RAM hog
        let model_share = fig
            .fn_ram
            .iter()
            .filter(|s| s.group == "ingest+model+refine" && s.function == "model")
            .map(|s| s.ram_mb)
            .fold(f64::NAN, f64::max);
        assert!(model_share > 400.0, "model attribution {model_share}");
        assert!(dir.join("fig7_fn_ram.csv").exists());
        let events = std::fs::read_to_string(dir.join("fig7_events.csv")).unwrap();
        assert!(events.contains("evict"));
        assert!(events.contains("cost_model"));
    }

    #[test]
    fn fig7_mixed_admission_planner_at_smoke_scale() {
        let dir = std::env::temp_dir().join("provuse_fig7_mixed_test");
        let fig = run(&dir, Fig7Params::mixed_smoke()).unwrap();
        for c in fig.checks() {
            assert!(c.pass, "{} — {}\n{}", c.label, c.detail, fig.render());
        }
        // the light pair was scored and admitted on a positive prediction
        assert!(
            fig.admissions
                .iter()
                .any(|a| a.caller == "light_api" && a.callee == "light_fmt" && a.admitted),
            "no admitted light evaluation: {:?}",
            fig.admissions
        );
        // no regrets: nothing the planner admitted was ever taken back
        assert!(fig.regrets.is_empty(), "{:?}", fig.regrets);
        assert!(dir.join("fig7_admissions.csv").exists());
        let admissions = std::fs::read_to_string(dir.join("fig7_admissions.csv")).unwrap();
        assert!(admissions.contains("heavy_api,heavy_model"));
        assert!(admissions.contains("false"), "no refusal rows exported");
    }

    #[test]
    fn fig7_mixed_negative_control_flaps_under_observation_count() {
        let mut p = Fig7Params::mixed_smoke();
        p.merge_policy = crate::config::MergePolicyKind::ObservationCount;
        let dir = std::env::temp_dir().join("provuse_fig7_mixed_neg_test");
        let fig = run(&dir, p).unwrap();
        for c in fig.checks() {
            assert!(c.pass, "{} — {}\n{}", c.label, c.detail, fig.render());
        }
        // the flap costs real work the planner avoids: heavy merges >= 2
        let heavy_merges = fig
            .merges
            .iter()
            .filter(|m| m.functions.iter().any(|f| f == "heavy_model"))
            .count();
        assert!(heavy_merges >= 2, "merges: {:?}", fig.merges);
        // observation-count admission never consults the planner
        assert!(fig.admissions.is_empty(), "{:?}", fig.admissions);
    }

    #[test]
    fn fig7_is_deterministic_per_seed() {
        // two tiny runs with identical seeds must agree on their event
        // timelines exactly (virtual clock determinism)
        let mut p = Fig7Params::smoke();
        p.phase_a_secs = 10.0;
        p.phase_b_secs = 12.0;
        p.phase_c_secs = 0.0;
        p.cooldown_ms = 20_000.0;
        let dir_a = std::env::temp_dir().join("provuse_fig7_det_a");
        let dir_b = std::env::temp_dir().join("provuse_fig7_det_b");
        let a = run(&dir_a, p).unwrap();
        let b = run(&dir_b, p).unwrap();
        assert_eq!(a.merges.len(), b.merges.len());
        assert_eq!(a.splits.len(), b.splits.len());
        for (x, y) in a.merges.iter().zip(&b.merges) {
            assert_eq!(x.t_ms, y.t_ms);
        }
        for (x, y) in a.splits.iter().zip(&b.splits) {
            assert_eq!(x.t_ms, y.t_ms);
            assert_eq!(x.reason, y.reason);
        }
        assert_eq!(a.baseline_p95_ms(), b.baseline_p95_ms());
    }
}
