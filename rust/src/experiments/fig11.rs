//! FIG11 (ours) — the greedy-vs-global planning A/B (ISSUE 8): run the
//! TRAP app (`apps::trap`) once under each `--planner` arm and self-check
//! that
//!
//! 1. **greedy provably locks into a local optimum**: every pairwise step
//!    on the trap chain trips the cost model's churn gate, so the greedy
//!    arm ends with zero merges and at least one refused admission on
//!    record — it evaluated the pairs and said no, forever;
//! 2. **global escapes it**: the periodic re-planner scores whole
//!    partitions, walks through the greedy-refused intermediate, and
//!    executes a plan that fuses the full chain;
//! 3. **global's steady state strictly dominates** greedy's on the same
//!    weighted latency×RAM×bill objective (both arms scored by
//!    [`plan::snapshot_objective`] over their final measured snapshots);
//! 4. neither arm drops a single request while doing so.
//!
//! Both arms share the seed, workload, cost weights, and cost-model merge
//! admission — the only difference is the planning regime, so the A/B
//! isolates exactly the paper's greedy-vs-global question.  The global
//! arm's full plan ledger (planned / executed / realized events) is
//! written as `fig11_plans.csv`, so the A/B is auditable from CSVs alone.

use std::path::Path;
use std::rc::Rc;

use super::write_output;
use crate::apps;
use crate::config::{
    ComputeMode, MergePolicyKind, PlannerKind, PlatformConfig, WorkloadConfig,
};
use crate::error::Result;
use crate::exec::{Executor, Mode};
use crate::fusion::plan;
use crate::metrics::PlanEvent;
use crate::platform::Platform;
use crate::util::stats::fmt_ms;
use crate::workload::{self, WorkloadReport};

/// FIG11 knobs (CLI + smoke test share the driver).
#[derive(Debug, Clone, Copy)]
pub struct Fig11Params {
    pub requests: u64,
    pub rate_rps: f64,
    pub seed: u64,
    pub compute: ComputeMode,
    pub feedback_interval_ms: f64,
    /// feedback ticks between re-plans in the global arm (`--replan-ticks`)
    pub replan_ticks: u32,
    pub min_observations: u32,
}

impl Fig11Params {
    pub fn defaults(smoke: bool) -> Self {
        Fig11Params {
            requests: if smoke { 1_500 } else { 12_000 },
            rate_rps: if smoke { 150.0 } else { 300.0 },
            seed: 13,
            compute: ComputeMode::Replay,
            feedback_interval_ms: 1_000.0,
            replan_ticks: 2,
            min_observations: 3,
        }
    }
}

/// One completed planner arm.
pub struct Fig11Arm {
    pub planner: PlannerKind,
    pub report: WorkloadReport,
    pub merges: usize,
    /// merge-admission evaluations the cost model refused
    pub refused: usize,
    pub inline_calls: u64,
    pub plans: Vec<PlanEvent>,
    pub plans_executed: u64,
    /// fused groups alive at the end of the run
    pub final_groups: Vec<Vec<String>>,
    /// whole-partition objective of the final measured snapshot
    pub objective: f64,
    pub plans_csv: String,
}

pub struct Fig11 {
    pub params: Fig11Params,
    pub greedy: Fig11Arm,
    pub global: Fig11Arm,
    pub checks: Vec<(String, bool)>,
}

impl Fig11 {
    pub fn passed(&self) -> bool {
        self.checks.iter().all(|(_, ok)| *ok)
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "FIG11: greedy vs global re-planning — trap app, {} requests @ {:.0} rps, \
             re-plan every {} ticks\n",
            self.params.requests, self.params.rate_rps, self.params.replan_ticks
        ));
        for arm in [&self.greedy, &self.global] {
            out.push_str(&format!(
                "  {:<6} : {} | {} merges, {} refused, {} plans executed, \
                 objective {:.4}, p95 {}\n",
                arm.planner.name(),
                arm.report.summary(),
                arm.merges,
                arm.refused,
                arm.plans_executed,
                arm.objective,
                fmt_ms(arm.report.latency.p95())
            ));
            out.push_str(&format!(
                "           final groups: {}\n",
                if arm.final_groups.is_empty() {
                    "(all singletons)".to_string()
                } else {
                    arm.final_groups
                        .iter()
                        .map(|g| g.join("+"))
                        .collect::<Vec<_>>()
                        .join(", ")
                }
            ));
        }
        for e in &self.global.plans {
            out.push_str(&format!(
                "  plan {} {:<8} [{} actions] predicted {:.4} -> {:.4}{} {}\n",
                e.plan_id,
                e.kind,
                e.actions,
                e.predicted_before,
                e.predicted_after,
                if e.realized.is_nan() {
                    String::new()
                } else {
                    format!(", realized {:.4}", e.realized)
                },
                e.detail
            ));
        }
        for (name, ok) in &self.checks {
            out.push_str(&format!("  [{}] {}\n", if *ok { "PASS" } else { "FAIL" }, name));
        }
        out
    }
}

fn config(p: &Fig11Params, planner: PlannerKind) -> PlatformConfig {
    let mut cfg = PlatformConfig::tiny().with_compute(p.compute).with_seed(p.seed);
    // fast pipelines so both arms converge well inside the run
    cfg.latency.image_build_ms = 400.0;
    cfg.latency.boot_ms = 200.0;
    cfg.fusion.min_observations = p.min_observations;
    cfg.fusion.feedback_interval_ms = p.feedback_interval_ms;
    // both arms gate admission on the same cost model; the planner is the
    // only difference between them
    cfg.fusion.merge_policy = MergePolicyKind::CostModel;
    // keep the cost model's RAM reference at its default (256 MiB) so the
    // trap's churn-gate arithmetic is exactly the one the app documents
    cfg.fusion.max_group_ram_mb = 0.0;
    cfg.fusion.planner = planner;
    cfg.fusion.replan_interval_ticks = p.replan_ticks;
    cfg
}

fn run_arm(p: &Fig11Params, planner: PlannerKind) -> Result<Fig11Arm> {
    let cfg = config(p, planner);
    let app = apps::trap();
    let wl = WorkloadConfig {
        requests: p.requests,
        rate_rps: p.rate_rps,
        seed: p.seed,
        timeout_ms: 120_000.0,
    };
    Executor::sharded(Mode::Virtual, 1).block_on(async move {
        let platform = Platform::deploy(app, cfg).await?;
        let report = workload::run(Rc::clone(&platform), wl).await?;
        // let the controller keep ticking (plan realization events land one
        // tick after execution) and stragglers settle
        crate::exec::sleep_ms(10_000.0).await;
        let snap = platform.observer.plan_snapshot();
        let objective = plan::snapshot_objective(&snap, &platform.config.fusion);
        platform.shutdown();
        let m = &platform.metrics;
        Ok::<Fig11Arm, crate::error::Error>(Fig11Arm {
            planner,
            merges: m.merges().len(),
            refused: m.admissions().iter().filter(|a| !a.admitted).count(),
            inline_calls: m.counter("inline_calls"),
            plans: m.plans(),
            plans_executed: m.counter("plans_executed"),
            final_groups: snap.groups.clone(),
            objective,
            plans_csv: m.plan_events_csv(),
            report,
        })
    })
}

/// Run FIG11 and write `fig11_summary.txt` + per-arm plan CSVs into
/// `out_dir`.
pub fn run(out_dir: &Path, p: Fig11Params) -> Result<Fig11> {
    let greedy = run_arm(&p, PlannerKind::Greedy)?;
    let global = run_arm(&p, PlannerKind::Global)?;

    let mut checks: Vec<(String, bool)> = Vec::new();
    checks.push((
        format!("greedy arm dropped nothing ({} failed)", greedy.report.failed),
        greedy.report.failed == 0,
    ));
    checks.push((
        format!("global arm dropped nothing ({} failed)", global.report.failed),
        global.report.failed == 0,
    ));
    checks.push((
        format!(
            "greedy locked into the trap's local optimum ({} merges, {} refused admissions)",
            greedy.merges, greedy.refused
        ),
        greedy.merges == 0 && greedy.refused >= 1,
    ));
    checks.push((
        format!("global executed at least one plan ({})", global.plans_executed),
        global.plans_executed >= 1,
    ));
    checks.push((
        "every emitted plan predicted an objective improvement".to_string(),
        global
            .plans
            .iter()
            .filter(|e| e.kind == "planned")
            .all(|e| e.predicted_after < e.predicted_before)
            && global.plans.iter().any(|e| e.kind == "planned"),
    ));
    checks.push((
        "global realized-objective audit trail present".to_string(),
        global.plans.iter().any(|e| e.kind == "realized"),
    ));
    checks.push((
        format!(
            "global fused the whole chain (final groups: {})",
            global
                .final_groups
                .iter()
                .map(|g| g.join("+"))
                .collect::<Vec<_>>()
                .join(", ")
        ),
        global.final_groups.iter().any(|g| g.len() == 3) && global.inline_calls > 0,
    ));
    checks.push((
        format!(
            "global steady state strictly dominates greedy on the objective \
             ({:.4} < {:.4})",
            global.objective, greedy.objective
        ),
        global.objective.is_finite()
            && greedy.objective.is_finite()
            && global.objective < greedy.objective,
    ));

    let fig = Fig11 { params: p, greedy, global, checks };
    write_output(&out_dir.join("fig11_plans.csv"), &fig.global.plans_csv)?;
    write_output(&out_dir.join("fig11_plans_greedy.csv"), &fig.greedy.plans_csv)?;
    write_output(&out_dir.join("fig11_summary.txt"), &fig.render())?;
    Ok(fig)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig11_reduced_scale_ab() {
        let mut p = Fig11Params::defaults(true);
        p.requests = 1_200;
        p.rate_rps = 150.0;
        p.compute = ComputeMode::Disabled;
        let dir = std::env::temp_dir().join("provuse_fig11_test");
        let fig = run(&dir, p).unwrap();
        assert!(fig.passed(), "{}", fig.render());
        // the greedy arm never emitted a plan event; the global arm's CSV
        // carries the full planned/executed/realized audit trail
        assert!(fig.greedy.plans.is_empty());
        let csv = std::fs::read_to_string(dir.join("fig11_plans.csv")).unwrap();
        assert!(csv.starts_with("t_ms,plan_id,kind,actions"));
        assert!(csv.contains(",planned,"));
        assert!(csv.contains(",executed,"));
    }

    #[test]
    fn fig11_arms_are_deterministic() {
        let mut p = Fig11Params::defaults(true);
        p.requests = 600;
        p.rate_rps = 150.0;
        p.compute = ComputeMode::Disabled;
        let a = run_arm(&p, PlannerKind::Global).unwrap();
        let b = run_arm(&p, PlannerKind::Global).unwrap();
        assert_eq!(a.plans_csv, b.plans_csv);
        assert_eq!(a.objective.to_bits(), b.objective.to_bits());
        assert_eq!(a.final_groups, b.final_groups);
    }
}
