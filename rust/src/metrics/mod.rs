//! Metrics recorder: request latencies, RAM time series (platform-wide and
//! per fused group), merge/split events, and named counters — everything
//! the paper's evaluation section reports plus the feedback controller's
//! observability surface.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::rc::Rc;

use crate::cluster::NodeId;
use crate::fusion::SplitReason;
use crate::util::stats::Quantiles;

/// Minimum samples a latency window needs before its p95 is considered
/// meaningful (shared by the feedback controller's window checks and the
/// merger's baseline capture).
pub const MIN_WINDOW_SAMPLES: usize = 5;

/// One completed request.
#[derive(Debug, Clone, Copy)]
pub struct LatencySample {
    /// virtual time the request arrived at the gateway (ms since start)
    pub t_ms: f64,
    /// end-to-end latency (ms)
    pub latency_ms: f64,
}

/// One RAM ledger sample.
#[derive(Debug, Clone, Copy)]
pub struct RamSample {
    pub t_ms: f64,
    /// total platform RAM across live instances (MiB)
    pub total_mb: f64,
    /// number of live (booting/healthy/draining) instances
    pub instances: usize,
}

/// One per-node RAM ledger sample (cluster mode; single-node platforms
/// record one series for node-0 that mirrors the platform series).
#[derive(Debug, Clone, Copy)]
pub struct NodeRamSample {
    pub t_ms: f64,
    pub node: NodeId,
    /// RAM across the node's live instances (MiB)
    pub ram_mb: f64,
    /// the node's capacity (MiB; 0 = uncapped) — recorded so the CSV is
    /// self-describing for pressure plots
    pub capacity_mb: f64,
    pub instances: usize,
}

/// One completed live migration: an instance moved between nodes with an
/// atomic route cutover (FIG8).
#[derive(Debug, Clone)]
pub struct MigrationEvent {
    /// virtual time the replacement took over the routes (ms)
    pub t_ms: f64,
    /// functions the migrated instance actively hosts (sorted)
    pub functions: Vec<String>,
    pub from: NodeId,
    pub to: NodeId,
    /// wall (virtual) duration of the migration pipeline (ms)
    pub duration_ms: f64,
    /// why the platform moved it ("node_pressure", "fusion_colocation")
    pub reason: &'static str,
}

/// One completed merge (a vertical line in the paper's Fig. 5).
#[derive(Debug, Clone)]
pub struct MergeEvent {
    /// virtual time the fused instance went healthy + routed (ms)
    pub t_ms: f64,
    /// functions hosted by the new fused instance
    pub functions: Vec<String>,
    /// wall (virtual) duration of the merge pipeline (ms)
    pub duration_ms: f64,
}

/// One completed defusion: a fused group broken back into per-function
/// instances by the feedback controller (FIG7).
#[derive(Debug, Clone)]
pub struct SplitEvent {
    /// virtual time the per-function routes were cut back over (ms)
    pub t_ms: f64,
    /// functions the group hosted (sorted)
    pub functions: Vec<String>,
    /// wall (virtual) duration of the split pipeline (ms)
    pub duration_ms: f64,
    /// which policy violation triggered the split
    pub reason: SplitReason,
}

/// One RAM attribution sample for a live fused group (the controller's
/// per-group view, recorded every feedback tick).
#[derive(Debug, Clone)]
pub struct GroupRamSample {
    pub t_ms: f64,
    /// `+`-joined sorted function names identifying the group
    pub group: String,
    /// instantaneous RAM of the fused instance (MiB)
    pub ram_mb: f64,
}

/// One per-function handler latency observation, emitted by the Function
/// Handler on every invocation (remote or inlined).  This is the signal
/// that gives *interior* functions of a fused group their own latency
/// series — the entry-route e2e p95 alone cannot attribute blame.
#[derive(Debug, Clone)]
pub struct FnSample {
    /// virtual time the handler finished the function body (ms since epoch)
    pub t_ms: f64,
    pub function: String,
    /// handler self time: dispatch/inline charge + compute + busy time,
    /// excluding time blocked on outbound calls (ms)
    pub handler_ms: f64,
}

/// One per-function RAM attribution inside a fused instance (code footprint
/// plus an equal share of the base runtime + in-flight working sets),
/// recorded by the controller every feedback tick.
#[derive(Debug, Clone)]
pub struct FnRamSample {
    pub t_ms: f64,
    /// `+`-joined sorted names of the hosting group
    pub group: String,
    pub function: String,
    /// attributed RAM (MiB); group members sum to the instance's RAM
    pub ram_mb: f64,
}

/// One merge-admission evaluation by the cost-aware planner (recorded each
/// time a candidate pair is re-scored against fresh window signals).
#[derive(Debug, Clone)]
pub struct AdmissionSample {
    pub t_ms: f64,
    pub caller: String,
    pub callee: String,
    /// predicted net benefit (see `fusion::cost::CostModel::predict_merge`)
    pub score: f64,
    pub admitted: bool,
}

/// One auto-tune regret: a cost-admitted fuse was evicted/split within one
/// cooldown of its cutover; the sample records the weights *after* the
/// hill-climb step so the series doubles as the weight trajectory.
#[derive(Debug, Clone)]
pub struct RegretSample {
    pub t_ms: f64,
    pub caller: String,
    pub callee: String,
    pub w_latency: f64,
    pub w_ram: f64,
    pub w_gbs: f64,
}

/// Attribute a fused instance's RAM to its members: each function keeps its
/// code footprint and receives a share of everything the code does not
/// explain (base runtime + in-flight working sets); shares sum to
/// `total_mb` whenever it covers the members' code footprints (always true
/// for a live instance).  `members` is `(function, code_mb)`.
///
/// `in_flight` is the per-member in-flight request count (index-aligned
/// with `members`; the platform samples `Instance::fn_inflight` at each
/// controller tick).  When any member holds in-flight requests, the
/// overhead is split **proportionally to ownership** — the member serving
/// 9 of 10 in-flight requests owns 90% of the working sets.  An idle
/// window (all zeros) or a mismatched slice falls back to the equal share,
/// so the pre-weighting behavior is the degenerate case, not a separate
/// code path.
pub fn attribute_ram(
    total_mb: f64,
    members: &[(String, f64)],
    in_flight: &[u64],
) -> Vec<(String, f64)> {
    if members.is_empty() {
        return Vec::new();
    }
    let code_total: f64 = members.iter().map(|(_, mb)| mb).sum();
    let overhead = (total_mb - code_total).max(0.0);
    let total_in_flight: u64 =
        if in_flight.len() == members.len() { in_flight.iter().sum() } else { 0 };
    let equal = 1.0 / members.len() as f64;
    members
        .iter()
        .enumerate()
        .map(|(i, (name, code_mb))| {
            let weight = if total_in_flight > 0 {
                in_flight[i] as f64 / total_in_flight as f64
            } else {
                equal
            };
            (name.clone(), code_mb + overhead * weight)
        })
        .collect()
}

/// One completed partial split: a single function evicted from a fused
/// group onto its own redeployed instance while the remainder stays fused.
#[derive(Debug, Clone)]
pub struct EvictEvent {
    /// virtual time the evicted function's route was cut over (ms)
    pub t_ms: f64,
    /// group membership before the eviction (sorted)
    pub group: Vec<String>,
    /// the function that left the group
    pub function: String,
    /// wall (virtual) duration of the evict pipeline (ms)
    pub duration_ms: f64,
    /// which policy violation triggered the eviction
    pub reason: SplitReason,
}

/// Shared, single-threaded metrics sink (cheap `Rc` handle).
#[derive(Clone, Default)]
pub struct Recorder {
    inner: Rc<RecorderInner>,
}

#[derive(Default)]
struct RecorderInner {
    latencies: RefCell<Vec<LatencySample>>,
    ram: RefCell<Vec<RamSample>>,
    node_ram: RefCell<Vec<NodeRamSample>>,
    migrations: RefCell<Vec<MigrationEvent>>,
    group_ram: RefCell<Vec<GroupRamSample>>,
    fn_latencies: RefCell<Vec<FnSample>>,
    fn_ram: RefCell<Vec<FnRamSample>>,
    merges: RefCell<Vec<MergeEvent>>,
    splits: RefCell<Vec<SplitEvent>>,
    evicts: RefCell<Vec<EvictEvent>>,
    admissions: RefCell<Vec<AdmissionSample>>,
    regrets: RefCell<Vec<RegretSample>>,
    counters: RefCell<BTreeMap<&'static str, u64>>,
    /// absolute virtual-time (ms) all recorded timestamps are relative to
    epoch_ms: std::cell::Cell<f64>,
}

impl Recorder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Anchor the time base at the current executor instant (set once, when
    /// the platform finishes deploying, so latency / RAM / merge series all
    /// share one clock).
    pub fn set_epoch_now(&self) {
        self.inner.epoch_ms.set(crate::exec::now().as_millis_f64());
    }

    /// Milliseconds since the epoch (requires a running executor).
    pub fn rel_now_ms(&self) -> f64 {
        crate::exec::now().as_millis_f64() - self.inner.epoch_ms.get()
    }

    pub fn record_latency(&self, t_ms: f64, latency_ms: f64) {
        self.inner.latencies.borrow_mut().push(LatencySample { t_ms, latency_ms });
    }

    pub fn record_ram(&self, t_ms: f64, total_mb: f64, instances: usize) {
        self.inner.ram.borrow_mut().push(RamSample { t_ms, total_mb, instances });
    }

    pub fn record_node_ram(&self, sample: NodeRamSample) {
        self.inner.node_ram.borrow_mut().push(sample);
    }

    pub fn record_migration(&self, event: MigrationEvent) {
        self.inner.migrations.borrow_mut().push(event);
    }

    pub fn record_group_ram(&self, t_ms: f64, group: String, ram_mb: f64) {
        self.inner.group_ram.borrow_mut().push(GroupRamSample { t_ms, group, ram_mb });
    }

    pub fn record_fn_latency(&self, t_ms: f64, function: String, handler_ms: f64) {
        self.inner.fn_latencies.borrow_mut().push(FnSample { t_ms, function, handler_ms });
    }

    pub fn record_fn_ram(&self, t_ms: f64, group: String, function: String, ram_mb: f64) {
        self.inner.fn_ram.borrow_mut().push(FnRamSample { t_ms, group, function, ram_mb });
    }

    pub fn record_merge(&self, event: MergeEvent) {
        self.inner.merges.borrow_mut().push(event);
    }

    pub fn record_split(&self, event: SplitEvent) {
        self.inner.splits.borrow_mut().push(event);
    }

    pub fn record_evict(&self, event: EvictEvent) {
        self.inner.evicts.borrow_mut().push(event);
    }

    pub fn record_admission(&self, sample: AdmissionSample) {
        self.inner.admissions.borrow_mut().push(sample);
    }

    pub fn record_regret(&self, sample: RegretSample) {
        self.inner.regrets.borrow_mut().push(sample);
    }

    pub fn bump(&self, name: &'static str) {
        *self.inner.counters.borrow_mut().entry(name).or_insert(0) += 1;
    }

    pub fn counter(&self, name: &'static str) -> u64 {
        self.inner.counters.borrow().get(name).copied().unwrap_or(0)
    }

    // -- accessors ----------------------------------------------------------

    pub fn latencies(&self) -> Vec<LatencySample> {
        self.inner.latencies.borrow().clone()
    }

    pub fn ram_series(&self) -> Vec<RamSample> {
        self.inner.ram.borrow().clone()
    }

    pub fn node_ram_series(&self) -> Vec<NodeRamSample> {
        self.inner.node_ram.borrow().clone()
    }

    pub fn migrations(&self) -> Vec<MigrationEvent> {
        self.inner.migrations.borrow().clone()
    }

    pub fn merges(&self) -> Vec<MergeEvent> {
        self.inner.merges.borrow().clone()
    }

    pub fn splits(&self) -> Vec<SplitEvent> {
        self.inner.splits.borrow().clone()
    }

    pub fn evicts(&self) -> Vec<EvictEvent> {
        self.inner.evicts.borrow().clone()
    }

    pub fn group_ram_series(&self) -> Vec<GroupRamSample> {
        self.inner.group_ram.borrow().clone()
    }

    pub fn fn_latency_series(&self) -> Vec<FnSample> {
        self.inner.fn_latencies.borrow().clone()
    }

    pub fn fn_ram_series(&self) -> Vec<FnRamSample> {
        self.inner.fn_ram.borrow().clone()
    }

    pub fn admissions(&self) -> Vec<AdmissionSample> {
        self.inner.admissions.borrow().clone()
    }

    pub fn regrets(&self) -> Vec<RegretSample> {
        self.inner.regrets.borrow().clone()
    }

    /// p95 of one function's handler latencies over `[from_ms, to_ms)`, or
    /// NaN when the window holds fewer than `min_n` samples — the per-route
    /// signal the cost model attributes blame with.
    ///
    /// `fn_latencies` is appended at completion time, so it is sorted by
    /// `t_ms`; a binary search bounds the controller's per-tick work to the
    /// trailing window instead of the whole run's history.
    pub fn fn_p95_window(&self, function: &str, from_ms: f64, to_ms: f64, min_n: usize) -> f64 {
        let borrowed = self.inner.fn_latencies.borrow();
        let series: &[FnSample] = &borrowed;
        let start = series.partition_point(|s| s.t_ms < from_ms);
        let q = Quantiles::from_samples(
            series[start..]
                .iter()
                .take_while(|s| s.t_ms < to_ms)
                .filter(|s| s.function == function)
                .map(|s| s.handler_ms)
                .collect(),
        );
        if q.len() >= min_n { q.p95() } else { f64::NAN }
    }

    /// Summed handler self-time (ms) of one function over `[from_ms,
    /// to_ms)` — with the billing ledger's windowed duration this yields
    /// the caller's blocked (double-billed) time, the merge planner's
    /// hop-savings signal.  Same binary-search bound as [`Self::fn_p95_window`].
    pub fn fn_self_ms_window(&self, function: &str, from_ms: f64, to_ms: f64) -> f64 {
        let borrowed = self.inner.fn_latencies.borrow();
        let series: &[FnSample] = &borrowed;
        let start = series.partition_point(|s| s.t_ms < from_ms);
        series[start..]
            .iter()
            .take_while(|s| s.t_ms < to_ms)
            .filter(|s| s.function == function)
            .map(|s| s.handler_ms)
            .sum()
    }

    /// RAM attribution samples of one fused group (`+`-joined sorted names).
    pub fn group_ram_for(&self, group: &str) -> Vec<GroupRamSample> {
        self.inner
            .group_ram
            .borrow()
            .iter()
            .filter(|s| s.group == group)
            .cloned()
            .collect()
    }

    pub fn request_count(&self) -> usize {
        self.inner.latencies.borrow().len()
    }

    /// Quantiles over all request latencies.
    pub fn latency_quantiles(&self) -> Quantiles {
        Quantiles::from_samples(
            self.inner.latencies.borrow().iter().map(|s| s.latency_ms).collect(),
        )
    }

    /// Quantiles over requests arriving in `[from_ms, to_ms)` — used to
    /// separate pre-merge and post-merge phases (paper Fig. 5 analysis).
    pub fn latency_quantiles_window(&self, from_ms: f64, to_ms: f64) -> Quantiles {
        Quantiles::from_samples(
            self.inner
                .latencies
                .borrow()
                .iter()
                .filter(|s| s.t_ms >= from_ms && s.t_ms < to_ms)
                .map(|s| s.latency_ms)
                .collect(),
        )
    }

    /// p95 over requests arriving in `[from_ms, to_ms)`, or NaN when the
    /// window holds fewer than `min_n` samples.
    pub fn p95_window(&self, from_ms: f64, to_ms: f64, min_n: usize) -> f64 {
        let q = self.latency_quantiles_window(from_ms, to_ms);
        if q.len() >= min_n { q.p95() } else { f64::NAN }
    }

    /// Time-weighted mean of the RAM series (MiB).
    pub fn ram_mean_mb(&self) -> f64 {
        let ram = self.inner.ram.borrow();
        if ram.len() < 2 {
            return ram.first().map(|s| s.total_mb).unwrap_or(f64::NAN);
        }
        let mut weighted = 0.0;
        let mut span = 0.0;
        for pair in ram.windows(2) {
            let dt = pair[1].t_ms - pair[0].t_ms;
            weighted += pair[0].total_mb * dt;
            span += dt;
        }
        if span <= 0.0 { ram[0].total_mb } else { weighted / span }
    }

    /// Steady-state RAM: time-weighted mean over the tail of the run
    /// (after `from_ms`).
    pub fn ram_mean_mb_after(&self, from_ms: f64) -> f64 {
        let ram: Vec<RamSample> = self
            .inner
            .ram
            .borrow()
            .iter()
            .copied()
            .filter(|s| s.t_ms >= from_ms)
            .collect();
        if ram.len() < 2 {
            return ram.first().map(|s| s.total_mb).unwrap_or(f64::NAN);
        }
        let mut weighted = 0.0;
        let mut span = 0.0;
        for pair in ram.windows(2) {
            let dt = pair[1].t_ms - pair[0].t_ms;
            weighted += pair[0].total_mb * dt;
            span += dt;
        }
        weighted / span
    }

    /// CSV export of the latency time series (`t_ms,latency_ms`).
    pub fn latency_csv(&self) -> String {
        let mut out = String::from("t_ms,latency_ms\n");
        for s in self.inner.latencies.borrow().iter() {
            out.push_str(&format!("{:.3},{:.3}\n", s.t_ms, s.latency_ms));
        }
        out
    }

    /// CSV export of the RAM series (`t_ms,total_mb,instances`).
    pub fn ram_csv(&self) -> String {
        let mut out = String::from("t_ms,total_mb,instances\n");
        for s in self.inner.ram.borrow().iter() {
            out.push_str(&format!("{:.3},{:.3},{}\n", s.t_ms, s.total_mb, s.instances));
        }
        out
    }

    /// CSV export of the per-node RAM series
    /// (`t_ms,node,ram_mb,capacity_mb,instances`).
    pub fn node_ram_csv(&self) -> String {
        let mut out = String::from("t_ms,node,ram_mb,capacity_mb,instances\n");
        for s in self.inner.node_ram.borrow().iter() {
            out.push_str(&format!(
                "{:.3},{},{:.3},{:.3},{}\n",
                s.t_ms, s.node, s.ram_mb, s.capacity_mb, s.instances
            ));
        }
        out
    }

    /// CSV export of migration events
    /// (`t_ms,duration_ms,from,to,reason,functions`).
    pub fn migrations_csv(&self) -> String {
        let mut out = String::from("t_ms,duration_ms,from,to,reason,functions\n");
        for m in self.inner.migrations.borrow().iter() {
            out.push_str(&format!(
                "{:.3},{:.3},{},{},{},{}\n",
                m.t_ms,
                m.duration_ms,
                m.from,
                m.to,
                m.reason,
                m.functions.join("+")
            ));
        }
        out
    }

    /// CSV export of merge events (`t_ms,duration_ms,functions`).
    pub fn merges_csv(&self) -> String {
        let mut out = String::from("t_ms,duration_ms,functions\n");
        for m in self.inner.merges.borrow().iter() {
            out.push_str(&format!(
                "{:.3},{:.3},{}\n",
                m.t_ms,
                m.duration_ms,
                m.functions.join("+")
            ));
        }
        out
    }

    /// CSV export of split events (`t_ms,duration_ms,reason,functions`).
    pub fn splits_csv(&self) -> String {
        let mut out = String::from("t_ms,duration_ms,reason,functions\n");
        for s in self.inner.splits.borrow().iter() {
            out.push_str(&format!(
                "{:.3},{:.3},{},{}\n",
                s.t_ms,
                s.duration_ms,
                s.reason.name(),
                s.functions.join("+")
            ));
        }
        out
    }

    /// CSV export of the per-group RAM attribution (`t_ms,group,ram_mb`).
    pub fn group_ram_csv(&self) -> String {
        let mut out = String::from("t_ms,group,ram_mb\n");
        for s in self.inner.group_ram.borrow().iter() {
            out.push_str(&format!("{:.3},{},{:.3}\n", s.t_ms, s.group, s.ram_mb));
        }
        out
    }

    /// CSV export of per-function handler latencies (`t_ms,function,handler_ms`).
    pub fn fn_latency_csv(&self) -> String {
        let mut out = String::from("t_ms,function,handler_ms\n");
        for s in self.inner.fn_latencies.borrow().iter() {
            out.push_str(&format!("{:.3},{},{:.3}\n", s.t_ms, s.function, s.handler_ms));
        }
        out
    }

    /// CSV export of per-function RAM attribution (`t_ms,group,function,ram_mb`).
    pub fn fn_ram_csv(&self) -> String {
        let mut out = String::from("t_ms,group,function,ram_mb\n");
        for s in self.inner.fn_ram.borrow().iter() {
            out.push_str(&format!(
                "{:.3},{},{},{:.3}\n",
                s.t_ms, s.group, s.function, s.ram_mb
            ));
        }
        out
    }

    /// CSV export of merge-admission evaluations
    /// (`t_ms,caller,callee,score,admitted`).
    pub fn admissions_csv(&self) -> String {
        let mut out = String::from("t_ms,caller,callee,score,admitted\n");
        for s in self.inner.admissions.borrow().iter() {
            out.push_str(&format!(
                "{:.3},{},{},{:.4},{}\n",
                s.t_ms, s.caller, s.callee, s.score, s.admitted
            ));
        }
        out
    }

    /// CSV export of auto-tune regrets + post-step weights
    /// (`t_ms,caller,callee,w_latency,w_ram,w_gbs`).
    pub fn regrets_csv(&self) -> String {
        let mut out = String::from("t_ms,caller,callee,w_latency,w_ram,w_gbs\n");
        for s in self.inner.regrets.borrow().iter() {
            out.push_str(&format!(
                "{:.3},{},{},{:.4},{:.4},{:.4}\n",
                s.t_ms, s.caller, s.callee, s.w_latency, s.w_ram, s.w_gbs
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_and_windows() {
        let r = Recorder::new();
        for i in 0..100 {
            // first half slow (100ms), second half fast (50ms)
            let lat = if i < 50 { 100.0 } else { 50.0 };
            r.record_latency(i as f64 * 10.0, lat);
        }
        assert_eq!(r.request_count(), 100);
        let pre = r.latency_quantiles_window(0.0, 500.0);
        let post = r.latency_quantiles_window(500.0, 1e9);
        assert_eq!(pre.median(), 100.0);
        assert_eq!(post.median(), 50.0);
    }

    #[test]
    fn ram_time_weighted_mean() {
        let r = Recorder::new();
        // 100 MB for 10ms, then 50 MB for 30ms -> (1000 + 1500)/40 = 62.5
        r.record_ram(0.0, 100.0, 2);
        r.record_ram(10.0, 50.0, 1);
        r.record_ram(40.0, 50.0, 1);
        assert!((r.ram_mean_mb() - 62.5).abs() < 1e-9);
        assert!((r.ram_mean_mb_after(10.0) - 50.0).abs() < 1e-9);
    }

    #[test]
    fn counters() {
        let r = Recorder::new();
        r.bump("merge_requests");
        r.bump("merge_requests");
        assert_eq!(r.counter("merge_requests"), 2);
        assert_eq!(r.counter("nope"), 0);
    }

    #[test]
    fn csv_headers() {
        let r = Recorder::new();
        r.record_latency(1.0, 2.0);
        r.record_ram(1.0, 3.0, 1);
        r.record_merge(MergeEvent {
            t_ms: 5.0,
            functions: vec!["a".into(), "b".into()],
            duration_ms: 7.0,
        });
        assert!(r.latency_csv().starts_with("t_ms,latency_ms\n1.000,2.000"));
        assert!(r.ram_csv().contains("1.000,3.000,1"));
        assert!(r.merges_csv().contains("a+b"));
    }

    #[test]
    fn split_events_and_group_ram_recorded() {
        let r = Recorder::new();
        r.record_split(SplitEvent {
            t_ms: 9.0,
            functions: vec!["a".into(), "b".into()],
            duration_ms: 2.0,
            reason: SplitReason::RamCap,
        });
        r.record_group_ram(4.0, "a+b".into(), 120.5);
        r.record_group_ram(5.0, "c+d".into(), 80.0);
        assert_eq!(r.splits().len(), 1);
        assert_eq!(r.splits()[0].reason, SplitReason::RamCap);
        assert!(r.splits_csv().contains("ram_cap"));
        assert!(r.splits_csv().contains("a+b"));
        assert_eq!(r.group_ram_series().len(), 2);
        assert_eq!(r.group_ram_for("a+b").len(), 1);
        assert!(r.group_ram_csv().contains("4.000,a+b,120.500"));
    }

    #[test]
    fn fn_attribution_series_and_windows() {
        let r = Recorder::new();
        for i in 0..10 {
            r.record_fn_latency(i as f64 * 100.0, "hot".into(), 200.0);
            r.record_fn_latency(i as f64 * 100.0, "cool".into(), 10.0);
        }
        r.record_fn_ram(50.0, "cool+hot".into(), "hot".into(), 120.0);
        assert_eq!(r.fn_latency_series().len(), 20);
        assert_eq!(r.fn_ram_series().len(), 1);
        // per-function windows are independent
        assert_eq!(r.fn_p95_window("hot", 0.0, 1_000.0, 5), 200.0);
        assert_eq!(r.fn_p95_window("cool", 0.0, 1_000.0, 5), 10.0);
        // too few samples in a narrow window -> NaN
        assert!(r.fn_p95_window("hot", 0.0, 250.0, 5).is_nan());
        assert!(r.fn_p95_window("ghost", 0.0, 1_000.0, 1).is_nan());
        assert!(r.fn_latency_csv().contains("hot,200.000"));
        assert!(r.fn_ram_csv().contains("cool+hot,hot,120.000"));
    }

    #[test]
    fn evict_events_recorded() {
        let r = Recorder::new();
        r.record_evict(EvictEvent {
            t_ms: 12.0,
            group: vec!["a".into(), "b".into(), "c".into()],
            function: "b".into(),
            duration_ms: 3.0,
            reason: SplitReason::CostModel,
        });
        assert_eq!(r.evicts().len(), 1);
        assert_eq!(r.evicts()[0].function, "b");
        assert_eq!(r.evicts()[0].reason, SplitReason::CostModel);
        assert_eq!(r.evicts()[0].group.join("+"), "a+b+c");
    }

    #[test]
    fn node_ram_and_migration_series_recorded() {
        let r = Recorder::new();
        r.record_node_ram(NodeRamSample {
            t_ms: 3.0,
            node: NodeId(1),
            ram_mb: 140.5,
            capacity_mb: 300.0,
            instances: 2,
        });
        r.record_migration(MigrationEvent {
            t_ms: 8.0,
            functions: vec!["a".into(), "b".into()],
            from: NodeId(1),
            to: NodeId(2),
            duration_ms: 450.0,
            reason: "node_pressure",
        });
        assert_eq!(r.node_ram_series().len(), 1);
        assert_eq!(r.node_ram_series()[0].node, NodeId(1));
        assert_eq!(r.migrations().len(), 1);
        assert_eq!(r.migrations()[0].to, NodeId(2));
        assert!(r.node_ram_csv().contains("3.000,node-1,140.500,300.000,2"));
        assert!(r
            .migrations_csv()
            .contains("8.000,450.000,node-1,node-2,node_pressure,a+b"));
    }

    #[test]
    fn clone_shares_state() {
        let r = Recorder::new();
        let r2 = r.clone();
        r2.record_latency(0.0, 1.0);
        assert_eq!(r.request_count(), 1);
    }

    #[test]
    fn admission_and_regret_series_recorded() {
        let r = Recorder::new();
        r.record_admission(AdmissionSample {
            t_ms: 5.0,
            caller: "a".into(),
            callee: "b".into(),
            score: 0.42,
            admitted: true,
        });
        r.record_admission(AdmissionSample {
            t_ms: 7.0,
            caller: "a".into(),
            callee: "big".into(),
            score: -1.5,
            admitted: false,
        });
        r.record_regret(RegretSample {
            t_ms: 30.0,
            caller: "a".into(),
            callee: "b".into(),
            w_latency: 0.8,
            w_ram: 1.25,
            w_gbs: 0.8,
        });
        assert_eq!(r.admissions().len(), 2);
        assert!(r.admissions()[1].score < 0.0 && !r.admissions()[1].admitted);
        assert_eq!(r.regrets().len(), 1);
        assert!(r.admissions_csv().contains("5.000,a,b,0.4200,true"));
        assert!(r.admissions_csv().contains("a,big,-1.5000,false"));
        assert!(r.regrets_csv().contains("30.000,a,b,0.8000,1.2500,0.8000"));
    }

    #[test]
    fn fn_self_ms_window_sums_only_the_window() {
        let r = Recorder::new();
        for i in 0..10 {
            r.record_fn_latency(i as f64 * 100.0, "hot".into(), 20.0);
            r.record_fn_latency(i as f64 * 100.0, "cool".into(), 5.0);
        }
        assert_eq!(r.fn_self_ms_window("hot", 0.0, 1_000.0), 200.0);
        // [from, to) bounds, per-function filter, empty windows
        assert_eq!(r.fn_self_ms_window("hot", 0.0, 500.0), 100.0);
        assert_eq!(r.fn_self_ms_window("cool", 300.0, 600.0), 15.0);
        assert_eq!(r.fn_self_ms_window("ghost", 0.0, 1_000.0), 0.0);
    }

    // -- working-set RAM attribution (ISSUE 3 satellite) ----------------------

    fn members(specs: &[(&str, f64)]) -> Vec<(String, f64)> {
        specs.iter().map(|(n, mb)| (n.to_string(), *mb)).collect()
    }

    #[test]
    fn attribute_ram_splits_overhead_equally_and_sums_to_total() {
        // Documented current behavior: each member keeps its code footprint
        // and the unexplained remainder (base runtime + in-flight working
        // sets) is split EQUALLY, regardless of who owns the in-flight
        // requests.
        let shares = attribute_ram(100.0, &members(&[("a", 10.0), ("b", 30.0)]), &[]);
        assert_eq!(shares.len(), 2);
        assert_eq!(shares[0], ("a".to_string(), 40.0)); // 10 + 60/2
        assert_eq!(shares[1], ("b".to_string(), 60.0)); // 30 + 60/2
        let sum: f64 = shares.iter().map(|(_, mb)| mb).sum();
        assert!((sum - 100.0).abs() < 1e-12);
        // code exceeding the measured total never attributes negative RAM
        let tight = attribute_ram(30.0, &members(&[("a", 20.0), ("b", 20.0)]), &[]);
        assert_eq!(tight[0].1, 20.0);
        assert_eq!(tight[1].1, 20.0);
        assert!(attribute_ram(50.0, &[], &[]).is_empty());
    }

    #[test]
    fn attribute_ram_weights_overhead_by_in_flight_ownership() {
        // The flipped PR 3 tripwire (ROADMAP: working-set RAM by in-flight
        // ownership): a member holding 9 of 10 in-flight requests is
        // attributed 90% of the unexplained overhead.
        let shares = attribute_ram(100.0, &members(&[("busy", 10.0), ("idle", 10.0)]), &[9, 1]);
        assert!(
            shares[0].1 > shares[1].1,
            "in-flight-weighted attribution regressed: busy={} idle={}",
            shares[0].1,
            shares[1].1
        );
        // overhead = 100 - 20 = 80: busy gets 10 + 72, idle gets 10 + 8
        assert!((shares[0].1 - 82.0).abs() < 1e-12);
        assert!((shares[1].1 - 18.0).abs() < 1e-12);
        let sum: f64 = shares.iter().map(|(_, mb)| mb).sum();
        assert!((sum - 100.0).abs() < 1e-12, "weighting must preserve the total");
    }

    #[test]
    fn attribute_ram_falls_back_to_equal_share_when_idle_or_unaligned() {
        // all-idle window: equal share
        let idle = attribute_ram(100.0, &members(&[("a", 10.0), ("b", 30.0)]), &[0, 0]);
        assert_eq!(idle[0].1, 40.0);
        assert_eq!(idle[1].1, 60.0);
        // a mismatched slice (e.g. a caller without ownership data) also
        // degrades to the equal share instead of panicking
        let unaligned = attribute_ram(100.0, &members(&[("a", 10.0), ("b", 30.0)]), &[5]);
        assert_eq!(unaligned[0].1, 40.0);
        assert_eq!(unaligned[1].1, 60.0);
    }
}
