//! Metrics recorder: request latencies, RAM time series (platform-wide and
//! per fused group), merge/split events, and named counters — everything
//! the paper's evaluation section reports plus the feedback controller's
//! observability surface.
//!
//! Since ISSUE 5 the recorder is a **two-tier pipeline**:
//!
//! * **Windowed shards** (always on) — per-function and end-to-end
//!   time-bucketed rings keyed by interned [`Sym`]s, each bucket holding
//!   the raw samples of one `bucket_ms` slice plus an incrementally
//!   maintained [`Summary`] + [`LogHistogram`].  The controller-tick
//!   signals (`fn_p95_window`, `fn_self_ms_window`, `p95_window`) read
//!   *only* the target function's overlapping buckets — no scan over the
//!   whole run's interleaved history, no per-tick allocation (a reusable
//!   scratch buffer holds the sort).  Ring memory is bounded by
//!   `buckets x bucket_ms` of retention regardless of run length.
//! * **Full series** ([`RecordingLevel::Full`], the default) — the seed's
//!   unbounded raw vectors, kept for experiments that export exact CSVs.
//!   [`RecordingLevel::Windowed`] drops them, bounding recorder memory at
//!   million-request scale (`figure9`); low-rate *event* series (merges,
//!   splits, evicts, admissions, regrets) are retained at every level
//!   because verdict parity checks need them.
//!
//! Exactness contract: windowed quantiles are computed from the retained
//! raw samples with the same retain/sort/interpolate steps as
//! [`Quantiles`], so for any trailing window inside the retention span the
//! result is bit-identical across recording levels (the FIG7 golden test
//! pins this).

use std::cell::{Cell, RefCell};
use std::collections::{BTreeMap, HashMap};
use std::rc::Rc;

use crate::cluster::NodeId;
use crate::fusion::SplitReason;
use crate::util::intern::{GroupKey, Sym};
use crate::util::stats::{quantile_sorted, LogHistogram, Quantiles, Summary};

/// Minimum samples a latency window needs before its p95 is considered
/// meaningful (shared by the feedback controller's window checks and the
/// merger's baseline capture).
pub const MIN_WINDOW_SAMPLES: usize = 5;

/// How much raw telemetry the recorder retains.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecordingLevel {
    /// Seed behavior: every sample of every series kept for the whole run
    /// (exact CSVs; memory grows with request count).
    Full,
    /// Bounded: only the windowed ring shards + event series are kept.
    /// Recorder memory is O(retention), independent of run length.
    Windowed,
}

impl RecordingLevel {
    /// Stable label (`full`/`windowed`) for CLI flags and exports.
    pub fn name(&self) -> &'static str {
        match self {
            RecordingLevel::Full => "full",
            RecordingLevel::Windowed => "windowed",
        }
    }

    /// Parse a recording-level name from the CLI.
    pub fn parse(s: &str) -> crate::error::Result<Self> {
        match s {
            "full" => Ok(RecordingLevel::Full),
            "windowed" | "window" | "bounded" => Ok(RecordingLevel::Windowed),
            other => Err(crate::error::Error::Config(format!(
                "unknown recording level `{other}` (available: full, windowed)"
            ))),
        }
    }
}

/// Windowed-shard shape: ring of `buckets` time slices of `bucket_ms`
/// each; retention = `buckets x bucket_ms`.
#[derive(Debug, Clone)]
pub struct RecordingConfig {
    /// raw-series retention tier
    pub level: RecordingLevel,
    /// windowed-shard bucket width (virtual ms)
    pub bucket_ms: f64,
    /// ring length; retention = `buckets * bucket_ms`
    pub buckets: usize,
}

impl Default for RecordingConfig {
    fn default() -> Self {
        RecordingConfig { level: RecordingLevel::Full, bucket_ms: 1_000.0, buckets: 128 }
    }
}

impl RecordingConfig {
    /// Grow `bucket_ms` (keeping the ring length) until the retention span
    /// covers `window_ms` — the platform calls this with twice the longest
    /// trailing window any consumer queries (controller interval, merger
    /// baseline lookback), so windowed answers are always complete.
    pub fn ensure_retention_ms(&mut self, window_ms: f64) {
        self.buckets = self.buckets.max(2);
        if self.bucket_ms <= 0.0 {
            self.bucket_ms = 1_000.0;
        }
        let retention = self.bucket_ms * self.buckets as f64;
        if window_ms > retention {
            self.bucket_ms = window_ms / self.buckets as f64;
        }
    }

    /// Trailing span the windowed shards retain (ms).
    pub fn retention_ms(&self) -> f64 {
        self.bucket_ms * self.buckets as f64
    }
}

/// One completed request.
#[derive(Debug, Clone, Copy)]
pub struct LatencySample {
    /// virtual time the request arrived at the gateway (ms since start)
    pub t_ms: f64,
    /// end-to-end latency (ms)
    pub latency_ms: f64,
}

/// One RAM ledger sample.
#[derive(Debug, Clone, Copy)]
pub struct RamSample {
    /// virtual timestamp (ms)
    pub t_ms: f64,
    /// total platform RAM across live instances (MiB)
    pub total_mb: f64,
    /// number of live (booting/healthy/draining) instances
    pub instances: usize,
}

/// One per-node RAM ledger sample (cluster mode; single-node platforms
/// record one series for node-0 that mirrors the platform series).
#[derive(Debug, Clone, Copy)]
pub struct NodeRamSample {
    /// virtual timestamp (ms)
    pub t_ms: f64,
    /// node sampled
    pub node: NodeId,
    /// RAM across the node's live instances (MiB)
    pub ram_mb: f64,
    /// the node's capacity (MiB; 0 = uncapped) — recorded so the CSV is
    /// self-describing for pressure plots
    pub capacity_mb: f64,
    /// the node's live instance count
    pub instances: usize,
}

/// One completed live migration: an instance moved between nodes with an
/// atomic route cutover (FIG8).
#[derive(Debug, Clone)]
pub struct MigrationEvent {
    /// virtual time the replacement took over the routes (ms)
    pub t_ms: f64,
    /// functions the migrated instance actively hosts (sorted)
    pub functions: Vec<String>,
    /// source node
    pub from: NodeId,
    /// target node
    pub to: NodeId,
    /// wall (virtual) duration of the migration pipeline (ms)
    pub duration_ms: f64,
    /// why the platform moved it ("node_pressure", "fusion_colocation")
    pub reason: &'static str,
}

/// One completed merge (a vertical line in the paper's Fig. 5).
#[derive(Debug, Clone)]
pub struct MergeEvent {
    /// virtual time the fused instance went healthy + routed (ms)
    pub t_ms: f64,
    /// functions hosted by the new fused instance
    pub functions: Vec<String>,
    /// wall (virtual) duration of the merge pipeline (ms)
    pub duration_ms: f64,
}

/// One completed defusion: a fused group broken back into per-function
/// instances by the feedback controller (FIG7).
#[derive(Debug, Clone)]
pub struct SplitEvent {
    /// virtual time the per-function routes were cut back over (ms)
    pub t_ms: f64,
    /// functions the group hosted (sorted)
    pub functions: Vec<String>,
    /// wall (virtual) duration of the split pipeline (ms)
    pub duration_ms: f64,
    /// which policy violation triggered the split
    pub reason: SplitReason,
}

/// One autoscaler replica-count transition on a route (FIG10): a
/// scale-up (cold boot or warm-pool claim), a scale-down, or a
/// scale-to-zero.
#[derive(Debug, Clone)]
pub struct ScaleEvent {
    /// virtual time the transition was applied (ms)
    pub t_ms: f64,
    /// route label (the first hosted function of the replica set)
    pub function: String,
    /// routable replica count before the transition
    pub from: u32,
    /// routable replica count after the transition
    pub to: u32,
    /// what drove it ("burst", "scale-down", "scale-to-zero",
    /// "scale-from-zero")
    pub reason: &'static str,
    /// scale-ups only: satisfied from the warm pool (attach delay) rather
    /// than a cold boot
    pub warm: bool,
}

/// One RAM attribution sample for a live fused group (the controller's
/// per-group view, recorded every feedback tick).
#[derive(Debug, Clone)]
pub struct GroupRamSample {
    /// virtual timestamp of the controller tick (ms)
    pub t_ms: f64,
    /// `+`-joined sorted function names identifying the group
    pub group: String,
    /// instantaneous RAM of the fused instance (MiB)
    pub ram_mb: f64,
}

/// One per-function handler latency observation, emitted by the Function
/// Handler on every invocation (remote or inlined).  This is the signal
/// that gives *interior* functions of a fused group their own latency
/// series — the entry-route e2e p95 alone cannot attribute blame.
#[derive(Debug, Clone)]
pub struct FnSample {
    /// virtual time the handler finished the function body (ms since epoch)
    pub t_ms: f64,
    /// function the sample belongs to
    pub function: String,
    /// handler self time: dispatch/inline charge + compute + busy time,
    /// excluding time blocked on outbound calls (ms)
    pub handler_ms: f64,
}

/// One per-function RAM attribution inside a fused instance (code footprint
/// plus an equal share of the base runtime + in-flight working sets),
/// recorded by the controller every feedback tick.
#[derive(Debug, Clone)]
pub struct FnRamSample {
    /// virtual timestamp of the controller tick (ms)
    pub t_ms: f64,
    /// `+`-joined sorted names of the hosting group
    pub group: String,
    /// member function attributed
    pub function: String,
    /// attributed RAM (MiB); group members sum to the instance's RAM
    pub ram_mb: f64,
}

/// One merge-admission evaluation by the cost-aware planner (recorded each
/// time a candidate pair is re-scored against fresh window signals).
#[derive(Debug, Clone)]
pub struct AdmissionSample {
    /// virtual timestamp of the evaluation (ms)
    pub t_ms: f64,
    /// candidate caller
    pub caller: String,
    /// candidate callee
    pub callee: String,
    /// predicted net benefit (see `fusion::cost::CostModel::predict_merge`)
    pub score: f64,
    /// verdict: `score >= merge_threshold` and the churn gate passed
    pub admitted: bool,
}

/// One auto-tune regret: a cost-admitted fuse was evicted/split within one
/// cooldown of its cutover; the sample records the weights *after* the
/// hill-climb step so the series doubles as the weight trajectory.
#[derive(Debug, Clone)]
pub struct RegretSample {
    /// virtual timestamp of the regret (ms)
    pub t_ms: f64,
    /// caller of the regretted fuse
    pub caller: String,
    /// callee of the regretted fuse
    pub callee: String,
    /// weights after the hill-climb step
    pub w_latency: f64,
    pub w_ram: f64,
    pub w_gbs: f64,
}

/// Attribute a fused instance's RAM to its members: each function keeps its
/// code footprint and receives a share of everything the code does not
/// explain (base runtime + in-flight working sets); shares sum to
/// `total_mb` whenever it covers the members' code footprints (always true
/// for a live instance).  `members` is `(function, code_mb)`.
///
/// `in_flight` is the per-member in-flight request count (index-aligned
/// with `members`; the platform samples `Instance::fn_inflight` at each
/// controller tick).  When any member holds in-flight requests, the
/// overhead is split **proportionally to ownership** — the member serving
/// 9 of 10 in-flight requests owns 90% of the working sets.  An idle
/// window (all zeros) or a mismatched slice falls back to the equal share,
/// so the pre-weighting behavior is the degenerate case, not a separate
/// code path.
pub fn attribute_ram(
    total_mb: f64,
    members: &[(String, f64)],
    in_flight: &[u64],
) -> Vec<(String, f64)> {
    if members.is_empty() {
        return Vec::new();
    }
    let code_total: f64 = members.iter().map(|(_, mb)| mb).sum();
    let overhead = (total_mb - code_total).max(0.0);
    let total_in_flight: u64 =
        if in_flight.len() == members.len() { in_flight.iter().sum() } else { 0 };
    let equal = 1.0 / members.len() as f64;
    members
        .iter()
        .enumerate()
        .map(|(i, (name, code_mb))| {
            let weight = if total_in_flight > 0 {
                in_flight[i] as f64 / total_in_flight as f64
            } else {
                equal
            };
            (name.clone(), code_mb + overhead * weight)
        })
        .collect()
}

/// One completed partial split: a single function evicted from a fused
/// group onto its own redeployed instance while the remainder stays fused.
#[derive(Debug, Clone)]
pub struct EvictEvent {
    /// virtual time the evicted function's route was cut over (ms)
    pub t_ms: f64,
    /// group membership before the eviction (sorted)
    pub group: Vec<String>,
    /// the function that left the group
    pub function: String,
    /// wall (virtual) duration of the evict pipeline (ms)
    pub duration_ms: f64,
    /// which policy violation triggered the eviction
    pub reason: SplitReason,
}

/// One global re-planner lifecycle event (`--planner global`).  Each plan
/// id appears up to three times: `planned` when the search emits it,
/// `executed` / `aborted` when the Merger finishes or epoch-guards it,
/// and `realized` when the next snapshot prices the live partition the
/// plan produced — predicted-vs-realized deltas are auditable from the
/// CSV alone.
#[derive(Debug, Clone)]
pub struct PlanEvent {
    /// virtual time of the event (ms)
    pub t_ms: f64,
    /// plan id (monotonic per platform run)
    pub plan_id: u64,
    /// `planned` | `executed` | `aborted` | `realized`
    pub kind: String,
    /// number of actions in the plan-diff
    pub actions: u32,
    /// partition objective of the snapshot the plan was computed against
    pub predicted_before: f64,
    /// predicted partition objective of the plan's target
    pub predicted_after: f64,
    /// measured objective of the live partition (NaN except `realized`)
    pub realized: f64,
    /// free-form context (action summary, abort cause, ...)
    pub detail: String,
}

// ---------------------------------------------------------------------------
// windowed ring shards
// ---------------------------------------------------------------------------

/// One `bucket_ms` time slice of a shard: raw samples plus incrementally
/// maintained aggregates (running sum, [`Summary`], [`LogHistogram`]).
struct Bucket {
    /// absolute bucket number (`floor(t / bucket_ms)`); `u64::MAX` = vacant
    index: u64,
    /// raw `(t_ms, value)` samples in record order
    samples: Vec<(f64, f64)>,
    sum: f64,
    summary: Summary,
    hist: LogHistogram,
}

impl Bucket {
    fn vacant() -> Bucket {
        Bucket {
            index: u64::MAX,
            samples: Vec::new(),
            sum: 0.0,
            summary: Summary::new(),
            hist: LogHistogram::new(),
        }
    }

    /// Reset in place for a new time slice, keeping allocations.
    fn reset(&mut self, index: u64) {
        self.index = index;
        self.samples.clear();
        self.sum = 0.0;
        self.summary = Summary::new();
        self.hist.clear();
    }

    fn approx_bytes(&self) -> usize {
        self.samples.capacity() * std::mem::size_of::<(f64, f64)>()
            + self.hist.approx_bytes()
            + std::mem::size_of::<Bucket>()
    }
}

/// Time-bucketed ring over one value series.  Memory is bounded by the
/// ring; trailing-window queries touch only the overlapping buckets.
struct WindowShard {
    bucket_ms: f64,
    /// highest bucket index ever recorded (query upper clamp)
    max_index: u64,
    any: bool,
    buckets: Vec<Bucket>,
}

impl WindowShard {
    fn new(cfg: &RecordingConfig) -> WindowShard {
        let n = cfg.buckets.max(2);
        WindowShard {
            bucket_ms: if cfg.bucket_ms > 0.0 { cfg.bucket_ms } else { 1_000.0 },
            max_index: 0,
            any: false,
            buckets: (0..n).map(|_| Bucket::vacant()).collect(),
        }
    }

    fn record(&mut self, t_ms: f64, value: f64) {
        let abs = (t_ms.max(0.0) / self.bucket_ms) as u64;
        let n = self.buckets.len() as u64;
        let slot = (abs % n) as usize;
        let b = &mut self.buckets[slot];
        if b.index != abs {
            if b.index != u64::MAX && b.index > abs {
                // a straggler older than the slot's current slice: beyond
                // retention, drop rather than corrupt the newer bucket
                return;
            }
            b.reset(abs);
        }
        b.samples.push((t_ms, value));
        b.sum += value;
        b.summary.add(value);
        b.hist.record(value);
        if !self.any || abs > self.max_index {
            self.max_index = abs;
        }
        self.any = true;
    }

    /// Absolute bucket range `[lo, hi)` overlapping `[from_ms, to_ms)`,
    /// clamped to what the ring can hold (`hi - lo <= buckets`).
    fn bucket_span(&self, from_ms: f64, to_ms: f64) -> Option<(u64, u64)> {
        if !self.any || to_ms <= from_ms {
            return None;
        }
        let lo = (from_ms.max(0.0) / self.bucket_ms) as u64;
        let hi = ((to_ms.max(0.0) / self.bucket_ms).ceil() as u64)
            .min(self.max_index.saturating_add(1));
        if hi <= lo {
            return None;
        }
        Some((lo.max(hi.saturating_sub(self.buckets.len() as u64)), hi))
    }

    /// Whether the ring still holds every bucket overlapping a window
    /// starting at `from_ms` — i.e. the window is inside the retention
    /// span.  Full-retention queries fall back to the raw series when this
    /// is false, so the seed's any-window exactness contract survives.
    fn covers(&self, from_ms: f64) -> bool {
        if !self.any {
            return true;
        }
        let lo = (from_ms.max(0.0) / self.bucket_ms) as u64;
        lo + self.buckets.len() as u64 > self.max_index
    }

    /// Visit every sample value with `t` in `[from_ms, to_ms)`, ascending
    /// bucket order.  Allocation-free; O(overlapping buckets + samples).
    fn for_each_in(&self, from_ms: f64, to_ms: f64, f: &mut impl FnMut(f64)) {
        let Some((lo, hi)) = self.bucket_span(from_ms, to_ms) else {
            return;
        };
        let n = self.buckets.len() as u64;
        for abs in lo..hi {
            let b = &self.buckets[(abs % n) as usize];
            if b.index != abs {
                continue;
            }
            let start = b.index as f64 * self.bucket_ms;
            let end = start + self.bucket_ms;
            if start >= from_ms && end <= to_ms {
                for &(_, v) in &b.samples {
                    f(v);
                }
            } else {
                for &(t, v) in &b.samples {
                    if t >= from_ms && t < to_ms {
                        f(v);
                    }
                }
            }
        }
    }

    /// Sum of values in `[from_ms, to_ms)`: fully covered buckets
    /// contribute their running `sum` (the O(#buckets) merge), edge
    /// buckets are filtered sample-by-sample.
    fn sum_in(&self, from_ms: f64, to_ms: f64) -> f64 {
        let Some((lo, hi)) = self.bucket_span(from_ms, to_ms) else {
            return 0.0;
        };
        let n = self.buckets.len() as u64;
        let mut total = 0.0;
        for abs in lo..hi {
            let b = &self.buckets[(abs % n) as usize];
            if b.index != abs {
                continue;
            }
            let start = b.index as f64 * self.bucket_ms;
            let end = start + self.bucket_ms;
            if start >= from_ms && end <= to_ms {
                total += b.sum;
            } else {
                for &(t, v) in &b.samples {
                    if t >= from_ms && t < to_ms {
                        total += v;
                    }
                }
            }
        }
        total
    }

    /// Mean over `[from_ms, to_ms)` via the per-bucket [`Summary`]s
    /// (O(#buckets); edge buckets included whole).
    fn mean_approx(&self, from_ms: f64, to_ms: f64) -> f64 {
        let Some((lo, hi)) = self.bucket_span(from_ms, to_ms) else {
            return f64::NAN;
        };
        let n = self.buckets.len() as u64;
        let mut count = 0u64;
        let mut weighted = 0.0;
        for abs in lo..hi {
            let b = &self.buckets[(abs % n) as usize];
            if b.index == abs && b.summary.count() > 0 {
                count += b.summary.count();
                weighted += b.summary.mean() * b.summary.count() as f64;
            }
        }
        if count == 0 { f64::NAN } else { weighted / count as f64 }
    }

    /// Approximate quantile over `[from_ms, to_ms)` via the O(#buckets)
    /// [`LogHistogram`] merge (edge buckets included whole — the cheap,
    /// non-verdict telemetry read).
    fn quantile_approx(&self, from_ms: f64, to_ms: f64, q: f64) -> f64 {
        let Some((lo, hi)) = self.bucket_span(from_ms, to_ms) else {
            return f64::NAN;
        };
        let n = self.buckets.len() as u64;
        let mut merged = LogHistogram::new();
        for abs in lo..hi {
            let b = &self.buckets[(abs % n) as usize];
            if b.index == abs {
                merged.merge_from(&b.hist);
            }
        }
        merged.q(q)
    }

    fn approx_bytes(&self) -> usize {
        self.buckets.iter().map(Bucket::approx_bytes).sum::<usize>()
            + std::mem::size_of::<WindowShard>()
    }
}

// ---------------------------------------------------------------------------
// recorder
// ---------------------------------------------------------------------------

/// Incremental time-weighted RAM mean (same accumulation order as the
/// seed's `windows(2)` loop, so the result is bit-identical).
#[derive(Default)]
struct RamAccum {
    n: u64,
    first_mb: f64,
    last_t: f64,
    last_mb: f64,
    weighted: f64,
    span: f64,
}

impl RamAccum {
    fn push(&mut self, t_ms: f64, mb: f64) {
        if self.n == 0 {
            self.first_mb = mb;
        } else {
            let dt = t_ms - self.last_t;
            self.weighted += self.last_mb * dt;
            self.span += dt;
        }
        self.last_t = t_ms;
        self.last_mb = mb;
        self.n += 1;
    }

    fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else if self.n < 2 || self.span <= 0.0 {
            self.first_mb
        } else {
            self.weighted / self.span
        }
    }
}

/// Shared, single-threaded metrics sink (cheap `Rc` handle).
#[derive(Clone)]
pub struct Recorder {
    inner: Rc<RecorderInner>,
}

impl Default for Recorder {
    fn default() -> Self {
        Self::with_config(RecordingConfig::default())
    }
}

struct RecorderInner {
    config: RecordingConfig,
    // -- full-retention raw series (RecordingLevel::Full only) -------------
    latencies: RefCell<Vec<LatencySample>>,
    ram: RefCell<Vec<RamSample>>,
    node_ram: RefCell<Vec<NodeRamSample>>,
    group_ram: RefCell<Vec<GroupRamSample>>,
    fn_latencies: RefCell<Vec<FnSample>>,
    fn_ram: RefCell<Vec<FnRamSample>>,
    // -- event series (every level: low-rate, verdict parity needs them) ---
    migrations: RefCell<Vec<MigrationEvent>>,
    merges: RefCell<Vec<MergeEvent>>,
    splits: RefCell<Vec<SplitEvent>>,
    evicts: RefCell<Vec<EvictEvent>>,
    scales: RefCell<Vec<ScaleEvent>>,
    admissions: RefCell<Vec<AdmissionSample>>,
    regrets: RefCell<Vec<RegretSample>>,
    plans: RefCell<Vec<PlanEvent>>,
    // -- windowed shards (every level: the controller's signal source) -----
    e2e: RefCell<WindowShard>,
    fn_shards: RefCell<HashMap<Sym, WindowShard>>,
    /// reusable sort buffer for window quantiles (zero steady-state alloc)
    scratch: RefCell<Vec<f64>>,
    ram_accum: RefCell<RamAccum>,
    latency_count: Cell<u64>,
    counters: RefCell<BTreeMap<&'static str, u64>>,
    /// absolute virtual-time (ms) all recorded timestamps are relative to
    epoch_ms: Cell<f64>,
}

impl Recorder {
    /// Full-retention recorder with the default shard shape (seed-compatible).
    pub fn new() -> Self {
        Self::default()
    }

    /// Recorder with an explicit recording level + shard shape.
    pub fn with_config(config: RecordingConfig) -> Self {
        let e2e = WindowShard::new(&config);
        Recorder {
            inner: Rc::new(RecorderInner {
                config,
                latencies: RefCell::new(Vec::new()),
                ram: RefCell::new(Vec::new()),
                node_ram: RefCell::new(Vec::new()),
                group_ram: RefCell::new(Vec::new()),
                fn_latencies: RefCell::new(Vec::new()),
                fn_ram: RefCell::new(Vec::new()),
                migrations: RefCell::new(Vec::new()),
                merges: RefCell::new(Vec::new()),
                splits: RefCell::new(Vec::new()),
                evicts: RefCell::new(Vec::new()),
                scales: RefCell::new(Vec::new()),
                admissions: RefCell::new(Vec::new()),
                regrets: RefCell::new(Vec::new()),
                plans: RefCell::new(Vec::new()),
                e2e: RefCell::new(e2e),
                fn_shards: RefCell::new(HashMap::new()),
                scratch: RefCell::new(Vec::new()),
                ram_accum: RefCell::new(RamAccum::default()),
                latency_count: Cell::new(0),
                counters: RefCell::new(BTreeMap::new()),
                epoch_ms: Cell::new(0.0),
            }),
        }
    }

    /// The recorder's retention tier.
    pub fn level(&self) -> RecordingLevel {
        self.inner.config.level
    }

    fn full(&self) -> bool {
        self.inner.config.level == RecordingLevel::Full
    }

    /// Anchor the time base at the current executor instant (set once, when
    /// the platform finishes deploying, so latency / RAM / merge series all
    /// share one clock).
    pub fn set_epoch_now(&self) {
        self.inner.epoch_ms.set(crate::exec::now().as_millis_f64());
    }

    /// Milliseconds since the epoch (requires a running executor).
    pub fn rel_now_ms(&self) -> f64 {
        crate::exec::now().as_millis_f64() - self.inner.epoch_ms.get()
    }

    /// Record one end-to-end request latency.
    pub fn record_latency(&self, t_ms: f64, latency_ms: f64) {
        if self.full() {
            self.inner.latencies.borrow_mut().push(LatencySample { t_ms, latency_ms });
        }
        self.inner.e2e.borrow_mut().record(t_ms, latency_ms);
        self.inner.latency_count.set(self.inner.latency_count.get() + 1);
    }

    /// Record a platform-wide RAM sample.
    pub fn record_ram(&self, t_ms: f64, total_mb: f64, instances: usize) {
        if self.full() {
            self.inner.ram.borrow_mut().push(RamSample { t_ms, total_mb, instances });
        }
        self.inner.ram_accum.borrow_mut().push(t_ms, total_mb);
    }

    /// Record one node's RAM sample (cluster mode).
    pub fn record_node_ram(&self, sample: NodeRamSample) {
        if self.full() {
            self.inner.node_ram.borrow_mut().push(sample);
        }
    }

    /// Record a completed live migration.
    pub fn record_migration(&self, event: MigrationEvent) {
        self.inner.migrations.borrow_mut().push(event);
    }

    /// Record one fused group's attributed RAM at a tick.
    pub fn record_group_ram(&self, t_ms: f64, group: GroupKey, ram_mb: f64) {
        if self.full() {
            self.inner.group_ram.borrow_mut().push(GroupRamSample {
                t_ms,
                group: group.as_str().to_string(),
                ram_mb,
            });
        }
    }

    /// Record one function's handler self-time sample.
    pub fn record_fn_latency(&self, t_ms: f64, function: Sym, handler_ms: f64) {
        if self.full() {
            self.inner.fn_latencies.borrow_mut().push(FnSample {
                t_ms,
                function: function.as_str().to_string(),
                handler_ms,
            });
        }
        let config = &self.inner.config;
        self.inner
            .fn_shards
            .borrow_mut()
            .entry(function)
            .or_insert_with(|| WindowShard::new(config))
            .record(t_ms, handler_ms);
    }

    /// Record one function's attributed RAM inside its group.
    pub fn record_fn_ram(&self, t_ms: f64, group: GroupKey, function: Sym, ram_mb: f64) {
        if self.full() {
            self.inner.fn_ram.borrow_mut().push(FnRamSample {
                t_ms,
                group: group.as_str().to_string(),
                function: function.as_str().to_string(),
                ram_mb,
            });
        }
    }

    /// Record a completed fuse cutover.
    pub fn record_merge(&self, event: MergeEvent) {
        self.inner.merges.borrow_mut().push(event);
    }

    /// Record a completed split.
    pub fn record_split(&self, event: SplitEvent) {
        self.inner.splits.borrow_mut().push(event);
    }

    /// Record a completed eviction (shrink-in-place).
    pub fn record_evict(&self, event: EvictEvent) {
        self.inner.evicts.borrow_mut().push(event);
    }

    /// Record a replica-count transition (event series: retained at every
    /// recording level, like the other low-rate pipeline events).
    pub fn record_scale(&self, event: ScaleEvent) {
        self.inner.scales.borrow_mut().push(event);
    }

    /// Record a merge-admission evaluation.
    pub fn record_admission(&self, sample: AdmissionSample) {
        self.inner.admissions.borrow_mut().push(sample);
    }

    /// Record an auto-tune regret (weights after the step).
    pub fn record_regret(&self, sample: RegretSample) {
        self.inner.regrets.borrow_mut().push(sample);
    }

    /// Record a global re-planner lifecycle event.
    pub fn record_plan(&self, event: PlanEvent) {
        self.inner.plans.borrow_mut().push(event);
    }

    /// Increment a named counter.
    pub fn bump(&self, name: &'static str) {
        *self.inner.counters.borrow_mut().entry(name).or_insert(0) += 1;
    }

    /// Read a named counter (0 if never bumped).
    pub fn counter(&self, name: &'static str) -> u64 {
        self.inner.counters.borrow().get(name).copied().unwrap_or(0)
    }

    // -- accessors ----------------------------------------------------------

    /// Snapshot of the end-to-end latency series.
    pub fn latencies(&self) -> Vec<LatencySample> {
        self.inner.latencies.borrow().clone()
    }

    /// Snapshot of the platform RAM series.
    pub fn ram_series(&self) -> Vec<RamSample> {
        self.inner.ram.borrow().clone()
    }

    /// Snapshot of the per-node RAM series.
    pub fn node_ram_series(&self) -> Vec<NodeRamSample> {
        self.inner.node_ram.borrow().clone()
    }

    /// Snapshot of the migration events.
    pub fn migrations(&self) -> Vec<MigrationEvent> {
        self.inner.migrations.borrow().clone()
    }

    /// Snapshot of the merge events.
    pub fn merges(&self) -> Vec<MergeEvent> {
        self.inner.merges.borrow().clone()
    }

    /// Snapshot of the split events.
    pub fn splits(&self) -> Vec<SplitEvent> {
        self.inner.splits.borrow().clone()
    }

    /// Snapshot of the evict events.
    pub fn evicts(&self) -> Vec<EvictEvent> {
        self.inner.evicts.borrow().clone()
    }

    /// Snapshot of the replica scale events.
    pub fn scales(&self) -> Vec<ScaleEvent> {
        self.inner.scales.borrow().clone()
    }

    /// Snapshot of the per-group RAM attribution series.
    pub fn group_ram_series(&self) -> Vec<GroupRamSample> {
        self.inner.group_ram.borrow().clone()
    }

    /// Snapshot of the per-function self-time series.
    pub fn fn_latency_series(&self) -> Vec<FnSample> {
        self.inner.fn_latencies.borrow().clone()
    }

    /// Snapshot of the per-function RAM attribution series.
    pub fn fn_ram_series(&self) -> Vec<FnRamSample> {
        self.inner.fn_ram.borrow().clone()
    }

    /// Snapshot of the admission evaluations.
    pub fn admissions(&self) -> Vec<AdmissionSample> {
        self.inner.admissions.borrow().clone()
    }

    /// Snapshot of the auto-tune regrets.
    pub fn regrets(&self) -> Vec<RegretSample> {
        self.inner.regrets.borrow().clone()
    }

    /// Snapshot of the global re-planner events.
    pub fn plans(&self) -> Vec<PlanEvent> {
        self.inner.plans.borrow().clone()
    }

    /// Exact quantile of a shard window via the shared scratch buffer:
    /// identical retain/sort/interpolate steps as [`Quantiles`], zero
    /// steady-state allocation.  NaN when fewer than `min_n` samples.
    fn shard_quantile(
        &self,
        shard: &WindowShard,
        from_ms: f64,
        to_ms: f64,
        q: f64,
        min_n: usize,
    ) -> f64 {
        let mut scratch = self.inner.scratch.borrow_mut();
        scratch.clear();
        shard.for_each_in(from_ms, to_ms, &mut |v| {
            if v.is_finite() {
                scratch.push(v);
            }
        });
        if scratch.len() < min_n {
            return f64::NAN;
        }
        scratch.sort_unstable_by(f64::total_cmp);
        quantile_sorted(&scratch, q)
    }

    /// p95 of one function's handler latencies over `[from_ms, to_ms)`, or
    /// NaN when the window holds fewer than `min_n` samples — the per-route
    /// signal the cost model attributes blame with.
    ///
    /// Reads only the function's own ring shard (no scan over the whole
    /// run's interleaved history, no allocation at steady state).  Under
    /// full retention, a window reaching back past the ring falls back to
    /// the exact raw series (seed semantics for any window); under
    /// windowed retention such a query is clipped to the retained span.
    pub fn fn_p95_window(&self, function: &str, from_ms: f64, to_ms: f64, min_n: usize) -> f64 {
        // lookup, not intern: query misses must not grow the leaked table
        match Sym::lookup(function) {
            Some(sym) => self.fn_p95_window_sym(sym, from_ms, to_ms, min_n),
            None => f64::NAN,
        }
    }

    /// [`Self::fn_p95_window`] for callers already holding a [`Sym`] (the
    /// controller tick: no interner round-trip per query).
    pub fn fn_p95_window_sym(&self, function: Sym, from_ms: f64, to_ms: f64, min_n: usize) -> f64 {
        {
            let shards = self.inner.fn_shards.borrow();
            match shards.get(&function) {
                Some(shard) if !self.full() || shard.covers(from_ms) => {
                    return self.shard_quantile(shard, from_ms, to_ms, 0.95, min_n.max(1));
                }
                Some(_) => {}
                None => return f64::NAN,
            }
        }
        // full retention, window older than the ring: exact legacy path
        let name = function.as_str();
        let borrowed = self.inner.fn_latencies.borrow();
        let series: &[FnSample] = &borrowed;
        let start = series.partition_point(|s| s.t_ms < from_ms);
        let mut scratch = self.inner.scratch.borrow_mut();
        scratch.clear();
        for s in series[start..].iter().take_while(|s| s.t_ms < to_ms) {
            if s.function == name && s.handler_ms.is_finite() {
                scratch.push(s.handler_ms);
            }
        }
        if scratch.len() < min_n.max(1) {
            return f64::NAN;
        }
        scratch.sort_unstable_by(f64::total_cmp);
        quantile_sorted(&scratch, 0.95)
    }

    /// Summed handler self-time (ms) of one function over `[from_ms,
    /// to_ms)` — with the billing ledger's windowed duration this yields
    /// the caller's blocked (double-billed) time, the merge planner's
    /// hop-savings signal.  Fully covered buckets contribute their running
    /// sums (O(#buckets)); only edge buckets are walked sample-by-sample.
    /// Same full-retention fallback as [`Self::fn_p95_window`].
    pub fn fn_self_ms_window(&self, function: &str, from_ms: f64, to_ms: f64) -> f64 {
        match Sym::lookup(function) {
            Some(sym) => self.fn_self_ms_window_sym(sym, from_ms, to_ms),
            None => 0.0,
        }
    }

    /// [`Self::fn_self_ms_window`] for callers already holding a [`Sym`].
    pub fn fn_self_ms_window_sym(&self, function: Sym, from_ms: f64, to_ms: f64) -> f64 {
        {
            let shards = self.inner.fn_shards.borrow();
            match shards.get(&function) {
                Some(shard) if !self.full() || shard.covers(from_ms) => {
                    return shard.sum_in(from_ms, to_ms);
                }
                Some(_) => {}
                None => return 0.0,
            }
        }
        let name = function.as_str();
        let borrowed = self.inner.fn_latencies.borrow();
        let series: &[FnSample] = &borrowed;
        let start = series.partition_point(|s| s.t_ms < from_ms);
        series[start..]
            .iter()
            .take_while(|s| s.t_ms < to_ms)
            .filter(|s| s.function == name)
            .map(|s| s.handler_ms)
            .sum()
    }

    /// Approximate per-function p95 via the O(#buckets) histogram merge —
    /// the cheap telemetry read for reports; verdict paths use the exact
    /// [`Self::fn_p95_window`].
    pub fn fn_p95_window_approx(&self, function: &str, from_ms: f64, to_ms: f64) -> f64 {
        let Some(sym) = Sym::lookup(function) else {
            return f64::NAN;
        };
        let shards = self.inner.fn_shards.borrow();
        match shards.get(&sym) {
            Some(shard) => shard.quantile_approx(from_ms, to_ms, 0.95),
            None => f64::NAN,
        }
    }

    /// Mean handler self-time over a window, merged from the per-bucket
    /// incremental summaries (whole buckets; O(#buckets)).
    pub fn fn_mean_window_approx(&self, function: &str, from_ms: f64, to_ms: f64) -> f64 {
        let Some(sym) = Sym::lookup(function) else {
            return f64::NAN;
        };
        let shards = self.inner.fn_shards.borrow();
        match shards.get(&sym) {
            Some(shard) => shard.mean_approx(from_ms, to_ms),
            None => f64::NAN,
        }
    }

    /// RAM attribution samples of one fused group (`+`-joined sorted names).
    pub fn group_ram_for(&self, group: &str) -> Vec<GroupRamSample> {
        self.inner
            .group_ram
            .borrow()
            .iter()
            .filter(|s| s.group == group)
            .cloned()
            .collect()
    }

    /// End-to-end latency samples recorded so far.
    pub fn request_count(&self) -> usize {
        self.inner.latency_count.get() as usize
    }

    /// Quantiles over all request latencies (full retention only; empty
    /// under [`RecordingLevel::Windowed`]).
    pub fn latency_quantiles(&self) -> Quantiles {
        Quantiles::from_samples(
            self.inner.latencies.borrow().iter().map(|s| s.latency_ms).collect(),
        )
    }

    /// Quantiles over requests arriving in `[from_ms, to_ms)` — used to
    /// separate pre-merge and post-merge phases (paper Fig. 5 analysis).
    /// Full retention only; windowed runs use [`Self::p95_window`] (exact
    /// inside the retention span) or [`Self::p95_window_approx`].
    pub fn latency_quantiles_window(&self, from_ms: f64, to_ms: f64) -> Quantiles {
        Quantiles::from_samples(
            self.inner
                .latencies
                .borrow()
                .iter()
                .filter(|s| s.t_ms >= from_ms && s.t_ms < to_ms)
                .map(|s| s.latency_ms)
                .collect(),
        )
    }

    /// p95 over requests arriving in `[from_ms, to_ms)`, or NaN when the
    /// window holds fewer than `min_n` samples.  Full retention answers
    /// from the raw series (any window); windowed retention answers from
    /// the e2e ring shard — bit-identical for trailing windows inside the
    /// retention span (the only windows the controller and merger ask for).
    pub fn p95_window(&self, from_ms: f64, to_ms: f64, min_n: usize) -> f64 {
        if self.full() {
            let q = self.latency_quantiles_window(from_ms, to_ms);
            return if q.len() >= min_n { q.p95() } else { f64::NAN };
        }
        self.shard_quantile(&self.inner.e2e.borrow(), from_ms, to_ms, 0.95, min_n.max(1))
    }

    /// Approximate e2e p95 over a window via the histogram merge (works at
    /// every recording level; O(#buckets)).
    pub fn p95_window_approx(&self, from_ms: f64, to_ms: f64) -> f64 {
        self.inner.e2e.borrow().quantile_approx(from_ms, to_ms, 0.95)
    }

    /// Time-weighted mean of the RAM series (MiB); maintained incrementally
    /// so it is exact at every recording level.
    pub fn ram_mean_mb(&self) -> f64 {
        self.inner.ram_accum.borrow().mean()
    }

    /// Steady-state RAM: time-weighted mean over the tail of the run
    /// (after `from_ms`).  Needs the full series (NaN under
    /// [`RecordingLevel::Windowed`]).
    pub fn ram_mean_mb_after(&self, from_ms: f64) -> f64 {
        let ram: Vec<RamSample> = self
            .inner
            .ram
            .borrow()
            .iter()
            .copied()
            .filter(|s| s.t_ms >= from_ms)
            .collect();
        if ram.len() < 2 {
            return ram.first().map(|s| s.total_mb).unwrap_or(f64::NAN);
        }
        let mut weighted = 0.0;
        let mut span = 0.0;
        for pair in ram.windows(2) {
            let dt = pair[1].t_ms - pair[0].t_ms;
            weighted += pair[0].total_mb * dt;
            span += dt;
        }
        weighted / span
    }

    /// Approximate recorder heap footprint (bytes): every retained series
    /// plus the ring shards.  The `figure9` scale run self-checks this
    /// stays bounded under [`RecordingLevel::Windowed`].
    pub fn approx_bytes(&self) -> usize {
        use std::mem::size_of;
        let i = &self.inner;
        let mut b = 0usize;
        b += i.latencies.borrow().capacity() * size_of::<LatencySample>();
        b += i.ram.borrow().capacity() * size_of::<RamSample>();
        b += i.node_ram.borrow().capacity() * size_of::<NodeRamSample>();
        b += i.group_ram.borrow().capacity() * size_of::<GroupRamSample>()
            + i.group_ram.borrow().iter().map(|s| s.group.capacity()).sum::<usize>();
        b += i.fn_latencies.borrow().capacity() * size_of::<FnSample>()
            + i.fn_latencies.borrow().iter().map(|s| s.function.capacity()).sum::<usize>();
        b += i.fn_ram.borrow().capacity() * size_of::<FnRamSample>()
            + i.fn_ram
                .borrow()
                .iter()
                .map(|s| s.group.capacity() + s.function.capacity())
                .sum::<usize>();
        b += i.migrations.borrow().capacity() * size_of::<MigrationEvent>();
        b += i.merges.borrow().capacity() * size_of::<MergeEvent>();
        b += i.splits.borrow().capacity() * size_of::<SplitEvent>();
        b += i.evicts.borrow().capacity() * size_of::<EvictEvent>();
        b += i.scales.borrow().capacity() * size_of::<ScaleEvent>()
            + i.scales.borrow().iter().map(|s| s.function.capacity()).sum::<usize>();
        b += i.admissions.borrow().capacity() * size_of::<AdmissionSample>();
        b += i.regrets.borrow().capacity() * size_of::<RegretSample>();
        b += i.plans.borrow().capacity() * size_of::<PlanEvent>()
            + i.plans
                .borrow()
                .iter()
                .map(|s| s.kind.capacity() + s.detail.capacity())
                .sum::<usize>();
        b += i.e2e.borrow().approx_bytes();
        b += i
            .fn_shards
            .borrow()
            .values()
            .map(WindowShard::approx_bytes)
            .sum::<usize>();
        b += i.scratch.borrow().capacity() * size_of::<f64>();
        b
    }

    /// CSV export of the latency time series (`t_ms,latency_ms`).
    pub fn latency_csv(&self) -> String {
        let mut out = String::from("t_ms,latency_ms\n");
        for s in self.inner.latencies.borrow().iter() {
            out.push_str(&format!("{:.3},{:.3}\n", s.t_ms, s.latency_ms));
        }
        out
    }

    /// CSV export of the RAM series (`t_ms,total_mb,instances`).
    pub fn ram_csv(&self) -> String {
        let mut out = String::from("t_ms,total_mb,instances\n");
        for s in self.inner.ram.borrow().iter() {
            out.push_str(&format!("{:.3},{:.3},{}\n", s.t_ms, s.total_mb, s.instances));
        }
        out
    }

    /// CSV export of the per-node RAM series
    /// (`t_ms,node,ram_mb,capacity_mb,instances`).
    pub fn node_ram_csv(&self) -> String {
        let mut out = String::from("t_ms,node,ram_mb,capacity_mb,instances\n");
        for s in self.inner.node_ram.borrow().iter() {
            out.push_str(&format!(
                "{:.3},{},{:.3},{:.3},{}\n",
                s.t_ms, s.node, s.ram_mb, s.capacity_mb, s.instances
            ));
        }
        out
    }

    /// CSV export of migration events
    /// (`t_ms,duration_ms,from,to,reason,functions`).
    pub fn migrations_csv(&self) -> String {
        let mut out = String::from("t_ms,duration_ms,from,to,reason,functions\n");
        for m in self.inner.migrations.borrow().iter() {
            out.push_str(&format!(
                "{:.3},{:.3},{},{},{},{}\n",
                m.t_ms,
                m.duration_ms,
                m.from,
                m.to,
                m.reason,
                m.functions.join("+")
            ));
        }
        out
    }

    /// CSV export of merge events (`t_ms,duration_ms,functions`).
    pub fn merges_csv(&self) -> String {
        let mut out = String::from("t_ms,duration_ms,functions\n");
        for m in self.inner.merges.borrow().iter() {
            out.push_str(&format!(
                "{:.3},{:.3},{}\n",
                m.t_ms,
                m.duration_ms,
                m.functions.join("+")
            ));
        }
        out
    }

    /// CSV export of split events (`t_ms,duration_ms,reason,functions`).
    pub fn splits_csv(&self) -> String {
        let mut out = String::from("t_ms,duration_ms,reason,functions\n");
        for s in self.inner.splits.borrow().iter() {
            out.push_str(&format!(
                "{:.3},{:.3},{},{}\n",
                s.t_ms,
                s.duration_ms,
                s.reason.name(),
                s.functions.join("+")
            ));
        }
        out
    }

    /// CSV export of autoscaler transitions
    /// (`t_ms,function,from,to,reason,warm`).
    pub fn scales_csv(&self) -> String {
        let mut out = String::from("t_ms,function,from,to,reason,warm\n");
        for s in self.inner.scales.borrow().iter() {
            out.push_str(&format!(
                "{:.3},{},{},{},{},{}\n",
                s.t_ms, s.function, s.from, s.to, s.reason, s.warm
            ));
        }
        out
    }

    /// CSV export of the per-group RAM attribution (`t_ms,group,ram_mb`).
    pub fn group_ram_csv(&self) -> String {
        let mut out = String::from("t_ms,group,ram_mb\n");
        for s in self.inner.group_ram.borrow().iter() {
            out.push_str(&format!("{:.3},{},{:.3}\n", s.t_ms, s.group, s.ram_mb));
        }
        out
    }

    /// CSV export of per-function handler latencies (`t_ms,function,handler_ms`).
    pub fn fn_latency_csv(&self) -> String {
        let mut out = String::from("t_ms,function,handler_ms\n");
        for s in self.inner.fn_latencies.borrow().iter() {
            out.push_str(&format!("{:.3},{},{:.3}\n", s.t_ms, s.function, s.handler_ms));
        }
        out
    }

    /// CSV export of per-function RAM attribution (`t_ms,group,function,ram_mb`).
    pub fn fn_ram_csv(&self) -> String {
        let mut out = String::from("t_ms,group,function,ram_mb\n");
        for s in self.inner.fn_ram.borrow().iter() {
            out.push_str(&format!(
                "{:.3},{},{},{:.3}\n",
                s.t_ms, s.group, s.function, s.ram_mb
            ));
        }
        out
    }

    /// CSV export of merge-admission evaluations
    /// (`t_ms,caller,callee,score,admitted`).
    pub fn admissions_csv(&self) -> String {
        let mut out = String::from("t_ms,caller,callee,score,admitted\n");
        for s in self.inner.admissions.borrow().iter() {
            out.push_str(&format!(
                "{:.3},{},{},{:.4},{}\n",
                s.t_ms, s.caller, s.callee, s.score, s.admitted
            ));
        }
        out
    }

    /// CSV export of auto-tune regrets + post-step weights
    /// (`t_ms,caller,callee,w_latency,w_ram,w_gbs`).
    pub fn regrets_csv(&self) -> String {
        let mut out = String::from("t_ms,caller,callee,w_latency,w_ram,w_gbs\n");
        for s in self.inner.regrets.borrow().iter() {
            out.push_str(&format!(
                "{:.3},{},{},{:.4},{:.4},{:.4}\n",
                s.t_ms, s.caller, s.callee, s.w_latency, s.w_ram, s.w_gbs
            ));
        }
        out
    }

    /// CSV export of the global re-planner lifecycle
    /// (`t_ms,plan_id,kind,actions,predicted_before,predicted_after,realized,detail`)
    /// — the greedy-vs-global A/B's audit trail: every plan's predicted
    /// objective delta next to what the following snapshot measured.
    pub fn plan_events_csv(&self) -> String {
        let mut out =
            String::from("t_ms,plan_id,kind,actions,predicted_before,predicted_after,realized,detail\n");
        for s in self.inner.plans.borrow().iter() {
            out.push_str(&format!(
                "{:.3},{},{},{},{:.4},{:.4},{:.4},{}\n",
                s.t_ms,
                s.plan_id,
                s.kind,
                s.actions,
                s.predicted_before,
                s.predicted_after,
                s.realized,
                s.detail
            ));
        }
        out
    }

    /// CSV export of every named event counter (`counter,value`), sorted by
    /// name (the backing map is a `BTreeMap`).  Makes hop and drop-cause
    /// counters — `request_failures` next to its `failed_*` split (ISSUE 9)
    /// — auditable from CSVs alone like every other event series.
    pub fn counters_csv(&self) -> String {
        let mut out = String::from("counter,value\n");
        for (name, value) in self.inner.counters.borrow().iter() {
            out.push_str(&format!("{name},{value}\n"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sym(name: &str) -> Sym {
        Sym::intern(name)
    }

    #[test]
    fn quantiles_and_windows() {
        let r = Recorder::new();
        for i in 0..100 {
            // first half slow (100ms), second half fast (50ms)
            let lat = if i < 50 { 100.0 } else { 50.0 };
            r.record_latency(i as f64 * 10.0, lat);
        }
        assert_eq!(r.request_count(), 100);
        let pre = r.latency_quantiles_window(0.0, 500.0);
        let post = r.latency_quantiles_window(500.0, 1e9);
        assert_eq!(pre.median(), 100.0);
        assert_eq!(post.median(), 50.0);
    }

    #[test]
    fn ram_time_weighted_mean() {
        let r = Recorder::new();
        // 100 MB for 10ms, then 50 MB for 30ms -> (1000 + 1500)/40 = 62.5
        r.record_ram(0.0, 100.0, 2);
        r.record_ram(10.0, 50.0, 1);
        r.record_ram(40.0, 50.0, 1);
        assert!((r.ram_mean_mb() - 62.5).abs() < 1e-9);
        assert!((r.ram_mean_mb_after(10.0) - 50.0).abs() < 1e-9);
    }

    #[test]
    fn counters() {
        let r = Recorder::new();
        r.bump("merge_requests");
        r.bump("merge_requests");
        assert_eq!(r.counter("merge_requests"), 2);
        assert_eq!(r.counter("nope"), 0);
    }

    #[test]
    fn counters_csv_lists_every_counter_sorted() {
        let r = Recorder::new();
        r.bump("request_failures");
        r.bump("failed_cutover_race");
        r.bump("failed_cutover_race");
        let csv = r.counters_csv();
        assert!(csv.starts_with("counter,value\n"));
        // BTreeMap order: failed_* sorts before request_failures
        assert_eq!(csv, "counter,value\nfailed_cutover_race,2\nrequest_failures,1\n");
    }

    #[test]
    fn csv_headers() {
        let r = Recorder::new();
        r.record_latency(1.0, 2.0);
        r.record_ram(1.0, 3.0, 1);
        r.record_merge(MergeEvent {
            t_ms: 5.0,
            functions: vec!["a".into(), "b".into()],
            duration_ms: 7.0,
        });
        assert!(r.latency_csv().starts_with("t_ms,latency_ms\n1.000,2.000"));
        assert!(r.ram_csv().contains("1.000,3.000,1"));
        assert!(r.merges_csv().contains("a+b"));
    }

    #[test]
    fn split_events_and_group_ram_recorded() {
        let r = Recorder::new();
        r.record_split(SplitEvent {
            t_ms: 9.0,
            functions: vec!["a".into(), "b".into()],
            duration_ms: 2.0,
            reason: SplitReason::RamCap,
        });
        r.record_group_ram(4.0, GroupKey::from_name("a+b"), 120.5);
        r.record_group_ram(5.0, GroupKey::from_name("c+d"), 80.0);
        assert_eq!(r.splits().len(), 1);
        assert_eq!(r.splits()[0].reason, SplitReason::RamCap);
        assert!(r.splits_csv().contains("ram_cap"));
        assert!(r.splits_csv().contains("a+b"));
        assert_eq!(r.group_ram_series().len(), 2);
        assert_eq!(r.group_ram_for("a+b").len(), 1);
        assert!(r.group_ram_csv().contains("4.000,a+b,120.500"));
    }

    #[test]
    fn fn_attribution_series_and_windows() {
        let r = Recorder::new();
        for i in 0..10 {
            r.record_fn_latency(i as f64 * 100.0, sym("hot"), 200.0);
            r.record_fn_latency(i as f64 * 100.0, sym("cool"), 10.0);
        }
        r.record_fn_ram(50.0, GroupKey::from_name("cool+hot"), sym("hot"), 120.0);
        assert_eq!(r.fn_latency_series().len(), 20);
        assert_eq!(r.fn_ram_series().len(), 1);
        // per-function windows are independent
        assert_eq!(r.fn_p95_window("hot", 0.0, 1_000.0, 5), 200.0);
        assert_eq!(r.fn_p95_window("cool", 0.0, 1_000.0, 5), 10.0);
        // too few samples in a narrow window -> NaN
        assert!(r.fn_p95_window("hot", 0.0, 250.0, 5).is_nan());
        assert!(r.fn_p95_window("ghost", 0.0, 1_000.0, 1).is_nan());
        assert!(r.fn_latency_csv().contains("hot,200.000"));
        assert!(r.fn_ram_csv().contains("cool+hot,hot,120.000"));
    }

    #[test]
    fn evict_events_recorded() {
        let r = Recorder::new();
        r.record_evict(EvictEvent {
            t_ms: 12.0,
            group: vec!["a".into(), "b".into(), "c".into()],
            function: "b".into(),
            duration_ms: 3.0,
            reason: SplitReason::CostModel,
        });
        assert_eq!(r.evicts().len(), 1);
        assert_eq!(r.evicts()[0].function, "b");
        assert_eq!(r.evicts()[0].reason, SplitReason::CostModel);
        assert_eq!(r.evicts()[0].group.join("+"), "a+b+c");
    }

    #[test]
    fn node_ram_and_migration_series_recorded() {
        let r = Recorder::new();
        r.record_node_ram(NodeRamSample {
            t_ms: 3.0,
            node: NodeId(1),
            ram_mb: 140.5,
            capacity_mb: 300.0,
            instances: 2,
        });
        r.record_migration(MigrationEvent {
            t_ms: 8.0,
            functions: vec!["a".into(), "b".into()],
            from: NodeId(1),
            to: NodeId(2),
            duration_ms: 450.0,
            reason: "node_pressure",
        });
        assert_eq!(r.node_ram_series().len(), 1);
        assert_eq!(r.node_ram_series()[0].node, NodeId(1));
        assert_eq!(r.migrations().len(), 1);
        assert_eq!(r.migrations()[0].to, NodeId(2));
        assert!(r.node_ram_csv().contains("3.000,node-1,140.500,300.000,2"));
        assert!(r
            .migrations_csv()
            .contains("8.000,450.000,node-1,node-2,node_pressure,a+b"));
    }

    #[test]
    fn clone_shares_state() {
        let r = Recorder::new();
        let r2 = r.clone();
        r2.record_latency(0.0, 1.0);
        assert_eq!(r.request_count(), 1);
    }

    #[test]
    fn scale_events_recorded_and_exported() {
        let r = Recorder::new();
        r.record_scale(ScaleEvent {
            t_ms: 12.0,
            function: "f0".into(),
            from: 1,
            to: 2,
            reason: "burst",
            warm: true,
        });
        r.record_scale(ScaleEvent {
            t_ms: 90.0,
            function: "f0".into(),
            from: 2,
            to: 0,
            reason: "scale-to-zero",
            warm: false,
        });
        assert_eq!(r.scales().len(), 2);
        assert!(r.scales()[0].warm && r.scales()[1].to == 0);
        assert!(r.scales_csv().contains("12.000,f0,1,2,burst,true"));
        assert!(r.scales_csv().contains("90.000,f0,2,0,scale-to-zero,false"));
        // event series survive windowed recording like the other pipelines
        let w = Recorder::with_config(RecordingConfig {
            level: RecordingLevel::Windowed,
            ..RecordingConfig::default()
        });
        w.record_scale(ScaleEvent {
            t_ms: 1.0,
            function: "g".into(),
            from: 0,
            to: 1,
            reason: "scale-from-zero",
            warm: false,
        });
        assert_eq!(w.scales().len(), 1);
    }

    #[test]
    fn admission_and_regret_series_recorded() {
        let r = Recorder::new();
        r.record_admission(AdmissionSample {
            t_ms: 5.0,
            caller: "a".into(),
            callee: "b".into(),
            score: 0.42,
            admitted: true,
        });
        r.record_admission(AdmissionSample {
            t_ms: 7.0,
            caller: "a".into(),
            callee: "big".into(),
            score: -1.5,
            admitted: false,
        });
        r.record_regret(RegretSample {
            t_ms: 30.0,
            caller: "a".into(),
            callee: "b".into(),
            w_latency: 0.8,
            w_ram: 1.25,
            w_gbs: 0.8,
        });
        assert_eq!(r.admissions().len(), 2);
        assert!(r.admissions()[1].score < 0.0 && !r.admissions()[1].admitted);
        assert_eq!(r.regrets().len(), 1);
        assert!(r.admissions_csv().contains("5.000,a,b,0.4200,true"));
        assert!(r.admissions_csv().contains("a,big,-1.5000,false"));
        assert!(r.regrets_csv().contains("30.000,a,b,0.8000,1.2500,0.8000"));
    }

    #[test]
    fn fn_self_ms_window_sums_only_the_window() {
        let r = Recorder::new();
        for i in 0..10 {
            r.record_fn_latency(i as f64 * 100.0, sym("hot"), 20.0);
            r.record_fn_latency(i as f64 * 100.0, sym("cool"), 5.0);
        }
        assert_eq!(r.fn_self_ms_window("hot", 0.0, 1_000.0), 200.0);
        // [from, to) bounds, per-function filter, empty windows
        assert_eq!(r.fn_self_ms_window("hot", 0.0, 500.0), 100.0);
        assert_eq!(r.fn_self_ms_window("cool", 300.0, 600.0), 15.0);
        assert_eq!(r.fn_self_ms_window("ghost", 0.0, 1_000.0), 0.0);
    }

    // -- windowed recording level (ISSUE 5) -----------------------------------

    fn windowed() -> Recorder {
        Recorder::with_config(RecordingConfig {
            level: RecordingLevel::Windowed,
            ..RecordingConfig::default()
        })
    }

    #[test]
    fn windowed_drops_raw_series_but_keeps_events_and_counts() {
        let r = windowed();
        r.record_latency(1.0, 2.0);
        r.record_ram(0.0, 100.0, 1);
        r.record_ram(10.0, 100.0, 1);
        r.record_fn_latency(1.0, sym("wf"), 5.0);
        r.record_group_ram(1.0, GroupKey::from_name("wa+wb"), 50.0);
        r.record_merge(MergeEvent { t_ms: 5.0, functions: vec!["wa".into()], duration_ms: 1.0 });
        assert!(r.latencies().is_empty());
        assert!(r.ram_series().is_empty());
        assert!(r.fn_latency_series().is_empty());
        assert!(r.group_ram_series().is_empty());
        // ... but the bounded views keep working
        assert_eq!(r.request_count(), 1);
        assert_eq!(r.merges().len(), 1);
        assert!((r.ram_mean_mb() - 100.0).abs() < 1e-12);
        assert_eq!(r.fn_self_ms_window("wf", 0.0, 100.0), 5.0);
    }

    #[test]
    fn windowed_trailing_queries_match_full_bit_for_bit() {
        let full = Recorder::new();
        let win = windowed();
        let mut rng = crate::util::rng::Rng::new(17);
        for i in 0..5_000 {
            let t = i as f64 * 20.0; // 100s of traffic
            let lat = rng.lognormal(80.0, 0.5);
            let hot = rng.lognormal(30.0, 0.4);
            for r in [&full, &win] {
                r.record_latency(t, lat);
                r.record_fn_latency(t, sym("wparity"), hot);
            }
        }
        let to = 100_000.0;
        for from in [99_000.0, 95_000.0, 60_000.0, 0.0] {
            let a = full.p95_window(from, to, MIN_WINDOW_SAMPLES);
            let b = win.p95_window(from, to, MIN_WINDOW_SAMPLES);
            assert_eq!(a.to_bits(), b.to_bits(), "e2e p95 window [{from}, {to})");
            let a = full.fn_p95_window("wparity", from, to, MIN_WINDOW_SAMPLES);
            let b = win.fn_p95_window("wparity", from, to, MIN_WINDOW_SAMPLES);
            assert_eq!(a.to_bits(), b.to_bits(), "fn p95 window [{from}, {to})");
            let a = full.fn_self_ms_window("wparity", from, to);
            let b = win.fn_self_ms_window("wparity", from, to);
            assert_eq!(a.to_bits(), b.to_bits(), "fn self window [{from}, {to})");
        }
    }

    #[test]
    fn windowed_memory_stays_bounded_at_a_million_samples() {
        // ISSUE 5 satellite: 10^6 synthetic samples, windowed mode stays
        // under a fixed byte budget while full mode grows with the run.
        let win = windowed();
        let full = Recorder::new();
        let f = sym("mbound");
        for i in 0..1_000_000u64 {
            let t = i as f64; // 1000s at 1000 samples/s
            win.record_latency(t, 50.0 + (i % 100) as f64);
            win.record_fn_latency(t, f, 10.0 + (i % 10) as f64);
            full.record_latency(t, 50.0 + (i % 100) as f64);
            full.record_fn_latency(t, f, 10.0 + (i % 10) as f64);
        }
        let win_bytes = win.approx_bytes();
        let full_bytes = full.approx_bytes();
        const BUDGET: usize = 32 * 1024 * 1024;
        assert!(
            win_bytes < BUDGET,
            "windowed recorder used {win_bytes} bytes (budget {BUDGET})"
        );
        assert!(
            full_bytes > win_bytes * 4,
            "full retention ({full_bytes}) should dwarf windowed ({win_bytes})"
        );
        assert_eq!(win.request_count(), 1_000_000);
        // the trailing window is still exact
        assert!(win.fn_p95_window("mbound", 999_000.0, 1_000_000.0, 5).is_finite());
    }

    #[test]
    fn approx_quantiles_track_exact_ones() {
        let r = Recorder::new();
        let mut rng = crate::util::rng::Rng::new(23);
        for i in 0..20_000 {
            r.record_latency(i as f64 * 5.0, rng.lognormal(100.0, 0.6));
        }
        let exact = r.p95_window(0.0, 100_000.0, 5);
        let approx = r.p95_window_approx(0.0, 100_000.0);
        let rel = (approx - exact).abs() / exact;
        assert!(rel < 0.15, "approx {approx} vs exact {exact}");
    }

    #[test]
    fn windowed_mean_merges_bucket_summaries() {
        let r = Recorder::new();
        let f = sym("meanfn");
        // bucket 0: 10ms, bucket 1: 30ms -> whole-window mean 20
        r.record_fn_latency(100.0, f, 10.0);
        r.record_fn_latency(1_100.0, f, 30.0);
        assert!((r.fn_mean_window_approx("meanfn", 0.0, 2_000.0) - 20.0).abs() < 1e-12);
        assert!((r.fn_mean_window_approx("meanfn", 0.0, 1_000.0) - 10.0).abs() < 1e-12);
        assert!(r.fn_mean_window_approx("ghost", 0.0, 2_000.0).is_nan());
        assert!(r.fn_p95_window_approx("meanfn", 0.0, 2_000.0) >= 10.0);
    }

    #[test]
    fn full_mode_windows_older_than_retention_stay_exact() {
        // Full retention must answer ANY window exactly (seed contract):
        // a query reaching back past the ring falls back to the raw series.
        let r = Recorder::new(); // 128 s retention
        let f = sym("longfn");
        for i in 0..200_000u64 {
            // 200 s at 1000 samples/s — ring holds only the last 128 s
            r.record_fn_latency(i as f64, f, (i % 7) as f64);
        }
        assert_eq!(r.fn_p95_window("longfn", 0.0, 200_000.0, 5), 6.0);
        let expected: f64 = (0..200_000u64).map(|i| (i % 7) as f64).sum();
        assert_eq!(r.fn_self_ms_window("longfn", 0.0, 200_000.0), expected);
        // trailing windows keep using the shard fast path
        assert!(r.fn_p95_window("longfn", 199_000.0, 200_000.0, 5).is_finite());
    }

    #[test]
    fn recording_config_retention_guard() {
        let mut c = RecordingConfig::default();
        let before = c.retention_ms();
        c.ensure_retention_ms(before / 2.0);
        assert_eq!(c.retention_ms(), before, "smaller windows never shrink retention");
        c.ensure_retention_ms(before * 4.0);
        assert!(c.retention_ms() >= before * 4.0 - 1e-9);
        assert_eq!(RecordingLevel::parse("windowed").unwrap(), RecordingLevel::Windowed);
        assert_eq!(RecordingLevel::parse("full").unwrap(), RecordingLevel::Full);
        assert!(RecordingLevel::parse("???").is_err());
    }

    // -- working-set RAM attribution (ISSUE 3 satellite) ----------------------

    fn members(specs: &[(&str, f64)]) -> Vec<(String, f64)> {
        specs.iter().map(|(n, mb)| (n.to_string(), *mb)).collect()
    }

    #[test]
    fn attribute_ram_splits_overhead_equally_and_sums_to_total() {
        // Documented current behavior: each member keeps its code footprint
        // and the unexplained remainder (base runtime + in-flight working
        // sets) is split EQUALLY, regardless of who owns the in-flight
        // requests.
        let shares = attribute_ram(100.0, &members(&[("a", 10.0), ("b", 30.0)]), &[]);
        assert_eq!(shares.len(), 2);
        assert_eq!(shares[0], ("a".to_string(), 40.0)); // 10 + 60/2
        assert_eq!(shares[1], ("b".to_string(), 60.0)); // 30 + 60/2
        let sum: f64 = shares.iter().map(|(_, mb)| mb).sum();
        assert!((sum - 100.0).abs() < 1e-12);
        // code exceeding the measured total never attributes negative RAM
        let tight = attribute_ram(30.0, &members(&[("a", 20.0), ("b", 20.0)]), &[]);
        assert_eq!(tight[0].1, 20.0);
        assert_eq!(tight[1].1, 20.0);
        assert!(attribute_ram(50.0, &[], &[]).is_empty());
    }

    #[test]
    fn attribute_ram_weights_overhead_by_in_flight_ownership() {
        // The flipped PR 3 tripwire (ROADMAP: working-set RAM by in-flight
        // ownership): a member holding 9 of 10 in-flight requests is
        // attributed 90% of the unexplained overhead.
        let shares = attribute_ram(100.0, &members(&[("busy", 10.0), ("idle", 10.0)]), &[9, 1]);
        assert!(
            shares[0].1 > shares[1].1,
            "in-flight-weighted attribution regressed: busy={} idle={}",
            shares[0].1,
            shares[1].1
        );
        // overhead = 100 - 20 = 80: busy gets 10 + 72, idle gets 10 + 8
        assert!((shares[0].1 - 82.0).abs() < 1e-12);
        assert!((shares[1].1 - 18.0).abs() < 1e-12);
        let sum: f64 = shares.iter().map(|(_, mb)| mb).sum();
        assert!((sum - 100.0).abs() < 1e-12, "weighting must preserve the total");
    }

    #[test]
    fn attribute_ram_falls_back_to_equal_share_when_idle_or_unaligned() {
        // all-idle window: equal share
        let idle = attribute_ram(100.0, &members(&[("a", 10.0), ("b", 30.0)]), &[0, 0]);
        assert_eq!(idle[0].1, 40.0);
        assert_eq!(idle[1].1, 60.0);
        // a mismatched slice (e.g. a caller without ownership data) also
        // degrades to the equal share instead of panicking
        let unaligned = attribute_ram(100.0, &members(&[("a", 10.0), ("b", 30.0)]), &[5]);
        assert_eq!(unaligned[0].1, 40.0);
        assert_eq!(unaligned[1].1, 60.0);
    }
}
