//! Real-time HTTP front end: serves a deployed platform over actual TCP
//! with a minimal HTTP/1.1 implementation (no hyper offline).
//!
//! Architecture: OS threads own the listener and per-connection I/O and
//! forward parsed requests through a thread-safe mpsc into the
//! single-threaded platform executor (running in [`crate::exec::Mode::Real`]);
//! replies travel back over oneshot channels.  Python is nowhere in sight:
//! the compute bodies the requests exercise are the AOT artifacts executed
//! through PJRT.
//!
//! Endpoints:
//! * `POST /invoke` — invoke the app's entry function. Body: optional JSON
//!   array of f32 (padded/truncated to the payload length); empty body uses
//!   a seeded payload.
//! * `POST /invoke/<function>` — invoke a specific function.
//! * `GET /metrics` — latency quantiles, RAM, merges, counters as JSON.
//! * `GET /routes` — current routing table.
//! * `GET /healthz` — liveness.
//! * `POST /shutdown` — stop the server.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::rc::Rc;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use crate::apps::AppSpec;
use crate::config::PlatformConfig;
use crate::error::{Error, Result};
use crate::exec::channel::{mpsc, oneshot, OneshotSender, Sender};
use crate::exec::{Executor, Mode};
use crate::platform::Platform;
use crate::util::json::Json;
use crate::workload::request_payload;

/// A parsed inbound request, crossing from the I/O threads to the executor.
struct FrontRequest {
    function: Option<String>,
    payload: Option<Vec<f32>>,
    reply: OneshotSender<FrontReply>,
}

enum FrontReply {
    Output(Vec<f32>, f64),
    Metrics(String),
    Routes(String),
    Error(String),
}

/// Serve `app` on `config` at `127.0.0.1:port`.  Blocks until
/// `POST /shutdown` (or `max_requests` invocations, if set).
pub fn serve(
    app: AppSpec,
    config: PlatformConfig,
    port: u16,
    max_requests: Option<u64>,
) -> Result<()> {
    let listener = TcpListener::bind(("127.0.0.1", port))?;
    let actual_port = listener.local_addr()?.port();
    eprintln!("provuse: serving on http://127.0.0.1:{actual_port}");

    let (tx, mut rx) = mpsc::<Option<FrontRequest>>();
    let stop = Arc::new(AtomicBool::new(false));
    let served = Arc::new(AtomicU64::new(0));

    // accept loop on an OS thread
    let accept_stop = Arc::clone(&stop);
    let accept_tx = tx.clone();
    let io_thread = std::thread::spawn(move || {
        for conn in listener.incoming() {
            if accept_stop.load(Ordering::SeqCst) {
                break;
            }
            let Ok(stream) = conn else { continue };
            let tx = accept_tx.clone();
            let stop = Arc::clone(&accept_stop);
            std::thread::spawn(move || {
                let _ = handle_connection(stream, &tx, &stop);
            });
        }
    });

    // platform executor on this thread
    let ex = Executor::new(Mode::Real);
    let served_main = Arc::clone(&served);
    let result: Result<()> = ex.block_on(async move {
        let platform = Platform::deploy(app, config).await?;
        eprintln!(
            "provuse: deployed `{}` ({} functions, {} instances)",
            platform.app.name,
            platform.app.len(),
            platform.cluster.live_count()
        );
        while let Some(msg) = rx.recv().await {
            let Some(req) = msg else { break }; // shutdown sentinel
            let platform = Rc::clone(&platform);
            let served = Arc::clone(&served_main);
            crate::exec::spawn(async move {
                let reply = match &req.function {
                    None => metrics_or_invoke(&platform, req.payload, &served).await,
                    Some(f) if f == "__metrics" => {
                        FrontReply::Metrics(metrics_json(&platform))
                    }
                    Some(f) if f == "__routes" => FrontReply::Routes(routes_json(&platform)),
                    Some(f) => {
                        let payload = materialize_payload(&platform, req.payload, &served);
                        match invoke_timed(&platform, Some(f.clone()), payload).await {
                            Ok((out, ms)) => FrontReply::Output(out, ms),
                            Err(e) => FrontReply::Error(e.to_string()),
                        }
                    }
                };
                let _ = req.reply.send(reply);
            });
            if let Some(max) = max_requests {
                if served_main.load(Ordering::SeqCst) >= max {
                    break;
                }
            }
        }
        platform.shutdown();
        Ok(())
    });

    stop.store(true, Ordering::SeqCst);
    // unblock the accept loop
    let _ = TcpStream::connect(("127.0.0.1", actual_port));
    let _ = io_thread.join();
    result
}

fn materialize_payload(
    platform: &Platform,
    payload: Option<Vec<f32>>,
    served: &AtomicU64,
) -> Vec<f32> {
    let len = platform.payload_len();
    match payload {
        Some(mut p) => {
            p.resize(len, 0.0);
            p
        }
        None => request_payload(0xF00D, served.load(Ordering::SeqCst), len),
    }
}

async fn metrics_or_invoke(
    platform: &Rc<Platform>,
    payload: Option<Vec<f32>>,
    served: &Arc<AtomicU64>,
) -> FrontReply {
    let payload = materialize_payload(platform, payload, served);
    match invoke_timed(platform, None, payload).await {
        Ok((out, ms)) => {
            served.fetch_add(1, Ordering::SeqCst);
            FrontReply::Output(out, ms)
        }
        Err(e) => FrontReply::Error(e.to_string()),
    }
}

async fn invoke_timed(
    platform: &Rc<Platform>,
    function: Option<String>,
    payload: Vec<f32>,
) -> Result<(Vec<f32>, f64)> {
    let t0 = crate::exec::now();
    let arrival = platform.metrics.rel_now_ms();
    let out = match &function {
        None => platform.invoke(payload).await?,
        Some(f) => platform.invoke_function(f, payload).await?,
    };
    let ms = crate::exec::now().duration_since(t0).as_secs_f64() * 1e3;
    platform.metrics.record_latency(arrival, ms);
    Ok((out, ms))
}

fn metrics_json(platform: &Platform) -> String {
    let q = platform.metrics.latency_quantiles();
    let merges = platform.metrics.merges();
    Json::obj(vec![
        ("requests", Json::Num(q.len() as f64)),
        ("median_ms", Json::Num(q.median())),
        ("p95_ms", Json::Num(q.p95())),
        ("p99_ms", Json::Num(q.p99())),
        ("ram_mb", Json::Num(platform.cluster.total_ram_mb())),
        ("instances", Json::Num(platform.cluster.live_count() as f64)),
        ("merges", Json::Num(merges.len() as f64)),
        (
            "merged_functions",
            Json::Arr(
                merges
                    .iter()
                    .map(|m| Json::str(m.functions.join("+")))
                    .collect(),
            ),
        ),
        ("inline_calls", Json::Num(platform.metrics.counter("inline_calls") as f64)),
        (
            "remote_sync_calls",
            Json::Num(platform.metrics.counter("remote_sync_calls") as f64),
        ),
    ])
    .to_string()
}

fn routes_json(platform: &Platform) -> String {
    Json::Obj(
        platform
            .gateway
            .snapshot()
            .into_iter()
            .map(|(f, inst)| (f, Json::str(inst.id().to_string())))
            .collect(),
    )
    .to_string()
}

// ---------------------------------------------------------------------------
// minimal HTTP/1.1
// ---------------------------------------------------------------------------

fn handle_connection(
    stream: TcpStream,
    tx: &Sender<Option<FrontRequest>>,
    stop: &AtomicBool,
) -> std::io::Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut request_line = String::new();
    reader.read_line(&mut request_line)?;
    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("/").to_string();

    let mut content_length = 0usize;
    loop {
        let mut line = String::new();
        reader.read_line(&mut line)?;
        let line = line.trim();
        if line.is_empty() {
            break;
        }
        if let Some(v) = line.to_ascii_lowercase().strip_prefix("content-length:") {
            content_length = v.trim().parse().unwrap_or(0);
        }
    }
    let mut body = vec![0u8; content_length.min(16 << 20)];
    if content_length > 0 {
        reader.read_exact(&mut body)?;
    }

    let mut stream = stream;
    let respond = |stream: &mut TcpStream, code: u16, body: &str| -> std::io::Result<()> {
        write!(
            stream,
            "HTTP/1.1 {code} {}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
            if code == 200 { "OK" } else { "Error" },
            body.len(),
        )
    };

    match (method.as_str(), path.as_str()) {
        ("GET", "/healthz") => respond(&mut stream, 200, r#"{"ok":true}"#),
        ("POST", "/shutdown") => {
            stop.store(true, Ordering::SeqCst);
            let _ = tx.send(None);
            respond(&mut stream, 200, r#"{"shutdown":true}"#)
        }
        ("GET", "/metrics") | ("GET", "/routes") => {
            let magic = if path == "/metrics" { "__metrics" } else { "__routes" };
            match roundtrip(tx, Some(magic.to_string()), None) {
                Ok(FrontReply::Metrics(j)) | Ok(FrontReply::Routes(j)) => {
                    respond(&mut stream, 200, &j)
                }
                _ => respond(&mut stream, 500, r#"{"error":"internal"}"#),
            }
        }
        ("POST", p) if p == "/invoke" || p.starts_with("/invoke/") => {
            let function = p.strip_prefix("/invoke/").map(|s| s.to_string());
            let payload = parse_payload(&body);
            match roundtrip(tx, function, payload) {
                Ok(FrontReply::Output(out, ms)) => {
                    let json = Json::obj(vec![
                        ("latency_ms", Json::Num(ms)),
                        ("output", Json::arr_f64(out.iter().map(|v| *v as f64))),
                    ]);
                    respond(&mut stream, 200, &json.to_string())
                }
                Ok(FrontReply::Error(e)) => {
                    respond(&mut stream, 500, &Json::obj(vec![("error", Json::str(e))]).to_string())
                }
                _ => respond(&mut stream, 500, r#"{"error":"internal"}"#),
            }
        }
        _ => respond(&mut stream, 404, r#"{"error":"not found"}"#),
    }
}

fn parse_payload(body: &[u8]) -> Option<Vec<f32>> {
    if body.is_empty() {
        return None;
    }
    let text = std::str::from_utf8(body).ok()?;
    let json = Json::parse(text).ok()?;
    json.as_f32_vec().ok()
}

/// Send a request into the executor and synchronously wait for the reply
/// (we are on an I/O thread; the oneshot is mutex-based so busy-wait with a
/// short sleep is fine and keeps the receiver non-async).
fn roundtrip(
    tx: &Sender<Option<FrontRequest>>,
    function: Option<String>,
    payload: Option<Vec<f32>>,
) -> Result<FrontReply> {
    let (reply_tx, reply_rx) = oneshot::<FrontReply>();
    tx.send(Some(FrontRequest { function, payload, reply: reply_tx }))
        .map_err(|_| Error::Request("server shutting down".into()))?;
    // poll the oneshot from this thread (no executor here)
    let mut rx = Box::pin(reply_rx);
    let waker = std::task::Waker::noop().clone();
    let mut cx = std::task::Context::from_waker(&waker);
    loop {
        use std::future::Future;
        match rx.as_mut().poll(&mut cx) {
            std::task::Poll::Ready(Ok(reply)) => return Ok(reply),
            std::task::Poll::Ready(Err(_)) => {
                return Err(Error::Request("reply channel closed".into()))
            }
            std::task::Poll::Pending => std::thread::sleep(std::time::Duration::from_millis(2)),
        }
    }
}
