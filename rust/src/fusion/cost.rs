//! Cost-model-driven fusion objectives (Konflux-style: grouping as an
//! explicit cost optimization instead of threshold-tripping) — both the
//! *split* side (score live fused groups, shed the heaviest member) and,
//! since the merge-side planner, the *admission* side
//! ([`CostModel::predict_merge`]: score candidate pairs before any fuse is
//! requested, so pairs that would be immediate eviction candidates are
//! never fused at all).
//!
//! A fused group is scored with one weighted objective:
//!
//! ```text
//! score = w_latency * max(0, window_p95 / baseline_p95 - 1)
//!       + w_ram     * ram_mb / ram_reference
//!       + w_gbs     * billed GiB-seconds per wall second
//! ```
//!
//! Every term is non-negative and monotone: more RAM, a worse p95, or a
//! larger bill can never *lower* the score.  When the score stays above
//! `evict_threshold` for the configured hysteresis, the controller sheds
//! the group's **heaviest** member — the function with the largest share of
//! the group's attributed RAM, handler latency, and billed GiB-seconds —
//! with ties broken deterministically toward the lexicographically smallest
//! name.
//!
//! The RAM reference is `max_group_ram_mb` when set (the cap doubles as the
//! pressure scale), else `CostParams::ram_ref_mb`.  The billed term uses
//! the provider price sheet in [`crate::billing::CostModel`] only for
//! reporting; the score keeps raw GiB-seconds per second so weights stay
//! O(1) human-tunable.

use crate::cluster::NodeId;
use crate::config::{CostParams, FusionParams};
use crate::util::intern::Sym;

use super::{FnAttribution, GroupSample};

/// Windowed standalone signals for one *routed* function, fused or not —
/// the raw material of merge-side admission.  Gathered by the platform's
/// controller tick every feedback interval from already-collected series:
/// the handler's `FnSample` self-times, the tick's RAM attribution, and the
/// billing ledger's trailing window.
#[derive(Debug, Clone, PartialEq)]
pub struct FnSignals {
    /// interned function name (ISSUE 5: no `String` per window record)
    pub function: Sym,
    /// attributed RAM (MiB): the whole instance for a singleton, the
    /// function's `fn_ram` share inside a fused group
    pub ram_mb: f64,
    /// p95 handler self-time over the window (ms); NaN = too few samples
    pub p95_ms: f64,
    /// billed GiB-seconds attributed to this function in the window
    pub gb_seconds: f64,
    /// billed wall milliseconds in the window (*including* time blocked on
    /// outbound sync calls — the double-billed waits, §2.3)
    pub billed_ms: f64,
    /// summed handler self-time milliseconds in the window (dispatch +
    /// compute + busy, *excluding* blocked waits)
    pub self_ms: f64,
    /// window length (seconds)
    pub window_s: f64,
    /// node hosting the function's instance (None on single-node
    /// platforms and in non-cluster tests: treated as co-located)
    pub node: Option<NodeId>,
    /// live replica count of the function's set (1 for the seed's
    /// one-instance-per-function shape).  `ram_mb` is a *per-replica*
    /// footprint, so fusing multiplies it by the fused set's count.
    pub replicas: u32,
}

/// Placement context of one merge-admission evaluation: everything the
/// cluster layer knows that the windowed signals alone cannot express.
/// [`MergeContext::local`] is the single-node identity (share 1, already
/// co-located, nothing to migrate, no capacity bound).
#[derive(Debug, Clone, Copy)]
pub struct MergeContext {
    /// the callee's fraction of the caller's observed outbound sync calls
    /// (the caller's blocked time aggregates waits on *all* callees, so
    /// fusing one pair recovers only this share of it)
    pub callee_share: f64,
    /// caller and callee instances already share a node
    pub colocated: bool,
    /// predicted one-off cost (ms) of migrating the callee to the
    /// caller's node first (0 when co-located)
    pub migration_ms: f64,
    /// headroom left on the caller's node after the callee moves over
    /// (MiB); negative = the co-location would breach node capacity, so
    /// the pair is churn-gated exactly like a RAM-pressure refusal
    pub target_headroom_mb: f64,
    /// replica count the fused set would deploy at — the busier
    /// endpoint's count (a 4-replica caller fusing a 1-replica callee
    /// boots the callee's footprint into all 4 fused replicas).  1 at the
    /// seed shape, where it changes nothing.
    pub replica_scale: f64,
}

impl MergeContext {
    /// Single-node / co-located identity context.
    pub fn local() -> Self {
        MergeContext {
            callee_share: 1.0,
            colocated: true,
            migration_ms: 0.0,
            target_headroom_mb: f64::INFINITY,
            replica_scale: 1.0,
        }
    }
}

/// One merge-admission verdict (kept for telemetry and regret attribution).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MergeDecision {
    /// net predicted benefit: `w_latency * lat + w_gbs * gbs - w_ram * ram`
    pub score: f64,
    /// verdict: `score >= merge_threshold` and the churn gate passed
    pub admit: bool,
    /// predicted hop-latency savings: the caller's double-billed blocked
    /// seconds per wall second (billed minus self time), which fusion
    /// inlines away
    pub lat_term: f64,
    /// the callee's separately billed GiB-seconds per wall second — the
    /// double billing an inlined call eliminates entirely
    pub gbs_term: f64,
    /// predicted fused working set over the RAM reference (caller + callee
    /// attributed RAM; slightly pessimistic — the shared base runtime is
    /// counted twice — which errs on the side of refusing)
    pub ram_term: f64,
    /// amortized co-location cost: the predicted migration milliseconds
    /// spread over the feedback window (0 for co-located pairs)
    pub mig_term: f64,
    /// true when the RAM penalty alone already crosses the defusion
    /// objective's evict threshold — or the co-location would breach the
    /// target node's capacity: fusing would create an immediate
    /// eviction/pressure candidate, so the pair is refused regardless of
    /// benefit
    pub churn_gated: bool,
}

/// The weighted defusion objective (see module docs).
#[derive(Debug, Clone)]
pub struct CostModel {
    w_latency: f64,
    w_ram: f64,
    w_gbs: f64,
    evict_threshold: f64,
    ram_ref_mb: f64,
}

impl CostModel {
    /// Build from the fusion policy; resolves the RAM reference scale.
    pub fn from_params(p: &FusionParams) -> Self {
        let ram_ref_mb = if p.max_group_ram_mb > 0.0 {
            p.max_group_ram_mb
        } else {
            p.cost.ram_ref_mb.max(f64::MIN_POSITIVE)
        };
        CostModel {
            w_latency: p.cost.w_latency,
            w_ram: p.cost.w_ram,
            w_gbs: p.cost.w_gbs,
            evict_threshold: p.cost.evict_threshold,
            ram_ref_mb,
        }
    }

    /// Whether cost-driven defusion is armed at all.
    pub fn armed(&self) -> bool {
        self.evict_threshold > 0.0
    }

    /// The configured eviction threshold.
    pub fn evict_threshold(&self) -> f64 {
        self.evict_threshold
    }

    /// The group objective.  `baseline_p95_ms` is the group's pre-fusion
    /// regime (NaN disarms the latency term, exactly like the threshold
    /// policy's regression check).
    pub fn group_score(&self, sample: &GroupSample, baseline_p95_ms: f64) -> f64 {
        let latency = if baseline_p95_ms.is_finite()
            && baseline_p95_ms > 0.0
            && sample.window_p95_ms.is_finite()
        {
            (sample.window_p95_ms / baseline_p95_ms - 1.0).max(0.0)
        } else {
            0.0
        };
        let ram = sample.ram_mb.max(0.0) / self.ram_ref_mb;
        let gbs_rate = if sample.window_s > 0.0 {
            sample.per_fn.iter().map(|f| f.gb_seconds.max(0.0)).sum::<f64>() / sample.window_s
        } else {
            0.0
        };
        self.w_latency * latency + self.w_ram * ram + self.w_gbs * gbs_rate
    }

    /// Per-function heaviness: each member's share of the group's
    /// attributed RAM, handler p95, and billed GiB-seconds, weighted like
    /// the group objective.  Sorted heaviest-first; equal scores order by
    /// function name (deterministic tie-break).
    pub fn fn_scores(&self, sample: &GroupSample) -> Vec<(String, f64)> {
        let ram_total: f64 = sample.per_fn.iter().map(|f| f.ram_mb.max(0.0)).sum();
        let lat_total: f64 = sample.per_fn.iter().map(|f| finite_or_zero(f.p95_ms)).sum();
        let gbs_total: f64 = sample.per_fn.iter().map(|f| f.gb_seconds.max(0.0)).sum();
        let mut scores: Vec<(String, f64)> = sample
            .per_fn
            .iter()
            .map(|f| {
                let score = self.w_ram * share(f.ram_mb.max(0.0), ram_total)
                    + self.w_latency * share(finite_or_zero(f.p95_ms), lat_total)
                    + self.w_gbs * share(f.gb_seconds.max(0.0), gbs_total);
                (f.function.clone(), score)
            })
            .collect();
        scores.sort_by(|a, b| {
            b.1.partial_cmp(&a.1).unwrap_or(std::cmp::Ordering::Equal).then(a.0.cmp(&b.0))
        });
        scores
    }

    /// The member an eviction should shed (None for empty attribution).
    pub fn heaviest(&self, sample: &GroupSample) -> Option<String> {
        self.fn_scores(sample).into_iter().next().map(|(name, _)| name)
    }

    /// Override the three weights (the auto-tuner's hook: admission runs on
    /// the *current* hill-climbed weights, not the configured priors).
    pub fn with_weights(mut self, w_latency: f64, w_ram: f64, w_gbs: f64) -> Self {
        self.w_latency = w_latency;
        self.w_ram = w_ram;
        self.w_gbs = w_gbs;
        self
    }

    /// Merge-side admission objective: predict whether fusing
    /// (`caller`, `callee`) pays for itself.
    ///
    /// ```text
    /// benefit = w_latency * caller blocked-time rate * callee share
    ///         + w_gbs     * callee billed GiB-s rate   (double billing gone)
    /// penalty = w_ram     * (caller_ram + callee_ram) * replica_scale / ram_reference
    ///         + w_latency * migration_ms / window_ms   (co-location, amortized)
    /// score   = benefit - penalty;  admit iff score >= merge_threshold
    /// ```
    ///
    /// The blocked-time rate is measured, not modeled: the billing ledger
    /// charges the caller's full duration *including* sync waits while the
    /// handler's self-time series excludes them, so `billed - self` per
    /// wall second is exactly the double-billed hop time fusion eliminates.
    /// It aggregates waits on *all* of the caller's callees, so the term is
    /// scaled by `ctx.callee_share` — the callee's observed fraction of the
    /// caller's outbound sync calls — instead of pricing the full blocked
    /// time against every candidate (the multi-callee upper bound the
    /// ROADMAP flagged).
    ///
    /// Cluster pricing: a pair on different nodes must first migrate; the
    /// predicted migration cost is amortized over the feedback window and
    /// charged in the latency dimension (`mig_term`), so a hot pair
    /// swallows it while a lukewarm one keeps waiting.
    ///
    /// Churn gates (either refuses outright): when cost-driven defusion is
    /// armed, a pair whose RAM penalty alone (`w_ram * ram_term`, a lower
    /// bound on the post-fuse group score) already crosses
    /// `evict_threshold` — fusing it would create an immediate eviction
    /// candidate; and a pair whose co-location would leave negative
    /// headroom on the target node — fusing it would manufacture the node
    /// pressure the cluster controller exists to relieve.
    pub fn predict_merge(
        &self,
        caller: &FnSignals,
        callee: &FnSignals,
        merge_threshold: f64,
        ctx: &MergeContext,
    ) -> MergeDecision {
        let share = ctx.callee_share.clamp(0.0, 1.0);
        let lat_term = if caller.window_s > 0.0 {
            share * (caller.billed_ms - caller.self_ms).max(0.0) / (caller.window_s * 1e3)
        } else {
            0.0
        };
        let gbs_term = if callee.window_s > 0.0 {
            callee.gb_seconds.max(0.0) / callee.window_s
        } else {
            0.0
        };
        let mig_term = if ctx.colocated || caller.window_s <= 0.0 {
            0.0
        } else {
            ctx.migration_ms.max(0.0) / (caller.window_s * 1e3)
        };
        // per-replica footprints sum, then every fused replica pays the
        // combined working set — the replica-count term of the planner
        let ram_term = (caller.ram_mb.max(0.0) + callee.ram_mb.max(0.0))
            * ctx.replica_scale.max(1.0)
            / self.ram_ref_mb;
        let score = self.w_latency * (lat_term - mig_term) + self.w_gbs * gbs_term
            - self.w_ram * ram_term;
        let churn_gated = (self.armed() && self.w_ram * ram_term >= self.evict_threshold)
            || ctx.target_headroom_mb < 0.0;
        MergeDecision {
            score,
            admit: !churn_gated && score >= merge_threshold,
            lat_term,
            gbs_term,
            ram_term,
            mig_term,
            churn_gated,
        }
    }

    // -- global re-planner pricing (ISSUE 8) --------------------------------
    //
    // The global planner scores whole partitions with the SAME per-term
    // prices `predict_merge` charges pairs, rearranged as a minimization:
    // every *cut* sync edge keeps paying its blocked-time and double-billing
    // rates, every group keeps paying RAM residency.  A partition's total is
    // therefore comparable across arbitrary rearrangements, while a single
    // pair's predict_merge score remains exactly the delta of fusing that
    // pair in isolation.

    /// Ongoing price of leaving the sync edge (`caller` -> `callee`) *cut*
    /// (un-fused): the caller's double-billed blocked-time rate scaled by
    /// the callee's share of its outbound calls, plus the callee's
    /// separately billed GiB-s rate — the two benefit terms of
    /// [`CostModel::predict_merge`], charged as a cost while the edge
    /// stays remote.
    pub fn cut_cost(&self, caller: &FnSignals, callee: &FnSignals, callee_share: f64) -> f64 {
        let share = callee_share.clamp(0.0, 1.0);
        let lat_term = if caller.window_s > 0.0 {
            share * (caller.billed_ms - caller.self_ms).max(0.0) / (caller.window_s * 1e3)
        } else {
            0.0
        };
        let gbs_term = if callee.window_s > 0.0 {
            callee.gb_seconds.max(0.0) / callee.window_s
        } else {
            0.0
        };
        self.w_latency * lat_term + self.w_gbs * gbs_term
    }

    /// Ongoing RAM-residency price of one group: summed per-replica
    /// footprints, every fused replica paying the combined working set —
    /// the penalty term of [`CostModel::predict_merge`] as a group cost.
    pub fn residency_cost(&self, ram_mb: f64, replica_scale: f64) -> f64 {
        self.w_ram * ram_mb.max(0.0) * replica_scale.max(1.0) / self.ram_ref_mb
    }

    /// One-off co-location price of a migration, amortized over the
    /// feedback window (the `mig_term` of [`CostModel::predict_merge`]).
    pub fn migration_cost(&self, migration_ms: f64, window_s: f64) -> f64 {
        if window_s <= 0.0 {
            return 0.0;
        }
        self.w_latency * migration_ms.max(0.0) / (window_s * 1e3)
    }

    /// The RAM reference scale (MiB) the residency term divides by.
    pub fn ram_ref_mb(&self) -> f64 {
        self.ram_ref_mb
    }
}

/// Online hill-climb over the three merge weights, driven by post-fuse
/// regret.  An admitted fuse that the defusion controller evicts or splits
/// within one cooldown of its cutover means admission mis-priced it: the
/// RAM penalty weight steps up and the benefit weights step down, the
/// direction that would have refused that fuse.  A fuse that survives its
/// cooldown decays the weights a fraction of the way back toward the
/// configured priors, so transient bad luck cannot skew them permanently.
///
/// Known limitation (see ROADMAP): the step is a uniform multiplicative
/// nudge — there is no per-term credit assignment, so a regret caused
/// purely by a latency mis-prediction still raises the RAM weight.
#[derive(Debug, Clone)]
pub struct AutoTuner {
    /// current (online-tuned) weights; start at the configured priors
    pub w_latency: f64,
    pub w_ram: f64,
    pub w_gbs: f64,
    prior_latency: f64,
    prior_ram: f64,
    prior_gbs: f64,
    step: f64,
    regrets: u64,
}

/// Weight clamp bounds: keep every weight strictly positive and within two
/// orders of magnitude of 1 so a pathological regret streak cannot disarm
/// a term forever.
const TUNE_MIN_W: f64 = 0.01;
const TUNE_MAX_W: f64 = 100.0;

impl AutoTuner {
    /// A tuner starting at the configured prior weights.
    pub fn new(p: &CostParams) -> Self {
        AutoTuner {
            w_latency: p.w_latency,
            w_ram: p.w_ram,
            w_gbs: p.w_gbs,
            prior_latency: p.w_latency,
            prior_ram: p.w_ram,
            prior_gbs: p.w_gbs,
            step: p.tune_step.max(0.0),
            regrets: 0,
        }
    }

    /// Current `(w_latency, w_ram, w_gbs)`.
    pub fn weights(&self) -> (f64, f64, f64) {
        (self.w_latency, self.w_ram, self.w_gbs)
    }

    /// Regrets observed so far.
    pub fn regrets(&self) -> u64 {
        self.regrets
    }

    /// An admitted fuse was defused within one cooldown of its cutover.
    pub fn on_regret(&mut self) {
        self.regrets += 1;
        let up = 1.0 + self.step;
        self.w_ram = (self.w_ram * up).clamp(TUNE_MIN_W, TUNE_MAX_W);
        self.w_latency = (self.w_latency / up).clamp(TUNE_MIN_W, TUNE_MAX_W);
        self.w_gbs = (self.w_gbs / up).clamp(TUNE_MIN_W, TUNE_MAX_W);
    }

    /// An admitted fuse outlived its cooldown without being defused: decay
    /// a tenth of the remaining distance back toward the configured priors.
    pub fn on_survival(&mut self) {
        self.pull_toward_priors(0.1);
    }

    /// Per-feedback-window decay (1% of the remaining distance to the
    /// priors).  This is the recovery path survivals cannot provide: after
    /// a regret streak has pushed `w_ram` high enough that the churn gate
    /// refuses *every* candidate, nothing is ever admitted again, so no
    /// survival would ever fire — without a time-based pull the tuner
    /// would lock fusion out for the rest of the run.
    pub fn on_window(&mut self) {
        self.pull_toward_priors(0.01);
    }

    fn pull_toward_priors(&mut self, pull: f64) {
        self.w_latency += (self.prior_latency - self.w_latency) * pull;
        self.w_ram += (self.prior_ram - self.w_ram) * pull;
        self.w_gbs += (self.prior_gbs - self.w_gbs) * pull;
    }
}

fn share(value: f64, total: f64) -> f64 {
    if total > 0.0 { value / total } else { 0.0 }
}

fn finite_or_zero(v: f64) -> f64 {
    if v.is_finite() { v.max(0.0) } else { 0.0 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SplitPolicyKind;
    use crate::util::prop::check;

    fn model(ram_cap: f64) -> CostModel {
        let mut p = FusionParams::default_enabled();
        p.split_policy = SplitPolicyKind::CostModel;
        p.max_group_ram_mb = ram_cap;
        CostModel::from_params(&p)
    }

    fn sample(ram_mb: f64, p95: f64, per_fn: Vec<FnAttribution>) -> GroupSample {
        GroupSample {
            functions: per_fn.iter().map(|f| f.function.clone()).collect(),
            ram_mb,
            window_p95_ms: p95,
            window_s: 2.0,
            per_fn,
        }
    }

    fn attr(function: &str, ram_mb: f64, p95_ms: f64, gb_seconds: f64) -> FnAttribution {
        FnAttribution { function: function.into(), ram_mb, p95_ms, gb_seconds }
    }

    #[test]
    fn score_is_monotone_in_ram_and_p95() {
        // Property (ISSUE 2): more RAM or a higher window p95 never lowers
        // the split score, for any weights and any baseline.
        check("cost score monotone", 256, |g| {
            let mut p = FusionParams::default_enabled();
            p.split_policy = SplitPolicyKind::CostModel;
            p.max_group_ram_mb = g.f64(50.0, 1_000.0);
            p.cost.w_latency = g.f64(0.0, 4.0);
            p.cost.w_ram = g.f64(0.0, 4.0);
            p.cost.w_gbs = g.f64(0.0, 4.0);
            let m = CostModel::from_params(&p);
            let baseline = g.f64(10.0, 1_000.0);
            let ram = g.f64(0.0, 2_000.0);
            let p95 = g.f64(1.0, 5_000.0);
            let gbs = g.f64(0.0, 10.0);
            let base = sample(ram, p95, vec![attr("a", ram, p95, gbs)]);
            let score = m.group_score(&base, baseline);
            assert!(score.is_finite() && score >= 0.0);

            let more_ram = sample(ram + g.f64(0.0, 500.0), p95, base.per_fn.clone());
            assert!(
                m.group_score(&more_ram, baseline) >= score,
                "more RAM lowered the score"
            );
            let worse_p95 = sample(ram, p95 + g.f64(0.0, 2_000.0), base.per_fn.clone());
            assert!(
                m.group_score(&worse_p95, baseline) >= score,
                "worse p95 lowered the score"
            );
        });
    }

    #[test]
    fn latency_term_disarmed_without_a_baseline() {
        let m = model(100.0);
        let s = sample(100.0, 10_000.0, vec![]);
        // NaN baseline -> only the RAM term remains (100/100 = 1.0)
        assert!((m.group_score(&s, f64::NAN) - 1.0).abs() < 1e-12);
        // improved latency clamps to zero rather than crediting the group
        assert!((m.group_score(&sample(100.0, 50.0, vec![]), 100.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn gbs_term_is_a_rate_over_the_window() {
        let m = model(1e9); // RAM term ~ 0
        let s = sample(
            0.0,
            f64::NAN,
            vec![attr("a", 0.0, f64::NAN, 3.0), attr("b", 0.0, f64::NAN, 1.0)],
        );
        // 4 GiB-s over a 2 s window = 2.0
        assert!((m.group_score(&s, f64::NAN) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn ram_reference_falls_back_when_cap_unset() {
        let mut p = FusionParams::default_enabled();
        p.split_policy = SplitPolicyKind::CostModel;
        p.max_group_ram_mb = 0.0;
        p.cost.ram_ref_mb = 512.0;
        let m = CostModel::from_params(&p);
        assert!((m.group_score(&sample(512.0, f64::NAN, vec![]), f64::NAN) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn heaviest_picks_dominant_member() {
        let m = model(200.0);
        let s = sample(
            400.0,
            f64::NAN,
            vec![
                attr("light", 40.0, 10.0, 0.1),
                attr("heavy", 320.0, 90.0, 2.0),
                attr("mid", 40.0, 20.0, 0.2),
            ],
        );
        assert_eq!(m.heaviest(&s).as_deref(), Some("heavy"));
        let scores = m.fn_scores(&s);
        assert_eq!(scores[0].0, "heavy");
        assert!(scores[0].1 > scores[1].1);
    }

    #[test]
    fn heaviest_ties_break_toward_smallest_name() {
        let m = model(200.0);
        // identical attribution -> deterministic lexicographic winner
        let s = sample(
            100.0,
            f64::NAN,
            vec![attr("zeta", 50.0, 30.0, 1.0), attr("alpha", 50.0, 30.0, 1.0)],
        );
        assert_eq!(m.heaviest(&s).as_deref(), Some("alpha"));
        // all-zero attribution (e.g. an idle window) is still deterministic
        let idle = sample(
            100.0,
            f64::NAN,
            vec![attr("b", 0.0, f64::NAN, 0.0), attr("a", 0.0, f64::NAN, 0.0)],
        );
        assert_eq!(m.heaviest(&idle).as_deref(), Some("a"));
        assert_eq!(m.heaviest(&sample(1.0, f64::NAN, vec![])), None);
    }

    // -- merge-side admission planner -----------------------------------------

    fn signals(function: &str, ram_mb: f64, billed_ms: f64, self_ms: f64, gbs: f64) -> FnSignals {
        FnSignals {
            function: Sym::intern(function),
            ram_mb,
            p95_ms: f64::NAN,
            gb_seconds: gbs,
            billed_ms,
            self_ms,
            window_s: 2.0,
            node: None,
            replicas: 1,
        }
    }

    #[test]
    fn predict_merge_admits_hot_light_pair_and_refuses_heavy_pair() {
        let m = model(256.0); // evict_threshold = 2.0 (default)
        let ctx = MergeContext::local();
        // light hot pair: caller blocked 1.6 s over a 2 s window, callee
        // bill small, combined RAM well under the reference
        let light = m.predict_merge(
            &signals("a", 70.0, 2_000.0, 400.0, 0.1),
            &signals("b", 70.0, 0.0, 0.0, 0.1),
            0.0,
            &ctx,
        );
        assert!(light.admit, "{light:?}");
        assert!(!light.churn_gated);
        assert!((light.lat_term - 0.8).abs() < 1e-12);
        assert!((light.gbs_term - 0.05).abs() < 1e-12);
        assert_eq!(light.mig_term, 0.0);
        // heavy pair: callee RAM alone pushes the predicted working set
        // past the evict threshold -> churn-gated even though the benefit
        // terms are large
        let heavy = m.predict_merge(
            &signals("a", 70.0, 2_000.0, 100.0, 0.1),
            &signals("big", 460.0, 0.0, 0.0, 2.0),
            0.0,
            &ctx,
        );
        assert!(!heavy.admit, "{heavy:?}");
        assert!(heavy.churn_gated, "refusal must be the churn gate");
    }

    #[test]
    fn predict_merge_refuses_cold_pair_on_threshold() {
        let m = model(256.0);
        // almost no traffic: benefit ~ 0, penalty ~ 0.55 -> score < 0
        let cold = m.predict_merge(
            &signals("a", 70.0, 20.0, 15.0, 0.001),
            &signals("b", 70.0, 0.0, 0.0, 0.001),
            0.0,
            &MergeContext::local(),
        );
        assert!(!cold.admit, "{cold:?}");
        assert!(!cold.churn_gated, "cold refusal is the score, not the churn gate");
        assert!(cold.score < 0.0);
    }

    #[test]
    fn predict_merge_scales_blocked_time_by_callee_share() {
        // ISSUE 4 satellite: a caller with several callees must not price
        // its whole blocked time against each of them.
        let m = model(1e9).with_weights(1.0, 0.0, 0.0); // latency term only
        let caller = signals("a", 70.0, 2_000.0, 400.0, 0.0);
        let callee = signals("b", 70.0, 0.0, 0.0, 0.0);
        let sole = m.predict_merge(&caller, &callee, 0.0, &MergeContext::local());
        let half = m.predict_merge(
            &caller,
            &callee,
            0.0,
            &MergeContext { callee_share: 0.5, ..MergeContext::local() },
        );
        assert!((sole.lat_term - 0.8).abs() < 1e-12);
        assert!((half.lat_term - 0.4).abs() < 1e-12, "{half:?}");
        assert!((half.score - sole.score / 2.0).abs() < 1e-12);
        // out-of-range shares clamp instead of inflating the benefit
        let wild = m.predict_merge(
            &caller,
            &callee,
            0.0,
            &MergeContext { callee_share: 7.0, ..MergeContext::local() },
        );
        assert!((wild.lat_term - 0.8).abs() < 1e-12);
    }

    #[test]
    fn predict_merge_prices_migration_and_gates_on_target_capacity() {
        let m = model(1e9).with_weights(1.0, 0.0, 0.0);
        let caller = signals("a", 70.0, 2_000.0, 400.0, 0.0);
        let callee = signals("b", 70.0, 0.0, 0.0, 0.0);
        // cross-node pair: a 1 s predicted migration amortized over the
        // 2 s window costs 0.5 in the latency dimension
        let cross = MergeContext {
            callee_share: 1.0,
            colocated: false,
            migration_ms: 1_000.0,
            target_headroom_mb: 100.0,
            replica_scale: 1.0,
        };
        let d = m.predict_merge(&caller, &callee, 0.0, &cross);
        assert!((d.mig_term - 0.5).abs() < 1e-12, "{d:?}");
        assert!((d.score - 0.3).abs() < 1e-12, "benefit 0.8 - migration 0.5");
        assert!(d.admit);
        // the same pair is refused when the hop is not worth the move
        let lukewarm = m.predict_merge(
            &signals("a", 70.0, 500.0, 400.0, 0.0),
            &callee,
            0.0,
            &cross,
        );
        assert!(lukewarm.score < 0.0 && !lukewarm.admit, "{lukewarm:?}");
        // negative target headroom churn-gates regardless of benefit
        let breach = m.predict_merge(
            &caller,
            &callee,
            0.0,
            &MergeContext { target_headroom_mb: -1.0, ..cross },
        );
        assert!(breach.churn_gated && !breach.admit, "{breach:?}");
    }

    #[test]
    fn predict_merge_scales_ram_penalty_by_replica_count() {
        // fusing a 4-replica caller with a 1-replica callee boots the
        // callee's footprint into all four fused replicas: the RAM
        // penalty must price the whole fleet, not one instance
        let m = model(256.0);
        let caller = signals("a", 40.0, 2_000.0, 400.0, 0.1);
        let callee = signals("b", 40.0, 0.0, 0.0, 0.1);
        let single = m.predict_merge(&caller, &callee, 0.0, &MergeContext::local());
        let fleet = m.predict_merge(
            &caller,
            &callee,
            0.0,
            &MergeContext { replica_scale: 4.0, ..MergeContext::local() },
        );
        assert!((single.ram_term - 80.0 / 256.0).abs() < 1e-12, "{single:?}");
        assert!((fleet.ram_term - 4.0 * 80.0 / 256.0).abs() < 1e-12, "{fleet:?}");
        assert!(fleet.score < single.score);
        // sub-1 scales clamp to the single-replica price instead of
        // discounting RAM below one instance's footprint
        let clamped = m.predict_merge(
            &caller,
            &callee,
            0.0,
            &MergeContext { replica_scale: 0.0, ..MergeContext::local() },
        );
        assert!((clamped.ram_term - single.ram_term).abs() < 1e-12);
    }

    #[test]
    fn predict_merge_blocked_time_clamps_and_weights_apply() {
        let m = model(256.0).with_weights(2.0, 0.0, 0.0);
        let ctx = MergeContext::local();
        // self > billed (e.g. inline-dominated window) clamps to zero
        let d = m.predict_merge(
            &signals("a", 70.0, 100.0, 500.0, 0.0),
            &signals("b", 70.0, 0.0, 0.0, 4.0),
            0.0,
            &ctx,
        );
        assert_eq!(d.lat_term, 0.0);
        // w_gbs = 0 silences the bill term; w_ram = 0 removes the penalty
        assert_eq!(d.score, 0.0);
        assert!(d.admit);
        // degenerate window disables the rate terms instead of dividing by 0
        let z = m.predict_merge(
            &FnSignals { window_s: 0.0, ..signals("a", 70.0, 100.0, 0.0, 1.0) },
            &FnSignals { window_s: 0.0, ..signals("b", 70.0, 0.0, 0.0, 1.0) },
            0.0,
            &ctx,
        );
        assert_eq!(z.lat_term, 0.0);
        assert_eq!(z.gbs_term, 0.0);
        assert_eq!(z.mig_term, 0.0);
    }

    #[test]
    fn predict_merge_score_is_monotone() {
        // More caller blocked time or callee bill never lowers the score;
        // more RAM never raises it.
        check("merge score monotone", 256, |g| {
            let mut p = FusionParams::default_enabled();
            p.max_group_ram_mb = g.f64(50.0, 1_000.0);
            p.cost.w_latency = g.f64(0.0, 4.0);
            p.cost.w_ram = g.f64(0.0, 4.0);
            p.cost.w_gbs = g.f64(0.0, 4.0);
            let m = CostModel::from_params(&p);
            let ctx = MergeContext {
                callee_share: g.f64(0.0, 1.0),
                colocated: g.bool(),
                migration_ms: g.f64(0.0, 5_000.0),
                target_headroom_mb: g.f64(0.0, 1_000.0),
                replica_scale: g.f64(1.0, 6.0),
            };
            let caller = FnSignals {
                function: "a".into(),
                ram_mb: g.f64(0.0, 1_000.0),
                p95_ms: f64::NAN,
                gb_seconds: g.f64(0.0, 5.0),
                billed_ms: g.f64(0.0, 10_000.0),
                self_ms: g.f64(0.0, 5_000.0),
                window_s: g.f64(0.5, 10.0),
                node: None,
                replicas: 1,
            };
            let callee = FnSignals {
                function: "b".into(),
                ram_mb: g.f64(0.0, 1_000.0),
                p95_ms: f64::NAN,
                gb_seconds: g.f64(0.0, 5.0),
                billed_ms: 0.0,
                self_ms: 0.0,
                window_s: caller.window_s,
                node: None,
                replicas: 1,
            };
            let base = m.predict_merge(&caller, &callee, 0.0, &ctx);
            assert!(base.score.is_finite());

            let busier = FnSignals {
                billed_ms: caller.billed_ms + g.f64(0.0, 5_000.0),
                ..caller.clone()
            };
            assert!(
                m.predict_merge(&busier, &callee, 0.0, &ctx).score >= base.score,
                "more blocked time lowered the merge score"
            );
            let pricier = FnSignals {
                gb_seconds: callee.gb_seconds + g.f64(0.0, 5.0),
                ..callee.clone()
            };
            assert!(
                m.predict_merge(&caller, &pricier, 0.0, &ctx).score >= base.score,
                "a bigger callee bill lowered the merge score"
            );
            let fatter = FnSignals { ram_mb: callee.ram_mb + g.f64(0.0, 500.0), ..callee.clone() };
            assert!(
                m.predict_merge(&caller, &fatter, 0.0, &ctx).score <= base.score,
                "more RAM raised the merge score"
            );
            // a larger callee share never lowers the score; a pricier
            // migration never raises it
            let keener = MergeContext {
                callee_share: (ctx.callee_share + g.f64(0.0, 1.0)).min(1.0),
                ..ctx
            };
            assert!(
                m.predict_merge(&caller, &callee, 0.0, &keener).score >= base.score,
                "a larger callee share lowered the merge score"
            );
            let farther = MergeContext {
                migration_ms: ctx.migration_ms + g.f64(0.0, 5_000.0),
                ..ctx
            };
            assert!(
                m.predict_merge(&caller, &callee, 0.0, &farther).score <= base.score,
                "a pricier migration raised the merge score"
            );
            let wider = MergeContext {
                replica_scale: ctx.replica_scale + g.f64(0.0, 4.0),
                ..ctx
            };
            assert!(
                m.predict_merge(&caller, &callee, 0.0, &wider).score <= base.score,
                "a larger replica scale raised the merge score"
            );
        });
    }

    #[test]
    fn planner_prices_decompose_predict_merge_exactly() {
        // The global planner's cut/residency/migration prices must be the
        // SAME terms predict_merge charges, so a pair's admission score is
        // exactly the objective delta of fusing it in isolation:
        //   score = cut_cost - migration_cost - residency_cost(pair)
        check("planner prices decompose predict_merge", 128, |g| {
            let mut p = FusionParams::default_enabled();
            p.max_group_ram_mb = g.f64(50.0, 1_000.0);
            p.cost.w_latency = g.f64(0.0, 4.0);
            p.cost.w_ram = g.f64(0.0, 4.0);
            p.cost.w_gbs = g.f64(0.0, 4.0);
            let m = CostModel::from_params(&p);
            let window_s = g.f64(0.5, 10.0);
            let caller = FnSignals {
                window_s,
                ..signals("a", g.f64(0.0, 500.0), g.f64(0.0, 8_000.0), g.f64(0.0, 4_000.0), g.f64(0.0, 4.0))
            };
            let callee = FnSignals {
                window_s,
                ..signals("b", g.f64(0.0, 500.0), 0.0, 0.0, g.f64(0.0, 4.0))
            };
            let colocated = g.bool();
            let ctx = MergeContext {
                callee_share: g.f64(0.0, 1.0),
                colocated,
                migration_ms: g.f64(0.0, 5_000.0),
                target_headroom_mb: f64::INFINITY,
                replica_scale: g.f64(1.0, 5.0),
            };
            let d = m.predict_merge(&caller, &callee, 0.0, &ctx);
            let mig = if colocated {
                0.0
            } else {
                m.migration_cost(ctx.migration_ms, caller.window_s)
            };
            let recomposed = m.cut_cost(&caller, &callee, ctx.callee_share)
                - mig
                - m.residency_cost(caller.ram_mb + callee.ram_mb, ctx.replica_scale);
            assert!(
                (d.score - recomposed).abs() < 1e-12,
                "predict_merge {} != decomposed {recomposed}",
                d.score
            );
        });
    }

    #[test]
    fn auto_tuner_regret_raises_ram_weight_and_survival_decays_back() {
        let p = CostParams::default();
        let mut t = AutoTuner::new(&p);
        assert_eq!(t.weights(), (1.0, 1.0, 1.0));
        t.on_regret();
        let (wl, wr, wg) = t.weights();
        assert!(wr > 1.0, "regret must raise the RAM penalty weight");
        assert!(wl < 1.0 && wg < 1.0, "regret must lower the benefit weights");
        assert_eq!(t.regrets(), 1);
        // survivals pull monotonically back toward the priors
        for _ in 0..100 {
            t.on_survival();
        }
        let (wl2, wr2, wg2) = t.weights();
        assert!((wl2 - 1.0).abs() < 1e-3 && (wr2 - 1.0).abs() < 1e-3 && (wg2 - 1.0).abs() < 1e-3);
        assert_eq!(t.regrets(), 1, "survival must not erase the regret count");
    }

    #[test]
    fn auto_tuner_window_decay_recovers_from_a_lockout_streak() {
        // After a regret streak pushes w_ram past the point where the
        // churn gate refuses everything, no fuse is ever admitted, so no
        // survival can fire — only the per-window decay can bring the
        // weights back toward the priors.
        let p = CostParams::default();
        let mut t = AutoTuner::new(&p);
        for _ in 0..8 {
            t.on_regret();
        }
        let (_, locked_ram, _) = t.weights();
        assert!(locked_ram > 2.0, "streak must have inflated w_ram: {locked_ram}");
        for _ in 0..1_000 {
            t.on_window();
        }
        let (wl, wr, wg) = t.weights();
        assert!((wr - 1.0).abs() < 1e-2, "window decay must recover w_ram: {wr}");
        assert!((wl - 1.0).abs() < 1e-2 && (wg - 1.0).abs() < 1e-2);
    }

    #[test]
    fn auto_tuner_weights_stay_clamped_under_regret_streaks() {
        let mut p = CostParams::default();
        p.tune_step = 10.0;
        let mut t = AutoTuner::new(&p);
        for _ in 0..50 {
            t.on_regret();
        }
        let (wl, wr, wg) = t.weights();
        assert!(wl >= 0.01 && wg >= 0.01, "benefit weights must not hit zero");
        assert!(wr <= 100.0, "RAM weight must stay bounded");
        assert_eq!(t.regrets(), 50);
    }

    #[test]
    fn disarmed_below_zero_threshold() {
        let mut p = FusionParams::default_enabled();
        p.cost.evict_threshold = 0.0;
        assert!(!CostModel::from_params(&p).armed());
        p.cost.evict_threshold = 2.0;
        assert!(CostModel::from_params(&p).armed());
    }
}
