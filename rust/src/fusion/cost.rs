//! Cost-model-driven defusion objective (Konflux-style: grouping as an
//! explicit cost optimization instead of threshold-tripping).
//!
//! A fused group is scored with one weighted objective:
//!
//! ```text
//! score = w_latency * max(0, window_p95 / baseline_p95 - 1)
//!       + w_ram     * ram_mb / ram_reference
//!       + w_gbs     * billed GiB-seconds per wall second
//! ```
//!
//! Every term is non-negative and monotone: more RAM, a worse p95, or a
//! larger bill can never *lower* the score.  When the score stays above
//! `evict_threshold` for the configured hysteresis, the controller sheds
//! the group's **heaviest** member — the function with the largest share of
//! the group's attributed RAM, handler latency, and billed GiB-seconds —
//! with ties broken deterministically toward the lexicographically smallest
//! name.
//!
//! The RAM reference is `max_group_ram_mb` when set (the cap doubles as the
//! pressure scale), else `CostParams::ram_ref_mb`.  The billed term uses
//! the provider price sheet in [`crate::billing::CostModel`] only for
//! reporting; the score keeps raw GiB-seconds per second so weights stay
//! O(1) human-tunable.

use crate::config::FusionParams;

use super::{FnAttribution, GroupSample};

/// The weighted defusion objective (see module docs).
#[derive(Debug, Clone)]
pub struct CostModel {
    w_latency: f64,
    w_ram: f64,
    w_gbs: f64,
    evict_threshold: f64,
    ram_ref_mb: f64,
}

impl CostModel {
    /// Build from the fusion policy; resolves the RAM reference scale.
    pub fn from_params(p: &FusionParams) -> Self {
        let ram_ref_mb = if p.max_group_ram_mb > 0.0 {
            p.max_group_ram_mb
        } else {
            p.cost.ram_ref_mb.max(f64::MIN_POSITIVE)
        };
        CostModel {
            w_latency: p.cost.w_latency,
            w_ram: p.cost.w_ram,
            w_gbs: p.cost.w_gbs,
            evict_threshold: p.cost.evict_threshold,
            ram_ref_mb,
        }
    }

    /// Whether cost-driven defusion is armed at all.
    pub fn armed(&self) -> bool {
        self.evict_threshold > 0.0
    }

    pub fn evict_threshold(&self) -> f64 {
        self.evict_threshold
    }

    /// The group objective.  `baseline_p95_ms` is the group's pre-fusion
    /// regime (NaN disarms the latency term, exactly like the threshold
    /// policy's regression check).
    pub fn group_score(&self, sample: &GroupSample, baseline_p95_ms: f64) -> f64 {
        let latency = if baseline_p95_ms.is_finite()
            && baseline_p95_ms > 0.0
            && sample.window_p95_ms.is_finite()
        {
            (sample.window_p95_ms / baseline_p95_ms - 1.0).max(0.0)
        } else {
            0.0
        };
        let ram = sample.ram_mb.max(0.0) / self.ram_ref_mb;
        let gbs_rate = if sample.window_s > 0.0 {
            sample.per_fn.iter().map(|f| f.gb_seconds.max(0.0)).sum::<f64>() / sample.window_s
        } else {
            0.0
        };
        self.w_latency * latency + self.w_ram * ram + self.w_gbs * gbs_rate
    }

    /// Per-function heaviness: each member's share of the group's
    /// attributed RAM, handler p95, and billed GiB-seconds, weighted like
    /// the group objective.  Sorted heaviest-first; equal scores order by
    /// function name (deterministic tie-break).
    pub fn fn_scores(&self, sample: &GroupSample) -> Vec<(String, f64)> {
        let ram_total: f64 = sample.per_fn.iter().map(|f| f.ram_mb.max(0.0)).sum();
        let lat_total: f64 = sample.per_fn.iter().map(|f| finite_or_zero(f.p95_ms)).sum();
        let gbs_total: f64 = sample.per_fn.iter().map(|f| f.gb_seconds.max(0.0)).sum();
        let mut scores: Vec<(String, f64)> = sample
            .per_fn
            .iter()
            .map(|f| {
                let score = self.w_ram * share(f.ram_mb.max(0.0), ram_total)
                    + self.w_latency * share(finite_or_zero(f.p95_ms), lat_total)
                    + self.w_gbs * share(f.gb_seconds.max(0.0), gbs_total);
                (f.function.clone(), score)
            })
            .collect();
        scores.sort_by(|a, b| {
            b.1.partial_cmp(&a.1).unwrap_or(std::cmp::Ordering::Equal).then(a.0.cmp(&b.0))
        });
        scores
    }

    /// The member an eviction should shed (None for empty attribution).
    pub fn heaviest(&self, sample: &GroupSample) -> Option<String> {
        self.fn_scores(sample).into_iter().next().map(|(name, _)| name)
    }
}

fn share(value: f64, total: f64) -> f64 {
    if total > 0.0 { value / total } else { 0.0 }
}

fn finite_or_zero(v: f64) -> f64 {
    if v.is_finite() { v.max(0.0) } else { 0.0 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SplitPolicyKind;
    use crate::util::prop::check;

    fn model(ram_cap: f64) -> CostModel {
        let mut p = FusionParams::default_enabled();
        p.split_policy = SplitPolicyKind::CostModel;
        p.max_group_ram_mb = ram_cap;
        CostModel::from_params(&p)
    }

    fn sample(ram_mb: f64, p95: f64, per_fn: Vec<FnAttribution>) -> GroupSample {
        GroupSample {
            functions: per_fn.iter().map(|f| f.function.clone()).collect(),
            ram_mb,
            window_p95_ms: p95,
            window_s: 2.0,
            per_fn,
        }
    }

    fn attr(function: &str, ram_mb: f64, p95_ms: f64, gb_seconds: f64) -> FnAttribution {
        FnAttribution { function: function.into(), ram_mb, p95_ms, gb_seconds }
    }

    #[test]
    fn score_is_monotone_in_ram_and_p95() {
        // Property (ISSUE 2): more RAM or a higher window p95 never lowers
        // the split score, for any weights and any baseline.
        check("cost score monotone", 256, |g| {
            let mut p = FusionParams::default_enabled();
            p.split_policy = SplitPolicyKind::CostModel;
            p.max_group_ram_mb = g.f64(50.0, 1_000.0);
            p.cost.w_latency = g.f64(0.0, 4.0);
            p.cost.w_ram = g.f64(0.0, 4.0);
            p.cost.w_gbs = g.f64(0.0, 4.0);
            let m = CostModel::from_params(&p);
            let baseline = g.f64(10.0, 1_000.0);
            let ram = g.f64(0.0, 2_000.0);
            let p95 = g.f64(1.0, 5_000.0);
            let gbs = g.f64(0.0, 10.0);
            let base = sample(ram, p95, vec![attr("a", ram, p95, gbs)]);
            let score = m.group_score(&base, baseline);
            assert!(score.is_finite() && score >= 0.0);

            let more_ram = sample(ram + g.f64(0.0, 500.0), p95, base.per_fn.clone());
            assert!(
                m.group_score(&more_ram, baseline) >= score,
                "more RAM lowered the score"
            );
            let worse_p95 = sample(ram, p95 + g.f64(0.0, 2_000.0), base.per_fn.clone());
            assert!(
                m.group_score(&worse_p95, baseline) >= score,
                "worse p95 lowered the score"
            );
        });
    }

    #[test]
    fn latency_term_disarmed_without_a_baseline() {
        let m = model(100.0);
        let s = sample(100.0, 10_000.0, vec![]);
        // NaN baseline -> only the RAM term remains (100/100 = 1.0)
        assert!((m.group_score(&s, f64::NAN) - 1.0).abs() < 1e-12);
        // improved latency clamps to zero rather than crediting the group
        assert!((m.group_score(&sample(100.0, 50.0, vec![]), 100.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn gbs_term_is_a_rate_over_the_window() {
        let m = model(1e9); // RAM term ~ 0
        let s = sample(
            0.0,
            f64::NAN,
            vec![attr("a", 0.0, f64::NAN, 3.0), attr("b", 0.0, f64::NAN, 1.0)],
        );
        // 4 GiB-s over a 2 s window = 2.0
        assert!((m.group_score(&s, f64::NAN) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn ram_reference_falls_back_when_cap_unset() {
        let mut p = FusionParams::default_enabled();
        p.split_policy = SplitPolicyKind::CostModel;
        p.max_group_ram_mb = 0.0;
        p.cost.ram_ref_mb = 512.0;
        let m = CostModel::from_params(&p);
        assert!((m.group_score(&sample(512.0, f64::NAN, vec![]), f64::NAN) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn heaviest_picks_dominant_member() {
        let m = model(200.0);
        let s = sample(
            400.0,
            f64::NAN,
            vec![
                attr("light", 40.0, 10.0, 0.1),
                attr("heavy", 320.0, 90.0, 2.0),
                attr("mid", 40.0, 20.0, 0.2),
            ],
        );
        assert_eq!(m.heaviest(&s).as_deref(), Some("heavy"));
        let scores = m.fn_scores(&s);
        assert_eq!(scores[0].0, "heavy");
        assert!(scores[0].1 > scores[1].1);
    }

    #[test]
    fn heaviest_ties_break_toward_smallest_name() {
        let m = model(200.0);
        // identical attribution -> deterministic lexicographic winner
        let s = sample(
            100.0,
            f64::NAN,
            vec![attr("zeta", 50.0, 30.0, 1.0), attr("alpha", 50.0, 30.0, 1.0)],
        );
        assert_eq!(m.heaviest(&s).as_deref(), Some("alpha"));
        // all-zero attribution (e.g. an idle window) is still deterministic
        let idle = sample(
            100.0,
            f64::NAN,
            vec![attr("b", 0.0, f64::NAN, 0.0), attr("a", 0.0, f64::NAN, 0.0)],
        );
        assert_eq!(m.heaviest(&idle).as_deref(), Some("a"));
        assert_eq!(m.heaviest(&sample(1.0, f64::NAN, vec![])), None);
    }

    #[test]
    fn disarmed_below_zero_threshold() {
        let mut p = FusionParams::default_enabled();
        p.cost.evict_threshold = 0.0;
        assert!(!CostModel::from_params(&p).armed());
        p.cost.evict_threshold = 2.0;
        assert!(CostModel::from_params(&p).armed());
    }
}
