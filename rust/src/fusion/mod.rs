//! Fusion decision layer: the call-graph observation store and the
//! admission policy.
//!
//! The Function Handler reports every *remote synchronous* call it observes
//! (paper §3: detected via blocking outbound sockets).  Once a (caller,
//! callee) pair crosses the observation threshold — and passes trust-domain,
//! cooldown, and group-size checks — a [`FusionRequest`] is emitted to the
//! Merger.  The observer also maintains the empirically discovered call
//! graph, which `provuse apps --observed` can dump.

use std::cell::RefCell;
use std::collections::{BTreeMap, HashMap, HashSet};

use crate::apps::AppSpec;
use crate::config::FusionParams;
use crate::error::Result;
use crate::exec;
use crate::exec::channel::Sender;

/// A request for the Merger to fuse the instances hosting two functions.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FusionRequest {
    pub caller: String,
    pub callee: String,
}

/// Shared observation store + policy gate.
pub struct Observer {
    policy: FusionParams,
    /// fn name -> trust domain (from the app spec)
    trust: HashMap<String, String>,
    state: RefCell<ObserverState>,
    tx: Sender<FusionRequest>,
}

#[derive(Default)]
struct ObserverState {
    /// sync-call observation counts per (caller, callee)
    counts: BTreeMap<(String, String), u64>,
    /// pairs already submitted to the merger (suppress duplicates)
    requested: HashSet<(String, String)>,
    /// virtual-time (ms) before which a pair may not be re-requested
    cooldown_until: HashMap<(String, String), f64>,
}

impl Observer {
    pub fn new(policy: FusionParams, app: &AppSpec, tx: Sender<FusionRequest>) -> Self {
        let trust = app
            .functions()
            .map(|f| (f.name.clone(), f.trust_domain.clone()))
            .collect();
        Observer { policy, trust, state: RefCell::new(ObserverState::default()), tx }
    }

    pub fn policy(&self) -> &FusionParams {
        &self.policy
    }

    /// Record one observed remote synchronous call; may emit a
    /// [`FusionRequest`] if the policy admits the pair.
    pub fn observe_sync_call(&self, caller: &str, callee: &str) {
        let key = (caller.to_string(), callee.to_string());
        let mut s = self.state.borrow_mut();
        let count = {
            let c = s.counts.entry(key.clone()).or_insert(0);
            *c += 1;
            *c
        };
        if !self.policy.enabled {
            return;
        }
        if count < self.policy.min_observations as u64 {
            return;
        }
        if s.requested.contains(&key) {
            return;
        }
        if let Some(&until) = s.cooldown_until.get(&key) {
            if exec::now().as_millis_f64() < until {
                return;
            }
        }
        if self.policy.respect_trust_domains {
            let (ta, tb) = (self.trust.get(caller), self.trust.get(callee));
            if ta.is_none() || tb.is_none() || ta != tb {
                return;
            }
        }
        s.requested.insert(key.clone());
        drop(s);
        // Receiver gone (merger shut down) is benign: fusion simply stops.
        let _ = self.tx.send(FusionRequest { caller: key.0, callee: key.1 });
    }

    /// Merger feedback: the pair's fusion failed — re-allow after cooldown.
    pub fn fusion_failed(&self, caller: &str, callee: &str) {
        let key = (caller.to_string(), callee.to_string());
        let mut s = self.state.borrow_mut();
        s.requested.remove(&key);
        s.cooldown_until
            .insert(key, exec::now().as_millis_f64() + self.policy.cooldown_ms);
    }

    /// Merger feedback: the pair is now colocated; further observations of
    /// this pair are inline calls and will not be reported anyway.
    pub fn fusion_succeeded(&self, caller: &str, callee: &str) {
        let key = (caller.to_string(), callee.to_string());
        self.state.borrow_mut().requested.insert(key);
    }

    /// Observation count of a pair.
    pub fn count(&self, caller: &str, callee: &str) -> u64 {
        self.state
            .borrow()
            .counts
            .get(&(caller.to_string(), callee.to_string()))
            .copied()
            .unwrap_or(0)
    }

    /// The empirically observed call graph, sorted.
    pub fn observed_graph(&self) -> Vec<((String, String), u64)> {
        self.state.borrow().counts.iter().map(|(k, v)| (k.clone(), *v)).collect()
    }
}

/// Validate a proposed fused group against the policy (used by the Merger
/// before committing to a build).
pub fn admit_group(policy: &FusionParams, group_size: usize) -> Result<()> {
    if policy.max_group_size > 0 && group_size > policy.max_group_size {
        return Err(crate::error::Error::FusionAborted(format!(
            "group size {group_size} exceeds max {}",
            policy.max_group_size
        )));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;
    use crate::exec::channel::{mpsc, Receiver};
    use crate::exec::run_virtual;

    fn observer(policy: FusionParams) -> (Observer, Receiver<FusionRequest>) {
        let (tx, rx) = mpsc();
        let app = apps::tree();
        (Observer::new(policy, &app, tx), rx)
    }

    #[test]
    fn threshold_gates_requests() {
        run_virtual(async {
            let (obs, mut rx) = observer(FusionParams::default_enabled());
            obs.observe_sync_call("a", "b");
            obs.observe_sync_call("a", "b");
            assert!(rx.try_recv().is_none(), "below threshold");
            obs.observe_sync_call("a", "b");
            assert_eq!(
                rx.try_recv(),
                Some(FusionRequest { caller: "a".into(), callee: "b".into() })
            );
            // no duplicate request
            obs.observe_sync_call("a", "b");
            assert!(rx.try_recv().is_none());
            assert_eq!(obs.count("a", "b"), 4);
        });
    }

    #[test]
    fn disabled_policy_never_requests() {
        run_virtual(async {
            let (obs, mut rx) = observer(FusionParams::disabled());
            for _ in 0..10 {
                obs.observe_sync_call("a", "b");
            }
            assert!(rx.try_recv().is_none());
            assert_eq!(obs.count("a", "b"), 10); // still observes
        });
    }

    #[test]
    fn trust_domain_mismatch_blocks() {
        run_virtual(async {
            let (tx, mut rx) = mpsc();
            let app = apps::AppSpec::builder("t")
                .function("a").entry().trust_domain("x").sync_call("b").done()
                .function("b").trust_domain("y").done()
                .build()
                .unwrap();
            let obs = Observer::new(FusionParams::default_enabled(), &app, tx);
            for _ in 0..5 {
                obs.observe_sync_call("a", "b");
            }
            assert!(rx.try_recv().is_none());
        });
    }

    #[test]
    fn cooldown_after_failure() {
        run_virtual(async {
            let (obs, mut rx) = observer(FusionParams::default_enabled());
            for _ in 0..3 {
                obs.observe_sync_call("a", "b");
            }
            assert!(rx.try_recv().is_some());
            obs.fusion_failed("a", "b");
            // immediately re-observed: still cooling down
            obs.observe_sync_call("a", "b");
            assert!(rx.try_recv().is_none());
            crate::exec::sleep_ms(10_001.0).await;
            obs.observe_sync_call("a", "b");
            assert!(rx.try_recv().is_some());
        });
    }

    #[test]
    fn group_size_admission() {
        let mut p = FusionParams::default_enabled();
        assert!(admit_group(&p, 100).is_ok());
        p.max_group_size = 3;
        assert!(admit_group(&p, 3).is_ok());
        assert!(admit_group(&p, 4).is_err());
    }

    #[test]
    fn observed_graph_sorted() {
        run_virtual(async {
            let (obs, _rx) = observer(FusionParams::disabled());
            obs.observe_sync_call("b", "d");
            obs.observe_sync_call("a", "b");
            obs.observe_sync_call("a", "b");
            let g = obs.observed_graph();
            assert_eq!(g[0].0, ("a".into(), "b".into()));
            assert_eq!(g[0].1, 2);
            assert_eq!(g[1].0, ("b".into(), "d".into()));
        });
    }
}
