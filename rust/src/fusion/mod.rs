//! Fusion decision layer: call-graph observation store, admission policy,
//! and the **feedback-driven defusion controller**.
//!
//! The Function Handler reports every *remote synchronous* call it observes
//! (paper §3: detected via blocking outbound sockets).  Once a (caller,
//! callee) pair crosses the observation threshold — and passes trust-domain,
//! cooldown, and group-size checks — a [`FusionRequest::Fuse`] is emitted to
//! the Merger.
//!
//! Fusion is no longer one-way: the platform's controller loop periodically
//! hands the Observer a [`GroupSample`] per live fused instance (RAM
//! attribution + trailing-window p95), and the Observer closes the loop à la
//! Fusionize/Fusionize++: a group that exceeds the configured RAM cap
//! (`FusionParams::max_group_ram_mb`) or regresses p95 latency past the
//! hysteresis threshold for `split_hysteresis_windows` consecutive windows
//! gets a [`FusionRequest::Split`].  After a completed split every pair in
//! the group enters cooldown so fuse ∧ split cannot flap.
//!
//! With [`crate::config::SplitPolicyKind::CostModel`] the two-threshold
//! check is replaced by a single weighted objective (see [`cost`]) over
//! per-function attribution, and a violating group sheds only its
//! **heaviest** member via [`FusionRequest::Evict`] — a partial split.
//!
//! Admission is symmetric since the merge-side planner
//! ([`crate::config::MergePolicyKind::CostModel`]): past the observation
//! threshold a candidate pair is *scored* with
//! [`cost::CostModel::predict_merge`] over the latest per-function window
//! signals the platform tick feeds in via [`Observer::update_fn_signals`],
//! and the Fuse request is emitted only when the predicted net benefit
//! clears `merge_threshold` — refused pairs are re-scored every window as
//! traffic evolves.  With `auto_tune` on, an admitted fuse that the
//! defusion controller takes back apart within one cooldown of its cutover
//! is a **regret**: the [`cost::AutoTuner`] hill-climbs the three weights
//! the way that would have refused it.
//!
//! The observer also maintains the empirically discovered call graph, which
//! `provuse apps --observed` can dump.

pub mod cost;
pub mod plan;

use std::cell::RefCell;
use std::collections::{BTreeMap, HashMap, HashSet};

use crate::apps::AppSpec;
use crate::cluster::NodeId;
use crate::config::{FusionParams, MergePolicyKind, PlannerKind, SplitPolicyKind};
use crate::error::Result;
use crate::exec;
use crate::exec::channel::Sender;
use crate::metrics::{AdmissionSample, Recorder, RegretSample};
use crate::util::intern::Sym;

pub use cost::{FnSignals, MergeContext, MergeDecision};
pub use plan::{Plan, PlanAction, PlanSnapshot};

use cost::{AutoTuner, CostModel};

/// A request for the Merger: consolidate two functions' instances, break a
/// fused group back apart, evict a single member from a fused group, or
/// move an instance to another node.
#[derive(Debug, Clone, PartialEq)]
pub enum FusionRequest {
    /// Fuse the instances hosting `caller` and `callee`.
    Fuse { caller: String, callee: String },
    /// Split the fused instance hosting exactly `functions` (sorted) back
    /// into one instance per function.
    Split {
        functions: Vec<String>,
        reason: SplitReason,
    },
    /// Partial split: redeploy only `function` from its original image and
    /// shrink the fused instance hosting exactly `functions` (sorted) in
    /// place — the remainder of the group stays fused.
    Evict {
        functions: Vec<String>,
        function: String,
        reason: SplitReason,
    },
    /// Live-migrate the instance hosting exactly `functions` (sorted) to
    /// node `to` — the node-pressure controller's cheaper alternative to
    /// defusing (no image build, the fusion wins survive the move).
    Migrate { functions: Vec<String>, to: NodeId },
    /// A whole plan-diff from the global re-planner (`--planner global`):
    /// an ordered action list the Merger executes atomically-or-aborts
    /// under the plan's snapshot-epoch guard.
    Plan(plan::Plan),
}

/// Which policy violation triggered a defusion.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SplitReason {
    /// The group's RAM footprint exceeded `max_group_ram_mb`.
    RamCap,
    /// The group's trailing-window p95 regressed past the pre-fusion
    /// baseline by more than `split_p95_regression`.
    LatencyRegression,
    /// The cost model's weighted objective crossed `evict_threshold`.
    CostModel,
    /// The hosting node exceeded its RAM capacity and no migration target
    /// could absorb any of its instances.
    NodePressure,
}

impl SplitReason {
    /// Stable snake_case label for event/CSV exports.
    pub fn name(&self) -> &'static str {
        match self {
            SplitReason::RamCap => "ram_cap",
            SplitReason::LatencyRegression => "latency_regression",
            SplitReason::CostModel => "cost_model",
            SplitReason::NodePressure => "node_pressure",
        }
    }
}

/// One node's load in the controller's latest cluster view (merge-planner
/// input: prices cross-node co-location and its capacity gate).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NodeLoad {
    /// node id
    pub node: NodeId,
    /// live RAM on the node (MiB)
    pub ram_mb: f64,
    /// capacity (MiB); 0 = uncapped
    pub capacity_mb: f64,
}

/// One controller observation of a node (produced every feedback tick on
/// capped multi-node clusters): aggregate pressure plus the healthy
/// instances that are candidates for relief.
#[derive(Debug, Clone, PartialEq)]
pub struct NodeSample {
    /// node id
    pub node: NodeId,
    /// live RAM on the node (MiB)
    pub ram_mb: f64,
    /// capacity (MiB); 0 = uncapped (never pressured)
    pub capacity_mb: f64,
    /// healthy instances on the node: (sorted active functions, ram MiB)
    pub instances: Vec<(Vec<String>, f64)>,
}

/// Per-function attribution inside one fused group, gathered by the
/// platform's controller tick (handler latency series + RAM shares + the
/// billing ledger's trailing window).
#[derive(Debug, Clone, PartialEq)]
pub struct FnAttribution {
    /// member function name
    pub function: String,
    /// attributed RAM (MiB): code footprint + an equal share of the base
    /// runtime and in-flight working sets; group members sum to the
    /// instance's RAM
    pub ram_mb: f64,
    /// p95 handler self-time over the trailing window (ms); NaN when the
    /// window had too few samples
    pub p95_ms: f64,
    /// billed GiB-seconds attributed to this function in the window
    pub gb_seconds: f64,
}

/// One controller observation of a live fused group (produced by the
/// platform's feedback loop each `feedback_interval_ms`).
#[derive(Debug, Clone, PartialEq)]
pub struct GroupSample {
    /// sorted function names hosted by the fused instance
    pub functions: Vec<String>,
    /// instantaneous RAM of the fused instance (MiB)
    pub ram_mb: f64,
    /// p95 end-to-end latency over the trailing feedback window (ms);
    /// NaN when the window had too few samples to be meaningful
    pub window_p95_ms: f64,
    /// trailing window length (seconds) the per-function attribution was
    /// gathered over
    pub window_s: f64,
    /// per-function attribution (empty under the threshold policy, which
    /// only needs the group aggregates)
    pub per_fn: Vec<FnAttribution>,
}

/// Shared observation store + policy gate + defusion feedback state.
///
/// Pair-keyed state is interned (ISSUE 5): `observe_sync_call` runs once
/// per remote sync call — the hottest fusion-layer path — and with
/// `(Sym, Sym)` keys the per-call bookkeeping is a map probe on two
/// `u32`s, no `String` clones.
pub struct Observer {
    policy: FusionParams,
    /// fn name -> trust domain (from the app spec)
    trust: HashMap<Sym, String>,
    state: RefCell<ObserverState>,
    tx: Sender<FusionRequest>,
    /// admission/regret telemetry sink (the platform's recorder; a private
    /// one in stand-alone tests)
    metrics: Recorder,
}

#[derive(Default)]
struct ObserverState {
    /// sync-call observation counts per (caller, callee)
    counts: BTreeMap<(Sym, Sym), u64>,
    /// pairs already submitted to the merger (suppress duplicates)
    requested: HashSet<(Sym, Sym)>,
    /// virtual-time (ms) before which a pair may not be re-requested
    cooldown_until: HashMap<(Sym, Sym), f64>,
    /// feedback accounting per live fused group (key: sorted functions)
    groups: BTreeMap<Vec<String>, GroupFeedback>,
    /// latest windowed per-function signals (merge planner input, set by
    /// the platform tick each feedback window)
    fn_signals: HashMap<Sym, FnSignals>,
    /// latest per-node loads (merge planner's placement context; empty on
    /// single-node platforms — every pair is then treated as co-located)
    node_loads: Vec<NodeLoad>,
    /// predicted one-off migration cost (ms) the platform derived from its
    /// boot + health-gate calibration
    migration_est_ms: f64,
    /// consecutive feedback windows each node spent over capacity
    node_strikes: HashMap<u64, u32>,
    /// per-node backoff after a completed/failed pressure resolution
    node_retry_after_ms: HashMap<u64, f64>,
    /// pressure migrations in flight: sorted group -> source node
    pending_migrations: HashMap<Vec<String>, u64>,
    /// groups that recently migrated (anti ping-pong): group -> until (ms)
    migrate_cooldown_until: HashMap<Vec<String>, f64>,
    /// bumped on every signals update; each pair is re-scored at most once
    /// per version (hot pairs observe thousands of calls per window)
    signals_version: u64,
    /// per-pair admission memo: (version scored at, verdict)
    admission_memo: HashMap<(Sym, Sym), (u64, bool)>,
    /// most recent admission score per pair (introspection)
    admission_scores: HashMap<(Sym, Sym), f64>,
    /// cost-admitted fuses awaiting the regret verdict
    pending_fuses: HashMap<(Sym, Sym), PendingFuse>,
    /// total defusion-within-cooldown regrets observed
    regret_count: u64,
    /// online weight tuner (Some only under CostModel merge policy with
    /// auto_tune on)
    tuner: Option<AutoTuner>,
    /// bumped on every completed topology change (fuse/split/evict/migrate)
    /// — the global planner's stale-plan guard: a plan emitted at epoch E
    /// aborts as soon as the live epoch disagrees with its expectation
    topology_epoch: u64,
}

/// A cost-admitted fuse awaiting its regret verdict.
#[derive(Debug, Clone, Copy)]
struct PendingFuse {
    /// admission time, replaced by the cutover instant once the merge
    /// completes — the regret window runs from the cutover, not from the
    /// admission (the pipeline's queue/build/boot time is not the
    /// planner's fault)
    at_ms: f64,
    /// the merge pipeline confirmed the cutover (`fusion_succeeded`)
    cutover: bool,
}

/// Per-group controller state.
struct GroupFeedback {
    /// p95 over the regime *before* this group (or its earliest fused
    /// ancestor) was created; NaN = unknown (latency check disabled)
    baseline_p95_ms: f64,
    /// virtual time (ms) the baseline was captured — earliest wins when
    /// groups grow transitively, keeping the baseline anchored to the
    /// closest-to-vanilla regime
    recorded_at_ms: f64,
    /// consecutive feedback windows over the RAM cap
    ram_strikes: u32,
    /// consecutive feedback windows past the latency-regression threshold
    latency_strikes: u32,
    /// consecutive feedback windows over the cost model's evict threshold
    cost_strikes: u32,
    /// most recent cost-model objective value (NaN until the first tick)
    last_score: f64,
    /// a split/evict request is in flight for this group
    split_pending: bool,
    /// virtual time (ms) before which no new split may be requested
    /// (set after a failed/aborted split)
    retry_after_ms: f64,
}

impl GroupFeedback {
    fn new(baseline_p95_ms: f64, recorded_at_ms: f64) -> Self {
        GroupFeedback {
            baseline_p95_ms,
            recorded_at_ms,
            ram_strikes: 0,
            latency_strikes: 0,
            cost_strikes: 0,
            last_score: f64::NAN,
            split_pending: false,
            retry_after_ms: 0.0,
        }
    }
}

impl Observer {
    /// An observer recording admission telemetry into a private recorder.
    pub fn new(policy: FusionParams, app: &AppSpec, tx: Sender<FusionRequest>) -> Self {
        Self::with_metrics(policy, app, tx, Recorder::new())
    }

    /// Like [`Observer::new`], but admission scores and auto-tune regrets
    /// land in the platform's shared recorder instead of a private one.
    pub fn with_metrics(
        policy: FusionParams,
        app: &AppSpec,
        tx: Sender<FusionRequest>,
        metrics: Recorder,
    ) -> Self {
        let trust = app
            .functions()
            .map(|f| (Sym::intern(&f.name), f.trust_domain.clone()))
            .collect();
        let mut state = ObserverState::default();
        if policy.merge_policy == MergePolicyKind::CostModel && policy.auto_tune {
            state.tuner = Some(AutoTuner::new(&policy.cost));
        }
        Observer { policy, trust, state: RefCell::new(state), tx, metrics }
    }

    /// The fusion policy this observer enforces.
    pub fn policy(&self) -> &FusionParams {
        &self.policy
    }

    /// Record one observed remote synchronous call; may emit a
    /// [`FusionRequest::Fuse`] if the policy admits the pair.  String
    /// convenience wrapper over [`Observer::observe_sync_call_sym`].
    pub fn observe_sync_call(&self, caller: &str, callee: &str) {
        self.observe_sync_call_sym(Sym::intern(caller), Sym::intern(callee));
    }

    /// The interned hot path the Function Handler calls once per remote
    /// sync call: all bookkeeping is `(Sym, Sym)`-keyed, no allocation at
    /// steady state.
    pub fn observe_sync_call_sym(&self, caller: Sym, callee: Sym) {
        let key = (caller, callee);
        let mut s = self.state.borrow_mut();
        let count = {
            let c = s.counts.entry(key).or_insert(0);
            *c += 1;
            *c
        };
        if !self.policy.enabled {
            return;
        }
        // Under the global planner the greedy pairwise path only *observes*
        // (the counts feed the planner's snapshot); all topology changes
        // come from periodic plan-diffs.
        if self.policy.planner == PlannerKind::Global {
            return;
        }
        if count < self.policy.min_observations as u64 {
            return;
        }
        if s.requested.contains(&key) {
            return;
        }
        if let Some(&until) = s.cooldown_until.get(&key) {
            if exec::now().as_millis_f64() < until {
                return;
            }
        }
        if self.policy.respect_trust_domains {
            let (ta, tb) = (self.trust.get(&caller), self.trust.get(&callee));
            if ta.is_none() || tb.is_none() || ta != tb {
                return;
            }
        }
        // merge-side admission planner: past the observation threshold the
        // pair must also *pay for itself* under the predicted cost
        // objective (refusals are not final — the pair is re-scored once
        // per feedback window as its signals evolve)
        if self.policy.merge_policy == MergePolicyKind::CostModel
            && !self.admit_merge(&mut s, caller, callee)
        {
            return;
        }
        s.requested.insert(key);
        drop(s);
        // Receiver gone (merger shut down) is benign: fusion simply stops.
        let _ = self.tx.send(FusionRequest::Fuse {
            caller: caller.as_str().to_string(),
            callee: callee.as_str().to_string(),
        });
    }

    /// Score one candidate pair against the latest window signals; memoized
    /// per signals version so hot pairs cost one evaluation per window.
    fn admit_merge(&self, s: &mut ObserverState, caller: Sym, callee: Sym) -> bool {
        let key = (caller, callee);
        if let Some(&(version, verdict)) = s.admission_memo.get(&key) {
            if version == s.signals_version {
                return verdict;
            }
        }
        let version = s.signals_version;
        let caller_sig = s.fn_signals.get(&caller).cloned();
        let callee_sig = s.fn_signals.get(&callee).cloned();
        let (Some(caller_sig), Some(callee_sig)) = (caller_sig, callee_sig) else {
            // the controller tick has not produced signals yet: refuse for
            // now, the next window re-scores
            s.admission_memo.insert(key, (version, false));
            return false;
        };
        let (w_latency, w_ram, w_gbs) = match &s.tuner {
            Some(t) => t.weights(),
            None => (self.policy.cost.w_latency, self.policy.cost.w_ram, self.policy.cost.w_gbs),
        };
        let model = CostModel::from_params(&self.policy).with_weights(w_latency, w_ram, w_gbs);
        let ctx = self.merge_context(s, &caller_sig, &callee_sig, caller, callee);
        let decision =
            model.predict_merge(&caller_sig, &callee_sig, self.policy.cost.merge_threshold, &ctx);
        self.metrics.record_admission(AdmissionSample {
            t_ms: self.metrics.rel_now_ms(),
            caller: caller.as_str().to_string(),
            callee: callee.as_str().to_string(),
            score: decision.score,
            admitted: decision.admit,
        });
        s.admission_scores.insert(key, decision.score);
        s.admission_memo.insert(key, (version, decision.admit));
        if decision.admit {
            s.pending_fuses.insert(
                key,
                PendingFuse { at_ms: exec::now().as_millis_f64(), cutover: false },
            );
        }
        decision.admit
    }

    /// Placement context for one admission evaluation: the callee's share
    /// of the caller's observed outbound sync calls (satellite of ISSUE 4:
    /// stop pricing the caller's whole blocked time against every callee)
    /// plus the cluster-side co-location facts — already colocated, or the
    /// predicted migration cost and the target node's post-move headroom.
    fn merge_context(
        &self,
        s: &ObserverState,
        caller_sig: &FnSignals,
        callee_sig: &FnSignals,
        caller: Sym,
        callee: Sym,
    ) -> MergeContext {
        // The share denominator counts only callees that are still REMOTE:
        // a callee already fused with the caller stopped being observed
        // (its calls are inline), but its historical counts would sit in
        // the denominator forever and underprice every later pair — while
        // the blocked-time rate this share scales is a trailing-window
        // signal that only ever contains the remaining remote waits.
        let caller_name = caller.as_str();
        // interned once up front: the counts loop below must compare plain
        // integers, not take the interner lock per entry
        let caller_group: Option<Vec<Sym>> = s
            .groups
            .keys()
            .find(|k| k.iter().any(|f| f == caller_name))
            .map(|g| g.iter().map(|f| Sym::intern(f)).collect());
        let outbound: u64 = s
            .counts
            .iter()
            .filter(|((a, b), _)| {
                *a == caller
                    && !caller_group.as_ref().map(|g| g.contains(b)).unwrap_or(false)
            })
            .map(|(_, n)| *n)
            .sum();
        let pair = s.counts.get(&(caller, callee)).copied().unwrap_or(0);
        let callee_share = if outbound > 0 { pair as f64 / outbound as f64 } else { 1.0 };
        let (colocated, target_headroom_mb) = match (caller_sig.node, callee_sig.node) {
            (Some(a), Some(b)) if a != b => {
                // moving the callee's instance onto the caller's node adds
                // the callee's attributed RAM there
                let headroom = s
                    .node_loads
                    .iter()
                    .find(|l| l.node == a)
                    .map(|l| {
                        if l.capacity_mb <= 0.0 {
                            f64::INFINITY
                        } else {
                            l.capacity_mb - l.ram_mb - callee_sig.ram_mb.max(0.0)
                        }
                    })
                    .unwrap_or(f64::INFINITY);
                (false, headroom)
            }
            // same node, or no cluster view (single-node legacy)
            _ => (true, f64::INFINITY),
        };
        MergeContext {
            callee_share,
            colocated,
            migration_ms: if colocated { 0.0 } else { s.migration_est_ms },
            target_headroom_mb,
            // the fused set deploys at the busier endpoint's replica
            // count, so every replica pays the combined working set
            replica_scale: caller_sig.replicas.max(callee_sig.replicas).max(1) as f64,
        }
    }

    /// Platform tick input on multi-node clusters: per-node loads and the
    /// calibrated one-off migration cost estimate, refreshed every
    /// feedback window alongside the function signals.
    pub fn update_cluster_view(&self, loads: Vec<NodeLoad>, migration_est_ms: f64) {
        let mut s = self.state.borrow_mut();
        s.node_loads = loads;
        s.migration_est_ms = migration_est_ms;
    }

    /// Platform tick input: fresh windowed signals for every routed
    /// function, fused or not.  Doubles as the regret clock: cost-admitted
    /// fuses that outlived one cooldown without being defused count as
    /// survivals and decay the tuned weights back toward the priors.
    pub fn update_fn_signals(&self, signals: Vec<FnSignals>) {
        let now = exec::now().as_millis_f64();
        let mut s = self.state.borrow_mut();
        s.signals_version += 1;
        s.fn_signals = signals.into_iter().map(|f| (f.function, f)).collect();
        // time-based recovery: a regret streak that locks admission out
        // would otherwise never see a survival to decay the weights back
        if let Some(t) = s.tuner.as_mut() {
            t.on_window();
        }
        let cooldown = self.policy.cooldown_ms;
        let expired: Vec<((Sym, Sym), PendingFuse)> = s
            .pending_fuses
            .iter()
            .filter(|(_, p)| {
                // survivals count from the CUTOVER; an admission whose
                // pipeline never confirmed (aborted as already-colocated,
                // or still queued for pathologically long) gets no verdict
                // and is pruned after a generous horizon
                (p.cutover && now - p.at_ms > cooldown)
                    || (!p.cutover && now - p.at_ms > 10.0 * cooldown)
            })
            .map(|(k, p)| (*k, *p))
            .collect();
        for (key, pending) in expired {
            s.pending_fuses.remove(&key);
            if pending.cutover {
                if let Some(t) = s.tuner.as_mut() {
                    t.on_survival();
                }
            }
        }
    }

    /// Regret scan after a completed defusion of `functions`: every
    /// cost-admitted pair the defusion tears apart — both members in the
    /// group and, for an eviction, one of them the evicted function —
    /// within one cooldown of its fuse penalizes the weights that admitted
    /// it (`evicted = None` means a whole-group split).
    fn note_defusion_regrets(
        &self,
        s: &mut ObserverState,
        functions: &[String],
        evicted: Option<&str>,
    ) {
        if self.policy.merge_policy != MergePolicyKind::CostModel {
            return;
        }
        let now = exec::now().as_millis_f64();
        // interned once: pending-fuse filtering compares integers
        let fn_syms: Vec<Sym> = functions.iter().map(|f| Sym::intern(f)).collect();
        let evicted_sym = evicted.map(Sym::intern);
        let affected: Vec<((Sym, Sym), PendingFuse)> = s
            .pending_fuses
            .iter()
            .filter(|((a, b), _)| {
                fn_syms.contains(a)
                    && fn_syms.contains(b)
                    && evicted_sym.map(|e| *a == e || *b == e).unwrap_or(true)
            })
            .map(|(k, p)| (*k, *p))
            .collect();
        for (key, pending) in affected {
            s.pending_fuses.remove(&key);
            if !pending.cutover {
                // this admission's own pipeline never confirmed a cutover
                // (e.g. aborted as already-colocated): no verdict either way
                continue;
            }
            if now - pending.at_ms > self.policy.cooldown_ms {
                // a defusion this long after the fuse is pressure drift,
                // not an admission mistake
                if let Some(t) = s.tuner.as_mut() {
                    t.on_survival();
                }
                continue;
            }
            s.regret_count += 1;
            let (w_latency, w_ram, w_gbs) = match s.tuner.as_mut() {
                Some(t) => {
                    t.on_regret();
                    t.weights()
                }
                // regret is telemetry even without the tuner: record the
                // (unchanged) configured weights
                None => (
                    self.policy.cost.w_latency,
                    self.policy.cost.w_ram,
                    self.policy.cost.w_gbs,
                ),
            };
            self.metrics.record_regret(RegretSample {
                t_ms: self.metrics.rel_now_ms(),
                caller: key.0.as_str().to_string(),
                callee: key.1.as_str().to_string(),
                w_latency,
                w_ram,
                w_gbs,
            });
        }
    }

    /// Merger feedback: the pair's fusion failed — re-allow after cooldown.
    pub fn fusion_failed(&self, caller: &str, callee: &str) {
        let key = (Sym::intern(caller), Sym::intern(callee));
        let mut s = self.state.borrow_mut();
        s.requested.remove(&key);
        // never fused: the admission gets no regret/survival verdict
        s.pending_fuses.remove(&key);
        s.cooldown_until
            .insert(key, exec::now().as_millis_f64() + self.policy.cooldown_ms);
    }

    /// Merger feedback: the pair is now colocated in the fused instance
    /// hosting `group`, whose pre-fusion p95 was `baseline_p95_ms` (NaN =
    /// too few samples; latency-triggered defusion stays disarmed).
    ///
    /// Further observations of the pair are inline calls and will not be
    /// reported anyway; the group enters feedback tracking.
    pub fn fusion_succeeded(
        &self,
        caller: &str,
        callee: &str,
        group: &[String],
        baseline_p95_ms: f64,
    ) {
        let now = exec::now().as_millis_f64();
        let mut s = self.state.borrow_mut();
        s.topology_epoch += 1;
        let pair = (Sym::intern(caller), Sym::intern(callee));
        s.requested.insert(pair);
        // the regret window runs from the cutover, not the admission (the
        // fuse pipeline's queue/build/boot time is not the planner's fault)
        if let Some(pending) = s.pending_fuses.get_mut(&pair) {
            pending.at_ms = now;
            pending.cutover = true;
        }

        let mut key: Vec<String> = group.to_vec();
        key.sort();
        // Transitive growth subsumes existing subgroups; inherit the
        // earliest baseline (closest to the vanilla regime).
        let mut baseline = baseline_p95_ms;
        let mut recorded = now;
        let subsumed: Vec<Vec<String>> = s
            .groups
            .keys()
            .filter(|k| k.iter().all(|f| key.contains(f)))
            .cloned()
            .collect();
        for k in subsumed {
            if let Some(old) = s.groups.remove(&k) {
                if old.baseline_p95_ms.is_finite() && old.recorded_at_ms < recorded {
                    recorded = old.recorded_at_ms;
                    baseline = old.baseline_p95_ms;
                }
            }
        }
        s.groups.insert(key, GroupFeedback::new(baseline, recorded));
    }

    /// Controller tick: evaluate every live fused group against the defusion
    /// policy once a violation has persisted for `split_hysteresis_windows`
    /// consecutive windows.
    ///
    /// * [`SplitPolicyKind::Threshold`] — PR 1 semantics, preserved verbatim:
    ///   RAM cap / p95 regression each tracked independently, whole-group
    ///   [`FusionRequest::Split`] on violation.
    /// * [`SplitPolicyKind::CostModel`] — one weighted objective (see
    ///   [`cost::CostModel`]); a violating group of three or more sheds its
    ///   heaviest member via [`FusionRequest::Evict`], a violating pair is
    ///   split whole (evicting from a pair and splitting it are the same
    ///   topology change, minus a pointlessly oversized instance).
    pub fn feedback(&self, samples: &[GroupSample]) {
        if !self.policy.enabled || !self.policy.defusion {
            return;
        }
        // Global planner: splits/evicts arrive via plan-diffs, not the
        // greedy per-group strike counters.
        if self.policy.planner == PlannerKind::Global {
            return;
        }
        match self.policy.split_policy {
            SplitPolicyKind::Threshold => self.feedback_threshold(samples),
            SplitPolicyKind::CostModel => self.feedback_cost(samples),
        }
    }

    /// PR 1's two-threshold policy (the `Threshold` fallback).
    fn feedback_threshold(&self, samples: &[GroupSample]) {
        let now = exec::now().as_millis_f64();
        let hysteresis = self.policy.split_hysteresis_windows.max(1);
        let mut s = self.state.borrow_mut();
        for sample in samples {
            let mut key = sample.functions.clone();
            key.sort();
            let g = s
                .groups
                .entry(key.clone())
                .or_insert_with(|| GroupFeedback::new(f64::NAN, now));
            if g.split_pending || now < g.retry_after_ms {
                continue;
            }
            let over_ram =
                self.policy.max_group_ram_mb > 0.0 && sample.ram_mb > self.policy.max_group_ram_mb;
            g.ram_strikes = if over_ram { g.ram_strikes + 1 } else { 0 };
            let regressed = self.policy.split_p95_regression > 0.0
                && g.baseline_p95_ms.is_finite()
                && sample.window_p95_ms.is_finite()
                && sample.window_p95_ms
                    > g.baseline_p95_ms * (1.0 + self.policy.split_p95_regression);
            g.latency_strikes = if regressed { g.latency_strikes + 1 } else { 0 };

            let reason = if g.ram_strikes >= hysteresis {
                Some(SplitReason::RamCap)
            } else if g.latency_strikes >= hysteresis {
                Some(SplitReason::LatencyRegression)
            } else {
                None
            };
            if let Some(reason) = reason {
                g.split_pending = true;
                g.ram_strikes = 0;
                g.latency_strikes = 0;
                let _ = self.tx.send(FusionRequest::Split { functions: key, reason });
            }
        }
    }

    /// Cost-model policy: weighted objective + heaviest-member eviction.
    fn feedback_cost(&self, samples: &[GroupSample]) {
        let model = CostModel::from_params(&self.policy);
        if !model.armed() {
            return;
        }
        let now = exec::now().as_millis_f64();
        let hysteresis = self.policy.split_hysteresis_windows.max(1);
        let mut s = self.state.borrow_mut();
        for sample in samples {
            let mut key = sample.functions.clone();
            key.sort();
            let g = s
                .groups
                .entry(key.clone())
                .or_insert_with(|| GroupFeedback::new(f64::NAN, now));
            let score = model.group_score(sample, g.baseline_p95_ms);
            g.last_score = score;
            if g.split_pending || now < g.retry_after_ms {
                continue;
            }
            g.cost_strikes = if score >= model.evict_threshold() {
                g.cost_strikes + 1
            } else {
                0
            };
            if g.cost_strikes < hysteresis {
                continue;
            }
            g.split_pending = true;
            g.cost_strikes = 0;
            let request = match model.heaviest(sample) {
                Some(function) if key.len() > 2 => FusionRequest::Evict {
                    functions: key,
                    function,
                    reason: SplitReason::CostModel,
                },
                _ => FusionRequest::Split { functions: key, reason: SplitReason::CostModel },
            };
            let _ = self.tx.send(request);
        }
    }

    /// Controller tick on capped multi-node clusters: evaluate every node
    /// against its RAM capacity.  A node over capacity for
    /// `split_hysteresis_windows` consecutive windows gets **one**
    /// corrective action, preferring the cheap one:
    ///
    /// 1. **Migrate** — the largest instance that fits on another node is
    ///    moved there ([`FusionRequest::Migrate`]): no image work, fusion
    ///    wins survive, the pressure relief equals the instance footprint.
    /// 2. **Defuse** — when nothing movable fits anywhere, the node's
    ///    largest fused group is split ([`SplitReason::NodePressure`]),
    ///    shedding working sets the slow way.
    ///
    /// After a resolution (either kind, success or failure) the node backs
    /// off one cooldown before being re-evaluated, and a migrated group
    /// will not be re-migrated within a cooldown — the anti-ping-pong
    /// counterpart of the fuse/split anti-flap contract.
    pub fn node_feedback(&self, samples: &[NodeSample]) {
        if !self.policy.enabled {
            return;
        }
        // Global planner: node pressure is a capacity constraint inside the
        // partition search; the greedy one-action-per-episode path is off.
        if self.policy.planner == PlannerKind::Global {
            return;
        }
        let now = exec::now().as_millis_f64();
        let hysteresis = self.policy.split_hysteresis_windows.max(1);
        let mut s = self.state.borrow_mut();
        for sample in samples {
            let node = sample.node.0;
            let over = sample.capacity_mb > 0.0 && sample.ram_mb > sample.capacity_mb;
            if !over {
                s.node_strikes.insert(node, 0);
                continue;
            }
            if s.pending_migrations.values().any(|&n| n == node) {
                continue;
            }
            if s.node_retry_after_ms.get(&node).map(|&t| now < t).unwrap_or(false) {
                continue;
            }
            let strikes = s.node_strikes.entry(node).or_insert(0);
            *strikes += 1;
            if *strikes < hysteresis {
                continue;
            }

            // candidates, largest footprint first (one move, most relief)
            let mut candidates: Vec<&(Vec<String>, f64)> = sample.instances.iter().collect();
            candidates.sort_by(|a, b| {
                b.1.partial_cmp(&a.1).unwrap_or(std::cmp::Ordering::Equal).then(a.0.cmp(&b.0))
            });
            let migration = candidates.iter().find_map(|(fns, ram)| {
                if s.migrate_cooldown_until.get(fns).map(|&t| now < t).unwrap_or(false) {
                    return None;
                }
                // a group whose defusion is already queued will be gone by
                // the time a Migrate reaches the serialized Merger — the
                // staleness abort would burn this node's retry budget for
                // nothing, so skip it and let the split do the relieving
                if s.groups.get(fns).map(|g| g.split_pending).unwrap_or(false) {
                    return None;
                }
                // best target: the other node with the most headroom that
                // still fits this instance
                samples
                    .iter()
                    .filter(|other| other.node.0 != node)
                    .map(|other| {
                        let headroom = if other.capacity_mb <= 0.0 {
                            f64::INFINITY
                        } else {
                            other.capacity_mb - other.ram_mb
                        };
                        (other.node, headroom)
                    })
                    .filter(|(_, headroom)| *headroom >= *ram)
                    .max_by(|a, b| {
                        a.1.partial_cmp(&b.1)
                            .unwrap_or(std::cmp::Ordering::Equal)
                            .then(b.0.cmp(&a.0))
                    })
                    .map(|(to, _)| (fns.clone(), to))
            });

            if let Some((functions, to)) = migration {
                s.node_strikes.insert(node, 0);
                s.pending_migrations.insert(functions.clone(), node);
                let _ = self.tx.send(FusionRequest::Migrate { functions, to });
                continue;
            }

            // nothing movable fits anywhere: defuse the largest fused group
            let fused = candidates.iter().find(|(fns, _)| fns.len() >= 2);
            match fused {
                Some((fns, _)) if self.policy.defusion => {
                    let key = fns.clone();
                    let g = s
                        .groups
                        .entry(key.clone())
                        .or_insert_with(|| GroupFeedback::new(f64::NAN, now));
                    if g.split_pending || now < g.retry_after_ms {
                        continue;
                    }
                    g.split_pending = true;
                    s.node_strikes.insert(node, 0);
                    s.node_retry_after_ms.insert(node, now + self.policy.cooldown_ms);
                    let _ = self.tx.send(FusionRequest::Split {
                        functions: key,
                        reason: SplitReason::NodePressure,
                    });
                }
                _ => {
                    // singleton-only node with nowhere to move: back off
                    // instead of re-scoring a hopeless node every window
                    s.node_strikes.insert(node, 0);
                    s.node_retry_after_ms.insert(node, now + self.policy.cooldown_ms);
                }
            }
        }
    }

    /// Merger feedback: the pressure migration of `functions` completed.
    /// The source node and the migrated group both enter cooldown so one
    /// over-capacity episode resolves with exactly one corrective action.
    pub fn migrate_succeeded(&self, functions: &[String]) {
        let now = exec::now().as_millis_f64();
        let mut s = self.state.borrow_mut();
        s.topology_epoch += 1;
        let mut key: Vec<String> = functions.to_vec();
        key.sort();
        if let Some(node) = s.pending_migrations.remove(&key) {
            s.node_retry_after_ms.insert(node, now + self.policy.cooldown_ms);
            s.node_strikes.insert(node, 0);
        }
        s.migrate_cooldown_until.insert(key, now + self.policy.cooldown_ms);
    }

    /// Merger feedback: the pressure migration failed/aborted — the source
    /// keeps serving; the node backs off one cooldown before retrying.
    pub fn migrate_failed(&self, functions: &[String]) {
        let now = exec::now().as_millis_f64();
        let mut s = self.state.borrow_mut();
        let mut key: Vec<String> = functions.to_vec();
        key.sort();
        if let Some(node) = s.pending_migrations.remove(&key) {
            s.node_retry_after_ms.insert(node, now + self.policy.cooldown_ms);
        }
    }

    /// Whether a pressure migration is currently in flight for `functions`
    /// (test/property introspection).
    pub fn migration_pending(&self, functions: &[String]) -> bool {
        let mut key: Vec<String> = functions.to_vec();
        key.sort();
        self.state.borrow().pending_migrations.contains_key(&key)
    }

    /// Merger feedback: the group was split back into per-function
    /// instances.  Every pair inside the group enters cooldown so the next
    /// observations cannot immediately re-fuse it (anti-flapping).
    pub fn split_succeeded(&self, functions: &[String]) {
        let now = exec::now().as_millis_f64();
        let mut s = self.state.borrow_mut();
        s.topology_epoch += 1;
        self.note_defusion_regrets(&mut s, functions, None);
        let mut key: Vec<String> = functions.to_vec();
        key.sort();
        s.groups.remove(&key);
        for a in functions {
            for b in functions {
                if a == b {
                    continue;
                }
                let pair = (Sym::intern(a), Sym::intern(b));
                s.requested.remove(&pair);
                s.cooldown_until.insert(pair, now + self.policy.cooldown_ms);
            }
        }
    }

    /// Merger feedback: the split failed/aborted — the fused instance keeps
    /// serving; retry no sooner than one cooldown from now.
    pub fn split_failed(&self, functions: &[String]) {
        let now = exec::now().as_millis_f64();
        let mut s = self.state.borrow_mut();
        let mut key: Vec<String> = functions.to_vec();
        key.sort();
        if let Some(g) = s.groups.get_mut(&key) {
            g.split_pending = false;
            g.retry_after_ms = now + self.policy.cooldown_ms;
        }
    }

    /// Merger feedback: `evicted` left the group and serves from its own
    /// instance; the remainder keeps its feedback history under the shrunk
    /// key.  Only the **evicted pairs** — (evicted, member) both ways —
    /// enter cooldown, so the surviving group is unaffected and the evicted
    /// function cannot be re-absorbed before the pressure verdict settles.
    pub fn evict_succeeded(&self, functions: &[String], evicted: &str) {
        let now = exec::now().as_millis_f64();
        let mut s = self.state.borrow_mut();
        s.topology_epoch += 1;
        self.note_defusion_regrets(&mut s, functions, Some(evicted));
        let mut key: Vec<String> = functions.to_vec();
        key.sort();
        let old = s.groups.remove(&key);
        let mut remaining = key;
        remaining.retain(|f| f != evicted);
        let evicted_sym = Sym::intern(evicted);
        for member in &remaining {
            let member_sym = Sym::intern(member);
            for pair in [(evicted_sym, member_sym), (member_sym, evicted_sym)] {
                s.requested.remove(&pair);
                s.cooldown_until.insert(pair, now + self.policy.cooldown_ms);
            }
        }
        if remaining.len() >= 2 {
            let mut g = match old {
                Some(old) => GroupFeedback::new(old.baseline_p95_ms, old.recorded_at_ms),
                None => GroupFeedback::new(f64::NAN, now),
            };
            g.last_score = f64::NAN;
            s.groups.insert(remaining, g);
        }
    }

    /// Merger feedback: the eviction failed/aborted — the fused instance
    /// keeps serving the whole group; retry after one cooldown.
    pub fn evict_failed(&self, functions: &[String]) {
        self.split_failed(functions);
    }

    /// Whether a (caller, callee) pair is currently inside a cooldown
    /// window (test/property introspection).
    pub fn pair_in_cooldown(&self, caller: &str, callee: &str) -> bool {
        self.state
            .borrow()
            .cooldown_until
            .get(&(Sym::intern(caller), Sym::intern(callee)))
            .map(|&until| exec::now().as_millis_f64() < until)
            .unwrap_or(false)
    }

    /// Most recent merge-admission score for a pair (NaN before any
    /// evaluation, or under the observation-count merge policy).
    pub fn admission_score(&self, caller: &str, callee: &str) -> f64 {
        self.state
            .borrow()
            .admission_scores
            .get(&(Sym::intern(caller), Sym::intern(callee)))
            .copied()
            .unwrap_or(f64::NAN)
    }

    /// Current merge weights: the auto-tuner's hill-climbed values when it
    /// is armed, the configured priors otherwise.
    pub fn merge_weights(&self) -> (f64, f64, f64) {
        match &self.state.borrow().tuner {
            Some(t) => t.weights(),
            None => (
                self.policy.cost.w_latency,
                self.policy.cost.w_ram,
                self.policy.cost.w_gbs,
            ),
        }
    }

    /// Total post-fuse regrets (admitted fuses defused within one cooldown
    /// of their cutover) observed so far.
    pub fn regret_count(&self) -> u64 {
        self.state.borrow().regret_count
    }

    /// Most recent cost-model objective for a fused group (NaN when
    /// untracked or before the first cost-policy tick).
    pub fn group_score(&self, functions: &[String]) -> f64 {
        let mut key: Vec<String> = functions.to_vec();
        key.sort();
        self.state
            .borrow()
            .groups
            .get(&key)
            .map(|g| g.last_score)
            .unwrap_or(f64::NAN)
    }

    /// Pre-fusion p95 baseline tracked for a fused group (test/report
    /// introspection); NaN when unknown or untracked.
    pub fn group_baseline_p95(&self, functions: &[String]) -> f64 {
        let mut key: Vec<String> = functions.to_vec();
        key.sort();
        self.state
            .borrow()
            .groups
            .get(&key)
            .map(|g| g.baseline_p95_ms)
            .unwrap_or(f64::NAN)
    }

    /// Observation count of a pair.
    pub fn count(&self, caller: &str, callee: &str) -> u64 {
        self.state
            .borrow()
            .counts
            .get(&(Sym::intern(caller), Sym::intern(callee)))
            .copied()
            .unwrap_or(0)
    }

    /// The empirically observed call graph, sorted by name.
    pub fn observed_graph(&self) -> Vec<((String, String), u64)> {
        let mut v: Vec<((String, String), u64)> = self
            .state
            .borrow()
            .counts
            .iter()
            .map(|((a, b), n)| ((a.as_str().to_string(), b.as_str().to_string()), *n))
            .collect();
        v.sort();
        v
    }

    /// Monotonic count of completed topology changes (fuse / split / evict
    /// / migrate).  The global planner stamps every plan with the epoch its
    /// snapshot was taken at; the executor aborts the remainder of a plan
    /// the moment the live epoch disagrees with its expectation.
    pub fn topology_epoch(&self) -> u64 {
        self.state.borrow().topology_epoch
    }

    /// Freeze the planner's world view: observed call graph, latest
    /// windowed per-function signals, live fused groups (any other
    /// observed function is an implicit singleton), node loads, trust
    /// domains, and the pairs still inside a fuse cooldown — stamped with
    /// the current topology epoch.
    pub fn plan_snapshot(&self) -> PlanSnapshot {
        let now = exec::now().as_millis_f64();
        let s = self.state.borrow();
        let mut signals: Vec<FnSignals> = s.fn_signals.values().cloned().collect();
        signals.sort_by(|a, b| a.function.as_str().cmp(b.function.as_str()));
        let mut edges: Vec<((String, String), u64)> = s
            .counts
            .iter()
            .map(|((a, b), n)| ((a.as_str().to_string(), b.as_str().to_string()), *n))
            .collect();
        edges.sort();
        let groups: Vec<Vec<String>> = s.groups.keys().cloned().collect();
        let mut cooling: Vec<(String, String)> = s
            .cooldown_until
            .iter()
            .filter(|&(_, &until)| now < until)
            .map(|((a, b), _)| (a.as_str().to_string(), b.as_str().to_string()))
            .collect();
        cooling.sort();
        let trust: BTreeMap<String, String> = self
            .trust
            .iter()
            .map(|(k, v)| (k.as_str().to_string(), v.clone()))
            .collect();
        PlanSnapshot {
            epoch: s.topology_epoch,
            signals,
            edges,
            groups,
            node_loads: s.node_loads.clone(),
            migration_est_ms: s.migration_est_ms,
            trust,
            cooling,
        }
    }

    /// Hand a plan-diff to the Merger for guarded execution.
    pub fn submit_plan(&self, plan: Plan) {
        let _ = self.tx.send(FusionRequest::Plan(plan));
    }
}

/// Validate a proposed fused group against the policy (used by the Merger
/// before committing to a build).
pub fn admit_group(policy: &FusionParams, group_size: usize) -> Result<()> {
    if policy.max_group_size > 0 && group_size > policy.max_group_size {
        return Err(crate::error::Error::FusionAborted(format!(
            "group size {group_size} exceeds max {}",
            policy.max_group_size
        )));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;
    use crate::exec::channel::{mpsc, Receiver};
    use crate::exec::run_virtual;

    fn observer(policy: FusionParams) -> (Observer, Receiver<FusionRequest>) {
        let (tx, rx) = mpsc();
        let app = apps::tree();
        (Observer::new(policy, &app, tx), rx)
    }

    fn fuse(caller: &str, callee: &str) -> FusionRequest {
        FusionRequest::Fuse { caller: caller.into(), callee: callee.into() }
    }

    fn sample(functions: &[&str], ram_mb: f64, p95: f64) -> GroupSample {
        GroupSample {
            functions: functions.iter().map(|s| s.to_string()).collect(),
            ram_mb,
            window_p95_ms: p95,
            window_s: 5.0,
            per_fn: Vec::new(),
        }
    }

    fn attr(function: &str, ram_mb: f64, p95_ms: f64, gb_seconds: f64) -> FnAttribution {
        FnAttribution { function: function.into(), ram_mb, p95_ms, gb_seconds }
    }

    fn attributed_sample(
        functions: &[&str],
        ram_mb: f64,
        per_fn: Vec<FnAttribution>,
    ) -> GroupSample {
        GroupSample {
            functions: functions.iter().map(|s| s.to_string()).collect(),
            ram_mb,
            window_p95_ms: f64::NAN,
            window_s: 5.0,
            per_fn,
        }
    }

    #[test]
    fn threshold_gates_requests() {
        run_virtual(async {
            let (obs, mut rx) = observer(FusionParams::default_enabled());
            obs.observe_sync_call("a", "b");
            obs.observe_sync_call("a", "b");
            assert!(rx.try_recv().is_none(), "below threshold");
            obs.observe_sync_call("a", "b");
            assert_eq!(rx.try_recv(), Some(fuse("a", "b")));
            // no duplicate request
            obs.observe_sync_call("a", "b");
            assert!(rx.try_recv().is_none());
            assert_eq!(obs.count("a", "b"), 4);
        });
    }

    #[test]
    fn disabled_policy_never_requests() {
        run_virtual(async {
            let (obs, mut rx) = observer(FusionParams::disabled());
            for _ in 0..10 {
                obs.observe_sync_call("a", "b");
            }
            assert!(rx.try_recv().is_none());
            assert_eq!(obs.count("a", "b"), 10); // still observes
        });
    }

    #[test]
    fn trust_domain_mismatch_blocks() {
        run_virtual(async {
            let (tx, mut rx) = mpsc();
            let app = apps::AppSpec::builder("t")
                .function("a").entry().trust_domain("x").sync_call("b").done()
                .function("b").trust_domain("y").done()
                .build()
                .unwrap();
            let obs = Observer::new(FusionParams::default_enabled(), &app, tx);
            for _ in 0..5 {
                obs.observe_sync_call("a", "b");
            }
            assert!(rx.try_recv().is_none());
        });
    }

    #[test]
    fn cooldown_after_failure() {
        run_virtual(async {
            let (obs, mut rx) = observer(FusionParams::default_enabled());
            for _ in 0..3 {
                obs.observe_sync_call("a", "b");
            }
            assert!(rx.try_recv().is_some());
            obs.fusion_failed("a", "b");
            // immediately re-observed: still cooling down
            obs.observe_sync_call("a", "b");
            assert!(rx.try_recv().is_none());
            crate::exec::sleep_ms(10_001.0).await;
            obs.observe_sync_call("a", "b");
            assert!(rx.try_recv().is_some());
        });
    }

    #[test]
    fn group_size_admission() {
        let mut p = FusionParams::default_enabled();
        assert!(admit_group(&p, 100).is_ok());
        p.max_group_size = 3;
        assert!(admit_group(&p, 3).is_ok());
        assert!(admit_group(&p, 4).is_err());
    }

    #[test]
    fn observed_graph_sorted() {
        run_virtual(async {
            let (obs, _rx) = observer(FusionParams::disabled());
            obs.observe_sync_call("b", "d");
            obs.observe_sync_call("a", "b");
            obs.observe_sync_call("a", "b");
            let g = obs.observed_graph();
            assert_eq!(g[0].0, ("a".into(), "b".into()));
            assert_eq!(g[0].1, 2);
            assert_eq!(g[1].0, ("b".into(), "d".into()));
        });
    }

    // -- defusion controller --------------------------------------------------

    fn defusion_policy() -> FusionParams {
        let mut p = FusionParams::default_enabled();
        p.max_group_ram_mb = 100.0;
        p.split_hysteresis_windows = 2;
        p.split_p95_regression = 0.5;
        p
    }

    #[test]
    fn ram_cap_violation_splits_after_hysteresis() {
        run_virtual(async {
            let (obs, mut rx) = observer(defusion_policy());
            let group = ["a".to_string(), "b".to_string()];
            obs.fusion_succeeded("a", "b", &group, 400.0);
            // one strike: not yet
            obs.feedback(&[sample(&["a", "b"], 150.0, f64::NAN)]);
            assert!(rx.try_recv().is_none());
            // second consecutive strike: split
            obs.feedback(&[sample(&["a", "b"], 150.0, f64::NAN)]);
            assert_eq!(
                rx.try_recv(),
                Some(FusionRequest::Split {
                    functions: vec!["a".into(), "b".into()],
                    reason: SplitReason::RamCap,
                })
            );
            // pending split suppresses duplicates
            obs.feedback(&[sample(&["a", "b"], 150.0, f64::NAN)]);
            assert!(rx.try_recv().is_none());
        });
    }

    #[test]
    fn transient_spike_resets_hysteresis() {
        run_virtual(async {
            let (obs, mut rx) = observer(defusion_policy());
            obs.fusion_succeeded("a", "b", &["a".to_string(), "b".to_string()], 400.0);
            obs.feedback(&[sample(&["a", "b"], 150.0, f64::NAN)]);
            obs.feedback(&[sample(&["a", "b"], 90.0, f64::NAN)]); // back under cap
            obs.feedback(&[sample(&["a", "b"], 150.0, f64::NAN)]);
            assert!(rx.try_recv().is_none(), "strikes must reset on recovery");
        });
    }

    #[test]
    fn latency_regression_splits_and_respects_baseline() {
        run_virtual(async {
            let (obs, mut rx) = observer(defusion_policy());
            obs.fusion_succeeded("a", "b", &["a".to_string(), "b".to_string()], 200.0);
            // improved latency: no split
            obs.feedback(&[sample(&["a", "b"], 50.0, 150.0)]);
            obs.feedback(&[sample(&["a", "b"], 50.0, 150.0)]);
            assert!(rx.try_recv().is_none());
            // regression past 200 * 1.5 = 300 for two windows: split
            obs.feedback(&[sample(&["a", "b"], 50.0, 320.0)]);
            obs.feedback(&[sample(&["a", "b"], 50.0, 310.0)]);
            assert_eq!(
                rx.try_recv(),
                Some(FusionRequest::Split {
                    functions: vec!["a".into(), "b".into()],
                    reason: SplitReason::LatencyRegression,
                })
            );
        });
    }

    #[test]
    fn split_success_cools_pairs_down_preventing_flap() {
        run_virtual(async {
            let (obs, mut rx) = observer(defusion_policy());
            for _ in 0..3 {
                obs.observe_sync_call("a", "b");
            }
            assert_eq!(rx.try_recv(), Some(fuse("a", "b")));
            obs.fusion_succeeded("a", "b", &["a".to_string(), "b".to_string()], 400.0);
            obs.feedback(&[sample(&["a", "b"], 150.0, f64::NAN)]);
            obs.feedback(&[sample(&["a", "b"], 150.0, f64::NAN)]);
            assert!(matches!(rx.try_recv(), Some(FusionRequest::Split { .. })));
            obs.split_succeeded(&["a".to_string(), "b".to_string()]);
            // immediately re-observed: cooldown must block re-fusion
            obs.observe_sync_call("a", "b");
            assert!(rx.try_recv().is_none(), "fuse->split->fuse flap");
            // after the cooldown the pair may fuse again
            crate::exec::sleep_ms(10_001.0).await;
            obs.observe_sync_call("a", "b");
            assert_eq!(rx.try_recv(), Some(fuse("a", "b")));
        });
    }

    #[test]
    fn split_failure_backs_off_before_retry() {
        run_virtual(async {
            let (obs, mut rx) = observer(defusion_policy());
            let group = ["a".to_string(), "b".to_string()];
            obs.fusion_succeeded("a", "b", &group, 400.0);
            obs.feedback(&[sample(&["a", "b"], 150.0, f64::NAN)]);
            obs.feedback(&[sample(&["a", "b"], 150.0, f64::NAN)]);
            assert!(matches!(rx.try_recv(), Some(FusionRequest::Split { .. })));
            obs.split_failed(&group);
            // still violating, but inside the retry backoff
            obs.feedback(&[sample(&["a", "b"], 150.0, f64::NAN)]);
            obs.feedback(&[sample(&["a", "b"], 150.0, f64::NAN)]);
            assert!(rx.try_recv().is_none());
            crate::exec::sleep_ms(10_001.0).await;
            obs.feedback(&[sample(&["a", "b"], 150.0, f64::NAN)]);
            obs.feedback(&[sample(&["a", "b"], 150.0, f64::NAN)]);
            assert!(matches!(rx.try_recv(), Some(FusionRequest::Split { .. })));
        });
    }

    #[test]
    fn defusion_disabled_never_splits() {
        run_virtual(async {
            let mut p = defusion_policy();
            p.defusion = false;
            let (obs, mut rx) = observer(p);
            obs.fusion_succeeded("a", "b", &["a".to_string(), "b".to_string()], 400.0);
            for _ in 0..10 {
                obs.feedback(&[sample(&["a", "b"], 500.0, 10_000.0)]);
            }
            assert!(rx.try_recv().is_none());
        });
    }

    // -- cost-model policy ----------------------------------------------------

    fn cost_policy(evict_threshold: f64) -> FusionParams {
        let mut p = FusionParams::default_enabled();
        p.split_policy = crate::config::SplitPolicyKind::CostModel;
        p.split_hysteresis_windows = 2;
        p.max_group_ram_mb = 200.0; // the cost model's RAM reference
        p.cost.evict_threshold = evict_threshold;
        p
    }

    #[test]
    fn cost_policy_evicts_heaviest_from_large_group_after_hysteresis() {
        run_virtual(async {
            let (obs, mut rx) = observer(cost_policy(1.0));
            let group = ["a".to_string(), "b".to_string(), "c".to_string()];
            obs.fusion_succeeded("a", "b", &group, 300.0);
            // RAM term alone: 400 / 200 = 2.0 >= threshold 1.0
            let heavy = || {
                attributed_sample(
                    &["a", "b", "c"],
                    400.0,
                    vec![
                        attr("a", 50.0, f64::NAN, 0.1),
                        attr("b", 300.0, f64::NAN, 2.0),
                        attr("c", 50.0, f64::NAN, 0.1),
                    ],
                )
            };
            obs.feedback(&[heavy()]);
            assert!(rx.try_recv().is_none(), "hysteresis must hold the first strike");
            assert!(obs.group_score(&group) >= 1.0);
            obs.feedback(&[heavy()]);
            assert_eq!(
                rx.try_recv(),
                Some(FusionRequest::Evict {
                    functions: vec!["a".into(), "b".into(), "c".into()],
                    function: "b".into(),
                    reason: SplitReason::CostModel,
                })
            );
            // pending eviction suppresses duplicates
            obs.feedback(&[heavy()]);
            assert!(rx.try_recv().is_none());
        });
    }

    #[test]
    fn cost_policy_splits_pairs_whole() {
        run_virtual(async {
            let (obs, mut rx) = observer(cost_policy(1.0));
            obs.fusion_succeeded("a", "b", &["a".to_string(), "b".to_string()], 300.0);
            let hot = || {
                attributed_sample(
                    &["a", "b"],
                    400.0,
                    vec![attr("a", 100.0, f64::NAN, 0.5), attr("b", 300.0, f64::NAN, 1.5)],
                )
            };
            obs.feedback(&[hot()]);
            obs.feedback(&[hot()]);
            assert_eq!(
                rx.try_recv(),
                Some(FusionRequest::Split {
                    functions: vec!["a".into(), "b".into()],
                    reason: SplitReason::CostModel,
                })
            );
        });
    }

    #[test]
    fn cost_policy_below_threshold_never_fires() {
        run_virtual(async {
            let (obs, mut rx) = observer(cost_policy(1_000.0));
            obs.fusion_succeeded("a", "b", &["a".to_string(), "b".to_string()], 300.0);
            for _ in 0..10 {
                obs.feedback(&[sample(&["a", "b"], 400.0, f64::NAN)]);
            }
            assert!(rx.try_recv().is_none());
        });
    }

    #[test]
    fn evict_cools_only_the_evicted_pairs_and_keeps_remainder_tracked() {
        run_virtual(async {
            let (obs, mut rx) = observer(cost_policy(1.0));
            let group = ["a".to_string(), "b".to_string(), "c".to_string()];
            obs.fusion_succeeded("a", "b", &group, 321.0);
            obs.evict_succeeded(&group, "b");
            // evicted pairs (both directions) are cooling down
            assert!(obs.pair_in_cooldown("b", "a"));
            assert!(obs.pair_in_cooldown("a", "b"));
            assert!(obs.pair_in_cooldown("b", "c"));
            assert!(obs.pair_in_cooldown("c", "b"));
            // the surviving pair is NOT penalized
            assert!(!obs.pair_in_cooldown("a", "c"));
            assert!(!obs.pair_in_cooldown("c", "a"));
            // the shrunk group keeps its baseline under the new key
            assert_eq!(obs.group_baseline_p95(&["a".to_string(), "c".to_string()]), 321.0);
            assert!(obs.group_baseline_p95(&group).is_nan(), "old key must be gone");
            // re-observation of an evicted pair is blocked until cooldown ends
            obs.observe_sync_call("a", "b");
            obs.observe_sync_call("a", "b");
            obs.observe_sync_call("a", "b");
            assert!(rx.try_recv().is_none(), "evicted pair re-fused during cooldown");
            crate::exec::sleep_ms(10_001.0).await;
            obs.observe_sync_call("a", "b");
            assert!(rx.try_recv().is_some());
        });
    }

    #[test]
    fn evict_failure_backs_off_like_split_failure() {
        run_virtual(async {
            let (obs, mut rx) = observer(cost_policy(1.0));
            let group = ["a".to_string(), "b".to_string(), "c".to_string()];
            obs.fusion_succeeded("a", "b", &group, 300.0);
            let hot = || {
                attributed_sample(
                    &["a", "b", "c"],
                    400.0,
                    vec![
                        attr("a", 50.0, f64::NAN, 0.0),
                        attr("b", 300.0, f64::NAN, 0.0),
                        attr("c", 50.0, f64::NAN, 0.0),
                    ],
                )
            };
            obs.feedback(&[hot()]);
            obs.feedback(&[hot()]);
            assert!(matches!(rx.try_recv(), Some(FusionRequest::Evict { .. })));
            obs.evict_failed(&group);
            // still violating, but inside the retry backoff
            obs.feedback(&[hot()]);
            obs.feedback(&[hot()]);
            assert!(rx.try_recv().is_none());
            crate::exec::sleep_ms(10_001.0).await;
            obs.feedback(&[hot()]);
            obs.feedback(&[hot()]);
            assert!(matches!(rx.try_recv(), Some(FusionRequest::Evict { .. })));
        });
    }

    // -- merge-side admission planner -----------------------------------------

    fn merge_cost_policy() -> FusionParams {
        let mut p = FusionParams::default_enabled();
        p.merge_policy = crate::config::MergePolicyKind::CostModel;
        p.max_group_ram_mb = 256.0; // RAM reference + cap
        p.cost.evict_threshold = 2.0;
        p.cost.merge_threshold = 0.0;
        p
    }

    fn sig(function: &str, ram_mb: f64, billed_ms: f64, self_ms: f64, gbs: f64) -> FnSignals {
        FnSignals {
            function: function.into(),
            ram_mb,
            p95_ms: f64::NAN,
            gb_seconds: gbs,
            billed_ms,
            self_ms,
            window_s: 2.0,
            node: None,
            replicas: 1,
        }
    }

    #[test]
    fn cost_admission_refuses_until_signals_exist_then_admits_profitable_pair() {
        run_virtual(async {
            let (obs, mut rx) = observer(merge_cost_policy());
            // past the observation threshold but no window signals yet
            for _ in 0..5 {
                obs.observe_sync_call("a", "b");
            }
            assert!(rx.try_recv().is_none(), "admitted without any signals");
            // first window: hot light pair (caller mostly blocked)
            obs.update_fn_signals(vec![
                sig("a", 70.0, 2_000.0, 400.0, 0.1),
                sig("b", 70.0, 0.0, 0.0, 0.1),
            ]);
            obs.observe_sync_call("a", "b");
            assert_eq!(rx.try_recv(), Some(fuse("a", "b")));
            assert!(obs.admission_score("a", "b") > 0.0);
        });
    }

    #[test]
    fn cost_admission_refuses_heavy_pair_despite_observation_count() {
        run_virtual(async {
            let (obs, mut rx) = observer(merge_cost_policy());
            obs.update_fn_signals(vec![
                sig("a", 70.0, 2_000.0, 100.0, 0.1),
                // callee alone pushes the predicted fused set past the
                // evict threshold: churn-gated
                sig("b", 460.0, 0.0, 0.0, 2.0),
            ]);
            for _ in 0..50 {
                obs.observe_sync_call("a", "b");
            }
            assert!(rx.try_recv().is_none(), "heavy pair must be refused admission");
            assert_eq!(obs.count("a", "b"), 50);
            // a later window in which the callee slimmed down flips the verdict
            obs.update_fn_signals(vec![
                sig("a", 70.0, 2_000.0, 400.0, 0.1),
                sig("b", 70.0, 0.0, 0.0, 0.1),
            ]);
            obs.observe_sync_call("a", "b");
            assert_eq!(rx.try_recv(), Some(fuse("a", "b")));
        });
    }

    #[test]
    fn cost_admission_refuses_cold_pair_below_merge_threshold() {
        run_virtual(async {
            let (obs, mut rx) = observer(merge_cost_policy());
            // barely any traffic: benefit ~ 0, RAM penalty dominates
            obs.update_fn_signals(vec![
                sig("a", 70.0, 20.0, 15.0, 0.001),
                sig("b", 70.0, 0.0, 0.0, 0.001),
            ]);
            for _ in 0..10 {
                obs.observe_sync_call("a", "b");
            }
            assert!(rx.try_recv().is_none());
            assert!(obs.admission_score("a", "b") < 0.0);
        });
    }

    #[test]
    fn observation_count_policy_is_the_untouched_default() {
        run_virtual(async {
            // default_enabled -> ObservationCount: no signals ever needed
            let (obs, mut rx) = observer(FusionParams::default_enabled());
            for _ in 0..3 {
                obs.observe_sync_call("a", "b");
            }
            assert_eq!(rx.try_recv(), Some(fuse("a", "b")));
            assert!(obs.admission_score("a", "b").is_nan());
        });
    }

    #[test]
    fn cost_admission_scales_blocked_time_by_observed_callee_share() {
        run_virtual(async {
            // ISSUE 4 satellite (ROADMAP multi-callee bound): caller `a`
            // splits its sync calls evenly between b and c, so each pair
            // recovers only ~half the caller's measured blocked time.  The
            // caller is blocked 1.6 s of a 2 s window (rate 0.8); with the
            // old all-callees pricing each score would be ~0.72, with the
            // share scaling it must land well below 0.5.
            let (obs, mut rx) = observer(merge_cost_policy());
            obs.update_fn_signals(vec![
                sig("a", 10.0, 2_000.0, 400.0, 0.0),
                sig("b", 10.0, 0.0, 0.0, 0.0),
                sig("c", 10.0, 0.0, 0.0, 0.0),
            ]);
            for _ in 0..5 {
                obs.observe_sync_call("a", "b");
                obs.observe_sync_call("a", "c");
            }
            assert!(rx.try_recv().is_some(), "half-share hot pairs still admit at 0");
            assert!(rx.try_recv().is_some());
            for callee in ["b", "c"] {
                let score = obs.admission_score("a", callee);
                assert!(
                    score.is_finite() && score < 0.5,
                    "a->{callee} score {score} looks like the unscaled blocked-time rate"
                );
            }
        });
    }

    #[test]
    fn cost_admission_share_ignores_callees_already_fused_with_the_caller() {
        run_virtual(async {
            // `a` historically called b and c equally, so at threshold 0.5
            // neither pair clears admission on its half share (~0.32).
            // Once (a, b) is fused — a->b is inline — a's remaining
            // windowed blocked time is all c waits: the share denominator
            // must drop b's stale counts, or (a, c) stays underpriced
            // forever.
            let mut p = merge_cost_policy();
            p.cost.merge_threshold = 0.5;
            let (obs, mut rx) = observer(p);
            obs.update_fn_signals(vec![
                sig("a", 10.0, 2_000.0, 400.0, 0.0),
                sig("b", 10.0, 0.0, 0.0, 0.0),
                sig("c", 10.0, 0.0, 0.0, 0.0),
            ]);
            for _ in 0..5 {
                obs.observe_sync_call("a", "b");
                obs.observe_sync_call("a", "c");
            }
            assert!(rx.try_recv().is_none(), "half shares must not clear threshold 0.5");
            // (a, b) fuses anyway (e.g. an operator action): the Observer
            // learns the group, and the next window re-scores (a, c) with
            // c owning the whole remote share -> the FULL blocked rate
            // (0.8) minus the RAM penalty clears the threshold
            obs.fusion_succeeded("a", "b", &["a".to_string(), "b".to_string()], 300.0);
            obs.update_fn_signals(vec![
                sig("a", 10.0, 2_000.0, 400.0, 0.0),
                sig("c", 10.0, 0.0, 0.0, 0.0),
            ]);
            obs.observe_sync_call("a", "c");
            assert_eq!(rx.try_recv(), Some(fuse("a", "c")));
            let score = obs.admission_score("a", "c");
            assert!(
                score > 0.6,
                "score {score} still priced against the fused callee's stale counts"
            );
        });
    }

    #[test]
    fn node_pressure_prefers_migration_and_resolves_exactly_once() {
        run_virtual(async {
            let (obs, mut rx) = observer(defusion_policy());
            let over = || NodeSample {
                node: NodeId(0),
                ram_mb: 350.0,
                capacity_mb: 300.0,
                instances: vec![
                    (vec!["a".to_string(), "b".to_string()], 180.0),
                    (vec!["c".to_string()], 90.0),
                ],
            };
            let idle = || NodeSample {
                node: NodeId(1),
                ram_mb: 20.0,
                capacity_mb: 300.0,
                instances: vec![(vec!["d".to_string()], 20.0)],
            };
            // hysteresis = 1 in defusion_policy? no: split_hysteresis_windows
            // is 2 there — first strike holds
            obs.node_feedback(&[over(), idle()]);
            assert!(rx.try_recv().is_none(), "hysteresis must hold the first strike");
            obs.node_feedback(&[over(), idle()]);
            // the largest instance fits on node 1 -> migrate, not split
            assert_eq!(
                rx.try_recv(),
                Some(FusionRequest::Migrate {
                    functions: vec!["a".into(), "b".into()],
                    to: NodeId(1),
                })
            );
            assert!(obs.migration_pending(&["a".to_string(), "b".to_string()]));
            // still over while the migration is pending: no second action
            obs.node_feedback(&[over(), idle()]);
            obs.node_feedback(&[over(), idle()]);
            assert!(rx.try_recv().is_none(), "pending migration must gate the node");
            // completion puts the node AND the group on cooldown
            obs.migrate_succeeded(&["a".to_string(), "b".to_string()]);
            obs.node_feedback(&[over(), idle()]);
            obs.node_feedback(&[over(), idle()]);
            assert!(rx.try_recv().is_none(), "resolved node must back off one cooldown");
            // after the cooldown the node is eligible again
            crate::exec::sleep_ms(10_001.0).await;
            obs.node_feedback(&[over(), idle()]);
            obs.node_feedback(&[over(), idle()]);
            assert!(matches!(rx.try_recv(), Some(FusionRequest::Migrate { .. })));
        });
    }

    #[test]
    fn node_pressure_falls_back_to_defusion_when_nothing_fits() {
        run_virtual(async {
            let (obs, mut rx) = observer(defusion_policy());
            // node 1 has no headroom for either instance -> the largest
            // fused group on the hot node is split instead
            let over = || NodeSample {
                node: NodeId(0),
                ram_mb: 350.0,
                capacity_mb: 300.0,
                instances: vec![
                    (vec!["a".to_string(), "b".to_string()], 180.0),
                    (vec!["c".to_string()], 90.0),
                ],
            };
            let full = || NodeSample {
                node: NodeId(1),
                ram_mb: 290.0,
                capacity_mb: 300.0,
                instances: vec![(vec!["d".to_string()], 290.0)],
            };
            obs.node_feedback(&[over(), full()]);
            obs.node_feedback(&[over(), full()]);
            assert_eq!(
                rx.try_recv(),
                Some(FusionRequest::Split {
                    functions: vec!["a".into(), "b".into()],
                    reason: SplitReason::NodePressure,
                })
            );
            // pending split + node backoff suppress duplicates
            obs.node_feedback(&[over(), full()]);
            obs.node_feedback(&[over(), full()]);
            assert!(rx.try_recv().is_none());
        });
    }

    #[test]
    fn node_under_capacity_or_uncapped_never_pressures() {
        run_virtual(async {
            let (obs, mut rx) = observer(defusion_policy());
            let fine = NodeSample {
                node: NodeId(0),
                ram_mb: 250.0,
                capacity_mb: 300.0,
                instances: vec![(vec!["a".to_string()], 250.0)],
            };
            let uncapped = NodeSample {
                node: NodeId(1),
                ram_mb: 9_000.0,
                capacity_mb: 0.0,
                instances: vec![(vec!["b".to_string()], 9_000.0)],
            };
            for _ in 0..5 {
                obs.node_feedback(&[fine.clone(), uncapped.clone()]);
            }
            assert!(rx.try_recv().is_none());
        });
    }

    #[test]
    fn auto_tune_regret_raises_ram_weight_after_fuse_then_split_inside_cooldown() {
        run_virtual(async {
            let mut p = merge_cost_policy();
            p.auto_tune = true;
            let (obs, mut rx) = observer(p);
            obs.update_fn_signals(vec![
                sig("a", 70.0, 2_000.0, 400.0, 0.1),
                sig("b", 70.0, 0.0, 0.0, 0.1),
            ]);
            for _ in 0..3 {
                obs.observe_sync_call("a", "b");
            }
            assert_eq!(rx.try_recv(), Some(fuse("a", "b")));
            let group = ["a".to_string(), "b".to_string()];
            obs.fusion_succeeded("a", "b", &group, 300.0);
            // defused 2 s after the cutover: well inside the cooldown
            crate::exec::sleep_ms(2_000.0).await;
            obs.split_succeeded(&group);
            assert_eq!(obs.regret_count(), 1);
            let (w_latency, w_ram, w_gbs) = obs.merge_weights();
            assert!(w_ram > 1.0, "regret must raise the RAM penalty weight");
            assert!(w_latency < 1.0 && w_gbs < 1.0);
        });
    }

    #[test]
    fn fuse_surviving_the_cooldown_is_not_a_regret() {
        run_virtual(async {
            let mut p = merge_cost_policy();
            p.auto_tune = true;
            let (obs, mut rx) = observer(p);
            obs.update_fn_signals(vec![
                sig("a", 70.0, 2_000.0, 400.0, 0.1),
                sig("b", 70.0, 0.0, 0.0, 0.1),
            ]);
            for _ in 0..3 {
                obs.observe_sync_call("a", "b");
            }
            assert!(rx.try_recv().is_some());
            let group = ["a".to_string(), "b".to_string()];
            obs.fusion_succeeded("a", "b", &group, 300.0);
            // outlive the 10 s default cooldown, then defuse
            crate::exec::sleep_ms(11_000.0).await;
            obs.split_succeeded(&group);
            assert_eq!(obs.regret_count(), 0);
            assert_eq!(obs.merge_weights(), (1.0, 1.0, 1.0));
        });
    }

    #[test]
    fn transitive_growth_inherits_earliest_baseline() {
        run_virtual(async {
            let (obs, _rx) = observer(defusion_policy());
            obs.fusion_succeeded("a", "b", &["a".to_string(), "b".to_string()], 400.0);
            crate::exec::sleep_ms(1_000.0).await;
            // group grows; the fresh (post-fusion, faster) baseline must NOT
            // replace the original pre-fusion one
            obs.fusion_succeeded(
                "b",
                "c",
                &["a".to_string(), "b".to_string(), "c".to_string()],
                250.0,
            );
            let b = obs.group_baseline_p95(&[
                "a".to_string(),
                "b".to_string(),
                "c".to_string(),
            ]);
            assert_eq!(b, 400.0);
            // subsumed subgroup is gone
            assert!(obs.group_baseline_p95(&["a".to_string(), "b".to_string()]).is_nan());
        });
    }
}
