//! Fusion decision layer: call-graph observation store, admission policy,
//! and the **feedback-driven defusion controller**.
//!
//! The Function Handler reports every *remote synchronous* call it observes
//! (paper §3: detected via blocking outbound sockets).  Once a (caller,
//! callee) pair crosses the observation threshold — and passes trust-domain,
//! cooldown, and group-size checks — a [`FusionRequest::Fuse`] is emitted to
//! the Merger.
//!
//! Fusion is no longer one-way: the platform's controller loop periodically
//! hands the Observer a [`GroupSample`] per live fused instance (RAM
//! attribution + trailing-window p95), and the Observer closes the loop à la
//! Fusionize/Fusionize++: a group that exceeds the configured RAM cap
//! (`FusionParams::max_group_ram_mb`) or regresses p95 latency past the
//! hysteresis threshold for `split_hysteresis_windows` consecutive windows
//! gets a [`FusionRequest::Split`].  After a completed split every pair in
//! the group enters cooldown so fuse ∧ split cannot flap.
//!
//! With [`crate::config::SplitPolicyKind::CostModel`] the two-threshold
//! check is replaced by a single weighted objective (see [`cost`]) over
//! per-function attribution, and a violating group sheds only its
//! **heaviest** member via [`FusionRequest::Evict`] — a partial split.
//!
//! The observer also maintains the empirically discovered call graph, which
//! `provuse apps --observed` can dump.

pub mod cost;

use std::cell::RefCell;
use std::collections::{BTreeMap, HashMap, HashSet};

use crate::apps::AppSpec;
use crate::config::{FusionParams, SplitPolicyKind};
use crate::error::Result;
use crate::exec;
use crate::exec::channel::Sender;

use cost::CostModel;

/// A request for the Merger: consolidate two functions' instances, break a
/// fused group back apart, or evict a single member from a fused group.
#[derive(Debug, Clone, PartialEq)]
pub enum FusionRequest {
    /// Fuse the instances hosting `caller` and `callee`.
    Fuse { caller: String, callee: String },
    /// Split the fused instance hosting exactly `functions` (sorted) back
    /// into one instance per function.
    Split {
        functions: Vec<String>,
        reason: SplitReason,
    },
    /// Partial split: redeploy only `function` from its original image and
    /// shrink the fused instance hosting exactly `functions` (sorted) in
    /// place — the remainder of the group stays fused.
    Evict {
        functions: Vec<String>,
        function: String,
        reason: SplitReason,
    },
}

/// Which policy violation triggered a defusion.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SplitReason {
    /// The group's RAM footprint exceeded `max_group_ram_mb`.
    RamCap,
    /// The group's trailing-window p95 regressed past the pre-fusion
    /// baseline by more than `split_p95_regression`.
    LatencyRegression,
    /// The cost model's weighted objective crossed `evict_threshold`.
    CostModel,
}

impl SplitReason {
    pub fn name(&self) -> &'static str {
        match self {
            SplitReason::RamCap => "ram_cap",
            SplitReason::LatencyRegression => "latency_regression",
            SplitReason::CostModel => "cost_model",
        }
    }
}

/// Per-function attribution inside one fused group, gathered by the
/// platform's controller tick (handler latency series + RAM shares + the
/// billing ledger's trailing window).
#[derive(Debug, Clone, PartialEq)]
pub struct FnAttribution {
    pub function: String,
    /// attributed RAM (MiB): code footprint + an equal share of the base
    /// runtime and in-flight working sets; group members sum to the
    /// instance's RAM
    pub ram_mb: f64,
    /// p95 handler self-time over the trailing window (ms); NaN when the
    /// window had too few samples
    pub p95_ms: f64,
    /// billed GiB-seconds attributed to this function in the window
    pub gb_seconds: f64,
}

/// One controller observation of a live fused group (produced by the
/// platform's feedback loop each `feedback_interval_ms`).
#[derive(Debug, Clone, PartialEq)]
pub struct GroupSample {
    /// sorted function names hosted by the fused instance
    pub functions: Vec<String>,
    /// instantaneous RAM of the fused instance (MiB)
    pub ram_mb: f64,
    /// p95 end-to-end latency over the trailing feedback window (ms);
    /// NaN when the window had too few samples to be meaningful
    pub window_p95_ms: f64,
    /// trailing window length (seconds) the per-function attribution was
    /// gathered over
    pub window_s: f64,
    /// per-function attribution (empty under the threshold policy, which
    /// only needs the group aggregates)
    pub per_fn: Vec<FnAttribution>,
}

/// Shared observation store + policy gate + defusion feedback state.
pub struct Observer {
    policy: FusionParams,
    /// fn name -> trust domain (from the app spec)
    trust: HashMap<String, String>,
    state: RefCell<ObserverState>,
    tx: Sender<FusionRequest>,
}

#[derive(Default)]
struct ObserverState {
    /// sync-call observation counts per (caller, callee)
    counts: BTreeMap<(String, String), u64>,
    /// pairs already submitted to the merger (suppress duplicates)
    requested: HashSet<(String, String)>,
    /// virtual-time (ms) before which a pair may not be re-requested
    cooldown_until: HashMap<(String, String), f64>,
    /// feedback accounting per live fused group (key: sorted functions)
    groups: BTreeMap<Vec<String>, GroupFeedback>,
}

/// Per-group controller state.
struct GroupFeedback {
    /// p95 over the regime *before* this group (or its earliest fused
    /// ancestor) was created; NaN = unknown (latency check disabled)
    baseline_p95_ms: f64,
    /// virtual time (ms) the baseline was captured — earliest wins when
    /// groups grow transitively, keeping the baseline anchored to the
    /// closest-to-vanilla regime
    recorded_at_ms: f64,
    /// consecutive feedback windows over the RAM cap
    ram_strikes: u32,
    /// consecutive feedback windows past the latency-regression threshold
    latency_strikes: u32,
    /// consecutive feedback windows over the cost model's evict threshold
    cost_strikes: u32,
    /// most recent cost-model objective value (NaN until the first tick)
    last_score: f64,
    /// a split/evict request is in flight for this group
    split_pending: bool,
    /// virtual time (ms) before which no new split may be requested
    /// (set after a failed/aborted split)
    retry_after_ms: f64,
}

impl GroupFeedback {
    fn new(baseline_p95_ms: f64, recorded_at_ms: f64) -> Self {
        GroupFeedback {
            baseline_p95_ms,
            recorded_at_ms,
            ram_strikes: 0,
            latency_strikes: 0,
            cost_strikes: 0,
            last_score: f64::NAN,
            split_pending: false,
            retry_after_ms: 0.0,
        }
    }
}

impl Observer {
    pub fn new(policy: FusionParams, app: &AppSpec, tx: Sender<FusionRequest>) -> Self {
        let trust = app
            .functions()
            .map(|f| (f.name.clone(), f.trust_domain.clone()))
            .collect();
        Observer { policy, trust, state: RefCell::new(ObserverState::default()), tx }
    }

    pub fn policy(&self) -> &FusionParams {
        &self.policy
    }

    /// Record one observed remote synchronous call; may emit a
    /// [`FusionRequest::Fuse`] if the policy admits the pair.
    pub fn observe_sync_call(&self, caller: &str, callee: &str) {
        let key = (caller.to_string(), callee.to_string());
        let mut s = self.state.borrow_mut();
        let count = {
            let c = s.counts.entry(key.clone()).or_insert(0);
            *c += 1;
            *c
        };
        if !self.policy.enabled {
            return;
        }
        if count < self.policy.min_observations as u64 {
            return;
        }
        if s.requested.contains(&key) {
            return;
        }
        if let Some(&until) = s.cooldown_until.get(&key) {
            if exec::now().as_millis_f64() < until {
                return;
            }
        }
        if self.policy.respect_trust_domains {
            let (ta, tb) = (self.trust.get(caller), self.trust.get(callee));
            if ta.is_none() || tb.is_none() || ta != tb {
                return;
            }
        }
        s.requested.insert(key.clone());
        drop(s);
        // Receiver gone (merger shut down) is benign: fusion simply stops.
        let _ = self.tx.send(FusionRequest::Fuse { caller: key.0, callee: key.1 });
    }

    /// Merger feedback: the pair's fusion failed — re-allow after cooldown.
    pub fn fusion_failed(&self, caller: &str, callee: &str) {
        let key = (caller.to_string(), callee.to_string());
        let mut s = self.state.borrow_mut();
        s.requested.remove(&key);
        s.cooldown_until
            .insert(key, exec::now().as_millis_f64() + self.policy.cooldown_ms);
    }

    /// Merger feedback: the pair is now colocated in the fused instance
    /// hosting `group`, whose pre-fusion p95 was `baseline_p95_ms` (NaN =
    /// too few samples; latency-triggered defusion stays disarmed).
    ///
    /// Further observations of the pair are inline calls and will not be
    /// reported anyway; the group enters feedback tracking.
    pub fn fusion_succeeded(
        &self,
        caller: &str,
        callee: &str,
        group: &[String],
        baseline_p95_ms: f64,
    ) {
        let now = exec::now().as_millis_f64();
        let mut s = self.state.borrow_mut();
        s.requested.insert((caller.to_string(), callee.to_string()));

        let mut key: Vec<String> = group.to_vec();
        key.sort();
        // Transitive growth subsumes existing subgroups; inherit the
        // earliest baseline (closest to the vanilla regime).
        let mut baseline = baseline_p95_ms;
        let mut recorded = now;
        let subsumed: Vec<Vec<String>> = s
            .groups
            .keys()
            .filter(|k| k.iter().all(|f| key.contains(f)))
            .cloned()
            .collect();
        for k in subsumed {
            if let Some(old) = s.groups.remove(&k) {
                if old.baseline_p95_ms.is_finite() && old.recorded_at_ms < recorded {
                    recorded = old.recorded_at_ms;
                    baseline = old.baseline_p95_ms;
                }
            }
        }
        s.groups.insert(key, GroupFeedback::new(baseline, recorded));
    }

    /// Controller tick: evaluate every live fused group against the defusion
    /// policy once a violation has persisted for `split_hysteresis_windows`
    /// consecutive windows.
    ///
    /// * [`SplitPolicyKind::Threshold`] — PR 1 semantics, preserved verbatim:
    ///   RAM cap / p95 regression each tracked independently, whole-group
    ///   [`FusionRequest::Split`] on violation.
    /// * [`SplitPolicyKind::CostModel`] — one weighted objective (see
    ///   [`cost::CostModel`]); a violating group of three or more sheds its
    ///   heaviest member via [`FusionRequest::Evict`], a violating pair is
    ///   split whole (evicting from a pair and splitting it are the same
    ///   topology change, minus a pointlessly oversized instance).
    pub fn feedback(&self, samples: &[GroupSample]) {
        if !self.policy.enabled || !self.policy.defusion {
            return;
        }
        match self.policy.split_policy {
            SplitPolicyKind::Threshold => self.feedback_threshold(samples),
            SplitPolicyKind::CostModel => self.feedback_cost(samples),
        }
    }

    /// PR 1's two-threshold policy (the `Threshold` fallback).
    fn feedback_threshold(&self, samples: &[GroupSample]) {
        let now = exec::now().as_millis_f64();
        let hysteresis = self.policy.split_hysteresis_windows.max(1);
        let mut s = self.state.borrow_mut();
        for sample in samples {
            let mut key = sample.functions.clone();
            key.sort();
            let g = s
                .groups
                .entry(key.clone())
                .or_insert_with(|| GroupFeedback::new(f64::NAN, now));
            if g.split_pending || now < g.retry_after_ms {
                continue;
            }
            let over_ram =
                self.policy.max_group_ram_mb > 0.0 && sample.ram_mb > self.policy.max_group_ram_mb;
            g.ram_strikes = if over_ram { g.ram_strikes + 1 } else { 0 };
            let regressed = self.policy.split_p95_regression > 0.0
                && g.baseline_p95_ms.is_finite()
                && sample.window_p95_ms.is_finite()
                && sample.window_p95_ms
                    > g.baseline_p95_ms * (1.0 + self.policy.split_p95_regression);
            g.latency_strikes = if regressed { g.latency_strikes + 1 } else { 0 };

            let reason = if g.ram_strikes >= hysteresis {
                Some(SplitReason::RamCap)
            } else if g.latency_strikes >= hysteresis {
                Some(SplitReason::LatencyRegression)
            } else {
                None
            };
            if let Some(reason) = reason {
                g.split_pending = true;
                g.ram_strikes = 0;
                g.latency_strikes = 0;
                let _ = self.tx.send(FusionRequest::Split { functions: key, reason });
            }
        }
    }

    /// Cost-model policy: weighted objective + heaviest-member eviction.
    fn feedback_cost(&self, samples: &[GroupSample]) {
        let model = CostModel::from_params(&self.policy);
        if !model.armed() {
            return;
        }
        let now = exec::now().as_millis_f64();
        let hysteresis = self.policy.split_hysteresis_windows.max(1);
        let mut s = self.state.borrow_mut();
        for sample in samples {
            let mut key = sample.functions.clone();
            key.sort();
            let g = s
                .groups
                .entry(key.clone())
                .or_insert_with(|| GroupFeedback::new(f64::NAN, now));
            let score = model.group_score(sample, g.baseline_p95_ms);
            g.last_score = score;
            if g.split_pending || now < g.retry_after_ms {
                continue;
            }
            g.cost_strikes = if score >= model.evict_threshold() {
                g.cost_strikes + 1
            } else {
                0
            };
            if g.cost_strikes < hysteresis {
                continue;
            }
            g.split_pending = true;
            g.cost_strikes = 0;
            let request = match model.heaviest(sample) {
                Some(function) if key.len() > 2 => FusionRequest::Evict {
                    functions: key,
                    function,
                    reason: SplitReason::CostModel,
                },
                _ => FusionRequest::Split { functions: key, reason: SplitReason::CostModel },
            };
            let _ = self.tx.send(request);
        }
    }

    /// Merger feedback: the group was split back into per-function
    /// instances.  Every pair inside the group enters cooldown so the next
    /// observations cannot immediately re-fuse it (anti-flapping).
    pub fn split_succeeded(&self, functions: &[String]) {
        let now = exec::now().as_millis_f64();
        let mut s = self.state.borrow_mut();
        let mut key: Vec<String> = functions.to_vec();
        key.sort();
        s.groups.remove(&key);
        for a in functions {
            for b in functions {
                if a == b {
                    continue;
                }
                let pair = (a.clone(), b.clone());
                s.requested.remove(&pair);
                s.cooldown_until.insert(pair, now + self.policy.cooldown_ms);
            }
        }
    }

    /// Merger feedback: the split failed/aborted — the fused instance keeps
    /// serving; retry no sooner than one cooldown from now.
    pub fn split_failed(&self, functions: &[String]) {
        let now = exec::now().as_millis_f64();
        let mut s = self.state.borrow_mut();
        let mut key: Vec<String> = functions.to_vec();
        key.sort();
        if let Some(g) = s.groups.get_mut(&key) {
            g.split_pending = false;
            g.retry_after_ms = now + self.policy.cooldown_ms;
        }
    }

    /// Merger feedback: `evicted` left the group and serves from its own
    /// instance; the remainder keeps its feedback history under the shrunk
    /// key.  Only the **evicted pairs** — (evicted, member) both ways —
    /// enter cooldown, so the surviving group is unaffected and the evicted
    /// function cannot be re-absorbed before the pressure verdict settles.
    pub fn evict_succeeded(&self, functions: &[String], evicted: &str) {
        let now = exec::now().as_millis_f64();
        let mut s = self.state.borrow_mut();
        let mut key: Vec<String> = functions.to_vec();
        key.sort();
        let old = s.groups.remove(&key);
        let mut remaining = key;
        remaining.retain(|f| f != evicted);
        for member in &remaining {
            for pair in [
                (evicted.to_string(), member.clone()),
                (member.clone(), evicted.to_string()),
            ] {
                s.requested.remove(&pair);
                s.cooldown_until.insert(pair, now + self.policy.cooldown_ms);
            }
        }
        if remaining.len() >= 2 {
            let mut g = match old {
                Some(old) => GroupFeedback::new(old.baseline_p95_ms, old.recorded_at_ms),
                None => GroupFeedback::new(f64::NAN, now),
            };
            g.last_score = f64::NAN;
            s.groups.insert(remaining, g);
        }
    }

    /// Merger feedback: the eviction failed/aborted — the fused instance
    /// keeps serving the whole group; retry after one cooldown.
    pub fn evict_failed(&self, functions: &[String]) {
        self.split_failed(functions);
    }

    /// Whether a (caller, callee) pair is currently inside a cooldown
    /// window (test/property introspection).
    pub fn pair_in_cooldown(&self, caller: &str, callee: &str) -> bool {
        self.state
            .borrow()
            .cooldown_until
            .get(&(caller.to_string(), callee.to_string()))
            .map(|&until| exec::now().as_millis_f64() < until)
            .unwrap_or(false)
    }

    /// Most recent cost-model objective for a fused group (NaN when
    /// untracked or before the first cost-policy tick).
    pub fn group_score(&self, functions: &[String]) -> f64 {
        let mut key: Vec<String> = functions.to_vec();
        key.sort();
        self.state
            .borrow()
            .groups
            .get(&key)
            .map(|g| g.last_score)
            .unwrap_or(f64::NAN)
    }

    /// Pre-fusion p95 baseline tracked for a fused group (test/report
    /// introspection); NaN when unknown or untracked.
    pub fn group_baseline_p95(&self, functions: &[String]) -> f64 {
        let mut key: Vec<String> = functions.to_vec();
        key.sort();
        self.state
            .borrow()
            .groups
            .get(&key)
            .map(|g| g.baseline_p95_ms)
            .unwrap_or(f64::NAN)
    }

    /// Observation count of a pair.
    pub fn count(&self, caller: &str, callee: &str) -> u64 {
        self.state
            .borrow()
            .counts
            .get(&(caller.to_string(), callee.to_string()))
            .copied()
            .unwrap_or(0)
    }

    /// The empirically observed call graph, sorted.
    pub fn observed_graph(&self) -> Vec<((String, String), u64)> {
        self.state.borrow().counts.iter().map(|(k, v)| (k.clone(), *v)).collect()
    }
}

/// Validate a proposed fused group against the policy (used by the Merger
/// before committing to a build).
pub fn admit_group(policy: &FusionParams, group_size: usize) -> Result<()> {
    if policy.max_group_size > 0 && group_size > policy.max_group_size {
        return Err(crate::error::Error::FusionAborted(format!(
            "group size {group_size} exceeds max {}",
            policy.max_group_size
        )));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;
    use crate::exec::channel::{mpsc, Receiver};
    use crate::exec::run_virtual;

    fn observer(policy: FusionParams) -> (Observer, Receiver<FusionRequest>) {
        let (tx, rx) = mpsc();
        let app = apps::tree();
        (Observer::new(policy, &app, tx), rx)
    }

    fn fuse(caller: &str, callee: &str) -> FusionRequest {
        FusionRequest::Fuse { caller: caller.into(), callee: callee.into() }
    }

    fn sample(functions: &[&str], ram_mb: f64, p95: f64) -> GroupSample {
        GroupSample {
            functions: functions.iter().map(|s| s.to_string()).collect(),
            ram_mb,
            window_p95_ms: p95,
            window_s: 5.0,
            per_fn: Vec::new(),
        }
    }

    fn attr(function: &str, ram_mb: f64, p95_ms: f64, gb_seconds: f64) -> FnAttribution {
        FnAttribution { function: function.into(), ram_mb, p95_ms, gb_seconds }
    }

    fn attributed_sample(
        functions: &[&str],
        ram_mb: f64,
        per_fn: Vec<FnAttribution>,
    ) -> GroupSample {
        GroupSample {
            functions: functions.iter().map(|s| s.to_string()).collect(),
            ram_mb,
            window_p95_ms: f64::NAN,
            window_s: 5.0,
            per_fn,
        }
    }

    #[test]
    fn threshold_gates_requests() {
        run_virtual(async {
            let (obs, mut rx) = observer(FusionParams::default_enabled());
            obs.observe_sync_call("a", "b");
            obs.observe_sync_call("a", "b");
            assert!(rx.try_recv().is_none(), "below threshold");
            obs.observe_sync_call("a", "b");
            assert_eq!(rx.try_recv(), Some(fuse("a", "b")));
            // no duplicate request
            obs.observe_sync_call("a", "b");
            assert!(rx.try_recv().is_none());
            assert_eq!(obs.count("a", "b"), 4);
        });
    }

    #[test]
    fn disabled_policy_never_requests() {
        run_virtual(async {
            let (obs, mut rx) = observer(FusionParams::disabled());
            for _ in 0..10 {
                obs.observe_sync_call("a", "b");
            }
            assert!(rx.try_recv().is_none());
            assert_eq!(obs.count("a", "b"), 10); // still observes
        });
    }

    #[test]
    fn trust_domain_mismatch_blocks() {
        run_virtual(async {
            let (tx, mut rx) = mpsc();
            let app = apps::AppSpec::builder("t")
                .function("a").entry().trust_domain("x").sync_call("b").done()
                .function("b").trust_domain("y").done()
                .build()
                .unwrap();
            let obs = Observer::new(FusionParams::default_enabled(), &app, tx);
            for _ in 0..5 {
                obs.observe_sync_call("a", "b");
            }
            assert!(rx.try_recv().is_none());
        });
    }

    #[test]
    fn cooldown_after_failure() {
        run_virtual(async {
            let (obs, mut rx) = observer(FusionParams::default_enabled());
            for _ in 0..3 {
                obs.observe_sync_call("a", "b");
            }
            assert!(rx.try_recv().is_some());
            obs.fusion_failed("a", "b");
            // immediately re-observed: still cooling down
            obs.observe_sync_call("a", "b");
            assert!(rx.try_recv().is_none());
            crate::exec::sleep_ms(10_001.0).await;
            obs.observe_sync_call("a", "b");
            assert!(rx.try_recv().is_some());
        });
    }

    #[test]
    fn group_size_admission() {
        let mut p = FusionParams::default_enabled();
        assert!(admit_group(&p, 100).is_ok());
        p.max_group_size = 3;
        assert!(admit_group(&p, 3).is_ok());
        assert!(admit_group(&p, 4).is_err());
    }

    #[test]
    fn observed_graph_sorted() {
        run_virtual(async {
            let (obs, _rx) = observer(FusionParams::disabled());
            obs.observe_sync_call("b", "d");
            obs.observe_sync_call("a", "b");
            obs.observe_sync_call("a", "b");
            let g = obs.observed_graph();
            assert_eq!(g[0].0, ("a".into(), "b".into()));
            assert_eq!(g[0].1, 2);
            assert_eq!(g[1].0, ("b".into(), "d".into()));
        });
    }

    // -- defusion controller --------------------------------------------------

    fn defusion_policy() -> FusionParams {
        let mut p = FusionParams::default_enabled();
        p.max_group_ram_mb = 100.0;
        p.split_hysteresis_windows = 2;
        p.split_p95_regression = 0.5;
        p
    }

    #[test]
    fn ram_cap_violation_splits_after_hysteresis() {
        run_virtual(async {
            let (obs, mut rx) = observer(defusion_policy());
            let group = ["a".to_string(), "b".to_string()];
            obs.fusion_succeeded("a", "b", &group, 400.0);
            // one strike: not yet
            obs.feedback(&[sample(&["a", "b"], 150.0, f64::NAN)]);
            assert!(rx.try_recv().is_none());
            // second consecutive strike: split
            obs.feedback(&[sample(&["a", "b"], 150.0, f64::NAN)]);
            assert_eq!(
                rx.try_recv(),
                Some(FusionRequest::Split {
                    functions: vec!["a".into(), "b".into()],
                    reason: SplitReason::RamCap,
                })
            );
            // pending split suppresses duplicates
            obs.feedback(&[sample(&["a", "b"], 150.0, f64::NAN)]);
            assert!(rx.try_recv().is_none());
        });
    }

    #[test]
    fn transient_spike_resets_hysteresis() {
        run_virtual(async {
            let (obs, mut rx) = observer(defusion_policy());
            obs.fusion_succeeded("a", "b", &["a".to_string(), "b".to_string()], 400.0);
            obs.feedback(&[sample(&["a", "b"], 150.0, f64::NAN)]);
            obs.feedback(&[sample(&["a", "b"], 90.0, f64::NAN)]); // back under cap
            obs.feedback(&[sample(&["a", "b"], 150.0, f64::NAN)]);
            assert!(rx.try_recv().is_none(), "strikes must reset on recovery");
        });
    }

    #[test]
    fn latency_regression_splits_and_respects_baseline() {
        run_virtual(async {
            let (obs, mut rx) = observer(defusion_policy());
            obs.fusion_succeeded("a", "b", &["a".to_string(), "b".to_string()], 200.0);
            // improved latency: no split
            obs.feedback(&[sample(&["a", "b"], 50.0, 150.0)]);
            obs.feedback(&[sample(&["a", "b"], 50.0, 150.0)]);
            assert!(rx.try_recv().is_none());
            // regression past 200 * 1.5 = 300 for two windows: split
            obs.feedback(&[sample(&["a", "b"], 50.0, 320.0)]);
            obs.feedback(&[sample(&["a", "b"], 50.0, 310.0)]);
            assert_eq!(
                rx.try_recv(),
                Some(FusionRequest::Split {
                    functions: vec!["a".into(), "b".into()],
                    reason: SplitReason::LatencyRegression,
                })
            );
        });
    }

    #[test]
    fn split_success_cools_pairs_down_preventing_flap() {
        run_virtual(async {
            let (obs, mut rx) = observer(defusion_policy());
            for _ in 0..3 {
                obs.observe_sync_call("a", "b");
            }
            assert_eq!(rx.try_recv(), Some(fuse("a", "b")));
            obs.fusion_succeeded("a", "b", &["a".to_string(), "b".to_string()], 400.0);
            obs.feedback(&[sample(&["a", "b"], 150.0, f64::NAN)]);
            obs.feedback(&[sample(&["a", "b"], 150.0, f64::NAN)]);
            assert!(matches!(rx.try_recv(), Some(FusionRequest::Split { .. })));
            obs.split_succeeded(&["a".to_string(), "b".to_string()]);
            // immediately re-observed: cooldown must block re-fusion
            obs.observe_sync_call("a", "b");
            assert!(rx.try_recv().is_none(), "fuse->split->fuse flap");
            // after the cooldown the pair may fuse again
            crate::exec::sleep_ms(10_001.0).await;
            obs.observe_sync_call("a", "b");
            assert_eq!(rx.try_recv(), Some(fuse("a", "b")));
        });
    }

    #[test]
    fn split_failure_backs_off_before_retry() {
        run_virtual(async {
            let (obs, mut rx) = observer(defusion_policy());
            let group = ["a".to_string(), "b".to_string()];
            obs.fusion_succeeded("a", "b", &group, 400.0);
            obs.feedback(&[sample(&["a", "b"], 150.0, f64::NAN)]);
            obs.feedback(&[sample(&["a", "b"], 150.0, f64::NAN)]);
            assert!(matches!(rx.try_recv(), Some(FusionRequest::Split { .. })));
            obs.split_failed(&group);
            // still violating, but inside the retry backoff
            obs.feedback(&[sample(&["a", "b"], 150.0, f64::NAN)]);
            obs.feedback(&[sample(&["a", "b"], 150.0, f64::NAN)]);
            assert!(rx.try_recv().is_none());
            crate::exec::sleep_ms(10_001.0).await;
            obs.feedback(&[sample(&["a", "b"], 150.0, f64::NAN)]);
            obs.feedback(&[sample(&["a", "b"], 150.0, f64::NAN)]);
            assert!(matches!(rx.try_recv(), Some(FusionRequest::Split { .. })));
        });
    }

    #[test]
    fn defusion_disabled_never_splits() {
        run_virtual(async {
            let mut p = defusion_policy();
            p.defusion = false;
            let (obs, mut rx) = observer(p);
            obs.fusion_succeeded("a", "b", &["a".to_string(), "b".to_string()], 400.0);
            for _ in 0..10 {
                obs.feedback(&[sample(&["a", "b"], 500.0, 10_000.0)]);
            }
            assert!(rx.try_recv().is_none());
        });
    }

    // -- cost-model policy ----------------------------------------------------

    fn cost_policy(evict_threshold: f64) -> FusionParams {
        let mut p = FusionParams::default_enabled();
        p.split_policy = crate::config::SplitPolicyKind::CostModel;
        p.split_hysteresis_windows = 2;
        p.max_group_ram_mb = 200.0; // the cost model's RAM reference
        p.cost.evict_threshold = evict_threshold;
        p
    }

    #[test]
    fn cost_policy_evicts_heaviest_from_large_group_after_hysteresis() {
        run_virtual(async {
            let (obs, mut rx) = observer(cost_policy(1.0));
            let group = ["a".to_string(), "b".to_string(), "c".to_string()];
            obs.fusion_succeeded("a", "b", &group, 300.0);
            // RAM term alone: 400 / 200 = 2.0 >= threshold 1.0
            let heavy = || {
                attributed_sample(
                    &["a", "b", "c"],
                    400.0,
                    vec![
                        attr("a", 50.0, f64::NAN, 0.1),
                        attr("b", 300.0, f64::NAN, 2.0),
                        attr("c", 50.0, f64::NAN, 0.1),
                    ],
                )
            };
            obs.feedback(&[heavy()]);
            assert!(rx.try_recv().is_none(), "hysteresis must hold the first strike");
            assert!(obs.group_score(&group) >= 1.0);
            obs.feedback(&[heavy()]);
            assert_eq!(
                rx.try_recv(),
                Some(FusionRequest::Evict {
                    functions: vec!["a".into(), "b".into(), "c".into()],
                    function: "b".into(),
                    reason: SplitReason::CostModel,
                })
            );
            // pending eviction suppresses duplicates
            obs.feedback(&[heavy()]);
            assert!(rx.try_recv().is_none());
        });
    }

    #[test]
    fn cost_policy_splits_pairs_whole() {
        run_virtual(async {
            let (obs, mut rx) = observer(cost_policy(1.0));
            obs.fusion_succeeded("a", "b", &["a".to_string(), "b".to_string()], 300.0);
            let hot = || {
                attributed_sample(
                    &["a", "b"],
                    400.0,
                    vec![attr("a", 100.0, f64::NAN, 0.5), attr("b", 300.0, f64::NAN, 1.5)],
                )
            };
            obs.feedback(&[hot()]);
            obs.feedback(&[hot()]);
            assert_eq!(
                rx.try_recv(),
                Some(FusionRequest::Split {
                    functions: vec!["a".into(), "b".into()],
                    reason: SplitReason::CostModel,
                })
            );
        });
    }

    #[test]
    fn cost_policy_below_threshold_never_fires() {
        run_virtual(async {
            let (obs, mut rx) = observer(cost_policy(1_000.0));
            obs.fusion_succeeded("a", "b", &["a".to_string(), "b".to_string()], 300.0);
            for _ in 0..10 {
                obs.feedback(&[sample(&["a", "b"], 400.0, f64::NAN)]);
            }
            assert!(rx.try_recv().is_none());
        });
    }

    #[test]
    fn evict_cools_only_the_evicted_pairs_and_keeps_remainder_tracked() {
        run_virtual(async {
            let (obs, mut rx) = observer(cost_policy(1.0));
            let group = ["a".to_string(), "b".to_string(), "c".to_string()];
            obs.fusion_succeeded("a", "b", &group, 321.0);
            obs.evict_succeeded(&group, "b");
            // evicted pairs (both directions) are cooling down
            assert!(obs.pair_in_cooldown("b", "a"));
            assert!(obs.pair_in_cooldown("a", "b"));
            assert!(obs.pair_in_cooldown("b", "c"));
            assert!(obs.pair_in_cooldown("c", "b"));
            // the surviving pair is NOT penalized
            assert!(!obs.pair_in_cooldown("a", "c"));
            assert!(!obs.pair_in_cooldown("c", "a"));
            // the shrunk group keeps its baseline under the new key
            assert_eq!(obs.group_baseline_p95(&["a".to_string(), "c".to_string()]), 321.0);
            assert!(obs.group_baseline_p95(&group).is_nan(), "old key must be gone");
            // re-observation of an evicted pair is blocked until cooldown ends
            obs.observe_sync_call("a", "b");
            obs.observe_sync_call("a", "b");
            obs.observe_sync_call("a", "b");
            assert!(rx.try_recv().is_none(), "evicted pair re-fused during cooldown");
            crate::exec::sleep_ms(10_001.0).await;
            obs.observe_sync_call("a", "b");
            assert!(rx.try_recv().is_some());
        });
    }

    #[test]
    fn evict_failure_backs_off_like_split_failure() {
        run_virtual(async {
            let (obs, mut rx) = observer(cost_policy(1.0));
            let group = ["a".to_string(), "b".to_string(), "c".to_string()];
            obs.fusion_succeeded("a", "b", &group, 300.0);
            let hot = || {
                attributed_sample(
                    &["a", "b", "c"],
                    400.0,
                    vec![
                        attr("a", 50.0, f64::NAN, 0.0),
                        attr("b", 300.0, f64::NAN, 0.0),
                        attr("c", 50.0, f64::NAN, 0.0),
                    ],
                )
            };
            obs.feedback(&[hot()]);
            obs.feedback(&[hot()]);
            assert!(matches!(rx.try_recv(), Some(FusionRequest::Evict { .. })));
            obs.evict_failed(&group);
            // still violating, but inside the retry backoff
            obs.feedback(&[hot()]);
            obs.feedback(&[hot()]);
            assert!(rx.try_recv().is_none());
            crate::exec::sleep_ms(10_001.0).await;
            obs.feedback(&[hot()]);
            obs.feedback(&[hot()]);
            assert!(matches!(rx.try_recv(), Some(FusionRequest::Evict { .. })));
        });
    }

    #[test]
    fn transitive_growth_inherits_earliest_baseline() {
        run_virtual(async {
            let (obs, _rx) = observer(defusion_policy());
            obs.fusion_succeeded("a", "b", &["a".to_string(), "b".to_string()], 400.0);
            crate::exec::sleep_ms(1_000.0).await;
            // group grows; the fresh (post-fusion, faster) baseline must NOT
            // replace the original pre-fusion one
            obs.fusion_succeeded(
                "b",
                "c",
                &["a".to_string(), "b".to_string(), "c".to_string()],
                250.0,
            );
            let b = obs.group_baseline_p95(&[
                "a".to_string(),
                "b".to_string(),
                "c".to_string(),
            ]);
            assert_eq!(b, 400.0);
            // subsumed subgroup is gone
            assert!(obs.group_baseline_p95(&["a".to_string(), "b".to_string()]).is_nan());
        });
    }
}
