//! Global fusion re-planner (ISSUE 8, Konflux-style).
//!
//! Instead of admitting one (caller, callee) pair at a time, the global
//! planner periodically freezes the Observer's world view into a
//! [`PlanSnapshot`] — observed call graph, windowed [`FnSignals`], live
//! fused groups, node loads — and searches over **whole call-graph
//! partitions** with simulated-annealing-style perturbations.  The score
//! is the same weighted latency×RAM×bill pricing the greedy planner uses
//! ([`CostModel::cut_cost`] / [`CostModel::residency_cost`]), summed over
//! the partition: every cut sync edge keeps paying its blocked-time and
//! double-billing rates, every group keeps paying RAM residency.  Because
//! the score is a whole-partition total, the search can walk *through*
//! intermediate partitions a greedy pairwise step would refuse — the
//! local optima Konflux shows greedy merging locks into.
//!
//! The winning partition is emitted as a [`Plan`]: an ordered **plan-diff**
//! (splits/evicts first, then migrations, then fuses along observed sync
//! edges) the Merger executes through its existing pipelines.  The plan
//! carries the snapshot's topology epoch; the executor aborts the
//! remainder cleanly the moment the live epoch disagrees (stale-plan
//! guard), so a plan never stomps a topology it did not see.
//!
//! Hard constraints the search enforces on every emitted target:
//! * groups are connected subgraphs of the **observed** sync-call graph;
//! * trust domains are uniform inside a group (when the policy says so);
//! * `max_group_size` / `max_group_ram_mb` caps;
//! * pairs inside a fuse cooldown are not regrouped (anti-flap);
//! * predicted per-node RAM (group footprint × replicas) ≤ node capacity.

use std::collections::{BTreeMap, HashMap, HashSet, VecDeque};

use crate::cluster::NodeId;
use crate::config::FusionParams;
use crate::util::intern::Sym;
use crate::util::rng::Rng;

use super::cost::{CostModel, FnSignals};
use super::NodeLoad;

/// Minimum relative score improvement before a plan is worth emitting —
/// re-plans cheaper than this are churn, not wins.
pub const REPLAN_MIN_GAIN: f64 = 0.01;

/// Per-MiB penalty (in objective units, scaled by the model's RAM
/// reference) charged while a search state overflows a node capacity or
/// the group RAM cap; large enough that any real objective gain cannot
/// pay for a constraint violation, while still giving the annealer a
/// gradient back to feasibility.
const OVERFLOW_PENALTY: f64 = 1e3;

/// The Observer's frozen world view a plan is computed against.
#[derive(Debug, Clone, PartialEq)]
pub struct PlanSnapshot {
    /// topology epoch at snapshot time (stale-plan guard)
    pub epoch: u64,
    /// latest windowed per-function signals, sorted by function name
    pub signals: Vec<FnSignals>,
    /// observed sync-call counts ((caller, callee) -> count), sorted
    pub edges: Vec<((String, String), u64)>,
    /// live fused groups (sorted member lists); observed functions not in
    /// any group are implicit singletons
    pub groups: Vec<Vec<String>>,
    /// latest per-node loads (empty on single-node platforms)
    pub node_loads: Vec<NodeLoad>,
    /// calibrated one-off migration cost estimate (ms)
    pub migration_est_ms: f64,
    /// fn name -> trust domain
    pub trust: BTreeMap<String, String>,
    /// (caller, callee) pairs inside a fuse cooldown at snapshot time
    pub cooling: Vec<(String, String)>,
}

/// One step of a plan-diff, executed through the existing Merger /
/// Migrator pipelines.
#[derive(Debug, Clone, PartialEq)]
pub enum PlanAction {
    /// Split the fused group hosting exactly `functions` into singletons.
    Split { functions: Vec<String> },
    /// Evict `function` from the group hosting exactly `functions`.
    Evict { functions: Vec<String>, function: String },
    /// Fuse `callee`'s group into `caller`'s (oriented along an observed
    /// sync edge).
    Fuse { caller: String, callee: String },
    /// Move the instance hosting exactly `functions` to node `to`.
    Migrate { functions: Vec<String>, to: NodeId },
}

/// One group of the plan's target partition, with its predicted node.
#[derive(Debug, Clone, PartialEq)]
pub struct PlanGroup {
    /// sorted member functions
    pub functions: Vec<String>,
    /// predicted hosting node (None on single-node platforms)
    pub node: Option<NodeId>,
}

/// An emitted plan-diff: ordered actions plus the bookkeeping the
/// executor and the A/B telemetry need.
#[derive(Debug, Clone, PartialEq)]
pub struct Plan {
    /// monotonically increasing plan id (per platform run)
    pub id: u64,
    /// topology epoch the snapshot was taken at
    pub epoch: u64,
    /// ordered plan-diff: splits/evicts, then migrations, then fuses
    pub actions: Vec<PlanAction>,
    /// partition objective of the snapshot's live partition
    pub predicted_before: f64,
    /// partition objective of the target partition
    pub predicted_after: f64,
    /// the target partition the diff reproduces, sorted
    pub target: Vec<PlanGroup>,
}

impl Plan {
    /// Compact per-kind action tally for event logs, e.g.
    /// `split:1 evict:0 migrate:0 fuse:2`.
    pub fn summary(&self) -> String {
        let mut split = 0;
        let mut evict = 0;
        let mut migrate = 0;
        let mut fuse = 0;
        for a in &self.actions {
            match a {
                PlanAction::Split { .. } => split += 1,
                PlanAction::Evict { .. } => evict += 1,
                PlanAction::Migrate { .. } => migrate += 1,
                PlanAction::Fuse { .. } => fuse += 1,
            }
        }
        format!("split:{split} evict:{evict} migrate:{migrate} fuse:{fuse}")
    }
}

/// The snapshot's live partition: fused groups plus one singleton per
/// observed-but-unfused function, sorted.
pub fn snapshot_partition(snap: &PlanSnapshot) -> Vec<Vec<String>> {
    let mut parts: Vec<Vec<String>> = snap
        .groups
        .iter()
        .map(|g| {
            let mut g = g.clone();
            g.sort();
            g
        })
        .collect();
    let grouped: HashSet<&String> = snap.groups.iter().flatten().collect();
    for s in &snap.signals {
        let name = s.function.as_str().to_string();
        if !grouped.contains(&name) {
            parts.push(vec![name]);
        }
    }
    parts.sort();
    parts
}

/// The partition objective (minimize): Σ cut-edge costs + Σ group RAM
/// residency, priced by the same [`CostModel`] terms greedy admission
/// uses.  A cut edge's callee share is computed against the *candidate*
/// partition — only still-remote callees sit in the denominator, exactly
/// like the greedy planner's `MergeContext`.
pub fn partition_objective(
    snap: &PlanSnapshot,
    partition: &[Vec<String>],
    model: &CostModel,
) -> f64 {
    let sigs: HashMap<&str, &FnSignals> =
        snap.signals.iter().map(|s| (s.function.as_str(), s)).collect();
    let mut owner: HashMap<&str, usize> = HashMap::new();
    for (gi, g) in partition.iter().enumerate() {
        for f in g {
            owner.insert(f.as_str(), gi);
        }
    }
    let is_cut = |a: &str, b: &str| match (owner.get(a), owner.get(b)) {
        (Some(x), Some(y)) => x != y,
        // an endpoint outside the partition stays remote by definition
        _ => true,
    };
    let mut total = 0.0;
    for g in partition {
        let priced: Vec<&FnSignals> =
            g.iter().filter_map(|f| sigs.get(f.as_str()).copied()).collect();
        if priced.is_empty() {
            continue;
        }
        let ram: f64 = priced.iter().map(|s| s.ram_mb.max(0.0)).sum();
        let replicas = priced.iter().map(|s| s.replicas.max(1)).max().unwrap_or(1);
        total += model.residency_cost(ram, replicas as f64);
    }
    let mut outbound: HashMap<&str, u64> = HashMap::new();
    for ((a, b), n) in &snap.edges {
        if is_cut(a, b) {
            *outbound.entry(a.as_str()).or_insert(0) += n;
        }
    }
    for ((a, b), n) in &snap.edges {
        if !is_cut(a, b) {
            continue;
        }
        let (Some(sa), Some(sb)) = (sigs.get(a.as_str()), sigs.get(b.as_str())) else {
            continue;
        };
        let out = outbound.get(a.as_str()).copied().unwrap_or(0);
        let share = if out > 0 { *n as f64 / out as f64 } else { 1.0 };
        total += model.cut_cost(sa, sb, share);
    }
    total
}

/// Objective of the snapshot's own live partition under the policy's cost
/// model — the number `figure11` compares across the greedy/global arms.
pub fn snapshot_objective(snap: &PlanSnapshot, policy: &FusionParams) -> f64 {
    let model = CostModel::from_params(policy);
    partition_objective(snap, &snapshot_partition(snap), &model)
}

/// Replay a plan-diff against a partition (pure bookkeeping — Migrate
/// does not change membership).  The plan-validity property asserts this
/// reproduces [`Plan::target`] exactly.
pub fn apply_diff(initial: &[Vec<String>], actions: &[PlanAction]) -> Vec<Vec<String>> {
    let mut parts: Vec<Vec<String>> = initial
        .iter()
        .map(|g| {
            let mut g = g.clone();
            g.sort();
            g
        })
        .collect();
    for action in actions {
        match action {
            PlanAction::Split { functions } => {
                let mut key = functions.clone();
                key.sort();
                parts.retain(|p| *p != key);
                for f in &key {
                    parts.push(vec![f.clone()]);
                }
            }
            PlanAction::Evict { functions, function } => {
                let mut key = functions.clone();
                key.sort();
                parts.retain(|p| *p != key);
                let mut rest = key;
                rest.retain(|f| f != function);
                parts.push(rest);
                parts.push(vec![function.clone()]);
            }
            PlanAction::Fuse { caller, callee } => {
                let a = parts.iter().position(|p| p.iter().any(|f| f == caller));
                let b = parts.iter().position(|p| p.iter().any(|f| f == callee));
                if let (Some(a), Some(b)) = (a, b) {
                    if a != b {
                        let (hi, lo) = if a > b { (a, b) } else { (b, a) };
                        let moved = parts.remove(hi);
                        parts[lo].extend(moved);
                        parts[lo].sort();
                    }
                }
            }
            PlanAction::Migrate { .. } => {}
        }
    }
    parts.retain(|p| !p.is_empty());
    parts.sort();
    parts
}

/// One group of a search state: sorted member indices plus the predicted
/// hosting node.
#[derive(Debug, Clone, PartialEq)]
struct Group {
    members: Vec<usize>,
    node: Option<NodeId>,
}

#[derive(Debug, Clone, PartialEq)]
struct State {
    groups: Vec<Group>,
}

/// Immutable search context derived from one snapshot.
struct World<'a> {
    names: Vec<String>,
    sigs: Vec<FnSignals>,
    counts: BTreeMap<(usize, usize), u64>,
    /// undirected adjacency over observed sync edges, sorted + deduped
    adj: Vec<Vec<usize>>,
    trust: Vec<Option<String>>,
    /// unordered cooling pairs, stored as (min, max)
    cooling: HashSet<(usize, usize)>,
    /// node id -> capacity (only nodes with a positive cap)
    capacities: HashMap<u64, f64>,
    /// node ids available as migration targets
    nodes: Vec<NodeId>,
    policy: &'a FusionParams,
    model: CostModel,
}

impl<'a> World<'a> {
    fn build(snap: &PlanSnapshot, policy: &'a FusionParams) -> World<'a> {
        let mut names: Vec<String> =
            snap.signals.iter().map(|s| s.function.as_str().to_string()).collect();
        let mut sigs: Vec<FnSignals> = snap.signals.clone();
        let mut index: HashMap<String, usize> =
            names.iter().enumerate().map(|(i, n)| (n.clone(), i)).collect();
        // group members the tick has not priced yet still need a slot so
        // the diff can reason about their group; they price as zero
        for g in &snap.groups {
            for f in g {
                if !index.contains_key(f) {
                    index.insert(f.clone(), names.len());
                    names.push(f.clone());
                    sigs.push(FnSignals {
                        function: Sym::intern(f),
                        ram_mb: 0.0,
                        p95_ms: f64::NAN,
                        gb_seconds: 0.0,
                        billed_ms: 0.0,
                        self_ms: 0.0,
                        window_s: 1.0,
                        node: None,
                        replicas: 1,
                    });
                }
            }
        }
        let n = names.len();
        let mut counts = BTreeMap::new();
        let mut adj: Vec<Vec<usize>> = vec![Vec::new(); n];
        for ((a, b), c) in &snap.edges {
            let (Some(&i), Some(&j)) = (index.get(a), index.get(b)) else {
                continue;
            };
            if i == j {
                continue;
            }
            *counts.entry((i, j)).or_insert(0) += c;
            adj[i].push(j);
            adj[j].push(i);
        }
        for l in adj.iter_mut() {
            l.sort_unstable();
            l.dedup();
        }
        let trust = names.iter().map(|f| snap.trust.get(f).cloned()).collect();
        let cooling = snap
            .cooling
            .iter()
            .filter_map(|(a, b)| {
                let (i, j) = (*index.get(a)?, *index.get(b)?);
                Some((i.min(j), i.max(j)))
            })
            .collect();
        let capacities = snap
            .node_loads
            .iter()
            .filter(|l| l.capacity_mb > 0.0)
            .map(|l| (l.node.0, l.capacity_mb))
            .collect();
        let nodes = snap.node_loads.iter().map(|l| l.node).collect();
        World {
            names,
            sigs,
            counts,
            adj,
            trust,
            cooling,
            capacities,
            nodes,
            policy,
            model: CostModel::from_params(policy),
        }
    }

    fn initial_state(&self, snap: &PlanSnapshot) -> State {
        let index: HashMap<&str, usize> =
            self.names.iter().enumerate().map(|(i, n)| (n.as_str(), i)).collect();
        let mut assigned = vec![false; self.names.len()];
        let mut groups = Vec::new();
        for g in &snap.groups {
            let mut members: Vec<usize> =
                g.iter().filter_map(|f| index.get(f.as_str()).copied()).collect();
            members.sort_unstable();
            members.dedup();
            if members.is_empty() {
                continue;
            }
            let node = members.iter().find_map(|&m| self.sigs[m].node);
            for &m in &members {
                assigned[m] = true;
            }
            groups.push(Group { members, node });
        }
        for i in 0..self.names.len() {
            if !assigned[i] {
                groups.push(Group { members: vec![i], node: self.sigs[i].node });
            }
        }
        State { groups }
    }

    fn owner_map(&self, state: &State) -> Vec<usize> {
        let mut owner = vec![usize::MAX; self.names.len()];
        for (gi, g) in state.groups.iter().enumerate() {
            for &m in &g.members {
                owner[m] = gi;
            }
        }
        owner
    }

    fn group_footprint(&self, g: &Group) -> f64 {
        let ram: f64 = g.members.iter().map(|&m| self.sigs[m].ram_mb.max(0.0)).sum();
        let replicas =
            g.members.iter().map(|&m| self.sigs[m].replicas.max(1)).max().unwrap_or(1);
        ram * replicas as f64
    }

    /// (objective, overflow penalty) of a state.  The objective mirrors
    /// [`partition_objective`]; the penalty prices node-capacity and
    /// group-RAM-cap overflows so the annealer is pulled back to
    /// feasibility without making infeasible intermediates unreachable.
    fn score(&self, state: &State) -> (f64, f64) {
        let owner = self.owner_map(state);
        let mut objective = 0.0;
        for g in &state.groups {
            let ram: f64 = g.members.iter().map(|&m| self.sigs[m].ram_mb.max(0.0)).sum();
            let replicas =
                g.members.iter().map(|&m| self.sigs[m].replicas.max(1)).max().unwrap_or(1);
            objective += self.model.residency_cost(ram, replicas as f64);
        }
        let mut outbound: HashMap<usize, u64> = HashMap::new();
        for (&(i, j), &c) in &self.counts {
            if owner[i] != owner[j] {
                *outbound.entry(i).or_insert(0) += c;
            }
        }
        for (&(i, j), &c) in &self.counts {
            if owner[i] == owner[j] {
                continue;
            }
            let out = outbound.get(&i).copied().unwrap_or(0);
            let share = if out > 0 { c as f64 / out as f64 } else { 1.0 };
            objective += self.model.cut_cost(&self.sigs[i], &self.sigs[j], share);
        }

        let ram_ref = self.model.ram_ref_mb();
        let mut penalty = 0.0;
        if self.policy.max_group_ram_mb > 0.0 {
            for g in &state.groups {
                let ram: f64 =
                    g.members.iter().map(|&m| self.sigs[m].ram_mb.max(0.0)).sum();
                if ram > self.policy.max_group_ram_mb {
                    penalty += OVERFLOW_PENALTY * (ram - self.policy.max_group_ram_mb) / ram_ref;
                }
            }
        }
        if !self.capacities.is_empty() {
            let mut load: HashMap<u64, f64> = HashMap::new();
            for g in &state.groups {
                if let Some(node) = g.node {
                    *load.entry(node.0).or_insert(0.0) += self.group_footprint(g);
                }
            }
            for (node, cap) in &self.capacities {
                let l = load.get(node).copied().unwrap_or(0.0);
                if l > *cap {
                    penalty += OVERFLOW_PENALTY * (l - cap) / ram_ref;
                }
            }
        }
        (objective, penalty)
    }

    fn connected(&self, members: &[usize]) -> bool {
        if members.len() <= 1 {
            return true;
        }
        let set: HashSet<usize> = members.iter().copied().collect();
        let mut seen = HashSet::new();
        let mut queue = VecDeque::new();
        seen.insert(members[0]);
        queue.push_back(members[0]);
        while let Some(u) = queue.pop_front() {
            for &v in &self.adj[u] {
                if set.contains(&v) && seen.insert(v) {
                    queue.push_back(v);
                }
            }
        }
        seen.len() == members.len()
    }

    /// Connected components of `members` over the observed-edge graph,
    /// each sorted, in ascending order of their smallest member.
    fn components(&self, members: &[usize]) -> Vec<Vec<usize>> {
        let set: HashSet<usize> = members.iter().copied().collect();
        let mut seen: HashSet<usize> = HashSet::new();
        let mut out = Vec::new();
        let mut sorted = members.to_vec();
        sorted.sort_unstable();
        for &start in &sorted {
            if seen.contains(&start) {
                continue;
            }
            let mut comp = vec![start];
            seen.insert(start);
            let mut queue = VecDeque::from([start]);
            while let Some(u) = queue.pop_front() {
                for &v in &self.adj[u] {
                    if set.contains(&v) && seen.insert(v) {
                        comp.push(v);
                        queue.push_back(v);
                    }
                }
            }
            comp.sort_unstable();
            out.push(comp);
        }
        out
    }

    /// Trust domains uniform, no cooling pair regrouped, size cap.
    fn merge_admissible(&self, a: &Group, b: &Group) -> bool {
        let size = a.members.len() + b.members.len();
        if self.policy.max_group_size > 0 && size > self.policy.max_group_size {
            return false;
        }
        if self.policy.respect_trust_domains {
            let domains: HashSet<&Option<String>> = a
                .members
                .iter()
                .chain(b.members.iter())
                .map(|&m| &self.trust[m])
                .collect();
            if domains.len() > 1 || domains.contains(&None) {
                return false;
            }
        }
        for &i in &a.members {
            for &j in &b.members {
                if self.cooling.contains(&(i.min(j), i.max(j))) {
                    return false;
                }
            }
        }
        true
    }

    /// All hard constraints on an emitted target: structural ones plus
    /// zero overflow.  Initial states from adversarial snapshots may fail
    /// this; the search only emits targets that pass.
    fn hard_valid(&self, state: &State) -> bool {
        for g in &state.groups {
            if g.members.len() < 2 {
                continue;
            }
            if !self.connected(&g.members) {
                return false;
            }
            if self.policy.max_group_size > 0 && g.members.len() > self.policy.max_group_size {
                return false;
            }
            if self.policy.respect_trust_domains {
                let domains: HashSet<&Option<String>> =
                    g.members.iter().map(|&m| &self.trust[m]).collect();
                if domains.len() > 1 || domains.contains(&None) {
                    return false;
                }
            }
            for (k, &i) in g.members.iter().enumerate() {
                for &j in &g.members[k + 1..] {
                    if self.cooling.contains(&(i.min(j), i.max(j))) {
                        return false;
                    }
                }
            }
            if self.policy.max_group_ram_mb > 0.0 {
                let ram: f64 =
                    g.members.iter().map(|&m| self.sigs[m].ram_mb.max(0.0)).sum();
                if ram > self.policy.max_group_ram_mb {
                    return false;
                }
            }
        }
        if !self.capacities.is_empty() {
            let mut load: HashMap<u64, f64> = HashMap::new();
            for g in &state.groups {
                if let Some(node) = g.node {
                    *load.entry(node.0).or_insert(0.0) += self.group_footprint(g);
                }
            }
            for (node, cap) in &self.capacities {
                if load.get(node).copied().unwrap_or(0.0) > *cap {
                    return false;
                }
            }
        }
        true
    }

    /// One random perturbation: merge across a cut edge, extract a member
    /// to a singleton (splitting a disconnected remainder into its
    /// components), or move a group to another node.
    fn propose(&self, state: &State, rng: &mut Rng) -> Option<State> {
        let moveable_nodes = self.nodes.len() >= 2;
        let roll = rng.below(100);
        if moveable_nodes && roll < 20 {
            // move a group to a random other node
            let gi = rng.below(state.groups.len() as u64) as usize;
            let current = state.groups[gi].node;
            let candidates: Vec<NodeId> =
                self.nodes.iter().copied().filter(|n| Some(*n) != current).collect();
            if candidates.is_empty() {
                return None;
            }
            let to = candidates[rng.below(candidates.len() as u64) as usize];
            let mut next = state.clone();
            next.groups[gi].node = Some(to);
            return Some(next);
        }
        if roll < if moveable_nodes { 60 } else { 55 } {
            // merge across a random cut edge
            let owner = self.owner_map(state);
            let cut: Vec<(usize, usize)> = self
                .counts
                .keys()
                .copied()
                .filter(|&(i, j)| owner[i] != owner[j])
                .collect();
            if cut.is_empty() {
                return None;
            }
            let (i, j) = cut[rng.below(cut.len() as u64) as usize];
            let (ga, gb) = (owner[i], owner[j]);
            if !self.merge_admissible(&state.groups[ga], &state.groups[gb]) {
                return None;
            }
            let mut next = state.clone();
            let mut merged = next.groups[ga].members.clone();
            merged.extend(next.groups[gb].members.iter().copied());
            merged.sort_unstable();
            // the fused set lands where the caller's group lives
            let node = next.groups[ga].node.or(next.groups[gb].node);
            let (hi, lo) = if ga > gb { (ga, gb) } else { (gb, ga) };
            next.groups.remove(hi);
            next.groups.remove(lo);
            next.groups.push(Group { members: merged, node });
            return Some(next);
        }
        // extract a random member of a multi-member group
        let multi: Vec<usize> = state
            .groups
            .iter()
            .enumerate()
            .filter(|(_, g)| g.members.len() >= 2)
            .map(|(i, _)| i)
            .collect();
        if multi.is_empty() {
            return None;
        }
        let gi = multi[rng.below(multi.len() as u64) as usize];
        let g = &state.groups[gi];
        let k = rng.below(g.members.len() as u64) as usize;
        let member = g.members[k];
        let mut rest = g.members.clone();
        rest.remove(k);
        let node = g.node;
        let mut next = state.clone();
        next.groups.remove(gi);
        next.groups.push(Group { members: vec![member], node });
        for comp in self.components(&rest) {
            next.groups.push(Group { members: comp, node });
        }
        Some(next)
    }

    /// The ordered plan-diff turning `initial` into `target`:
    /// splits/evicts, then migrations of groups that survive intact, then
    /// fuses along a spanning order of observed sync edges.
    fn diff(&self, initial: &State, target: &State) -> Vec<PlanAction> {
        let tgt_owner = self.owner_map(target);
        let names = |members: &[usize]| -> Vec<String> {
            members.iter().map(|&m| self.names[m].clone()).collect()
        };
        let mut actions = Vec::new();
        // 1. break every current group not contained in a target group;
        //    track each surviving component and the node it came from
        let mut components: Vec<(Vec<usize>, Option<NodeId>)> = Vec::new();
        for g in &initial.groups {
            if g.members.len() < 2 {
                components.push((g.members.clone(), g.node));
                continue;
            }
            let t0 = tgt_owner[g.members[0]];
            if g.members.iter().all(|&m| tgt_owner[m] == t0) {
                components.push((g.members.clone(), g.node));
                continue;
            }
            let evict = if g.members.len() >= 3 {
                g.members.iter().enumerate().find(|&(k, _)| {
                    let rest: Vec<usize> = g
                        .members
                        .iter()
                        .enumerate()
                        .filter(|&(r, _)| r != k)
                        .map(|(_, &m)| m)
                        .collect();
                    let t = tgt_owner[rest[0]];
                    rest.iter().all(|&m| tgt_owner[m] == t)
                })
            } else {
                None
            };
            match evict {
                Some((k, &m)) => {
                    actions.push(PlanAction::Evict {
                        functions: names(&g.members),
                        function: self.names[m].clone(),
                    });
                    let mut rest = g.members.clone();
                    rest.remove(k);
                    components.push((rest, g.node));
                    components.push((vec![m], g.node));
                }
                None => {
                    actions.push(PlanAction::Split { functions: names(&g.members) });
                    for &m in &g.members {
                        components.push((vec![m], g.node));
                    }
                }
            }
        }
        // 2. migrate components that already equal their target group —
        //    fused-to-be components skip this, the fuse pipeline colocates
        for (comp, origin) in &components {
            let t = tgt_owner[comp[0]];
            if target.groups[t].members != *comp {
                continue;
            }
            if let (Some(from), Some(to)) = (*origin, target.groups[t].node) {
                if from != to {
                    actions.push(PlanAction::Migrate { functions: names(comp), to });
                }
            }
        }
        // 3. fuse along a BFS spanning order of observed edges inside
        //    each target group, skipping already-joined components
        let mut uf = Uf::new(self.names.len());
        for (comp, _) in &components {
            for w in comp.windows(2) {
                uf.union(w[0], w[1]);
            }
        }
        let mut tgroups: Vec<&Group> =
            target.groups.iter().filter(|g| g.members.len() >= 2).collect();
        tgroups.sort_by(|a, b| a.members.cmp(&b.members));
        for g in tgroups {
            let set: HashSet<usize> = g.members.iter().copied().collect();
            let root = g.members[0];
            let mut seen = HashSet::from([root]);
            let mut queue = VecDeque::from([root]);
            while let Some(u) = queue.pop_front() {
                for &v in &self.adj[u] {
                    if !set.contains(&v) || !seen.insert(v) {
                        continue;
                    }
                    queue.push_back(v);
                    if uf.find(u) != uf.find(v) {
                        uf.union(u, v);
                        let (caller, callee) =
                            if self.counts.contains_key(&(u, v)) { (u, v) } else { (v, u) };
                        actions.push(PlanAction::Fuse {
                            caller: self.names[caller].clone(),
                            callee: self.names[callee].clone(),
                        });
                    }
                }
            }
        }
        actions
    }

    fn plan_groups(&self, state: &State) -> Vec<PlanGroup> {
        let mut out: Vec<PlanGroup> = state
            .groups
            .iter()
            .map(|g| PlanGroup {
                functions: g.members.iter().map(|&m| self.names[m].clone()).collect(),
                node: g.node,
            })
            .collect();
        out.sort_by(|a, b| a.functions.cmp(&b.functions));
        out
    }
}

/// Plain union-find with path halving (diff bookkeeping).
struct Uf {
    parent: Vec<usize>,
}

impl Uf {
    fn new(n: usize) -> Uf {
        Uf { parent: (0..n).collect() }
    }
    fn find(&mut self, mut x: usize) -> usize {
        while self.parent[x] != x {
            self.parent[x] = self.parent[self.parent[x]];
            x = self.parent[x];
        }
        x
    }
    fn union(&mut self, a: usize, b: usize) {
        let (ra, rb) = (self.find(a), self.find(b));
        if ra != rb {
            self.parent[ra] = rb;
        }
    }
}

/// Search for a better whole-graph partition.  Deterministic for a given
/// (snapshot, policy, seed); returns `None` when the best feasible
/// partition found does not beat the live one by [`REPLAN_MIN_GAIN`], or
/// when the diff is empty.
pub fn search(snap: &PlanSnapshot, policy: &FusionParams, seed: u64, plan_id: u64) -> Option<Plan> {
    let world = World::build(snap, policy);
    if world.names.is_empty() {
        return None;
    }
    let initial = world.initial_state(snap);
    let (obj0, pen0) = world.score(&initial);
    let start_total = obj0 + pen0;

    let mut cur = initial.clone();
    let mut cur_total = start_total;
    let mut best = initial.clone();
    let mut best_total = start_total;
    let mut best_obj = obj0;
    let mut have_best = world.hard_valid(&initial);

    let n = world.names.len();
    let iters = (150 * n).clamp(300, 3000);
    let mut temp = (start_total.abs() * 0.2).max(1e-3);
    let t_end = (temp * 1e-3).max(1e-9);
    let alpha = (t_end / temp).powf(1.0 / iters as f64);
    let mut rng = Rng::new(seed ^ 0x9e37_79b9_7f4a_7c15);

    for _ in 0..iters {
        if let Some(cand) = world.propose(&cur, &mut rng) {
            let (obj, pen) = world.score(&cand);
            let total = obj + pen;
            let d = total - cur_total;
            if d <= 0.0 || rng.f64() < (-d / temp.max(1e-12)).exp() {
                cur = cand;
                cur_total = total;
                if (total < best_total - 1e-12 || !have_best) && world.hard_valid(&cur) {
                    best = cur.clone();
                    best_total = total;
                    best_obj = obj;
                    have_best = true;
                }
            }
        }
        temp *= alpha;
    }

    if !have_best {
        return None;
    }
    let gain = start_total - best_total;
    if gain < REPLAN_MIN_GAIN * start_total.abs().max(1e-9) {
        return None;
    }
    let actions = world.diff(&initial, &best);
    if actions.is_empty() {
        return None;
    }
    let target = world.plan_groups(&best);
    Some(Plan {
        id: plan_id,
        epoch: snap.epoch,
        actions,
        predicted_before: obj0,
        predicted_after: best_obj,
        target,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sig(name: &str, ram_mb: f64, billed_ms: f64, self_ms: f64, gbs: f64) -> FnSignals {
        FnSignals {
            function: Sym::intern(name),
            ram_mb,
            p95_ms: 10.0,
            gb_seconds: gbs,
            billed_ms,
            self_ms,
            window_s: 5.0,
            node: None,
            replicas: 1,
        }
    }

    fn policy() -> FusionParams {
        let mut p = FusionParams::default_enabled();
        p.respect_trust_domains = false;
        p.max_group_size = 0;
        p.max_group_ram_mb = 0.0;
        p
    }

    /// The figure-11 trap in miniature: a -> b -> c chain where every
    /// pairwise fuse is refused by greedy admission (huge combined RAM
    /// against the churn gate) but the all-fused partition strictly wins
    /// the whole-partition objective.  The global search must find it.
    #[test]
    fn search_escapes_the_pairwise_trap_on_a_chain() {
        let p = policy();
        let snap = PlanSnapshot {
            epoch: 7,
            signals: vec![
                sig("a", 60.0, 4000.0, 500.0, 1.0),
                sig("b", 600.0, 4000.0, 500.0, 1.5),
                sig("c", 60.0, 1000.0, 900.0, 0.5),
            ],
            edges: vec![
                (("a".into(), "b".into()), 200),
                (("b".into(), "c".into()), 200),
            ],
            groups: Vec::new(),
            node_loads: Vec::new(),
            migration_est_ms: 0.0,
            trust: BTreeMap::new(),
            cooling: Vec::new(),
        };
        let plan = search(&snap, &p, 42, 1).expect("chain trap must yield a plan");
        assert_eq!(plan.epoch, 7);
        assert!(plan.predicted_after < plan.predicted_before);
        let target: Vec<Vec<String>> =
            plan.target.iter().map(|g| g.functions.clone()).collect();
        assert_eq!(target, vec![vec!["a".to_string(), "b".into(), "c".into()]]);
        // replaying the diff reproduces the target exactly
        let replayed = apply_diff(&snapshot_partition(&snap), &plan.actions);
        assert_eq!(replayed, target);
        // fuses are oriented along observed edges
        for a in &plan.actions {
            if let PlanAction::Fuse { caller, callee } = a {
                assert!(snap
                    .edges
                    .iter()
                    .any(|((x, y), _)| (x == caller && y == callee)
                        || (x == callee && y == caller)));
            }
        }
    }

    #[test]
    fn optimal_snapshot_yields_no_plan() {
        let p = policy();
        let snap = PlanSnapshot {
            epoch: 0,
            signals: vec![
                sig("a", 60.0, 4000.0, 500.0, 1.0),
                sig("b", 60.0, 1000.0, 900.0, 0.5),
            ],
            edges: vec![(("a".into(), "b".into()), 100)],
            groups: vec![vec!["a".into(), "b".into()]],
            node_loads: Vec::new(),
            migration_est_ms: 0.0,
            trust: BTreeMap::new(),
            cooling: Vec::new(),
        };
        assert!(search(&snap, &p, 1, 1).is_none());
    }

    #[test]
    fn cooling_pair_is_not_regrouped() {
        let p = policy();
        let snap = PlanSnapshot {
            epoch: 0,
            signals: vec![
                sig("a", 60.0, 4000.0, 500.0, 1.0),
                sig("b", 60.0, 1000.0, 900.0, 0.5),
            ],
            edges: vec![(("a".into(), "b".into()), 100)],
            groups: Vec::new(),
            node_loads: Vec::new(),
            migration_est_ms: 0.0,
            trust: BTreeMap::new(),
            cooling: vec![("a".into(), "b".into())],
        };
        assert!(search(&snap, &p, 3, 1).is_none());
    }

    #[test]
    fn trust_domains_partition_the_search_space() {
        let mut p = policy();
        p.respect_trust_domains = true;
        let mut trust = BTreeMap::new();
        trust.insert("a".to_string(), "alpha".to_string());
        trust.insert("b".to_string(), "beta".to_string());
        let snap = PlanSnapshot {
            epoch: 0,
            signals: vec![
                sig("a", 60.0, 4000.0, 500.0, 1.0),
                sig("b", 60.0, 1000.0, 900.0, 0.5),
            ],
            edges: vec![(("a".into(), "b".into()), 100)],
            groups: Vec::new(),
            node_loads: Vec::new(),
            migration_est_ms: 0.0,
            trust,
            cooling: Vec::new(),
        };
        assert!(search(&snap, &p, 5, 1).is_none());
    }

    #[test]
    fn node_capacity_blocks_an_otherwise_winning_fuse() {
        let p = policy();
        let mut a = sig("a", 400.0, 4000.0, 500.0, 1.0);
        a.node = Some(NodeId(0));
        let mut b = sig("b", 400.0, 1000.0, 900.0, 0.5);
        b.node = Some(NodeId(1));
        let snap = PlanSnapshot {
            epoch: 0,
            signals: vec![a, b],
            edges: vec![(("a".into(), "b".into()), 100)],
            groups: Vec::new(),
            node_loads: vec![
                NodeLoad { node: NodeId(0), ram_mb: 400.0, capacity_mb: 500.0 },
                NodeLoad { node: NodeId(1), ram_mb: 400.0, capacity_mb: 500.0 },
            ],
            migration_est_ms: 100.0,
            trust: BTreeMap::new(),
            cooling: Vec::new(),
        };
        // the fused group (800 MiB) fits on no node: any emitted plan must
        // keep a and b apart
        if let Some(plan) = search(&snap, &p, 11, 1) {
            for g in &plan.target {
                assert!(g.functions.len() < 2, "over-capacity group emitted: {:?}", g);
            }
        }
    }

    #[test]
    fn apply_diff_replays_split_evict_fuse() {
        let initial = vec![
            vec!["a".to_string(), "b".into(), "c".into()],
            vec!["d".to_string()],
        ];
        let actions = vec![
            PlanAction::Evict {
                functions: vec!["a".into(), "b".into(), "c".into()],
                function: "c".into(),
            },
            PlanAction::Fuse { caller: "c".into(), callee: "d".into() },
        ];
        let out = apply_diff(&initial, &actions);
        assert_eq!(
            out,
            vec![
                vec!["a".to_string(), "b".into()],
                vec!["c".to_string(), "d".into()],
            ]
        );
    }

    #[test]
    fn partition_objective_prices_cut_edges_and_residency() {
        let p = policy();
        let model = CostModel::from_params(&p);
        let snap = PlanSnapshot {
            epoch: 0,
            signals: vec![
                sig("a", 60.0, 4000.0, 500.0, 1.0),
                sig("b", 60.0, 1000.0, 900.0, 0.5),
            ],
            edges: vec![(("a".into(), "b".into()), 100)],
            groups: Vec::new(),
            node_loads: Vec::new(),
            migration_est_ms: 0.0,
            trust: BTreeMap::new(),
            cooling: Vec::new(),
        };
        let split = vec![vec!["a".to_string()], vec!["b".to_string()]];
        let fused = vec![vec!["a".to_string(), "b".to_string()]];
        let split_cost = partition_objective(&snap, &split, &model);
        let fused_cost = partition_objective(&snap, &fused, &model);
        // fusing removes the cut edge; residency is linear so with equal
        // replica counts the fused partition strictly wins
        assert!(fused_cost < split_cost);
        // and the delta is exactly the edge's cut cost
        let sa = &snap.signals[0];
        let sb = &snap.signals[1];
        let delta = split_cost - fused_cost;
        assert!((delta - model.cut_cost(sa, sb, 1.0)).abs() < 1e-9);
    }
}
