//! Images as content-addressed filesystem manifests.
//!
//! The Merger's filesystem-union step operates on these: `FsManifest` is the
//! simulated analog of a container filesystem export, and the
//! collision-preserving union (paper §3: "the Merger preserves the original
//! identifiers of each function instance while copying them into the shared
//! file system") lives in `merger::fsunion` on top of these primitives.

/// Unique image identifier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ImageId(pub u64);

impl std::fmt::Display for ImageId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "img-{}", self.0)
    }
}

/// One file inside a container filesystem.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FileEntry {
    /// absolute path inside the container
    pub path: String,
    /// file size (KiB) — drives image-size accounting
    pub size_kb: u64,
    /// content digest (synthetic; collisions model identical files)
    pub digest: u64,
}

/// A container filesystem as a sorted list of file entries.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct FsManifest {
    entries: Vec<FileEntry>,
}

impl FsManifest {
    pub fn new(mut entries: Vec<FileEntry>) -> Self {
        entries.sort_by(|a, b| a.path.cmp(&b.path));
        entries.dedup_by(|a, b| a.path == b.path);
        FsManifest { entries }
    }

    /// Synthesize the filesystem of a single deployed function: language
    /// runtime layer + handler shim + the function's code directory.  The
    /// layout mirrors the paper's bring-your-own-code model where the
    /// platform owns the entry point and the code lives in a predictable
    /// directory.
    pub fn function_code(name: &str, code_kb: u64) -> Self {
        let digest = fnv1a(name.as_bytes());
        FsManifest::new(vec![
            FileEntry {
                path: "/runtime/python3.11".into(),
                size_kb: 48_000,
                digest: 0xBA5E,
            },
            FileEntry {
                path: "/platform/handler.py".into(),
                size_kb: 64,
                digest: 0x4A4D,
            },
            FileEntry {
                path: format!("/app/{name}/main.py"),
                size_kb: code_kb,
                digest,
            },
            FileEntry {
                path: format!("/app/{name}/requirements.txt"),
                size_kb: 1,
                digest: digest ^ 0xDEAD,
            },
        ])
    }

    pub fn entries(&self) -> &[FileEntry] {
        &self.entries
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn total_kb(&self) -> u64 {
        self.entries.iter().map(|e| e.size_kb).sum()
    }

    pub fn contains_path(&self, path: &str) -> bool {
        self.entries.binary_search_by(|e| e.path.as_str().cmp(path)).is_ok()
    }

    pub fn get(&self, path: &str) -> Option<&FileEntry> {
        self.entries
            .binary_search_by(|e| e.path.as_str().cmp(path))
            .ok()
            .map(|i| &self.entries[i])
    }

    /// Paths under a prefix (e.g. all code of one function).
    pub fn under<'a>(&'a self, prefix: &'a str) -> impl Iterator<Item = &'a FileEntry> + 'a {
        self.entries.iter().filter(move |e| e.path.starts_with(prefix))
    }
}

/// An image: a filesystem plus the functions it hosts.
#[derive(Debug, Clone)]
pub struct Image {
    pub id: ImageId,
    pub manifest: FsManifest,
    /// (function name, code+deps RAM footprint MiB)
    pub functions: Vec<(String, f64)>,
}

impl Image {
    pub fn code_ram_mb(&self) -> f64 {
        self.functions.iter().map(|(_, mb)| mb).sum()
    }

    pub fn hosts(&self, function: &str) -> bool {
        self.functions.iter().any(|(f, _)| f == function)
    }
}

pub(crate) fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_sorted_and_deduped() {
        let m = FsManifest::new(vec![
            FileEntry { path: "/b".into(), size_kb: 1, digest: 1 },
            FileEntry { path: "/a".into(), size_kb: 2, digest: 2 },
            FileEntry { path: "/b".into(), size_kb: 3, digest: 3 },
        ]);
        assert_eq!(m.len(), 2);
        assert_eq!(m.entries()[0].path, "/a");
        assert!(m.contains_path("/b"));
        assert!(!m.contains_path("/c"));
    }

    #[test]
    fn function_code_layout() {
        let m = FsManifest::function_code("temperature", 120);
        assert!(m.contains_path("/app/temperature/main.py"));
        assert!(m.contains_path("/platform/handler.py"));
        assert!(m.contains_path("/runtime/python3.11"));
        assert_eq!(m.get("/app/temperature/main.py").unwrap().size_kb, 120);
    }

    #[test]
    fn distinct_functions_distinct_digests() {
        let a = FsManifest::function_code("a", 10);
        let b = FsManifest::function_code("b", 10);
        assert_ne!(
            a.get("/app/a/main.py").unwrap().digest,
            b.get("/app/b/main.py").unwrap().digest
        );
        // shared runtime layer has identical digest (dedupable)
        assert_eq!(
            a.get("/runtime/python3.11").unwrap().digest,
            b.get("/runtime/python3.11").unwrap().digest
        );
    }

    #[test]
    fn under_prefix() {
        let m = FsManifest::function_code("x", 10);
        assert_eq!(m.under("/app/x/").count(), 2);
        assert_eq!(m.under("/nope").count(), 0);
    }

    #[test]
    fn image_accessors() {
        let img = Image {
            id: ImageId(1),
            manifest: FsManifest::function_code("a", 1),
            functions: vec![("a".into(), 9.0), ("b".into(), 6.5)],
        };
        assert!((img.code_ram_mb() - 15.5).abs() < 1e-12);
        assert!(img.hosts("a") && img.hosts("b") && !img.hosts("c"));
    }
}
