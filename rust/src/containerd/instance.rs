//! Instance lifecycle state machine + per-instance RAM accounting.

use std::cell::{Cell, RefCell};
use std::collections::BTreeMap;
use std::rc::Rc;

use super::image::{Image, ImageId};
use crate::config::PlatformConfig;
use crate::error::{Error, Result};
use crate::exec::sync::{Gauge, Notify};

/// Unique instance identifier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct InstanceId(pub u64);

impl std::fmt::Display for InstanceId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "inst-{}", self.0)
    }
}

/// Lifecycle:
/// `Booting -> Healthy -> Draining -> Terminated`; any live state may also
/// jump directly to `Terminated` on a rollback of a never-routed instance.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InstanceState {
    Booting,
    Healthy,
    Draining,
    Terminated,
}

impl InstanceState {
    pub fn name(&self) -> &'static str {
        match self {
            InstanceState::Booting => "Booting",
            InstanceState::Healthy => "Healthy",
            InstanceState::Draining => "Draining",
            InstanceState::Terminated => "Terminated",
        }
    }

    pub fn is_live(&self) -> bool {
        !matches!(self, InstanceState::Terminated)
    }
}

/// One container instance.
pub struct Instance {
    id: InstanceId,
    /// mutable so a warm-pool instance (booted from the blank warm image)
    /// can adopt a function image at claim time without a re-boot
    image: RefCell<Rc<Image>>,
    config: Rc<PlatformConfig>,
    state: Cell<InstanceState>,
    /// functions actively served: the image's hosted set minus members the
    /// defusion controller evicted (a fused group "shrinks in place" — the
    /// instance keeps running while an evicted function's code is unloaded)
    active: RefCell<Vec<(String, f64)>>,
    /// in-flight request gauge (awaitable for drain)
    inflight: Gauge,
    /// per-function in-flight ownership (remote arrivals only; inlined
    /// child calls ride their caller's request) — the weighting signal for
    /// `metrics::attribute_ram`
    fn_inflight: RefCell<BTreeMap<String, i64>>,
    /// lifetime request count (merge observability)
    served: Cell<u64>,
    /// requests holding a concurrency slot (distinct from `inflight`: a
    /// slot is taken before boot-wait/billing so queued arrivals don't
    /// stampede the instance the moment one finishes)
    busy: Cell<i64>,
    /// wakes one queued arrival when a concurrency slot frees up
    slot_freed: Notify,
}

impl Instance {
    pub(crate) fn new(id: InstanceId, image: Rc<Image>, config: Rc<PlatformConfig>) -> Self {
        let active = RefCell::new(image.functions.clone());
        Instance {
            id,
            image: RefCell::new(image),
            config,
            state: Cell::new(InstanceState::Booting),
            active,
            inflight: Gauge::new(),
            fn_inflight: RefCell::new(BTreeMap::new()),
            served: Cell::new(0),
            busy: Cell::new(0),
            slot_freed: Notify::new(),
        }
    }

    pub fn id(&self) -> InstanceId {
        self.id
    }

    pub fn image(&self) -> ImageId {
        self.image.borrow().id
    }

    /// Swap in a new image and serve its function set — the warm-pool
    /// claim step: a pre-booted blank instance becomes a replica of the
    /// claiming function without paying boot latency (only the much
    /// smaller code-attach delay, modeled by the scaler).
    pub fn adopt_image(&self, image: Rc<Image>) {
        *self.active.borrow_mut() = image.functions.clone();
        *self.image.borrow_mut() = image;
    }

    /// Functions actively served by this instance (name, code MiB).  Starts
    /// as the image's hosted set; shrinks when members are evicted.
    pub fn functions(&self) -> Vec<(String, f64)> {
        self.active.borrow().clone()
    }

    /// Number of actively served functions (allocation-free: the hot
    /// controller/gateway paths only need the count).
    pub fn fn_count(&self) -> usize {
        self.active.borrow().len()
    }

    pub fn hosts(&self, function: &str) -> bool {
        self.active.borrow().iter().any(|(n, _)| n == function)
    }

    /// Stop serving `function` and unload its code (the partial-split
    /// pipeline's "shrink in place" step; the route must already point at
    /// the replacement instance).  Refuses to empty the instance — a group
    /// down to one member takes the whole-group split path instead.
    pub fn evict_function(&self, function: &str) -> Result<()> {
        let mut active = self.active.borrow_mut();
        let Some(pos) = active.iter().position(|(n, _)| n == function) else {
            return Err(Error::SplitAborted(format!(
                "instance {} does not actively host `{function}`",
                self.id
            )));
        };
        if active.len() <= 1 {
            return Err(Error::SplitAborted(format!(
                "evicting `{function}` would empty instance {}",
                self.id
            )));
        }
        active.remove(pos);
        Ok(())
    }

    pub fn state(&self) -> InstanceState {
        self.state.get()
    }

    pub fn inflight(&self) -> i64 {
        self.inflight.value()
    }

    pub fn served(&self) -> u64 {
        self.served.get()
    }

    /// Code + dependency RAM of the actively served functions (MiB).
    fn active_code_mb(&self) -> f64 {
        self.active.borrow().iter().map(|(_, mb)| mb).sum()
    }

    /// Static memory allocation (MiB) a provider would bill this instance
    /// at: base runtime + active code (no transient working sets).
    pub fn alloc_mb(&self) -> f64 {
        self.config.ram.base_instance_mb + self.active_code_mb()
    }

    /// RAM footprint (MiB): base runtime + active code + in-flight working
    /// sets.  Fusion saves the `(N-1) * base` term — the paper's §5.2 RAM
    /// reduction — and an eviction sheds the evicted function's code.
    pub fn ram_mb(&self) -> f64 {
        if !self.state.get().is_live() {
            return 0.0;
        }
        let r = &self.config.ram;
        r.base_instance_mb
            + self.active_code_mb()
            + self.inflight.value() as f64 * r.working_per_request_mb
    }

    // -- request accounting ---------------------------------------------------

    pub fn request_started(&self) {
        self.inflight.add(1);
        self.served.set(self.served.get() + 1);
    }

    pub fn request_finished(&self) {
        self.inflight.sub(1);
    }

    /// Like [`Instance::request_started`], attributing the in-flight slot
    /// to `function` (the remote arrival's target) so the controller can
    /// weight working-set RAM by in-flight ownership.
    pub fn request_started_for(&self, function: &str) {
        self.request_started();
        *self.fn_inflight.borrow_mut().entry(function.to_string()).or_insert(0) += 1;
    }

    /// Companion to [`Instance::request_started_for`].
    pub fn request_finished_for(&self, function: &str) {
        self.request_finished();
        if let Some(n) = self.fn_inflight.borrow_mut().get_mut(function) {
            *n = (*n - 1).max(0);
        }
    }

    /// In-flight requests currently attributed to `function` (0 when the
    /// function never received an attributed arrival).
    pub fn fn_inflight(&self, function: &str) -> u64 {
        self.fn_inflight.borrow().get(function).copied().unwrap_or(0).max(0) as u64
    }

    /// Await zero in-flight requests (merge drain step).
    pub async fn drained(&self) {
        self.inflight.wait_zero().await;
    }

    // -- concurrency slots ----------------------------------------------------

    /// Acquire one of `cap` concurrency slots, queueing (FIFO-ish via
    /// [`Notify`] wakeups) until one frees.  `cap == 0` means unlimited —
    /// the seed behavior — and returns immediately without touching the
    /// slot counter, so default configs take zero overhead here.
    pub async fn acquire_slot(&self, cap: u32) {
        if cap == 0 {
            return;
        }
        loop {
            if self.busy.get() < cap as i64 {
                self.busy.set(self.busy.get() + 1);
                return;
            }
            self.slot_freed.notified().await;
        }
    }

    /// Release a slot taken by [`Instance::acquire_slot`] and wake one
    /// queued arrival.  Must be called with the same `cap` (a no-op at 0).
    pub fn release_slot(&self, cap: u32) {
        if cap == 0 {
            return;
        }
        self.busy.set((self.busy.get() - 1).max(0));
        self.slot_freed.notify_one();
    }

    /// Requests currently holding a concurrency slot (0 under unlimited
    /// concurrency — the slot counter is bypassed entirely).
    pub fn busy_slots(&self) -> i64 {
        self.busy.get()
    }

    // -- lifecycle transitions -------------------------------------------------

    pub(crate) fn mark_healthy(&self) {
        // A hung/rolled-back instance may have been terminated while booting.
        if self.state.get() == InstanceState::Booting {
            self.state.set(InstanceState::Healthy);
        }
    }

    /// Stop accepting new traffic (router must already point elsewhere).
    pub fn begin_drain(&self) -> Result<()> {
        match self.state.get() {
            InstanceState::Healthy | InstanceState::Booting => {
                self.state.set(InstanceState::Draining);
                Ok(())
            }
            s => Err(Error::BadTransition {
                instance: self.id.0,
                from: s.name(),
                to: "Draining",
            }),
        }
    }

    pub(crate) fn mark_terminated(&self) -> Result<()> {
        match self.state.get() {
            InstanceState::Draining | InstanceState::Booting => {
                self.state.set(InstanceState::Terminated);
                Ok(())
            }
            InstanceState::Healthy => Err(Error::BadTransition {
                instance: self.id.0,
                from: "Healthy",
                to: "Terminated (must drain first)",
            }),
            InstanceState::Terminated => Ok(()), // idempotent
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::containerd::FsManifest;

    fn instance() -> Instance {
        let config = Rc::new(PlatformConfig::tiny());
        let image = Rc::new(Image {
            id: ImageId(1),
            manifest: FsManifest::function_code("a", 10),
            functions: vec![("a".into(), 9.0)],
        });
        Instance::new(InstanceId(1), image, config)
    }

    fn fused_instance() -> Instance {
        let config = Rc::new(PlatformConfig::tiny());
        let image = Rc::new(Image {
            id: ImageId(2),
            manifest: FsManifest::function_code("ab", 10),
            functions: vec![("a".into(), 9.0), ("b".into(), 30.0)],
        });
        Instance::new(InstanceId(2), image, config)
    }

    #[test]
    fn lifecycle_happy_path() {
        let i = instance();
        assert_eq!(i.state(), InstanceState::Booting);
        i.mark_healthy();
        assert_eq!(i.state(), InstanceState::Healthy);
        i.begin_drain().unwrap();
        assert_eq!(i.state(), InstanceState::Draining);
        i.mark_terminated().unwrap();
        assert_eq!(i.state(), InstanceState::Terminated);
        assert!(!i.state().is_live());
    }

    #[test]
    fn healthy_cannot_terminate_directly() {
        let i = instance();
        i.mark_healthy();
        assert!(i.mark_terminated().is_err());
    }

    #[test]
    fn drain_from_terminated_fails() {
        let i = instance();
        i.begin_drain().unwrap();
        i.mark_terminated().unwrap();
        assert!(i.begin_drain().is_err());
    }

    #[test]
    fn terminated_instance_has_zero_ram() {
        let i = instance();
        i.mark_healthy();
        assert!(i.ram_mb() > 0.0);
        i.begin_drain().unwrap();
        i.mark_terminated().unwrap();
        assert_eq!(i.ram_mb(), 0.0);
    }

    #[test]
    fn ram_includes_inflight_working_sets() {
        let i = instance();
        i.mark_healthy();
        let idle = i.ram_mb();
        i.request_started();
        i.request_started();
        assert!((i.ram_mb() - idle - 3.0).abs() < 1e-12); // 2 x 1.5 MiB
        i.request_finished();
        i.request_finished();
        assert_eq!(i.ram_mb(), idle);
        assert_eq!(i.served(), 2);
    }

    #[test]
    fn fn_inflight_tracks_per_function_ownership() {
        let i = fused_instance();
        i.mark_healthy();
        i.request_started_for("a");
        i.request_started_for("a");
        i.request_started_for("b");
        assert_eq!(i.fn_inflight("a"), 2);
        assert_eq!(i.fn_inflight("b"), 1);
        assert_eq!(i.fn_inflight("ghost"), 0);
        assert_eq!(i.inflight(), 3, "attributed starts must feed the drain gauge");
        i.request_finished_for("a");
        i.request_finished_for("b");
        // per-function over-finishing clamps at zero instead of going
        // negative (the gauge itself stays balanced: 3 starts, 3 finishes)
        i.request_finished_for("b");
        assert_eq!(i.fn_inflight("a"), 1);
        assert_eq!(i.fn_inflight("b"), 0);
        assert_eq!(i.inflight(), 0);
    }

    #[test]
    fn evict_shrinks_active_set_and_sheds_code_ram() {
        let i = fused_instance();
        i.mark_healthy();
        assert!(i.hosts("a") && i.hosts("b"));
        let before = i.ram_mb();
        i.evict_function("b").unwrap();
        assert!(!i.hosts("b"));
        assert!(i.hosts("a"));
        assert_eq!(i.functions().len(), 1);
        // the evicted function's 30 MiB of code is unloaded
        assert!((before - i.ram_mb() - 30.0).abs() < 1e-12);
        assert!((i.alloc_mb() - (58.0 + 9.0)).abs() < 1e-12);
    }

    #[test]
    fn evict_rejects_unknown_and_refuses_to_empty() {
        let i = fused_instance();
        i.mark_healthy();
        assert!(i.evict_function("ghost").is_err());
        i.evict_function("a").unwrap();
        // sole remaining member must stay
        assert!(i.evict_function("b").is_err());
        assert!(i.hosts("b"));
    }

    #[test]
    fn adopt_image_swaps_function_set() {
        let i = instance();
        i.mark_healthy();
        assert!(i.hosts("a"));
        let fused = Rc::new(Image {
            id: ImageId(7),
            manifest: FsManifest::function_code("bc", 10),
            functions: vec![("b".into(), 9.0), ("c".into(), 30.0)],
        });
        i.adopt_image(Rc::clone(&fused));
        assert_eq!(i.image(), ImageId(7));
        assert!(!i.hosts("a"));
        assert!(i.hosts("b") && i.hosts("c"));
        assert_eq!(i.functions().len(), 2);
    }

    #[test]
    fn slot_cap_zero_is_unlimited_and_free() {
        crate::exec::run_virtual(async {
            let i = Rc::new(instance());
            i.mark_healthy();
            for _ in 0..100 {
                i.acquire_slot(0).await;
            }
            assert_eq!(i.busy_slots(), 0, "cap 0 must bypass the counter");
            i.release_slot(0);
            assert_eq!(i.busy_slots(), 0);
        });
    }

    #[test]
    fn slots_queue_and_wake_in_order() {
        use std::cell::RefCell;
        crate::exec::run_virtual(async {
            let i = Rc::new(instance());
            i.mark_healthy();
            let order: Rc<RefCell<Vec<u32>>> = Rc::new(RefCell::new(Vec::new()));
            for k in 0..4u32 {
                let i = Rc::clone(&i);
                let order = Rc::clone(&order);
                crate::exec::spawn(async move {
                    i.acquire_slot(2).await;
                    order.borrow_mut().push(k);
                    crate::exec::sleep_ms(10.0).await;
                    i.release_slot(2);
                });
            }
            crate::exec::sleep_ms(5.0).await;
            assert_eq!(i.busy_slots(), 2, "only cap slots admitted at once");
            assert_eq!(order.borrow().len(), 2);
            crate::exec::sleep_ms(100.0).await;
            assert_eq!(order.borrow().as_slice(), &[0, 1, 2, 3]);
            assert_eq!(i.busy_slots(), 0);
        });
    }

    #[test]
    fn healthy_after_terminate_is_noop() {
        let i = instance();
        i.begin_drain().unwrap();
        i.mark_terminated().unwrap();
        i.mark_healthy(); // must not resurrect
        assert_eq!(i.state(), InstanceState::Terminated);
    }
}
