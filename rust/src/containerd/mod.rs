//! Simulated container runtime (DESIGN.md substitution #1).
//!
//! The paper's Merger manipulates real containers: it exports their
//! filesystems, unions them, builds a new image, deploys it, and terminates
//! the originals.  This module reproduces that control surface — images as
//! content-addressed layer manifests, instances as lifecycle state machines
//! with calibrated boot/build latencies and a RAM ledger — so the Merger
//! exercises the identical control flow with synthetic bytes.

mod image;
mod instance;

pub use image::{FileEntry, FsManifest, Image, ImageId};
pub use instance::{Instance, InstanceId, InstanceState};

use std::cell::{Cell, RefCell};
use std::collections::HashMap;
use std::rc::Rc;

use crate::config::PlatformConfig;
use crate::error::{Error, Result};
use crate::exec;

/// Functions hosted by an image: (function name, code+deps footprint MiB).
pub type HostedFunctions = Vec<(String, f64)>;

/// Content-addressed image registry.  One store may back several
/// [`ContainerRuntime`]s: a multi-node cluster shares images (any node can
/// pull any image) while each node keeps its own instance registry.  The
/// store also allocates **cluster-unique** instance ids, so instances on
/// different nodes can never alias in the routing table or the invariant
/// oracles.
pub struct ImageStore {
    images: RefCell<HashMap<ImageId, Rc<Image>>>,
    next_image: Cell<u64>,
    next_instance: Cell<u64>,
}

impl ImageStore {
    pub fn new() -> Self {
        ImageStore {
            images: RefCell::new(HashMap::new()),
            next_image: Cell::new(1),
            next_instance: Cell::new(1),
        }
    }
}

impl Default for ImageStore {
    fn default() -> Self {
        Self::new()
    }
}

/// Handle to the simulated container runtime (cheaply clonable).
#[derive(Clone)]
pub struct ContainerRuntime {
    inner: Rc<RuntimeInner>,
}

struct RuntimeInner {
    config: Rc<PlatformConfig>,
    images: Rc<ImageStore>,
    instances: RefCell<HashMap<InstanceId, Rc<Instance>>>,
    /// fault injection: number of upcoming builds that must fail
    failing_builds: Cell<u32>,
    /// fault injection: number of upcoming launches that never get healthy
    hanging_boots: Cell<u32>,
}

impl ContainerRuntime {
    pub fn new(config: Rc<PlatformConfig>) -> Self {
        Self::with_images(config, Rc::new(ImageStore::new()))
    }

    /// A runtime sharing `images` with other runtimes (per-node runtimes of
    /// one cluster all see the same registry, and the store also allocates
    /// the instance ids, so they stay unique across every sharing runtime).
    pub fn with_images(config: Rc<PlatformConfig>, images: Rc<ImageStore>) -> Self {
        ContainerRuntime {
            inner: Rc::new(RuntimeInner {
                config,
                images,
                instances: RefCell::new(HashMap::new()),
                failing_builds: Cell::new(0),
                hanging_boots: Cell::new(0),
            }),
        }
    }

    /// The image registry backing this runtime (share it with
    /// [`ContainerRuntime::with_images`] to model a cluster-wide registry).
    pub fn image_store(&self) -> Rc<ImageStore> {
        Rc::clone(&self.inner.images)
    }

    // -- images --------------------------------------------------------------

    /// Register a pre-built image (initial function deployment artifacts
    /// exist before the experiment starts; no build cost).
    pub fn register_image(&self, manifest: FsManifest, functions: HostedFunctions) -> ImageId {
        let store = &self.inner.images;
        let id = ImageId(store.next_image.get());
        store.next_image.set(id.0 + 1);
        let image = Rc::new(Image { id, manifest, functions });
        store.images.borrow_mut().insert(id, image);
        id
    }

    /// Build a new image at runtime (the Merger's fused images): charges the
    /// calibrated export+union+build latency on the virtual clock.
    pub async fn build_image(
        &self,
        manifest: FsManifest,
        functions: HostedFunctions,
    ) -> Result<ImageId> {
        exec::sleep_ms(self.inner.config.latency.image_build_ms).await;
        if self.inner.failing_builds.get() > 0 {
            self.inner.failing_builds.set(self.inner.failing_builds.get() - 1);
            return Err(Error::FusionAborted("injected image build failure".into()));
        }
        Ok(self.register_image(manifest, functions))
    }

    pub fn image(&self, id: ImageId) -> Result<Rc<Image>> {
        self.inner
            .images
            .images
            .borrow()
            .get(&id)
            .cloned()
            .ok_or(Error::UnknownImage(id.0))
    }

    /// Export a live instance's filesystem (the Merger's first step).
    pub fn export_fs(&self, instance: &Instance) -> Result<FsManifest> {
        let image = self.image(instance.image())?;
        Ok(image.manifest.clone())
    }

    // -- instances -----------------------------------------------------------

    /// Start a container from `image`. Returns immediately with the handle
    /// in `Booting` state; a background task flips it to `Healthy` after the
    /// calibrated boot latency (or never, under injected boot hangs).
    pub fn launch(&self, image_id: ImageId) -> Result<Rc<Instance>> {
        let image = self.image(image_id)?;
        let id = InstanceId(self.inner.images.next_instance.get());
        self.inner.images.next_instance.set(id.0 + 1);
        let instance = Rc::new(Instance::new(id, image, self.inner.config.clone()));
        self.inner.instances.borrow_mut().insert(id, Rc::clone(&instance));

        let hang = self.inner.hanging_boots.get() > 0;
        if hang {
            self.inner.hanging_boots.set(self.inner.hanging_boots.get() - 1);
        }
        let boot_ms = self.inner.config.latency.boot_ms;
        let inst = Rc::clone(&instance);
        exec::spawn(async move {
            if hang {
                return; // stays Booting forever (fault injection)
            }
            exec::sleep_ms(boot_ms).await;
            inst.mark_healthy();
        });
        Ok(instance)
    }

    pub fn instance(&self, id: InstanceId) -> Result<Rc<Instance>> {
        self.inner
            .instances
            .borrow()
            .get(&id)
            .cloned()
            .ok_or(Error::UnknownInstance(id.0))
    }

    /// Probe an instance's health endpoint (charged a trivial cost by the
    /// caller's polling interval, not here).
    pub fn health_check(&self, instance: &Instance) -> bool {
        instance.state() == InstanceState::Healthy
    }

    /// Terminate an instance (caller must have drained it; termination of a
    /// draining instance with in-flight requests is a platform bug).
    pub fn terminate(&self, instance: &Instance) -> Result<()> {
        if instance.inflight() > 0 {
            return Err(Error::BadTransition {
                instance: instance.id().0,
                from: instance.state().name(),
                to: "Terminated (inflight > 0)",
            });
        }
        instance.mark_terminated()
    }

    /// All live (non-terminated) instances.
    pub fn live_instances(&self) -> Vec<Rc<Instance>> {
        self.inner
            .instances
            .borrow()
            .values()
            .filter(|i| i.state() != InstanceState::Terminated)
            .cloned()
            .collect()
    }

    /// Total platform RAM across live instances (MiB) — the paper's
    /// resource-efficiency metric.
    pub fn total_ram_mb(&self) -> f64 {
        self.live_instances().iter().map(|i| i.ram_mb()).sum()
    }

    pub fn live_count(&self) -> usize {
        self.live_instances().len()
    }

    // -- fault injection -------------------------------------------------------

    pub fn inject_build_failures(&self, n: u32) {
        self.inner.failing_builds.set(self.inner.failing_builds.get() + n);
    }

    pub fn inject_boot_hangs(&self, n: u32) {
        self.inner.hanging_boots.set(self.inner.hanging_boots.get() + n);
    }
}

/// Poll `inst` until `health_checks_required` consecutive healthy checks or
/// the deadline (4x boot + 5 s) expires — the shared health gate every
/// traffic-moving pipeline (fuse, split, evict, migration) runs before a
/// cutover, so a deadline tuning can never diverge between them.
pub async fn await_healthy(latency: &crate::config::LatencyParams, inst: &Instance) -> Result<()> {
    let deadline_ms = exec::now().as_millis_f64() + latency.boot_ms * 4.0 + 5_000.0;
    let mut passes = 0u32;
    loop {
        exec::sleep_ms(latency.health_interval_ms).await;
        if inst.state() == InstanceState::Healthy {
            passes += 1;
            if passes >= latency.health_checks_required {
                return Ok(());
            }
        } else {
            passes = 0;
        }
        if exec::now().as_millis_f64() > deadline_ms {
            return Err(Error::HealthTimeout(inst.id().0));
        }
    }
}

/// Detached reclaim: terminate `old` once its in-flight requests drain and
/// bump `instances_reclaimed` — the shared tail of the fuse, split, and
/// migration pipelines ("stopped and deleted as soon as they are no longer
/// processing requests").
pub fn reclaim_when_drained(
    containers: ContainerRuntime,
    metrics: crate::metrics::Recorder,
    old: Rc<Instance>,
) {
    exec::spawn(async move {
        old.drained().await;
        if containers.terminate(&old).is_ok() {
            metrics.bump("instances_reclaimed");
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::{now, run_virtual};

    fn runtime() -> ContainerRuntime {
        ContainerRuntime::new(Rc::new(PlatformConfig::tiny()))
    }

    fn manifest_for(name: &str) -> FsManifest {
        FsManifest::function_code(name, 42)
    }

    #[test]
    fn launch_becomes_healthy_after_boot() {
        run_virtual(async {
            let rt = runtime();
            let img = rt.register_image(manifest_for("a"), vec![("a".into(), 9.0)]);
            let inst = rt.launch(img).unwrap();
            assert_eq!(inst.state(), InstanceState::Booting);
            assert!(!rt.health_check(&inst));
            exec::sleep_ms(1_300.0).await;
            assert_eq!(inst.state(), InstanceState::Healthy);
            assert!(rt.health_check(&inst));
            assert_eq!(now().as_millis_f64(), 1_300.0);
        });
    }

    #[test]
    fn build_charges_latency() {
        run_virtual(async {
            let rt = runtime();
            let t0 = now().as_millis_f64();
            let img = rt
                .build_image(manifest_for("ab"), vec![("a".into(), 9.0), ("b".into(), 9.0)])
                .await
                .unwrap();
            assert_eq!(now().as_millis_f64() - t0, 4_000.0);
            assert_eq!(rt.image(img).unwrap().functions.len(), 2);
        });
    }

    #[test]
    fn injected_build_failure() {
        run_virtual(async {
            let rt = runtime();
            rt.inject_build_failures(1);
            let r = rt.build_image(manifest_for("x"), vec![("x".into(), 1.0)]).await;
            assert!(r.is_err());
            // next build succeeds
            let r = rt.build_image(manifest_for("x"), vec![("x".into(), 1.0)]).await;
            assert!(r.is_ok());
        });
    }

    #[test]
    fn injected_boot_hang_never_heals() {
        run_virtual(async {
            let rt = runtime();
            let img = rt.register_image(manifest_for("a"), vec![("a".into(), 9.0)]);
            rt.inject_boot_hangs(1);
            let inst = rt.launch(img).unwrap();
            exec::sleep_ms(60_000.0).await;
            assert_eq!(inst.state(), InstanceState::Booting);
        });
    }

    #[test]
    fn ram_ledger_counts_live_instances() {
        run_virtual(async {
            let rt = runtime();
            let img = rt.register_image(manifest_for("a"), vec![("a".into(), 9.0)]);
            let i1 = rt.launch(img).unwrap();
            let i2 = rt.launch(img).unwrap();
            exec::sleep_ms(2_000.0).await;
            // 2 instances x (58 base + 9 code)
            assert!((rt.total_ram_mb() - 2.0 * 67.0).abs() < 1e-9);
            i1.begin_drain().unwrap();
            rt.terminate(&i1).unwrap();
            assert!((rt.total_ram_mb() - 67.0).abs() < 1e-9);
            assert_eq!(rt.live_count(), 1);
            drop(i2);
        });
    }

    #[test]
    fn terminate_with_inflight_fails() {
        run_virtual(async {
            let rt = runtime();
            let img = rt.register_image(manifest_for("a"), vec![("a".into(), 9.0)]);
            let inst = rt.launch(img).unwrap();
            exec::sleep_ms(1_500.0).await;
            inst.request_started();
            inst.begin_drain().unwrap();
            assert!(rt.terminate(&inst).is_err());
            inst.request_finished();
            assert!(rt.terminate(&inst).is_ok());
        });
    }

    #[test]
    fn await_healthy_gates_and_times_out() {
        run_virtual(async {
            let rt = runtime();
            let latency = PlatformConfig::tiny().latency;
            let img = rt.register_image(manifest_for("a"), vec![("a".into(), 9.0)]);
            let inst = rt.launch(img).unwrap();
            let t0 = now().as_millis_f64();
            await_healthy(&latency, &inst).await.unwrap();
            // healthy at boot (1200 ms); the 250 ms polling grid passes its
            // second consecutive check at 1500 ms
            assert_eq!(now().as_millis_f64() - t0, 1_500.0);
            // a hung boot exhausts the 4x boot + 5 s deadline
            rt.inject_boot_hangs(1);
            let hung = rt.launch(img).unwrap();
            assert!(await_healthy(&latency, &hung).await.is_err());
        });
    }

    #[test]
    fn reclaim_when_drained_waits_for_inflight() {
        run_virtual(async {
            let rt = runtime();
            let metrics = crate::metrics::Recorder::new();
            let img = rt.register_image(manifest_for("a"), vec![("a".into(), 9.0)]);
            let inst = rt.launch(img).unwrap();
            exec::sleep_ms(1_500.0).await;
            inst.request_started();
            inst.begin_drain().unwrap();
            reclaim_when_drained(rt.clone(), metrics.clone(), Rc::clone(&inst));
            exec::sleep_ms(500.0).await;
            assert_eq!(inst.state(), InstanceState::Draining, "must wait for in-flight");
            inst.request_finished();
            exec::sleep_ms(100.0).await;
            assert_eq!(inst.state(), InstanceState::Terminated);
            assert_eq!(metrics.counter("instances_reclaimed"), 1);
        });
    }

    #[test]
    fn shared_image_store_spans_runtimes_and_keeps_instance_ids_unique() {
        run_virtual(async {
            // two "nodes": independent instance registries, one image store
            let node_a = runtime();
            let node_b = ContainerRuntime::with_images(
                Rc::new(PlatformConfig::tiny()),
                node_a.image_store(),
            );
            let img = node_a.register_image(manifest_for("a"), vec![("a".into(), 9.0)]);
            // the image registered on node A is pullable on node B
            let ia = node_a.launch(img).unwrap();
            let ib = node_b.launch(img).unwrap();
            // instance ids are cluster-unique, not per-node
            assert_ne!(ia.id(), ib.id());
            // instance registries stay per-node
            assert!(node_a.instance(ia.id()).is_ok());
            assert!(node_a.instance(ib.id()).is_err());
            assert!(node_b.instance(ib.id()).is_ok());
            assert_eq!(node_a.live_count(), 1);
            assert_eq!(node_b.live_count(), 1);
        });
    }

    #[test]
    fn export_fs_returns_image_manifest() {
        run_virtual(async {
            let rt = runtime();
            let m = manifest_for("a");
            let img = rt.register_image(m.clone(), vec![("a".into(), 9.0)]);
            let inst = rt.launch(img).unwrap();
            let exported = rt.export_fs(&inst).unwrap();
            assert_eq!(exported, m);
        });
    }
}
