//! Async coordination primitives for the in-repo executor: [`Notify`]
//! (edge-triggered with a permit, tokio-flavored) and [`Gauge`] (an awaited
//! counter used for instance drain accounting).

use std::future::Future;
use std::pin::Pin;
use std::sync::{Arc, Mutex};
use std::task::{Context, Poll, Waker};

// ---------------------------------------------------------------------------
// Notify
// ---------------------------------------------------------------------------

/// Wait/notify with a single stored permit, so a `notify_one()` that races
/// ahead of `notified().await` is not lost.
#[derive(Clone, Default)]
pub struct Notify {
    state: Arc<Mutex<NotifyState>>,
}

#[derive(Default)]
struct NotifyState {
    permit: bool,
    waiters: Vec<Arc<Mutex<WaiterState>>>,
}

#[derive(Default)]
struct WaiterState {
    fired: bool,
    waker: Option<Waker>,
}

impl Notify {
    pub fn new() -> Self {
        Self::default()
    }

    /// Wake one waiter, or store a permit if none are waiting.
    pub fn notify_one(&self) {
        let mut s = self.state.lock().unwrap();
        if let Some(waiter) = s.waiters.pop() {
            let mut w = waiter.lock().unwrap();
            w.fired = true;
            if let Some(waker) = w.waker.take() {
                waker.wake();
            }
        } else {
            s.permit = true;
        }
    }

    /// Wake all current waiters (does not store a permit).
    pub fn notify_all(&self) {
        let mut s = self.state.lock().unwrap();
        for waiter in s.waiters.drain(..) {
            let mut w = waiter.lock().unwrap();
            w.fired = true;
            if let Some(waker) = w.waker.take() {
                waker.wake();
            }
        }
    }

    /// Wait until notified (consumes a stored permit immediately if present).
    pub fn notified(&self) -> Notified {
        Notified { notify: self.clone(), waiter: None }
    }
}

pub struct Notified {
    notify: Notify,
    waiter: Option<Arc<Mutex<WaiterState>>>,
}

impl Future for Notified {
    type Output = ();
    fn poll(mut self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<()> {
        // Already registered: check our own fired flag.
        if let Some(waiter) = &self.waiter {
            let mut w = waiter.lock().unwrap();
            if w.fired {
                return Poll::Ready(());
            }
            w.waker = Some(cx.waker().clone());
            return Poll::Pending;
        }
        let mut s = self.notify.state.lock().unwrap();
        if s.permit {
            s.permit = false;
            return Poll::Ready(());
        }
        let waiter = Arc::new(Mutex::new(WaiterState {
            fired: false,
            waker: Some(cx.waker().clone()),
        }));
        s.waiters.push(Arc::clone(&waiter));
        drop(s);
        self.waiter = Some(waiter);
        Poll::Pending
    }
}

impl Drop for Notified {
    fn drop(&mut self) {
        // Deregister so an abandoned waiter doesn't swallow a notify_one.
        if let Some(waiter) = self.waiter.take() {
            let fired = waiter.lock().unwrap().fired;
            let mut s = self.notify.state.lock().unwrap();
            s.waiters.retain(|w| !Arc::ptr_eq(w, &waiter));
            // If we were already fired but never observed it, hand the
            // wakeup to someone else.
            if fired {
                drop(s);
                self.notify.notify_one();
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Gauge — awaited counter (drain accounting)
// ---------------------------------------------------------------------------

/// A counter whose transitions can be awaited; used for in-flight request
/// accounting: `add(1)` on dispatch, `sub(1)` on completion,
/// `wait_zero().await` to drain.
#[derive(Clone, Default)]
pub struct Gauge {
    state: Arc<Mutex<GaugeState>>,
}

#[derive(Default)]
struct GaugeState {
    value: i64,
    waiters: Vec<Waker>,
}

impl Gauge {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&self, n: i64) {
        let mut s = self.state.lock().unwrap();
        s.value += n;
        debug_assert!(s.value >= 0, "gauge went negative");
        if s.value == 0 {
            for w in s.waiters.drain(..) {
                w.wake();
            }
        }
    }

    pub fn sub(&self, n: i64) {
        self.add(-n);
    }

    pub fn value(&self) -> i64 {
        self.state.lock().unwrap().value
    }

    /// Resolve once the gauge reads zero (immediately if it already does).
    pub fn wait_zero(&self) -> WaitZero {
        WaitZero { gauge: self.clone() }
    }
}

pub struct WaitZero {
    gauge: Gauge,
}

impl Future for WaitZero {
    type Output = ();
    fn poll(self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<()> {
        let mut s = self.gauge.state.lock().unwrap();
        if s.value == 0 {
            Poll::Ready(())
        } else {
            s.waiters.retain(|w| !w.will_wake(cx.waker()));
            s.waiters.push(cx.waker().clone());
            Poll::Pending
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::{now, run_virtual, sleep_ms, spawn};

    #[test]
    fn notify_wakes_waiter() {
        run_virtual(async {
            let n = Notify::new();
            let n2 = n.clone();
            let h = spawn(async move {
                n2.notified().await;
                now().as_millis_f64()
            });
            sleep_ms(7.0).await;
            n.notify_one();
            assert_eq!(h.await, 7.0);
        });
    }

    #[test]
    fn notify_permit_not_lost() {
        run_virtual(async {
            let n = Notify::new();
            n.notify_one(); // before anyone waits
            n.notified().await; // must not hang
        });
    }

    #[test]
    fn notify_all_wakes_everyone() {
        run_virtual(async {
            let n = Notify::new();
            let mut handles = Vec::new();
            for _ in 0..5 {
                let n = n.clone();
                handles.push(spawn(async move { n.notified().await }));
            }
            sleep_ms(1.0).await;
            n.notify_all();
            for h in handles {
                h.await;
            }
        });
    }

    #[test]
    fn gauge_drain() {
        run_virtual(async {
            let g = Gauge::new();
            for i in 0..4u64 {
                g.add(1);
                let g = g.clone();
                spawn(async move {
                    sleep_ms(10.0 + i as f64).await;
                    g.sub(1);
                });
            }
            g.wait_zero().await;
            assert_eq!(now().as_millis_f64(), 13.0);
            assert_eq!(g.value(), 0);
        });
    }

    #[test]
    fn gauge_zero_resolves_immediately() {
        run_virtual(async {
            Gauge::new().wait_zero().await;
        });
    }
}
