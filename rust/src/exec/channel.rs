//! Async channels for the in-repo executor: `oneshot` and unbounded `mpsc`.
//!
//! Both are `Mutex`-based so their `Sender` halves are usable from external
//! OS threads (the real-time HTTP front end); receivers must live on the
//! executor thread.

use std::collections::VecDeque;
use std::future::Future;
use std::pin::Pin;
use std::sync::{Arc, Mutex};
use std::task::{Context, Poll, Waker};

// ---------------------------------------------------------------------------
// oneshot
// ---------------------------------------------------------------------------

/// Create a oneshot channel.
pub fn oneshot<T>() -> (OneshotSender<T>, OneshotReceiver<T>) {
    let state = Arc::new(Mutex::new(OneshotState {
        value: None,
        waker: None,
        closed: false,
    }));
    (
        OneshotSender { state: Arc::clone(&state) },
        OneshotReceiver { state },
    )
}

struct OneshotState<T> {
    value: Option<T>,
    waker: Option<Waker>,
    closed: bool,
}

pub struct OneshotSender<T> {
    state: Arc<Mutex<OneshotState<T>>>,
}

/// Error: the receiving half was dropped.
#[derive(Debug, PartialEq, Eq)]
pub struct Closed;

impl<T> OneshotSender<T> {
    /// Deliver the value; fails if the receiver is gone.
    pub fn send(self, value: T) -> Result<(), Closed> {
        let mut s = self.state.lock().unwrap();
        if s.closed {
            return Err(Closed);
        }
        s.value = Some(value);
        if let Some(w) = s.waker.take() {
            w.wake();
        }
        Ok(())
    }
}

impl<T> Drop for OneshotSender<T> {
    fn drop(&mut self) {
        let mut s = self.state.lock().unwrap();
        if s.value.is_none() {
            s.closed = true;
            if let Some(w) = s.waker.take() {
                w.wake();
            }
        }
    }
}

pub struct OneshotReceiver<T> {
    state: Arc<Mutex<OneshotState<T>>>,
}

impl<T> Drop for OneshotReceiver<T> {
    fn drop(&mut self) {
        self.state.lock().unwrap().closed = true;
    }
}

impl<T> Future for OneshotReceiver<T> {
    type Output = Result<T, Closed>;
    fn poll(self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<Self::Output> {
        let mut s = self.state.lock().unwrap();
        if let Some(v) = s.value.take() {
            return Poll::Ready(Ok(v));
        }
        if s.closed {
            return Poll::Ready(Err(Closed));
        }
        s.waker = Some(cx.waker().clone());
        Poll::Pending
    }
}

// ---------------------------------------------------------------------------
// unbounded mpsc
// ---------------------------------------------------------------------------

/// Create an unbounded mpsc channel.
pub fn mpsc<T>() -> (Sender<T>, Receiver<T>) {
    let state = Arc::new(Mutex::new(MpscState {
        queue: VecDeque::new(),
        waker: None,
        senders: 1,
        receiver_alive: true,
    }));
    (
        Sender { state: Arc::clone(&state) },
        Receiver { state },
    )
}

struct MpscState<T> {
    queue: VecDeque<T>,
    waker: Option<Waker>,
    senders: usize,
    receiver_alive: bool,
}

pub struct Sender<T> {
    state: Arc<Mutex<MpscState<T>>>,
}

impl<T> Clone for Sender<T> {
    fn clone(&self) -> Self {
        self.state.lock().unwrap().senders += 1;
        Sender { state: Arc::clone(&self.state) }
    }
}

impl<T> Drop for Sender<T> {
    fn drop(&mut self) {
        let mut s = self.state.lock().unwrap();
        s.senders -= 1;
        if s.senders == 0 {
            if let Some(w) = s.waker.take() {
                w.wake();
            }
        }
    }
}

impl<T> Sender<T> {
    /// Enqueue a message; fails if the receiver was dropped.
    pub fn send(&self, value: T) -> Result<(), Closed> {
        let mut s = self.state.lock().unwrap();
        if !s.receiver_alive {
            return Err(Closed);
        }
        s.queue.push_back(value);
        if let Some(w) = s.waker.take() {
            w.wake();
        }
        Ok(())
    }
}

pub struct Receiver<T> {
    state: Arc<Mutex<MpscState<T>>>,
}

impl<T> Drop for Receiver<T> {
    fn drop(&mut self) {
        self.state.lock().unwrap().receiver_alive = false;
    }
}

impl<T> Receiver<T> {
    /// Await the next message; `None` once all senders are gone and the
    /// queue is drained.
    pub fn recv(&mut self) -> Recv<'_, T> {
        Recv { receiver: self }
    }

    /// Non-blocking poll of the queue.
    pub fn try_recv(&mut self) -> Option<T> {
        self.state.lock().unwrap().queue.pop_front()
    }
}

pub struct Recv<'a, T> {
    receiver: &'a mut Receiver<T>,
}

impl<T> Future for Recv<'_, T> {
    type Output = Option<T>;
    fn poll(self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<Self::Output> {
        let mut s = self.receiver.state.lock().unwrap();
        if let Some(v) = s.queue.pop_front() {
            return Poll::Ready(Some(v));
        }
        if s.senders == 0 {
            return Poll::Ready(None);
        }
        s.waker = Some(cx.waker().clone());
        Poll::Pending
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::{run_virtual, sleep_ms, spawn};

    #[test]
    fn oneshot_roundtrip() {
        run_virtual(async {
            let (tx, rx) = oneshot();
            spawn(async move {
                sleep_ms(5.0).await;
                tx.send(42).unwrap();
            });
            assert_eq!(rx.await, Ok(42));
        });
    }

    #[test]
    fn oneshot_sender_drop_closes() {
        run_virtual(async {
            let (tx, rx) = oneshot::<u32>();
            spawn(async move {
                sleep_ms(1.0).await;
                drop(tx);
            });
            assert_eq!(rx.await, Err(Closed));
        });
    }

    #[test]
    fn oneshot_receiver_drop_fails_send() {
        let (tx, rx) = oneshot::<u32>();
        drop(rx);
        assert_eq!(tx.send(1), Err(Closed));
    }

    #[test]
    fn mpsc_fifo_across_tasks() {
        run_virtual(async {
            let (tx, mut rx) = mpsc();
            for i in 0..3u64 {
                let tx = tx.clone();
                spawn(async move {
                    sleep_ms(i as f64).await;
                    tx.send(i).unwrap();
                });
            }
            drop(tx);
            let mut got = Vec::new();
            while let Some(v) = rx.recv().await {
                got.push(v);
            }
            assert_eq!(got, vec![0, 1, 2]);
        });
    }

    #[test]
    fn mpsc_close_on_all_senders_dropped() {
        run_virtual(async {
            let (tx, mut rx) = mpsc::<u8>();
            let tx2 = tx.clone();
            drop(tx);
            tx2.send(9).unwrap();
            drop(tx2);
            assert_eq!(rx.recv().await, Some(9));
            assert_eq!(rx.recv().await, None);
        });
    }

    #[test]
    fn mpsc_send_to_dropped_receiver_errors() {
        let (tx, rx) = mpsc::<u8>();
        drop(rx);
        assert!(tx.send(1).is_err());
    }

    #[test]
    fn mpsc_try_recv() {
        let (tx, mut rx) = mpsc::<u8>();
        assert_eq!(rx.try_recv(), None);
        tx.send(3).unwrap();
        assert_eq!(rx.try_recv(), Some(3));
    }
}
