//! Cross-shard plumbing for the sharded simulation core (ISSUE 7).
//!
//! A sharded [`Executor`](super::Executor) partitions its tasks and timers
//! into per-node *lanes*.  Everything that crosses a lane boundary travels
//! through the types in this module, and all of them are `Send`:
//!
//! * [`Inbox`] — a lane's ready queue.  Wakes are stamped with a globally
//!   monotone sequence number at wake time; the scheduler drains every
//!   lane and merges by that stamp, which reconstructs the exact order a
//!   single shared queue would have produced.  That merge is what makes an
//!   N-shard schedule bit-identical to the 1-shard schedule for a pinned
//!   seed — determinism holds *by construction*, independent of how tasks
//!   are assigned to lanes or (in the threaded milestone) which worker
//!   thread drains first.
//! * [`WakeLane`] — the `Send + Sync` half a [`Waker`](std::task::Waker)
//!   carries: an inbox handle plus the shared wake counter.  No `Rc`, no
//!   thread-local — a waker for a sharded task may be invoked from any
//!   thread.
//! * [`EpochGate`] — a reusable barrier for the threaded milestone.  One
//!   epoch is the interval between two virtual-clock advances; workers
//!   arrive at the gate once their lane has quiesced, and the clock only
//!   moves when every shard has arrived.
//!
//! The executor in `exec/mod.rs` currently drives all lanes from one
//! thread (the sharded-ready fallback milestone — see `docs/ARCHITECTURE.md`);
//! these types are the contract that lets worker threads be introduced
//! without touching scheduling semantics.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// A lane's ready queue: `(wake_seq, task_id)` pairs pushed by wakers
/// (possibly from other threads) and drained by the scheduler.
#[derive(Default)]
pub(crate) struct Inbox {
    entries: Mutex<Vec<(u64, u64)>>,
}

impl Inbox {
    pub(crate) fn new() -> Arc<Inbox> {
        Arc::new(Inbox::default())
    }

    pub(crate) fn push(&self, seq: u64, id: u64) {
        self.entries.lock().unwrap().push((seq, id));
    }

    /// Append all pending entries to `buf` (reused across scheduler
    /// iterations; the merge sorts by `seq` afterwards).
    pub(crate) fn drain_into(&self, buf: &mut Vec<(u64, u64)>) {
        let mut entries = self.entries.lock().unwrap();
        buf.append(&mut entries);
    }
}

/// The `Send + Sync` wake route a sharded task's waker holds: pushing
/// stamps the wake with the executor-wide sequence counter so the
/// scheduler's k-way merge replays single-queue FIFO order exactly.
pub(crate) struct WakeLane {
    inbox: Arc<Inbox>,
    seq: Arc<AtomicU64>,
}

impl WakeLane {
    pub(crate) fn new(inbox: &Arc<Inbox>, seq: &Arc<AtomicU64>) -> Self {
        WakeLane { inbox: Arc::clone(inbox), seq: Arc::clone(seq) }
    }

    pub(crate) fn push(&self, id: u64) {
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        self.inbox.push(seq, id);
    }
}

/// Reusable N-participant barrier synchronizing shards at epoch
/// boundaries (an epoch = the interval between two virtual-clock
/// advances).  Workers call [`EpochGate::arrive`] when their lane has no
/// runnable tasks; the call blocks until every participant has arrived,
/// then all are released into the next epoch together.  Generation
/// counting makes the gate safe to reuse round after round.
pub struct EpochGate {
    state: Mutex<GateState>,
    cv: Condvar,
}

struct GateState {
    parties: usize,
    arrived: usize,
    epoch: u64,
}

impl EpochGate {
    /// Gate for `parties` participants (clamped to at least 1; a
    /// single-party gate never blocks).
    pub fn new(parties: usize) -> Self {
        EpochGate {
            state: Mutex::new(GateState { parties: parties.max(1), arrived: 0, epoch: 0 }),
            cv: Condvar::new(),
        }
    }

    /// Arrive at the gate and wait for the rest of the cohort; returns
    /// the epoch number everyone is released into.
    pub fn arrive(&self) -> u64 {
        let mut s = self.state.lock().unwrap();
        let epoch = s.epoch;
        s.arrived += 1;
        if s.arrived == s.parties {
            s.arrived = 0;
            s.epoch += 1;
            self.cv.notify_all();
            return s.epoch;
        }
        while s.epoch == epoch {
            s = self.cv.wait(s).unwrap();
        }
        s.epoch
    }

    /// Completed epochs so far.
    pub fn epoch(&self) -> u64 {
        self.state.lock().unwrap().epoch
    }
}

// The whole point of this module: nothing on the cross-shard path may be
// `Rc` or thread-local.  Enforced at compile time.
#[allow(dead_code)]
fn assert_cross_shard_types_are_send_sync() {
    fn check<T: Send + Sync>() {}
    check::<Inbox>();
    check::<WakeLane>();
    check::<EpochGate>();
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn inbox_merge_reconstructs_global_push_order() {
        // pushes interleaved across two lanes; the seq-sorted merge must
        // equal the order a single shared queue would have seen
        let seq = Arc::new(AtomicU64::new(0));
        let a = Inbox::new();
        let b = Inbox::new();
        let lane_a = WakeLane::new(&a, &seq);
        let lane_b = WakeLane::new(&b, &seq);
        lane_a.push(10);
        lane_b.push(20);
        lane_a.push(11);
        lane_b.push(21);
        lane_a.push(12);
        let mut merged = Vec::new();
        a.drain_into(&mut merged);
        b.drain_into(&mut merged);
        merged.sort_unstable();
        let ids: Vec<u64> = merged.iter().map(|&(_, id)| id).collect();
        assert_eq!(ids, vec![10, 20, 11, 21, 12]);
        // drained: both inboxes empty now
        let mut rest = Vec::new();
        a.drain_into(&mut rest);
        b.drain_into(&mut rest);
        assert!(rest.is_empty());
    }

    #[test]
    fn inbox_accepts_pushes_from_other_threads() {
        let seq = Arc::new(AtomicU64::new(0));
        let inbox = Inbox::new();
        let mut joins = Vec::new();
        for t in 0..4u64 {
            let lane = WakeLane::new(&inbox, &seq);
            joins.push(std::thread::spawn(move || {
                for i in 0..100u64 {
                    lane.push(t * 1000 + i);
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let mut buf = Vec::new();
        inbox.drain_into(&mut buf);
        assert_eq!(buf.len(), 400);
        // every wake got a unique global stamp
        let mut seqs: Vec<u64> = buf.iter().map(|&(s, _)| s).collect();
        seqs.sort_unstable();
        seqs.dedup();
        assert_eq!(seqs.len(), 400);
    }

    #[test]
    fn epoch_gate_releases_whole_cohort_each_round() {
        const PARTIES: usize = 4;
        const ROUNDS: u64 = 50;
        let gate = Arc::new(EpochGate::new(PARTIES));
        let peak = Arc::new(AtomicUsize::new(0));
        let mut joins = Vec::new();
        for _ in 0..PARTIES {
            let gate = Arc::clone(&gate);
            let peak = Arc::clone(&peak);
            joins.push(std::thread::spawn(move || {
                let mut epochs = Vec::new();
                for _ in 0..ROUNDS {
                    epochs.push(gate.arrive());
                }
                peak.fetch_max(epochs.len(), Ordering::Relaxed);
                epochs
            }));
        }
        let want: Vec<u64> = (1..=ROUNDS).collect();
        for j in joins {
            // every worker observes the same strictly increasing epoch
            // sequence: nobody skips a round, nobody sees one twice
            assert_eq!(j.join().unwrap(), want);
        }
        assert_eq!(gate.epoch(), ROUNDS);
    }

    #[test]
    fn single_party_gate_never_blocks() {
        let gate = EpochGate::new(1);
        assert_eq!(gate.arrive(), 1);
        assert_eq!(gate.arrive(), 2);
        assert_eq!(gate.epoch(), 2);
        // zero clamps to one
        let gate = EpochGate::new(0);
        assert_eq!(gate.arrive(), 1);
    }
}
