//! Cross-shard plumbing for the sharded simulation core (ISSUE 7).
//!
//! A sharded [`Executor`](super::Executor) partitions its tasks and timers
//! into per-node *lanes*.  Everything that crosses a lane boundary travels
//! through the types in this module, and all of them are `Send`:
//!
//! * [`Inbox`] — a lane's ready queue.  Wakes are stamped with a globally
//!   monotone sequence number at wake time; the scheduler drains every
//!   lane and merges by that stamp, which reconstructs the exact order a
//!   single shared queue would have produced.  That merge is what makes an
//!   N-shard schedule bit-identical to the 1-shard schedule for a pinned
//!   seed — determinism holds *by construction*, independent of how tasks
//!   are assigned to lanes or (in the threaded milestone) which worker
//!   thread drains first.
//! * [`WakeLane`] — the `Send + Sync` half a [`Waker`](std::task::Waker)
//!   carries: an inbox handle plus the shared wake counter.  No `Rc`, no
//!   thread-local — a waker for a sharded task may be invoked from any
//!   thread.
//! * [`EpochGate`] — a reusable barrier for the threaded milestone.  One
//!   epoch is the interval between two virtual-clock advances; workers
//!   arrive at the gate once their lane has quiesced, and the clock only
//!   moves when every shard has arrived.  A panicking worker [poisons]
//!   the gate instead of leaving the cohort hung ([`EpochGate::poison`]).
//! * [`WindowGovernor`] — the epoch-window coordinator the threaded core
//!   ([`super::threads`]) runs on: workers drain their lanes up to a
//!   shared virtual-time bound, rendezvous at the embedded [`EpochGate`],
//!   and the governor advances the bound to the earliest pending deadline
//!   plus the conservative *lookahead* (the minimum latency of any
//!   cross-lane edge — [`crate::netsim::Fabric::epoch_lookahead_ms`] for
//!   fabric-coupled lanes).  Lanes may therefore skew by at most one
//!   lookahead, which is exactly the horizon within which no cross-lane
//!   event can affect them: the classic conservative-PDES window.
//!
//! [poisons]: EpochGate::poison
//!
//! The single-thread scheduler in `exec/mod.rs` still drives merged lanes
//! for the shared-platform (`--threads off`) path; the threaded core in
//! `exec/threads.rs` drives decoupled lanes through these types — see
//! `docs/ARCHITECTURE.md` § "Sharded simulation core".

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// A lane's ready queue: `(wake_seq, task_id)` pairs pushed by wakers
/// (possibly from other threads) and drained by the scheduler.
#[derive(Default)]
pub(crate) struct Inbox {
    entries: Mutex<Vec<(u64, u64)>>,
}

impl Inbox {
    pub(crate) fn new() -> Arc<Inbox> {
        Arc::new(Inbox::default())
    }

    pub(crate) fn push(&self, seq: u64, id: u64) {
        self.entries.lock().unwrap().push((seq, id));
    }

    /// Append all pending entries to `buf` (reused across scheduler
    /// iterations; the merge sorts by `seq` afterwards).
    pub(crate) fn drain_into(&self, buf: &mut Vec<(u64, u64)>) {
        let mut entries = self.entries.lock().unwrap();
        buf.append(&mut entries);
    }
}

/// The `Send + Sync` wake route a sharded task's waker holds: pushing
/// stamps the wake with the executor-wide sequence counter so the
/// scheduler's k-way merge replays single-queue FIFO order exactly.
pub(crate) struct WakeLane {
    inbox: Arc<Inbox>,
    seq: Arc<AtomicU64>,
}

impl WakeLane {
    pub(crate) fn new(inbox: &Arc<Inbox>, seq: &Arc<AtomicU64>) -> Self {
        WakeLane { inbox: Arc::clone(inbox), seq: Arc::clone(seq) }
    }

    pub(crate) fn push(&self, id: u64) {
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        self.inbox.push(seq, id);
    }
}

/// What a dying worker leaves behind when it poisons the gate: the shard
/// that panicked and the (stringified) panic payload.  Carried out of the
/// barrier to every surviving worker and ultimately converted into
/// [`Error::ShardPanicked`](crate::error::Error::ShardPanicked).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardPanic {
    pub shard: usize,
    pub payload: String,
}

impl std::fmt::Display for ShardPanic {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "shard {} panicked: {}", self.shard, self.payload)
    }
}

/// Reusable N-participant barrier synchronizing shards at epoch
/// boundaries (an epoch = the interval between two virtual-clock
/// advances).  Workers call [`EpochGate::arrive`] when their lane has no
/// runnable tasks; the call blocks until every participant has arrived,
/// then all are released into the next epoch together.  Generation
/// counting makes the gate safe to reuse round after round.
///
/// Threaded-core extensions: a worker whose lane panicked calls
/// [`EpochGate::poison`] so the rest of the cohort aborts instead of
/// waiting forever on a party that will never arrive, and a worker whose
/// lane has finished all of its roots calls [`EpochGate::retire`] to
/// shrink the cohort without blocking it.
pub struct EpochGate {
    state: Mutex<GateState>,
    cv: Condvar,
}

struct GateState {
    parties: usize,
    arrived: usize,
    epoch: u64,
    poison: Option<ShardPanic>,
}

impl EpochGate {
    /// Gate for `parties` participants (clamped to at least 1; a
    /// single-party gate never blocks).
    pub fn new(parties: usize) -> Self {
        EpochGate {
            state: Mutex::new(GateState {
                parties: parties.max(1),
                arrived: 0,
                epoch: 0,
                poison: None,
            }),
            cv: Condvar::new(),
        }
    }

    /// Arrive at the gate and wait for the rest of the cohort; returns
    /// the epoch number everyone is released into.
    ///
    /// # Panics
    /// If the gate was [poisoned](EpochGate::poison) — single-thread
    /// callers that never poison keep the infallible signature; the
    /// threaded core uses [`EpochGate::arrive_checked`] instead.
    pub fn arrive(&self) -> u64 {
        match self.arrive_checked() {
            Ok(epoch) => epoch,
            Err(p) => panic!("EpochGate poisoned: {p}"),
        }
    }

    /// [`EpochGate::arrive`], except a poisoned gate returns the poison
    /// instead of panicking — the abort path a surviving worker unwinds
    /// through when a sibling shard dies.
    pub fn arrive_checked(&self) -> Result<u64, ShardPanic> {
        let mut s = self.state.lock().unwrap();
        if let Some(p) = &s.poison {
            return Err(p.clone());
        }
        let epoch = s.epoch;
        s.arrived += 1;
        if s.arrived >= s.parties {
            s.arrived = 0;
            s.epoch += 1;
            self.cv.notify_all();
            return Ok(s.epoch);
        }
        while s.epoch == epoch {
            s = self.cv.wait(s).unwrap();
            if let Some(p) = &s.poison {
                return Err(p.clone());
            }
        }
        Ok(s.epoch)
    }

    /// Poison the gate on behalf of a panicking shard: every current and
    /// future arrival returns the poison instead of blocking on a party
    /// that will never come.  First poison wins.
    pub fn poison(&self, shard: usize, payload: String) {
        let mut s = self.state.lock().unwrap();
        if s.poison.is_none() {
            s.poison = Some(ShardPanic { shard, payload });
        }
        self.cv.notify_all();
    }

    /// The poison left by a dead shard, if any.
    pub fn poisoned(&self) -> Option<ShardPanic> {
        self.state.lock().unwrap().poison.clone()
    }

    /// Permanently remove one party from the cohort (a worker whose roots
    /// all completed).  If everyone else is already waiting, the round
    /// completes immediately — retiring never strands the cohort.
    pub fn retire(&self) {
        let mut s = self.state.lock().unwrap();
        s.parties = s.parties.saturating_sub(1);
        if s.parties > 0 && s.arrived >= s.parties {
            s.arrived = 0;
            s.epoch += 1;
            self.cv.notify_all();
        }
    }

    /// Completed epochs so far.
    pub fn epoch(&self) -> u64 {
        self.state.lock().unwrap().epoch
    }
}

/// Sentinel lookahead meaning "no cross-lane edges": workers free-run to
/// quiescence without intermediate rendezvous.  Negotiated by
/// [`crate::netsim::negotiate_lookahead`] when the lane graph is
/// edge-free (e.g. the tenant-partitioned fleet, where every fabric hop
/// is internal to one lane).
pub const UNBOUNDED_LOOKAHEAD: u64 = u64::MAX;

/// What a worker tells the governor when its lane has drained up to the
/// current window bound.
#[derive(Debug, Clone, Copy)]
pub struct LaneReport {
    /// earliest pending timer deadline on this lane (ns), if any
    pub next_deadline: Option<u64>,
    /// whether the lane polled any task or fired any timer this window —
    /// cross-lane wakes it produced may still be in flight, so a busy
    /// cohort re-runs the window before quiescence can be declared
    pub progressed: bool,
}

/// The governor's decision for the next round.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Window {
    /// drain your lane up to `end_ns` (inclusive), then arrive again
    Open { end_ns: u64 },
    /// two consecutive all-idle rounds with no pending deadline anywhere:
    /// the cohort is globally quiescent.  A worker holding unfinished
    /// roots at this point is deadlocked (mirrors the single-thread
    /// "executor stalled" panic).
    Quiesced,
}

/// Epoch-window coordinator for the threaded simulation core.
///
/// Every round, each worker drains its lane up to the current window
/// bound, then calls [`WindowGovernor::arrive`] with a [`LaneReport`].
/// The call aggregates the report, rendezvouses the cohort at the
/// embedded [`EpochGate`], and returns the next [`Window`]:
///
/// * any pending deadline → the window advances to the *earliest*
///   deadline across all lanes plus the lookahead (never backwards, so
///   lane clocks stay within one lookahead of each other);
/// * no deadlines but somebody progressed → the same window re-runs
///   (cross-lane wakes the busy lane produced may still be undrained);
/// * nobody progressed and no deadlines → one *confirm* round re-runs
///   the window (every in-flight cross-thread wake push happens-before
///   the round decision under the gate's lock, so a single re-drain
///   observes them all), and only a second silent round returns
///   [`Window::Quiesced`].
///
/// Panic propagation rides the gate's poison: [`WindowGovernor::arrive`]
/// returns `Err(ShardPanic)` for every survivor once any worker has
/// called [`WindowGovernor::poison`].
pub struct WindowGovernor {
    lookahead_ns: u64,
    gate: EpochGate,
    agg: Mutex<WindowState>,
}

struct WindowState {
    /// round inputs, reset by the first worker released from each round
    min_deadline: Option<u64>,
    busy: bool,
    /// gate epoch the current `window`/`confirming` were computed for
    computed_for: u64,
    /// current window bound (monotone; 0 lets the cohort run t=0 work)
    window_end: u64,
    /// a confirm round is in flight (first all-idle round seen)
    confirming: bool,
    window: Window,
}

impl WindowGovernor {
    /// Governor for `parties` workers with the given conservative
    /// lookahead in nanoseconds ([`UNBOUNDED_LOOKAHEAD`] for decoupled
    /// lanes).
    pub fn new(parties: usize, lookahead_ns: u64) -> Self {
        WindowGovernor {
            lookahead_ns,
            gate: EpochGate::new(parties),
            agg: Mutex::new(WindowState {
                min_deadline: None,
                busy: false,
                computed_for: 0,
                window_end: 0,
                confirming: false,
                window: Window::Open { end_ns: 0 },
            }),
        }
    }

    /// The bound workers drain to before their first arrival: 0, i.e. all
    /// ready work and t=0 timers.
    pub fn initial_window(&self) -> u64 {
        0
    }

    /// Report a drained lane and block until the cohort decides the next
    /// window.  Returns the poison instead if any shard died.
    pub fn arrive(&self, report: LaneReport) -> Result<Window, ShardPanic> {
        {
            let mut a = self.agg.lock().unwrap();
            a.min_deadline = match (a.min_deadline, report.next_deadline) {
                (Some(x), Some(y)) => Some(x.min(y)),
                (x, y) => x.or(y),
            };
            a.busy |= report.progressed;
        }
        let epoch = self.gate.arrive_checked()?;
        let mut a = self.agg.lock().unwrap();
        // Exactly-once window computation per round: every released
        // worker reaches this lock only after the barrier, and every
        // worker of the *next* round can only aggregate after returning
        // from this arrive — so the first worker through computes from a
        // complete, uncontended aggregate and resets it for the next
        // round.
        if a.computed_for < epoch {
            a.window = match (a.min_deadline, a.busy) {
                (Some(d), _) => {
                    a.confirming = false;
                    a.window_end = d.saturating_add(self.lookahead_ns).max(a.window_end);
                    Window::Open { end_ns: a.window_end }
                }
                (None, true) => {
                    a.confirming = false;
                    Window::Open { end_ns: a.window_end }
                }
                (None, false) if !a.confirming => {
                    a.confirming = true;
                    Window::Open { end_ns: a.window_end }
                }
                (None, false) => Window::Quiesced,
            };
            a.min_deadline = None;
            a.busy = false;
            a.computed_for = epoch;
        }
        Ok(a.window)
    }

    /// Remove this worker from the cohort (all of its roots completed).
    pub fn retire(&self) {
        self.gate.retire();
    }

    /// Poison the cohort on behalf of a panicking worker (first wins).
    pub fn poison(&self, shard: usize, payload: String) {
        self.gate.poison(shard, payload);
    }

    /// The poison left by a dead shard, if any.
    pub fn poisoned(&self) -> Option<ShardPanic> {
        self.gate.poisoned()
    }

    /// Completed window rounds (epoch-gate generations) so far.
    pub fn windows(&self) -> u64 {
        self.gate.epoch()
    }
}

// The whole point of this module: nothing on the cross-shard path may be
// `Rc` or thread-local.  Enforced at compile time.
#[allow(dead_code)]
fn assert_cross_shard_types_are_send_sync() {
    fn check<T: Send + Sync>() {}
    check::<Inbox>();
    check::<WakeLane>();
    check::<EpochGate>();
    check::<WindowGovernor>();
    check::<ShardPanic>();
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn inbox_merge_reconstructs_global_push_order() {
        // pushes interleaved across two lanes; the seq-sorted merge must
        // equal the order a single shared queue would have seen
        let seq = Arc::new(AtomicU64::new(0));
        let a = Inbox::new();
        let b = Inbox::new();
        let lane_a = WakeLane::new(&a, &seq);
        let lane_b = WakeLane::new(&b, &seq);
        lane_a.push(10);
        lane_b.push(20);
        lane_a.push(11);
        lane_b.push(21);
        lane_a.push(12);
        let mut merged = Vec::new();
        a.drain_into(&mut merged);
        b.drain_into(&mut merged);
        merged.sort_unstable();
        let ids: Vec<u64> = merged.iter().map(|&(_, id)| id).collect();
        assert_eq!(ids, vec![10, 20, 11, 21, 12]);
        // drained: both inboxes empty now
        let mut rest = Vec::new();
        a.drain_into(&mut rest);
        b.drain_into(&mut rest);
        assert!(rest.is_empty());
    }

    #[test]
    fn inbox_accepts_pushes_from_other_threads() {
        let seq = Arc::new(AtomicU64::new(0));
        let inbox = Inbox::new();
        let mut joins = Vec::new();
        for t in 0..4u64 {
            let lane = WakeLane::new(&inbox, &seq);
            joins.push(std::thread::spawn(move || {
                for i in 0..100u64 {
                    lane.push(t * 1000 + i);
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let mut buf = Vec::new();
        inbox.drain_into(&mut buf);
        assert_eq!(buf.len(), 400);
        // every wake got a unique global stamp
        let mut seqs: Vec<u64> = buf.iter().map(|&(s, _)| s).collect();
        seqs.sort_unstable();
        seqs.dedup();
        assert_eq!(seqs.len(), 400);
    }

    #[test]
    fn epoch_gate_releases_whole_cohort_each_round() {
        const PARTIES: usize = 4;
        const ROUNDS: u64 = 50;
        let gate = Arc::new(EpochGate::new(PARTIES));
        let peak = Arc::new(AtomicUsize::new(0));
        let mut joins = Vec::new();
        for _ in 0..PARTIES {
            let gate = Arc::clone(&gate);
            let peak = Arc::clone(&peak);
            joins.push(std::thread::spawn(move || {
                let mut epochs = Vec::new();
                for _ in 0..ROUNDS {
                    epochs.push(gate.arrive());
                }
                peak.fetch_max(epochs.len(), Ordering::Relaxed);
                epochs
            }));
        }
        let want: Vec<u64> = (1..=ROUNDS).collect();
        for j in joins {
            // every worker observes the same strictly increasing epoch
            // sequence: nobody skips a round, nobody sees one twice
            assert_eq!(j.join().unwrap(), want);
        }
        assert_eq!(gate.epoch(), ROUNDS);
    }

    #[test]
    fn single_party_gate_never_blocks() {
        let gate = EpochGate::new(1);
        assert_eq!(gate.arrive(), 1);
        assert_eq!(gate.arrive(), 2);
        assert_eq!(gate.epoch(), 2);
        // zero clamps to one
        let gate = EpochGate::new(0);
        assert_eq!(gate.arrive(), 1);
    }

    #[test]
    fn poisoned_gate_releases_waiters_with_the_first_poison() {
        let gate = Arc::new(EpochGate::new(3));
        let mut joins = Vec::new();
        for _ in 0..2 {
            let gate = Arc::clone(&gate);
            joins.push(std::thread::spawn(move || gate.arrive_checked()));
        }
        // give the two survivors time to block, then the third dies
        std::thread::sleep(std::time::Duration::from_millis(20));
        gate.poison(2, "boom".to_string());
        gate.poison(0, "late poison must not win".to_string());
        for j in joins {
            let err = j.join().unwrap().unwrap_err();
            assert_eq!(err.shard, 2);
            assert_eq!(err.payload, "boom");
        }
        // arrivals after the fact fail fast too
        assert_eq!(gate.arrive_checked().unwrap_err().shard, 2);
        assert_eq!(gate.poisoned().unwrap().payload, "boom");
    }

    #[test]
    fn retiring_last_party_completes_the_round_for_waiters() {
        let gate = Arc::new(EpochGate::new(2));
        let waiter = {
            let gate = Arc::clone(&gate);
            std::thread::spawn(move || gate.arrive_checked())
        };
        std::thread::sleep(std::time::Duration::from_millis(20));
        gate.retire();
        assert_eq!(waiter.join().unwrap().unwrap(), 1);
        // the survivor is now a single-party cohort
        assert_eq!(gate.arrive(), 2);
    }

    #[test]
    fn governor_advances_window_by_min_deadline_plus_lookahead() {
        let gov = WindowGovernor::new(1, 100);
        assert_eq!(gov.initial_window(), 0);
        let w = gov
            .arrive(LaneReport { next_deadline: Some(1_000), progressed: true })
            .unwrap();
        assert_eq!(w, Window::Open { end_ns: 1_100 });
        // busy with no deadline re-runs the same window
        let w = gov.arrive(LaneReport { next_deadline: None, progressed: true }).unwrap();
        assert_eq!(w, Window::Open { end_ns: 1_100 });
        // the window never moves backwards even if a smaller deadline shows
        // up later (it can't in practice — drained lanes only hold future
        // deadlines — but monotonicity is the invariant lane clocks rely on)
        let w = gov
            .arrive(LaneReport { next_deadline: Some(500), progressed: true })
            .unwrap();
        assert_eq!(w, Window::Open { end_ns: 1_100 });
        assert_eq!(gov.windows(), 3);
    }

    #[test]
    fn governor_quiesces_only_after_a_confirm_round() {
        let gov = WindowGovernor::new(1, 100);
        let idle = LaneReport { next_deadline: None, progressed: false };
        // first silent round: confirm (re-run the window once)
        assert_eq!(gov.arrive(idle).unwrap(), Window::Open { end_ns: 0 });
        // second silent round: quiesced
        assert_eq!(gov.arrive(idle).unwrap(), Window::Quiesced);
        // progress during a confirm round cancels it
        let gov = WindowGovernor::new(1, 100);
        assert_eq!(gov.arrive(idle).unwrap(), Window::Open { end_ns: 0 });
        let busy = LaneReport { next_deadline: None, progressed: true };
        assert_eq!(gov.arrive(busy).unwrap(), Window::Open { end_ns: 0 });
        assert_eq!(gov.arrive(idle).unwrap(), Window::Open { end_ns: 0 });
        assert_eq!(gov.arrive(idle).unwrap(), Window::Quiesced);
    }

    #[test]
    fn governor_cohort_agrees_on_each_window() {
        const PARTIES: usize = 3;
        const ROUNDS: usize = 40;
        let gov = Arc::new(WindowGovernor::new(PARTIES, 7));
        let mut joins = Vec::new();
        for worker in 0..PARTIES {
            let gov = Arc::clone(&gov);
            joins.push(std::thread::spawn(move || {
                let mut seen = Vec::new();
                for round in 0..ROUNDS {
                    // only worker 0 ever has pending work; everyone must
                    // still agree on every window bound
                    let report = LaneReport {
                        next_deadline: (worker == 0).then_some((round as u64 + 1) * 10),
                        progressed: worker == 0,
                    };
                    seen.push(gov.arrive(report).unwrap());
                }
                seen
            }));
        }
        let want: Vec<Window> =
            (0..ROUNDS).map(|r| Window::Open { end_ns: (r as u64 + 1) * 10 + 7 }).collect();
        for j in joins {
            assert_eq!(j.join().unwrap(), want);
        }
        assert_eq!(gov.windows(), ROUNDS as u64);
    }

    #[test]
    fn unbounded_lookahead_saturates_the_window() {
        let gov = WindowGovernor::new(1, UNBOUNDED_LOOKAHEAD);
        let w = gov
            .arrive(LaneReport { next_deadline: Some(123), progressed: true })
            .unwrap();
        assert_eq!(w, Window::Open { end_ns: u64::MAX });
    }
}
