//! Single-threaded async executor with a **virtual clock** (discrete-event
//! semantics) or a real clock — the substrate the whole platform runs on
//! (the offline crate set has no tokio).
//!
//! * **Virtual mode** (experiments, tests, benches): `sleep()` registers a
//!   timer; when no task is runnable the clock jumps to the next deadline.
//!   The paper's 2 000-second workload executes in wall-milliseconds and
//!   every run is deterministic.
//! * **Real mode** (the live HTTP gateway example): the same timer wheel is
//!   driven off `std::time::Instant`, and external OS threads (TCP accept
//!   loops) can inject wakeups through the thread-safe wake queue.
//!
//! Tasks are plain non-`Send` futures (`Rc`-friendly platform state);
//! wakers are `Send` as the contract requires — they only push a task id
//! onto a mutex-protected queue.
//!
//! **Sharded virtual mode** ([`Executor::sharded`], ISSUE 7): tasks and
//! timers are partitioned into per-node lanes whose cross-lane traffic is
//! `Send` ([`shard`]).  Wakes carry a global sequence stamp and the
//! scheduler merges lanes by that stamp, so the N-shard schedule is
//! bit-identical to the 1-shard schedule for a pinned seed — see
//! `docs/ARCHITECTURE.md` § "Sharded simulation core".

pub mod channel;
pub mod shard;
pub mod sync;
pub mod threads;

use std::cell::{Cell, RefCell};
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, VecDeque};
use std::future::Future;
use std::pin::Pin;
use std::rc::Rc;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::task::{Context, Poll, Waker};
use std::time::{Duration, Instant};

/// Clock mode for an [`Executor`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// Discrete-event virtual time: idle executor jumps to the next timer.
    Virtual,
    /// Wall-clock time: idle executor parks until the next timer/wakeup.
    Real,
}

/// Nanosecond-resolution instant on the executor's clock.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct SimInstant(pub u64);

impl SimInstant {
    pub fn duration_since(&self, earlier: SimInstant) -> Duration {
        Duration::from_nanos(self.0.saturating_sub(earlier.0))
    }
    pub fn as_secs_f64(&self) -> f64 {
        self.0 as f64 / 1e9
    }
    pub fn as_millis_f64(&self) -> f64 {
        self.0 as f64 / 1e6
    }
}

// ---------------------------------------------------------------------------
// wake queue (thread-safe so Waker is genuinely Send)
// ---------------------------------------------------------------------------

#[derive(Default)]
struct WakeQueue {
    queue: Mutex<VecDeque<u64>>,
    condvar: Condvar,
}

impl WakeQueue {
    fn push(&self, id: u64) {
        self.queue.lock().unwrap().push_back(id);
        self.condvar.notify_one();
    }
    /// Move all pending wakeups into `buf` (reused across loop iterations
    /// to keep the scheduler allocation-free at steady state).
    fn drain_into(&self, buf: &mut Vec<u64>) {
        let mut q = self.queue.lock().unwrap();
        buf.extend(q.drain(..));
    }
}

/// Executor-instance ids let thread-local wake entries survive (unlikely
/// but legal) nested `block_on` calls without cross-talk.
static NEXT_EXEC_ID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    /// exec id of the executor currently running `block_on` on this thread
    /// (0 = none).
    static ACTIVE_EXEC: Cell<u64> = const { Cell::new(0) };
    /// Virtual-mode ready list (ISSUE 5 satellite): `(exec_id, task_id)`
    /// wakeups taken without the `Mutex<VecDeque>` + condvar round trip.
    /// In `Mode::Virtual` every wake happens on the executor thread itself
    /// (timers fire inside `advance_idle`, tasks wake tasks mid-poll), so
    /// the thread-safe queue only pays for contention that cannot exist.
    static LOCAL_READY: RefCell<Vec<(u64, u64)>> = const { RefCell::new(Vec::new()) };
    /// Shard the currently-polled task belongs to (0 outside task polls).
    /// Spawned tasks inherit it, so a request's continuation stays on the
    /// lane of the node it is executing on; [`spawn_on`] overrides it.
    static CURRENT_SHARD: Cell<u32> = const { Cell::new(0) };
}

/// Drain this executor's entries from the thread-local ready list into
/// `buf`, preserving entries of any other (nested) executor.
fn drain_local_ready(exec_id: u64, buf: &mut Vec<u64>) {
    LOCAL_READY.with(|q| {
        let mut q = q.borrow_mut();
        if q.is_empty() {
            return;
        }
        if q.iter().all(|&(e, _)| e == exec_id) {
            buf.extend(q.drain(..).map(|(_, id)| id));
        } else {
            let mut i = 0;
            while i < q.len() {
                if q[i].0 == exec_id {
                    buf.push(q.remove(i).1);
                } else {
                    i += 1;
                }
            }
        }
    });
}

struct TaskWaker {
    id: u64,
    exec_id: u64,
    /// take the thread-local fast path when woken on the owning executor's
    /// thread (set only in `Mode::Virtual`; `Mode::Real` keeps the
    /// thread-safe queue so external I/O threads park/wake correctly)
    fast_local: bool,
    queue: Arc<WakeQueue>,
    /// sharded executors route every wake through the owning lane's
    /// `Send` inbox instead of the thread-local fast path — a sharded
    /// task's waker is legal to invoke from any worker thread
    lane: Option<shard::WakeLane>,
}

impl TaskWaker {
    fn wake_id(&self) {
        if let Some(lane) = &self.lane {
            lane.push(self.id);
        } else if self.fast_local && ACTIVE_EXEC.with(|c| c.get()) == self.exec_id {
            LOCAL_READY.with(|q| q.borrow_mut().push((self.exec_id, self.id)));
        } else {
            self.queue.push(self.id);
        }
    }
}

impl std::task::Wake for TaskWaker {
    fn wake(self: Arc<Self>) {
        self.wake_id();
    }
    fn wake_by_ref(self: &Arc<Self>) {
        self.wake_id();
    }
}

// ---------------------------------------------------------------------------
// executor core
// ---------------------------------------------------------------------------

struct TimerEntry {
    deadline: u64,
    seq: u64,
    waker: Waker,
}

impl PartialEq for TimerEntry {
    fn eq(&self, other: &Self) -> bool {
        self.deadline == other.deadline && self.seq == other.seq
    }
}
impl Eq for TimerEntry {}
impl PartialOrd for TimerEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for TimerEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.deadline, self.seq).cmp(&(other.deadline, other.seq))
    }
}

type LocalFuture = Pin<Box<dyn Future<Output = ()>>>;

struct TaskEntry {
    future: LocalFuture,
    /// created once per task; cloning is a refcount bump, not an alloc
    waker: Waker,
    /// owning lane (0 on unsharded executors)
    shard: u32,
}

/// One shard's lane: the timers it owns plus the `Send` inbox its tasks
/// are woken through.  Lane membership never changes after spawn.
struct Lane {
    timers: RefCell<BinaryHeap<Reverse<TimerEntry>>>,
    inbox: Arc<shard::Inbox>,
}

/// Per-executor sharding state ([`Executor::sharded`]).  The scheduler
/// drains every lane inbox and merges by the shared `wake_seq` stamp —
/// exactly the order one global queue would have produced, which is what
/// keeps N-shard schedules bit-identical to 1-shard ones.
struct ShardedState {
    lanes: Vec<Lane>,
    /// executor-wide wake-order counter, shared by every lane's wakers
    wake_seq: Arc<AtomicU64>,
}

struct Inner {
    mode: Mode,
    exec_id: u64,
    now_ns: Cell<u64>,
    real_anchor: Instant,
    next_task_id: Cell<u64>,
    next_timer_seq: Cell<u64>,
    tasks: RefCell<HashMap<u64, TaskEntry>>,
    /// tasks spawned while the executor is mid-poll (picked up next loop):
    /// `(task_id, shard, future)`
    incoming: RefCell<Vec<(u64, u32, LocalFuture)>>,
    timers: RefCell<BinaryHeap<Reverse<TimerEntry>>>,
    wake_queue: Arc<WakeQueue>,
    /// `Some` iff built by [`Executor::sharded`] with more than one shard
    sharded: Option<ShardedState>,
    /// virtual-clock advances completed (one per discrete-event epoch) —
    /// the unit the threaded milestone's `shard::EpochGate` synchronizes on
    epochs: Cell<u64>,
    /// the lane [`current_shard`] reports while this executor runs outside
    /// task polls — 0 for ordinary executors; a [`Stepper`] pinned to a
    /// worker lane by [`Stepper::on_lane`] reports that lane instead, so
    /// threaded-core tenants see the shard that hosts them
    home_lane: Cell<u32>,
}

thread_local! {
    static CURRENT: RefCell<Option<Rc<Inner>>> = const { RefCell::new(None) };
}

fn with_current<T>(f: impl FnOnce(&Rc<Inner>) -> T) -> T {
    CURRENT.with(|c| {
        let borrowed = c.borrow();
        let inner = borrowed
            .as_ref()
            .expect("no executor running on this thread (use Executor::block_on)");
        f(inner)
    })
}

/// The executor. Create one per experiment / test.
pub struct Executor {
    inner: Rc<Inner>,
}

impl Executor {
    pub fn new(mode: Mode) -> Self {
        Self::sharded(mode, 1)
    }

    /// Executor whose tasks/timers are partitioned into `shards` lanes
    /// (one per cluster node; clamped to at least 1).  Scheduling is
    /// bit-identical to the unsharded executor for any shard count — the
    /// global wake/timer sequence stamps are merged back into single-queue
    /// order — so `--shards N` reproduces `--shards 1` exactly under a
    /// pinned seed.  `shards == 1` uses the unsharded fast path verbatim.
    ///
    /// # Panics
    /// If `shards > 1` with [`Mode::Real`]: discrete-event sharding is
    /// defined over the virtual clock only (real mode parks on wall time,
    /// which has no epoch boundaries to merge on).
    pub fn sharded(mode: Mode, shards: usize) -> Self {
        let shards = shards.max(1);
        assert!(
            shards == 1 || mode == Mode::Virtual,
            "sharded execution requires Mode::Virtual"
        );
        let sharded = (shards > 1).then(|| ShardedState {
            lanes: (0..shards)
                .map(|_| Lane {
                    timers: RefCell::new(BinaryHeap::new()),
                    inbox: shard::Inbox::new(),
                })
                .collect(),
            wake_seq: Arc::new(AtomicU64::new(0)),
        });
        Executor {
            inner: Rc::new(Inner {
                mode,
                exec_id: NEXT_EXEC_ID.fetch_add(1, Ordering::Relaxed),
                now_ns: Cell::new(0),
                real_anchor: Instant::now(),
                next_task_id: Cell::new(1),
                next_timer_seq: Cell::new(0),
                tasks: RefCell::new(HashMap::new()),
                incoming: RefCell::new(Vec::new()),
                timers: RefCell::new(BinaryHeap::new()),
                wake_queue: Arc::new(WakeQueue::default()),
                sharded,
                epochs: Cell::new(0),
                home_lane: Cell::new(0),
            }),
        }
    }

    /// Number of lanes this executor schedules over (1 when unsharded).
    pub fn shards(&self) -> usize {
        self.inner.shard_count()
    }

    /// Handle external threads can use to wake the executor (real mode).
    pub fn remote(&self) -> Remote {
        Remote { queue: Arc::clone(&self.inner.wake_queue) }
    }

    /// Drive `root` to completion, running all spawned tasks.
    pub fn block_on<T: 'static>(&self, root: impl Future<Output = T> + 'static) -> T {
        let guard = CurrentGuard::install(Rc::clone(&self.inner));
        let result: Rc<RefCell<Option<T>>> = Rc::new(RefCell::new(None));
        let result2 = Rc::clone(&result);
        // the root always lives on shard 0 (the control lane)
        let root_id = self.inner.spawn_inner_on(0, async move {
            *result2.borrow_mut() = Some(root.await);
        });
        self.inner.wake_spawned(root_id, 0);
        let v = if self.inner.sharded.is_some() {
            self.run_sharded(&result)
        } else {
            self.run_single(&result)
        };
        drop(guard);
        v
    }

    /// The unsharded scheduler loop — the PR 5 thread-local fast path,
    /// byte-for-byte the pre-sharding behavior.
    fn run_single<T: 'static>(&self, result: &Rc<RefCell<Option<T>>>) -> T {
        let fast_local = self.inner.mode == Mode::Virtual;
        let mut ready: Vec<u64> = Vec::new();
        loop {
            // move freshly spawned tasks into the task table
            {
                let mut incoming = self.inner.incoming.borrow_mut();
                if !incoming.is_empty() {
                    let mut tasks = self.inner.tasks.borrow_mut();
                    for (id, shard, future) in incoming.drain(..) {
                        let waker = Waker::from(Arc::new(TaskWaker {
                            id,
                            exec_id: self.inner.exec_id,
                            fast_local,
                            queue: Arc::clone(&self.inner.wake_queue),
                            lane: None,
                        }));
                        tasks.insert(id, TaskEntry { future, waker, shard });
                    }
                }
            }

            ready.clear();
            self.inner.wake_queue.drain_into(&mut ready);
            drain_local_ready(self.inner.exec_id, &mut ready);
            let mut polled_any = false;
            for &id in ready.iter() {
                let entry = self.inner.tasks.borrow_mut().remove(&id);
                let Some(mut entry) = entry else { continue }; // completed or duplicate wake
                polled_any = true;
                let mut cx = Context::from_waker(&entry.waker);
                match entry.future.as_mut().poll(&mut cx) {
                    Poll::Ready(()) => {}
                    Poll::Pending => {
                        self.inner.tasks.borrow_mut().insert(id, entry);
                    }
                }
            }

            if let Some(v) = result.borrow_mut().take() {
                return v;
            }
            if polled_any || !self.inner.incoming.borrow().is_empty() {
                continue;
            }
            // Nothing runnable: advance (virtual) or park (real).
            if !self.inner.advance_idle() {
                panic!(
                    "executor stalled: root not finished, no runnable tasks, no timers \
                     ({} tasks parked)",
                    self.inner.tasks.borrow().len()
                );
            }
        }
    }

    /// The sharded scheduler loop.  Each iteration drains every lane's
    /// `Send` inbox and merges by the global wake stamp — reconstructing
    /// the exact FIFO order the unsharded loop's single ready list would
    /// hold — then polls with `CURRENT_SHARD` pinned to the task's lane so
    /// spawns and timers land on the right shard.
    fn run_sharded<T: 'static>(&self, result: &Rc<RefCell<Option<T>>>) -> T {
        let s = self.inner.sharded.as_ref().expect("run_sharded on unsharded executor");
        let mut ready: Vec<u64> = Vec::new();
        let mut staged: Vec<(u64, u64)> = Vec::new();
        loop {
            {
                let mut incoming = self.inner.incoming.borrow_mut();
                if !incoming.is_empty() {
                    let mut tasks = self.inner.tasks.borrow_mut();
                    for (id, shard, future) in incoming.drain(..) {
                        let waker = Waker::from(Arc::new(TaskWaker {
                            id,
                            exec_id: self.inner.exec_id,
                            fast_local: false,
                            queue: Arc::clone(&self.inner.wake_queue),
                            lane: Some(shard::WakeLane::new(
                                &s.lanes[shard as usize].inbox,
                                &s.wake_seq,
                            )),
                        }));
                        tasks.insert(id, TaskEntry { future, waker, shard });
                    }
                }
            }

            ready.clear();
            // external (Remote) nudges first, mirroring the unsharded loop
            self.inner.wake_queue.drain_into(&mut ready);
            staged.clear();
            for lane in &s.lanes {
                lane.inbox.drain_into(&mut staged);
            }
            // k-way merge by wake stamp: single-queue FIFO order, exactly
            staged.sort_unstable();
            ready.extend(staged.iter().map(|&(_, id)| id));

            let mut polled_any = false;
            for &id in ready.iter() {
                let entry = self.inner.tasks.borrow_mut().remove(&id);
                let Some(mut entry) = entry else { continue }; // completed or duplicate wake
                polled_any = true;
                let prev = CURRENT_SHARD.with(|c| c.replace(entry.shard));
                let mut cx = Context::from_waker(&entry.waker);
                let poll = entry.future.as_mut().poll(&mut cx);
                CURRENT_SHARD.with(|c| c.set(prev));
                match poll {
                    Poll::Ready(()) => {}
                    Poll::Pending => {
                        self.inner.tasks.borrow_mut().insert(id, entry);
                    }
                }
            }

            if let Some(v) = result.borrow_mut().take() {
                return v;
            }
            if polled_any || !self.inner.incoming.borrow().is_empty() {
                continue;
            }
            if !self.inner.advance_idle() {
                panic!(
                    "executor stalled: root not finished, no runnable tasks, no timers \
                     ({} tasks parked)",
                    self.inner.tasks.borrow().len()
                );
            }
        }
    }

    /// Current instant on this executor's clock (for assertions in tests).
    pub fn now(&self) -> SimInstant {
        self.inner.current_now()
    }
}

struct CurrentGuard {
    prev: Option<Rc<Inner>>,
    prev_exec: u64,
    prev_shard: u32,
    exec_id: u64,
}

impl CurrentGuard {
    fn install(inner: Rc<Inner>) -> Self {
        let exec_id = inner.exec_id;
        let home_lane = inner.home_lane.get();
        let prev = CURRENT.with(|c| c.borrow_mut().replace(inner));
        let prev_exec = ACTIVE_EXEC.with(|c| c.replace(exec_id));
        // a nested block_on starts on its own home lane (shard 0 for
        // ordinary executors); the outer executor's lane is restored on drop
        let prev_shard = CURRENT_SHARD.with(|c| c.replace(home_lane));
        CurrentGuard { prev, prev_exec, prev_shard, exec_id }
    }
}

impl Drop for CurrentGuard {
    fn drop(&mut self) {
        CURRENT.with(|c| *c.borrow_mut() = self.prev.take());
        let prev_exec = self.prev_exec;
        let exec_id = self.exec_id;
        ACTIVE_EXEC.with(|c| c.set(prev_exec));
        CURRENT_SHARD.with(|c| c.set(self.prev_shard));
        // purge this executor's leftover local wakeups (tasks that were
        // still pending when the root finished); try_borrow so an unwind
        // mid-push cannot double-panic
        let _ = LOCAL_READY.try_with(|q| {
            if let Ok(mut q) = q.try_borrow_mut() {
                q.retain(|&(e, _)| e != exec_id);
            }
        });
    }
}

/// Thread-safe wakeup handle for external threads (real mode I/O).
#[derive(Clone)]
pub struct Remote {
    queue: Arc<WakeQueue>,
}

impl Remote {
    /// Nudge the executor loop (it will re-drain channels guarded by wakers).
    pub fn nudge(&self) {
        self.queue.condvar.notify_one();
    }
}

impl Inner {
    fn shard_count(&self) -> usize {
        self.sharded.as_ref().map_or(1, |s| s.lanes.len())
    }

    /// Resolve a spawn's lane: an explicit request wraps modulo the lane
    /// count; `None` inherits the spawning task's lane.  Unsharded
    /// executors collapse everything to 0.
    fn resolve_shard(&self, explicit: Option<usize>) -> u32 {
        match &self.sharded {
            Some(s) => {
                let shard =
                    explicit.unwrap_or_else(|| CURRENT_SHARD.with(|c| c.get()) as usize);
                (shard % s.lanes.len()) as u32
            }
            None => 0,
        }
    }

    /// Enqueue a wakeup for `id`, taking the virtual-mode thread-local
    /// fast path when running on this executor's own thread.
    fn wake_task(&self, id: u64) {
        if self.mode == Mode::Virtual
            && ACTIVE_EXEC.with(|c| c.get()) == self.exec_id
        {
            LOCAL_READY.with(|q| q.borrow_mut().push((self.exec_id, id)));
        } else {
            self.wake_queue.push(id);
        }
    }

    /// Wake a freshly spawned task whose waker does not exist yet (it is
    /// created when `incoming` drains): sharded executors stamp it into
    /// the owning lane's inbox so spawn order keeps its global position.
    fn wake_spawned(&self, id: u64, shard: u32) {
        match &self.sharded {
            Some(s) => {
                let seq = s.wake_seq.fetch_add(1, Ordering::Relaxed);
                s.lanes[shard as usize].inbox.push(seq, id);
            }
            None => self.wake_task(id),
        }
    }

    fn current_now(&self) -> SimInstant {
        match self.mode {
            Mode::Virtual => SimInstant(self.now_ns.get()),
            Mode::Real => SimInstant(self.real_anchor.elapsed().as_nanos() as u64),
        }
    }

    fn spawn_inner_on(&self, shard: u32, fut: impl Future<Output = ()> + 'static) -> u64 {
        let id = self.next_task_id.get();
        self.next_task_id.set(id + 1);
        self.incoming.borrow_mut().push((id, shard, Box::pin(fut)));
        id
    }

    fn register_timer(&self, deadline: u64, waker: Waker) {
        let seq = self.next_timer_seq.get();
        self.next_timer_seq.set(seq + 1);
        match &self.sharded {
            // the currently-polled task's lane owns its timers; the global
            // `seq` keeps cross-lane firing order identical to one heap
            Some(s) => {
                let shard = CURRENT_SHARD.with(|c| c.get()) as usize % s.lanes.len();
                s.lanes[shard]
                    .timers
                    .borrow_mut()
                    .push(Reverse(TimerEntry { deadline, seq, waker }));
            }
            None => {
                self.timers
                    .borrow_mut()
                    .push(Reverse(TimerEntry { deadline, seq, waker }));
            }
        }
    }

    /// Fire timers with deadline <= now; returns how many fired.
    fn fire_due_timers(&self) -> usize {
        let now = self.current_now().0;
        let mut fired = 0;
        if let Some(s) = &self.sharded {
            // pop due timers across lanes in global (deadline, seq) order —
            // identical to the order one shared heap would pop them in
            loop {
                let mut best: Option<((u64, u64), usize)> = None;
                for (idx, lane) in s.lanes.iter().enumerate() {
                    if let Some(Reverse(e)) = lane.timers.borrow().peek() {
                        if e.deadline <= now {
                            let key = (e.deadline, e.seq);
                            if best.map(|(k, _)| key < k).unwrap_or(true) {
                                best = Some((key, idx));
                            }
                        }
                    }
                }
                let Some((_, idx)) = best else { break };
                let Reverse(entry) = s.lanes[idx].timers.borrow_mut().pop().unwrap();
                entry.waker.wake();
                fired += 1;
            }
            return fired;
        }
        let mut timers = self.timers.borrow_mut();
        while let Some(Reverse(head)) = timers.peek() {
            if head.deadline > now {
                break;
            }
            let Reverse(entry) = timers.pop().unwrap();
            entry.waker.wake();
            fired += 1;
        }
        fired
    }

    /// Idle step: advance virtual clock to next timer, or park until one is
    /// due / an external wake arrives. Returns false on deadlock.
    fn advance_idle(&self) -> bool {
        match self.mode {
            Mode::Virtual => {
                let next = match &self.sharded {
                    // earliest deadline across every lane's heap; in the
                    // threaded milestone this is the value workers agree on
                    // at the `shard::EpochGate` before the clock moves
                    Some(s) => s
                        .lanes
                        .iter()
                        .filter_map(|l| l.timers.borrow().peek().map(|Reverse(e)| e.deadline))
                        .min(),
                    None => self.timers.borrow().peek().map(|Reverse(e)| e.deadline),
                };
                match next {
                    Some(deadline) => {
                        self.now_ns.set(self.now_ns.get().max(deadline));
                        self.fire_due_timers();
                        self.epochs.set(self.epochs.get() + 1);
                        true
                    }
                    None => false,
                }
            }
            Mode::Real => {
                if self.fire_due_timers() > 0 {
                    return true;
                }
                let next = self.timers.borrow().peek().map(|Reverse(e)| e.deadline);
                let q = self.wake_queue.queue.lock().unwrap();
                if !q.is_empty() {
                    return true;
                }
                match next {
                    Some(deadline) => {
                        let now = self.current_now().0;
                        let wait = Duration::from_nanos(deadline.saturating_sub(now));
                        let (guard, _timeout) = self
                            .wake_queue
                            .condvar
                            .wait_timeout(q, wait)
                            .unwrap();
                        drop(guard);
                        self.fire_due_timers();
                        true
                    }
                    None => {
                        // No timers: only an external wake can unblock us.
                        let (guard, timeout) = self
                            .wake_queue
                            .condvar
                            .wait_timeout(q, Duration::from_millis(50))
                            .unwrap();
                        let empty = guard.is_empty();
                        drop(guard);
                        // Spin while external I/O threads are alive; a truly
                        // stalled real-mode executor keeps polling (it cannot
                        // distinguish deadlock from quiescent I/O).
                        let _ = timeout;
                        let _ = empty;
                        true
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// public task & time API (free functions, tokio-flavored)
// ---------------------------------------------------------------------------

/// Spawn a task on the current executor; returns a [`JoinHandle`].
/// On a sharded executor the task inherits the spawner's shard.
pub fn spawn<T: 'static>(fut: impl Future<Output = T> + 'static) -> JoinHandle<T> {
    spawn_with(None, fut)
}

/// Spawn pinned to `shard` (wrapped modulo the executor's shard count) —
/// how the dispatcher keeps a remote call's task on the lane of the node
/// that executes it.  On an unsharded executor this is exactly [`spawn`].
pub fn spawn_on<T: 'static>(
    shard: usize,
    fut: impl Future<Output = T> + 'static,
) -> JoinHandle<T> {
    spawn_with(Some(shard), fut)
}

fn spawn_with<T: 'static>(
    shard: Option<usize>,
    fut: impl Future<Output = T> + 'static,
) -> JoinHandle<T> {
    let state = Rc::new(RefCell::new(JoinState::<T> { value: None, waker: None }));
    let state2 = Rc::clone(&state);
    let id = with_current(|inner| {
        let shard = inner.resolve_shard(shard);
        let id = inner.spawn_inner_on(shard, async move {
            let value = fut.await;
            let mut s = state2.borrow_mut();
            s.value = Some(value);
            if let Some(w) = s.waker.take() {
                w.wake();
            }
        });
        inner.wake_spawned(id, shard);
        id
    });
    JoinHandle { state, id }
}

/// Shard of the currently-polled task (0 on unsharded executors and
/// outside task polls).
pub fn current_shard() -> usize {
    CURRENT_SHARD.with(|c| c.get()) as usize
}

/// Lane count of the running executor (1 when unsharded).
pub fn shard_count() -> usize {
    with_current(|inner| inner.shard_count())
}

/// Discrete-event epochs completed so far (virtual-clock advances) — the
/// unit the sharded core's barrier synchronizes on; equal across shard
/// counts for a pinned seed, which the fig9 parity check exploits.
pub fn epochs() -> u64 {
    with_current(|inner| inner.epochs.get())
}

struct JoinState<T> {
    value: Option<T>,
    waker: Option<Waker>,
}

/// Await the result of a spawned task.
pub struct JoinHandle<T> {
    state: Rc<RefCell<JoinState<T>>>,
    #[allow(dead_code)]
    id: u64,
}

impl<T> Future for JoinHandle<T> {
    type Output = T;
    fn poll(self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<T> {
        let mut s = self.state.borrow_mut();
        if let Some(v) = s.value.take() {
            Poll::Ready(v)
        } else {
            s.waker = Some(cx.waker().clone());
            Poll::Pending
        }
    }
}

/// Current instant on the running executor's clock.
pub fn now() -> SimInstant {
    with_current(|inner| inner.current_now())
}

/// Sleep for `dur` on the executor clock (virtual: may complete instantly
/// in wall time; ordering across tasks is preserved).
pub fn sleep(dur: Duration) -> Sleep {
    Sleep { dur, deadline: None }
}

/// Sleep specified in (possibly fractional) milliseconds.
pub fn sleep_ms(ms: f64) -> Sleep {
    sleep(Duration::from_nanos((ms.max(0.0) * 1e6) as u64))
}

pub struct Sleep {
    dur: Duration,
    deadline: Option<u64>,
}

impl Future for Sleep {
    type Output = ();
    fn poll(mut self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<()> {
        with_current(|inner| {
            let now = inner.current_now().0;
            let dur_ns = self.dur.as_nanos() as u64;
            let deadline = *self.deadline.get_or_insert(now + dur_ns);
            if now >= deadline {
                Poll::Ready(())
            } else {
                inner.register_timer(deadline, cx.waker().clone());
                Poll::Pending
            }
        })
    }
}

/// Yield once (re-queue at the back of the ready list).
pub fn yield_now() -> YieldNow {
    YieldNow { yielded: false }
}

pub struct YieldNow {
    yielded: bool,
}

impl Future for YieldNow {
    type Output = ();
    fn poll(mut self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<()> {
        if self.yielded {
            Poll::Ready(())
        } else {
            self.yielded = true;
            cx.waker().wake_by_ref();
            Poll::Pending
        }
    }
}

/// Outcome of [`timeout`].
#[derive(Debug, PartialEq, Eq)]
pub struct Elapsed;

/// Run `fut` with a deadline on the executor clock.
pub async fn timeout<T>(
    dur: Duration,
    fut: impl Future<Output = T>,
) -> std::result::Result<T, Elapsed> {
    let mut fut = Box::pin(fut);
    let mut slept = Box::pin(sleep(dur));
    std::future::poll_fn(move |cx| {
        if let Poll::Ready(v) = fut.as_mut().poll(cx) {
            return Poll::Ready(Ok(v));
        }
        if slept.as_mut().poll(cx).is_ready() {
            return Poll::Ready(Err(Elapsed));
        }
        Poll::Pending
    })
    .await
}

/// Convenience: run a future on a fresh virtual-clock executor.
pub fn run_virtual<T: 'static>(fut: impl Future<Output = T> + 'static) -> T {
    Executor::new(Mode::Virtual).block_on(fut)
}

// ---------------------------------------------------------------------------
// resumable execution (the threaded core's per-lane drain loop)
// ---------------------------------------------------------------------------

/// Outcome of one [`Stepper::pump_until`] call.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Pump {
    /// the root future completed (its value is held until
    /// [`Stepper::into_result`])
    Done,
    /// no runnable work at or before the window bound; `next_deadline` is
    /// the earliest pending timer (ns), `progressed` whether anything was
    /// polled or fired during this pump — the [`shard::LaneReport`] pair
    /// the threaded core hands its governor
    Idle { next_deadline: Option<u64>, progressed: bool },
}

/// A virtual-clock executor driven in bounded slices instead of to
/// completion: [`Stepper::pump_until`] runs the scheduler loop exactly as
/// [`Executor::block_on`] would, but stops advancing the clock at a caller
/// -supplied bound and reports back instead of panicking when it runs dry.
///
/// This is the per-lane drain loop of the threaded simulation core
/// ([`threads`]): each worker thread owns the steppers of the lanes
/// assigned to it and pumps them window by window under the
/// [`shard::WindowGovernor`].  Between pumps, wakes from other threads
/// land in the executor's thread-safe wake queue and are drained by the
/// next pump; everything else about the schedule — poll order, timer
/// order, clock arithmetic — is byte-for-byte the single-threaded loop,
/// which is what keeps a pumped schedule bit-identical to a `block_on` of
/// the same root (window boundaries never create or reorder clock points).
pub struct Stepper<T> {
    exec: Executor,
    result: Rc<RefCell<Option<T>>>,
    done: bool,
}

impl<T: 'static> Stepper<T> {
    /// Stepper for `root` on a fresh single-lane virtual executor.
    pub fn new(root: impl Future<Output = T> + 'static) -> Self {
        Self::on_lane(0, root)
    }

    /// Like [`Stepper::new`], with [`current_shard`] reporting `lane`
    /// inside this stepper's polls — how threaded-core tenants observe
    /// the worker lane hosting them.
    pub fn on_lane(lane: u32, root: impl Future<Output = T> + 'static) -> Self {
        let exec = Executor::new(Mode::Virtual);
        exec.inner.home_lane.set(lane);
        let result: Rc<RefCell<Option<T>>> = Rc::new(RefCell::new(None));
        let result2 = Rc::clone(&result);
        let root_id = exec.inner.spawn_inner_on(0, async move {
            *result2.borrow_mut() = Some(root.await);
        });
        exec.inner.wake_spawned(root_id, 0);
        Stepper { exec, result, done: false }
    }

    /// Run until the root completes or nothing is runnable at or before
    /// `bound_ns` on the virtual clock.  Mirrors the unsharded
    /// `block_on` loop exactly, except the idle step refuses to advance
    /// the clock past the bound.  Safe to call again after `Idle` (the
    /// usual case) and after `Done` (returns `Done` immediately).
    pub fn pump_until(&mut self, bound_ns: u64) -> Pump {
        if self.done {
            return Pump::Done;
        }
        let guard = CurrentGuard::install(Rc::clone(&self.exec.inner));
        let inner = &self.exec.inner;
        let mut ready: Vec<u64> = Vec::new();
        let mut progressed = false;
        let outcome = loop {
            {
                let mut incoming = inner.incoming.borrow_mut();
                if !incoming.is_empty() {
                    let mut tasks = inner.tasks.borrow_mut();
                    for (id, shard, future) in incoming.drain(..) {
                        let waker = Waker::from(Arc::new(TaskWaker {
                            id,
                            exec_id: inner.exec_id,
                            fast_local: true,
                            queue: Arc::clone(&inner.wake_queue),
                            lane: None,
                        }));
                        tasks.insert(id, TaskEntry { future, waker, shard });
                    }
                }
            }

            ready.clear();
            inner.wake_queue.drain_into(&mut ready);
            drain_local_ready(inner.exec_id, &mut ready);
            let mut polled_any = false;
            for &id in ready.iter() {
                let entry = inner.tasks.borrow_mut().remove(&id);
                let Some(mut entry) = entry else { continue }; // completed or duplicate wake
                polled_any = true;
                let mut cx = Context::from_waker(&entry.waker);
                match entry.future.as_mut().poll(&mut cx) {
                    Poll::Ready(()) => {}
                    Poll::Pending => {
                        inner.tasks.borrow_mut().insert(id, entry);
                    }
                }
            }
            progressed |= polled_any;

            if self.result.borrow().is_some() {
                break Pump::Done;
            }
            if polled_any || !inner.incoming.borrow().is_empty() {
                continue;
            }
            // Nothing runnable: the bounded idle step.  Same clock
            // arithmetic as `advance_idle`, stopping at the bound.
            let next = inner.timers.borrow().peek().map(|Reverse(e)| e.deadline);
            match next {
                Some(deadline) if deadline <= bound_ns => {
                    inner.now_ns.set(inner.now_ns.get().max(deadline));
                    inner.fire_due_timers();
                    inner.epochs.set(inner.epochs.get() + 1);
                    progressed = true;
                }
                next_deadline => break Pump::Idle { next_deadline, progressed },
            }
        };
        drop(guard);
        if outcome == Pump::Done {
            self.done = true;
        }
        outcome
    }

    /// Discrete-event epochs this stepper's executor has completed.
    pub fn epochs(&self) -> u64 {
        self.exec.inner.epochs.get()
    }

    /// Current instant on this stepper's virtual clock.
    pub fn now(&self) -> SimInstant {
        self.exec.inner.current_now()
    }

    /// The root's value, if it completed.
    pub fn into_result(self) -> Option<T> {
        self.result.borrow_mut().take()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn virtual_sleep_is_instant_in_wall_time() {
        let wall = Instant::now();
        let ex = Executor::new(Mode::Virtual);
        ex.block_on(async {
            sleep(Duration::from_secs(3600)).await;
            assert_eq!(now().0, 3_600_000_000_000);
        });
        assert!(wall.elapsed() < Duration::from_millis(200));
    }

    #[test]
    fn sleeps_order_across_tasks() {
        run_virtual(async {
            let log = Rc::new(RefCell::new(Vec::new()));
            let mut handles = Vec::new();
            for (tag, ms) in [("c", 30.0), ("a", 10.0), ("b", 20.0)] {
                let log = Rc::clone(&log);
                handles.push(spawn(async move {
                    sleep_ms(ms).await;
                    log.borrow_mut().push(tag);
                }));
            }
            for h in handles {
                h.await;
            }
            assert_eq!(*log.borrow(), vec!["a", "b", "c"]);
        });
    }

    #[test]
    fn nested_spawns_run() {
        let total = run_virtual(async {
            let h = spawn(async {
                let inner = spawn(async {
                    sleep_ms(1.0).await;
                    21
                });
                inner.await + 21
            });
            h.await
        });
        assert_eq!(total, 42);
    }

    #[test]
    fn timeout_fires() {
        run_virtual(async {
            let r = timeout(Duration::from_millis(5), sleep_ms(50.0)).await;
            assert_eq!(r, Err(Elapsed));
            assert_eq!(now().as_millis_f64(), 5.0);
            let r = timeout(Duration::from_millis(100), async { 7 }).await;
            assert_eq!(r, Ok(7));
        });
    }

    #[test]
    fn deterministic_interleaving() {
        fn run_once() -> Vec<(u32, u64)> {
            run_virtual(async {
                let log = Rc::new(RefCell::new(Vec::new()));
                let mut handles = Vec::new();
                for i in 0..20u32 {
                    let log = Rc::clone(&log);
                    handles.push(spawn(async move {
                        sleep_ms(((i * 7) % 13) as f64).await;
                        log.borrow_mut().push((i, now().0));
                        sleep_ms((i % 3) as f64).await;
                        log.borrow_mut().push((i + 100, now().0));
                    }));
                }
                for h in handles {
                    h.await;
                }
                Rc::try_unwrap(log).unwrap().into_inner()
            })
        }
        assert_eq!(run_once(), run_once());
    }

    #[test]
    fn zero_sleep_completes() {
        run_virtual(async {
            sleep_ms(0.0).await;
            yield_now().await;
        });
    }

    #[test]
    #[should_panic(expected = "executor stalled")]
    fn deadlock_panics_in_virtual_mode() {
        run_virtual(async {
            std::future::poll_fn::<(), _>(|_| Poll::Pending).await;
        });
    }

    #[test]
    fn real_mode_sleep_actually_sleeps() {
        let ex = Executor::new(Mode::Real);
        let wall = Instant::now();
        ex.block_on(async {
            sleep(Duration::from_millis(30)).await;
        });
        assert!(wall.elapsed() >= Duration::from_millis(25));
    }

    #[test]
    fn real_mode_external_thread_wakes_executor() {
        // models the HTTP front end: an OS thread sends into an mpsc whose
        // receiver lives on a Real-mode executor with no timers pending
        let ex = Executor::new(Mode::Real);
        let (tx, mut rx) = crate::exec::channel::mpsc::<u32>();
        let remote = ex.remote();
        std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(40));
            tx.send(7).unwrap();
            remote.nudge();
        });
        let got = ex.block_on(async move { rx.recv().await });
        assert_eq!(got, Some(7));
    }

    #[test]
    fn join_handle_found_after_task_completes() {
        run_virtual(async {
            let h = spawn(async { 5u8 });
            sleep_ms(10.0).await; // task finishes long before we join
            assert_eq!(h.await, 5);
        });
    }

    #[test]
    fn timeout_zero_duration_still_polls_future_first() {
        run_virtual(async {
            // an immediately-ready future wins over a zero timeout
            let r = timeout(Duration::from_millis(0), async { 1u8 }).await;
            assert_eq!(r, Ok(1));
        });
    }

    #[test]
    fn nested_virtual_executors_do_not_cross_wake() {
        // The thread-local ready list tags entries with the executor id:
        // an inner block_on must neither steal nor drop the outer
        // executor's pending wakeups.
        run_virtual(async {
            let h = spawn(async {
                sleep_ms(5.0).await;
                7u32
            });
            let inner = Executor::new(Mode::Virtual).block_on(async {
                let a = spawn(async {
                    sleep_ms(1.0).await;
                    1u32
                });
                a.await + 1
            });
            assert_eq!(inner, 2);
            assert_eq!(h.await, 7);
        });
    }

    #[test]
    fn sharded_schedule_bit_identical_across_shard_counts() {
        // the tentpole invariant: the merged N-shard schedule replays the
        // 1-shard schedule exactly — same poll order, same timestamps —
        // even with tasks scattered across lanes on purpose
        fn run_once(shards: usize) -> (Vec<(u32, u64)>, u64) {
            Executor::sharded(Mode::Virtual, shards).block_on(async move {
                let log = Rc::new(RefCell::new(Vec::new()));
                let mut handles = Vec::new();
                for i in 0..24u32 {
                    let log = Rc::clone(&log);
                    handles.push(spawn_on(i as usize, async move {
                        sleep_ms(((i * 7) % 13) as f64).await;
                        log.borrow_mut().push((i, now().0));
                        sleep_ms((i % 3) as f64).await;
                        log.borrow_mut().push((i + 100, now().0));
                    }));
                }
                for h in handles {
                    h.await;
                }
                let log = Rc::try_unwrap(log).unwrap().into_inner();
                (log, epochs())
            })
        }
        let single = run_once(1);
        assert_eq!(single, run_once(2));
        assert_eq!(single, run_once(3));
        assert_eq!(single, run_once(7));
    }

    #[test]
    fn spawn_on_pins_and_spawn_inherits_the_lane() {
        Executor::sharded(Mode::Virtual, 3).block_on(async {
            assert_eq!(shard_count(), 3);
            assert_eq!(current_shard(), 0); // root lives on the control lane
            let h = spawn_on(1, async {
                assert_eq!(current_shard(), 1);
                // plain spawn inherits the spawner's lane
                let child = spawn(async { current_shard() });
                // explicit shard wraps modulo the lane count
                let wrapped = spawn_on(5, async { current_shard() });
                (child.await, wrapped.await)
            });
            assert_eq!(h.await, (1, 2));
        });
    }

    #[test]
    #[should_panic(expected = "sharded execution requires Mode::Virtual")]
    fn real_mode_rejects_multiple_shards() {
        let _ = Executor::sharded(Mode::Real, 2);
    }

    #[test]
    fn sharded_single_lane_is_the_legacy_executor() {
        // shards == 1 must take the unsharded fast path (Executor::new is
        // defined as sharded(mode, 1)); behavior and clock agree
        let ex = Executor::sharded(Mode::Virtual, 1);
        assert_eq!(ex.shards(), 1);
        ex.block_on(async {
            assert_eq!(shard_count(), 1);
            let h = spawn_on(9, async { current_shard() }); // wraps to 0
            assert_eq!(h.await, 0);
            sleep_ms(5.0).await;
            assert_eq!(now().as_millis_f64(), 5.0);
        });
    }

    #[test]
    fn nested_executor_inside_a_sharded_task_stays_isolated() {
        // a task on lane 2 runs a whole inner (sharded) executor to
        // completion; the outer executor's pending wakeups and the task's
        // lane must survive untouched
        Executor::sharded(Mode::Virtual, 3).block_on(async {
            let outer = spawn_on(1, async {
                sleep_ms(5.0).await;
                7u32
            });
            let h = spawn_on(2, async {
                let inner = Executor::sharded(Mode::Virtual, 2).block_on(async {
                    assert_eq!(shard_count(), 2);
                    let a = spawn_on(1, async {
                        sleep_ms(1.0).await;
                        current_shard() as u32
                    });
                    a.await + 1
                });
                // back on the outer executor: still lane 2
                assert_eq!(current_shard(), 2);
                inner
            });
            assert_eq!(h.await, 2);
            assert_eq!(outer.await, 7);
        });
    }

    #[test]
    fn stepper_replays_block_on_bit_for_bit() {
        // the threaded-core invariant: pumping a schedule in windows must
        // reproduce the block_on schedule exactly — same poll order, same
        // timestamps, same epoch count
        fn workload(log: Rc<RefCell<Vec<(u32, u64)>>>) -> impl Future<Output = ()> {
            async move {
                let mut handles = Vec::new();
                for i in 0..20u32 {
                    let log = Rc::clone(&log);
                    handles.push(spawn(async move {
                        sleep_ms(((i * 7) % 13) as f64).await;
                        log.borrow_mut().push((i, now().0));
                        sleep_ms((i % 3) as f64).await;
                        log.borrow_mut().push((i + 100, now().0));
                    }));
                }
                for h in handles {
                    h.await;
                }
            }
        }
        let baseline = {
            let log = Rc::new(RefCell::new(Vec::new()));
            let ex = Executor::new(Mode::Virtual);
            ex.block_on(workload(Rc::clone(&log)));
            Rc::try_unwrap(log).unwrap().into_inner()
        };
        // pump in 1ms windows
        let log = Rc::new(RefCell::new(Vec::new()));
        let mut stepper = Stepper::new(workload(Rc::clone(&log)));
        let mut bound = 0;
        let mut pumps = 0;
        loop {
            match stepper.pump_until(bound) {
                Pump::Done => break,
                Pump::Idle { next_deadline, .. } => {
                    bound = next_deadline.expect("root unfinished yet no timers");
                    pumps += 1;
                    assert!(pumps < 10_000, "stepper failed to make progress");
                }
            }
        }
        assert_eq!(stepper.into_result(), Some(()));
        assert_eq!(Rc::try_unwrap(log).unwrap().into_inner(), baseline);
    }

    #[test]
    fn stepper_idles_at_the_bound_without_advancing_past_it() {
        let mut stepper = Stepper::new(async {
            sleep_ms(10.0).await;
            42u32
        });
        // bound below the deadline: runs t=0 work, reports the deadline
        match stepper.pump_until(5_000_000) {
            Pump::Idle { next_deadline, progressed } => {
                assert_eq!(next_deadline, Some(10_000_000));
                assert!(progressed); // the root was polled to its first await
            }
            done => panic!("unexpected {done:?}"),
        }
        assert_eq!(stepper.now().0, 0); // clock never passed the bound
        // an idle re-pump below the bound reports no progress
        match stepper.pump_until(5_000_000) {
            Pump::Idle { next_deadline, progressed } => {
                assert_eq!(next_deadline, Some(10_000_000));
                assert!(!progressed);
            }
            done => panic!("unexpected {done:?}"),
        }
        // bound at the deadline: completes
        assert_eq!(stepper.pump_until(10_000_000), Pump::Done);
        // pumping a finished stepper is a no-op
        assert_eq!(stepper.pump_until(u64::MAX), Pump::Done);
        assert_eq!(stepper.epochs(), 1);
        assert_eq!(stepper.into_result(), Some(42));
    }

    #[test]
    fn stepper_on_lane_reports_its_home_shard() {
        let mut stepper = Stepper::on_lane(3, async { current_shard() });
        assert_eq!(stepper.pump_until(0), Pump::Done);
        assert_eq!(stepper.into_result(), Some(3));
        // ordinary executors still report lane 0
        assert_eq!(run_virtual(async { current_shard() }), 0);
    }

    #[test]
    fn stepper_receives_cross_thread_wakes_between_pumps() {
        // a waker captured by another thread lands in the thread-safe wake
        // queue while the stepper is idle; the next pump drains it
        let (tx, mut rx) = crate::exec::channel::mpsc::<u32>();
        let mut stepper = Stepper::new(async move { rx.recv().await });
        match stepper.pump_until(u64::MAX) {
            Pump::Idle { next_deadline, .. } => assert_eq!(next_deadline, None),
            done => panic!("unexpected {done:?}"),
        }
        tx.send(9).unwrap();
        assert_eq!(stepper.pump_until(u64::MAX), Pump::Done);
        assert_eq!(stepper.into_result(), Some(Some(9)));
    }

    #[test]
    fn many_tasks_throughput() {
        let n = run_virtual(async {
            let mut handles = Vec::new();
            for i in 0..10_000u64 {
                handles.push(spawn(async move {
                    sleep_ms((i % 97) as f64).await;
                    1u64
                }));
            }
            let mut total = 0;
            for h in handles {
                total += h.await;
            }
            total
        });
        assert_eq!(n, 10_000);
    }
}
